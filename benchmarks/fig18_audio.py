"""Fig. 18 reproduction: FEx response to a "yes" keyword — low channels
light up for the voiced /ye/, high channels for the fricative /s/."""

import jax
import numpy as np

from repro.core.calibration import calibrate_chip
from repro.core.tdfex import TDFExConfig, counts_to_fv_raw, draw_chip, tdfex_raw_counts
from repro.data.gscd import GSCDSynthConfig, _TEMPLATES, synth_keyword


def run(seed: int = 0):
    print('== Fig. 18: FEx audio response to "yes" ==')
    cfg = TDFExConfig()
    chip = draw_chip(jax.random.PRNGKey(seed), cfg)
    beta, alpha = calibrate_chip(cfg, chip)

    rng = np.random.default_rng(seed)
    scfg = GSCDSynthConfig(amplitude=0.127)  # ~254 mVpp, like the paper
    audio = synth_keyword(rng, _TEMPLATES["yes"], scfg)[None, :]
    counts = tdfex_raw_counts(
        jax_arr(audio), cfg, chip
    )
    fv = np.asarray(counts_to_fv_raw(counts, cfg, beta, alpha))[0]
    # normalize per Fig. 18 (offset/std of the clip)
    fvn = (fv - fv.mean(0)) / (fv.std(0) + 1e-6)

    # voiced segment = frames with most low-channel energy;
    # fricative = frames with most high-channel energy
    energy = fv.sum(-1)
    active = energy > energy.mean()
    low = fvn[:, :6].mean(-1)
    high = fvn[:, 11:].mean(-1)
    voiced_frames = np.argsort(low)[-8:]
    fric_frames = np.argsort(high)[-8:]
    lo_ratio = fvn[voiced_frames][:, :6].mean() - fvn[voiced_frames][:, 11:].mean()
    hi_ratio = fvn[fric_frames][:, 11:].mean() - fvn[fric_frames][:, :6].mean()
    print(f"  voiced /ye/ frames: low-high channel contrast {lo_ratio:+.2f}")
    print(f"  fricative /s/ frames: high-low channel contrast {hi_ratio:+.2f}")
    ok = lo_ratio > 0.3 and hi_ratio > 0.3 and bool(active.any())
    print(f"  claim (formant vs fricative bands separate): "
          f"{'PASS' if ok else 'FAIL'}")
    return {"lo_contrast": float(lo_ratio), "hi_contrast": float(hi_ratio),
            "ok": ok}


def jax_arr(x):
    import jax.numpy as jnp

    return jnp.asarray(x)


if __name__ == "__main__":
    run()
