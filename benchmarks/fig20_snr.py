"""Fig. 20 reproduction: KWS accuracy vs added feature-domain noise.

Gaussian noise of power P_Avg,GSCD/SNR is added to FV_Raw (train with
noisy features, evaluate with fresh noise — the paper retrains per SNR);
claim: accuracy degrades gracefully, <1% drop at 40 dB SNR."""

import numpy as np

from benchmarks.common import (
    QUICK,
    datasets,
    evaluate,
    frames_to_features,
    record_software_frames,
    train_classifier,
)
from repro.core import quant
from repro.core.fex import FExConfig


def run(seed: int = 0):
    print("== Fig. 20: accuracy vs SNR (feature-domain noise) ==")
    cfg = FExConfig()
    train, test = datasets(seed)
    fr_tr = record_software_frames(train["audio"], cfg)
    fr_te = record_software_frames(test["audio"], cfg)
    raw_tr = np.asarray(quant.quantize_unsigned(
        fr_tr, cfg.quant_bits, cfg.quant_full_scale))
    raw_te = np.asarray(quant.quantize_unsigned(
        fr_te, cfg.quant_bits, cfg.quant_full_scale))
    p_avg = float((raw_tr.astype(np.float64) ** 2).mean())

    rng = np.random.default_rng(seed + 5)
    snrs = [np.inf, 40.0, 20.0, 10.0] if QUICK else [
        np.inf, 50.0, 40.0, 30.0, 20.0, 10.0, 5.0]
    accs = {}
    for snr in snrs:
        if np.isinf(snr):
            n_tr = n_te = 0.0
        else:
            sigma = np.sqrt(p_avg / (10 ** (snr / 10)))
            n_tr = rng.normal(0, sigma, raw_tr.shape)
            n_te = rng.normal(0, sigma, raw_te.shape)
        tr = np.clip(raw_tr + n_tr, 0, 4095)
        te = np.clip(raw_te + n_te, 0, 4095)
        ftr, stats = frames_to_features(tr, cfg, True, True,
                                        already_raw=True)
        fte, _ = frames_to_features(te, cfg, True, True, stats=stats,
                                    already_raw=True)
        model = train_classifier(ftr, train["label"], seed=seed)
        acc, _ = evaluate(model, fte, test["label"])
        accs[snr] = acc
        label = "clean" if np.isinf(snr) else f"{snr:4.0f} dB"
        print(f"  SNR {label}: {acc:6.2%}")

    drop40 = accs[np.inf] - accs.get(40.0, accs[np.inf])
    monotone_ok = accs[10.0] <= accs[np.inf] + 0.02
    print(f"  drop at 40 dB SNR: {drop40:+.2%} (paper: <1%)")
    ok = drop40 < 0.05 and monotone_ok
    print(f"  claim (graceful degradation): {'PASS' if ok else 'FAIL'}")
    return {"accs": {str(k): v for k, v in accs.items()}, "ok": ok}


if __name__ == "__main__":
    run()
