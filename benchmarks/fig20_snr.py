"""Fig. 20 reproduction: KWS accuracy vs added feature-domain noise.

Gaussian noise of power P_Avg,GSCD/SNR is added to FV_Raw (train with
noisy features, evaluate with fresh noise — the paper retrains per SNR);
claim: accuracy degrades gracefully, <1% drop at 40 dB SNR.

Each SNR point also reports the stage-1 cascade detector's behaviour
on its (noisy, normalized) test features — the energy detector of
`repro.serving.cascade` at a fixed wake threshold: the fraction of
frames it would wake the classifier on (``wake``) and the fraction of
speech examples (label != silence) with no waking frame at all
(``FR``, a stage-1 false reject — the classifier never sees the
utterance). Feature-domain noise raises the rectified energy of every
frame, so the gate opens more, never less: noise degrades the
cascade's duty-cycle savings, not its recall."""

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    QUICK,
    datasets,
    evaluate,
    frames_to_features,
    record_software_frames,
    train_classifier,
)
from repro.core import quant
from repro.core.fex import FExConfig
from repro.serving.cascade import CascadeConfig, detector_scores

# the stage-1 operating point reported per SNR (energy detector on the
# normalized feature frame; matches the fig_cascade_roc sweep's knee)
GATE = CascadeConfig(detector="energy", wake_threshold=0.1)


def run(seed: int = 0):
    print("== Fig. 20: accuracy vs SNR (feature-domain noise) ==")
    cfg = FExConfig()
    train, test = datasets(seed)
    fr_tr = record_software_frames(train["audio"], cfg)
    fr_te = record_software_frames(test["audio"], cfg)
    raw_tr = np.asarray(quant.quantize_unsigned(
        fr_tr, cfg.quant_bits, cfg.quant_full_scale))
    raw_te = np.asarray(quant.quantize_unsigned(
        fr_te, cfg.quant_bits, cfg.quant_full_scale))
    p_avg = float((raw_tr.astype(np.float64) ** 2).mean())

    rng = np.random.default_rng(seed + 5)
    snrs = [np.inf, 40.0, 20.0, 10.0] if QUICK else [
        np.inf, 50.0, 40.0, 30.0, 20.0, 10.0, 5.0]
    accs = {}
    stage1 = {}
    speech = np.asarray(test["label"]) != 0  # silence is class 0
    for snr in snrs:
        if np.isinf(snr):
            n_tr = n_te = 0.0
        else:
            sigma = np.sqrt(p_avg / (10 ** (snr / 10)))
            n_tr = rng.normal(0, sigma, raw_tr.shape)
            n_te = rng.normal(0, sigma, raw_te.shape)
        tr = np.clip(raw_tr + n_tr, 0, 4095)
        te = np.clip(raw_te + n_te, 0, 4095)
        ftr, stats = frames_to_features(tr, cfg, True, True,
                                        already_raw=True)
        fte, _ = frames_to_features(te, cfg, True, True, stats=stats,
                                    already_raw=True)
        model = train_classifier(ftr, train["label"], seed=seed)
        acc, _ = evaluate(model, fte, test["label"])
        accs[snr] = acc
        # stage-1 cascade detector on the same noisy test features:
        # which frames would wake the classifier, and does every speech
        # example wake it at least once?
        fired = np.asarray(
            detector_scores(jnp.asarray(fte), GATE)
        ) >= GATE.wake_threshold
        wake = float(fired.mean())
        false_reject = float((~fired.any(axis=-1))[speech].mean())
        stage1[snr] = {"wake_rate": wake, "false_reject": false_reject}
        label = "clean" if np.isinf(snr) else f"{snr:4.0f} dB"
        print(f"  SNR {label}: {acc:6.2%}  "
              f"(stage-1 wake {wake:5.1%}, FR {false_reject:5.1%})")

    drop40 = accs[np.inf] - accs.get(40.0, accs[np.inf])
    monotone_ok = accs[10.0] <= accs[np.inf] + 0.02
    print(f"  drop at 40 dB SNR: {drop40:+.2%} (paper: <1%)")
    ok = drop40 < 0.05 and monotone_ok
    print(f"  claim (graceful degradation): {'PASS' if ok else 'FAIL'}")
    return {
        "accs": {str(k): v for k, v in accs.items()},
        "stage1": {str(k): v for k, v in stage1.items()},
        "ok": ok,
    }


if __name__ == "__main__":
    run()
