"""Benchmark harness: one module per paper table/figure + the roofline
table. `python -m benchmarks.run` (quick) or BENCH_FULL=1 for the
full-size runs. Each module prints its own PASS/FAIL claim check."""

import sys
import time


def main():
    from benchmarks import (
        fig2_ablation,
        fig17_freq_response,
        fig17c_spectrum,
        fig18_audio,
        fig19_accuracy,
        fig20_snr,
        fig_delta_tradeoff,
        serve_load,
        table1_fom,
        table2_system,
        roofline_bench,
    )

    modules = [
        ("table2_system", table2_system),
        ("table1_fom", table1_fom),
        ("fig17_freq_response", fig17_freq_response),
        ("fig17c_spectrum", fig17c_spectrum),
        ("fig18_audio", fig18_audio),
        ("fig2_ablation", fig2_ablation),
        ("fig19_accuracy", fig19_accuracy),
        ("fig20_snr", fig20_snr),
        ("fig_delta_tradeoff", fig_delta_tradeoff),
        ("roofline", roofline_bench),
        ("serve_load", serve_load),
    ]
    results = {}
    t0 = time.time()
    failures = []
    for name, mod in modules:
        t = time.time()
        try:
            results[name] = mod.run()
            if not results[name].get("ok", True):
                failures.append(name)
        except Exception as e:  # noqa: BLE001
            import traceback

            traceback.print_exc()
            failures.append(name)
        print(f"  ({name}: {time.time() - t:.1f}s)\n")
    print("=" * 60)
    print(f"benchmarks: {len(modules) - len(failures)}/{len(modules)} "
          f"claims PASS in {time.time() - t0:.0f}s")
    if failures:
        print("FAILED:", ", ".join(failures))
        sys.exit(1)


if __name__ == "__main__":
    main()
