"""Elastic serving under churn: open/close load, live resizes, and
injected shard loss.

Where benchmarks/serve_load.py measures steady-state throughput at a
fixed capacity, this generator drives the elastic serving stack the way
a deployment actually stresses it — a three-phase open/close schedule
(``ramp`` -> ``peak`` -> ``drain``) with Poisson arrivals and per-stream
departures, while the occupancy/SLO autoscaler
(`repro.serving.autoscale.Autoscaler`) watches every tick and calls
`StreamingKWSServer.resize` live:

  * ``ramp``  — arrivals push occupancy through the grow watermark;
    the autoscaler doubles capacity (possibly repeatedly). Arrivals
    that land while capacity lags the offered load are REJECTED at
    `open_stream` and fed back as `note_rejection()` — the immediate
    grow signal.
  * ``peak``  — steady churn at high occupancy. With ``--shard-loss``
    (and a multi-device server) one shard is lost mid-peak:
    `recover_shard_loss` shrink-reshards onto the survivors, reopens
    the lost shard's streams, and the bench VERIFIES in-line that every
    healthy stream's per-slot state is bit-unchanged through the move
    (the recovery contract of tests/test_serve_sharded.py, re-checked
    on the benchmark's own traffic).
  * ``drain`` — departures dominate; occupancy falls through the
    shrink watermark and the autoscaler halves capacity under
    hysteresis, SLO veto, and the open-streams block floor.

Tick latencies come from the server's own observability layer
(``metrics=True``): each blocking `step_batch` observes into the
``kws_serve_tick_ms`` histogram, and the bench reads ``.last`` off it.
A tick that traced+compiled a fresh program — the first tick ever, and
the first tick at any slot width this program set has not served yet —
is excluded from the steady-state percentiles EXACTLY, by comparing
`srv.retrace_count` around the call (the counted shape-keyed retraces
of the serving stack; the old next-tick-after-resize heuristic both
missed recompiles it didn't know about and excluded warm cache-hit
ticks after a resize back to a seen capacity). Compile ticks are
recorded separately (``resize.post_change_compile_ms``), as are the
in-band pauses of the `resize()` / `recover_shard_loss()` calls
themselves (``pause_ms`` / ``recovery_ms``); the full registry
snapshot (histograms, decision journal, gauges) lands in
``METRICS_churn.json`` next to the BENCH artifact.

Writes ``BENCH_churn.json`` (every field documented in
benchmarks/common.py, ``BENCH_CHURN_FIELDS``) and gates an SLO block:
steady-state peak p99 within the 16 ms tick budget, the rejection rate
within budget, and the elasticity smoke — the autoscaler actually grew
during ramp and shrank during drain, and injected shard loss left the
healthy streams bit-unchanged. ``--fail-on-slo`` turns a violated gate
into a non-zero exit for CI.

  PYTHONPATH=src python -m benchmarks.churn_load [--classifier qat]
      [--devices 1] [--shard-loss] [--seed 0] [--fail-on-slo]

Multi-device runs (``--devices 2``) need visible devices; emulate on
CPU with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from benchmarks.common import QUICK
from benchmarks.serve_load import _pipeline
from repro.distributed.fault_tolerance import StragglerMonitor
from repro.serving.autoscale import Autoscaler, AutoscalePolicy, shard_of_slot
from repro.serving.serve_loop import StreamingKWSServer

# phase schedule: (name, n_ticks, arrival rate per tick, per-stream
# close probability, target open streams). Arrivals pause once the open
# count overshoots the target by 10% — the generator models offered
# load with backpressure, so rejections happen only while capacity lags
# a rising target (exactly the window the autoscaler is meant to close).
PHASES = (
    ("ramp", 40 if QUICK else 200, 3.0, 0.02, 48),
    ("peak", 60 if QUICK else 300, 2.0, 0.04, 48),
    ("drain", 40 if QUICK else 200, 0.0, 0.12, 4),
)
START_STREAMS = 12
START_CAPACITY = 16
MAX_CAPACITY = 64 if QUICK else 256

SLO_P99_MS = 16.0
SLO_MAX_REJECTION_RATE = 0.10


def _verify_survivors(srv, pre_by_sid):
    """Healthy streams' per-slot state must be bit-unchanged; returns
    (ok, n_checked)."""
    leaves = [np.asarray(leaf) for leaf in jax.tree_util.tree_leaves(srv.state)]
    ok = True
    for sid, rows in pre_by_sid.items():
        slot = srv.active[sid]
        for row, leaf in zip(rows, leaves):
            if not np.array_equal(row, leaf[slot]):
                ok = False
    return ok, len(pre_by_sid)


def run(classifier="qat", devices=1, shard_loss=False, seed=0,
        fail_on_slo=False):
    visible = len(jax.devices())
    if devices < 1 or devices > visible:
        raise ValueError(
            f"--devices {devices} invalid for this platform ({visible} "
            f"visible device(s); emulate more with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N)"
        )
    if shard_loss and devices < 2:
        raise ValueError("--shard-loss needs --devices >= 2")
    pipe = _pipeline(classifier)
    params = pipe.init_params(jax.random.PRNGKey(0))
    srv = StreamingKWSServer(
        pipe, params, max_streams=START_CAPACITY, devices=devices,
        metrics=True,
    )
    tick_hist = srv.metrics.histogram("kws_serve_tick_ms")
    policy = AutoscalePolicy(
        min_streams=max(8, devices),
        max_streams=MAX_CAPACITY,
        grow_at=0.85,
        shrink_at=0.30,
        hysteresis_ticks=3,
        cooldown_ticks=4,
        factor=2,
    )
    auto = Autoscaler(
        srv, policy,
        monitor=StragglerMonitor(threshold=4.0, budget=8, warmup=1),
    )
    rng = np.random.default_rng(seed)
    dim = pipe.config.fex.num_channels
    next_sid = 0
    for _ in range(START_STREAMS):
        srv.open_stream(next_sid)
        next_sid += 1

    phase_rows = []
    pause_ms = []
    compile_ms = []
    loss_record = None
    totals = {"opens": START_STREAMS, "closes": 0, "rejections": 0,
              "arrivals": START_STREAMS, "stream_frames": 0}
    step = 0
    wall_t0 = time.perf_counter()
    for name, n_ticks, rate, p_close, target in PHASES:
        lat, opens, closes, rejections, active_sum = [], 0, 0, 0, 0
        loss_tick = n_ticks // 2 if (shard_loss and name == "peak") else None
        for t in range(n_ticks):
            # departures
            for sid in [s for s in list(srv.active)
                        if rng.random() < p_close]:
                srv.close_stream(sid)
                closes += 1
            # arrivals (offered load pauses past 110% of the target)
            n_arrive = (
                int(rng.poisson(rate))
                if len(srv.active) < target * 1.1 and rate > 0 else 0
            )
            for _ in range(n_arrive):
                totals["arrivals"] += 1
                try:
                    srv.open_stream(next_sid)
                    next_sid += 1
                    opens += 1
                except RuntimeError:
                    rejections += 1
                    auto.note_rejection()
            # injected shard loss: mid-peak, timed, verified in-line
            if loss_tick is not None and t == loss_tick and srv.n_devices > 1:
                lost = srv.n_devices - 1
                healthy = {
                    sid: slot for sid, slot in srv.active.items()
                    if shard_of_slot(
                        slot, srv.max_streams, srv.n_devices
                    ) != lost
                }
                leaves = [
                    np.asarray(leaf)
                    for leaf in jax.tree_util.tree_leaves(srv.state)
                ]
                pre = {
                    sid: [leaf[slot].copy() for leaf in leaves]
                    for sid, slot in healthy.items()
                }
                t0 = time.perf_counter()
                info = srv.recover_shard_loss(lost)
                recovery_s = time.perf_counter() - t0
                ok, n_checked = _verify_survivors(srv, pre)
                loss_record = {
                    "step": step,
                    "lost_shard": lost,
                    "recovery_ms": recovery_s * 1e3,
                    "reopened": len(info["reopened"]),
                    "survivors": len(info["survivors"]),
                    "survivors_checked": n_checked,
                    "healthy_bit_unchanged": ok,
                    "n_devices_after": srv.n_devices,
                    "max_streams_after": srv.max_streams,
                }
            # one fused tick over the current active set
            slab = np.zeros((srv.max_streams, dim), np.float32)
            mask = np.zeros((srv.max_streams,), bool)
            for sid, slot in srv.active.items():
                slab[slot] = rng.standard_normal(dim).astype(np.float32) * 0.05
                mask[slot] = True
            # tick latency comes from the server's own histogram; a
            # tick whose dispatch traced+compiled a new program is
            # identified EXACTLY by the retrace counter (no latency
            # heuristic — a resize back to an already-compiled width
            # is a warm tick and stays in the steady-state pool)
            r0 = srv.retrace_count
            srv.step_batch(slab, mask)
            dt = tick_hist.last * 1e-3
            if srv.retrace_count > r0:
                compile_ms.append(dt * 1e3)
            else:
                lat.append(dt)
            active_sum += len(srv.active)
            totals["stream_frames"] += len(srv.active)
            # autoscaler observes the measured tick; an action is a
            # capacity change — its in-band pause is the observe() time
            t0 = time.perf_counter()
            action = auto.observe(dt)
            if action is not None:
                pause_ms.append((time.perf_counter() - t0) * 1e3)
            step += 1
        lat_ms = np.asarray(lat, np.float64) * 1e3
        phase_rows.append({
            "phase": name,
            "ticks": n_ticks,
            "p50_ms": float(np.percentile(lat_ms, 50)),
            "p99_ms": float(np.percentile(lat_ms, 99)),
            "mean_ms": float(lat_ms.mean()),
            "ticks_per_s": 1e3 / float(lat_ms.mean()),
            "mean_active": active_sum / n_ticks,
            "capacity_end": srv.max_streams,
            "opens": opens,
            "closes": closes,
            "rejections": rejections,
        })
        totals["opens"] += opens
        totals["closes"] += closes
        totals["rejections"] += rejections
        print(
            f"  {name:5s} {n_ticks:4d} ticks: p50 "
            f"{phase_rows[-1]['p50_ms']:6.2f} ms  p99 "
            f"{phase_rows[-1]['p99_ms']:6.2f} ms  mean active "
            f"{phase_rows[-1]['mean_active']:5.1f}  capacity -> "
            f"{srv.max_streams:3d}  ({opens} opens, {closes} closes, "
            f"{rejections} rejections)"
        )
    wall_s = time.perf_counter() - wall_t0

    grew = any(e["action"] == "grow" for e in auto.events)
    shrank = any(e["action"] == "shrink" for e in auto.events)
    peak = next(r for r in phase_rows if r["phase"] == "peak")
    rejection_rate = totals["rejections"] / max(1, totals["arrivals"])
    p99_ok = peak["p99_ms"] <= SLO_P99_MS
    rejection_ok = rejection_rate <= SLO_MAX_REJECTION_RATE
    elastic_ok = grew and shrank and (
        loss_record is None or loss_record["healthy_bit_unchanged"]
    )
    slo = {
        "what": (
            f"steady-state peak p99 <= {SLO_P99_MS} ms, rejection rate "
            f"<= {SLO_MAX_REJECTION_RATE}, and the elasticity smoke: "
            f"the autoscaler grew during ramp AND shrank during drain"
            + (", and injected shard loss left every healthy stream's "
               "state bit-unchanged" if shard_loss else "")
        ),
        "p99_ms": peak["p99_ms"],
        "p99_budget_ms": SLO_P99_MS,
        "p99_ok": p99_ok,
        "rejection_rate": rejection_rate,
        "rejection_budget": SLO_MAX_REJECTION_RATE,
        "rejection_ok": rejection_ok,
        "grew": grew,
        "shrank": shrank,
        "elastic_ok": elastic_ok,
        "ok": p99_ok and rejection_ok and elastic_ok,
    }
    payload = {
        "backend": jax.default_backend(),
        "classifier": pipe.config.classifier_key,
        "devices_initial": devices,
        "devices_final": srv.n_devices,
        "seed": seed,
        "quick": QUICK,
        "policy": {
            "min_streams": policy.min_streams,
            "max_streams": policy.max_streams,
            "grow_at": policy.grow_at,
            "shrink_at": policy.shrink_at,
            "hysteresis_ticks": policy.hysteresis_ticks,
            "cooldown_ticks": policy.cooldown_ticks,
            "factor": policy.factor,
        },
        "phases": phase_rows,
        "resize": {
            "events": auto.events,
            "count": len(auto.events),
            "pause_ms": pause_ms,
            "max_pause_ms": max(pause_ms) if pause_ms else None,
            "post_change_compile_ms": compile_ms,
            # exact jit accounting from the observability layer: the
            # compile-tick exclusion above counted THESE retraces
            "retraces": srv.retrace_count,
            "compiles": srv.compile_count,
        },
        "shard_loss": loss_record,
        "totals": {
            **totals,
            "ticks": step,
            "wall_s": wall_s,
            "stream_frames_per_s": totals["stream_frames"] / wall_s,
        },
        "slo": slo,
    }
    with open("BENCH_churn.json", "w") as f:
        json.dump(payload, f, indent=2)
    # full registry snapshot — tick histograms, occupancy gauges, and
    # the journal of every autoscale / resize / retrace / shard-loss
    # event with its reason, in order (the CI slow job uploads this)
    with open("METRICS_churn.json", "w") as f:
        json.dump(srv.metrics_snapshot(), f, indent=2)
    sizes = " -> ".join(
        str(s) for s in
        [START_CAPACITY] + [e["to"] for e in auto.events]
    )
    print(
        f"churn_load: {step} ticks, {totals['opens']} opens / "
        f"{totals['closes']} closes / {totals['rejections']} rejections "
        f"({rejection_rate:.1%} of offered), capacity {sizes}, "
        f"{len(auto.events)} resize(s), max pause "
        f"{max(pause_ms) if pause_ms else 0.0:.1f} ms"
    )
    if loss_record is not None:
        print(
            f"churn_load shard-loss: shard {loss_record['lost_shard']} "
            f"lost at step {loss_record['step']}: recovered in "
            f"{loss_record['recovery_ms']:.0f} ms, "
            f"{loss_record['reopened']} stream(s) reopened, "
            f"{loss_record['survivors_checked']} healthy stream(s) "
            f"bit-unchanged="
            f"{'yes' if loss_record['healthy_bit_unchanged'] else 'NO'}"
        )
    print(
        f"churn_load SLO: peak p99 {slo['p99_ms']:.2f} ms (budget "
        f"{SLO_P99_MS:.0f} ms), rejections {rejection_rate:.1%} "
        f"(budget {SLO_MAX_REJECTION_RATE:.0%}), grew="
        f"{'yes' if grew else 'NO'} shrank={'yes' if shrank else 'NO'}"
        f"  [{'PASS' if slo['ok'] else 'FAIL'}] (BENCH_churn.json "
        f"written)"
    )
    if fail_on_slo and not slo["ok"]:
        raise SystemExit(
            "churn_load: --fail-on-slo and the churn SLO gate failed "
            "(see the SLO line above)"
        )
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--classifier", default="qat",
        choices=["qat", "integer", "float", "delta", "delta-int"],
        help="classifier backend the churn traffic is served with",
    )
    ap.add_argument(
        "--devices", type=int, default=1,
        help="initial device count; > 1 builds the server on a "
             "('stream',) mesh (emulate on CPU with "
             "XLA_FLAGS=--xla_force_host_platform_device_count=N)",
    )
    ap.add_argument(
        "--shard-loss", action="store_true",
        help="inject the loss of one shard mid-peak (needs "
             "--devices >= 2): times recover_shard_loss, counts the "
             "reopened streams, and bit-verifies the healthy ones",
    )
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--fail-on-slo", action="store_true",
        help="exit non-zero when the churn SLO gate fails (peak p99, "
             "rejection rate, or the elasticity smoke) — the CI slow "
             "job's regression tripwire for elastic serving",
    )
    args = ap.parse_args()
    run(
        classifier=args.classifier,
        devices=args.devices,
        shard_loss=args.shard_loss,
        seed=args.seed,
        fail_on_slo=args.fail_on_slo,
    )
