"""Table I reproduction: FEx dynamic range + Schreier FoM.

DR = 20 log10(largest linear signal / zero-input noise floor), measured
like the paper: integrated in-band noise with zero input (the chip's
248 uV_RMS input-referred noise dominates — our sim includes it) vs the
full-scale channel response.

FoM_{S,DR} = DR + 10 log10(1/(P_norm * 2 * FrameShift)) with P_norm from
eq. (7). Unit note: the published 93.11 dB for this work back-solves to
FrameShift entered as 16 (milliseconds as a number, not 0.016 s):
54.89 + 10 log10(1/(4.71e-6 * 2 * 16)) = 93.1. We reproduce the paper's
arithmetic with that convention (verified below) — the *relative*
comparison across Table I rows is unaffected.
"""

import jax
import numpy as np

from repro.core.filters import design_filterbank
from repro.core.tdfex import TDFExConfig, tdfex_raw_counts


def _power_norm(p_watt: float, f_l: float, f_h: float, n: int) -> float:
    """Eq. (7): normalize a parallel FEx's power to a 20 kHz band."""
    r = (f_l / f_h) ** (1.0 / (n - 1))
    return p_watt * (1 - r) / (1 - r**n) * (20e3 / f_h)


def run(seed: int = 0):
    print("== Table I: FEx dynamic range + Schreier FoM ==")
    import dataclasses

    import jax.numpy as jnp

    # Two configs: the ideal behavioral chain (noise = input-referred
    # white + DeltaSigma quantization only -> DR upper bound), and a
    # NOISE-CALIBRATED chain whose SRO accumulated phase jitter is set so
    # the zero-input floor matches the chip's measured 248 uV_RMS
    # in-band noise (1/f + phase-noise dominated on silicon; the paper's
    # DR 54.89 dB back-solves to ~30 counts RMS at this gain).
    cfg_ideal = TDFExConfig()
    cfg_cal = dataclasses.replace(cfg_ideal, phase_noise_rms=1.4)
    fexc = cfg_ideal.fex
    ch = 8  # measure at a mid-bank channel like the paper (ch 8)
    f0 = float(design_filterbank(16, fexc.fs_internal).f0[ch])

    n_frames = 48
    t = int(fexc.fs_internal * n_frames * fexc.frame_shift_ms / 1e3)
    silence = jnp.zeros((1, t), jnp.float32)
    ts = np.arange(t) / fexc.fs_internal
    tone = jnp.asarray(
        (0.9 * np.sin(2 * np.pi * f0 * ts))[None, :], jnp.float32
    )

    drs = {}
    for name, cfg in [("ideal", cfg_ideal), ("calibrated", cfg_cal)]:
        c0 = np.asarray(tdfex_raw_counts(
            silence, cfg, key=jax.random.PRNGKey(seed), audio_rate=False))
        noise_counts = max(float(c0[0, 4:, ch].std()), 0.3)
        c1 = np.asarray(tdfex_raw_counts(tone, cfg, audio_rate=False))
        sig_counts = float(c1[0, 4:, ch].mean()) - cfg.beta_nominal
        drs[name] = 20 * np.log10(sig_counts / noise_counts)
        print(f"  [{name:10s}] noise {noise_counts:6.2f} counts RMS, "
              f"signal {sig_counts:8.1f} -> DR {drs[name]:5.1f} dB")
    dr_db = drs["calibrated"]
    print(f"  dynamic range (calibrated): {dr_db:5.1f} dB "
          f"(paper: 54.89 dB; ideal chain bound: {drs['ideal']:.1f} dB)")

    # Schreier FoM with the paper's measured power (9.3 uW, 16 ch) and
    # the paper's unit convention (frame shift as ms-number)
    p_norm = _power_norm(9.3e-6, 111.0, 10.4e3, 16)
    fom_term = 10 * np.log10(1.0 / (p_norm * 2 * 16.0))
    fom = dr_db + fom_term
    fom_paper = 54.89 + fom_term
    print(f"  P_norm (eq. 7):         {p_norm * 1e6:5.2f} uW")
    print(f"  FoM_S,DR (our DR):      {fom:5.1f} dB")
    print(f"  FoM_S,DR (paper DR):    {fom_paper:5.2f} dB (paper: 93.11)")
    ok = 45.0 < dr_db < 70.0 and abs(fom_paper - 93.11) < 0.5
    print(f"  claim (DR in the ~55 dB regime; FoM arithmetic "
          f"reproduces): {'PASS' if ok else 'FAIL'}")
    return {"dr_db": float(dr_db), "fom": float(fom), "ok": ok}


if __name__ == "__main__":
    run()
