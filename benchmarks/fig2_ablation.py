"""Fig. 2 reproduction: KWS accuracy of the software model — baseline
(no compressor/normalizer) vs +log vs +log+norm.

Paper claim: 77.89% baseline -> 91.35% with both stages on GSCD. On the
synthetic corpus we validate the *ordering and a substantial gap*, not
the absolute numbers (DESIGN.md §3)."""

import numpy as np

from benchmarks.common import (
    datasets,
    evaluate,
    frames_to_features,
    train_classifier,
)
from repro.core.fex import FExConfig
from repro.core.pipeline import KWSPipeline, KWSPipelineConfig


def run(seed: int = 0):
    print("== Fig. 2: log-compression + normalization ablation ==")
    cfg = FExConfig()
    train, test = datasets(seed)
    # record FV_Raw once via the registered software frontend; the
    # ablation only varies the digital back-end (log / norm)
    pipe = KWSPipeline(KWSPipelineConfig(frontend="software", fex=cfg))
    raw_train = pipe.record_features(train["audio"])
    raw_test = pipe.record_features(test["audio"])

    results = {}
    for name, use_log, use_norm in [
        ("baseline", False, False),
        ("+log", True, False),
        ("+log+norm", True, True),
    ]:
        ftr, stats = frames_to_features(
            raw_train, cfg, use_log, use_norm, already_raw=True
        )
        fte, _ = frames_to_features(
            raw_test, cfg, use_log, use_norm, stats=stats,
            already_raw=True
        )
        model = train_classifier(ftr, train["label"], seed=seed)
        acc, _ = evaluate(model, fte, test["label"])
        results[name] = acc
        print(f"  {name:10s}: {acc:6.2%}")

    gap = results["+log+norm"] - results["baseline"]
    print(f"  gap (both stages vs baseline): {gap:+.2%} "
          f"(paper: +13.46pp, 77.89% -> 91.35%)")
    ok = (
        results["+log+norm"] > results["baseline"]
        and results["+log+norm"] >= results["+log"] - 0.02
    )
    print(f"  claim (stages help, ordering holds): {'PASS' if ok else 'FAIL'}")
    return {"results": results, "gap": gap, "ok": ok}


if __name__ == "__main__":
    run()
