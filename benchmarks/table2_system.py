"""Table II reproduction: system-level latency/power from the
first-principles accelerator model (energy.py) vs the published row."""

from repro.core.energy import paper_accelerator, paper_power_model
from repro.core.gru import GRUConfig, classifier_macs, classifier_param_bytes


def run(seed: int = 0):
    print("== Table II: system row (model vs paper) ==")
    gcfg = GRUConfig()
    acc = paper_accelerator()
    pm = paper_power_model()
    rows = [
        ("weights (KB, 8-bit)", classifier_param_bytes(gcfg) / 1024, 24.0),
        ("MACs / frame", classifier_macs(gcfg), 24204),
        ("latency (ms)", acc.latency_s(gcfg) * 1e3, 12.4),
        ("accelerator power (uW)", pm.accelerator_power_w(gcfg) * 1e6, 9.96),
        ("FEx power (uW)", pm.fex_power_w(16) * 1e6, 9.3),
        ("KWS core power (uW)", pm.total_power_w(gcfg) * 1e6, 23.0),
        ("frame shift (ms)", 16.0, 16.0),
        ("classes", 12, 12),
    ]
    ok = True
    for name, ours, paper in rows:
        rel = abs(ours - paper) / max(abs(paper), 1e-9)
        ok &= rel < 0.05
        print(f"  {name:24s} model {ours:10.2f} | paper {paper:10.2f} "
              f"({rel:5.1%} off)")
    print(f"  claim (model reproduces Table II within 5%): "
          f"{'PASS' if ok else 'FAIL'}")
    return {"ok": bool(ok)}


if __name__ == "__main__":
    run()
