"""Cascade operating curve: wake threshold vs duty cycle vs accuracy.

Trains the paper's QAT GRU-FC on the synthetic GSCD (the
benchmarks.common recipe), then serves silence-dominated streaming
traffic — per test utterance, 8 seconds of near-silence features
followed by the 1-second utterance, the always-on deployment shape the
cascade exists for — through `StreamingKWSServer` with the stage-1
wake gate (`repro.serving.cascade`) at a sweep of wake thresholds, and
measures per threshold:

  * the measured classifier duty cycle (`srv.wake_rate`, mean over
    streams — the fraction of ticks the gate woke the classifier);
  * end-to-end 12-class accuracy from the final-tick smoothed argmax
    (threshold 0 must reproduce the non-cascaded server EXACTLY — the
    always-open bit-identity contract, array equality of the full
    score trajectories);
  * stage-1 false rejects: speech streams (label != silence) whose
    gate never fired, so the classifier never saw the utterance;
  * predicted IC power via `repro.core.energy.ICPowerModel` with the
    measured duty cycle AND the measured within-wake ΔGRU sparsity
    composed multiplicatively
    (`AcceleratorModel(duty_cycle=..., effective_mac_fraction=...)`) —
    the classifier backend is the ΔGRU ("delta", θ=0.15), so the rows
    quantify the full gate x sparsity stack.

Two linear-scorer rows (the trainable stage-1 variant,
`fit_linear_detector` on the train split's speech vs silence frames)
ride along after the energy-threshold sweep.

Claim checked: some threshold > 0 achieves >= 5x duty-cycle reduction
(mean wake rate <= 0.2) within 1 accuracy point of the non-cascaded
server, with zero stage-1 false rejects, and threshold 0 is exact.
Writes ``BENCH_cascade.json``.

  PYTHONPATH=src python -m benchmarks.fig_cascade_roc
"""

from __future__ import annotations

import json

import numpy as np

from benchmarks.common import (
    datasets,
    frames_to_features,
    record_software_frames,
    timed,
    train_classifier,
)
from repro.core.energy import AcceleratorModel, ICPowerModel
from repro.core.fex import FExConfig
from repro.core.gru_delta import DeltaConfig
from repro.core.pipeline import KWSPipeline, KWSPipelineConfig
from repro.serving.cascade import CascadeConfig, fit_linear_detector
from repro.serving.serve_loop import StreamingKWSServer

SILENCE_SECONDS = 8  # per 1 s utterance -> speech is 1/9 of the traffic
THRESHOLDS = (0.0, 0.02, 0.05, 0.1, 0.2, 0.4)
LINEAR_THRESHOLDS = (0.5, 0.9)
HANGOVER = 3
THETA = 0.15  # ΔGRU threshold of the served classifier backend


def _serve(pipe_cfg, stats, params, slab, mask):
    """One full replay of the traffic slab through a fresh server."""
    pipe = KWSPipeline(pipe_cfg, norm_stats=stats)
    srv = StreamingKWSServer(pipe, params, max_streams=slab.shape[1])
    for sid in range(slab.shape[1]):
        srv.open_stream(sid)
    scores_seq, tops = srv.run_batch(slab, mask)
    return srv, scores_seq, tops


def run(seed: int = 0):
    print("== cascade ROC: wake threshold vs duty cycle vs accuracy ==")
    train, test = datasets(seed)
    cfg = FExConfig()
    with timed("features"):
        ftr, stats = frames_to_features(
            record_software_frames(train["audio"], cfg), cfg, True, True
        )
        fte, _ = frames_to_features(
            record_software_frames(test["audio"], cfg), cfg, True, True,
            stats=stats,
        )
    with timed("train"):
        model = train_classifier(ftr, train["label"], seed=seed)
    gcfg = model["config"]
    labels = np.asarray(test["label"])
    n = len(labels)

    # silence-dominated traffic: per test stream, SILENCE_SECONDS of
    # near-silence featurization (fresh mic noise through the same
    # frontend + train norm stats) then the utterance LAST, so the
    # final-tick smoothed argmax is the stream's decision
    rng = np.random.default_rng(seed + 11)
    sil_audio = rng.standard_normal((n, 16000)).astype(np.float32) * 1e-3
    sil, _ = frames_to_features(
        record_software_frames(sil_audio, cfg), cfg, True, True,
        stats=stats,
    )
    stream = np.concatenate([sil] * SILENCE_SECONDS + [fte], axis=1)
    slab = stream.transpose(1, 0, 2)  # (n_ticks, n_streams, C)
    mask = np.ones(slab.shape[:2], bool)
    speech = labels != 0  # silence is class 0
    print(
        f"  traffic: {n} streams x {slab.shape[0]} ticks "
        f"({SILENCE_SECONDS} s silence + 1 s utterance each), "
        f"classifier 'delta' θ={THETA}"
    )

    params = None
    delta = DeltaConfig(theta_x=THETA, theta_h=THETA)

    def pipe_cfg(cascade=None):
        return KWSPipelineConfig(
            classifier="delta", delta=delta, cascade=cascade
        )

    # the non-cascaded baseline every row is measured against
    base_pipe = KWSPipeline(pipe_cfg(), norm_stats=stats)
    params = base_pipe.prepare_params(model["params"])
    with timed("baseline replay"):
        base_srv, base_scores, base_tops = _serve(
            pipe_cfg(), stats, params, slab, mask
        )
    base_acc = float((np.asarray(base_tops[-1]) == labels).mean())
    base_sparsity = float(np.mean(base_srv.sparsity))
    base_pm = ICPowerModel(
        accel=AcceleratorModel(effective_mac_fraction=base_sparsity)
    )
    base_uw = base_pm.total_power_w(gcfg) * 1e6
    print(
        f"  baseline (no cascade): acc {base_acc:6.2%}  "
        f"eff-MAC {base_sparsity:.3f}  -> {base_uw:.1f} µW predicted"
    )

    # stage-1 linear scorer, fit on the train split's own frames
    # (speech utterances vs the silence class)
    sp_fr = ftr[np.asarray(train["label"]) != 0].reshape(-1, ftr.shape[-1])
    si_fr = ftr[np.asarray(train["label"]) == 0].reshape(-1, ftr.shape[-1])
    lin_w, lin_b = fit_linear_detector(sp_fr, si_fr)

    sweep = [("energy", t) for t in THRESHOLDS] + [
        ("linear", t) for t in LINEAR_THRESHOLDS
    ]
    rows = []
    threshold0_exact = None
    for det, thr in sweep:
        cc = CascadeConfig(
            detector=det, wake_threshold=thr, hangover_frames=HANGOVER,
            linear_w=lin_w if det == "linear" else None,
            linear_b=lin_b if det == "linear" else 0.0,
        )
        srv, scores_seq, tops = _serve(
            pipe_cfg(cc), stats, params, slab, mask
        )
        if det == "energy" and thr == 0.0:
            # always-open bit-identity: the gated server must reproduce
            # the non-cascaded one exactly (full trajectories, not just
            # the final decisions)
            threshold0_exact = bool(
                np.array_equal(scores_seq, base_scores)
                and np.array_equal(tops, base_tops)
            )
        wake = np.asarray(srv.wake_rate)
        sparsity = float(np.mean(srv.sparsity))
        wake_mean = float(wake.mean())
        acc = float((np.asarray(tops[-1]) == labels).mean())
        false_reject = float((wake[speech] == 0.0).mean())
        pm = ICPowerModel(accel=AcceleratorModel(
            duty_cycle=wake_mean, effective_mac_fraction=sparsity,
        ))
        row = {
            "detector": det,
            "wake_threshold": thr,
            "hangover_frames": HANGOVER,
            "wake_rate": wake_mean,
            "duty_reduction": 1.0 / max(wake_mean, 1e-9),
            "within_wake_mac_fraction": sparsity,
            "accuracy": acc,
            "accuracy_drop_pts": (base_acc - acc) * 100.0,
            "false_reject": false_reject,
            "pred_accel_uw": pm.accelerator_power_w(gcfg) * 1e6,
            "pred_total_uw": pm.total_power_w(gcfg) * 1e6,
        }
        rows.append(row)
        print(
            f"  {det:6s} thr={thr:4.2f}: wake {wake_mean:5.3f} "
            f"({row['duty_reduction']:5.1f}x)  acc {acc:6.2%} "
            f"(Δ {row['accuracy_drop_pts']:+5.2f} pts)  "
            f"FR {false_reject:5.1%}  "
            f"eff-MAC|wake {sparsity:.3f}  "
            f"-> {row['pred_total_uw']:5.2f} µW"
        )

    good = [
        r for r in rows
        if r["wake_threshold"] > 0.0
        and r["wake_rate"] <= 0.2
        and r["accuracy_drop_pts"] <= 1.0
        and r["false_reject"] == 0.0
    ]
    best = min(good, key=lambda r: r["pred_total_uw"], default=None)
    ok = bool(threshold0_exact) and best is not None
    claim = {
        "what": "cascade: some wake threshold > 0 achieves >= 5x "
                "classifier duty-cycle reduction (mean wake rate <= "
                "0.2) within 1 accuracy point of the non-cascaded "
                "server, with zero stage-1 false rejects, on "
                "silence-dominated synthetic-GSCD traffic; threshold 0 "
                "reproduces the non-cascaded server exactly; predicted "
                "µW composes the measured duty cycle with the measured "
                "within-wake ΔGRU sparsity through ICPowerModel",
        "classifier": "delta",
        "theta": THETA,
        "baseline_accuracy": base_acc,
        "baseline_mac_fraction": base_sparsity,
        "baseline_pred_total_uw": base_uw,
        "threshold0_exact": threshold0_exact,
        "best": best,
        "ok": ok,
    }
    with open("BENCH_cascade.json", "w") as f:
        json.dump({"rows": rows, "claim": claim}, f, indent=2)
    if best is not None:
        print(
            f"fig_cascade_roc: {best['detector']} "
            f"thr={best['wake_threshold']:.2f} wakes the classifier on "
            f"{best['wake_rate']:.1%} of ticks "
            f"({best['duty_reduction']:.1f}x duty reduction) at "
            f"{best['accuracy_drop_pts']:+.2f} pts, 0 false rejects "
            f"({best['pred_total_uw']:.1f} µW predicted vs "
            f"{base_uw:.1f} µW ungated), threshold-0 exact: "
            f"{threshold0_exact}  [{'PASS' if ok else 'FAIL'}] "
            f"(BENCH_cascade.json written)"
        )
    else:
        print(
            f"fig_cascade_roc: no threshold reached 5x within 1 pt at "
            f"0 false rejects (threshold-0 exact: {threshold0_exact})  "
            f"[FAIL] (BENCH_cascade.json written)"
        )
    return claim


if __name__ == "__main__":
    run()
