"""Fig. 17(a)/(b) reproduction: measured FEx frequency response with and
without per-channel gain (alpha) calibration.

Drives tones across 100 Hz-10 kHz through the time-domain hardware sim
(mismatched chip) and reports per-channel gain curves; calibration must
collapse the inter-channel gain spread (paper: systematic SRO-bias
mismatch before, flat Mel bank after)."""

import jax
import numpy as np

from repro.core.calibration import calibrate_chip
from repro.core.filters import design_filterbank
from repro.core.tdfex import TDFExConfig, draw_chip, tdfex_raw_counts


def run(seed: int = 0):
    print("== Fig. 17a/b: FEx frequency response +- calibration ==")
    cfg = TDFExConfig()
    chip = draw_chip(jax.random.PRNGKey(seed), cfg)
    beta, alpha = calibrate_chip(cfg, chip)

    fexc = cfg.fex
    freqs = np.geomspace(100, 10000, 25)
    amp = 0.25
    t = np.arange(int(fexc.fs_internal * 0.25)) / fexc.fs_internal
    tones = np.stack(
        [amp * np.sin(2 * np.pi * f * t) for f in freqs]
    ).astype(np.float32)
    counts = np.asarray(
        tdfex_raw_counts(tones, cfg, chip, audio_rate=False)
    )  # (F_tones, frames, C)
    resp = counts[:, 4:, :].mean(1) - np.asarray(beta)[None, :]  # (F, C)
    resp = np.maximum(resp, 1e-3)

    f0 = design_filterbank(16, fexc.fs_internal).f0
    peak_raw = resp.max(axis=0)  # per-channel peak across tones
    peak_cal = (resp * np.asarray(alpha)[None, :]).max(axis=0)

    spread_raw = 20 * np.log10(peak_raw.max() / peak_raw.min())
    spread_cal = 20 * np.log10(peak_cal.max() / peak_cal.min())
    print(f"  channel gain spread before cal: {spread_raw:5.2f} dB")
    print(f"  channel gain spread after  cal: {spread_cal:5.2f} dB")

    # each channel's best tone should be near its design f0
    best = freqs[resp.argmax(axis=0)]
    ratio = best / np.asarray(f0)
    centers_ok = bool(np.all((ratio > 0.6) & (ratio < 1.7)))
    print(f"  center frequencies track Mel design: "
          f"{'PASS' if centers_ok else 'FAIL'} "
          f"(worst ratio {ratio.max():.2f}/{ratio.min():.2f})")
    ok = spread_cal < spread_raw * 0.6 and centers_ok
    print(f"  claim (calibration flattens bank): {'PASS' if ok else 'FAIL'}")
    return {"spread_raw_db": spread_raw, "spread_cal_db": spread_cal,
            "ok": ok}


if __name__ == "__main__":
    run()
