"""Shared benchmark machinery: dataset prep, feature recording, and the
QAT classifier training loop (the paper's recipe: AdamW 1e-3, wd 0.01,
ReduceLROnPlateau 0.8/3, floor 5e-4 — Section III-F)."""

from __future__ import annotations

import functools
import os
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.classifier import get_classifier, resolve_classifier_key
from repro.core.fex import FExConfig, FExNormStats, fex_frames
from repro.core.gru import (
    GRUConfig,
    gru_classifier_forward,
    init_gru_classifier,
)
from repro.data.gscd import make_dataset
from repro.training.optimizer import (
    AdamWConfig,
    ReduceLROnPlateau,
    adamw_update,
    init_opt_state,
)

QUICK = os.environ.get("BENCH_FULL", "0") != "1"

# quick mode: enough samples for the claims' *ordering* to be stable;
# BENCH_FULL=1 scales everything up.
N_TRAIN = 24 if QUICK else 120  # per class
N_TEST = 10 if QUICK else 40
EPOCHS = 60 if QUICK else 200


def datasets(seed: int = 0):
    train = make_dataset(N_TRAIN, seed=seed, unknown_split="train")
    test = make_dataset(N_TEST, seed=seed + 1, unknown_split="test")
    return train, test


@functools.partial(jax.jit, static_argnums=(1,))
def _frames_batch(audio, cfg: FExConfig):
    return fex_frames(audio, cfg)


def record_software_frames(audio: np.ndarray, cfg: FExConfig,
                           batch: int = 64) -> np.ndarray:
    outs = []
    for i in range(0, len(audio), batch):
        outs.append(np.asarray(_frames_batch(jnp.asarray(audio[i:i + batch]), cfg)))
    return np.concatenate(outs)


def frames_to_features(
    frames_or_raw: np.ndarray,
    cfg: FExConfig,
    use_log: bool,
    use_norm: bool,
    stats: Optional[FExNormStats] = None,
    already_raw: bool = False,
) -> Tuple[np.ndarray, Optional[FExNormStats]]:
    """Rectified frames (or recorded FV_Raw codes) -> classifier input."""
    if already_raw:
        fv_raw = jnp.asarray(frames_or_raw)
    else:
        fv_raw = quant.quantize_unsigned(
            jnp.asarray(frames_or_raw), cfg.quant_bits, cfg.quant_full_scale
        )
    x = fv_raw
    if use_log:
        x = quant.log_compress_lut(x, cfg.quant_bits, cfg.log_bits)
    if use_norm:
        if stats is None:
            flat = x.reshape(-1, x.shape[-1])
            stats = FExNormStats(
                mu=flat.mean(0), sigma=flat.std(0) + 1e-3
            )
        x = (x - stats.mu) / stats.sigma
    else:
        in_bits = cfg.log_bits if use_log else cfg.quant_bits
        x = x * 2.0 ** -(in_bits - 5)
    return np.asarray(quant.fake_quant(x, quant.ACT_Q6_8)), stats


def train_classifier(
    feats: np.ndarray,
    labels: np.ndarray,
    seed: int = 0,
    epochs: int = EPOCHS,
    batch: int = 64,
    verbose: bool = False,
) -> Dict:
    """QAT training of the 2x48 GRU-FC. Returns dict with params+curve."""
    gcfg = GRUConfig()
    params = init_gru_classifier(jax.random.PRNGKey(seed), gcfg)
    ocfg = AdamWConfig(lr=1e-3, weight_decay=0.01)
    opt = init_opt_state(params, ocfg)
    sched = ReduceLROnPlateau(1e-3, 0.8, 3, 5e-4)

    @jax.jit
    def step(params, opt, fv, y, lr):
        def loss_fn(p):
            logits = gru_classifier_forward(p, fv, gcfg)[:, -1, :]
            logz = jax.nn.logsumexp(logits, -1)
            gold = jnp.take_along_axis(logits, y[:, None], -1)[:, 0]
            return jnp.mean(logz - gold)

        loss, g = jax.value_and_grad(loss_fn)(params)
        params, opt, _ = adamw_update(params, g, opt, ocfg, lr)
        return params, opt, loss

    n = len(labels)
    rng = np.random.default_rng(seed)
    lr = 1e-3
    history = []
    for epoch in range(epochs):
        order = rng.permutation(n)
        losses = []
        for i in range(0, n - n % batch, batch):
            sl = order[i:i + batch]
            params, opt, loss = step(
                params, opt, jnp.asarray(feats[sl]),
                jnp.asarray(labels[sl]), lr,
            )
            losses.append(float(loss))
        mean_loss = float(np.mean(losses))
        lr = sched.step(mean_loss)
        history.append(mean_loss)
        if verbose and epoch % 10 == 0:
            print(f"    epoch {epoch:3d} loss {mean_loss:.4f} lr {lr:.2e}")
    return {"params": params, "config": gcfg, "history": history}


def evaluate(model: Dict, feats: np.ndarray, labels: np.ndarray,
             batch: int = 128, classifier: Optional[str] = None):
    """Accuracy + confusion matrix through a registered classifier
    backend; ``classifier=None`` resolves from the model config (the
    QAT path), ``"integer"`` runs the bit-exact int8/Q6.8 engine. (The
    ΔGRU θ sweep needs per-example MAC fractions as well, so it drives
    `repro.core.gru_delta.delta_classifier_forward` directly — see
    benchmarks/fig_delta_tradeoff.py.)"""
    gcfg = model["config"]
    backend = get_classifier(resolve_classifier_key(classifier, gcfg))
    params = backend.prepare(model["params"], gcfg)

    @jax.jit
    def logits_fn(fv):
        return backend.forward(params, fv, gcfg)[:, -1, :]

    preds = []
    for i in range(0, len(labels), batch):
        preds.append(np.argmax(np.asarray(
            logits_fn(jnp.asarray(feats[i:i + batch]))), -1))
    preds = np.concatenate(preds)
    acc = float((preds == labels).mean())
    n_cls = int(labels.max()) + 1
    conf = np.zeros((n_cls, n_cls), np.int32)
    for t, p in zip(labels, preds):
        conf[t, p] += 1
    return acc, conf


def percentile_stats(latencies_s) -> Dict[str, float]:
    """Per-tick latency list (seconds) -> p50/p99/mean in milliseconds.

    This is the shared latency summary of the serving benchmarks; the
    field names match what `benchmarks/serve_load.py` writes to
    ``BENCH_serve.json``:

      backend        jax backend the sweep ran on ("cpu" / "tpu" / ...)
      frontend       registered FeatureFrontend of the benched pipeline
      tick_impl      requested tick implementation for the sweep's
                     non-legacy servers (--tick-impl: "auto" / "xla" /
                     "fused-pallas" / "fused-interpret"); each row
                     records what "auto" resolved to
      classifiers    registered ClassifierBackend keys the sweep covered
      theta          ΔGRU threshold (Q6.8 value units) the delta rows
                     ran at (--theta; dense rows are unaffected)
      cascade        True when the sweep served every non-legacy point
                     with the stage-1 wake gate
                     (`repro.serving.cascade`; --cascade)
      wake_threshold energy-detector wake threshold the cascaded sweep
                     ran at (--wake-threshold; None when cascade is
                     False)
      devices        device counts the sweep covered (counts > 1 bench
                     the stream-parallel server on a ("stream",) mesh)
      quick          True when the quick (CI-sized) sweep ran
      results[]      one entry per (classifier, mode, kind, devices,
                     max_streams, occupancy):
        classifier     registered ClassifierBackend of the point: "qat"
                       (fake-quant float tick), "integer" (bit-exact
                       int8/Q6.8 engine, weight codes resident), or
                       "delta"/"delta-int" (temporal-sparsity ΔGRU at
                       the sweep's theta); "legacy" mode exists only
                       for "qat" (the pre-refactor path had no integer
                       or delta engine)
        mode           "fused" (one jitted tick per step_batch call),
                       "legacy" (pre-refactor per-stream path),
                       "pipelined" (live async ingress:
                       `repro.serving.ingress.PipelinedIngress` over
                       step_batch_async/run_batch_async — double-
                       buffered staging, non-blocking dispatch,
                       deferred fetch, window-tick coalescing), or
                       "scan" (run_batch lax.scan replay: ONE device
                       program and one host round-trip for the whole
                       tick sequence, so there is no per-tick latency
                       to measure)
        kind           tick payload: "fv" = precomputed FV_Norm frames
                       (isolates serving-path overhead), "audio" = raw
                       16 ms hops (adds the frontend filter scan, a
                       cost shared by every mode)
        tick_impl      resolved tick implementation the row's server
                       ran ("xla" = one fused XLA program,
                       "fused-pallas" = the whole tick as ONE Pallas
                       megakernel over stream blocks,
                       "fused-interpret" = the same kernel body under
                       the Pallas interpreter); None for the legacy
                       path, which predates tick_impl
        tick_dispatch  kernel dispatch tier of the row's ticks ("xla" /
                       "pallas" / "interpret" —
                       `repro.kernels.dispatch` naming); None for
                       legacy rows
        jax_backend    jax backend the row ran on (repeated per row so
                       rows merged across artifacts stay attributable)
        devices        device count the row ran on; > 1 means the slot
                       axis was sharded over a ("stream",) mesh (bit-
                       identical to devices=1 — the row measures pure
                       throughput, tests/test_serve_sharded.py proves
                       the equality)
        max_streams    server slot capacity for the point
        occupancy      fraction of slots with an open, submitting stream
        active_streams occupancy * max_streams, rounded, >= 1
        n_ticks        measured ticks (after warmup)
        ticks_per_s    sustained tick throughput. For the blocking
                       per-call modes (fused/legacy) this is
                       1 / mean(latency); for scan and pipelined it is
                       n_ticks / wall-clock — pipelined ticks overlap,
                       so the reciprocal mean would overcount
        streams_per_s  ticks_per_s * active_streams (stream-frames/sec)
        window         pipelined rows only: ticks coalesced into one
                       scan dispatch by the ingress (the throughput/
                       latency knob); None for every other mode
        sparsity       measured effective-MAC fraction, mean over the
                       point's active streams (the `srv.sparsity`
                       telemetry): < 1.0 for the ΔGRU backends when
                       their traffic lets them skip, identically 1.0
                       for dense backends, None for the legacy path
                       (predates the telemetry)
        theta          ΔGRU threshold of the point's pipeline (None for
                       dense backends)
        wake_rate      measured classifier duty cycle, mean over the
                       point's active streams (the `srv.wake_rate`
                       telemetry): < 1.0 when the stage-1 cascade gate
                       held the classifier asleep for part of the
                       traffic, identically 1.0 for ungated sweeps,
                       None for the legacy path (predates the
                       telemetry)
        wake_threshold stage-1 wake threshold of the point's pipeline
                       (None when the sweep ran without --cascade)
        retraces       counted jit retraces the row's server paid: the
                       exact number of (program, operand-shape) keys
                       first-dispatched since construction
                       (`srv.retrace_count` — the counter
                       benchmarks/churn_load.py uses to exclude compile
                       ticks exactly). 1 for a steady-state row (the
                       warmup tick traces once); None for the legacy
                       path, which predates the counter
        spans          pipelined rows only: per-span duration rollups
                       of the row's `TickTrace` ring
                       (repro.serving.metrics.span_percentiles) — span
                       name ("stage_to_commit" / "commit_to_dispatch" /
                       "dispatch_to_retire" / "total") ->
                       {count, p50_ms, p99_ms, mean_ms}. The
                       dispatch_to_retire span is the device-side
                       residency; None for every other mode (the
                       blocking modes have no pipeline stages)
        p50_ms/p99_ms  per-tick wall latency percentiles. Null for scan
                       rows: the replay returns to the host once, so
                       per-tick percentiles do not exist there (they
                       used to be fabricated as wall/n_ticks repeated,
                       which made p50==p99==mean look measured).
                       Fused/legacy rows measure each blocking call;
                       pipelined rows measure real submit-to-scores
                       latency per tick (commit timestamp to handle
                       retirement — the SLO-relevant number)
        mean_ms        mean per-tick wall latency (scan rows: the
                       amortized wall/n_ticks, the only latency-like
                       number a single-program replay has)
      scaling[]      per device count: sustained scan-fv ticks/sec at
                     256 streams and the ratio vs the devices=1 row
                     (on emulated CPU meshes this measures SPMD
                     overhead, on real multi-chip platforms the
                     stream-parallel scaling curve)
      claim          the checked headline ("ok" bool): sustained
                     fused-tick throughput (scan driver) >= 5x legacy
                     ticks/sec at 256 streams, full occupancy, fv kind,
                     devices=1; "speedup_live" carries the per-call
                     fused ratio
      slo            the live-serving latency gate ("ok" bool, also
                     "p99_ok"/"ratio_ok"): pipelined p99 <= the 16 ms
                     tick budget at 256 streams AND pipelined
                     throughput >= 0.5x the scan ceiling at 64 and 256
                     streams ("pipelined_vs_scan", keyed by stream
                     count), all at full occupancy, fv kind, devices=1
                     on the sweep's first classifier;
                     `--fail-on-slo` exits non-zero when violated
      metrics_overhead
                     the observability cost gate ("ok" bool): a
                     metrics-enabled server's fused tick vs a
                     metrics-off twin at 256 streams, fv, full
                     occupancy, devices=1 (best-of-3 INTERLEAVED round
                     means, so platform drift hits both arms equally) —
                     mean_ms_metrics_off / mean_ms_metrics_on,
                     overhead_frac (on/off - 1), budget_frac (0.05),
                     ok = overhead_frac < budget_frac. `--fail-on-slo`
                     exits non-zero when violated. The full registry
                     snapshot of the metrics-on server (plus the
                     deployment-relevant 256-stream sweep points) is
                     written to ``METRICS_serve.json`` next to the
                     BENCH artifact
      sparsity_speedup
                     the tick-kernel claim: the fused delta tick
                     benched against ITSELF across ΔGRU thresholds
                     (rows[] of {theta, mean_ms, ticks_per_s,
                     sparsity} at 64 streams, fv ticks, on the fused
                     tier the platform executes — "fused-pallas" on
                     TPU, else "fused-interpret").
                     "speedup_vs_dense" = t(θ=0)/t(θ=0.15); "ok"
                     gates it >= 1.5x only when "gated" is true (a
                     real accelerator ran the pallas tier), else None
                     — on CPU only "monotone_in_theta" (fused tick
                     times non-increasing in θ) is meaningful
    """
    lat = np.asarray(latencies_s, np.float64) * 1e3
    return {
        "p50_ms": float(np.percentile(lat, 50)),
        "p99_ms": float(np.percentile(lat, 99)),
        "mean_ms": float(lat.mean()),
    }


BENCH_CHURN_FIELDS = """\
Field reference for ``BENCH_churn.json`` (written by
benchmarks/churn_load.py — elastic serving under open/close churn,
live autoscaler resizes, and injected shard loss):

  backend          jax backend the run executed on ("cpu"/"tpu"/...)
  classifier       registered ClassifierBackend the traffic was served
                   with (--classifier)
  devices_initial  device count the server was built on (--devices)
  devices_final    device count at exit — smaller than initial exactly
                   when --shard-loss shrank the mesh mid-run
  seed             traffic RNG seed (--seed)
  quick            True when the quick (CI-sized) schedule ran
  policy           the AutoscalePolicy the run was driven by:
                   min_streams / max_streams (capacity clamp),
                   grow_at / shrink_at (occupancy watermarks),
                   hysteresis_ticks (consecutive breaches before an
                   act), cooldown_ticks (dead time after an act), and
                   factor (the grow/shrink multiple)
  phases[]         one entry per schedule phase (ramp / peak / drain):
    phase            phase name
    ticks            ticks driven in the phase
    p50_ms/p99_ms/mean_ms
                     steady-state per-tick `step_batch` wall latency —
                     compile ticks are EXCLUDED here and recorded under
                     resize.post_change_compile_ms instead. A compile
                     tick is identified EXACTLY: the server's
                     shape-keyed retrace counter (`srv.retrace_count`)
                     incremented across the call. (The old heuristic —
                     "skip the first tick after any capacity change" —
                     missed recompiles it didn't predict and excluded
                     warm cache-hit ticks after a resize back to a
                     seen capacity)
    ticks_per_s      1e3 / mean_ms (blocking per-call cadence)
    mean_active      mean open-stream count over the phase's ticks
    capacity_end     server max_streams when the phase ended
    opens/closes     streams opened / closed during the phase
    rejections       open_stream calls refused at capacity (each one
                     also fed the autoscaler's note_rejection — the
                     immediate grow signal)
  resize           the elasticity trace:
    events[]         the Autoscaler event log, one entry per capacity
                     change: {step, action ("grow"/"shrink"), from, to}
    count            len(events)
    pause_ms[]       in-band wall time of each autoscaler-triggered
                     resize() call (state relay + re-placement; the
                     serving pause the tick loop actually felt)
    max_pause_ms     max(pause_ms), null when no resize fired
    post_change_compile_ms[]
                     wall time of each excluded compile tick (every
                     tick whose dispatch traced a fresh program:
                     first tick at a new slot width, plus the first
                     tick after shard-loss recovery, which rebuilds
                     the jitted programs on the shrunken mesh)
    retraces         `srv.retrace_count` at exit — the exact number of
                     (program, shape) first-dispatches the run paid;
                     len(post_change_compile_ms) equals the retraces
                     the tick loop itself triggered
    compiles         `srv.compile_count` at exit: program rebuilds
                     (construction + one per shard-loss recovery)
  shard_loss       null without --shard-loss, else the injected-loss
                   record:
    step               global tick index the loss was injected at
    lost_shard         mesh index of the lost shard
    recovery_ms        wall time of recover_shard_loss (host state
                       relay + mesh rebuild + program recompile +
                       reopening the lost streams)
    reopened           streams that lived on the lost shard, reopened
                       (same ids) on fresh zeroed slots
    survivors          streams on healthy shards
    survivors_checked  survivors bit-verified by the bench
    healthy_bit_unchanged
                       True when every survivor's per-slot state was
                       bitwise identical through the move (the
                       recovery contract, re-checked on the bench's
                       own traffic; gates slo.elastic_ok)
    n_devices_after / max_streams_after
                       mesh and capacity after recovery (capacity is
                       rounded UP to whole blocks of the surviving
                       device count)
  totals           run-wide counters: ticks, opens, closes, arrivals
                   (offered opens, accepted + rejected), rejections,
                   stream_frames (sum of active streams over ticks),
                   wall_s, stream_frames_per_s
  slo              the churn SLO gate ("ok" bool, and the per-clause
                   p99_ok / rejection_ok / elastic_ok): steady-state
                   PEAK-phase p99 <= the 16 ms tick budget, rejection
                   rate (rejections / arrivals) <= 10%, and the
                   elasticity smoke — the autoscaler grew during ramp
                   AND shrank during drain, and (when injected) shard
                   loss left every healthy stream bit-unchanged.
                   `--fail-on-slo` exits non-zero when violated

The run's full `srv.metrics_snapshot()` — tick histograms, occupancy
gauges, and the structured event journal (every autoscale / resize /
retrace / shard-loss event with its reason, in order) — is written to
``METRICS_churn.json`` next to the BENCH artifact.
"""


def timed(name):
    class _T:
        def __enter__(self):
            self.t0 = time.time()
            return self

        def __exit__(self, *a):
            print(f"  [{name}: {time.time() - self.t0:.1f}s]")

    return _T()
