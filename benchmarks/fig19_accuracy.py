"""Fig. 19 reproduction: 12-class KWS accuracy with the HARDWARE-SIM
feature extractor (mismatch + noise + calibration), confusion matrix,
and per-class true-positive rates.

Paper: 86.03% on chip vs 91.35% software; silence easiest (100%),
"unknown" hardest. We validate those *relations* on the synthetic corpus
and report the hw-vs-sw gap measured the same way. The trained model is
additionally evaluated through the bit-exact integer classifier backend
("integer" — int8 weights / Q6.8 activations on codes), which must
reproduce the QAT confusion matrix exactly."""

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    datasets,
    evaluate,
    frames_to_features,
    train_classifier,
)
from repro.core.calibration import calibrate_state
from repro.core.pipeline import KWSPipeline, KWSPipelineConfig
from repro.core.tdfex import TDFExConfig, draw_chip
from repro.data.gscd import CLASSES
from repro.core.fex import FExConfig


def run(seed: int = 0):
    print("== Fig. 19: 12-class accuracy, hardware-sim FEx ==")
    import dataclasses

    # noise-calibrated chip model: SRO accumulated jitter set to the
    # chip's measured 248 uV_RMS floor (same calibration as Table I) —
    # this is the "increased noise floor" the paper blames for the
    # 86% (chip) vs 91% (software) gap
    tdcfg = dataclasses.replace(TDFExConfig(), phase_noise_rms=1.4)
    chip = draw_chip(jax.random.PRNGKey(seed), tdcfg)
    state = calibrate_state(tdcfg, chip)
    pipe_hw = KWSPipeline(
        KWSPipelineConfig(frontend="hardware", tdfex=tdcfg), state=state
    )
    train, test = datasets(seed)

    # record FV_Raw from the "chip" for train + test (Section III-F flow)
    key = jax.random.PRNGKey(seed + 99)
    k1, k2 = jax.random.split(key)
    raw_tr = pipe_hw.record_features(train["audio"], key=k1)
    raw_te = pipe_hw.record_features(test["audio"], key=k2)
    cfg = tdcfg.fex
    ftr, stats = frames_to_features(
        raw_tr, cfg, True, True, already_raw=True
    )
    fte, _ = frames_to_features(
        raw_te, cfg, True, True, stats=stats, already_raw=True
    )
    model = train_classifier(ftr, train["label"], seed=seed)
    acc, conf = evaluate(model, fte, test["label"])
    print(f"  hardware-sim accuracy: {acc:6.2%} (paper chip: 86.03%)")

    # deployment check: the bit-exact integer engine (int8 weight codes,
    # Q6.8 activations, 24-bit accumulators — what the IC actually runs)
    # must reproduce the QAT evaluation decision-for-decision
    acc_int, conf_int = evaluate(
        model, fte, test["label"], classifier="integer"
    )
    int_ok = bool(np.array_equal(conf, conf_int))
    print(
        f"  integer-engine accuracy: {acc_int:6.2%} "
        f"(bit-exact vs QAT: {'PASS' if int_ok else 'FAIL'})"
    )

    # software-model comparison on the same data/split — the same
    # pipeline call sites with frontend="software"
    pipe_sw = KWSPipeline(KWSPipelineConfig(frontend="software"))
    raw_sw_tr = pipe_sw.record_features(train["audio"])
    raw_sw_te = pipe_sw.record_features(test["audio"])
    str_, stats_sw = frames_to_features(
        raw_sw_tr, cfg, True, True, already_raw=True
    )
    ste, _ = frames_to_features(
        raw_sw_te, cfg, True, True, stats=stats_sw, already_raw=True
    )
    model_sw = train_classifier(str_, train["label"], seed=seed)
    acc_sw, _ = evaluate(model_sw, ste, test["label"])
    print(f"  software-model accuracy: {acc_sw:6.2%} (paper: 91.35%)")
    print(f"  hw-sw gap: {acc_sw - acc:+.2%} (paper: +5.3pp)")

    tpr = np.diag(conf) / np.maximum(conf.sum(1), 1)
    order = np.argsort(tpr)
    print("  per-class TPR (worst -> best):")
    for i in order:
        print(f"    {CLASSES[i]:8s} {tpr[i]:6.2%}")
    print("  confusion matrix (rows=true):")
    for i, row in enumerate(conf):
        print(f"    {CLASSES[i]:8s} " + " ".join(f"{v:3d}" for v in row))
    ok = acc > 2.0 / 12.0 and acc_sw >= acc - 0.03 and int_ok
    print(f"  claim (noisy hw <= sw within tolerance, both >> chance, "
          f"integer == QAT): {'PASS' if ok else 'FAIL'}")
    return {"acc_hw": acc, "acc_sw": acc_sw, "acc_int": acc_int,
            "integer_matches_qat": int_ok, "tpr": tpr.tolist(), "ok": ok}


if __name__ == "__main__":
    run()
