"""DeltaKWS-style accuracy vs effective-MACs tradeoff for the ΔGRU.

Trains the paper's QAT GRU-FC on the synthetic GSCD (the
benchmarks.common recipe), then sweeps the ΔGRU threshold θ
(`repro.core.gru_delta`, input and hidden deltas alike) and measures,
per θ:

  * 12-class accuracy through the delta engine (θ=0 must reproduce the
    QAT predictions EXACTLY — the bit-identity contract);
  * the measured effective-MAC fraction (executed / offered, dense FC
    included — the same accounting as the serving telemetry
    `srv.sparsity`);
  * predicted IC latency and power at that sparsity, via
    `repro.core.energy.AcceleratorModel(effective_mac_fraction=...)` —
    dynamic MAC energy scales with the executed work, leakage does not
    (the DeltaKWS split).

Claim checked (the DeltaKWS result, transposed to our corpus): some θ
achieves >= 2x MAC reduction (effective fraction <= 0.5) within 1
accuracy point of the dense QAT baseline. Writes ``BENCH_delta.json``.

  PYTHONPATH=src python -m benchmarks.fig_delta_tradeoff
"""

from __future__ import annotations

import functools
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    datasets,
    frames_to_features,
    record_software_frames,
    timed,
    train_classifier,
)
from repro.core.energy import AcceleratorModel, ICPowerModel
from repro.core.fex import FExConfig
from repro.core.gru_delta import (
    DeltaConfig,
    delta_classifier_forward,
    effective_mac_fraction,
)

THETAS = (0.0, 0.05, 0.1, 0.15, 0.25, 0.4, 0.6, 1.0)


def run(seed: int = 0):
    print("== ΔGRU accuracy vs effective-MACs tradeoff (DeltaKWS-style) ==")
    train, test = datasets(seed)
    cfg = FExConfig()
    with timed("features"):
        ftr, stats = frames_to_features(
            record_software_frames(train["audio"], cfg), cfg, True, True
        )
        fte, _ = frames_to_features(
            record_software_frames(test["audio"], cfg), cfg, True, True,
            stats=stats,
        )
    with timed("train"):
        model = train_classifier(ftr, train["label"], seed=seed)
    gcfg = model["config"]
    labels = np.asarray(test["label"])

    # dense QAT baseline: ONE forward pass yields both the per-example
    # predictions (the θ=0 gate compares decisions, not aggregate
    # accuracy — compensating flips must not pass) and the accuracy
    @jax.jit
    def qat_preds_fn(fv):
        from repro.core.gru import gru_classifier_forward

        return jnp.argmax(
            gru_classifier_forward(model["params"], fv, gcfg)[:, -1, :],
            axis=-1,
        )

    base_preds = np.concatenate([
        np.asarray(qat_preds_fn(jnp.asarray(fte[i : i + 128])))
        for i in range(0, len(labels), 128)
    ])
    base_acc = float((base_preds == labels).mean())
    print(f"  dense QAT baseline: {base_acc:6.2%}")

    @functools.partial(jax.jit, static_argnums=(1,))
    def delta_eval(fv, thetas):
        logits, states = delta_classifier_forward(
            model["params"], fv, gcfg, thetas, return_states=True
        )
        return (
            jnp.argmax(logits[:, -1, :], axis=-1),
            effective_mac_fraction(states, gcfg),
        )

    pm_dense = ICPowerModel()
    dense_lat_ms = pm_dense.accel.latency_s(gcfg) * 1e3
    dense_uw = pm_dense.total_power_w(gcfg) * 1e6

    rows = []
    theta0_exact = None
    for theta in THETAS:
        thetas = DeltaConfig(
            theta_x=theta, theta_h=theta
        ).code_thresholds(gcfg.num_layers)
        preds, fracs = [], []
        for i in range(0, len(labels), 128):
            p, f = delta_eval(jnp.asarray(fte[i : i + 128]), thetas)
            preds.append(np.asarray(p))
            fracs.append(np.asarray(f))
        preds = np.concatenate(preds)
        frac = float(np.concatenate(fracs).mean())
        acc = float((preds == labels).mean())
        if theta == 0.0:
            theta0_exact = bool(np.array_equal(preds, base_preds))
        # predicted IC numbers at this measured sparsity
        accel = AcceleratorModel(effective_mac_fraction=frac)
        pm = ICPowerModel(accel=accel)
        row = {
            "theta": theta,
            "accuracy": acc,
            "effective_mac_fraction": frac,
            "mac_reduction": 1.0 / max(frac, 1e-9),
            "accuracy_drop_pts": (base_acc - acc) * 100.0,
            "pred_latency_ms": accel.latency_s(gcfg) * 1e3,
            "pred_accel_uw": pm.accelerator_power_w(gcfg) * 1e6,
            "pred_total_uw": pm.total_power_w(gcfg) * 1e6,
        }
        rows.append(row)
        print(
            f"  θ={theta:4.2f}: acc {acc:6.2%} "
            f"(Δ {row['accuracy_drop_pts']:+5.2f} pts)  "
            f"eff-MAC {frac:5.3f} ({row['mac_reduction']:4.1f}x)  "
            f"-> {row['pred_latency_ms']:5.2f} ms, "
            f"{row['pred_total_uw']:5.2f} µW"
        )

    # θ=0 is the bit-identity point: the delta engine reproduced the
    # dense QAT predictions decision-for-decision (array equality of
    # per-example argmaxes, set inside the sweep above)
    # DeltaKWS claim: >= 2x MAC reduction within 1 accuracy point
    good = [
        r for r in rows
        if r["effective_mac_fraction"] <= 0.5
        and r["accuracy_drop_pts"] <= 1.0
    ]
    best = max(good, key=lambda r: r["mac_reduction"], default=None)
    ok = theta0_exact and best is not None
    claim = {
        "what": "ΔGRU: some θ achieves >= 2x MAC reduction (effective "
                "fraction <= 0.5) within 1 accuracy point of dense QAT "
                "on the synthetic GSCD; θ=0 reproduces QAT exactly",
        "dense_accuracy": base_acc,
        "dense_latency_ms": dense_lat_ms,
        "dense_total_uw": dense_uw,
        "theta0_exact": theta0_exact,
        "best": best,
        "ok": ok,
    }
    with open("BENCH_delta.json", "w") as f:
        json.dump({"rows": rows, "claim": claim}, f, indent=2)
    if best is not None:
        print(
            f"fig_delta_tradeoff: θ={best['theta']:.2f} gives "
            f"{best['mac_reduction']:.1f}x fewer MACs at "
            f"{best['accuracy_drop_pts']:+.2f} pts "
            f"({best['pred_total_uw']:.1f} µW predicted vs "
            f"{dense_uw:.1f} µW dense), θ=0 exact: {theta0_exact}  "
            f"[{'PASS' if ok else 'FAIL'}] (BENCH_delta.json written)"
        )
    else:
        print(
            f"fig_delta_tradeoff: no θ reached 2x within 1 pt "
            f"(θ=0 exact: {theta0_exact})  [FAIL] "
            f"(BENCH_delta.json written)"
        )
    return claim


if __name__ == "__main__":
    run()
