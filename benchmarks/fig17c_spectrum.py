"""Fig. 17(c) reproduction: output spectrum of the DeltaSigma TDC shows
first-order noise shaping (20 dB/dec) for both zero and sine inputs."""

import numpy as np

from repro.core.tdfex import TDFExConfig, sro_tdc


def _slope_db_per_decade(freqs, psd, f_lo, f_hi):
    m = (freqs > f_lo) & (freqs < f_hi)
    x = np.log10(freqs[m])
    y = 10 * np.log10(psd[m] + 1e-30)
    a, _b = np.polyfit(x, y, 1)
    return a


def run(seed: int = 0):
    print("== Fig. 17c: DeltaSigma TDC noise shaping ==")
    cfg = TDFExConfig()
    fs_tdc = cfg.f_tdc
    n_frames = 16
    spf = cfg.decimation // cfg.tdc_oversample

    rng = np.random.default_rng(seed)
    results = {}
    for name, u in [
        ("zero input", np.full((1, spf * n_frames, 1), 0.08, np.float32)),
        ("sine input", (0.08 + 0.05 * np.sin(
            2 * np.pi * 100.0 * np.arange(spf * n_frames)
            / cfg.fex.fs_internal))[None, :, None].astype(np.float32)),
    ]:
        _, diff = sro_tdc(jnp_u(u), cfg, return_diff_stream=True)
        d = np.asarray(diff)[0, :, 0]
        d = d - d.mean()
        win = np.hanning(len(d))
        psd = np.abs(np.fft.rfft(d * win)) ** 2
        freqs = np.fft.rfftfreq(len(d), 1 / fs_tdc)
        slope = _slope_db_per_decade(freqs, psd, fs_tdc / 2000, fs_tdc / 4)
        results[name] = slope
        print(f"  {name:11s}: quantization-noise slope "
              f"{slope:+5.1f} dB/dec (ideal 1st-order: +20)")
    ok = all(10.0 < s < 32.0 for s in results.values())
    print(f"  claim (first-order shaping): {'PASS' if ok else 'FAIL'}")
    return {"slopes": results, "ok": ok}


def jnp_u(u):
    import jax.numpy as jnp

    return jnp.asarray(u)


if __name__ == "__main__":
    run()
