"""Roofline table: render the dry-run matrix (results/dryrun/*.json)
into the EXPERIMENTS.md §Roofline table. Run the dry-run first:

  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod --out results/dryrun
"""

import glob
import json
import os

RESULTS = os.environ.get("DRYRUN_RESULTS", "results/dryrun")


def load_reports(tag="sp"):
    out = {}
    for fn in sorted(glob.glob(os.path.join(RESULTS, f"*__{tag}.json"))):
        with open(fn) as f:
            r = json.load(f)
        out[(r["arch"], r["shape"])] = r
    return out


def run(seed: int = 0):
    print("== Roofline table (single-pod 16x16, per-device terms) ==")
    reports = load_reports("sp")
    if not reports:
        print(f"  no reports in {RESULTS} — run the dry-run first")
        return {"ok": False, "n": 0}
    hdr = (f"  {'arch':22s} {'shape':11s} {'compute':>9s} {'memory':>9s} "
           f"{'collect':>9s} {'bound':>10s} {'useful':>6s} {'roofl%':>7s} "
           f"{'peakGB':>7s}")
    print(hdr)
    for (arch, shape), r in sorted(reports.items()):
        print(
            f"  {arch:22s} {shape:11s} "
            f"{r['compute_s'] * 1e3:8.1f}m {r['memory_s'] * 1e3:8.1f}m "
            f"{r['collective_s'] * 1e3:8.1f}m {r['dominant']:>10s} "
            f"{r['useful_ratio']:6.2f} {r['roofline_fraction']:7.2%} "
            f"{r['peak_bytes_per_device'] / 1e9:7.2f}"
        )
    mp = load_reports("mp")
    fits = sum(
        1 for r in reports.values()
        if r["peak_bytes_per_device"] < 16e9
    )
    print(f"  single-pod cells: {len(reports)} ({fits} fit 16 GB HBM); "
          f"multi-pod cells compiled: {len(mp)}")
    ok = len(reports) >= 33 and len(mp) >= 33
    print(f"  claim (full matrix compiles on both meshes): "
          f"{'PASS' if ok else 'FAIL'}")
    return {"ok": ok, "n": len(reports), "n_mp": len(mp)}


if __name__ == "__main__":
    run()
