"""Roofline table: render the dry-run matrix (results/dryrun/*.json)
into the EXPERIMENTS.md §Roofline table. Run the dry-run first:

  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod --out results/dryrun

Alongside the analytic table, `measure_fused_tick()` contributes two
MEASURED points from the one-kernel serving tick
(`repro.kernels.tick_fused`): the fused delta tick at θ=0
(dense-equivalent — every Δ column fires) and at θ=0.15 (the
fig_delta_tradeoff operating point), each with its wall-clock ms/tick,
measured effective-MAC fraction, and achieved MAC/s against the
classifier's offered work. The pair is the roofline-facing view of the
sparsity_speedup claim in BENCH_serve.json: on the compiled pallas
tier the θ>0 point should sit at the SAME achieved useful-MAC/s but
lower latency, because the gather-compacted column update skips the
work instead of masking it.
"""

import glob
import json
import os

RESULTS = os.environ.get("DRYRUN_RESULTS", "results/dryrun")


def measure_fused_tick(n_streams=64, n_ticks=20, thetas=(0.0, 0.15)):
    """Measured fused-tick points: (θ, ms/tick, eff-MAC fraction,
    achieved offered-MAC/s). Self-contained — builds its own synthetic
    pipeline; uses the fused tier this platform executes (fused-pallas
    on TPU, fused-interpret elsewhere)."""
    import time

    import jax
    import numpy as np

    from benchmarks.serve_load import WARMUP, _pipeline, _traffic
    from repro.serving.serve_loop import StreamingKWSServer

    impl = (
        "fused-pallas" if jax.default_backend() == "tpu"
        else "fused-interpret"
    )
    points = []
    for theta in thetas:
        pipe = _pipeline("delta", theta=theta)
        params = pipe.init_params(jax.random.PRNGKey(0))
        srv = StreamingKWSServer(
            pipe, params, max_streams=n_streams, tick_impl=impl
        )
        for sid in range(n_streams):
            srv.open_stream(sid)
        slabs, _ = _traffic(pipe, n_streams, n_streams, "fv")
        lat = []
        for t in range(WARMUP + n_ticks):
            slab, mask = slabs[t % len(slabs)]
            t0 = time.perf_counter()
            srv.step_batch(slab, mask)
            if t >= WARMUP:
                lat.append(time.perf_counter() - t0)
        mean_s = float(np.mean(lat))
        # offered classifier work per stream-tick (dense MAC count:
        # 3H(I+H) + 3H(2H) per-layer gates + the FC head)
        g = pipe.config.gru
        offered = 0
        in_dim = g.input_dim
        for _layer in range(g.num_layers):
            offered += 3 * g.hidden_dim * (in_dim + g.hidden_dim)
            in_dim = g.hidden_dim
        offered += g.hidden_dim * g.num_classes
        slots = list(srv.active.values())
        frac = float(np.mean(srv.sparsity[slots]))
        points.append({
            "theta": theta,
            "tick_impl": impl,
            "jax_backend": jax.default_backend(),
            "ms_per_tick": mean_s * 1e3,
            "eff_mac_fraction": frac,
            "offered_mac_per_s": offered * n_streams / mean_s,
            "useful_mac_per_s": offered * n_streams * frac / mean_s,
        })
    return points


def load_reports(tag="sp"):
    out = {}
    for fn in sorted(glob.glob(os.path.join(RESULTS, f"*__{tag}.json"))):
        with open(fn) as f:
            r = json.load(f)
        out[(r["arch"], r["shape"])] = r
    return out


def run(seed: int = 0):
    print("== Roofline table (single-pod 16x16, per-device terms) ==")
    reports = load_reports("sp")
    if not reports:
        print(f"  no reports in {RESULTS} — run the dry-run first")
        return {"ok": False, "n": 0}
    hdr = (f"  {'arch':22s} {'shape':11s} {'compute':>9s} {'memory':>9s} "
           f"{'collect':>9s} {'bound':>10s} {'useful':>6s} {'roofl%':>7s} "
           f"{'peakGB':>7s}")
    print(hdr)
    for (arch, shape), r in sorted(reports.items()):
        print(
            f"  {arch:22s} {shape:11s} "
            f"{r['compute_s'] * 1e3:8.1f}m {r['memory_s'] * 1e3:8.1f}m "
            f"{r['collective_s'] * 1e3:8.1f}m {r['dominant']:>10s} "
            f"{r['useful_ratio']:6.2f} {r['roofline_fraction']:7.2%} "
            f"{r['peak_bytes_per_device'] / 1e9:7.2f}"
        )
    mp = load_reports("mp")
    fits = sum(
        1 for r in reports.values()
        if r["peak_bytes_per_device"] < 16e9
    )
    print(f"  single-pod cells: {len(reports)} ({fits} fit 16 GB HBM); "
          f"multi-pod cells compiled: {len(mp)}")
    ok = len(reports) >= 33 and len(mp) >= 33
    print(f"  claim (full matrix compiles on both meshes): "
          f"{'PASS' if ok else 'FAIL'}")
    print("== Measured: one-kernel serving tick (repro.kernels."
          "tick_fused) ==")
    tick_points = measure_fused_tick()
    for p in tick_points:
        print(
            f"  fused tick ({p['tick_impl']}, {p['jax_backend']}) "
            f"theta={p['theta']:.2f}: {p['ms_per_tick']:7.2f} ms/tick  "
            f"eff-MAC {p['eff_mac_fraction']:.3f}  "
            f"offered {p['offered_mac_per_s'] / 1e6:8.1f} MMAC/s  "
            f"useful {p['useful_mac_per_s'] / 1e6:8.1f} MMAC/s"
        )
    return {
        "ok": ok, "n": len(reports), "n_mp": len(mp),
        "fused_tick": tick_points,
    }


if __name__ == "__main__":
    run()
