"""Serving load generator: fused jitted tick vs the pre-refactor path.

Sweeps classifier backend x `max_streams` x occupancy x input kind over
the streaming KWS server and measures sustained tick throughput and
per-tick latency for:

  * ``fused``  — the current `StreamingKWSServer.step_batch`: one
    jit-compiled device program per tick (frontend + GRU + softmax +
    smoothing) over donated `ServerState` buffers, slab-in/slab-out;
  * ``legacy`` — a faithful copy of the pre-refactor `step`: separate
    jitted feature / GRU dispatches, host-side carry masking via
    tree_map, and a per-stream Python loop doing numpy softmax + score
    smoothing;
  * ``scan``   — the offline `run_batch` lax.scan replay (whole tick
    sequence as one device program; the replay returns to the host
    once, so there are NO per-tick latencies — scan rows report
    sustained throughput only, with ``p50_ms``/``p99_ms`` = null),
    swept for both kinds. The scan-fv point at 256 streams is what the
    headline claim below gates on;
  * ``pipelined`` — the live async ingress
    (`repro.serving.ingress.PipelinedIngress` over
    `step_batch_async`/`run_batch_async`): double-buffered host slab
    staging, non-blocking dispatch, deferred score fetch, and a
    ``window``-tick coalescing scan dispatch. Unlike scan this is a
    LIVE mode — every tick's submit-to-scores latency is measured
    (handle retirement timestamps), so its rows carry real p50/p99,
    and the SLO block below gates on them.

Input kinds: ``fv`` ticks carry precomputed FV_Norm frames (isolates
the serving-path overhead the fused tick removes); ``audio`` ticks
carry raw 16 ms hops (adds the frontend filter scan, identical compute
in both paths, so the ratio there is bounded by the shared filter cost
on CPU).

Classifier backends (``--classifier``, default sweeps qat + integer +
delta): ``qat`` is the fake-quant float tick; ``integer`` runs the
bit-exact int8/Q6.8 engine (`repro.core.gru_int`) — weight codes
resident, int32 GRU state leaves in `ServerState` — through the same
fused tick and scan drivers; ``delta`` / ``delta-int`` run the
temporal-sparsity ΔGRU engine (`repro.core.gru_delta`) at the
``--theta`` threshold, and their rows record the measured per-stream
effective-MAC fraction (``sparsity``, mean over active streams — the
`srv.sparsity` telemetry; dense backends record 1.0). ``legacy`` mode
is benched only for ``qat`` (the pre-refactor path had no integer or
delta engine), so the headline claim is unchanged; the other backends'
rows quantify the cost/benefit of code-domain and sparsity-aware
serving.

Cascade (``--cascade``, plus ``--wake-threshold``): serves every
non-legacy point with the stage-1 wake gate
(`repro.serving.cascade`, energy detector) in the tick; each row then
records the measured mean classifier duty cycle (``wake_rate``, the
`srv.wake_rate` telemetry over the point's active streams — the load
generator's noise traffic mostly sits below a real threshold, so the
gate holds the classifier asleep and the row measures the gated
tick's throughput). Cascaded sweeps skip the headline claim: the
legacy baseline has no gate, so fused-vs-legacy is not
apples-to-apples there — the default (no ``--cascade``) sweep keeps
the claim unchanged.

Tick implementation (``--tick-impl``, default "auto"): every
non-legacy server is built with the chosen
`StreamingKWSServer(tick_impl=...)` — "xla" (one fused XLA program),
"fused-pallas" (the whole tick as ONE Pallas megakernel over stream
blocks, TPU), or "fused-interpret" (the same kernel body under the
Pallas interpreter; CPU-testable but interpreter-slow, so only for
correctness-shaped sweeps). Every row records the resolved
``tick_impl``, the kernel dispatch tier it ran (``tick_dispatch``:
"xla" / "pallas" / "interpret"), and the jax backend
(``jax_backend``), so artifacts from different platforms stay
comparable. Independent of the sweep, the payload carries a
``sparsity_speedup`` block benching the fused delta tick against
itself across ΔGRU thresholds (θ=0 dense-equivalent vs θ>0): the
gather-compacted column update turns temporal sparsity into wall-clock
tick speed, and the block's ``speedup_vs_dense`` (θ=0 time / θ=0.15
time) is gated >= 1.5x on real accelerators (recorded, not gated, on
CPU — where only the θ-monotonicity of the fused tick times is
meaningful).

Devices (``--devices``, default "auto"): every row records the device
count it ran on. Counts > 1 build the server on a ``("stream",)`` mesh
(the slot axis sharded block-wise, params replicated — bit-identical to
the single-device tick, see tests/test_serve_sharded.py) and are swept
for the fused/scan modes at 256+ streams, quantifying stream-parallel
scaling. "auto" sweeps 1 plus every power-of-two count the platform
exposes; emulate a multi-device host on CPU with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the CI slow job
records a devices=2 row this way). The headline claim stays pinned to
devices=1 so it is comparable across platforms.

Writes ``BENCH_serve.json`` (fields documented in benchmarks/common.py)
and checks the claim: at 256 streams, full occupancy, FV_Norm ticks, the
fused tick body sustains >= 5x the legacy path's ticks/sec. The claimed
number is the *sustained* throughput of the fused tick — the scanned
replay driver, a serving mode that exists only because the tick is one
on-device function (the legacy path's per-tick numpy smoothing forces a
host round-trip every 16 ms, so it cannot be scanned at all). The live
per-call fused tick is reported alongside as ``speedup_live`` (it wins
by dispatch/host overhead only, since both paths pay the same GRU
compute per tick on CPU).

Alongside the claim the payload carries an SLO block ("slo") gating the
live async path the way a deployment would — latency, not throughput
alone: pipelined p99 <= the 16 ms tick budget at 256 streams (full
occupancy, fv, qat, devices=1) AND live pipelined throughput >= 0.5x
the scan ceiling on the same state at 64 and 256 streams.
``--fail-on-slo`` turns a violated gate into a non-zero exit for CI.

Observability (PR 10): the instrumented modes are built with
``metrics=True`` and CONSUME the server's own registry instead of
private perf_counter lists — fused rows read per-tick latency from the
``kws_serve_tick_ms`` histogram, pipelined rows read submit-to-scores
latency and throughput from the ingress's stage→commit→dispatch→retire
`TickTrace` spans (each pipelined row records the rolled-up ``spans``
percentiles; every instrumented row records its counted ``retraces``).
A ``metrics_overhead`` block measures metrics-on vs metrics-off fused
ticks on identical traffic and gates the difference < 5%
(``--fail-on-slo``), and the full registry snapshots of the
deployment-relevant points land in ``METRICS_serve.json`` next to the
BENCH artifact.

  PYTHONPATH=src python -m benchmarks.serve_load [--classifier all]
      [--devices auto|1|1,2,...] [--theta 0.25]
      [--tick-impl auto|xla|fused-pallas|fused-interpret]
      [--cascade [--wake-threshold 0.15]] [--fail-on-slo]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import QUICK, percentile_stats
from repro.core import quant
from repro.core.fex import fit_norm_stats
from repro.core.gru_delta import DeltaConfig
from repro.core.pipeline import KWSPipeline, KWSPipelineConfig
from repro.serving.cascade import CascadeConfig
from repro.serving.ingress import PipelinedIngress
from repro.serving.metrics import span_percentiles
from repro.serving.serve_loop import StreamingKWSServer

N_TICKS = 40 if QUICK else 200
WARMUP = 5
# async ingress shape for the pipelined rows: double buffering plus a
# 4-tick coalescing window — enough to amortize the fixed per-dispatch
# host cost below the per-tick device compute, while bounding the
# latency a tick spends waiting for its window at 3 ticks (well inside
# the 16 ms budget the SLO gates at 256 streams)
PIPELINE_DEPTH = 2
PIPELINE_WINDOW = 4
# the SLO gate (see run()): live pipelined p99 within the paper's tick
# budget, live pipelined throughput within 2x of the scan ceiling
SLO_P99_MS = 16.0
SLO_MIN_VS_SCAN = 0.5
# metrics-overhead gate (see _bench_metrics_overhead): a
# metrics-enabled fused tick may cost < 5% throughput over metrics-off
OVERHEAD_STREAMS = 256
OVERHEAD_BUDGET_FRAC = 0.05

# metrics snapshots captured per instrumented benched point, keyed
# (mode, classifier, kind, max_streams, occupancy, devices); run()
# writes the deployment-relevant ones to METRICS_serve.json
_SNAPSHOTS = {}


class _LegacyStreamingServer:
    """The pre-refactor per-stream serving path, kept verbatim as the
    benchmark baseline: per-tick Python dict loops, separate device
    dispatches, host-side carry masking, and numpy softmax + smoothing
    per stream. (It also carries the pre-refactor bug of advancing idle
    streams' GRU states on zero frames — harmless here because the load
    generator submits every active stream each tick.)
    """

    def __init__(self, pipeline, params, max_streams):
        self.pipeline = pipeline
        self.params = params
        self.max_streams = max_streams
        self.smoothing = 0.7
        self.frontend_state = pipeline.state
        self.states = pipeline.streaming_init(max_streams)
        self.feat_carry = pipeline.streaming_features_init(max_streams)
        self.active = {}
        self.scores = np.zeros(
            (max_streams, pipeline.config.gru.num_classes), np.float32
        )
        self._free = list(range(max_streams))[::-1]

    def open_stream(self, stream_id):
        slot = self._free.pop()
        self.active[stream_id] = slot

    def _features_tick(self, chunks):
        s = self.pipeline.chunk_samples
        audio = np.zeros((self.max_streams, s), np.float32)
        mask = np.zeros((self.max_streams,), bool)
        for sid, chunk in chunks.items():
            audio[self.active[sid]] = chunk
            mask[self.active[sid]] = True
        new_carry, fv = self.pipeline.streaming_features_step(
            self.feat_carry, jnp.asarray(audio), self.frontend_state
        )
        m = jnp.asarray(mask)[:, None]
        self.feat_carry = jax.tree_util.tree_map(
            lambda new, old: jnp.where(m, new, old),
            new_carry, self.feat_carry,
        )
        return np.asarray(fv)

    def step(self, frames):
        c = self.pipeline.config.fex.num_channels
        hop = self.pipeline.chunk_samples
        dim = next(iter(frames.values())).shape[-1]
        if dim == hop:
            fv_all = self._features_tick(frames)
            fv = np.zeros((self.max_streams, c), np.float32)
            for sid in frames:
                fv[self.active[sid]] = fv_all[self.active[sid]]
        else:
            fv = np.zeros((self.max_streams, c), np.float32)
            for sid, frame in frames.items():
                fv[self.active[sid]] = frame
        self.states, logits = self.pipeline.streaming_step(
            self.params, self.states, jnp.asarray(fv)
        )
        logits = np.asarray(logits)
        out = {}
        for sid in frames:
            slot = self.active[sid]
            p = np.exp(logits[slot] - logits[slot].max())
            p /= p.sum()
            self.scores[slot] = (
                self.smoothing * self.scores[slot]
                + (1 - self.smoothing) * p
            )
            out[sid] = {
                "probs": self.scores[slot].copy(),
                "top": int(self.scores[slot].argmax()),
            }
        return out


def _pipeline(classifier=None, theta=0.0, cascade=None):
    rng = np.random.default_rng(0)
    audio = jnp.asarray(
        rng.standard_normal((4, 16000)).astype(np.float32) * 0.05
    )
    boot = KWSPipeline(KWSPipelineConfig(use_norm=False))
    _, raw = boot.features(audio)
    stats = fit_norm_stats(quant.log_compress_lut(raw, 12, 10))
    delta = (
        DeltaConfig(theta_x=theta, theta_h=theta)
        if classifier in ("delta", "delta-int")
        else None
    )
    return KWSPipeline(
        KWSPipelineConfig(
            classifier=classifier, delta=delta, cascade=cascade
        ),
        norm_stats=stats,
    )


def _traffic(pipe, max_streams, n_active, kind, seed=0, n_variants=8):
    """Pre-built per-tick inputs (synthesis outside the timer): a list of
    (slab, mask) for the fused path and matching {sid: frame} dicts for
    the legacy path."""
    rng = np.random.default_rng(seed)
    dim = pipe.chunk_samples if kind == "audio" else \
        pipe.config.fex.num_channels
    slabs, dicts = [], []
    for _ in range(n_variants):
        slab = np.zeros((max_streams, dim), np.float32)
        mask = np.zeros((max_streams,), bool)
        frames = {}
        for sid in range(n_active):
            f = rng.standard_normal(dim).astype(np.float32) * 0.05
            slab[sid] = f
            mask[sid] = True
            frames[sid] = f
        slabs.append((slab, mask))
        dicts.append(frames)
    return slabs, dicts


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _bench_mode(mode, kind, pipe, params, max_streams, occupancy, n_ticks,
                devices=1, tick_impl="auto"):
    n_active = max(1, int(round(max_streams * occupancy)))
    slabs, dicts = _traffic(pipe, max_streams, n_active, kind)
    n_var = len(slabs)
    lat = []
    spans = None
    srv = None
    if mode == "legacy":
        assert devices == 1, "legacy path predates the serving mesh"
        srv = _LegacyStreamingServer(pipe, params, max_streams)
        for sid in range(n_active):
            srv.open_stream(sid)
        for t in range(WARMUP + n_ticks):
            frames = dicts[t % n_var]
            t0 = time.perf_counter()
            srv.step(frames)
            if t >= WARMUP:
                lat.append(time.perf_counter() - t0)
    elif mode == "fused":
        # metrics=True: the per-tick latencies below come from the
        # server's own kws_serve_tick_ms histogram (the registry IS
        # the bookkeeping; the benchmark keeps no private timer list).
        # The registry's cost is itself measured and gated by
        # _bench_metrics_overhead — < 5% of a fused tick.
        srv = StreamingKWSServer(
            pipe, params, max_streams=max_streams, devices=devices,
            tick_impl=tick_impl, metrics=True,
        )
        for sid in range(n_active):
            srv.open_stream(sid)
        for t in range(WARMUP + n_ticks):
            slab, mask = slabs[t % n_var]
            srv.step_batch(slab, mask)
        tick_hist = srv.metrics.histogram("kws_serve_tick_ms")
        lat = [s * 1e-3 for s in list(tick_hist.samples)[WARMUP:]]
        assert len(lat) == n_ticks
    elif mode == "pipelined":
        srv = StreamingKWSServer(
            pipe, params, max_streams=max_streams, devices=devices,
            tick_impl=tick_impl, metrics=True,
        )
        for sid in range(n_active):
            srv.open_stream(sid)
        dim = slabs[0][0].shape[-1]
        ing = PipelinedIngress(
            srv, dim, depth=PIPELINE_DEPTH, window=PIPELINE_WINDOW
        )
        for t in range(max(WARMUP, 2 * PIPELINE_WINDOW)):
            src_slab, src_mask = slabs[t % n_var]
            slab, mask = ing.stage()
            slab[:] = src_slab
            mask[:] = src_mask
            ing.commit()
        ing.drain()
        n0 = len(srv.metrics.traces)  # skip the warmup ticks' traces
        for t in range(n_ticks):
            src_slab, src_mask = slabs[t % n_var]
            slab, mask = ing.stage()
            slab[:] = src_slab
            mask[:] = src_mask
            ing.commit()
        ing.drain()
        # per-tick latency and throughput both come from the ingress's
        # TickTrace spans (the registry replaces the old meta=
        # perf_counter freight): submit-to-scores = commit -> retire
        # per tick (ticks of one coalesced window share a retirement
        # instant but not a commit instant), wall = first stage ->
        # last retire over the measured ticks
        traces = list(srv.metrics.traces)[n0:]
        lat = [
            tr.marks["retire"] - tr.marks["commit"] for tr in traces
        ]
        assert len(lat) == n_ticks
        wall = traces[-1].marks["retire"] - traces[0].marks["stage"]
        spans = span_percentiles(traces)
    elif mode == "scan":
        srv = StreamingKWSServer(
            pipe, params, max_streams=max_streams, devices=devices,
            tick_impl=tick_impl,
        )
        for sid in range(n_active):
            srv.open_stream(sid)
        slab = np.stack(
            [slabs[t % n_var][0] for t in range(n_ticks)], axis=0
        )
        mask = np.stack(
            [slabs[t % n_var][1] for t in range(n_ticks)], axis=0
        )
        srv.run_batch(slab, mask)  # warm the (n_ticks,)-shaped program
        # best of 3 timed replays: the amortized number is a property of
        # the compiled program, not of transient host load
        wall = min(
            _timed(lambda: srv.run_batch(slab, mask)) for _ in range(3)
        )
    else:
        raise ValueError(mode)
    if mode in ("legacy", "fused"):
        # blocking per-call modes: each tick's wall time is disjoint, so
        # throughput is the reciprocal mean latency
        stats = percentile_stats(lat)
        ticks_per_s = 1.0 / float(np.mean(lat))
    elif mode == "pipelined":
        # overlapped latencies: percentiles are real (per-tick submit-to-
        # scores), but throughput MUST come from the wall clock — ticks
        # are in flight concurrently, so 1/mean(lat) would undercount
        stats = percentile_stats(lat)
        ticks_per_s = n_ticks / wall
    else:
        # scan: one device program, one host round-trip — there is no
        # per-tick latency to report. Fabricating lat = [wall/n]*n here
        # used to make p50==p99==mean look like measured percentiles.
        stats = {
            "p50_ms": None,
            "p99_ms": None,
            "mean_ms": wall / n_ticks * 1e3,
        }
        ticks_per_s = n_ticks / wall
    # measured temporal sparsity of this point's traffic: mean
    # effective-MAC fraction over the active streams (srv.sparsity
    # telemetry; identically 1.0 for the dense backends, None for the
    # pre-telemetry legacy path)
    sparsity = None
    wake = None
    if isinstance(srv, StreamingKWSServer):
        slots = list(srv.active.values())
        sparsity = float(np.mean(srv.sparsity[slots]))
        # measured classifier duty cycle under the stage-1 gate (mean
        # srv.wake_rate over active streams; identically 1.0 when no
        # cascade is configured, None for the pre-telemetry legacy path)
        wake = float(np.mean(srv.wake_rate[slots]))
    delta_cfg = pipe.config.delta
    casc_cfg = pipe.config.cascade
    row = {
        "classifier": pipe.config.classifier_key,
        "mode": mode,
        "kind": kind,
        # tick implementation the row's server resolved to, the kernel
        # dispatch tier its ticks ran, and the jax backend underneath —
        # None/None for the legacy path (predates tick_impl); recorded
        # per row so artifacts from different platforms compare
        "tick_impl": (
            srv.tick_impl if isinstance(srv, StreamingKWSServer) else None
        ),
        "tick_dispatch": (
            srv.tick_dispatch
            if isinstance(srv, StreamingKWSServer) else None
        ),
        "jax_backend": jax.default_backend(),
        "devices": devices,
        "max_streams": max_streams,
        "occupancy": occupancy,
        "active_streams": n_active,
        "n_ticks": n_ticks,
        "ticks_per_s": ticks_per_s,
        "streams_per_s": ticks_per_s * n_active,
        "window": PIPELINE_WINDOW if mode == "pipelined" else None,
        "sparsity": sparsity,
        "theta": None if delta_cfg is None else delta_cfg.theta_x,
        "wake_rate": wake,
        "wake_threshold": (
            None if casc_cfg is None else casc_cfg.wake_threshold
        ),
        # counted (program, shape) retraces this row's server paid —
        # exact jit accounting from the observability layer (None for
        # the pre-telemetry legacy path)
        "retraces": (
            srv.retrace_count
            if isinstance(srv, StreamingKWSServer) else None
        ),
        # pipelined rows: stage->commit->dispatch->retire span
        # percentiles from the ingress's per-tick traces
        "spans": spans,
        **stats,
    }
    if isinstance(srv, StreamingKWSServer) and srv.metrics is not None:
        _SNAPSHOTS[
            (mode, pipe.config.classifier_key, kind, max_streams,
             occupancy, devices)
        ] = srv.metrics_snapshot()
    return row


# θ points of the sparsity-speedup block: θ=0 is the dense-equivalent
# fused tick (every column fires), 0.15 is the fig_delta_tradeoff
# operating point the headline gate compares against, 0.3 extends the
# monotonicity check
SPARSITY_THETAS = (0.0, 0.15, 0.3)
SPARSITY_STREAMS = 64
SPEEDUP_FLOOR = 1.5


def _bench_sparsity_speedup(n_ticks):
    """Fused delta tick vs ITSELF across ΔGRU thresholds.

    The megakernel's gather-compacted column update does work
    proportional to the fire count, so the θ=0.15 tick should beat the
    θ=0 (dense-equivalent) tick on wall clock — temporal sparsity as
    latency, not just a counter. Benched on the fused tier this
    platform executes ("fused-pallas" on TPU, else "fused-interpret"):
    ``speedup_vs_dense`` = t(θ=0)/t(θ=0.15) is gated >= 1.5x only when
    the pallas tier actually ran (a real accelerator); on CPU the
    interpreter's per-block overhead swamps the MAC savings, so the
    block records the times and the θ-monotonicity without gating.
    """
    impl = (
        "fused-pallas" if jax.default_backend() == "tpu"
        else "fused-interpret"
    )
    rows = []
    for theta in SPARSITY_THETAS:
        pipe = _pipeline("delta", theta=theta)
        params = pipe.init_params(jax.random.PRNGKey(0))
        srv = StreamingKWSServer(
            pipe, params, max_streams=SPARSITY_STREAMS, tick_impl=impl
        )
        for sid in range(SPARSITY_STREAMS):
            srv.open_stream(sid)
        slabs, _ = _traffic(pipe, SPARSITY_STREAMS, SPARSITY_STREAMS, "fv")
        lat = []
        for t in range(WARMUP + n_ticks):
            slab, mask = slabs[t % len(slabs)]
            t0 = time.perf_counter()
            srv.step_batch(slab, mask)
            if t >= WARMUP:
                lat.append(time.perf_counter() - t0)
        slots = list(srv.active.values())
        rows.append({
            "theta": theta,
            "mean_ms": float(np.mean(lat)) * 1e3,
            "ticks_per_s": 1.0 / float(np.mean(lat)),
            "sparsity": float(np.mean(srv.sparsity[slots])),
        })
        print(
            f"  sparsity-speedup {impl}: theta={theta:.2f} "
            f"{rows[-1]['mean_ms']:7.2f} ms/tick  "
            f"eff-MAC {rows[-1]['sparsity']:.3f}"
        )
    dense = rows[0]
    sparse = next(r for r in rows if r["theta"] == 0.15)
    speedup = dense["mean_ms"] / sparse["mean_ms"]
    # 5% timing-noise tolerance: adjacent θ points with near-equal fire
    # counts (e.g. 0.15 vs 0.3 on already-sparse traffic) jitter within
    # a host scheduler quantum
    monotone = all(
        rows[i + 1]["mean_ms"] <= rows[i]["mean_ms"] * 1.05
        for i in range(len(rows) - 1)
    )
    gated = jax.default_backend() in ("tpu", "gpu")
    return {
        "what": (
            f"fused delta tick at theta=0.15 beats its own theta=0 "
            f"(dense-equivalent) tick by >= {SPEEDUP_FLOOR}x at "
            f"{SPARSITY_STREAMS} streams, fv ticks; gated on real "
            f"accelerators, recorded (with theta-monotonicity) on CPU"
        ),
        "tick_impl": impl,
        "tick_dispatch": _TICK_DISPATCH_TIER[impl],
        "jax_backend": jax.default_backend(),
        "rows": rows,
        "speedup_vs_dense": speedup,
        "monotone_in_theta": monotone,
        "gated": gated,
        "ok": (speedup >= SPEEDUP_FLOOR) if gated else None,
    }


# mirrors repro.serving.serve_loop._TICK_DISPATCH for the artifact
_TICK_DISPATCH_TIER = {
    "xla": "xla", "fused-pallas": "pallas", "fused-interpret": "interpret",
}


def _bench_metrics_overhead(n_ticks):
    """Measured cost of `metrics=`: fused fv ticks, metrics-on vs -off.

    Two servers on identical pipeline/params/traffic at 256 streams
    full occupancy — one with a `MetricsRegistry`, one without — timed
    by the SAME external wall clock (so the measurement itself is
    symmetric), in interleaved rounds so transient host load hits both
    configs alike; best-of-3 round means per config. The observability
    contract gates ``overhead_frac`` (on/off - 1) < 5%: the registry
    is two host clock reads and a couple of dict/deque updates per
    tick, which must stay invisible next to a 256-stream device tick.
    The metrics-on server's full `metrics_snapshot()` is returned
    alongside and written to METRICS_serve.json.
    """
    pipe = _pipeline("qat")
    params = pipe.init_params(jax.random.PRNGKey(0))
    slabs, _ = _traffic(
        pipe, OVERHEAD_STREAMS, OVERHEAD_STREAMS, "fv"
    )
    n_var = len(slabs)
    servers = {
        "off": StreamingKWSServer(
            pipe, params, max_streams=OVERHEAD_STREAMS
        ),
        "on": StreamingKWSServer(
            pipe, params, max_streams=OVERHEAD_STREAMS, metrics=True
        ),
    }
    for srv in servers.values():
        for sid in range(OVERHEAD_STREAMS):
            srv.open_stream(sid)
        for t in range(WARMUP):
            srv.step_batch(*slabs[t % n_var])
    means = {"off": [], "on": []}
    for _round in range(3):
        for name, srv in servers.items():
            t0 = time.perf_counter()
            for t in range(n_ticks):
                srv.step_batch(*slabs[t % n_var])
            means[name].append(
                (time.perf_counter() - t0) / n_ticks
            )
    off = min(means["off"])
    on = min(means["on"])
    overhead = on / off - 1.0
    block = {
        "what": (
            f"metrics-enabled fused tick costs < "
            f"{OVERHEAD_BUDGET_FRAC:.0%} throughput over metrics-off "
            f"at {OVERHEAD_STREAMS} streams (fv, qat, occupancy 1.0, "
            f"devices=1; best-of-3 interleaved round means)"
        ),
        "streams": OVERHEAD_STREAMS,
        "n_ticks": n_ticks,
        "mean_ms_metrics_off": off * 1e3,
        "mean_ms_metrics_on": on * 1e3,
        "overhead_frac": overhead,
        "budget_frac": OVERHEAD_BUDGET_FRAC,
        "ok": overhead < OVERHEAD_BUDGET_FRAC,
    }
    return block, servers["on"].metrics_snapshot()


def _auto_devices():
    """[1] plus every power-of-two device count the platform exposes."""
    visible = len(jax.devices())
    counts = [1]
    d = 2
    while d <= visible:
        counts.append(d)
        d *= 2
    return counts


def run(classifiers=("qat", "integer", "delta"), devices=None, theta=0.25,
        cascade=False, wake_threshold=0.15, fail_on_slo=False,
        tick_impl="auto"):
    casc = (
        CascadeConfig(wake_threshold=wake_threshold) if cascade else None
    )
    if devices is None:
        devices = _auto_devices()
    sweep_streams = [64, 256] if QUICK else [64, 256, 1024]
    visible = len(jax.devices())
    bad = [d for d in devices if d < 1 or d > visible]
    if bad:
        # fail before any row is benched — a mid-sweep ValueError from
        # stream_mesh would throw away minutes of measurements
        raise ValueError(
            f"--devices {bad} invalid for this platform ({visible} "
            f"visible device(s); emulate more with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N)"
        )
    multi_sizes = [ms for ms in sweep_streams if ms >= 256]
    useless = [
        d for d in devices
        if d > 1 and not any(ms % d == 0 for ms in multi_sizes)
    ]
    if useless:
        # same fail-fast contract: a count that divides none of the
        # multi-device stream sizes would silently produce zero rows
        raise ValueError(
            f"--devices {useless} divide none of the multi-device "
            f"stream sizes {multi_sizes}; pick divisors of those"
        )
    occupancies = [0.5, 1.0]
    results = []
    frontend = None
    for clf in classifiers:
        pipe = _pipeline(clf, theta=theta, cascade=casc)
        frontend = pipe.config.frontend
        params = pipe.init_params(jax.random.PRNGKey(0))
        for kind in ("fv", "audio"):
            # the legacy baseline predates the classifier registry;
            # bench it only on the backend it historically ran (qat) —
            # and never under the cascade (it has no gate, so a gated
            # sweep drops it rather than bench an unlike-for-unlike
            # pair)
            modes = (
                ("fused", "pipelined", "scan", "legacy")
                if clf == "qat" and casc is None
                else ("fused", "pipelined", "scan")
            )
            for ms in sweep_streams:
                for occ in occupancies:
                    for mode in modes:
                        # multi-device rows: the sharded fused tick /
                        # scan at full occupancy and serving scale —
                        # the stream-parallel scaling axis; everything
                        # else stays on the devices=1 baseline, which
                        # is always benched (the claim and the scaling
                        # ratios are defined against it even when
                        # --devices omits 1)
                        devs = [1]
                        if mode != "legacy" and ms >= 256 and occ == 1.0:
                            devs = sorted(
                                {1, *(d for d in devices if ms % d == 0)}
                            )
                        for d in devs:
                            r = _bench_mode(
                                mode, kind, pipe, params, ms, occ,
                                N_TICKS, devices=d, tick_impl=tick_impl,
                            )
                            results.append(r)
                            sp = (
                                f"  eff-MAC {r['sparsity']:.3f}"
                                if r["theta"] is not None else ""
                            )
                            if r["wake_threshold"] is not None:
                                sp += f"  wake {r['wake_rate']:.3f}"
                            # scan rows have no per-tick latency
                            # (p50/p99 null — the replay is one device
                            # program); print throughput alone there
                            pct = (
                                f"p50 {r['p50_ms']:7.2f} ms  "
                                f"p99 {r['p99_ms']:7.2f} ms  "
                                if r["p99_ms"] is not None
                                else "(amortized; no percentiles)  "
                            )
                            print(
                                f"  {clf:9s} {kind:5s} {mode:9s} "
                                f"N={ms:5d} occ={occ:.1f} dev={d}: "
                                f"{r['ticks_per_s']:8.1f} ticks/s  "
                                f"{pct}"
                                f"({r['streams_per_s']:.0f} streams/s)"
                                f"{sp}"
                            )

    def _pick(mode, kind, clf="qat", devs=1, ms=256):
        return next(
            (r for r in results
             if r["mode"] == mode and r["kind"] == kind
             and r["classifier"] == clf and r["devices"] == devs
             and r["max_streams"] == ms and r["occupancy"] == 1.0),
            None,
        )

    # Headline: sustained ticks/sec of the fused tick body (the scanned
    # replay driver — a mode only the fused architecture admits, since
    # the pre-refactor path's per-tick numpy smoothing forces a host
    # round-trip every tick and cannot scan) vs the pre-refactor
    # per-stream path on the same traffic. The live per-call fused tick
    # is reported separately as speedup_live, not folded into the claim.
    # The claim gates on the qat backend; a sweep restricted to another
    # backend (--classifier integer) or run under --cascade (no legacy
    # rows to compare against) records results without a claim.
    claim = None
    if "qat" in classifiers and casc is None:
        fused_live = _pick("fused", "fv")
        fused_scan = _pick("scan", "fv")
        legacy = _pick("legacy", "fv")
        speedup_scan = fused_scan["ticks_per_s"] / legacy["ticks_per_s"]
        speedup_live = fused_live["ticks_per_s"] / legacy["ticks_per_s"]
        ok = speedup_scan >= 5.0
        audio_scan_speedup = (
            _pick("scan", "audio")["ticks_per_s"]
            / _pick("legacy", "audio")["ticks_per_s"]
        )
        claim = {
            "what": "sustained fused-tick throughput (scanned replay "
                    "driver) >= 5x legacy ticks/sec at 256 streams, "
                    "occupancy 1.0, FV_Norm ticks, qat classifier; live "
                    "per-call fused ticks reported as speedup_live",
            "fused_live_ticks_per_s": fused_live["ticks_per_s"],
            "fused_scan_ticks_per_s": fused_scan["ticks_per_s"],
            "legacy_ticks_per_s": legacy["ticks_per_s"],
            "speedup": speedup_scan,
            "speedup_live": speedup_live,
            "audio_scan_speedup": audio_scan_speedup,
            "ok": ok,
        }
        int_scan = _pick("scan", "fv", "integer")
        if int_scan is not None:
            claim["integer_scan_ticks_per_s"] = int_scan["ticks_per_s"]
            claim["integer_vs_qat_scan"] = (
                int_scan["ticks_per_s"] / fused_scan["ticks_per_s"]
            )
        delta_scan = _pick("scan", "fv", "delta") or _pick(
            "scan", "fv", "delta-int"
        )
        if delta_scan is not None:
            claim["delta_scan_ticks_per_s"] = delta_scan["ticks_per_s"]
            claim["delta_vs_qat_scan"] = (
                delta_scan["ticks_per_s"] / fused_scan["ticks_per_s"]
            )
            claim["delta_sparsity"] = delta_scan["sparsity"]
    # SLO gate for the live async path — latency AND throughput, the
    # way a deployment would gate it: pipelined p99 within the paper's
    # 16 ms tick budget at 256 streams, and live pipelined throughput
    # within 2x of the scan ceiling on the same state at 64 and 256
    # streams (all at full occupancy, fv kind, devices=1; gated on the
    # sweep's first classifier so cascaded / single-backend sweeps get
    # a gate too).
    slo = None
    slo_clf = classifiers[0]
    p99_row = _pick("pipelined", "fv", slo_clf)
    ratios = {}
    for ms in (64, 256):
        pr = _pick("pipelined", "fv", slo_clf, ms=ms)
        sr = _pick("scan", "fv", slo_clf, ms=ms)
        if pr is not None and sr is not None:
            ratios[ms] = pr["ticks_per_s"] / sr["ticks_per_s"]
    if p99_row is not None and ratios:
        p99_ok = p99_row["p99_ms"] <= SLO_P99_MS
        ratio_ok = all(v >= SLO_MIN_VS_SCAN for v in ratios.values())
        slo = {
            "what": (
                f"live pipelined (window={PIPELINE_WINDOW}, "
                f"depth={PIPELINE_DEPTH}) p99 <= {SLO_P99_MS} ms at "
                f"256 streams AND >= {SLO_MIN_VS_SCAN}x the scan "
                f"ceiling at 64/256 streams (fv, {slo_clf}, occupancy "
                f"1.0, devices=1)"
            ),
            "classifier": slo_clf,
            "p99_ms": p99_row["p99_ms"],
            "p99_budget_ms": SLO_P99_MS,
            "pipelined_vs_scan": {str(k): v for k, v in ratios.items()},
            "min_vs_scan": SLO_MIN_VS_SCAN,
            "p99_ok": p99_ok,
            "ratio_ok": ratio_ok,
            "ok": p99_ok and ratio_ok,
        }
    # stream-parallel scaling summary: sustained scan-fv throughput at
    # 256 streams per device count (vs the devices=1 row). On emulated
    # CPU meshes the "devices" share one physical socket, so the ratio
    # mostly measures SPMD overhead; on real multi-chip platforms it is
    # the scaling curve.
    scaling = []
    for d in sorted({1, *devices}):
        row = _pick("scan", "fv", classifiers[0], devs=d)
        base = _pick("scan", "fv", classifiers[0], devs=1)
        if row is None or base is None:
            continue
        scaling.append({
            "devices": d,
            "scan_fv_ticks_per_s": row["ticks_per_s"],
            "vs_single_device": row["ticks_per_s"] / base["ticks_per_s"],
        })
    # the tick-kernel's own claim: sparsity -> wall clock, fused tick vs
    # itself across θ (independent of the sweep's tick_impl choice)
    sparsity_speedup = _bench_sparsity_speedup(max(10, N_TICKS // 2))
    # the observability layer's own claim: metrics cost < 5% of a
    # fused tick (measured, recorded, and gated with the SLO)
    metrics_overhead, overhead_snapshot = _bench_metrics_overhead(
        N_TICKS
    )
    payload = {
        "backend": jax.default_backend(),
        "frontend": frontend,
        # requested tick implementation for the sweep's rows (each row
        # additionally records what it resolved to and the dispatch
        # tier it ran)
        "tick_impl": tick_impl,
        "classifiers": list(classifiers),
        # ΔGRU threshold the delta rows ran at (per-row "theta" repeats
        # it; dense rows carry theta=None and sparsity=1.0)
        "theta": theta,
        # stage-1 cascade the sweep served under (per-row
        # "wake_threshold"/"wake_rate" repeat/record it; False -> every
        # row ran the ungated tick and wake_rate is identically 1.0)
        "cascade": cascade,
        "wake_threshold": wake_threshold if cascade else None,
        # counts that actually produced rows (a requested count that
        # divides none of the 256+ stream sizes is swept nowhere and
        # must not be claimed in the artifact)
        "devices": sorted({r["devices"] for r in results}),
        "quick": QUICK,
        "results": results,
        "scaling": scaling,
        "claim": claim,
        "slo": slo,
        "sparsity_speedup": sparsity_speedup,
        "metrics_overhead": metrics_overhead,
    }
    with open("BENCH_serve.json", "w") as f:
        json.dump(payload, f, indent=2)
    # full registry snapshots of the deployment-relevant points, as an
    # artifact next to the BENCH rows (histogram buckets + percentiles,
    # journal events, per-span rollups — the CI slow job uploads this)
    snapshots = {"metrics_overhead_on": overhead_snapshot}
    slo_key = ("pipelined", classifiers[0], "fv", 256, 1.0, 1)
    if slo_key in _SNAPSHOTS:
        snapshots["pipelined_256"] = _SNAPSHOTS[slo_key]
    fused_key = ("fused", classifiers[0], "fv", 256, 1.0, 1)
    if fused_key in _SNAPSHOTS:
        snapshots["fused_256"] = _SNAPSHOTS[fused_key]
    with open("METRICS_serve.json", "w") as f:
        json.dump(snapshots, f, indent=2)
    for s in scaling:
        if s["devices"] > 1:
            print(
                f"serve_load: {s['devices']} devices sustain "
                f"{s['scan_fv_ticks_per_s']:.1f} scan ticks/s at 256 "
                f"streams ({s['vs_single_device']:.2f}x the single-"
                f"device program)"
            )
    if claim is not None:
        extra = (
            f", integer scan {claim['integer_vs_qat_scan']:.2f}x qat"
            if "integer_vs_qat_scan" in claim else ""
        )
        print(
            f"serve_load: fused scan "
            f"{claim['fused_scan_ticks_per_s']:.1f} / live "
            f"{claim['fused_live_ticks_per_s']:.1f} vs legacy "
            f"{claim['legacy_ticks_per_s']:.1f} ticks/s at 256 streams "
            f"(fv, qat) -> {claim['speedup']:.1f}x sustained, "
            f"{claim['speedup_live']:.1f}x live "
            f"(audio scan: {claim['audio_scan_speedup']:.1f}x{extra})  "
            f"[{'PASS' if claim['ok'] else 'FAIL'}] "
            f"(BENCH_serve.json written)"
        )
    else:
        why = (
            "cascaded sweep has no like-for-like legacy baseline"
            if cascade else "no qat baseline in sweep"
        )
        print(
            f"serve_load: swept classifiers {list(classifiers)} "
            f"({why} -> no claim); BENCH_serve.json written"
        )
    if slo is not None:
        rat = ", ".join(
            f"{k} streams {v:.2f}x"
            for k, v in sorted(slo["pipelined_vs_scan"].items(),
                               key=lambda kv: int(kv[0]))
        )
        print(
            f"serve_load SLO: pipelined p99 {slo['p99_ms']:.2f} ms "
            f"(budget {slo['p99_budget_ms']:.0f} ms) at 256 streams; "
            f"vs scan ceiling: {rat} (floor {slo['min_vs_scan']:.2f}x)"
            f"  [{'PASS' if slo['ok'] else 'FAIL'}]"
        )
    ss = sparsity_speedup
    verdict = (
        f"[{'PASS' if ss['ok'] else 'FAIL'}]" if ss["gated"]
        else f"[recorded; monotone_in_theta="
             f"{'yes' if ss['monotone_in_theta'] else 'no'}]"
    )
    print(
        f"serve_load sparsity-speedup ({ss['tick_impl']}, "
        f"{ss['jax_backend']}): theta=0.15 fused delta tick is "
        f"{ss['speedup_vs_dense']:.2f}x its theta=0 self "
        f"(floor {SPEEDUP_FLOOR}x on accelerators)  {verdict}"
    )
    mo = metrics_overhead
    print(
        f"serve_load metrics-overhead: metrics-on fused tick "
        f"{mo['mean_ms_metrics_on']:.3f} ms vs off "
        f"{mo['mean_ms_metrics_off']:.3f} ms at {mo['streams']} "
        f"streams -> {mo['overhead_frac']:+.2%} "
        f"(budget {mo['budget_frac']:.0%})  "
        f"[{'PASS' if mo['ok'] else 'FAIL'}] "
        f"(METRICS_serve.json written)"
    )
    if fail_on_slo and (slo is None or not slo["ok"]):
        raise SystemExit(
            "serve_load: --fail-on-slo and the live-serving SLO gate "
            + ("produced no measurable rows" if slo is None
               else "failed (see the SLO line above)")
        )
    if fail_on_slo and not mo["ok"]:
        raise SystemExit(
            "serve_load: --fail-on-slo and the metrics-overhead gate "
            "failed (see the metrics-overhead line above)"
        )
    return claim


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--classifier", default="all",
        choices=["all", "qat", "integer", "float", "delta", "delta-int"],
        help="classifier backend(s) to sweep; "
             "'all' = qat + integer + delta",
    )
    ap.add_argument(
        "--devices", default="auto",
        help="device counts to sweep, e.g. '1,2' ('auto' = 1 plus "
             "every power-of-two count the platform exposes; emulate "
             "with XLA_FLAGS=--xla_force_host_platform_device_count=N)",
    )
    ap.add_argument(
        "--cascade", action="store_true",
        help="serve every non-legacy point with the stage-1 wake gate "
             "(repro.serving.cascade, energy detector at "
             "--wake-threshold); rows record the measured classifier "
             "duty cycle as 'wake_rate'; the fused-vs-legacy claim is "
             "skipped (the legacy path has no gate)",
    )
    ap.add_argument(
        "--wake-threshold", type=float, default=0.15,
        help="stage-1 energy-detector wake threshold for --cascade "
             "(mean rectified FV_Norm units; 0 = always-open, "
             "bit-identical to the ungated tick)",
    )
    ap.add_argument(
        "--fail-on-slo", action="store_true",
        help="exit non-zero when the live-serving SLO gate fails "
             "(pipelined p99 <= 16 ms at 256 streams AND >= 0.5x the "
             "scan ceiling at 64/256 streams) or when the metrics-"
             "overhead gate fails (metrics-on fused tick < 5% over "
             "metrics-off) — the CI slow job's regression tripwire "
             "for the async ingress and observability layers",
    )
    ap.add_argument(
        "--tick-impl", default="auto",
        choices=["auto", "xla", "fused-pallas", "fused-interpret"],
        help="tick implementation for every non-legacy server "
             "(StreamingKWSServer(tick_impl=...)): 'auto' = "
             "fused-pallas on TPU, xla elsewhere; 'fused-interpret' "
             "runs the megakernel under the Pallas interpreter "
             "(correctness-shaped, interpreter-slow on CPU). Rows "
             "record the resolved impl + dispatch tier",
    )
    ap.add_argument(
        "--theta", type=float, default=0.25,
        help="ΔGRU delta threshold (Q6.8 value units, applied to both "
             "input and hidden deltas of every layer) for the "
             "delta/delta-int rows; their 'sparsity' fields record the "
             "measured effective-MAC fraction under this threshold",
    )
    args = ap.parse_args()
    run(
        ("qat", "integer", "delta") if args.classifier == "all"
        else (args.classifier,),
        devices=(
            None if args.devices == "auto"
            else [int(d) for d in args.devices.split(",")]
        ),
        theta=args.theta,
        cascade=args.cascade,
        wake_threshold=args.wake_threshold,
        fail_on_slo=args.fail_on_slo,
        tick_impl=args.tick_impl,
    )
