"""Streaming KWS serving: N concurrent audio streams, one batched
weights-resident GRU step per 16 ms frame — the chip's deployment shape
(Fig. 4) scaled to a TPU serving binary.

  PYTHONPATH=src python examples/serve_streaming.py [--streams 32]
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.fex import FExConfig, FExNormStats, fex_frames
from repro.core.pipeline import KWSPipeline, KWSPipelineConfig
from repro.data.gscd import CLASSES, make_dataset
from repro.serving.serve_loop import StreamingKWSServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=32)
    ap.add_argument("--seconds", type=float, default=1.0)
    args = ap.parse_args()

    # corpus + features + a quickly trained model (or random for demo)
    data = make_dataset(6, seed=0)
    fcfg = FExConfig()
    frames = fex_frames(jnp.asarray(data["audio"][: args.streams]), fcfg)
    fv_raw = quant.quantize_unsigned(frames, 12, fcfg.quant_full_scale)
    fv_log = quant.log_compress_lut(fv_raw, 12, 10)
    stats = FExNormStats(
        mu=fv_log.reshape(-1, 16).mean(0),
        sigma=fv_log.reshape(-1, 16).std(0) + 1e-3,
    )
    pipe = KWSPipeline(KWSPipelineConfig(), norm_stats=stats)
    params = pipe.init_params(jax.random.PRNGKey(0))
    fv = np.asarray(pipe.features_from_raw(fv_raw))

    srv = StreamingKWSServer(pipe, params, max_streams=args.streams)
    for sid in range(args.streams):
        srv.open_stream(sid)

    n_frames = min(fv.shape[1], int(args.seconds / 16e-3))
    print(f"serving {args.streams} streams x {n_frames} frames "
          f"(16 ms each)...")
    t0 = time.time()
    detections = {}
    for t in range(n_frames):
        out = srv.step({sid: fv[sid, t] for sid in range(args.streams)})
        for sid, r in out.items():
            detections[sid] = r["top"]
    wall = time.time() - t0
    per_frame = wall / n_frames * 1e3
    rt_streams = args.streams * (16.0 / per_frame)
    print(f"wall {wall:.2f}s -> {per_frame:.2f} ms per batched frame "
          f"step; real-time capacity at this batch ~{rt_streams:.0f} "
          f"streams/host (CPU interpret mode)")
    top_counts = {}
    for sid, cls in detections.items():
        top_counts[CLASSES[cls]] = top_counts.get(CLASSES[cls], 0) + 1
    print("final per-stream top classes (untrained weights -> arbitrary):",
          top_counts)
    print("the IC serves 1 stream at 23 uW; TPU serving amortizes one "
          "weights-resident GRU across thousands of streams")


if __name__ == "__main__":
    main()
