"""Streaming KWS serving: N concurrent audio streams, one batched
weights-resident GRU step per 16 ms frame — the chip's deployment shape
(Fig. 4) scaled to a TPU serving binary.

The server consumes RAW 16 ms audio hops per stream: feature extraction
runs inside the tick through the pipeline's registered frontend
(--frontend software|hardware|hardware-pallas), with per-stream filter
and SRO-phase carry. The whole tick (frontend + GRU + softmax +
smoothing) is one fused jit over donated state buffers; --offline
replays each stream's full buffered audio through the server's
`lax.scan` driver instead of live per-tick calls.

`--pipelined` swaps the blocking live loop for the async ingress
(`repro.serving.ingress.PipelinedIngress`): double-buffered host
staging, non-blocking dispatch, deferred score fetch, and `--window`
ticks coalesced per device dispatch — same score trajectory
bit-identically, fewer host round-trips.

`--metrics` serves through a `repro.serving.metrics.MetricsRegistry`
(bit-identical — instrumentation is host-side only) and dumps the full
`srv.metrics_snapshot()` JSON on exit; `--autoscale` drives an
occupancy/SLO `Autoscaler` per tick and prints every capacity decision
WITH its reason (`auto.last_decision`: occupancy_watermark, rejection,
or an slo_veto hold).

  PYTHONPATH=src python examples/serve_streaming.py [--streams 32]
      [--frontend software] [--classifier qat|integer]
      [--cascade [--wake-threshold 0.1]] [--offline]
      [--pipelined [--window 4]] [--grow 64]
      [--metrics] [--autoscale]
"""

import argparse
import json
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.fex import FExConfig, FExNormStats, fex_frames
from repro.core.pipeline import KWSPipeline, KWSPipelineConfig
from repro.data.gscd import CLASSES, make_dataset
from repro.serving.serve_loop import StreamingKWSServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=32)
    ap.add_argument("--seconds", type=float, default=1.0)
    ap.add_argument("--frontend", default="software",
                    choices=["software", "hardware", "hardware-pallas"])
    ap.add_argument("--classifier", default="qat",
                    choices=["float", "qat", "integer", "delta",
                             "delta-int"],
                    help="classifier backend; 'integer' serves the "
                         "bit-exact int8/Q6.8 code engine (the IC's "
                         "WMEM-resident arithmetic); 'delta'/"
                         "'delta-int' serve the temporal-sparsity ΔGRU "
                         "engine at --theta (θ=0 is bit-identical to "
                         "qat/integer) and report per-stream "
                         "effective-MAC fractions")
    ap.add_argument("--theta", type=float, default=0.0,
                    help="ΔGRU delta threshold (Q6.8 value units, "
                         "input and hidden deltas of every layer) for "
                         "--classifier delta/delta-int; 0 = exact "
                         "dense replay, larger skips more MACs")
    ap.add_argument("--cascade", action="store_true",
                    help="run the stage-1 always-on wake gate "
                         "(repro.serving.cascade, energy detector) "
                         "inside the tick: the classifier advances "
                         "only on ticks the gate wakes, and the "
                         "per-stream duty cycle (srv.wake_rate) is "
                         "printed next to the posterior trace")
    ap.add_argument("--wake-threshold", type=float, default=0.1,
                    help="energy-detector wake threshold for --cascade "
                         "(mean rectified FV_Norm units; 0 = "
                         "always-open, bit-identical to no cascade)")
    ap.add_argument("--offline", action="store_true",
                    help="replay buffered audio via the lax.scan driver "
                         "(server.run) instead of live per-tick step calls")
    ap.add_argument("--pipelined", action="store_true",
                    help="serve live ticks through the async ingress "
                         "(repro.serving.ingress.PipelinedIngress): "
                         "double-buffered staging, non-blocking "
                         "dispatch, scores fetched via deferred "
                         "TickHandles — bit-identical to the blocking "
                         "loop, fewer host round-trips")
    ap.add_argument("--window", type=int, default=4,
                    help="ticks coalesced into one scan dispatch by "
                         "--pipelined (the throughput/latency knob; "
                         "1 = one fused tick per dispatch)")
    ap.add_argument("--grow", type=int, default=None,
                    help="elastic-serving demo: live-resize the server "
                         "to this many slots halfway through the run "
                         "(must be a multiple of the device count; the "
                         "open streams' state moves bitwise, so the "
                         "score trajectories are unaffected). Only in "
                         "the live blocking mode")
    ap.add_argument("--metrics", action="store_true",
                    help="serve through a MetricsRegistry "
                         "(repro.serving.metrics — bit-identical, "
                         "host-side instrumentation only) and dump the "
                         "full metrics_snapshot() JSON on exit: tick "
                         "latency histograms, occupancy gauges, "
                         "retrace/compile counters, and the structured "
                         "event journal")
    ap.add_argument("--autoscale", action="store_true",
                    help="drive an occupancy/SLO Autoscaler "
                         "(repro.serving.autoscale) per live tick and "
                         "print every capacity decision with its "
                         "reason (auto.last_decision). Only in the "
                         "live blocking mode")
    ap.add_argument("--devices", type=int, default=None,
                    help="shard the stream-slot axis over the first N "
                         "visible devices (('stream',) mesh; default: "
                         "the largest visible count that divides "
                         "--streams — 1 device keeps the plain "
                         "single-device program). Emulate a mesh on "
                         "CPU with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    args = ap.parse_args()

    # corpus + norm stats + a model (random weights for the demo)
    data = make_dataset(6, seed=0)
    fcfg = FExConfig()
    frames = fex_frames(jnp.asarray(data["audio"][: args.streams]), fcfg)
    fv_raw = quant.quantize_unsigned(frames, 12, fcfg.quant_full_scale)
    fv_log = quant.log_compress_lut(fv_raw, 12, 10)
    stats = FExNormStats(
        mu=fv_log.reshape(-1, 16).mean(0),
        sigma=fv_log.reshape(-1, 16).std(0) + 1e-3,
    )
    delta = None
    if args.classifier in ("delta", "delta-int"):
        from repro.core.gru_delta import DeltaConfig

        delta = DeltaConfig(theta_x=args.theta, theta_h=args.theta)
    cascade = None
    if args.cascade:
        from repro.serving.cascade import CascadeConfig

        cascade = CascadeConfig(wake_threshold=args.wake_threshold)
    pipe = KWSPipeline(
        KWSPipelineConfig(
            frontend=args.frontend, classifier=args.classifier,
            delta=delta, cascade=cascade,
        ),
        norm_stats=stats,
    )
    # calibrated FrontendState (beta/alpha for the hardware paths; the
    # fitted norm stats are carried over automatically)
    pipe = pipe.with_state(pipe.init_frontend_state(mismatch=False))
    params = pipe.init_params(jax.random.PRNGKey(0))

    audio = np.asarray(data["audio"][: args.streams], np.float32)
    # devices=None shards over every visible device (single visible
    # device -> the plain single-device program, bit-identically)
    n_dev = args.devices
    if n_dev is None:
        n_dev = len(jax.devices())
        while args.streams % n_dev:
            n_dev -= 1  # largest visible count the slot axis divides
    srv = StreamingKWSServer(
        pipe, params, max_streams=args.streams, devices=n_dev,
        metrics=args.metrics,
    )
    for sid in range(args.streams):
        srv.open_stream(sid)
    auto = None
    if args.autoscale:
        from repro.serving.autoscale import Autoscaler, AutoscalePolicy

        auto = Autoscaler(
            srv,
            AutoscalePolicy(
                min_streams=srv.n_devices,
                max_streams=4 * args.streams,
                hysteresis_ticks=2, cooldown_ticks=4,
            ),
        )

    hop = pipe.chunk_samples  # 256 samples = 16 ms @ 16 kHz
    n_frames = min(audio.shape[1] // hop, int(args.seconds / 16e-3))
    if args.offline:
        mode = "offline lax.scan replay"
    elif args.pipelined:
        mode = f"live async ingress (depth 2, window {args.window})"
    else:
        mode = "live fused ticks"
    print(f"serving {args.streams} streams x {n_frames} raw-audio hops "
          f"({hop} samples / 16 ms each) via frontend "
          f"{args.frontend!r}, classifier {args.classifier!r} "
          f"on {srv.n_devices} device(s) [{mode}]...")
    t0 = time.time()
    detections = {}
    if args.offline:
        out = srv.run({sid: audio[sid, : n_frames * hop]
                       for sid in range(args.streams)})
        for sid, r in out.items():
            detections[sid] = r["top"]
    elif args.pipelined:
        from repro.serving.ingress import PipelinedIngress

        ing = PipelinedIngress(srv, dim=hop, window=args.window)
        slots = {sid: srv.active[sid] for sid in range(args.streams)}
        for t in range(n_frames):
            slab, mask = ing.stage()  # host staging overlaps the
            for sid, slot in slots.items():  # in-flight dispatch
                slab[slot] = audio[sid, t * hop:(t + 1) * hop]
                mask[slot] = True
            ing.commit(meta=t)  # non-blocking past the first `depth`
        # every score row is in some retired handle; the final tick's
        # top row lives in the last handle's last window row
        tops = ing.drain()[-1].top
        tops = tops[-1] if tops.ndim == 2 else tops  # window > 1
        for sid, slot in slots.items():
            detections[sid] = int(tops[slot])
    else:
        for t in range(n_frames):
            if args.grow is not None and t == n_frames // 2:
                # live grow: the ServerState pytree is re-laid onto the
                # larger slot axis bitwise, open streams keep serving
                srv.resize(args.grow)
                print(f"  [tick {t}] resized live to {srv.max_streams} "
                      f"slots ({len(srv.active)} open streams moved "
                      f"bitwise)")
            chunk = {sid: audio[sid, t * hop:(t + 1) * hop]
                     for sid in range(args.streams)}
            t_tick = time.perf_counter()
            out = srv.step(chunk)
            for sid, r in out.items():
                detections[sid] = r["top"]
            if auto is not None:
                # why did (or didn't) capacity change? last_decision
                # carries the reason for grows, shrinks, AND slo-veto
                # holds — print each decision as it lands
                before = auto.last_decision
                auto.observe(time.perf_counter() - t_tick)
                d = auto.last_decision
                if d is not None and d is not before:
                    print(f"  [tick {t}] autoscaler {d['action']}: "
                          f"{d['from']} -> {d['to']} slots "
                          f"(reason: {d['reason']})")
    wall = time.time() - t0
    per_frame = wall / n_frames * 1e3
    rt_streams = args.streams * (16.0 / per_frame)
    print(f"wall {wall:.2f}s -> {per_frame:.2f} ms per batched "
          f"audio-in tick (FEx + GRU); real-time capacity at this batch "
          f"~{rt_streams:.0f} streams/host (CPU)")
    top_counts = {}
    for sid, cls in detections.items():
        top_counts[CLASSES[cls]] = top_counts.get(CLASSES[cls], 0) + 1
    print("final per-stream top classes (untrained weights -> arbitrary):",
          top_counts)
    if args.classifier in ("delta", "delta-int"):
        # per-stream effective-MAC fraction next to the posterior trace
        # (the srv.sparsity telemetry the ΔGRU state accumulates)
        frac = srv.sparsity
        per_stream = {
            sid: float(frac[srv.active[sid]]) for sid in sorted(detections)
        }
        shown = {s: round(f, 3) for s, f in list(per_stream.items())[:8]}
        vals = list(per_stream.values())
        print(f"ΔGRU θ={args.theta:g}: effective-MAC fraction "
              f"mean {np.mean(vals):.3f} "
              f"(min {np.min(vals):.3f} / max {np.max(vals):.3f}); "
              f"first streams: {shown}")
    if args.cascade:
        # per-stream classifier duty cycle next to the effective-MAC
        # fraction (the srv.wake_rate telemetry the stage-1 gate
        # accumulates; composes multiplicatively with the ΔGRU
        # sparsity in the IC energy model)
        wr = srv.wake_rate
        per_stream = {
            sid: float(wr[srv.active[sid]]) for sid in sorted(detections)
        }
        shown = {s: round(w, 3) for s, w in list(per_stream.items())[:8]}
        vals = list(per_stream.values())
        print(f"cascade thr={args.wake_threshold:g}: classifier duty "
              f"cycle (wake rate) mean {np.mean(vals):.3f} "
              f"(min {np.min(vals):.3f} / max {np.max(vals):.3f}); "
              f"first streams: {shown}")
    if args.metrics:
        # the full observability snapshot: tick histograms (with exact
        # percentiles), occupancy gauges, retrace/compile counters, and
        # the structured event journal (every resize / retrace /
        # autoscale decision with its reason, in order)
        print("metrics snapshot:")
        print(json.dumps(srv.metrics_snapshot(), indent=2))
    print("the IC serves 1 stream at 23 uW; TPU serving amortizes one "
          "weights-resident GRU across thousands of streams")


if __name__ == "__main__":
    main()
