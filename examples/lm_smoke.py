"""LM-side smoke driver: train a reduced assigned architecture with the
full distributed substrate (sharded train step on a small fake-device
mesh, AdamW, checkpointing) — the same code path the 512-chip dry-run
lowers, executed for real at toy scale.

  PYTHONPATH=src python examples/lm_smoke.py [--arch qwen3-4b] [--steps 30]
"""

import argparse
import os
import sys

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--devices", type=int, default=8)
    args = ap.parse_args()
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.devices}"
    )

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.distributed.sharding import (
        ShardingRules, make_mesh_context, named, param_specs)
    from repro.models.registry import get_backbone
    from repro.training.optimizer import AdamWConfig, init_opt_state
    from repro.training.train_loop import TrainConfig, build_train_step

    cfg = get_config(args.arch).reduced()
    backbone = get_backbone(cfg)
    mesh = jax.make_mesh((2, args.devices // 2), ("data", "model"))
    rules = ShardingRules(mesh=mesh)
    mesh_ctx = make_mesh_context(rules)
    print(f"== {args.arch} (reduced: {cfg.n_layers}L d={cfg.d_model}) on "
          f"a (2, {args.devices // 2}) mesh ==")

    params = backbone.init_params(jax.random.PRNGKey(0), cfg, mesh_ctx)
    params = jax.device_put(params, named(param_specs(params, rules), mesh))
    opt = init_opt_state(params, AdamWConfig())
    step_fn = build_train_step(
        cfg, rules, TrainConfig(optimizer=AdamWConfig(lr=3e-3)))

    rng = np.random.default_rng(0)
    data = rng.integers(0, cfg.vocab, (args.steps, 8, 33))
    with mesh:
        jitted = jax.jit(step_fn)
        for it in range(args.steps):
            batch = {
                "tokens": jnp.asarray(data[it, :, :-1], jnp.int32),
                "labels": jnp.asarray(data[it, :, 1:], jnp.int32),
            }
            if cfg.frontend == "embedding":
                batch = {
                    "embeddings": jax.random.normal(
                        jax.random.PRNGKey(it), (8, 32, cfg.d_model),
                        cfg.activation_dtype),
                    "labels": batch["labels"],
                }
            params, opt, metrics = jitted(params, opt, batch)
            if it % 5 == 0:
                print(f"  step {it:3d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.2f}")
    print("smoke train OK — same train_step the 512-chip dry-run compiles")


if __name__ == "__main__":
    main()
