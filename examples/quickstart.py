"""Quickstart: one synthetic keyword through the full KWS pipeline.

  PYTHONPATH=src python examples/quickstart.py

Shows: synthesize a "yes" -> software-model FEx (and the fused Pallas
kernel producing the same frames) -> quantize/log/normalize -> GRU-FC
classifier -> per-frame scores, plus the IC's latency/power figures.
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.energy import paper_accelerator, paper_power_model
from repro.core.fex import FExConfig, fit_norm_stats
from repro.core.gru import GRUConfig
from repro.core.pipeline import KWSPipeline, KWSPipelineConfig
from repro.data.gscd import CLASSES, GSCDSynthConfig, _TEMPLATES, synth_keyword
from repro.kernels.fex_fused import fex_fused
from repro.core.fex import fex_frames, oversample2x


def main():
    rng = np.random.default_rng(0)
    audio = synth_keyword(rng, _TEMPLATES["yes"], GSCDSynthConfig())
    print(f"synthesized 'yes': {audio.shape[0]} samples @16 kHz, "
          f"peak {np.abs(audio).max():.3f}")

    fcfg = FExConfig()
    # software-model frames vs the fused Pallas kernel (interpret mode)
    frames_ref = fex_frames(jnp.asarray(audio[None]), fcfg)
    frames_krn = fex_fused(
        oversample2x(jnp.asarray(audio[None])), fcfg.filterbank(),
        fcfg.frame_len,
    )
    err = float(jnp.abs(frames_ref - frames_krn).max())
    print(f"FEx frames: {frames_ref.shape} (62 frames x 16 ch); "
          f"fused-kernel max err vs reference: {err:.2e}")

    # fit mu/sigma on this clip (demo only; training fits on the corpus)
    fv_raw = quant.quantize_unsigned(frames_ref, 12, fcfg.quant_full_scale)
    fv_log = quant.log_compress_lut(fv_raw, 12, 10)
    stats = fit_norm_stats(fv_log)
    pipe = KWSPipeline(KWSPipelineConfig(), norm_stats=stats)
    params = pipe.init_params(jax.random.PRNGKey(0))
    fv_norm, _ = pipe.features(jnp.asarray(audio[None]))
    scores = pipe.logits_all_frames(params, fv_norm)
    top = int(jnp.argmax(scores[0, -1]))
    print(f"classifier (untrained) final-frame top class: {CLASSES[top]}")

    # the same call site runs every registered feature path: the paper's
    # whole point is that the analog frontend is swappable
    from repro.core.frontend import available_frontends

    for name in available_frontends():
        p = KWSPipeline(
            KWSPipelineConfig(frontend=name), norm_stats=stats
        )
        st = p.init_frontend_state(mismatch=False)
        fv_f, raw_f = p.features(jnp.asarray(audio[None]), st)
        err = float(jnp.abs(raw_f - fv_raw).max())
        print(f"frontend {name:15s}: FV_Raw max |diff| vs software "
              f"reference = {err:.1f} LSB")

    # ...and the classifier is swappable the same way: the "integer"
    # backend evaluates the IC's actual arithmetic (int8 weight codes,
    # Q6.8 activations, 24-bit accumulators) bit-identically to QAT
    pipe_int = KWSPipeline(
        KWSPipelineConfig(classifier="integer"), norm_stats=stats
    )
    scores_int = pipe_int.logits_all_frames(params, fv_norm)
    exact = bool(jnp.array_equal(scores, scores_int))
    print(f"classifier 'integer' (int8/Q6.8 codes): bit-identical to "
          f"QAT scores = {exact}")

    acc = paper_accelerator()
    pm = paper_power_model()
    g = GRUConfig()
    print(f"IC model: latency {acc.latency_s(g) * 1e3:.1f} ms "
          f"(paper 12.4), core power {pm.total_power_w(g) * 1e6:.1f} uW "
          f"(paper 23)")
    print("next: examples/train_kws.py trains this pipeline end-to-end")


if __name__ == "__main__":
    main()
