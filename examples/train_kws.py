"""End-to-end driver: train the paper's KWS model for a few hundred
steps on the synthetic GSCD corpus, with the full production substrate —
QAT (8-bit weights / 14-bit activations), AdamW + ReduceLROnPlateau
(the paper's recipe), periodic checkpointing with resume, straggler
monitoring, and optional data-parallel training with int8-compressed
gradient all-reduce.

  PYTHONPATH=src python examples/train_kws.py [--steps 300] [--resume]
  PYTHONPATH=src python examples/train_kws.py --dp 8 --compress-grads
      (runs 8-way data-parallel on fake devices, compressed psums)
"""

import argparse
import os
import sys
import time

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--n-per-class", type=int, default=24)
    ap.add_argument("--ckpt-dir", default="/tmp/kws_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--dp", type=int, default=0,
                    help="data-parallel ways (fake devices)")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()
    if args.dp:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.dp}"
        )

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import quant
    from repro.core.fex import FExConfig, FExNormStats
    from repro.core.gru import GRUConfig, gru_classifier_forward, init_gru_classifier
    from repro.core.pipeline import KWSPipeline, KWSPipelineConfig
    from repro.data.gscd import CLASSES, make_dataset
    from repro.distributed.fault_tolerance import (
        CheckpointManager, CheckpointPolicy, StragglerMonitor)
    from repro.training.optimizer import (
        AdamWConfig, ReduceLROnPlateau, adamw_update, init_opt_state)

    print("== synthesizing corpus ==")
    train = make_dataset(args.n_per_class, seed=0, unknown_split="train")
    test = make_dataset(max(args.n_per_class // 3, 4), seed=1,
                        unknown_split="test")
    fcfg = FExConfig()

    print("== extracting features (frontend='software') ==")
    pipe = KWSPipeline(KWSPipelineConfig(fex=fcfg))
    raw_tr = pipe.record_features(train["audio"])
    raw_te = pipe.record_features(test["audio"])
    log_tr = quant.log_compress_lut(jnp.asarray(raw_tr), 12, 10)
    stats = FExNormStats(
        mu=log_tr.reshape(-1, 16).mean(0),
        sigma=log_tr.reshape(-1, 16).std(0) + 1e-3,
    )
    pipe = KWSPipeline(KWSPipelineConfig(fex=fcfg), norm_stats=stats)

    def normalize(raw):
        return np.asarray(pipe.features_from_raw(jnp.asarray(raw)))

    ftr, fte = normalize(raw_tr), normalize(raw_te)

    gcfg = GRUConfig()  # QAT on by default (8-bit w / Q6.8 act)
    params = init_gru_classifier(jax.random.PRNGKey(0), gcfg)
    ocfg = AdamWConfig(lr=1e-3, weight_decay=0.01)
    opt = init_opt_state(params, ocfg)
    sched = ReduceLROnPlateau(1e-3, 0.8, 3, 5e-4)
    ckpt = CheckpointManager(CheckpointPolicy(
        args.ckpt_dir, every_steps=100, async_save=True))
    monitor = StragglerMonitor()
    start_step = 0
    if args.resume:
        try:
            (params, opt), start_step = ckpt.restore_latest((params, opt))
            print(f"resumed from step {start_step}")
        except FileNotFoundError:
            print("no checkpoint found; starting fresh")

    def loss_fn(p, fv, y):
        logits = gru_classifier_forward(p, fv, gcfg)[:, -1, :]
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, y[:, None], -1)[:, 0]
        return jnp.mean(logz - gold)

    if args.dp:
        from jax.sharding import PartitionSpec as P

        try:  # jax >= 0.5 exposes shard_map at the top level
            from jax import shard_map
        except ImportError:
            from jax.experimental.shard_map import shard_map

        from repro.distributed.collectives import (
            compressed_psum_with_error_feedback, init_residual)

        mesh = jax.make_mesh((args.dp,), ("data",))
        residual = init_residual(params) if args.compress_grads else None

        def dp_grads(p, fv, y, r):
            l, g = jax.value_and_grad(loss_fn)(p, fv, y)
            if args.compress_grads:
                g, r = compressed_psum_with_error_feedback(g, r, "data")
            else:
                g = jax.tree.map(
                    lambda t: jax.lax.pmean(t, "data"), g)
            return jax.lax.pmean(l, "data"), g, r

        in_specs = (P(), P("data"), P("data"),
                    P() if not args.compress_grads else P())
        print(f"== {args.dp}-way data parallel"
              f"{' + int8 compressed grads' if args.compress_grads else ''} ==")

    @jax.jit
    def step(p, o, fv, y, lr, r):
        if args.dp:
            l, g, r = shard_map(
                dp_grads, mesh=mesh,
                in_specs=(P(), P("data"), P("data"), P()),
                out_specs=(P(), P(), P()),
            )(p, fv, y, r)
        else:
            l, g = jax.value_and_grad(loss_fn)(p, fv, y)
        p, o, _ = adamw_update(p, g, o, ocfg, lr)
        return p, o, l, r

    residual = (
        jax.tree.map(lambda t: jnp.zeros(t.shape, jnp.float32), params)
    )
    rng = np.random.default_rng(0)
    n = len(train["label"])
    lr = sched.lr
    print(f"== training {args.steps} steps ==")
    t0 = time.time()
    losses = []
    for it in range(start_step, args.steps):
        sl = rng.choice(n, args.batch, replace=False)
        with monitor.timed(it):
            params, opt, loss, residual = step(
                params, opt, jnp.asarray(ftr[sl]),
                jnp.asarray(train["label"][sl]), lr, residual)
        losses.append(float(loss))
        if (it + 1) % 20 == 0:
            lr = sched.step(float(np.mean(losses[-20:])))
            print(f"  step {it + 1:4d} loss {np.mean(losses[-20:]):.4f} "
                  f"lr {lr:.2e}")
        ckpt.maybe_save(it + 1, (params, opt))
    ckpt.wait()
    print(f"trained in {time.time() - t0:.0f}s; "
          f"stragglers flagged: {len(monitor.events)}")

    @jax.jit
    def logits_fn(fv):
        return gru_classifier_forward(params, fv, gcfg)[:, -1, :]

    preds = np.argmax(np.asarray(logits_fn(jnp.asarray(fte))), -1)
    acc = (preds == test["label"]).mean()
    print(f"test accuracy: {acc:.2%} over {len(CLASSES)} classes "
          f"(paper software model: 91.35% on real GSCD)")


if __name__ == "__main__":
    main()
