"""GRU classifier: convention, quantized bounds, streaming equivalence."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.gru import (
    GRUConfig,
    classifier_macs,
    classifier_param_bytes,
    gru_cell,
    gru_classifier_forward,
    gru_classifier_step,
    init_gru_classifier,
    init_states,
)


def _manual_gru_step(layer, h, x):
    """PyTorch-convention reference in numpy."""
    w_i, w_h = np.asarray(layer["w_i"]), np.asarray(layer["w_h"])
    b_i, b_h = np.asarray(layer["b_i"]), np.asarray(layer["b_h"])
    gi = x @ w_i + b_i
    gh = h @ w_h + b_h
    H = h.shape[-1]

    def sig(v):
        return 1 / (1 + np.exp(-v))

    r = sig(gi[:, :H] + gh[:, :H])
    z = sig(gi[:, H : 2 * H] + gh[:, H : 2 * H])
    n = np.tanh(gi[:, 2 * H :] + r * gh[:, 2 * H :])
    return (1 - z) * n + z * h


def test_cell_matches_pytorch_convention():
    cfg = GRUConfig(quantized=False)
    params = init_gru_classifier(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((4, 16)).astype(np.float32)
    h = rng.standard_normal((4, 48)).astype(np.float32)
    ours = gru_cell(params["gru"][0], jnp.asarray(h), jnp.asarray(x), cfg)
    ref = _manual_gru_step(params["gru"][0], h, x)
    np.testing.assert_allclose(np.asarray(ours), ref, atol=2e-6)


def test_quantized_activations_within_format():
    cfg = GRUConfig(quantized=True)
    params = init_gru_classifier(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 20, 16)) * 10
    out = gru_classifier_forward(params, x, cfg)
    assert float(jnp.abs(out).max()) <= quant.ACT_Q6_8.max_value
    # outputs land exactly on the Q6.8 grid
    codes = np.asarray(out) * 256.0
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-3)


def test_streaming_equals_full_forward():
    cfg = GRUConfig(quantized=True)
    params = init_gru_classifier(jax.random.PRNGKey(3), cfg)
    fv = jax.random.normal(jax.random.PRNGKey(4), (3, 12, 16))
    full = gru_classifier_forward(params, fv, cfg)
    states = init_states(cfg, 3)
    outs = []
    for t in range(12):
        states, logits = gru_classifier_step(params, states, fv[:, t], cfg)
        outs.append(logits)
    stream = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(full), np.asarray(stream), atol=1e-6
    )


def test_paper_size_checks():
    cfg = GRUConfig()
    assert classifier_macs(cfg) == 24204  # = 24 KB at 8-bit (WMEM)
    assert classifier_param_bytes(cfg) == 24204
