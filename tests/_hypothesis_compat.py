"""Shared optional-hypothesis shim: hypothesis is a `test` extra
(pyproject.toml); when absent, modules stay collectable and only the
property-based tests are skipped."""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # pragma: no cover - exercised when the extra is absent

    class _MissingStrategies:
        """Stands in for `st`; any call/chain returns another stub so
        strategy expressions still evaluate at collection time."""

        def __call__(self, *_a, **_k):
            return _MissingStrategies()

        def __getattr__(self, _name):
            return _MissingStrategies()

    st = _MissingStrategies()

    def settings(*_a, **_k):
        return lambda f: f

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")


__all__ = ["given", "settings", "st"]
