"""Energy/latency model vs the paper's published numbers (Table II),
plus the ΔGRU effective-MAC knob (dense fraction=1.0 stays pinned to
the paper; fractions < 1 scale MAC cycles and dynamic power only) and
the cascade duty-cycle knob (always-on duty=1.0 likewise pinned;
duty < 1 scales time-averaged dynamic power only — never the
per-woken-frame latency — and composes multiplicatively with the MAC
fraction)."""

import dataclasses

import pytest

from repro.core.energy import (
    AcceleratorModel,
    ICPowerModel,
    paper_accelerator,
    paper_power_model,
)
from repro.core.gru import GRUConfig, classifier_macs


def test_latency_matches_table2():
    acc = paper_accelerator()
    lat_ms = acc.latency_s(GRUConfig()) * 1e3
    assert abs(lat_ms - 12.4) < 0.1  # paper: 12.4 ms


def test_latency_fits_frame_budget():
    acc = paper_accelerator()
    assert acc.utilization(GRUConfig()) < 1.0  # finishes within 16 ms


def test_accelerator_power_matches():
    pm = paper_power_model()
    p = pm.accelerator_power_w(GRUConfig()) * 1e6
    assert abs(p - 9.96) < 0.15  # paper: 9.96 uW


def test_total_power_matches():
    pm = paper_power_model()
    total = pm.total_power_w(GRUConfig()) * 1e6
    assert abs(total - 23.0) < 0.2  # paper: 23 uW


def test_dense_fraction_pins_paper_numbers():
    """effective_mac_fraction=1.0 (explicitly constructed) must leave
    the calibrated Table II numbers untouched: 12.4 ms latency and
    9.96 uW accelerator power."""
    acc = AcceleratorModel(effective_mac_fraction=1.0)
    assert acc.effective_macs(GRUConfig()) == classifier_macs(GRUConfig())
    assert abs(acc.latency_s(GRUConfig()) * 1e3 - 12.4) < 0.1
    pm = ICPowerModel(accel=acc)
    assert abs(pm.accelerator_power_w(GRUConfig()) * 1e6 - 9.96) < 0.15
    assert abs(pm.total_power_w(GRUConfig()) * 1e6 - 23.0) < 0.2


def test_effective_mac_fraction_scales_cycles_and_dynamic_power():
    """A 2x MAC reduction (fraction 0.5): MAC cycles halve (FSM
    overhead does not), and exactly the dynamic MAC energy halves
    (leakage untouched) — the DeltaKWS power split."""
    cfg = GRUConfig()
    dense = paper_accelerator()
    sparse = AcceleratorModel(effective_mac_fraction=0.5)
    overhead = dense.overhead_cycles_per_op * dense.n_sequenced_ops
    dense_mac_cycles = dense.cycles_per_frame(cfg) - overhead
    sparse_mac_cycles = sparse.cycles_per_frame(cfg) - overhead
    assert sparse_mac_cycles == -(-(classifier_macs(cfg) // 2) // dense.n_hpe)
    assert sparse_mac_cycles < 0.51 * dense_mac_cycles
    assert sparse.latency_s(cfg) < dense.latency_s(cfg)

    pm_dense = paper_power_model()
    pm_sparse = ICPowerModel(accel=sparse)
    frame = 16e-3
    dyn_dense = pm_dense.e_mac_j * classifier_macs(cfg) / frame
    leak = pm_dense.accelerator_power_w(cfg) - dyn_dense
    expect = leak + dyn_dense / 2
    assert abs(pm_sparse.accelerator_power_w(cfg) - expect) < 1e-9
    # total power drops by the same delta (FEx/digital-frontend fixed)
    assert (
        pm_dense.total_power_w(cfg) - pm_sparse.total_power_w(cfg)
        == pytest.approx(dyn_dense / 2, rel=1e-6)
    )


def test_effective_mac_fraction_validated():
    with pytest.raises(ValueError, match="effective_mac_fraction"):
        AcceleratorModel(effective_mac_fraction=1.5)
    with pytest.raises(ValueError, match="effective_mac_fraction"):
        dataclasses.replace(paper_accelerator(), effective_mac_fraction=-0.1)


def test_always_on_duty_pins_paper_numbers():
    """duty_cycle=1.0 (explicitly constructed) must leave the
    calibrated Table II numbers untouched."""
    acc = AcceleratorModel(duty_cycle=1.0)
    assert abs(acc.latency_s(GRUConfig()) * 1e3 - 12.4) < 0.1
    pm = ICPowerModel(accel=acc)
    assert abs(pm.accelerator_power_w(GRUConfig()) * 1e6 - 9.96) < 0.15
    assert abs(pm.total_power_w(GRUConfig()) * 1e6 - 23.0) < 0.2


def test_duty_cycle_scales_dynamic_power_not_latency():
    """A gate waking the classifier on 20 % of frames: the
    time-averaged dynamic MAC power drops 5x (leakage untouched — the
    weights stay SRAM-resident), while the per-WOKEN-frame cycle count
    and latency are unchanged: the gate skips frames, it does not
    speed them up."""
    cfg = GRUConfig()
    gated = AcceleratorModel(duty_cycle=0.2)
    assert gated.cycles_per_frame(cfg) == paper_accelerator().cycles_per_frame(cfg)
    assert gated.latency_s(cfg) == paper_accelerator().latency_s(cfg)

    pm_dense = paper_power_model()
    frame = 16e-3
    dyn_dense = pm_dense.e_mac_j * classifier_macs(cfg) / frame
    leak = pm_dense.accelerator_power_w(cfg) - dyn_dense
    pm_gated = ICPowerModel(accel=gated)
    assert abs(pm_gated.accelerator_power_w(cfg) - (leak + dyn_dense * 0.2)) < 1e-9
    assert (
        pm_dense.total_power_w(cfg) - pm_gated.total_power_w(cfg)
        == pytest.approx(dyn_dense * 0.8, rel=1e-6)
    )


def test_duty_cycle_composes_with_mac_fraction():
    """Cascade duty cycle x ΔGRU within-wake sparsity multiply in the
    dynamic term: duty 0.25 at fraction 0.5 -> 8x less dynamic MAC
    power than dense always-on."""
    cfg = GRUConfig()
    pm_dense = paper_power_model()
    frame = 16e-3
    dyn_dense = pm_dense.e_mac_j * classifier_macs(cfg) / frame
    leak = pm_dense.accelerator_power_w(cfg) - dyn_dense
    pm = ICPowerModel(
        accel=AcceleratorModel(duty_cycle=0.25, effective_mac_fraction=0.5)
    )
    assert pm.accelerator_power_w(cfg) == pytest.approx(
        leak + dyn_dense * 0.25 * 0.5, rel=1e-6
    )


def test_duty_cycle_validated():
    with pytest.raises(ValueError, match="duty_cycle"):
        AcceleratorModel(duty_cycle=1.5)
    with pytest.raises(ValueError, match="duty_cycle"):
        dataclasses.replace(paper_accelerator(), duty_cycle=-0.1)


def test_model_extrapolates_bigger_network():
    """The 94.2%-accuracy GRU of [36] is ~21x our size; the model must
    predict super-linear power growth (Section IV's argument)."""
    pm = paper_power_model()
    big = GRUConfig(hidden_dim=48 * 5, num_layers=3)
    assert pm.accelerator_power_w(big) > 5 * pm.accelerator_power_w(GRUConfig())
