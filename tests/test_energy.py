"""Energy/latency model vs the paper's published numbers (Table II)."""

from repro.core.energy import paper_accelerator, paper_power_model
from repro.core.gru import GRUConfig


def test_latency_matches_table2():
    acc = paper_accelerator()
    lat_ms = acc.latency_s(GRUConfig()) * 1e3
    assert abs(lat_ms - 12.4) < 0.1  # paper: 12.4 ms


def test_latency_fits_frame_budget():
    acc = paper_accelerator()
    assert acc.utilization(GRUConfig()) < 1.0  # finishes within 16 ms


def test_accelerator_power_matches():
    pm = paper_power_model()
    p = pm.accelerator_power_w(GRUConfig()) * 1e6
    assert abs(p - 9.96) < 0.15  # paper: 9.96 uW


def test_total_power_matches():
    pm = paper_power_model()
    total = pm.total_power_w(GRUConfig()) * 1e6
    assert abs(total - 23.0) < 0.2  # paper: 23 uW


def test_model_extrapolates_bigger_network():
    """The 94.2%-accuracy GRU of [36] is ~21x our size; the model must
    predict super-linear power growth (Section IV's argument)."""
    pm = paper_power_model()
    big = GRUConfig(hidden_dim=48 * 5, num_layers=3)
    assert pm.accelerator_power_w(big) > 5 * pm.accelerator_power_w(GRUConfig())
