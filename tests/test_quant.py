"""Quantization substrate: formats, STE, saturation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import quant


def test_paper_formats():
    assert quant.ACT_Q6_8.bits == 14 and quant.ACT_Q6_8.frac_bits == 8
    assert quant.WEIGHT_INT8.bits == 8
    assert quant.ACC_INT24.bits == 24
    assert abs(quant.ACT_Q6_8.max_value - (2**13 - 1) / 256.0) < 1e-9


@settings(max_examples=50, deadline=None)
@given(st.floats(-40.0, 40.0))
def test_fake_quant_error_bound(x):
    """Within range: |err| <= LSB/2; outside: saturates."""
    spec = quant.ACT_Q6_8
    y = float(quant.fake_quant(jnp.float32(x), spec))
    if spec.min_value <= x <= spec.max_value:
        assert abs(y - x) <= spec.scale / 2 + 1e-7
    else:
        assert y in (spec.min_value, spec.max_value)


def test_int_roundtrip_exact_on_grid():
    spec = quant.ACT_Q6_8
    codes = jnp.arange(spec.qmin, spec.qmax + 1, 37)
    x = codes * spec.scale
    back = quant.dequantize_int(quant.quantize_int(x, spec), spec)
    np.testing.assert_allclose(back, x, atol=0)


def test_ste_gradient_passthrough():
    g = jax.grad(lambda x: quant.fake_quant(x, quant.ACT_Q6_8))(1.2345)
    assert abs(g - 1.0) < 1e-6
    # saturated region still passes gradient (clip has zero grad only
    # through the clip; STE round passes) — check it's finite
    g2 = jax.grad(lambda x: quant.fake_quant(x, quant.ACT_Q6_8))(100.0)
    assert np.isfinite(g2)


def test_weight_int8_range():
    w = jnp.asarray([-1.0, -0.5, 0.0, 0.5, 0.9921875])
    q = quant.quantize_int(w, quant.WEIGHT_INT8, jnp.int8)
    assert int(q.min()) >= -128 and int(q.max()) <= 127
    back = quant.dequantize_int(q, quant.WEIGHT_INT8)
    np.testing.assert_allclose(back, w, atol=quant.WEIGHT_INT8.scale / 2)
