"""Fault-tolerance control flow: stragglers, elastic shrink, manager."""

import pytest

from repro.distributed.fault_tolerance import (
    CheckpointManager,
    CheckpointPolicy,
    ElasticMeshManager,
    StragglerMonitor,
)


def test_straggler_detection_and_budget():
    mon = StragglerMonitor(threshold=2.0, budget=3)
    for step in range(10):
        assert not mon.record(step, 1.0)
    # three consecutive slow steps exhaust the budget
    assert not mon.record(10, 5.0)
    assert not mon.record(11, 5.0)
    assert mon.record(12, 5.0)
    assert len(mon.events) == 3


def test_straggler_ema_not_poisoned():
    mon = StragglerMonitor(threshold=2.0, budget=100)
    for step in range(5):
        mon.record(step, 1.0)
    ema_before = mon.ema
    mon.record(5, 50.0)  # one straggler
    assert mon.ema == ema_before  # slow steps don't move the baseline


def test_elastic_shrink_power_of_two():
    made = []
    mgr = ElasticMeshManager(lambda n: made.append(n) or n, 16)
    mgr.shrink(1)  # 15 -> rounds down to 8
    assert mgr.data_size == 8
    mgr.shrink(3)  # 5 -> 4
    assert mgr.data_size == 4
    mgr.shrink(3)  # 1
    assert mgr.data_size == 1
    with pytest.raises(RuntimeError):
        mgr.shrink(1)
    assert made == [8, 4, 1]


def test_checkpoint_manager_periodic(tmp_path):
    import jax.numpy as jnp

    mgr = CheckpointManager(
        CheckpointPolicy(str(tmp_path), every_steps=10, async_save=False)
    )
    tree = {"w": jnp.arange(4.0)}
    for step in range(1, 31):
        mgr.maybe_save(step, tree)
    restored, step = mgr.restore_latest(tree)
    assert step == 30
