"""Fault-tolerance control flow: stragglers, elastic shrink, manager,
and the occupancy/SLO-driven serving autoscaler policy (host-side unit
tests against a fake server; the end-to-end resize bit-identity story
lives in tests/test_serve_sharded.py)."""

import pytest

from repro.distributed.fault_tolerance import (
    CheckpointManager,
    CheckpointPolicy,
    ElasticMeshManager,
    StragglerMonitor,
)
from repro.serving.autoscale import Autoscaler, AutoscalePolicy


def test_straggler_detection_and_budget():
    mon = StragglerMonitor(threshold=2.0, budget=3)
    for step in range(10):
        assert not mon.record(step, 1.0)
    # three consecutive slow steps exhaust the budget
    assert not mon.record(10, 5.0)
    assert not mon.record(11, 5.0)
    assert mon.record(12, 5.0)
    assert len(mon.events) == 3


def test_straggler_ema_not_poisoned():
    mon = StragglerMonitor(threshold=2.0, budget=100)
    for step in range(5):
        mon.record(step, 1.0)
    ema_before = mon.ema
    mon.record(5, 50.0)  # one straggler
    assert mon.ema == ema_before  # slow steps don't move the baseline


def test_elastic_shrink_power_of_two():
    made = []
    mgr = ElasticMeshManager(lambda n: made.append(n) or n, 16)
    mgr.shrink(1)  # 15 -> rounds down to 8
    assert mgr.data_size == 8
    mgr.shrink(3)  # 5 -> 4
    assert mgr.data_size == 4
    mgr.shrink(3)  # 1
    assert mgr.data_size == 1
    with pytest.raises(RuntimeError):
        mgr.shrink(1)
    assert made == [8, 4, 1]


def test_checkpoint_manager_periodic(tmp_path):
    import jax.numpy as jnp

    mgr = CheckpointManager(
        CheckpointPolicy(str(tmp_path), every_steps=10, async_save=False)
    )
    tree = {"w": jnp.arange(4.0)}
    for step in range(1, 31):
        mgr.maybe_save(step, tree)
    restored, step = mgr.restore_latest(tree)
    assert step == 30


def test_straggler_warmup_discards_compile_step():
    """Regression: the EMA used to seed from the very FIRST duration —
    step 0 of any jitted loop includes compilation, so a ~100x-slow
    compile step became the baseline and genuinely slow steps were
    never flagged. The default warmup=1 discards it; the EMA seeds
    from the first post-warmup step."""
    mon = StragglerMonitor(threshold=2.0, budget=1)
    assert not mon.record(0, 100.0)  # compile step: discarded entirely
    assert not mon.record(1, 1.0)    # seeds the EMA
    assert mon.ema == 1.0            # NOT 100.0 (the pre-fix poison)
    # a 3x-slow step is a straggler against the healthy baseline;
    # pre-fix it looked fast against the 100.0 baseline and this
    # returned False
    assert mon.record(2, 3.0)
    assert len(mon.events) == 1 and mon.events[0].duration == 3.0


def test_straggler_warmup_knob():
    # warmup=0 opts back into seeding from the first duration
    mon = StragglerMonitor(threshold=2.0, budget=1, warmup=0)
    mon.record(0, 4.0)
    assert mon.ema == 4.0
    # longer warmups discard exactly that many steps
    mon = StragglerMonitor(warmup=3)
    for step in range(3):
        mon.record(step, 99.0)
    assert mon.ema is None
    mon.record(3, 1.0)
    assert mon.ema == 1.0
    with pytest.raises(ValueError, match="warmup"):
        StragglerMonitor(warmup=-1)


def test_checkpoint_manager_skips_step_zero(tmp_path):
    """Regression: `0 % every_steps == 0`, so step 0 used to save the
    untrained init — burning a `keep` slot and making it
    `restore_latest`'s answer after an early crash."""
    from repro.training.checkpoint import latest_step

    mgr = CheckpointManager(
        CheckpointPolicy(str(tmp_path), every_steps=10, async_save=False)
    )
    tree = {"w": [0.0]}
    mgr.maybe_save(0, tree)
    assert latest_step(str(tmp_path)) is None  # nothing saved
    with pytest.raises(FileNotFoundError):
        mgr.restore_latest(tree)


def test_checkpoint_keep_rotation_around_step_zero_fix(tmp_path):
    """`keep` retains the NEWEST trained checkpoints: with keep=2 and
    saves at 10/20/30, steps 20 and 30 survive — and step 0 never
    occupied a slot in the first place."""
    import jax.numpy as jnp

    from repro.training.checkpoint import latest_step, restore_checkpoint

    mgr = CheckpointManager(
        CheckpointPolicy(
            str(tmp_path), every_steps=10, keep=2, async_save=False
        )
    )
    for step in range(0, 31):
        mgr.maybe_save(step, {"w": jnp.full((2,), float(step))})
    mgr.wait()
    kept = sorted(p.name for p in tmp_path.iterdir())
    assert kept == ["step_000000020", "step_000000030"]
    assert latest_step(str(tmp_path)) == 30
    restored, step = restore_checkpoint(
        str(tmp_path), {"w": jnp.zeros((2,))}
    )
    assert step == 30 and float(restored["w"][0]) == 30.0


# --------------------------------------------------------------------------
# autoscaler policy (host-side, against a fake server)
# --------------------------------------------------------------------------


class _FakeServer:
    """Just the surface `Autoscaler` drives: occupancy inputs and a
    recording `resize`."""

    def __init__(self, max_streams=16, n_devices=4, n_open=0):
        self.max_streams = max_streams
        self.n_devices = n_devices
        self.active = {sid: sid for sid in range(n_open)}
        self.resizes = []

    def resize(self, n):
        self.resizes.append(n)
        self.max_streams = n


def _policy(**kw):
    base = dict(
        min_streams=4, max_streams=64, grow_at=0.85, shrink_at=0.30,
        hysteresis_ticks=3, cooldown_ticks=0, factor=2,
    )
    base.update(kw)
    return AutoscalePolicy(**base)


def test_autoscaler_grows_on_sustained_occupancy():
    srv = _FakeServer(max_streams=16, n_open=15)  # 0.94 occupancy
    auto = Autoscaler(srv, _policy())
    assert auto.observe() is None
    assert auto.observe() is None
    assert auto.observe() == "grow"  # third consecutive breach
    assert srv.resizes == [32]
    assert srv.max_streams % srv.n_devices == 0


def test_autoscaler_rejection_is_an_immediate_grow_signal():
    srv = _FakeServer(max_streams=16, n_open=8)  # mid occupancy
    auto = Autoscaler(srv, _policy())
    auto.note_rejection()
    assert auto.observe() == "grow"  # no hysteresis wait on rejection
    assert srv.resizes == [32]


def test_autoscaler_dead_zone_never_resizes():
    srv = _FakeServer(max_streams=16, n_open=8)  # 0.5: between marks
    auto = Autoscaler(srv, _policy())
    assert all(auto.observe() is None for _ in range(20))
    assert srv.resizes == []


def test_autoscaler_shrinks_only_when_slo_healthy():
    # low occupancy AND healthy latency -> shrink after hysteresis
    srv = _FakeServer(max_streams=32, n_open=4)
    mon = StragglerMonitor(threshold=2.0, budget=100, warmup=0)
    auto = Autoscaler(srv, _policy(), monitor=mon)
    for _ in range(2):
        assert auto.observe(1.0) is None
    assert auto.observe(1.0) == "shrink"
    assert srv.resizes == [16]
    # low occupancy but a straggler streak -> shrink is vetoed until
    # the latency recovers (shrinking packs more streams per device)
    srv2 = _FakeServer(max_streams=32, n_open=4)
    mon2 = StragglerMonitor(threshold=2.0, budget=100, warmup=0)
    auto2 = Autoscaler(srv2, _policy(), monitor=mon2)
    auto2.observe(1.0)  # seeds the EMA
    for _ in range(5):
        assert auto2.observe(10.0) is None  # breached: vetoed
    assert srv2.resizes == []
    acts = [auto2.observe(1.0) for _ in range(3)]  # healthy again
    assert "shrink" in acts and srv2.resizes == [16]


def test_autoscaler_shrink_clamps_to_open_blocks():
    # 9 open streams on 4 devices need ceil(9/4)*4 = 12 slots; the
    # halving target 8 is clamped up to the 12-slot block floor
    srv = _FakeServer(max_streams=16, n_devices=4, n_open=9)
    # 9/16 = 0.56 sits in the dead zone; widen shrink_at to force the
    # shrink path so the clamp is what's under test
    auto = Autoscaler(srv, _policy(shrink_at=0.60, grow_at=0.85))
    for _ in range(3):
        auto.observe()
    assert srv.resizes == [12]
    assert srv.max_streams >= len(srv.active)


def test_autoscaler_cooldown_and_caps():
    srv = _FakeServer(max_streams=16, n_open=16)
    auto = Autoscaler(srv, _policy(cooldown_ticks=5, max_streams=32))
    acts = [auto.observe() for _ in range(12)]
    assert acts.count("grow") == 1  # cooldown blocks a back-to-back act
    # at the cap: occupancy stays high but no further grow fires
    srv.active = {sid: sid for sid in range(32)}
    assert all(auto.observe() is None for _ in range(10))
    assert srv.resizes == [32]
    assert auto.events and auto.events[0]["action"] == "grow"


def test_autoscale_policy_validation():
    with pytest.raises(ValueError, match="shrink_at"):
        AutoscalePolicy(grow_at=0.3, shrink_at=0.8)
    with pytest.raises(ValueError, match="min_streams"):
        AutoscalePolicy(min_streams=0)
    with pytest.raises(ValueError, match="factor"):
        AutoscalePolicy(factor=1)
    with pytest.raises(ValueError, match="hysteresis"):
        AutoscalePolicy(hysteresis_ticks=0)
