"""Async double-buffered ingress: bit-identity + staging discipline.

The pipelined serving path (`repro.serving.ingress`) must be a pure
latency transformation: `step_batch_async` / `run_batch_async` dispatch
the SAME jitted programs on the SAME operands in the SAME order as the
synchronous `step_batch` sequence — only the host-side fetch moves
later in time. This suite proves it with `np.testing.assert_array_equal`
(never allclose) for every classifier backend ("float" / "qat" /
"integer" / "delta" / "delta-int"), with the stage-1 cascade enabled
(always-open and a real threshold), and on the 8-emulated-device
("stream",) mesh (tests/conftest.py forces the platform), including:

  * deferred handles fetched arbitrarily late — after further ticks
    donated the `ServerState` buffers the raw tick outputs alias, and
    after slot resets (`open_stream`) rewrote state in place;
  * `PipelinedIngress` buffer discipline: ping-pong reuse only after
    the consuming dispatch retired, FIFO retirement order, stage/commit
    protocol errors, and the `window` coalescing path (full and
    partial windows) against the per-tick reference;
  * `TickCoalescer` semantics: deadline / tick-full / second-frame
    flushes under an injected clock, kind and lifecycle validation,
    and slot mapping captured at dispatch time;
  * a lifecycle-oracle hypothesis harness interleaving open/close with
    in-flight async ticks: a stream's scores depend only on its own
    submitted frames, never on when handles were fetched.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.core.fex import fit_norm_stats
from repro.core.pipeline import KWSPipeline, KWSPipelineConfig
from repro.serving.cascade import CascadeConfig
from repro.serving.ingress import (
    CoalescedTick,
    PipelinedIngress,
    TickCoalescer,
    TickHandle,
)
from repro.serving.serve_loop import StreamingKWSServer

from _hypothesis_compat import given, settings, st

N_DEV = len(jax.devices())
MESH_DEV = (
    max(d for d in (2, 4, 8) if d <= min(8, N_DEV)) if N_DEV >= 2 else 1
)
MAX_STREAMS = 8
CLASSIFIERS = ("float", "qat", "integer", "delta", "delta-int")


@pytest.fixture(scope="module")
def norm_stats():
    rng = np.random.default_rng(0)
    audio = jnp.asarray(
        rng.standard_normal((4, 16000)).astype(np.float32) * 0.05
    )
    boot = KWSPipeline(KWSPipelineConfig(use_norm=False))
    _, raw = boot.features(audio)
    return fit_norm_stats(quant.log_compress_lut(raw, 12, 10))


@pytest.fixture(scope="module", params=CLASSIFIERS)
def backend(request, norm_stats):
    """(pipeline, params) per classifier backend, built once."""
    pipe = KWSPipeline(
        KWSPipelineConfig(classifier=request.param), norm_stats=norm_stats
    )
    return pipe, pipe.init_params(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def qat_server(norm_stats):
    """A single qat server for the ingress-discipline tests (state is
    fully reset per test via close+open, like the sharded suite)."""
    pipe = KWSPipeline(
        KWSPipelineConfig(classifier="qat"), norm_stats=norm_stats
    )
    params = pipe.init_params(jax.random.PRNGKey(3))
    return pipe, StreamingKWSServer(pipe, params, max_streams=MAX_STREAMS)


def _reset(srv, n_open=MAX_STREAMS):
    for sid in list(srv.active):
        srv.close_stream(sid)
    for sid in range(n_open):
        srv.open_stream(sid)


def _state_leaves(srv):
    return [
        np.asarray(leaf).copy()
        for leaf in jax.tree_util.tree_leaves(srv.state)
    ]


def _assert_states_identical(a, b):
    la, lb = _state_leaves(a), _state_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(x, y)


def _ticks(pipe, n, kind="fv", seed=0, n_streams=MAX_STREAMS):
    """n random (slab, mask) tick operands with partial masks."""
    rng = np.random.default_rng(seed)
    dim = (
        pipe.chunk_samples if kind == "audio"
        else pipe.config.fex.num_channels
    )
    out = []
    for _ in range(n):
        slab = rng.standard_normal(
            (n_streams, dim)
        ).astype(np.float32) * 0.05
        mask = rng.random(n_streams) > 0.25
        out.append((slab, mask))
    return out


def _drive_async_vs_sync(pipe, params, ticks, devices=1,
                         max_streams=MAX_STREAMS):
    """Dispatch every tick async (fetching nothing), then fetch all
    handles; replay the same ticks synchronously on a twin server.
    Returns (async_srv, sync_srv, async_results, sync_results)."""
    a = StreamingKWSServer(
        pipe, params, max_streams=max_streams, devices=devices
    )
    b = StreamingKWSServer(pipe, params, max_streams=max_streams)
    for sid in range(max_streams):
        a.open_stream(sid)
        b.open_stream(sid)
    handles = [a.step_batch_async(slab, mask) for slab, mask in ticks]
    got = [h.result() for h in handles]
    ref = [b.step_batch(slab, mask) for slab, mask in ticks]
    return a, b, got, ref


# --------------------------------------------------------------------------
# step_batch_async bit-identity (every backend, both kinds, deferred)
# --------------------------------------------------------------------------

def test_async_bit_identical_all_backends(backend):
    """All handles fetched AFTER the last dispatch: every tick's scores
    and top, and the final state, bit-match the synchronous sequence —
    for fv and raw-audio ticks alike."""
    pipe, params = backend
    ticks = _ticks(pipe, 4, "fv", seed=1) + _ticks(pipe, 2, "audio", seed=2)
    a, b, got, ref = _drive_async_vs_sync(pipe, params, ticks)
    for (gs, gt), (rs, rt) in zip(got, ref):
        np.testing.assert_array_equal(gs, rs)
        np.testing.assert_array_equal(gt, rt)
    _assert_states_identical(a, b)


def test_run_batch_async_window_matches_sequential(backend):
    """A run_batch_async window dispatch == the same ticks stepped one
    by one (the scan body IS the fused tick — the coalescing window
    inherits the correctness story)."""
    pipe, params = backend
    ticks = _ticks(pipe, 5, "fv", seed=3)
    a = StreamingKWSServer(pipe, params, max_streams=MAX_STREAMS)
    b = StreamingKWSServer(pipe, params, max_streams=MAX_STREAMS)
    for sid in range(MAX_STREAMS):
        a.open_stream(sid)
        b.open_stream(sid)
    slab = np.stack([s for s, _ in ticks])
    mask = np.stack([m for _, m in ticks])
    h = a.run_batch_async(slab, mask)
    ref = [b.step_batch(s, m) for s, m in ticks]
    scores_seq, tops = h.result()
    for t, (rs, rt) in enumerate(ref):
        np.testing.assert_array_equal(scores_seq[t], rs)
        np.testing.assert_array_equal(tops[t], rt)
    _assert_states_identical(a, b)


@pytest.mark.parametrize("wake_threshold", [0.0, 0.3])
def test_async_bit_identical_cascaded(norm_stats, wake_threshold):
    """Async == sync with the stage-1 wake gate in the tick, both
    always-open (threshold 0) and at a real threshold with hangover —
    the gate's frozen-state holds ride the deferred handles too."""
    pipe = KWSPipeline(
        KWSPipelineConfig(
            classifier="qat",
            cascade=CascadeConfig(
                wake_threshold=wake_threshold, hangover_frames=1
            ),
        ),
        norm_stats=norm_stats,
    )
    params = pipe.init_params(jax.random.PRNGKey(5))
    ticks = _ticks(pipe, 6, "fv", seed=5)
    a, b, got, ref = _drive_async_vs_sync(pipe, params, ticks)
    for (gs, gt), (rs, rt) in zip(got, ref):
        np.testing.assert_array_equal(gs, rs)
        np.testing.assert_array_equal(gt, rt)
    _assert_states_identical(a, b)
    np.testing.assert_array_equal(a.wake_rate, b.wake_rate)


@pytest.mark.skipif(
    N_DEV < 2,
    reason="needs a multi-device platform (conftest forces 8 emulated "
    "CPU devices unless XLA_FLAGS overrides it)",
)
def test_async_bit_identical_sharded(backend):
    """Async dispatch against the mesh-sharded server == the sync
    single-device sequence, handles fetched late — deferred fetches
    must materialize correctly from sharded score buffers. (2 slots
    per shard, matching tests/test_serve_sharded.py: a 1-slot shard
    compiles a batch-1 per-shard program whose float reduction order
    differs bitwise — a platform quirk, not an async property.)"""
    pipe, params = backend
    ms = 2 * MESH_DEV
    ticks = _ticks(pipe, 4, "fv", seed=7, n_streams=ms)
    a, b, got, ref = _drive_async_vs_sync(
        pipe, params, ticks, devices=MESH_DEV, max_streams=ms
    )
    for (gs, gt), (rs, rt) in zip(got, ref):
        np.testing.assert_array_equal(gs, rs)
        np.testing.assert_array_equal(gt, rt)
    _assert_states_identical(a, b)


# --------------------------------------------------------------------------
# handle-after-donation safety
# --------------------------------------------------------------------------

def test_handle_survives_later_ticks_and_slot_resets(qat_server):
    """A handle fetched two ticks late — and again after open_stream
    slot resets rewrote state in place — reads exactly what an
    immediate fetch would have."""
    pipe, srv = qat_server
    _reset(srv)
    ticks = _ticks(pipe, 5, "fv", seed=11)
    ref_srv = StreamingKWSServer(
        pipe, srv.params, max_streams=MAX_STREAMS
    )
    for sid in range(MAX_STREAMS):
        ref_srv.open_stream(sid)
    ref0 = ref_srv.step_batch(*ticks[0])

    h0 = srv.step_batch_async(*ticks[0])
    srv.step_batch_async(*ticks[1])  # donates the state h0's raw
    srv.step_batch_async(*ticks[2])  # outputs could alias — twice
    got0 = h0.result()
    np.testing.assert_array_equal(got0[0], ref0[0])
    np.testing.assert_array_equal(got0[1], ref0[1])
    # a handle still unfetched while slots reset in place
    h3 = srv.step_batch_async(*ticks[3])
    srv.close_stream(0)
    srv.open_stream(100)  # _reset rewrites slot 0's state buffers
    srv.step_batch_async(*ticks[4])
    got3a = h3.result()
    got3b = h3.result()  # idempotent: cached host copy
    assert got3a is got3b
    assert h3.ready() and h3.done_at is not None
    assert got3a[0].flags["OWNDATA"] and got3a[1].flags["OWNDATA"]


def test_step_batch_is_async_fetched_immediately(qat_server):
    """The sync path IS the async path + immediate result(): same
    arrays, owned copies."""
    pipe, srv = qat_server
    _reset(srv)
    slab, mask = _ticks(pipe, 1, "fv", seed=12)[0]
    scores, top = srv.step_batch(slab, mask)
    assert scores.flags["OWNDATA"] and top.flags["OWNDATA"]
    assert scores.shape == (MAX_STREAMS, pipe.config.gru.num_classes)
    assert top.shape == (MAX_STREAMS,)


# --------------------------------------------------------------------------
# PipelinedIngress staging discipline
# --------------------------------------------------------------------------

def test_ingress_bit_identity_and_fifo_order(qat_server):
    """depth=2 ping-pong over distinct per-tick data: every retired
    handle bit-matches the sync reference, retirement order is dispatch
    order, and buffer reuse never corrupts an in-flight tick."""
    pipe, srv = qat_server
    _reset(srv)
    ref_srv = StreamingKWSServer(pipe, srv.params, max_streams=MAX_STREAMS)
    for sid in range(MAX_STREAMS):
        ref_srv.open_stream(sid)
    ticks = _ticks(pipe, 7, "fv", seed=13)
    ing = PipelinedIngress(srv, pipe.config.fex.num_channels, depth=2)
    for i, (s, m) in enumerate(ticks):
        slab, mask = ing.stage()
        assert not mask.any()  # stage() hands the mask back cleared
        slab[:] = s
        mask[:] = m
        ing.commit(meta=i)
        assert ing.in_flight <= 2
    handles = ing.drain()
    assert [h.meta for h in handles] == list(range(7))
    assert ing.in_flight == 0
    ref = [ref_srv.step_batch(s, m) for s, m in ticks]
    for h, (rs, rt) in zip(handles, ref):
        np.testing.assert_array_equal(h.scores, rs)
        np.testing.assert_array_equal(h.top, rt)
    _assert_states_identical(srv, ref_srv)


def test_ingress_windowed_bit_identity_with_partial_flush(qat_server):
    """window=3 over 8 ticks (2 full windows + a partial of 2): per-tick
    rows of every window handle bit-match the sync sequence; partial
    windows scan only the staged ticks (no padded no-ops)."""
    pipe, srv = qat_server
    _reset(srv)
    ref_srv = StreamingKWSServer(pipe, srv.params, max_streams=MAX_STREAMS)
    for sid in range(MAX_STREAMS):
        ref_srv.open_stream(sid)
    ticks = _ticks(pipe, 8, "fv", seed=14)
    ing = PipelinedIngress(
        srv, pipe.config.fex.num_channels, depth=2, window=3
    )
    returned = []
    for i, (s, m) in enumerate(ticks):
        slab, mask = ing.stage()
        slab[:] = s
        mask[:] = m
        returned.append(ing.commit(meta=i))
    # window=3: commits 2, 5 dispatch (0-indexed), the rest return None
    assert [r is not None for r in returned] == [
        False, False, True, False, False, True, False, False
    ]
    assert ing.pending_ticks == 2
    handles = ing.drain()
    assert ing.pending_ticks == 0
    metas = [m for h in handles for m in h.meta]
    assert metas == list(range(8))
    ref = [ref_srv.step_batch(s, m) for s, m in ticks]
    t = 0
    for h in handles:
        scores_seq, tops = h.result()
        assert scores_seq.shape[0] == len(h.meta)
        for k in range(scores_seq.shape[0]):
            np.testing.assert_array_equal(scores_seq[k], ref[t][0])
            np.testing.assert_array_equal(tops[k], ref[t][1])
            t += 1
    assert t == 8
    _assert_states_identical(srv, ref_srv)


def test_ingress_protocol_errors(qat_server):
    pipe, srv = qat_server
    _reset(srv)
    dim = pipe.config.fex.num_channels
    with pytest.raises(ValueError, match="depth"):
        PipelinedIngress(srv, dim, depth=0)
    with pytest.raises(ValueError, match="window"):
        PipelinedIngress(srv, dim, window=0)
    with pytest.raises(ValueError, match="trailing dim"):
        PipelinedIngress(srv, dim + 1)  # neither hop nor frame width
    ing = PipelinedIngress(srv, dim)
    with pytest.raises(RuntimeError, match="commit"):
        ing.commit()  # commit without stage
    ing.stage()
    with pytest.raises(RuntimeError, match="stage"):
        ing.stage()  # stage twice without commit
    with pytest.raises(RuntimeError, match="flush"):
        ing.flush()  # flush with a staged-but-uncommitted tick
    ing.commit()
    assert ing.drain()  # leaves the ingress reusable
    assert ing.in_flight == 0


def test_ingress_reallocates_after_resize(qat_server):
    """A live `resize()` invalidates the preallocated slabs: staging
    with old-capacity work still in flight raises (those buffers may
    still be read by the device), while drain() + stage() silently
    reallocates at the new capacity — and both the pre- and
    post-resize ticks bit-match a synchronous twin resized at the same
    point."""
    pipe, base = qat_server
    srv = StreamingKWSServer(pipe, base.params, max_streams=MAX_STREAMS)
    twin = StreamingKWSServer(pipe, base.params, max_streams=MAX_STREAMS)
    for sid in range(MAX_STREAMS):
        srv.open_stream(sid)
        twin.open_stream(sid)
    dim = pipe.config.fex.num_channels
    ing = PipelinedIngress(srv, dim, depth=2)
    ref = []
    for s, m in _ticks(pipe, 3, "fv", seed=21):
        slab, mask = ing.stage()
        slab[:] = s
        mask[:] = m
        ing.commit()
        ref.append(twin.step_batch(s, m))
    assert ing.in_flight > 0
    grown = MAX_STREAMS * 2
    srv.resize(grown)
    with pytest.raises(RuntimeError, match="drain"):
        ing.stage()  # in-flight dispatches hold old-capacity slabs
    for h, (rs, rt) in zip(ing.drain(), ref):
        np.testing.assert_array_equal(h.scores, rs)
        np.testing.assert_array_equal(h.top, rt)
    twin.resize(grown)
    assert twin.active == srv.active  # single-device remap is identity
    for k, (s, m) in enumerate(
        _ticks(pipe, 3, "fv", seed=22, n_streams=grown)
    ):
        m[MAX_STREAMS:] = False  # grown slots are still unopened
        slab, mask = ing.stage()
        assert slab.shape == (grown, dim)  # reallocated, new capacity
        assert mask.shape == (grown,)
        slab[:] = s
        mask[:] = m
        ing.commit(meta=k)
        ref.append(twin.step_batch(s, m))
    for h, (rs, rt) in zip(ing.drain(), ref[3:]):
        np.testing.assert_array_equal(h.scores, rs)
        np.testing.assert_array_equal(h.top, rt)
    _assert_states_identical(srv, twin)


# --------------------------------------------------------------------------
# TickCoalescer
# --------------------------------------------------------------------------

class _FakeClock:
    def __init__(self, t=100.0):
        self.t = t

    def __call__(self):
        return self.t


def _coalescer(srv, **kw):
    clock = _FakeClock()
    return TickCoalescer(srv, clock=clock, **kw), clock


def test_coalescer_flushes_when_every_open_stream_submitted(qat_server):
    pipe, srv = qat_server
    _reset(srv, n_open=3)
    co, _clock = _coalescer(srv)
    rng = np.random.default_rng(15)
    frames = {
        sid: rng.standard_normal(16).astype(np.float32) for sid in range(3)
    }
    co.add(0, frames[0])
    co.add(1, frames[1])
    assert co.pending_streams == 2
    co.add(2, frames[2])  # tick full -> flush
    assert co.pending_streams == 0
    (h,) = co.drain()
    assert isinstance(h.meta, CoalescedTick)
    assert h.meta.sids == {sid: srv.active[sid] for sid in range(3)}
    assert h.meta.flushed_at is not None
    # rows bit-match a sync reference serving the same frames
    ref_srv = StreamingKWSServer(pipe, srv.params, max_streams=MAX_STREAMS)
    for sid in range(3):
        ref_srv.open_stream(sid)
    ref = ref_srv.step(frames)
    for sid, slot in h.meta.sids.items():
        np.testing.assert_array_equal(h.scores[slot], ref[sid]["probs"])


def test_coalescer_deadline_flush_via_injected_clock(qat_server):
    pipe, srv = qat_server
    _reset(srv, n_open=2)
    co, clock = _coalescer(srv, window_ms=16.0)
    f = np.ones(16, np.float32)
    co.add(0, f)
    assert co.poll() == []  # deadline not reached: no flush
    assert co.pending_streams == 1
    clock.t += 0.0159
    assert co.poll() == []  # 15.9 ms: still inside the window
    clock.t += 0.0002
    co.poll()  # 16.1 ms: flushes
    assert co.pending_streams == 0
    handles = co.drain()
    assert len(handles) == 1
    assert handles[0].meta.flushed_at - handles[0].meta.staged_at >= 0.016


def test_coalescer_second_frame_flushes_previous_window(qat_server):
    pipe, srv = qat_server
    _reset(srv, n_open=2)
    co, _clock = _coalescer(srv)
    f1 = np.ones(16, np.float32)
    f2 = np.full(16, 2.0, np.float32)
    co.add(0, f1)
    co.add(0, f2)  # same stream again: f1's window flushes first
    assert co.pending_streams == 1  # f2 opened the next window
    co.flush()
    handles = co.drain()
    assert len(handles) == 2
    assert list(handles[0].meta.sids) == [0]
    assert list(handles[1].meta.sids) == [0]
    # two ticks for stream 0, in submission order
    ref_srv = StreamingKWSServer(pipe, srv.params, max_streams=MAX_STREAMS)
    ref_srv.open_stream(0)
    r1 = ref_srv.step({0: f1})
    r2 = ref_srv.step({0: f2})
    slot = handles[0].meta.sids[0]
    np.testing.assert_array_equal(handles[0].scores[slot], r1[0]["probs"])
    np.testing.assert_array_equal(handles[1].scores[slot], r2[0]["probs"])


def test_coalescer_validation(qat_server):
    pipe, srv = qat_server
    _reset(srv, n_open=2)
    with pytest.raises(ValueError, match="window_ms"):
        TickCoalescer(srv, window_ms=0)
    co, _clock = _coalescer(srv)
    with pytest.raises(ValueError, match="stream 99 not open"):
        co.add(99, np.ones(16, np.float32))
    with pytest.raises(ValueError, match="trailing dim"):
        co.add(0, np.ones(17, np.float32))
    co.add(0, np.ones(16, np.float32))
    with pytest.raises(ValueError, match="same kind"):
        co.add(1, np.ones(pipe.chunk_samples, np.float32))
    assert co.pending_streams == 1  # the bad adds staged nothing
    co.drain()


# --------------------------------------------------------------------------
# lifecycle oracle: open/close interleaved with in-flight async ticks
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def async_oracle_servers(norm_stats):
    """(async 8-slot server, single-device 1-slot reference) on shared
    qat params — module-scoped so hypothesis examples reuse the
    compiled tick programs."""
    pipe = KWSPipeline(
        KWSPipelineConfig(classifier="qat"), norm_stats=norm_stats
    )
    params = pipe.init_params(jax.random.PRNGKey(7))
    srv = StreamingKWSServer(pipe, params, max_streams=MAX_STREAMS)
    reference = StreamingKWSServer(pipe, params, max_streams=1)
    return srv, reference


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    events=st.lists(
        st.tuples(
            st.booleans(),  # open a new stream before this tick?
            st.booleans(),  # close the oldest open stream first?
            st.integers(min_value=0, max_value=255),  # submit bitmask
        ),
        min_size=2,
        max_size=6,
    ),
)
def test_async_random_schedule_matches_lifecycle_oracle(
    async_oracle_servers, seed, events
):
    """Random open/close/submit schedules driven entirely through
    `step_batch_async` with handles held in flight across open/close
    events and fetched only at the end: each open stream's final scores
    bit-match a single-device synchronous replay of its own recorded
    frames — independent of every other stream's traffic and of when
    any handle was fetched."""
    srv, reference = async_oracle_servers
    for sid in list(srv.active):
        srv.close_stream(sid)
    rng = np.random.default_rng(seed)
    next_sid = 0
    frames_of = {}
    handles = []

    def do_open():
        nonlocal next_sid
        srv.open_stream(next_sid)
        frames_of[next_sid] = []
        next_sid += 1

    do_open()
    for want_open, want_close, submit_bits in events:
        if want_close and len(srv.active) > 1:
            victim = min(srv.active)
            srv.close_stream(victim)
            del frames_of[victim]
        if want_open and len(srv.active) < srv.max_streams:
            do_open()
        slab = np.zeros((srv.max_streams, 16), np.float32)
        mask = np.zeros((srv.max_streams,), bool)
        for i, sid in enumerate(sorted(srv.active)):
            if submit_bits >> (i % 8) & 1:
                f = rng.standard_normal(16).astype(np.float32)
                slab[srv.active[sid]] = f
                mask[srv.active[sid]] = True
                frames_of[sid].append(f)
        # dispatch WITHOUT fetching: handles stay in flight across the
        # open/close events of later iterations
        handles.append(srv.step_batch_async(slab.copy(), mask.copy()))
    for h in handles:
        h.result()  # late fetches must all still be valid
    for sid in sorted(srv.active):
        reference.open_stream(sid)
        expected = np.zeros_like(np.asarray(reference.state.scores[0]))
        for f in frames_of[sid]:
            out = reference.step({sid: f})
            expected = out[sid]["probs"]
        got = srv.scores[srv.active[sid]]
        np.testing.assert_array_equal(got, expected)
        reference.close_stream(sid)


# --------------------------------------------------------------------------
# TickHandle unit behavior
# --------------------------------------------------------------------------

def test_tick_handle_plain_arrays():
    """Non-jax stand-ins (plain numpy) work: ready() is immediately
    True and result() copies to owned host arrays."""
    h = TickHandle(np.arange(6.0).reshape(2, 3), np.array([1, 2]),
                   meta="m")
    assert h.ready()
    s, t = h.result()
    assert s.flags["OWNDATA"] and t.flags["OWNDATA"]
    assert h.meta == "m" and h.done_at is not None
