"""One-kernel serving tick: megakernel == XLA tick, bit for bit.

`repro.kernels.tick_fused` runs the ENTIRE 16 ms serving tick
(frontend feature frame, cascade wake gate, GRU layers, FC head,
softmax, smoothing, masked state advance) as one `pallas_call` over
stream blocks. This suite pins the whole contract down to array
equality (`np.testing.assert_array_equal`, never allclose) on the CPU
interpret tier, which executes the same kernel body — block slicing,
operand encoding, the ΔGRU gather path — as the compiled TPU tier:

  * fused-interpret == xla for every classifier backend ("float" /
    "qat" / "integer" / "delta" / "delta-int", the delta pair at a
    real θ>0 where the gather path actually skips columns), across
    live ticks (raw audio and FV_Norm slabs, rotating partial masks,
    an all-idle tick), late-fetched async handles, the `lax.scan`
    replay, a cascaded pipeline, and the 8-emulated-device stream
    mesh;
  * the gather-compacted Δ·W building blocks equal their dense
    counterparts exactly on the fixed-point grids (float domain vs
    ``d @ w``, code domain vs `intgemm_ref` incl. the int24 clip),
    and the wake-mask row zeroing touches ONLY rows the tick's
    `masked_select` discards;
  * kernel geometry edges (hypothesis): odd max_streams that leave a
    block remainder, hidden_dim % lane != 0, single-stream slabs,
    all-idle ticks.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.core.fex import fit_norm_stats
from repro.core.gru import GRUConfig
from repro.core.gru_delta import DeltaConfig
from repro.core.pipeline import KWSPipeline, KWSPipelineConfig
from repro.kernels.intgemm import intgemm_ref
from repro.kernels.tick_fused import (
    gather_delta_intgemm,
    gather_delta_matmul,
    make_sparse_step,
    resolve_tick_dispatch,
)
from repro.serving.cascade import CascadeConfig
from repro.serving.serve_loop import StreamingKWSServer

from _hypothesis_compat import given, settings, st

N_DEV = len(jax.devices())
CLASSIFIERS = ("float", "qat", "integer", "delta", "delta-int")
THETA = 0.15  # real sparsity: the gather path must actually skip work


@pytest.fixture(scope="module")
def norm_stats():
    rng = np.random.default_rng(0)
    audio = jnp.asarray(
        rng.standard_normal((4, 16000)).astype(np.float32) * 0.05
    )
    boot = KWSPipeline(KWSPipelineConfig(use_norm=False))
    _, raw = boot.features(audio)
    return fit_norm_stats(quant.log_compress_lut(raw, 12, 10))


@pytest.fixture(scope="module")
def shared_params():
    return KWSPipeline(KWSPipelineConfig()).init_params(
        jax.random.PRNGKey(7)
    )


def _pipe(norm_stats, classifier, cascade=None, gru=None):
    kw = dict(classifier=classifier, delta=DeltaConfig(THETA, THETA))
    if cascade is not None:
        kw["cascade"] = cascade
    if gru is not None:
        kw["gru"] = gru
    return KWSPipeline(KWSPipelineConfig(**kw), norm_stats=norm_stats)


def _pair(norm_stats, params, classifier, max_streams=5, cascade=None,
          gru=None, devices=None):
    """(xla, fused-interpret) servers on identical params/config."""
    mk = lambda impl, dev: StreamingKWSServer(  # noqa: E731
        _pipe(norm_stats, classifier, cascade, gru), params,
        max_streams=max_streams, tick_impl=impl, devices=dev,
    )
    return mk("xla", None), mk("fused-interpret", devices)


def _assert_servers_identical(a, b):
    for la, lb in zip(
        jax.tree_util.tree_leaves(a.state),
        jax.tree_util.tree_leaves(b.state),
    ):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _drive_live(a, b, rng, ticks=5, open_ids=(0, 1, 2)):
    """Raw-audio ticks with rotating partial masks + one all-idle tick,
    asserting scores/top equality every tick and state equality after."""
    for srv in (a, b):
        for sid in open_ids:
            srv.open_stream(sid)
    hop = a.pipeline.chunk_samples
    n = a.max_streams
    for t in range(ticks):
        slab = np.zeros((n, hop), np.float32)
        mask = np.zeros((n,), bool)
        for sid in open_ids:
            if (t + sid) % 3 != 0:
                slab[a.active[sid]] = (
                    rng.standard_normal(hop).astype(np.float32) * 0.05
                )
                mask[a.active[sid]] = True
        s_a, t_a = a.step_batch(slab, mask)
        s_b, t_b = b.step_batch(slab, mask)
        np.testing.assert_array_equal(s_a, s_b)
        np.testing.assert_array_equal(t_a, t_b)
    # all-idle tick: zero firing columns, empty gather loop
    idle = np.zeros((n, hop), np.float32), np.zeros((n,), bool)
    np.testing.assert_array_equal(a.step_batch(*idle)[0],
                                  b.step_batch(*idle)[0])
    _assert_servers_identical(a, b)


# --------------------------------------------------------------------------
# serving API surface
# --------------------------------------------------------------------------

def test_tick_impl_validation_and_resolution(norm_stats, shared_params):
    pipe = _pipe(norm_stats, "qat")
    with pytest.raises(ValueError, match="tick_impl"):
        StreamingKWSServer(pipe, shared_params, max_streams=4,
                           tick_impl="pallas")  # kernel-tier name, not an impl
    srv = StreamingKWSServer(pipe, shared_params, max_streams=4,
                             tick_impl="fused-interpret")
    assert srv.tick_impl == "fused-interpret"
    assert srv.tick_dispatch == "interpret"
    auto = StreamingKWSServer(pipe, shared_params, max_streams=4)
    if jax.default_backend() == "tpu":  # pragma: no cover - TPU runner
        assert auto.tick_impl == "fused-pallas"
    else:
        assert auto.tick_impl == "xla"
    assert auto.tick_dispatch == ("pallas" if auto.tick_impl ==
                                  "fused-pallas" else "xla")


def test_resolve_tick_dispatch_off_tpu():
    if jax.default_backend() == "tpu":  # pragma: no cover - TPU runner
        assert resolve_tick_dispatch() == "pallas"
    else:
        assert resolve_tick_dispatch() == "reference"
    assert resolve_tick_dispatch("interpret") == "interpret"
    assert resolve_tick_dispatch(interpret=True) == "interpret"


def test_make_sparse_step_only_for_delta(norm_stats):
    assert make_sparse_step(_pipe(norm_stats, "qat")) is None
    assert make_sparse_step(_pipe(norm_stats, "integer")) is None
    assert make_sparse_step(_pipe(norm_stats, "delta")) is not None
    assert make_sparse_step(_pipe(norm_stats, "delta-int")) is not None


# --------------------------------------------------------------------------
# gather-compacted Δ·W building blocks
# --------------------------------------------------------------------------

def _grid_delta(rng, b, i, fire_frac):
    """A thresholded-Δ block on the Q6.8 grid with dead columns."""
    d = quant.fake_quant(
        jnp.asarray(rng.standard_normal((b, i)).astype(np.float32)),
        quant.ACT_Q6_8,
    )
    cols = rng.random(i) < fire_frac
    return jnp.where(jnp.asarray(cols)[None, :], d, 0.0)


@pytest.mark.parametrize("fire_frac", [0.0, 0.3, 1.0])
def test_gather_matmul_matches_dense(fire_frac):
    rng = np.random.default_rng(3)
    d = _grid_delta(rng, 4, 48, fire_frac)
    w = quant.fake_quant(
        jnp.asarray(rng.standard_normal((48, 36)).astype(np.float32)),
        quant.WEIGHT_INT8,
    )
    np.testing.assert_array_equal(
        np.asarray(gather_delta_matmul(d, w)), np.asarray(d @ w)
    )


@pytest.mark.parametrize("fire_frac", [0.0, 0.3, 1.0])
def test_gather_intgemm_matches_ref(fire_frac):
    rng = np.random.default_rng(4)
    d = jnp.asarray(
        rng.integers(-4096, 4096, (4, 48)).astype(np.int32)
        * (rng.random((4, 48)) < fire_frac)
    ).astype(jnp.int16)
    w = jnp.asarray(rng.integers(-128, 128, (48, 36)), jnp.int8)
    np.testing.assert_array_equal(
        np.asarray(gather_delta_intgemm(d, w)),
        np.asarray(intgemm_ref(d, w)),
    )


def test_gather_intgemm_saturates_like_ref():
    """int24 clip applied to the whole contribution, like intgemm_ref."""
    d = jnp.full((2, 48), 32767, jnp.int16)
    w = jnp.full((48, 8), 127, jnp.int8)
    np.testing.assert_array_equal(
        np.asarray(gather_delta_intgemm(d, w)),
        np.asarray(intgemm_ref(d, w)),
    )


def test_gather_row_mask_touches_only_masked_rows():
    rng = np.random.default_rng(5)
    d = _grid_delta(rng, 4, 48, 0.5)
    w = quant.fake_quant(
        jnp.asarray(rng.standard_normal((48, 36)).astype(np.float32)),
        quant.WEIGHT_INT8,
    )
    keep = jnp.asarray([True, False, True, False])
    out = np.asarray(gather_delta_matmul(d, w, row_mask=keep))
    dense = np.asarray(d @ w)
    np.testing.assert_array_equal(out[np.asarray(keep)],
                                  dense[np.asarray(keep)])


# --------------------------------------------------------------------------
# megakernel == XLA tick, end to end
# --------------------------------------------------------------------------

@pytest.mark.parametrize("classifier", CLASSIFIERS)
def test_fused_interpret_bit_identical_live(
    norm_stats, shared_params, classifier
):
    a, b = _pair(norm_stats, shared_params, classifier)
    _drive_live(a, b, np.random.default_rng(10))


@pytest.mark.parametrize("classifier", ("qat", "integer", "delta",
                                        "delta-int"))
def test_fused_interpret_bit_identical_scan(
    norm_stats, shared_params, classifier
):
    a, b = _pair(norm_stats, shared_params, classifier)
    for srv in (a, b):
        for sid in range(3):
            srv.open_stream(sid)
    hop = a.pipeline.chunk_samples
    rng = np.random.default_rng(11)
    slab = rng.standard_normal((6, 5, hop)).astype(np.float32) * 0.05
    mask = rng.random((6, 5)) < 0.6
    mask[:, 3:] = False  # never-opened slots stay idle
    seq_a, tops_a = a.run_batch(slab, mask)
    seq_b, tops_b = b.run_batch(slab, mask)
    np.testing.assert_array_equal(seq_a, seq_b)
    np.testing.assert_array_equal(tops_a, tops_b)
    _assert_servers_identical(a, b)


def test_fused_interpret_async_handles_survive_later_ticks(
    norm_stats, shared_params
):
    a, b = _pair(norm_stats, shared_params, "delta")
    for srv in (a, b):
        srv.open_stream(0)
    hop = a.pipeline.chunk_samples
    rng = np.random.default_rng(12)
    slabs = [rng.standard_normal((5, hop)).astype(np.float32) * 0.05
             for _ in range(3)]
    mask = np.zeros((5,), bool)
    mask[0] = True
    ha = [a.step_batch_async(s, mask) for s in slabs]
    hb = [b.step_batch_async(s, mask) for s in slabs]
    for x, y in zip(ha, hb):  # fetched AFTER later ticks donated state
        sa, ta = x.result()
        sb, tb = y.result()
        np.testing.assert_array_equal(sa, sb)
        np.testing.assert_array_equal(ta, tb)


@pytest.mark.parametrize("classifier", ("qat", "delta", "delta-int"))
def test_fused_interpret_bit_identical_cascaded(
    norm_stats, shared_params, classifier
):
    """Real wake threshold: gated streams' frozen state + score decay
    must survive the block-sliced kernel unchanged."""
    casc = CascadeConfig()
    a, b = _pair(norm_stats, shared_params, classifier, cascade=casc)
    _drive_live(a, b, np.random.default_rng(13))
    np.testing.assert_array_equal(a.wake_rate, b.wake_rate)
    np.testing.assert_array_equal(a.sparsity, b.sparsity)


@pytest.mark.skipif(N_DEV < 2, reason="needs the emulated multi-device "
                    "platform (tests/conftest.py)")
@pytest.mark.parametrize("classifier", ("qat", "delta", "delta-int"))
def test_fused_interpret_bit_identical_sharded(
    norm_stats, shared_params, classifier
):
    """shard_map'd megakernel (one kernel per shard-local slab) == the
    single-device XLA tick."""
    mesh_dev = max(d for d in (2, 4, 8) if d <= min(8, N_DEV))
    a, b = _pair(norm_stats, shared_params, classifier, max_streams=8,
                 devices=mesh_dev)
    _drive_live(a, b, np.random.default_rng(14))
    np.testing.assert_array_equal(a.sparsity, b.sparsity)


# --------------------------------------------------------------------------
# kernel geometry edges
# --------------------------------------------------------------------------

@pytest.mark.parametrize(
    "max_streams,hidden,classifier",
    [
        (1, 48, "qat"),        # single-stream slab, 7-row block pad
        (5, 20, "delta-int"),  # odd remainder + lane-misaligned hidden
        (13, 20, "delta"),     # two blocks + remainder, gather path
    ],
)
def test_geometry_edges_deterministic(
    norm_stats, max_streams, hidden, classifier
):
    """Pinned geometry-edge cases (the hypothesis sweep below widens
    the net when the extra is installed)."""
    gru = GRUConfig(hidden_dim=hidden)
    params = _pipe(norm_stats, classifier, gru=gru).init_params(
        jax.random.PRNGKey(21)
    )
    a, b = _pair(norm_stats, params, classifier,
                 max_streams=max_streams, gru=gru)
    open_ids = tuple(range(min(3, max_streams)))
    _drive_live(a, b, np.random.default_rng(21), ticks=3,
                open_ids=open_ids)

@settings(max_examples=5, deadline=None)
@given(
    max_streams=st.sampled_from([1, 5, 7, 13]),
    hidden=st.sampled_from([20, 48]),  # 20: hidden % lane width != 0
    classifier=st.sampled_from(["qat", "delta-int"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_geometry_edges_bit_identical(
    norm_stats, max_streams, hidden, classifier, seed
):
    """Odd stream counts (block remainders incl. a single-stream slab),
    lane-misaligned hidden dims, and all-idle ticks: the padded block
    grid must stay exact."""
    gru = GRUConfig(hidden_dim=hidden)
    params = _pipe(norm_stats, classifier, gru=gru).init_params(
        jax.random.PRNGKey(seed % 1000)
    )
    a, b = _pair(norm_stats, params, classifier,
                 max_streams=max_streams, gru=gru)
    open_ids = tuple(range(min(3, max_streams)))
    _drive_live(a, b, np.random.default_rng(seed), ticks=3,
                open_ids=open_ids)
