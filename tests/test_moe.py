"""MoE layer: routing exactness, capacity dropping, expert padding."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.moe import moe_apply, moe_init, padded_num_experts


def _tiny_cfg(capacity_factor=16.0, num_experts=8):
    cfg = get_config("granite-moe-3b-a800m").reduced()
    return dataclasses.replace(
        cfg,
        dtype="float32",
        moe=dataclasses.replace(
            cfg.moe,
            num_experts=num_experts,
            capacity_factor=capacity_factor,
        ),
    )


def _dense_reference(p, x, cfg):
    """Route every token to its top-k experts with NO capacity limit."""
    m = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf @ p["router"]
    e_pad = p["w_up"].shape[0]
    mask = jnp.arange(e_pad) < m.num_experts
    logits = jnp.where(mask[None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, m.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    out = jnp.zeros_like(xf)
    for e in range(e_pad):
        h = jax.nn.silu(xf @ p["w_gate"][e]) * (xf @ p["w_up"][e])
        y_e = h @ p["w_down"][e]
        w = ((idx == e) * gates).sum(-1)  # (T,)
        out = out + w[:, None] * y_e
    return out.reshape(b, s, d)


def test_moe_matches_dense_reference_when_no_drops():
    cfg = _tiny_cfg(capacity_factor=16.0)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, aux = moe_apply(p, x, cfg)
    ref = _dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)
    assert float(aux) > 0


def test_capacity_drops_reduce_output_norm():
    cfg_hi = _tiny_cfg(capacity_factor=16.0)
    cfg_lo = _tiny_cfg(capacity_factor=0.25)
    p = moe_init(jax.random.PRNGKey(0), cfg_hi)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg_hi.d_model))
    y_hi, _ = moe_apply(p, x, cfg_hi)
    y_lo, _ = moe_apply(p, x, cfg_lo)
    assert float(jnp.linalg.norm(y_lo)) < float(jnp.linalg.norm(y_hi))


def test_padded_experts_never_receive_tokens():
    """num_experts=5 padded to 8: padded routing mass must be zero."""
    cfg = _tiny_cfg(num_experts=5)

    class FakeMC:
        model_size = 8

    e_pad = padded_num_experts(5, FakeMC())
    assert e_pad == 8
    p = moe_init(jax.random.PRNGKey(0), cfg)
    # manually pad router to 8 and check -inf masking via dense ref:
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 16, cfg.d_model))
    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ p["router"]
    mask = jnp.arange(p["router"].shape[1]) < 5
    logits = jnp.where(mask[None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, -1)
    assert float(probs[:, 5:].sum()) == 0.0


def test_moe_grads_flow_to_experts_and_router():
    cfg = _tiny_cfg()
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))

    def loss(p):
        y, aux = moe_apply(p, x, cfg)
        return jnp.sum(y**2) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).max()) > 0
    assert float(jnp.abs(g["w_up"]).max()) > 0
    assert float(jnp.abs(g["w_down"]).max()) > 0


def test_int8_expert_serving_weights():
    """serve_quant path: ~1% output error, exact structural roundtrip."""
    from repro.models.moe_quant import (
        quantize_expert_params, quantize_expert_shapes)

    cfg = _tiny_cfg()
    p = moe_init(jax.random.PRNGKey(0), cfg)
    pq = quantize_expert_params({"moe": p})["moe"]
    assert pq["w_up"]["q"].dtype.name == "int8"
    assert pq["w_up"]["s"].shape == p["w_up"].shape[:-1] + (1,)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model)) * 0.5
    y, _ = moe_apply(p, x, cfg)
    yq, _ = moe_apply(pq, x, cfg)
    rel = float(jnp.abs(yq - y).max() / jnp.abs(y).max())
    assert rel < 0.05, rel
    # abstract transform matches the concrete one
    shapes = jax.eval_shape(lambda: p)
    qs = quantize_expert_shapes({"moe": shapes})["moe"]
    assert qs["w_up"]["q"].shape == pq["w_up"]["q"].shape
