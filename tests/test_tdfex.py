"""Hardware-sim FEx: TDC counts, calibration, noise shaping."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.calibration import calibrate_chip, measure_beta
from repro.core.tdfex import (
    TDFExConfig,
    counts_to_fv_raw,
    draw_chip,
    sro_tdc,
    tdfex_raw_counts,
    vtc,
)

CFG = TDFExConfig()


def test_beta_matches_free_running_frequency():
    beta = measure_beta(CFG, chip=None)
    np.testing.assert_allclose(
        np.asarray(beta), CFG.beta_nominal, rtol=0.01
    )


def test_dc_input_counts_match_ideal():
    """Constant rectified input -> counts == ideal within 1 LSB."""
    u = jnp.full((1, 512 * 4, 16), 0.3, jnp.float32)
    counts = np.asarray(sro_tdc(u, CFG))
    ideal = CFG.counts_per_frame(0.3)
    assert np.all(np.abs(counts - ideal) <= 1.0)


def test_alpha_recovers_gain_mismatch():
    chip = draw_chip(jax.random.PRNGKey(7), CFG)
    beta, alpha = calibrate_chip(CFG, chip)
    g = np.asarray(1.0 + chip.gain_mismatch)
    ideal = (1.0 / g) / np.mean(1.0 / g)
    # channel 15 sits near the internal Nyquist; its calibration also
    # absorbs filter discretization — exclude from the strict check
    np.testing.assert_allclose(
        np.asarray(alpha)[:15], ideal[:15], rtol=0.06
    )


def test_vtc_distortion_level():
    """HD2/HD3 at -70 dB per the post-layout sim (Fig. 7)."""
    t = np.arange(16000) / 16000.0
    x = jnp.asarray(0.25 * np.sin(2 * np.pi * 1000 * t), jnp.float32)[None]
    y = np.asarray(vtc(x, CFG))[0]
    spec = np.abs(np.fft.rfft(y * np.hanning(len(y))))
    f = np.fft.rfftfreq(len(y), 1 / 32000.0)
    fund = spec[np.argmin(np.abs(f - 1000))]
    hd2 = spec[np.argmin(np.abs(f - 2000))]
    hd3 = spec[np.argmin(np.abs(f - 3000))]
    assert 20 * np.log10(hd2 / fund + 1e-12) < -60
    assert 20 * np.log10(hd3 / fund + 1e-12) < -60


def test_noise_shaping_first_order():
    """XOR-diff stream of a DC input shows 1st-order (20 dB/dec) shaped
    quantization noise: high-frequency noise >> low-frequency noise."""
    u = jnp.full((1, 512 * 8, 4), 0.11, jnp.float32)
    _, diff = sro_tdc(u, TDFExConfig(), return_diff_stream=True)
    d = np.asarray(diff)[0, :, 0]
    d = d - d.mean()
    spec = np.abs(np.fft.rfft(d)) ** 2
    n = len(spec)
    lo = spec[1 : n // 100].mean()  # in-band
    hi = spec[n // 4 : n // 2].mean()  # near Nyquist
    assert hi / max(lo, 1e-12) > 30  # >15 dB shaping headroom


def test_counts_to_fv_raw_range_and_calibration():
    chip = draw_chip(jax.random.PRNGKey(3), CFG)
    beta, alpha = calibrate_chip(CFG, chip)
    rng = np.random.default_rng(0)
    audio = jnp.asarray(
        rng.standard_normal((2, 8192)).astype(np.float32) * 0.1
    )
    counts = tdfex_raw_counts(audio, CFG, chip)
    codes = np.asarray(counts_to_fv_raw(counts, CFG, beta, alpha))
    assert codes.min() >= 0 and codes.max() <= 4095
    assert codes.shape[-1] == 16
