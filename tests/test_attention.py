"""Attention variants: GQA grouping, windows, softcap, flash chunking."""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.attention import (
    _mask,
    _sdpa,
    _sdpa_grouped,
    attn_apply,
    attn_init,
)

RNG = np.random.default_rng(0)


def _qkv(b=2, s=32, h=8, kv=2, d=16):
    q = jnp.asarray(RNG.standard_normal((b, s, h, d)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((b, s, kv, d)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((b, s, kv, d)).astype(np.float32))
    return q, k, v


def _ref_attention(q, k, v, mask, scale, cap=None):
    """Dense reference with explicit per-head group expansion."""
    b, s, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    out = np.zeros((b, s, h, d), np.float32)
    qn, kn, vn = map(np.asarray, (q, k, v))
    mk = np.asarray(mask)
    for hh in range(h):
        kk = kn[:, :, hh // g]
        vv = vn[:, :, hh // g]
        sc = np.einsum("bsd,btd->bst", qn[:, :, hh], kk) * scale
        if cap:
            sc = cap * np.tanh(sc / cap)
        sc = np.where(mk if mk.ndim == 3 else mk[None], sc, -1e30)
        w = np.exp(sc - sc.max(-1, keepdims=True))
        w = w / w.sum(-1, keepdims=True)
        out[:, :, hh] = np.einsum("bst,btd->bsd", w, vv)
    return out


@pytest.mark.parametrize("cap", [None, 30.0])
def test_sdpa_matches_reference(cap):
    q, k, v = _qkv()
    s = q.shape[1]
    mask = _mask(jnp.arange(s), jnp.arange(s), True, None, None)
    out = _sdpa(q, k, v, mask, 0.25, cap, None)
    ref = _ref_attention(q, k, v, mask, 0.25, cap)
    np.testing.assert_allclose(np.asarray(out), ref, atol=2e-5)


def test_flash_chunking_matches_vanilla():
    q, k, v = _qkv(s=64)
    mask = _mask(jnp.arange(64), jnp.arange(64), True, None, None)
    full = _sdpa(q, k, v, mask, 0.25, None, None)
    chunked = _sdpa(q, k, v, mask, 0.25, None, 16)
    np.testing.assert_allclose(
        np.asarray(full), np.asarray(chunked), atol=3e-5
    )


def test_sliding_window_mask():
    m = np.asarray(_mask(jnp.arange(8), jnp.arange(8), True, 3, None))
    # position 5 attends 3, 4, 5 only
    assert list(np.where(m[5])[0]) == [3, 4, 5]


def test_grouped_decode_matches_repeat_path():
    b, h, kv, d, s_cache = 2, 8, 2, 16, 24
    q = jnp.asarray(RNG.standard_normal((b, 1, h, d)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((b, s_cache, kv, d)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((b, s_cache, kv, d)).astype(np.float32))
    mask2 = jnp.ones((b, 1, s_cache), bool)
    out_g = _sdpa_grouped(q, k, v, mask2, 0.25, None)
    mask3 = jnp.ones((1, s_cache), bool)
    out_r = _sdpa(q, k, v, mask3, 0.25, None, None)
    np.testing.assert_allclose(
        np.asarray(out_g), np.asarray(out_r), atol=2e-5
    )


def test_qk_norm_changes_scores_boundedly():
    cfg = dataclasses.replace(
        get_config("qwen3-4b").reduced(), dtype="float32"
    )
    p = attn_init(jax.random.PRNGKey(0), cfg.d_model, cfg.n_heads,
                  cfg.n_kv_heads, cfg.resolved_head_dim, qk_norm=True)
    x = jnp.asarray(
        RNG.standard_normal((2, 8, cfg.d_model)).astype(np.float32)
    )
    out, (k, _) = attn_apply(p, x, cfg)
    assert bool(jnp.isfinite(out).all())
    # qk-norm bounds per-head key norms to ~sqrt(d)
    norms = jnp.linalg.norm(k, axis=-1)
    assert float(norms.max()) < 3 * math.sqrt(cfg.resolved_head_dim)
