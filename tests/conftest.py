import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Emulate an 8-device host platform for the whole suite (must be set
# before the first jax import anywhere in the test process): the
# sharded-serving suite (tests/test_serve_sharded.py) proves the
# stream-parallel server bit-identical to the single-device path on a
# real multi-device mesh without TPU hardware, and everything else
# simply runs on device 0 of the emulated platform. Guarded so a
# user-set count (e.g. XLA_FLAGS="--xla_force_host_platform_device_count=2"
# to reproduce a CI bench row) is never clobbered. Subprocess tests
# (launch/dryrun.py, test_sharding_dryrun.py) overwrite XLA_FLAGS
# themselves before importing jax, so inheriting this is harmless.
_COUNT_FLAG = "--xla_force_host_platform_device_count"
_existing = os.environ.get("XLA_FLAGS", "")
if _COUNT_FLAG not in _existing:
    os.environ["XLA_FLAGS"] = f"{_existing} {_COUNT_FLAG}=8".strip()
