"""Roofline HLO analyzer: trip-count scaling, collectives, window rules.

Also documents WHY the analyzer exists: cost_analysis counts while
bodies once (demonstrated below).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.roofline import (
    HARDWARE,
    _shape_numel_bytes,
    analyze_hlo,
)


def _compile_scan(n_steps=5, dim=64):
    def f(w, x):
        def body(x, wi):
            return jnp.tanh(x @ wi), None

        x, _ = jax.lax.scan(body, x, w)
        return x.sum()

    w = jax.ShapeDtypeStruct((n_steps, dim, dim), jnp.float32)
    x = jax.ShapeDtypeStruct((8, dim), jnp.float32)
    return jax.jit(f).lower(w, x).compile()


def test_cost_analysis_undercounts_scan_and_we_dont():
    n, dim = 5, 64
    compiled = _compile_scan(n, dim)
    per_step = 2 * 8 * dim * dim
    from repro.launch.roofline import cost_analysis_dict

    ca_flops = cost_analysis_dict(compiled).get("flops", 0)
    assert ca_flops < 2 * per_step  # body counted ~once
    ours = analyze_hlo(compiled.as_text()).flops
    assert abs(ours - n * per_step) / (n * per_step) < 0.01


def test_trip_count_scales_with_length():
    f5 = analyze_hlo(_compile_scan(5).as_text()).flops
    f10 = analyze_hlo(_compile_scan(10).as_text()).flops
    assert abs(f10 / f5 - 2.0) < 0.05


def test_shape_parsing():
    assert _shape_numel_bytes("bf16[8,64]{1,0}") == (512, 1024)
    assert _shape_numel_bytes("f32[2,3]") == (6, 24)
    n, b = _shape_numel_bytes("(s32[], f32[4]{0})")
    assert n == 5 and b == 20
    assert _shape_numel_bytes("pred[10]")[1] == 10


def test_collective_ring_model():
    hlo = """
HloModule test, is_scheduled=true

ENTRY %main (p: f32[1024]) -> f32[1024] {
  %p = f32[1024]{0} parameter(0)
  ROOT %ar = f32[1024]{0} all-reduce(%p), replica_groups=[2,4]<=[8], to_apply=%add
}
"""
    a = analyze_hlo(hlo)
    # all-reduce of 4096 B over groups of 4: 2*B*(n-1)/n = 6144
    assert abs(a.wire_bytes - 2 * 4096 * 3 / 4) < 1
    assert "all-reduce" in a.collective_breakdown


def test_dynamic_slice_window_not_full_operand():
    hlo = """
HloModule test, is_scheduled=true

ENTRY %main (p: f32[100,64]) -> f32[1,64] {
  %p = f32[100,64]{1,0} parameter(0)
  %c = s32[] constant(3)
  ROOT %ds = f32[1,64]{1,0} dynamic-slice(%p, %c, %c), dynamic_slice_sizes={1,64}
}
"""
    a = analyze_hlo(hlo)
    assert a.hbm_bytes == 2 * 64 * 4  # window, not 100x64


def test_hardware_constants_match_spec():
    assert HARDWARE.peak_flops == 197e12
    assert HARDWARE.hbm_bw == 819e9
    assert HARDWARE.ici_bw == 50e9
