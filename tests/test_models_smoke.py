"""Per-architecture smoke tests: reduced config, one forward + train
step on CPU, output shapes + no NaNs (assignment requirement), plus
decode-step mechanics and fp32 streaming equivalence where exact."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_archs
from repro.models.registry import get_backbone

# Full-architecture forward/backward smokes dominate suite wall-clock;
# `pytest -m "not slow"` keeps the pre-commit loop fast.
pytestmark = pytest.mark.slow

ARCHS = list_archs()


def _batch_for(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab // 2, (b, s)), jnp.int32)
    if cfg.frontend == "embedding":
        emb = jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model)).astype(np.float32)
        ).astype(cfg.activation_dtype)
        return {"embeddings": emb, "labels": toks}
    return {"tokens": toks, "labels": toks}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    backbone = get_backbone(cfg)
    params = backbone.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg)
    logits, _aux = backbone.forward(params, batch, cfg)
    assert logits.shape == (2, 16, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    loss, grads = jax.value_and_grad(
        lambda p: backbone.loss_fn(p, batch, cfg)
    )(params)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(
        float(jnp.sum(jnp.square(g.astype(jnp.float32))))
        for g in jax.tree.leaves(grads)
    )
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch).reduced()
    backbone = get_backbone(cfg)
    params = backbone.init_params(jax.random.PRNGKey(1), cfg)
    cache = backbone.init_cache(cfg, 2, 32)
    if cfg.frontend == "embedding":
        step = {"embeddings": jnp.zeros((2, 1, cfg.d_model), cfg.activation_dtype)}
    else:
        step = {"tokens": jnp.zeros((2, 1), jnp.int32)}
    logits, new_cache = backbone.decode_step(
        params, cache, jnp.int32(0), step, cfg
    )
    assert logits.shape == (2, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert jax.tree_util.tree_structure(cache) == jax.tree_util.tree_structure(new_cache)


@pytest.mark.parametrize(
    "arch", ["qwen3-4b", "gemma2-27b", "rwkv6-7b", "zamba2-7b"]
)
def test_streaming_equals_full_fp32(arch):
    """prefill(s[:n]) + decode(s[n]) == forward(s)[-1] in fp32."""
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    backbone = get_backbone(cfg)
    params = backbone.init_params(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, 100, (2, 33)), jnp.int32)
    full, _ = backbone.forward(params, {"tokens": toks}, cfg)
    _, cache = backbone.prefill(
        params, {"tokens": toks[:, :32]}, cfg, max_len=48
    )
    ld, _ = backbone.decode_step(
        params, cache, jnp.int32(32), {"tokens": toks[:, 32:33]}, cfg
    )
    from repro.models.layers import softcap

    ref = softcap(full[:, -1, :], cfg.final_softcap)
    np.testing.assert_allclose(
        np.asarray(ld), np.asarray(ref), rtol=1e-3, atol=2e-4
    )


def test_param_counts_match_analytic():
    """Analytic param_count (used for MODEL_FLOPS) tracks the real tree
    within the vocab-padding margin."""
    import math

    for arch in ["qwen3-4b", "granite-moe-3b-a800m", "rwkv6-7b"]:
        cfg = get_config(arch)
        backbone = get_backbone(cfg)
        shape = jax.eval_shape(
            lambda k, c=cfg, b=backbone: b.init_params(k, c),
            jax.random.PRNGKey(0),
        )
        real = sum(math.prod(l.shape) for l in jax.tree.leaves(shape))
        analytic = cfg.param_count()
        assert abs(real - analytic) / analytic < 0.1, (arch, real, analytic)


def test_assigned_dimensions_match_table():
    """The exact numbers from the assignment table."""
    t = get_config("musicgen-medium")
    assert (t.n_layers, t.d_model, t.n_heads, t.n_kv_heads, t.d_ff,
            t.vocab) == (48, 1536, 24, 24, 6144, 2048)
    t = get_config("qwen3-4b")
    assert (t.n_layers, t.d_model, t.n_heads, t.n_kv_heads, t.d_ff,
            t.vocab) == (36, 2560, 32, 8, 9728, 151936)
    assert t.qk_norm
    t = get_config("gemma2-27b")
    assert (t.n_layers, t.d_model, t.n_heads, t.n_kv_heads, t.d_ff,
            t.vocab) == (46, 4608, 32, 16, 36864, 256000)
    assert t.attn_softcap and t.final_softcap and t.sliding_window == 4096
    t = get_config("codeqwen1.5-7b")
    assert (t.n_layers, t.d_model, t.n_heads, t.n_kv_heads, t.d_ff,
            t.vocab) == (32, 4096, 32, 32, 13440, 92416)
    t = get_config("phi4-mini-3.8b")
    assert (t.n_layers, t.d_model, t.n_heads, t.n_kv_heads, t.d_ff,
            t.vocab) == (32, 3072, 24, 8, 8192, 200064)
    t = get_config("zamba2-7b")
    assert (t.n_layers, t.d_model, t.vocab, t.ssm.d_state) == (
        81, 3584, 32000, 64)
    t = get_config("llava-next-mistral-7b")
    assert (t.n_layers, t.d_model, t.n_heads, t.n_kv_heads, t.d_ff,
            t.vocab) == (32, 4096, 32, 8, 14336, 32000)
    t = get_config("rwkv6-7b")
    assert (t.n_layers, t.d_model, t.d_ff, t.vocab) == (
        32, 4096, 14336, 65536)
    t = get_config("kimi-k2-1t-a32b")
    assert (t.n_layers, t.d_model, t.n_heads, t.n_kv_heads,
            t.vocab) == (61, 7168, 64, 8, 163840)
    assert (t.moe.num_experts, t.moe.top_k, t.moe.d_expert) == (384, 8, 2048)
    assert abs(t.param_count() - 1.03e12) / 1.03e12 < 0.05  # ~1T
    t = get_config("granite-moe-3b-a800m")
    assert (t.n_layers, t.d_model, t.n_heads, t.n_kv_heads,
            t.vocab) == (32, 1536, 24, 8, 49155)
    assert (t.moe.num_experts, t.moe.top_k, t.moe.d_expert) == (40, 8, 512)


def test_long_500k_skip_list():
    """long_500k only runs for sub-quadratic / hybrid stacks."""
    runs = {
        a for a in ARCHS
        if "long_500k" not in get_config(a).skip_shapes
    }
    assert runs == {"rwkv6-7b", "zamba2-7b", "gemma2-27b"}
    # 33 dry-run cells total (DESIGN.md §4)
    n = sum(len(get_config(a).shapes()) for a in ARCHS)
    assert n == 33
