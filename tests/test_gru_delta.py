"""Temporal-sparsity ΔGRU backend: θ=0 bit-identity + telemetry suite.

The contract under test (repro.core.gru_delta): at θ=0 the delta engine
skips only exactly-unchanged components, its partial sums telescope to
the dense matmuls on the nose, and the "delta" backend is BIT-identical
(assert_array_equal, never allclose) to "qat" — and "delta-int" to
"integer" — for the full forward, the streaming step, the fused serving
tick, slab ingress, and the lax.scan replay. (The sharded multi-device
twin of these identities lives in tests/test_serve_sharded.py.) At
θ > 0 the skipped/total MAC counters must be monotone, bounded by the
offered work, exact in their totals, masked for idle streams, and reset
with the slot — the invariants `srv.sparsity` telemetry rests on.

Like the integer-identity suite, these tests are fast and run in the
`-m "not slow"` CI selection (and as an explicit first-class CI step).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import quant
from repro.core.classifier import available_classifiers, get_classifier
from repro.core.fex import fit_norm_stats
from repro.core.gru import (
    GRUConfig,
    gru_classifier_forward,
    gru_classifier_step,
    init_gru_classifier,
    init_states,
)
from repro.core.gru_delta import (
    DeltaConfig,
    delta_classifier_forward,
    delta_classifier_step,
    delta_eligible_macs_per_frame,
    delta_init_states,
    dense_fc_macs_per_frame,
    effective_mac_fraction,
    int_delta_classifier_forward,
    is_delta_states,
)
from repro.core.gru_int import (
    QuantizedClassifier,
    dequantize_acts,
    int_gru_classifier_forward,
    quantize_acts,
)
from repro.core.pipeline import KWSPipeline, KWSPipelineConfig
from repro.serving.quantize import quantize_classifier
from repro.serving.serve_loop import StreamingKWSServer

CFG = GRUConfig(quantized=True)
T0 = DeltaConfig().code_thresholds(CFG.num_layers)


def _params(seed=0):
    return init_gru_classifier(jax.random.PRNGKey(seed), CFG)


def _grid_fv(shape, seed=0, scale=4.0):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape) * scale
    return quant.fake_quant(x, quant.ACT_Q6_8)


# --------------------------------------------------------------------------
# registry + config mechanics
# --------------------------------------------------------------------------

def test_delta_backends_registered():
    assert "delta" in available_classifiers()
    assert "delta-int" in available_classifiers()
    assert get_classifier("delta").name == "delta"
    assert get_classifier("delta-int").name == "delta-int"


def test_delta_config_validation():
    with pytest.raises(ValueError, match=">= 0"):
        DeltaConfig(theta_x=-0.1)
    with pytest.raises(ValueError, match=">= 0"):
        DeltaConfig(per_layer=((0.1, 0.1), (-0.2, 0.0)))
    with pytest.raises(ValueError, match="entries"):
        DeltaConfig(per_layer=((0.1, 0.1),)).code_thresholds(2)
    # thresholds snap to the Q6.8 grid, per layer
    dc = DeltaConfig(per_layer=((0.25, 0.5), (0.0, 1.0)))
    assert dc.code_thresholds(2) == ((64, 128), (0, 256))
    assert DeltaConfig(theta_x=0.25).code_thresholds(2) == ((64, 0), (64, 0))


def test_pipeline_binds_delta_config():
    """KWSPipelineConfig(delta=...) reaches the backend instance; the
    registry singleton itself stays at θ=0."""
    dc = DeltaConfig(theta_x=0.25, theta_h=0.125)
    pipe = KWSPipeline(KWSPipelineConfig(classifier="delta", delta=dc))
    assert pipe.classifier.delta == dc
    assert get_classifier("delta").delta == DeltaConfig()
    # delta=None (the default) keeps the θ=0 singleton
    pipe0 = KWSPipeline(KWSPipelineConfig(classifier="delta"))
    assert pipe0.classifier is get_classifier("delta")
    # dense backends ignore the field entirely
    pq = KWSPipeline(KWSPipelineConfig(classifier="qat", delta=dc))
    assert pq.classifier is get_classifier("qat")


def test_prepare_params_shapes():
    params = _params()
    pd = KWSPipeline(KWSPipelineConfig(classifier="delta"))
    assert pd.prepare_params(params) is params  # float domain: untouched
    pdi = KWSPipeline(KWSPipelineConfig(classifier="delta-int"))
    q = pdi.prepare_params(params)
    assert isinstance(q, QuantizedClassifier)
    assert pdi.prepare_params(q) is q  # idempotent
    with pytest.raises(TypeError, match="prepare_params"):
        get_classifier("delta-int").forward(params, _grid_fv((1, 2, 16)), CFG)


# --------------------------------------------------------------------------
# θ=0 bit-identity: forward + streaming step
# --------------------------------------------------------------------------

def test_forward_theta0_bit_identical_to_qat():
    params = _params(0)
    fv = _grid_fv((3, 25, 16), seed=1)
    ref = gru_classifier_forward(params, fv, CFG)
    out = delta_classifier_forward(params, fv, CFG, T0)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


def test_forward_theta0_bit_identical_to_integer():
    params = _params(1)
    q = quantize_classifier(params, CFG)
    fv = _grid_fv((3, 25, 16), seed=2)
    ref = int_gru_classifier_forward(q, quantize_acts(fv), CFG)
    out = int_delta_classifier_forward(q, quantize_acts(fv), CFG, T0)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


def test_streaming_step_theta0_tracks_qat_states():
    params = _params(2)
    fv = _grid_fv((4, 15, 16), seed=3)
    sq = init_states(CFG, 4)
    sd = delta_init_states(CFG, 4)
    for t in range(fv.shape[1]):
        sq, lq = gru_classifier_step(params, sq, fv[:, t], CFG)
        sd, ld = delta_classifier_step(params, sd, fv[:, t], CFG, T0)
        np.testing.assert_array_equal(np.asarray(lq), np.asarray(ld))
        for hq, std in zip(sq, sd):
            np.testing.assert_array_equal(
                np.asarray(hq), np.asarray(std["h"])
            )


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.floats(min_value=0.25, max_value=16.0),
    t=st.integers(min_value=1, max_value=8),
)
def test_forward_theta0_identity_property(seed, scale, t):
    """Identity must hold for any on-grid input (magnitude and length
    swept), in both arithmetic domains."""
    params = _params(seed % 5)
    q = quantize_classifier(params, CFG)
    fv = quant.fake_quant(
        jax.random.normal(jax.random.PRNGKey(seed), (2, t, 16)) * scale,
        quant.ACT_Q6_8,
    )
    np.testing.assert_array_equal(
        np.asarray(gru_classifier_forward(params, fv, CFG)),
        np.asarray(delta_classifier_forward(params, fv, CFG, T0)),
    )
    np.testing.assert_array_equal(
        np.asarray(int_gru_classifier_forward(q, quantize_acts(fv), CFG)),
        np.asarray(
            int_delta_classifier_forward(q, quantize_acts(fv), CFG, T0)
        ),
    )


def test_pipeline_logits_and_predict_parity():
    audio = jnp.asarray(
        np.random.default_rng(4).standard_normal((3, 8192)).astype(
            np.float32
        ) * 0.05
    )
    boot = KWSPipeline(KWSPipelineConfig(use_norm=False))
    _, raw = boot.features(audio)
    stats = fit_norm_stats(quant.log_compress_lut(raw, 12, 10))
    pq = KWSPipeline(KWSPipelineConfig(classifier="qat"), norm_stats=stats)
    pd = KWSPipeline(KWSPipelineConfig(classifier="delta"), norm_stats=stats)
    params = pq.init_params(jax.random.PRNGKey(4))
    fv, _ = pq.features(audio)
    np.testing.assert_array_equal(
        np.asarray(pq.logits(params, fv)), np.asarray(pd.logits(params, fv))
    )
    np.testing.assert_array_equal(
        np.asarray(pq.predict(params, audio)),
        np.asarray(pd.predict(params, audio)),
    )


# --------------------------------------------------------------------------
# θ=0 bit-identity: the whole serving stack (single device; the sharded
# twin lives in tests/test_serve_sharded.py)
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def norm_stats():
    rng = np.random.default_rng(0)
    audio = jnp.asarray(
        rng.standard_normal((4, 16000)).astype(np.float32) * 0.05
    )
    boot = KWSPipeline(KWSPipelineConfig(use_norm=False))
    _, raw = boot.features(audio)
    return fit_norm_stats(quant.log_compress_lut(raw, 12, 10))


@pytest.fixture(scope="module")
def shared_params():
    return KWSPipeline(KWSPipelineConfig()).init_params(
        jax.random.PRNGKey(7)
    )


def _server(norm_stats, params, classifier, theta=0.0, max_streams=4,
            tick_impl="auto"):
    pipe = KWSPipeline(
        KWSPipelineConfig(
            classifier=classifier,
            delta=DeltaConfig(theta_x=theta, theta_h=theta),
        ),
        norm_stats=norm_stats,
    )
    return StreamingKWSServer(
        pipe, params, max_streams=max_streams, tick_impl=tick_impl
    )


@pytest.mark.parametrize(
    "delta_key,base_key", [("delta", "qat"), ("delta-int", "integer")]
)
def test_server_theta0_bit_identical(
    norm_stats, shared_params, delta_key, base_key
):
    """Fused tick (raw audio + FV slabs, partial masks) and the scan
    replay: the θ=0 delta server matches its dense base bit for bit."""
    sb = _server(norm_stats, shared_params, base_key)
    sd = _server(norm_stats, shared_params, delta_key)
    for s in (sb, sd):
        for sid in range(3):
            s.open_stream(sid)
    hop = sb.pipeline.chunk_samples
    rng = np.random.default_rng(8)
    for t in range(3):  # live raw-audio ticks, rotating partial masks
        slab = rng.standard_normal((4, hop)).astype(np.float32) * 0.05
        mask = np.zeros(4, bool)
        mask[:3] = True
        mask[t % 3] = False
        s_a, t_a = sb.step_batch(slab, mask)
        s_b, t_b = sd.step_batch(slab, mask)
        np.testing.assert_array_equal(s_a, s_b)
        np.testing.assert_array_equal(t_a, t_b)
    # FV_Norm ticks must sit on the Q6.8 grid (the documented input
    # contract — cross-backend identity only holds for grid frames,
    # exactly as in the integer/QAT suite)
    fv = np.asarray(
        quant.fake_quant(
            jnp.asarray(rng.standard_normal((4, 16)).astype(np.float32)),
            quant.ACT_Q6_8,
        )
    )
    s_a, _ = sb.step_batch(fv, np.ones(4, bool))
    s_b, _ = sd.step_batch(fv, np.ones(4, bool))
    np.testing.assert_array_equal(s_a, s_b)
    # scan replay
    slab = rng.standard_normal((5, 4, hop)).astype(np.float32) * 0.05
    mask = rng.random((5, 4)) < 0.7
    seq_a, tops_a = sb.run_batch(slab, mask)
    seq_b, tops_b = sd.run_batch(slab, mask)
    np.testing.assert_array_equal(seq_a, seq_b)
    np.testing.assert_array_equal(tops_a, tops_b)
    # the delta server's hidden state tracks the dense server's exactly
    for hb, std in zip(sb.state.gru, sd.state.gru):
        np.testing.assert_array_equal(
            np.asarray(hb), np.asarray(std["h"])
        )


def test_theta_gt0_cross_domain_equality(norm_stats, shared_params):
    """At θ>0 the float- and code-domain ΔGRU engines fire identically
    and produce bit-identical posteriors (same grid arithmetic)."""
    sd = _server(norm_stats, shared_params, "delta", theta=0.25)
    si = _server(norm_stats, shared_params, "delta-int", theta=0.25)
    for s in (sd, si):
        s.open_stream(0)
    hop = sd.pipeline.chunk_samples
    rng = np.random.default_rng(9)
    for _ in range(5):
        f = rng.standard_normal(hop).astype(np.float32) * 0.05
        od = sd.step({0: f})
        oi = si.step({0: f})
        np.testing.assert_array_equal(od[0]["probs"], oi[0]["probs"])
    np.testing.assert_array_equal(sd.sparsity, si.sparsity)
    assert sd.sparsity[sd.active[0]] < 1.0


@pytest.mark.parametrize(
    "delta_key,base_key", [("delta", "qat"), ("delta-int", "integer")]
)
def test_server_theta0_bit_identical_fused_tick(
    norm_stats, shared_params, delta_key, base_key
):
    """The megakernel tick (gather-compacted Δ·W, interpret tier) keeps
    the θ=0 telescoping guarantee: fused-interpret delta == xla dense
    base, cross-backend AND cross-implementation."""
    sb = _server(norm_stats, shared_params, base_key, tick_impl="xla")
    sd = _server(
        norm_stats, shared_params, delta_key, tick_impl="fused-interpret"
    )
    for s in (sb, sd):
        for sid in range(3):
            s.open_stream(sid)
    hop = sb.pipeline.chunk_samples
    rng = np.random.default_rng(8)
    for t in range(3):
        slab = rng.standard_normal((4, hop)).astype(np.float32) * 0.05
        mask = np.zeros(4, bool)
        mask[:3] = True
        mask[t % 3] = False
        s_a, t_a = sb.step_batch(slab, mask)
        s_b, t_b = sd.step_batch(slab, mask)
        np.testing.assert_array_equal(s_a, s_b)
        np.testing.assert_array_equal(t_a, t_b)
    for hb, std in zip(sb.state.gru, sd.state.gru):
        np.testing.assert_array_equal(np.asarray(hb), np.asarray(std["h"]))


# --------------------------------------------------------------------------
# θ>0: MAC counters + sparsity telemetry invariants
# --------------------------------------------------------------------------

def _counters(srv):
    sk = np.stack([np.asarray(st["skipped"]) for st in srv.state.gru])
    to = np.stack([np.asarray(st["total"]) for st in srv.state.gru])
    return sk, to


def test_counters_monotone_and_bounded(norm_stats, shared_params):
    srv = _server(norm_stats, shared_params, "delta", theta=0.25)
    srv.open_stream(0)
    srv.open_stream(1)
    hop = srv.pipeline.chunk_samples
    rng = np.random.default_rng(10)
    prev_sk = prev_to = None
    # counters tick in weight-column units: a layer offers I+H columns
    # per frame (each worth 3H MACs — effective_mac_fraction converts)
    per_step = [
        i + CFG.hidden_dim for i in (CFG.input_dim, CFG.hidden_dim)
    ]
    for t in range(6):
        srv.step({
            sid: rng.standard_normal(hop).astype(np.float32) * 0.05
            for sid in (0, 1)
        })
        sk, to = _counters(srv)
        assert (sk >= 0).all() and (sk <= to).all()
        if prev_sk is not None:  # monotone, never decreasing
            assert (sk >= prev_sk).all() and (to >= prev_to).all()
        # totals are exact: (t+1) steps of the full offered work per
        # open slot, zero elsewhere
        for layer, per in enumerate(per_step):
            for sid in (0, 1):
                assert to[layer, srv.active[sid]] == (t + 1) * per
        prev_sk, prev_to = sk, to
    frac = srv.sparsity
    assert frac.shape == (4,) and ((frac >= 0) & (frac <= 1)).all()
    assert is_delta_states(list(srv.state.gru))


def test_repeated_frame_is_skipped(norm_stats, shared_params):
    """Submitting the same FV frame twice: the second tick's input
    deltas are all zero, so the input-side counters must record a full
    skip (the DeltaKWS steady-state win)."""
    srv = _server(norm_stats, shared_params, "delta", theta=0.0)
    srv.open_stream(0)
    fv = np.asarray(_grid_fv((16,), seed=11, scale=2.0))
    srv.step({0: fv})
    sk1, _ = _counters(srv)
    srv.step({0: fv})
    sk2, to2 = _counters(srv)
    slot = srv.active[0]
    # layer 0 skipped at least the whole input matmul on tick 2 (all
    # input_dim weight columns)
    assert sk2[0, slot] - sk1[0, slot] >= CFG.input_dim
    assert srv.sparsity[slot] < 1.0


def test_counters_idle_isolation_and_reset(norm_stats, shared_params):
    """Idle streams' counters are untouched by other streams' ticks;
    open_stream hands out zeroed counters (sparsity telemetry resets
    with the slot)."""
    srv = _server(norm_stats, shared_params, "delta", theta=0.25)
    srv.open_stream(0)
    srv.open_stream(1)
    hop = srv.pipeline.chunk_samples
    rng = np.random.default_rng(12)
    srv.step({
        sid: rng.standard_normal(hop).astype(np.float32) * 0.05
        for sid in (0, 1)
    })
    slot1 = srv.active[1]
    sk_before, to_before = _counters(srv)
    for _ in range(3):  # stream 1 idles
        srv.step({0: rng.standard_normal(hop).astype(np.float32) * 0.05})
    sk_after, to_after = _counters(srv)
    np.testing.assert_array_equal(sk_before[:, slot1], sk_after[:, slot1])
    np.testing.assert_array_equal(to_before[:, slot1], to_after[:, slot1])
    # close + reopen: the reused slot's telemetry starts fresh
    frac_open = srv.sparsity[slot1]
    srv.close_stream(1)
    srv.open_stream(99)
    assert srv.active[99] == slot1
    sk, to = _counters(srv)
    assert (sk[:, slot1] == 0).all() and (to[:, slot1] == 0).all()
    assert srv.sparsity[slot1] == 1.0
    del frac_open


def test_dense_backends_report_unity_sparsity(norm_stats, shared_params):
    srv = _server(norm_stats, shared_params, "qat")
    np.testing.assert_array_equal(
        srv.sparsity, np.ones(srv.max_streams, np.float32)
    )


def test_effective_mac_fraction_accounting():
    """The fraction folds the always-dense FC back in: a stream that
    skipped every eligible MAC still pays the FC head."""
    states = delta_init_states(CFG, 2)
    per = delta_eligible_macs_per_frame(CFG)
    fc = dense_fc_macs_per_frame(CFG)
    h = CFG.hidden_dim
    per_layer_cols = [CFG.input_dim + h, h + h]  # counter units: columns
    assert sum(3 * h * c for c in per_layer_cols) == per
    # stream 0: one frame, every eligible column skipped; stream 1: no
    # traffic at all
    for st_l, cols in zip(states, per_layer_cols):
        st_l["total"] = jnp.asarray([cols, 0], jnp.int32)
        st_l["skipped"] = jnp.asarray([cols, 0], jnp.int32)
    frac = np.asarray(effective_mac_fraction(states, CFG))
    np.testing.assert_allclose(frac[0], fc / (per + fc), rtol=1e-6)
    assert frac[1] == 1.0


# --------------------------------------------------------------------------
# property test: random lifecycle schedules, delta(θ=0) vs qat
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def schedule_servers(norm_stats, shared_params):
    """(delta θ=0, qat) servers on shared params — module-scoped so
    hypothesis examples reuse the compiled tick programs (the PR 4
    lifecycle-oracle harness, pointed at the ΔGRU backend)."""
    sd = _server(norm_stats, shared_params, "delta", max_streams=8)
    sq = _server(norm_stats, shared_params, "qat", max_streams=8)
    return sd, sq


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    events=st.lists(
        st.tuples(
            st.booleans(),  # open a new stream before this tick?
            st.booleans(),  # close the oldest open stream first?
            st.integers(min_value=0, max_value=255),  # submit bitmask
        ),
        min_size=2,
        max_size=6,
    ),
)
def test_random_schedule_delta_matches_qat(schedule_servers, seed, events):
    """Random open/close/submit schedules: the θ=0 delta server's
    per-stream posteriors bit-match the qat server's at every tick —
    lifecycle hygiene (slot reuse, idle masking) included."""
    sd, sq = schedule_servers
    for srv in (sd, sq):
        for sid in list(srv.active):
            srv.close_stream(sid)
    rng = np.random.default_rng(seed)
    next_sid = 0

    def do_open():
        nonlocal next_sid
        sd.open_stream(next_sid)
        sq.open_stream(next_sid)
        next_sid += 1

    do_open()
    for want_open, want_close, submit_bits in events:
        if want_close and len(sd.active) > 1:
            victim = min(sd.active)
            sd.close_stream(victim)
            sq.close_stream(victim)
        if want_open and len(sd.active) < sd.max_streams:
            do_open()
        frames = {}
        for i, sid in enumerate(sorted(sd.active)):
            if submit_bits >> (i % 8) & 1:
                # on the Q6.8 grid — the FV_Norm input contract
                frames[sid] = np.asarray(
                    quant.fake_quant(
                        jnp.asarray(
                            rng.standard_normal(16).astype(np.float32)
                        ),
                        quant.ACT_Q6_8,
                    )
                )
        out_d = sd.step(dict(frames))
        out_q = sq.step(dict(frames))
        for sid in frames:
            np.testing.assert_array_equal(
                out_d[sid]["probs"], out_q[sid]["probs"]
            )
            assert out_d[sid]["top"] == out_q[sid]["top"]
    np.testing.assert_array_equal(sd.scores, sq.scores)
