"""Software-model FEx: shapes, stage invariants (hypothesis), ablation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import quant
from repro.core.fex import (
    FExConfig,
    FExNormStats,
    biquad_filterbank,
    fex_forward,
    fex_frames,
    fit_norm_stats,
    frame_average,
    full_wave_rectify,
    oversample2x,
)

CFG = FExConfig()


def test_frame_math():
    assert CFG.fs_internal == 32000.0
    assert CFG.frame_len == 512  # 16 ms @ 32 kHz


def test_oversample2x_shape_and_interp():
    x = jnp.asarray([[0.0, 1.0, 0.0, -1.0]])
    y = oversample2x(x)
    assert y.shape == (1, 8)
    np.testing.assert_allclose(y[0, :4], [0.0, 0.5, 1.0, 0.5], atol=1e-6)


def test_fex_frames_shape():
    audio = jnp.zeros((3, 16000))
    fr = fex_frames(audio, CFG)
    assert fr.shape == (3, 62, 16)  # 1 s -> 62 full 16 ms frames


def test_sine_selects_matching_channel():
    coeffs = CFG.filterbank()
    f0 = np.asarray(coeffs.f0)
    t = np.arange(16000) / 16000.0
    audio = jnp.asarray(0.2 * np.sin(2 * np.pi * f0[5] * t), jnp.float32)
    fr = np.asarray(fex_frames(audio[None], CFG))[0, 10:]  # settled
    assert fr.mean(0).argmax() == 5


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_rectified_frames_nonnegative(seed):
    rng = np.random.default_rng(seed)
    audio = jnp.asarray(
        rng.standard_normal((1, 2048)).astype(np.float32) * 0.3
    )
    fr = fex_frames(audio, CFG)
    assert bool((fr >= 0).all())


@settings(max_examples=20, deadline=None)
@given(st.floats(0.01, 0.5))
def test_frame_average_bounded_by_peak(amp):
    audio = jnp.full((1, 2048), float(amp), jnp.float32)
    y = biquad_filterbank(oversample2x(audio), CFG.filterbank())
    fr = frame_average(full_wave_rectify(y), CFG.frame_len)
    assert float(fr.max()) <= float(jnp.abs(y).max()) + 1e-6


def test_quantizer_monotone_and_range():
    x = jnp.linspace(0, 1.0, 100)
    q = quant.quantize_unsigned(x, 12, 0.7)
    assert bool((jnp.diff(q) >= 0).all())
    assert float(q.min()) == 0.0 and float(q.max()) == 4095.0


def test_log_compress_monotone_10bit():
    codes = jnp.arange(4096.0)
    out = quant.log_compress_lut(codes, 12, 10)
    assert bool((jnp.diff(out) >= 0).all())
    assert float(out.min()) == 0.0 and float(out.max()) == 1023.0
    lut = quant.make_log_lut(12, 10)
    np.testing.assert_allclose(out, lut, atol=0)


def test_ablation_paths_differ_and_norm_is_zero_mean():
    rng = np.random.default_rng(0)
    audio = jnp.asarray(
        rng.standard_normal((4, 16000)).astype(np.float32) * 0.05
    )
    frames = fex_frames(audio, CFG)
    fv_raw = quant.quantize_unsigned(frames, 12, CFG.quant_full_scale)
    fv_log = quant.log_compress_lut(fv_raw, 12, 10)
    stats = fit_norm_stats(fv_log)
    base, _ = fex_forward(audio, CFG, use_log=False, use_norm=False)
    logd, _ = fex_forward(audio, CFG, use_log=True, use_norm=False)
    norm, _ = fex_forward(
        audio, CFG, norm_stats=stats, use_log=True, use_norm=True
    )
    assert not np.allclose(base, logd)
    assert not np.allclose(logd, norm)
    # normalized features ~zero mean unit-ish variance per channel
    m = np.asarray(norm).reshape(-1, 16).mean(0)
    assert np.abs(m).max() < 0.5
    # all within the Q6.8 representable range
    assert float(jnp.abs(norm).max()) <= quant.ACT_Q6_8.max_value


def test_fex_is_differentiable():
    audio = jnp.ones((1, 4096)) * 0.1
    stats = FExNormStats(mu=jnp.full((16,), 100.0), sigma=jnp.full((16,), 50.0))

    def loss(a):
        fv, _ = fex_forward(a, CFG, stats)
        return jnp.sum(fv**2)

    g = jax.grad(loss)(audio)
    assert bool(jnp.isfinite(g).all())
    assert float(jnp.abs(g).max()) > 0
