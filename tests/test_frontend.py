"""FeatureFrontend registry: three-way parity, streaming, serving e2e.

The paper's claim is that the time-domain FEx is a drop-in replacement
for a voltage-domain FEx: with mismatch and noise off and nominal
beta/alpha calibration, all registered frontends must produce the same
FV_Raw codes up to quantization granularity (the TDC counts in ~0.2-LSB
steps, the software quantizer in 1-LSB steps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.core.fex import fit_norm_stats
from repro.core.frontend import (
    FrontendState,
    available_frontends,
    get_frontend,
)
from repro.core.pipeline import KWSPipeline, KWSPipelineConfig
from repro.core.tdfex import TDFExConfig
from repro.serving.serve_loop import StreamingKWSServer

ALL_FRONTENDS = ("software", "hardware", "hardware-pallas")


def _audio(batch=3, samples=4096, seed=0, amp=0.05):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.standard_normal((batch, samples)).astype(np.float32) * amp
    )


def _nominal_state(tdcfg: TDFExConfig) -> FrontendState:
    """Ideal calibration: nominal beta, unity alpha, no mismatch draw."""
    c = tdcfg.fex.num_channels
    return FrontendState(
        beta=jnp.full((c,), tdcfg.beta_nominal, jnp.float32),
        alpha=jnp.ones((c,), jnp.float32),
    )


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

def test_registry_contains_all_paths():
    assert set(ALL_FRONTENDS) <= set(available_frontends())
    for name in ALL_FRONTENDS:
        assert get_frontend(name).name == name


def test_unknown_frontend_raises_with_listing():
    with pytest.raises(KeyError) as err:
        get_frontend("does-not-exist")
    msg = str(err.value)
    for name in ALL_FRONTENDS:
        assert name in msg


def test_pipeline_rejects_unknown_frontend():
    with pytest.raises(KeyError) as err:
        KWSPipeline(KWSPipelineConfig(frontend="nope"))
    assert "software" in str(err.value)


# --------------------------------------------------------------------------
# three-way FV_Raw parity (mismatch off, noise off, nominal calibration)
# --------------------------------------------------------------------------

def test_three_way_raw_code_parity():
    audio = _audio()
    state = _nominal_state(TDFExConfig())
    raws = {}
    for name in ALL_FRONTENDS:
        pipe = KWSPipeline(
            KWSPipelineConfig(frontend=name, use_norm=False)
        )
        fv, raw = pipe.features(audio, state)
        assert raw.shape == fv.shape
        raws[name] = np.asarray(raw)
    # software vs hardware sim: same signal chain up to TDC counting
    d_hw = np.abs(raws["hardware"] - raws["software"])
    assert d_hw.max() <= 2.0, f"hw vs sw max diff {d_hw.max()} LSB"
    # hardware sim vs the Pallas TDC kernel (interpret mode on CPU for
    # this batch shape): identical math, fractional-carry formulation
    d_pl = np.abs(raws["hardware-pallas"] - raws["hardware"])
    assert d_pl.max() <= 2.0, f"pallas vs hw max diff {d_pl.max()} LSB"


def test_one_call_site_for_all_frontends():
    """The acceptance-criterion shape: one loop, one call signature."""
    audio = _audio(batch=2, samples=2048)
    for name in available_frontends():
        pipe = KWSPipeline(
            KWSPipelineConfig(frontend=name, use_norm=False)
        )
        state = pipe.init_frontend_state(mismatch=False)
        fv, raw = pipe.features(audio, state)
        assert fv.shape == raw.shape == (2, 8, 16)


def test_hardware_state_calibration_fields():
    pipe = KWSPipeline(KWSPipelineConfig(frontend="hardware"))
    state = pipe.init_frontend_state(jax.random.PRNGKey(0))
    assert state.chip is not None  # mismatch drawn by default with a key
    assert state.beta.shape == (16,) and state.alpha.shape == (16,)
    assert state.coeffs.shape == (5, 16)
    # mismatch off -> ideal chip, but calibration still measured
    ideal = pipe.init_frontend_state(mismatch=False)
    assert ideal.chip is None
    np.testing.assert_allclose(
        np.asarray(ideal.alpha).mean(), 1.0, rtol=1e-5
    )


# --------------------------------------------------------------------------
# streaming features
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["software", "hardware"])
def test_streaming_features_match_batch(name):
    audio = _audio()
    cfg = KWSPipelineConfig(frontend=name, use_norm=False)
    pipe = KWSPipeline(cfg, state=_nominal_state(cfg.tdfex_config))
    _, raw_batch = pipe.features(audio)

    fe = pipe.frontend
    carry = pipe.streaming_features_init(audio.shape[0])
    hop = pipe.chunk_samples
    frames = []
    for t in range(audio.shape[1] // hop):
        carry, codes = fe.streaming_step(
            audio[:, t * hop : (t + 1) * hop], cfg, pipe.state, carry
        )
        frames.append(np.asarray(codes))
    raw_stream = np.stack(frames, axis=1)
    assert raw_stream.shape == raw_batch.shape
    # chunk-edge oversampler approximation + TDC count granularity
    d = np.abs(raw_stream - np.asarray(raw_batch))
    assert d.max() <= 2.0, f"streaming vs batch max diff {d.max()} LSB"


def test_streaming_features_step_normalized_output():
    audio = _audio()
    boot = KWSPipeline(KWSPipelineConfig(use_norm=False))
    _, raw = boot.features(audio)
    stats = fit_norm_stats(quant.log_compress_lut(raw, 12, 10))
    pipe = KWSPipeline(KWSPipelineConfig(), norm_stats=stats)
    fv_batch, _ = pipe.features(audio)
    carry = pipe.streaming_features_init(audio.shape[0])
    hop = pipe.chunk_samples
    outs = []
    for t in range(audio.shape[1] // hop):
        carry, fv = pipe.streaming_features_step(
            carry, audio[:, t * hop : (t + 1) * hop]
        )
        outs.append(np.asarray(fv))
    stream = np.stack(outs, axis=1)
    # 1-LSB raw-code differences map through log LUT + 1/sigma
    np.testing.assert_allclose(
        stream, np.asarray(fv_batch), atol=0.5
    )


# --------------------------------------------------------------------------
# serving e2e: raw audio in, posteriors out
# --------------------------------------------------------------------------

def _server(frontend="software", max_streams=4):
    audio = _audio(batch=2, samples=16000, seed=5)
    boot = KWSPipeline(KWSPipelineConfig(use_norm=False))
    _, raw = boot.features(audio)
    stats = fit_norm_stats(quant.log_compress_lut(raw, 12, 10))
    cfg = KWSPipelineConfig(frontend=frontend)
    pipe = KWSPipeline(
        cfg, state=_nominal_state(cfg.tdfex_config).with_norm_stats(stats)
    )
    params = pipe.init_params(jax.random.PRNGKey(0))
    return pipe, StreamingKWSServer(pipe, params, max_streams=max_streams)


@pytest.mark.parametrize("frontend", ["software", "hardware"])
def test_server_accepts_raw_audio_chunks(frontend):
    pipe, srv = _server(frontend)
    srv.open_stream(7)
    srv.open_stream(9)
    hop = pipe.chunk_samples
    rng = np.random.default_rng(0)
    for _ in range(4):
        chunks = {
            7: rng.standard_normal(hop).astype(np.float32) * 0.05,
            9: rng.standard_normal(hop).astype(np.float32) * 0.05,
        }
        out = srv.step(chunks)
    assert set(out) == {7, 9}
    for r in out.values():
        assert r["probs"].shape == (pipe.config.gru.num_classes,)
        np.testing.assert_allclose(
            r["probs"].sum(), 1.0 - srv.smoothing**4, atol=1e-5
        )


def test_server_carry_only_advances_for_submitting_streams():
    """A stream that skips a raw-audio tick must resume from its own
    contiguous filter/SRO carry, not one advanced over fabricated
    silence."""
    pipe, srv = _server()
    srv.open_stream(1)
    srv.open_stream(2)
    hop = pipe.chunk_samples
    rng = np.random.default_rng(3)
    chunk = rng.standard_normal(hop).astype(np.float32) * 0.05
    srv.step({1: chunk, 2: chunk})
    before = jax.tree_util.tree_map(
        lambda t: np.asarray(t[srv.active[2]]), srv.feat_carry
    )
    srv.step({1: chunk})  # stream 2 skips this tick
    after = jax.tree_util.tree_map(
        lambda t: np.asarray(t[srv.active[2]]), srv.feat_carry
    )
    jax.tree_util.tree_map(np.testing.assert_array_equal, before, after)


def test_server_rejects_wrong_length_input():
    pipe, srv = _server()
    srv.open_stream(1)
    with pytest.raises(ValueError, match="FV_Norm frame"):
        srv.step({1: np.zeros(100, np.float32)})


def test_server_still_accepts_fv_frames():
    pipe, srv = _server()
    srv.open_stream(1)
    out = srv.step({1: np.ones(16, np.float32)})
    assert set(out) == {1}


def test_server_audio_matches_offline_features():
    """An audio-fed server equals a feature-fed server whose FV_Norm
    frames came from the batch `features` path, within the documented
    streaming tolerance."""
    pipe, srv_audio = _server()
    _, srv_fv = _server()
    srv_fv.params = srv_audio.params  # identical weights
    audio = _audio(batch=1, samples=2048, seed=11)
    fv_batch = np.asarray(pipe.features(audio)[0])
    srv_audio.open_stream(0)
    srv_fv.open_stream(0)
    hop = pipe.chunk_samples
    for t in range(audio.shape[1] // hop):
        out_a = srv_audio.step(
            {0: np.asarray(audio[0, t * hop : (t + 1) * hop])}
        )
        out_f = srv_fv.step({0: fv_batch[0, t]})
    np.testing.assert_allclose(
        out_a[0]["probs"], out_f[0]["probs"], atol=0.02
    )
