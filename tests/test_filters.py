"""Filter design: biquad vs scipy oracle, Mel spacing, Q factor."""

import numpy as np
import pytest
from scipy import signal as sps

from repro.core.filters import (
    biquad_frequency_response,
    design_bandpass_biquad,
    design_filterbank,
    hz_to_mel,
    mel_center_frequencies,
    mel_to_hz,
)


def test_mel_roundtrip():
    f = np.array([100.0, 440.0, 1000.0, 8000.0])
    np.testing.assert_allclose(mel_to_hz(hz_to_mel(f)), f, rtol=1e-10)


def test_mel_spacing_endpoints_and_monotone():
    f0 = mel_center_frequencies(16, 100.0, 8000.0)
    assert abs(f0[0] - 100.0) < 1e-6 and abs(f0[-1] - 8000.0) < 1e-3
    assert np.all(np.diff(f0) > 0)
    # Mel spacing: low-frequency channels closer together in log terms
    # -> linear spacing increases with frequency (paper Fig. 17)
    assert np.diff(f0)[-1] > np.diff(f0)[0]


def test_biquad_matches_scipy_butter_bandpass():
    """Our bilinear BPF response matches scipy butter(1, band,
    'bandpass') (same 2nd-order Butterworth band-pass; the two designs
    pre-warp center vs edges, so responses agree to ~1% in-band)."""
    fs, f0, q = 32000.0, 1000.0, 2.0
    c = design_bandpass_biquad(f0, fs, q)
    bw = f0 / q
    lo = f0 * (np.sqrt(1 + 1 / (4 * q * q)) - 1 / (2 * q))
    hi = lo + bw
    b_ref, a_ref = sps.butter(1, [lo, hi], btype="bandpass", fs=fs)
    freqs = np.linspace(200, 4000, 200)
    _, h_ref = sps.freqz(b_ref, a_ref, worN=freqs, fs=fs)
    h_ours = biquad_frequency_response(c, freqs)[0]
    np.testing.assert_allclose(h_ours, np.abs(h_ref), rtol=0.02, atol=5e-3)


def test_unity_peak_gain_at_center():
    coeffs = design_filterbank(16, 32000.0)
    mags = biquad_frequency_response(coeffs, coeffs.f0)
    np.testing.assert_allclose(np.diagonal(mags), 1.0, rtol=1e-6)


def test_q_factor_bandwidth():
    fs, f0, q = 32000.0, 1000.0, 2.0
    c = design_bandpass_biquad(f0, fs, q)
    freqs = np.linspace(100, 4000, 20000)
    mag = biquad_frequency_response(c, freqs)[0]
    above = freqs[mag >= 1 / np.sqrt(2)]
    bw = above.max() - above.min()
    assert abs(bw - f0 / q) / (f0 / q) < 0.05  # within 5% (pre-warp)


def test_stability_all_channels():
    coeffs = design_filterbank(16, 32000.0)
    for i in range(16):
        poles = np.roots([1.0, coeffs.a1[i], coeffs.a2[i]])
        assert np.all(np.abs(poles) < 1.0)


def test_rejects_out_of_range_center():
    with pytest.raises(ValueError):
        design_bandpass_biquad(20000.0, 32000.0, 2.0)
