"""KWS pipeline end-to-end (tiny) + streaming server mechanics."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fex import fit_norm_stats
from repro.core import quant
from repro.core.pipeline import KWSPipeline, KWSPipelineConfig
from repro.serving.serve_loop import StreamingKWSServer


def _pipeline_with_stats(audio):
    # bootstrap pass (no normalizer) records FV_Raw to fit mu/sigma,
    # mirroring the chip's recording flow (Section III-F)
    boot = KWSPipeline(KWSPipelineConfig(use_norm=False))
    _, fv_raw = boot.features_software(audio)
    fv_log = quant.log_compress_lut(fv_raw, 12, 10)
    stats = fit_norm_stats(fv_log)
    return KWSPipeline(KWSPipelineConfig(), norm_stats=stats)


def test_pipeline_features_and_logits_shapes():
    rng = np.random.default_rng(0)
    audio = jnp.asarray(rng.standard_normal((4, 16000)).astype(np.float32) * 0.05)
    pipe = _pipeline_with_stats(audio)
    fv, raw = pipe.features_software(audio)
    assert fv.shape == (4, 62, 16) and raw.shape == (4, 62, 16)
    params = pipe.init_params(jax.random.PRNGKey(0))
    logits = pipe.logits(params, fv)
    assert logits.shape == (4, 12)


def test_streaming_matches_batch_inference():
    rng = np.random.default_rng(1)
    audio = jnp.asarray(rng.standard_normal((2, 16000)).astype(np.float32) * 0.05)
    pipe = _pipeline_with_stats(audio)
    params = pipe.init_params(jax.random.PRNGKey(1))
    fv, _ = pipe.features_software(audio)
    batch_logits = pipe.logits(params, fv)
    states = pipe.streaming_init(2)
    for t in range(fv.shape[1]):
        states, logits = pipe.streaming_step(params, states, fv[:, t])
    np.testing.assert_allclose(
        np.asarray(batch_logits), np.asarray(logits), atol=1e-5
    )


def test_streaming_server_lifecycle():
    rng = np.random.default_rng(2)
    audio = jnp.asarray(rng.standard_normal((2, 16000)).astype(np.float32) * 0.05)
    pipe = _pipeline_with_stats(audio)
    params = pipe.init_params(jax.random.PRNGKey(2))
    srv = StreamingKWSServer(pipe, params, max_streams=4)
    srv.open_stream(101)
    srv.open_stream(202)
    out = srv.step({101: np.ones(16, np.float32),
                    202: np.zeros(16, np.float32)})
    assert set(out) == {101, 202}
    assert abs(out[101]["probs"].sum() - (1 - srv.smoothing)) < 1e-5
    srv.close_stream(101)
    out = srv.step({202: np.ones(16, np.float32)})
    assert set(out) == {202}
    # slot reuse
    srv.open_stream(303)
    assert len(srv.active) == 2


def test_server_capacity():
    rng = np.random.default_rng(3)
    audio = jnp.asarray(rng.standard_normal((1, 16000)).astype(np.float32) * 0.05)
    pipe = _pipeline_with_stats(audio)
    params = pipe.init_params(jax.random.PRNGKey(3))
    srv = StreamingKWSServer(pipe, params, max_streams=2)
    srv.open_stream(1)
    srv.open_stream(2)
    import pytest

    with pytest.raises(RuntimeError):
        srv.open_stream(3)
