"""KWS pipeline end-to-end + the fused streaming serving stack.

Covers the serving hardening sweep: idle-stream state isolation (the
temporal-sparsity contract — a stream that skips a tick must be
bit-identical across it), slot-reuse hygiene on close/reopen, empty and
mixed-kind ticks, pre-batched slab ticks (`step_batch`), the lax.scan
offline replay driver (`run` / `run_batch`), three-way streaming-vs-
batch feature parity, and a TDC dispatch-mode parity sweep (deterministic
+ property-based via hypothesis when installed).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fex import fit_norm_stats
from repro.core import quant
from repro.core.frontend import hardware_state
from repro.core.pipeline import KWSPipeline, KWSPipelineConfig
from repro.core.tdfex import TDFExConfig
from repro.kernels.tdc import tdc_counts
from repro.serving.serve_loop import ServerState, StreamingKWSServer

from _hypothesis_compat import given, settings, st


def _pipeline_with_stats(audio):
    # bootstrap pass (no normalizer) records FV_Raw to fit mu/sigma,
    # mirroring the chip's recording flow (Section III-F)
    boot = KWSPipeline(KWSPipelineConfig(use_norm=False))
    _, fv_raw = boot.features(audio)
    fv_log = quant.log_compress_lut(fv_raw, 12, 10)
    stats = fit_norm_stats(fv_log)
    return KWSPipeline(KWSPipelineConfig(), norm_stats=stats)


def _audio(batch=2, samples=16000, seed=0, scale=0.05):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.standard_normal((batch, samples)).astype(np.float32) * scale
    )


def _server(max_streams=4, seed=0):
    pipe = _pipeline_with_stats(_audio(seed=seed))
    params = pipe.init_params(jax.random.PRNGKey(seed))
    return pipe, StreamingKWSServer(pipe, params, max_streams=max_streams)


def _slot_state(srv, sid):
    """One stream's slice of every ServerState buffer, as host arrays."""
    slot = srv.active[sid]
    return jax.tree_util.tree_map(
        lambda t: np.asarray(t[slot]).copy(), srv.state
    )


def _hops(pipe, n, seed=0):
    rng = np.random.default_rng(seed)
    hop = pipe.chunk_samples
    return [
        rng.standard_normal(hop).astype(np.float32) * 0.05 for _ in range(n)
    ]


# --------------------------------------------------------------------------
# pipeline basics (pre-existing coverage)
# --------------------------------------------------------------------------

def test_pipeline_features_and_logits_shapes():
    audio = _audio(batch=4)
    pipe = _pipeline_with_stats(audio)
    fv, raw = pipe.features(audio)
    assert fv.shape == (4, 62, 16) and raw.shape == (4, 62, 16)
    params = pipe.init_params(jax.random.PRNGKey(0))
    logits = pipe.logits(params, fv)
    assert logits.shape == (4, 12)


def test_deprecated_shims_warn():
    """The pre-registry shims must emit DeprecationWarning pointing at
    the CHANGES.md migration table (they were silent before)."""
    audio = _audio(batch=1, samples=2048)
    pipe = _pipeline_with_stats(audio)
    with pytest.warns(DeprecationWarning, match="CHANGES.md"):
        pipe.features_software(audio)
    from repro.core.pipeline import record_features_hardware
    from repro.core.tdfex import TDFExConfig

    tdcfg = TDFExConfig()
    c = tdcfg.fex.num_channels
    with pytest.warns(DeprecationWarning, match="CHANGES.md"):
        record_features_hardware(
            np.asarray(audio), tdcfg, None,
            jnp.full((c,), tdcfg.beta_nominal), jnp.ones((c,)),
        )


def test_streaming_matches_batch_inference():
    audio = _audio(seed=1)
    pipe = _pipeline_with_stats(audio)
    params = pipe.init_params(jax.random.PRNGKey(1))
    fv, _ = pipe.features(audio)
    batch_logits = pipe.logits(params, fv)
    states = pipe.streaming_init(2)
    for t in range(fv.shape[1]):
        states, logits = pipe.streaming_step(params, states, fv[:, t])
    np.testing.assert_allclose(
        np.asarray(batch_logits), np.asarray(logits), atol=1e-5
    )


def test_streaming_server_lifecycle():
    _, srv = _server(seed=2)
    srv.open_stream(101)
    srv.open_stream(202)
    out = srv.step({101: np.ones(16, np.float32),
                    202: np.zeros(16, np.float32)})
    assert set(out) == {101, 202}
    assert abs(out[101]["probs"].sum() - (1 - srv.smoothing)) < 1e-5
    srv.close_stream(101)
    out = srv.step({202: np.ones(16, np.float32)})
    assert set(out) == {202}
    # slot reuse
    srv.open_stream(303)
    assert len(srv.active) == 2


def test_server_capacity():
    _, srv = _server(max_streams=2, seed=3)
    srv.open_stream(1)
    srv.open_stream(2)
    with pytest.raises(RuntimeError):
        srv.open_stream(3)


# --------------------------------------------------------------------------
# idle-stream isolation (regression: the pre-fused server advanced GRU
# state for streams that did not submit a frame)
# --------------------------------------------------------------------------

def test_idle_stream_state_bit_identical_across_other_ticks():
    """A stream that skips ticks must have bit-identical GRU state,
    frontend carry, scores, and posteriors while other streams tick."""
    pipe, srv = _server(seed=4)
    srv.open_stream(1)
    srv.open_stream(2)
    hops = _hops(pipe, 4, seed=4)
    srv.step({1: hops[0], 2: hops[0]})
    idle_before = _slot_state(srv, 2)
    for h in hops[1:]:  # stream 2 never submits
        srv.step({1: h})
    idle_after = _slot_state(srv, 2)
    jax.tree_util.tree_map(
        np.testing.assert_array_equal, idle_before, idle_after
    )
    # ...and its reported posteriors pick up exactly where they left off:
    # identical to a server that ran only stream 2's traffic.
    out = srv.step({2: hops[1]})
    pipe2, srv2 = _server(seed=4)
    srv2.params = srv.params
    srv2.open_stream(2)
    srv2.step({2: hops[0]})
    out2 = srv2.step({2: hops[1]})
    np.testing.assert_array_equal(out[2]["probs"], out2[2]["probs"])


def test_idle_stream_isolated_under_fv_ticks():
    """Same isolation when ticks carry FV_Norm frames (no frontend)."""
    _, srv = _server(seed=5)
    srv.open_stream(1)
    srv.open_stream(2)
    fv = np.ones(16, np.float32)
    srv.step({1: fv, 2: fv})
    idle_before = _slot_state(srv, 2)
    srv.step({1: fv})
    srv.step({1: 2 * fv})
    idle_after = _slot_state(srv, 2)
    jax.tree_util.tree_map(
        np.testing.assert_array_equal, idle_before, idle_after
    )


def test_empty_tick_is_noop():
    """`step({})` must not touch any state and not dispatch anything."""
    pipe, srv = _server(seed=6)
    srv.open_stream(7)
    srv.step({7: _hops(pipe, 1, seed=6)[0]})
    before = jax.tree_util.tree_map(
        lambda t: np.asarray(t).copy(), srv.state
    )
    assert srv.step({}) == {}
    after = jax.tree_util.tree_map(lambda t: np.asarray(t), srv.state)
    jax.tree_util.tree_map(np.testing.assert_array_equal, before, after)


# --------------------------------------------------------------------------
# stream lifecycle: slot-reuse hygiene
# --------------------------------------------------------------------------

def test_close_reopen_zeroes_reused_slot_only():
    """open -> tick -> close -> reopen must hand out a fully zeroed slot
    (GRU, carry, scores) while a concurrent stream's state is untouched."""
    pipe, srv = _server(max_streams=2, seed=7)
    srv.open_stream(1)
    srv.open_stream(2)
    hops = _hops(pipe, 2, seed=7)
    srv.step({1: hops[0], 2: hops[0]})
    survivor_before = _slot_state(srv, 2)
    old_slot = srv.active[1]
    srv.close_stream(1)
    srv.open_stream(3)  # only free slot -> must reuse stream 1's
    assert srv.active[3] == old_slot
    reused = _slot_state(srv, 3)
    jax.tree_util.tree_map(
        lambda t: np.testing.assert_array_equal(t, np.zeros_like(t)),
        reused,
    )
    survivor_after = _slot_state(srv, 2)
    jax.tree_util.tree_map(
        np.testing.assert_array_equal, survivor_before, survivor_after
    )
    # the reopened stream starts from scratch: same first-tick output as
    # a fresh server
    out = srv.step({3: hops[1]})
    _, fresh = _server(max_streams=2, seed=7)
    fresh.params = srv.params
    fresh.open_stream(3)
    out_fresh = fresh.step({3: hops[1]})
    np.testing.assert_array_equal(out[3]["probs"], out_fresh[3]["probs"])


def test_reopen_same_stream_id_rejected():
    _, srv = _server(seed=8)
    srv.open_stream(1)
    with pytest.raises(ValueError, match="already open"):
        srv.open_stream(1)


def test_mixed_kind_tick_rejected():
    pipe, srv = _server(seed=9)
    srv.open_stream(1)
    srv.open_stream(2)
    with pytest.raises(ValueError, match="same kind"):
        srv.step({1: np.ones(16, np.float32),
                  2: np.zeros(pipe.chunk_samples, np.float32)})


# --------------------------------------------------------------------------
# pre-batched ticks + scan replay driver
# --------------------------------------------------------------------------

def test_step_batch_matches_step():
    """The slab ingress path and the dict path are the same tick."""
    pipe, srv_a = _server(seed=10)
    _, srv_b = _server(seed=10)
    srv_b.params = srv_a.params
    for s in (srv_a, srv_b):
        s.open_stream(0)
        s.open_stream(1)
    hop = pipe.chunk_samples
    rng = np.random.default_rng(10)
    for _ in range(3):
        chunks = {i: rng.standard_normal(hop).astype(np.float32) * 0.05
                  for i in range(2)}
        out = srv_a.step(chunks)
        slab = np.zeros((srv_b.max_streams, hop), np.float32)
        mask = np.zeros((srv_b.max_streams,), bool)
        for sid, chunk in chunks.items():
            slab[srv_b.active[sid]] = chunk
            mask[srv_b.active[sid]] = True
        scores, tops = srv_b.step_batch(slab, mask)
    for sid in (0, 1):
        slot = srv_b.active[sid]
        np.testing.assert_array_equal(out[sid]["probs"], scores[slot])
        assert out[sid]["top"] == int(tops[slot])


def test_run_replay_matches_step_sequence():
    """`run` (lax.scan over the fused tick) == the same audio fed
    hop-by-hop through `step`, including ragged stream lengths."""
    pipe, srv_live = _server(seed=11)
    _, srv_scan = _server(seed=11)
    srv_scan.params = srv_live.params
    hop = pipe.chunk_samples
    rng = np.random.default_rng(11)
    buf1 = rng.standard_normal(hop * 4).astype(np.float32) * 0.05
    buf2 = rng.standard_normal(hop * 2).astype(np.float32) * 0.05
    for s in (srv_live, srv_scan):
        s.open_stream(1)
        s.open_stream(2)
    live = {1: [], 2: []}
    for t in range(4):
        frames = {1: buf1[t * hop:(t + 1) * hop]}
        if t < 2:  # stream 2 ends after 2 ticks (ragged)
            frames[2] = buf2[t * hop:(t + 1) * hop]
        out = srv_live.step(frames)
        for sid, r in out.items():
            live[sid].append(r["probs"])
    replay = srv_scan.run({1: buf1, 2: buf2})
    np.testing.assert_array_equal(np.stack(live[1]), replay[1]["probs"])
    np.testing.assert_array_equal(np.stack(live[2]), replay[2]["probs"])
    assert replay[1]["top"] == int(np.stack(live[1])[-1].argmax())
    # the servers end in identical states (scan leaves stream 2 masked
    # after its buffer ends, exactly like the live skips)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)
        ),
        srv_live.state, srv_scan.state,
    )


def test_run_batch_fv_matches_live_fv_ticks():
    _, srv_live = _server(seed=12)
    _, srv_scan = _server(seed=12)
    srv_scan.params = srv_live.params
    n, c = srv_live.max_streams, 16
    rng = np.random.default_rng(12)
    slab = rng.standard_normal((3, n, c)).astype(np.float32)
    mask = np.ones((3, n), bool)
    for s in (srv_live, srv_scan):
        for sid in range(n):
            s.open_stream(sid)
    live_scores = []
    for t in range(3):
        scores, _ = srv_live.step_batch(slab[t], mask[t])
        live_scores.append(scores)
    scores_seq, tops = srv_scan.run_batch(slab, mask)
    np.testing.assert_array_equal(np.stack(live_scores), scores_seq)
    assert tops.shape == (3, n)


# --------------------------------------------------------------------------
# streaming-vs-batch feature parity (three-way, through the pipeline's
# streaming_features_step — the path the fused tick inlines)
# --------------------------------------------------------------------------

@pytest.mark.parametrize(
    "frontend", ["software", "hardware", "hardware-pallas"]
)
def test_streaming_features_parity_all_frontends(frontend):
    """Hop-by-hop `streaming_features_step` must match the whole-
    utterance `features` path: exactly (up to the documented 1-sample
    chunk-edge oversampler replication and TDC count granularity — <= 1
    raw-code LSB) for every registered frontend, and through the full
    normalizer for the software backend."""
    audio = _audio(batch=2, samples=4096, seed=13)
    cfg = KWSPipelineConfig(frontend=frontend, use_norm=False)
    state = (
        hardware_state(cfg.tdfex_config) if frontend != "software" else None
    )
    pipe = KWSPipeline(cfg, state=state)
    _, raw_batch = pipe.features(audio)
    carry = pipe.streaming_features_init(audio.shape[0])
    hop = pipe.chunk_samples
    frames = []
    for t in range(audio.shape[1] // hop):
        carry, codes = pipe.frontend.streaming_step(
            audio[:, t * hop:(t + 1) * hop], cfg, pipe.state, carry
        )
        frames.append(np.asarray(codes))
    raw_stream = np.stack(frames, axis=1)
    d = np.abs(raw_stream - np.asarray(raw_batch))
    assert d.max() <= 1.0, f"{frontend}: {d.max()} LSB"
    assert (d == 0).mean() > 0.5, "parity should hold for most codes"


def test_streaming_fv_norm_parity_software():
    """FV_Norm parity through log LUT + normalizer + Q6.8 (the frames
    the GRU actually consumes): 1 raw-LSB code flips stay below one
    normalized quantization step."""
    audio = _audio(batch=2, samples=8192, seed=14)
    pipe = _pipeline_with_stats(audio)
    fv_batch, _ = pipe.features(audio)
    carry = pipe.streaming_features_init(audio.shape[0])
    hop = pipe.chunk_samples
    outs = []
    for t in range(audio.shape[1] // hop):
        carry, fv = pipe.streaming_features_step(
            carry, audio[:, t * hop:(t + 1) * hop]
        )
        outs.append(np.asarray(fv))
    stream = np.stack(outs, axis=1)
    np.testing.assert_allclose(stream, np.asarray(fv_batch), atol=0.5)


# --------------------------------------------------------------------------
# TDC dispatch parity: reference vs interpret
# --------------------------------------------------------------------------

_TDC_CFG = TDFExConfig()
_SPF = _TDC_CFG.decimation // _TDC_CFG.tdc_oversample


def _tdc_parity(b, frames, c, seed):
    rng = np.random.default_rng(seed)
    u = jnp.asarray(
        np.abs(rng.standard_normal((b, _SPF * frames, c))).astype(np.float32)
        * 0.2
    )
    ref = np.asarray(tdc_counts(u, _TDC_CFG, dispatch="reference"))
    itp = np.asarray(tdc_counts(u, _TDC_CFG, dispatch="interpret"))
    assert ref.shape == itp.shape == (b, frames, c)
    # The two formulations are algebraically identical; float32 rounding
    # can land a phase exactly on a floor boundary, flipping single
    # counts by 1 (both stay within 1 LSB of the float64 oracle, see
    # test_kernels). Anything beyond that is a real dispatch bug.
    d = np.abs(ref - itp)
    assert d.max() <= 1.0, f"dispatch divergence: {d.max()} counts"
    assert (d == 0).mean() >= 0.95, "boundary flips must be rare"


@pytest.mark.parametrize(
    "b,frames,c", [(1, 1, 1), (2, 3, 16), (5, 2, 7), (9, 1, 3)]
)
def test_tdc_dispatch_parity_sweep(b, frames, c):
    _tdc_parity(b, frames, c, seed=b * 100 + frames * 10 + c)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=6),
    frames=st.integers(min_value=1, max_value=4),
    c=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_tdc_dispatch_parity_property(b, frames, c, seed):
    """Property sweep across random (batch, frames, channels) shapes:
    reference and interpret dispatch agree for every shape (skipped when
    the hypothesis test extra is absent)."""
    _tdc_parity(b, frames, c, seed)


def test_tdc_dispatch_inside_jit_matches_outside():
    """`tdc_counts` must dispatch identically under an outer jit (the
    fused tick / features path) — and without a nested jit boundary."""
    rng = np.random.default_rng(15)
    u = jnp.asarray(
        np.abs(rng.standard_normal((2, _SPF * 2, 4))).astype(np.float32)
        * 0.2
    )
    outside = np.asarray(tdc_counts(u, _TDC_CFG, dispatch="interpret"))
    inside = np.asarray(
        jax.jit(lambda x: tdc_counts(x, _TDC_CFG, dispatch="interpret"))(u)
    )
    np.testing.assert_array_equal(outside, inside)


# --------------------------------------------------------------------------
# ServerState pytree mechanics
# --------------------------------------------------------------------------

def test_step_twice_keeps_first_scores_single_device():
    """Donation-hazard regression (single-device path; the sharded twin
    lives in tests/test_serve_sharded.py): two ticks back-to-back
    without fetching `scores` in between must leave the first tick's
    returned arrays intact. The tick's scores output can alias the new
    state's scores buffer, and that buffer is DONATED to the next tick
    — a zero-copy `np.asarray` view of it would turn into
    read-after-donation garbage, so the host boundary must hand out
    owned copies."""
    _, srv = _server(seed=17)
    srv.open_stream(0)
    srv.open_stream(1)
    rng = np.random.default_rng(17)
    mask = np.zeros((srv.max_streams,), bool)
    mask[:2] = True
    fv1 = rng.standard_normal((srv.max_streams, 16)).astype(np.float32)
    fv2 = rng.standard_normal((srv.max_streams, 16)).astype(np.float32)
    scores1, top1 = srv.step_batch(fv1, mask)
    assert scores1.flags["OWNDATA"] and top1.flags["OWNDATA"]
    snap_s, snap_t = scores1.copy(), top1.copy()
    view = srv.scores  # the property must also be an owned copy
    assert view.flags["OWNDATA"]
    srv.step_batch(fv2, mask)  # donates the state scores1 could alias
    srv.step_batch(fv1, mask)
    np.testing.assert_array_equal(scores1, snap_s)
    np.testing.assert_array_equal(top1, snap_t)
    np.testing.assert_array_equal(view, snap_s)
    # same guard on the scanned replay driver
    slab = rng.standard_normal((2, srv.max_streams, 16)).astype(np.float32)
    seq, tops = srv.run_batch(slab, np.stack([mask, mask]))
    assert seq.flags["OWNDATA"] and tops.flags["OWNDATA"]
    snap_seq = seq.copy()
    srv.run_batch(slab, np.stack([mask, mask]))
    np.testing.assert_array_equal(seq, snap_seq)


def test_server_state_is_donation_safe_pytree():
    """Every ServerState leaf must be a distinct buffer (the fused tick
    donates the whole pytree) and must round-trip tree flatten."""
    _, srv = _server(seed=16)
    leaves = jax.tree_util.tree_leaves(srv.state)
    buf_ids = [id(leaf) for leaf in leaves]
    assert len(set(buf_ids)) == len(buf_ids)
    flat, treedef = jax.tree_util.tree_flatten(srv.state)
    rebuilt = jax.tree_util.tree_unflatten(treedef, flat)
    assert isinstance(rebuilt, ServerState)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)
        ),
        srv.state, rebuilt,
    )


# --------------------------------------------------------------------------
# lifecycle error hygiene: canonical ValueError, no partial mutation
# --------------------------------------------------------------------------

def test_close_stream_never_opened_raises_value_error():
    """Closing an id that was never opened must raise the canonical
    ValueError, not leak a raw KeyError from the slot bookkeeping."""
    _, srv = _server(seed=20)
    with pytest.raises(ValueError, match="stream 99 not open"):
        srv.close_stream(99)
    # the router's free list must be untouched by the rejected close
    srv.open_stream(1)
    assert srv.active == {1: 0}


def test_close_stream_double_close_raises_value_error():
    _, srv = _server(seed=20)
    srv.open_stream(7)
    srv.close_stream(7)
    with pytest.raises(ValueError, match="stream 7 not open"):
        srv.close_stream(7)
    # the slot freed by the first close is still reusable
    srv.open_stream(8)
    assert 8 in srv.active


def _state_snapshot(srv):
    return [np.asarray(leaf).copy()
            for leaf in jax.tree_util.tree_leaves(srv.state)]


def test_step_unopened_stream_rejected_before_any_mutation():
    """A tick naming an unopened stream must raise the canonical
    ValueError and leave the server BIT-unchanged — the pre-validation
    code KeyError'd out of the slab build mid-tick."""
    pipe, srv = _server(seed=21)
    srv.open_stream(1)
    srv.open_stream(2)
    fv = np.ones(16, np.float32)
    srv.step({1: fv, 2: fv})  # advance to a non-trivial state
    before = _state_snapshot(srv)
    active_before = dict(srv.active)
    with pytest.raises(ValueError, match=r"stream\(s\) \[99\] not open"):
        srv.step({1: fv, 99: fv})
    after = _state_snapshot(srv)
    assert len(before) == len(after)
    for a, b in zip(before, after):
        np.testing.assert_array_equal(a, b)
    assert srv.active == active_before
    # multiple unknown ids are all reported, sorted
    with pytest.raises(
        ValueError, match=r"stream\(s\) \[41, 99\] not open"
    ):
        srv.step({99: fv, 41: fv})
    # the offline replay driver shares the validation
    with pytest.raises(ValueError, match=r"stream\(s\) \[50\] not open"):
        srv.run({50: np.zeros(srv.pipeline.chunk_samples * 2, np.float32)})


def test_ambiguous_serving_geometry_rejected_at_construction():
    """A config where a raw audio hop and an FV_Norm frame have the
    same width would make `_is_raw` silently route every tick down the
    raw-audio path; the server must refuse to build. (The paper's
    geometry — 256-sample hops vs 16 channels — never collides; this
    uses fs_audio=1000 Hz so one 16 ms hop is exactly 16 samples.)"""
    from repro.core.fex import FExConfig

    cfg = KWSPipelineConfig(
        use_norm=False, fex=FExConfig(fs_audio=1000.0)
    )
    pipe = KWSPipeline(cfg)
    assert pipe.chunk_samples == pipe.config.fex.num_channels == 16
    params = pipe.init_params(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="ambiguous serving geometry"):
        StreamingKWSServer(pipe, params, max_streams=4)
