"""Chunked SSD (Mamba2) and WKV6 (RWKV6) vs sequential oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.mamba2 import _ssd_chunked, ssd_sequential
from repro.models.rwkv6 import wkv6_chunked, wkv6_sequential

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("l,q", [(32, 8), (64, 16), (50, 16), (16, 64)])
def test_ssd_chunked_matches_sequential(l, q):
    b, h, p, n = 2, 3, 8, 5
    xh = jnp.asarray(RNG.standard_normal((b, l, h, p)).astype(np.float32))
    a_log = jnp.asarray(
        -np.abs(RNG.standard_normal((b, l, h))).astype(np.float32)
    )
    bm = jnp.asarray(RNG.standard_normal((b, l, n)).astype(np.float32))
    cm = jnp.asarray(RNG.standard_normal((b, l, n)).astype(np.float32))
    y_c, _ = _ssd_chunked(xh, a_log, bm, cm, q)
    y_s = ssd_sequential(xh, a_log, bm, cm)
    np.testing.assert_allclose(
        np.asarray(y_c), np.asarray(y_s), rtol=2e-4, atol=2e-4
    )


def test_ssd_strong_decay_stable():
    b, l, h, p, n = 1, 64, 2, 4, 4
    xh = jnp.asarray(RNG.standard_normal((b, l, h, p)).astype(np.float32))
    a_log = jnp.asarray(
        -np.abs(RNG.standard_normal((b, l, h)) * 20).astype(np.float32)
    )
    bm = jnp.asarray(RNG.standard_normal((b, l, n)).astype(np.float32))
    cm = jnp.asarray(RNG.standard_normal((b, l, n)).astype(np.float32))
    y_c, _ = _ssd_chunked(xh, a_log, bm, cm, 16)
    assert bool(jnp.isfinite(y_c).all())
    y_s = ssd_sequential(xh, a_log, bm, cm)
    np.testing.assert_allclose(
        np.asarray(y_c), np.asarray(y_s), atol=5e-4
    )


def test_ssd_final_state_feeds_decode():
    """Chunked final state == sequential final state (handoff contract)."""
    b, l, h, p, n = 1, 32, 2, 4, 4
    xh = jnp.asarray(RNG.standard_normal((b, l, h, p)).astype(np.float32))
    a_log = jnp.asarray(
        -np.abs(RNG.standard_normal((b, l, h))).astype(np.float32)
    )
    bm = jnp.asarray(RNG.standard_normal((b, l, n)).astype(np.float32))
    cm = jnp.asarray(RNG.standard_normal((b, l, n)).astype(np.float32))
    _, s_c = _ssd_chunked(xh, a_log, bm, cm, 8)

    def step(s, inputs):
        x_t, a_t, b_t, _ = inputs
        s = s * jnp.exp(a_t)[:, :, None, None] + jnp.einsum(
            "bn,bhp->bhnp", b_t, x_t
        )
        return s, None

    s_seq, _ = jax.lax.scan(
        step,
        jnp.zeros((b, h, n, p)),
        (
            jnp.moveaxis(xh, 1, 0),
            jnp.moveaxis(a_log, 1, 0),
            jnp.moveaxis(bm, 1, 0),
            jnp.moveaxis(cm, 1, 0),
        ),
    )
    np.testing.assert_allclose(
        np.asarray(s_c), np.asarray(s_seq), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("l,q", [(32, 8), (48, 16), (33, 16)])
def test_wkv6_chunked_matches_sequential(l, q):
    b, h, p = 2, 3, 8
    r = jnp.asarray(RNG.standard_normal((b, l, h, p)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((b, l, h, p)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((b, l, h, p)).astype(np.float32))
    logw = jnp.asarray(
        -np.exp(RNG.standard_normal((b, l, h, p)) - 1).astype(np.float32)
    )
    u = jnp.asarray(RNG.standard_normal((h, p)).astype(np.float32) * 0.3)
    yc, sc = wkv6_chunked(r, k, v, logw, u, q)
    ys = wkv6_sequential(r, k, v, logw, u)
    np.testing.assert_allclose(
        np.asarray(yc), np.asarray(ys), rtol=2e-4, atol=2e-4
    )


def test_wkv6_strong_decay_no_overflow():
    b, l, h, p = 1, 32, 2, 4
    r = jnp.asarray(RNG.standard_normal((b, l, h, p)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((b, l, h, p)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((b, l, h, p)).astype(np.float32))
    logw = jnp.full((b, l, h, p), -50.0, jnp.float32)  # near-total decay
    u = jnp.zeros((h, p), jnp.float32)
    yc, _ = wkv6_chunked(r, k, v, logw, u, 8)
    assert bool(jnp.isfinite(yc).all())
    ys = wkv6_sequential(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(ys), atol=1e-4)
