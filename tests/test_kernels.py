"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.fex import FExConfig
from repro.core.filters import design_filterbank
from repro.core.tdfex import TDFExConfig, draw_chip
from repro.kernels.fex_fused import fex_fused, fex_fused_ref
from repro.kernels.gru import gru_sequence, gru_sequence_ref
from repro.kernels.intgemm import intgemm, intgemm_ref
from repro.kernels.tdc import tdc_counts, tdc_counts_ref

RNG = np.random.default_rng(42)


# ---------------- fex_fused ----------------

@pytest.mark.parametrize("batch,t,channels,frame", [
    (1, 1024, 16, 512),
    (3, 2048, 16, 512),
    (8, 1536, 8, 256),
    (5, 4096, 4, 128),
])
def test_fex_fused_sweep(batch, t, channels, frame):
    coeffs = design_filterbank(channels, 32000.0)
    x = jnp.asarray(RNG.standard_normal((batch, t)).astype(np.float32) * 0.2)
    out = fex_fused(x, coeffs, frame)
    ref = fex_fused_ref(x, coeffs, frame)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=2e-5, atol=1e-6
    )


def test_fex_fused_trims_partial_frames():
    coeffs = design_filterbank(16, 32000.0)
    x = jnp.zeros((2, 1000), jnp.float32)
    assert fex_fused(x, coeffs, 512).shape == (2, 1, 16)


def test_fex_fused_state_carries_across_frames():
    """An impulse in frame 0 must ring into frame 1 (IIR state carry)."""
    coeffs = design_filterbank(16, 32000.0)
    x = np.zeros((1, 1024), np.float32)
    x[0, 500] = 1.0  # near the end of frame 0
    out = np.asarray(fex_fused(jnp.asarray(x), coeffs, 512))
    assert out[0, 1].max() > 1e-4  # ringing continues into frame 1


# ---------------- gru ----------------

@pytest.mark.parametrize("b,t,i,h", [
    (1, 5, 16, 48),
    (4, 20, 16, 48),
    (9, 7, 32, 64),
    (2, 62, 16, 48),  # the paper's frame count
])
def test_gru_sequence_sweep(b, t, i, h):
    xs = jnp.asarray(RNG.standard_normal((b, t, i)).astype(np.float32))
    w = jnp.asarray(RNG.standard_normal((i, 3 * h)).astype(np.float32) * 0.2)
    u = jnp.asarray(RNG.standard_normal((h, 3 * h)).astype(np.float32) * 0.2)
    bi = jnp.asarray(RNG.standard_normal(3 * h).astype(np.float32) * 0.1)
    bh = jnp.asarray(RNG.standard_normal(3 * h).astype(np.float32) * 0.1)
    out = gru_sequence(xs, w, u, bi, bh)
    ref = jnp.moveaxis(
        gru_sequence_ref(
            jnp.moveaxis(xs, 1, 0), w, u, bi, bh,
            jnp.zeros((b, h), jnp.float32),
        ), 0, 1,
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), atol=1e-5
    )


def test_gru_nonzero_initial_state():
    b, t, i, h = 2, 4, 8, 16
    xs = jnp.zeros((b, t, i))
    w = jnp.zeros((i, 3 * h))
    u = jnp.asarray(RNG.standard_normal((h, 3 * h)).astype(np.float32) * 0.3)
    bi = jnp.zeros(3 * h)
    bh = jnp.zeros(3 * h)
    h0 = jnp.asarray(RNG.standard_normal((b, h)).astype(np.float32))
    out = gru_sequence(xs, w, u, bi, bh, h0=h0)
    ref = jnp.moveaxis(
        gru_sequence_ref(jnp.moveaxis(xs, 1, 0), w, u, bi, bh, h0), 0, 1
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


# ---------------- intgemm ----------------

# dispatch="interpret" forces the Pallas kernel body (under the
# interpreter off-TPU) — the auto path resolves to the jnp reference on
# CPU, which would make a kernel-vs-reference comparison vacuous.

@pytest.mark.parametrize("m,k,n", [
    (1, 16, 12),
    (7, 100, 30),
    (8, 512, 8),
    (33, 144, 48),  # GRU-shaped
])
def test_intgemm_exact_sweep(m, k, n):
    x = jnp.asarray(RNG.integers(-8191, 8192, (m, k)), jnp.int32)
    w = jnp.asarray(RNG.integers(-128, 128, (k, n)), jnp.int32)
    out = intgemm(x, w, dispatch="interpret")
    assert bool((out == intgemm_ref(x, w)).all())


def test_intgemm_saturates_at_24bit():
    x = jnp.full((8, 512), 8191, jnp.int32)
    w = jnp.full((512, 8), 127, jnp.int32)
    out = intgemm(x, w, dispatch="interpret")
    assert int(out[0, 0]) == 2**23 - 1
    out2 = intgemm(x, -w, dispatch="interpret")
    assert int(out2[0, 0]) == -(2**23)


def test_intgemm_dispatch_paths_agree():
    """reference and interpret dispatch are the same function; auto off
    TPU resolves to reference and must inline under an outer jit."""
    x = jnp.asarray(RNG.integers(-8191, 8192, (5, 33)), jnp.int32)
    w = jnp.asarray(RNG.integers(-128, 128, (33, 20)), jnp.int32)
    ref = np.asarray(intgemm(x, w, dispatch="reference"))
    itp = np.asarray(intgemm(x, w, dispatch="interpret"))
    auto = np.asarray(intgemm(x, w))
    np.testing.assert_array_equal(ref, itp)
    np.testing.assert_array_equal(ref, auto)
    inside = np.asarray(jax.jit(lambda a, b: intgemm(a, b))(x, w))
    np.testing.assert_array_equal(ref, inside)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=17),
    k=st.integers(min_value=1, max_value=700),
    n=st.integers(min_value=1, max_value=33),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    full_scale=st.booleans(),
)
def test_intgemm_matches_integer_oracle_property(m, k, n, seed, full_scale):
    """Property sweep: the Pallas kernel body (interpret) and the jnp
    reference both match an exact int64 numpy oracle bit-for-bit across
    odd/unpadded (M, K, N) shapes, including magnitudes that drive the
    accumulator to (and past) the 24-bit saturation rails (skipped when
    the hypothesis test extra is absent)."""
    rng = np.random.default_rng(seed)
    if full_scale:
        # near-24-bit accumulators: full-range 14-bit x 8-bit codes,
        # |sum| up to k * 2^20 — saturation binds for k >= 8
        x = rng.integers(-8191, 8192, (m, k))
        w = rng.integers(-128, 128, (k, n))
    else:
        x = rng.integers(-512, 513, (m, k))
        w = rng.integers(-128, 128, (k, n))
    oracle = np.clip(
        x.astype(np.int64) @ w.astype(np.int64), -(2**23), 2**23 - 1
    ).astype(np.int32)
    xj = jnp.asarray(x, jnp.int32)
    wj = jnp.asarray(w, jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(intgemm(xj, wj, dispatch="reference")), oracle
    )
    np.testing.assert_array_equal(
        np.asarray(intgemm(xj, wj, dispatch="interpret")), oracle
    )


# ---------------- tdc ----------------

@pytest.mark.parametrize("b,frames,c", [(1, 3, 16), (3, 6, 16), (2, 4, 4)])
def test_tdc_matches_float64_oracle(b, frames, c):
    cfg = TDFExConfig()
    spf = cfg.decimation // cfg.tdc_oversample
    u = jnp.asarray(
        np.abs(RNG.standard_normal((b, spf * frames, c))).astype(np.float32)
        * 0.2
    )
    out = np.asarray(tdc_counts(u, cfg))
    ref = tdc_counts_ref(
        np.asarray(u),
        np.full(c, cfg.f_free_hz),
        np.full(c, cfg.k_sro_hz),
        spf, cfg.tdc_oversample, cfg.f_tdc,
    )
    assert np.abs(out - ref).max() <= 1.0  # <= 1 LSB (noise-shaped)


def test_tdc_with_chip_mismatch():
    cfg = TDFExConfig()
    chip = draw_chip(jax.random.PRNGKey(5), cfg)
    spf = cfg.decimation // cfg.tdc_oversample
    u = jnp.asarray(
        np.abs(RNG.standard_normal((2, spf * 3, 16))).astype(np.float32) * 0.1
    )
    g = np.asarray(1.0 + chip.gain_mismatch)
    out = np.asarray(tdc_counts(u, cfg, chip))
    ref = tdc_counts_ref(
        np.asarray(u), cfg.f_free_hz * g, cfg.k_sro_hz * g,
        spf, cfg.tdc_oversample, cfg.f_tdc,
    )
    assert np.abs(out - ref).max() <= 1.0


# ---------------- wkv6 ----------------

@pytest.mark.parametrize("b,t,h,p", [(1, 8, 1, 4), (3, 24, 2, 8), (2, 16, 4, 16)])
def test_wkv6_kernel_matches_sequential(b, t, h, p):
    from repro.kernels.wkv6 import wkv6, wkv6_ref

    r = jnp.asarray(RNG.standard_normal((b, t, h, p)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((b, t, h, p)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((b, t, h, p)).astype(np.float32))
    lw = jnp.asarray(
        -np.exp(RNG.standard_normal((b, t, h, p)) - 1).astype(np.float32)
    )
    u = jnp.asarray(RNG.standard_normal((h, p)).astype(np.float32) * 0.3)
    out = wkv6(r, k, v, lw, u)
    ref = wkv6_ref(r, k, v, lw, u)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), atol=2e-5
    )


def test_wkv6_kernel_strong_decay():
    from repro.kernels.wkv6 import wkv6, wkv6_ref

    b, t, h, p = 2, 12, 1, 4
    r = jnp.asarray(RNG.standard_normal((b, t, h, p)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((b, t, h, p)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((b, t, h, p)).astype(np.float32))
    lw = jnp.full((b, t, h, p), -50.0, jnp.float32)
    u = jnp.zeros((h, p), jnp.float32)
    out = wkv6(r, k, v, lw, u)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(wkv6_ref(r, k, v, lw, u)), atol=1e-5
    )


# ---------------- dtype sweeps ----------------

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 3e-2)])
def test_fex_fused_dtypes(dtype, tol):
    """bf16 IO compares against the f32 oracle: the kernel accumulates
    its IIR state in f32 regardless of IO dtype (a bf16 reference scan
    is the *lossier* computation)."""
    coeffs = design_filterbank(16, 32000.0)
    x32 = jnp.asarray(RNG.standard_normal((2, 2048)).astype(np.float32) * 0.2)
    out = fex_fused(x32.astype(dtype), coeffs, 512).astype(jnp.float32)
    ref = fex_fused_ref(x32, coeffs, 512)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5), (jnp.bfloat16, 3e-2)])
def test_gru_sequence_dtypes(dtype, tol):
    b, t, i, h = 2, 8, 16, 48
    xs = jnp.asarray(RNG.standard_normal((b, t, i)).astype(np.float32)).astype(dtype)
    w = jnp.asarray(RNG.standard_normal((i, 3 * h)).astype(np.float32) * 0.2).astype(dtype)
    u = jnp.asarray(RNG.standard_normal((h, 3 * h)).astype(np.float32) * 0.2).astype(dtype)
    bi = jnp.zeros(3 * h, dtype)
    bh = jnp.zeros(3 * h, dtype)
    out = gru_sequence(xs, w, u, bi, bh).astype(jnp.float32)
    ref = jnp.moveaxis(
        gru_sequence_ref(jnp.moveaxis(xs, 1, 0), w, u, bi, bh,
                         jnp.zeros((b, h), dtype)), 0, 1
    ).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=tol)


@pytest.mark.parametrize("in_dtype", [jnp.int32, jnp.int16])
def test_intgemm_input_dtypes(in_dtype):
    x = jnp.asarray(RNG.integers(-8191, 8192, (4, 64)), in_dtype)
    w = jnp.asarray(RNG.integers(-128, 128, (64, 16)), jnp.int8)
    out = intgemm(x, w, dispatch="interpret")
    assert bool((out == intgemm_ref(x, w)).all())
