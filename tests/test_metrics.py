"""Observability layer: bit-identity gate + registry/journal/trace unit
behavior.

The contract of `repro.serving.metrics` is that telemetry is free of
numerical side effects: every instrument is a host-side clock read or
dict update AROUND an existing call — device operands, jitted programs,
and dispatch order are untouched. This suite proves the hard gate with
`np.testing.assert_array_equal` (never allclose): a metrics-enabled
`StreamingKWSServer` is BIT-identical to a metrics-off twin for every
classifier backend ("float" / "qat" / "integer" / "delta" /
"delta-int"), sync and async (deferred handles + scan windows), with
the stage-1 cascade gating the tick, and on the 8-emulated-device
("stream",) mesh (tests/conftest.py forces the platform).

Unit coverage around the gate:

  * `Histogram` bucket-edge semantics — Prometheus ``le``: a value
    exactly ON an edge lands in that edge's bucket, above the last edge
    in the implicit +Inf bucket; exact percentiles over the retained
    sample window.
  * `EventJournal` — ``seq`` stays monotonic across drop-oldest trims;
    the server's journal orders "resize" / "compile_programs" /
    "shard_loss" events the way the control flow actually ran.
  * `Autoscaler` — `last_decision` carries the reason ("rejection",
    "occupancy_watermark", "slo_veto"), vetoes are journaled once per
    hysteresis trip, and the server's "resize" event lands before the
    "autoscale" decision that caused it.
  * `metrics_snapshot()` JSON round-trips equal; `render_prometheus()`
    emits parseable text exposition with cumulative buckets whose +Inf
    count equals ``_count``.
  * `TickHandle.done_at` regression — stamped on the FIRST
    ``ready() == True`` poll, not first observed at `result()`.
"""

import json
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.core.fex import fit_norm_stats
from repro.core.pipeline import KWSPipeline, KWSPipelineConfig
from repro.distributed.fault_tolerance import StragglerMonitor
from repro.serving.autoscale import Autoscaler, AutoscalePolicy
from repro.serving.cascade import CascadeConfig
from repro.serving.ingress import PipelinedIngress, TickCoalescer
from repro.serving.metrics import (
    Counter,
    EventJournal,
    Gauge,
    Histogram,
    MetricsRegistry,
    TickTrace,
    span_percentiles,
)
from repro.serving.serve_loop import StreamingKWSServer

N_DEV = len(jax.devices())
MESH_DEV = (
    max(d for d in (2, 4, 8) if d <= min(8, N_DEV)) if N_DEV >= 2 else 1
)
MAX_STREAMS = 8
CLASSIFIERS = ("float", "qat", "integer", "delta", "delta-int")


@pytest.fixture(scope="module")
def norm_stats():
    rng = np.random.default_rng(0)
    audio = jnp.asarray(
        rng.standard_normal((4, 16000)).astype(np.float32) * 0.05
    )
    boot = KWSPipeline(KWSPipelineConfig(use_norm=False))
    _, raw = boot.features(audio)
    return fit_norm_stats(quant.log_compress_lut(raw, 12, 10))


@pytest.fixture(scope="module", params=CLASSIFIERS)
def backend(request, norm_stats):
    """(pipeline, params) per classifier backend, built once."""
    pipe = KWSPipeline(
        KWSPipelineConfig(classifier=request.param), norm_stats=norm_stats
    )
    return pipe, pipe.init_params(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def qat_backend(norm_stats):
    pipe = KWSPipeline(
        KWSPipelineConfig(classifier="qat"), norm_stats=norm_stats
    )
    return pipe, pipe.init_params(jax.random.PRNGKey(3))


def _ticks(pipe, n, kind="fv", seed=0, n_streams=MAX_STREAMS):
    """n random (slab, mask) tick operands with partial masks."""
    rng = np.random.default_rng(seed)
    dim = (
        pipe.chunk_samples if kind == "audio"
        else pipe.config.fex.num_channels
    )
    out = []
    for _ in range(n):
        slab = rng.standard_normal(
            (n_streams, dim)
        ).astype(np.float32) * 0.05
        mask = rng.random(n_streams) > 0.25
        out.append((slab, mask))
    return out


def _twin_servers(pipe, params, devices=1, max_streams=MAX_STREAMS,
                  n_open=None):
    """(metrics-on, metrics-off) servers with the same open streams."""
    on = StreamingKWSServer(
        pipe, params, max_streams=max_streams, devices=devices,
        metrics=True,
    )
    off = StreamingKWSServer(
        pipe, params, max_streams=max_streams, devices=devices
    )
    for sid in range(max_streams if n_open is None else n_open):
        on.open_stream(sid)
        off.open_stream(sid)
    return on, off


def _assert_states_identical(a, b):
    la = [np.asarray(x) for x in jax.tree_util.tree_leaves(a.state)]
    lb = [np.asarray(x) for x in jax.tree_util.tree_leaves(b.state)]
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(x, y)


# --------------------------------------------------------------------------
# the hard gate: metrics on == metrics off, bitwise
# --------------------------------------------------------------------------

def test_metrics_bit_identical_all_backends(backend):
    """Sync ticks, deferred async handles, and a run_batch_async scan
    window on a metrics-enabled server bit-match a metrics-off twin —
    scores, top indices, and every ServerState leaf — for fv and
    raw-audio ticks alike."""
    pipe, params = backend
    on, off = _twin_servers(pipe, params)
    sync = _ticks(pipe, 3, "fv", seed=1) + _ticks(pipe, 2, "audio", seed=2)
    for slab, mask in sync:
        gs, gt = on.step_batch(slab, mask)
        rs, rt = off.step_batch(slab, mask)
        np.testing.assert_array_equal(gs, rs)
        np.testing.assert_array_equal(gt, rt)
    # async: every handle fetched after the last dispatch
    deferred = _ticks(pipe, 4, "fv", seed=3)
    handles = [on.step_batch_async(s, m) for s, m in deferred]
    ref = [off.step_batch(s, m) for s, m in deferred]
    for h, (rs, rt) in zip(handles, ref):
        gs, gt = h.result()
        np.testing.assert_array_equal(gs, rs)
        np.testing.assert_array_equal(gt, rt)
    # coalesced window: one scan dispatch vs per-tick reference
    window = _ticks(pipe, 3, "fv", seed=4)
    wh = on.run_batch_async(
        np.stack([s for s, _ in window]), np.stack([m for _, m in window])
    )
    wref = [off.step_batch(s, m) for s, m in window]
    scores_seq, tops = wh.result()
    for t, (rs, rt) in enumerate(wref):
        np.testing.assert_array_equal(scores_seq[t], rs)
        np.testing.assert_array_equal(tops[t], rt)
    _assert_states_identical(on, off)
    # the registry actually observed the work it didn't perturb
    assert on.metrics.counter("kws_serve_ticks_total").value > 0
    assert on.metrics.histogram("kws_serve_tick_ms").count == len(sync)


@pytest.mark.parametrize("wake_threshold", [0.0, 0.3])
def test_metrics_bit_identical_cascaded(norm_stats, wake_threshold):
    """The stage-1 wake gate's frozen-state holds are untouched by
    instrumentation: cascaded metrics-on == cascaded metrics-off,
    including the wake duty-cycle telemetry itself."""
    pipe = KWSPipeline(
        KWSPipelineConfig(
            classifier="qat",
            cascade=CascadeConfig(
                wake_threshold=wake_threshold, hangover_frames=1
            ),
        ),
        norm_stats=norm_stats,
    )
    params = pipe.init_params(jax.random.PRNGKey(5))
    on, off = _twin_servers(pipe, params)
    for slab, mask in _ticks(pipe, 6, "fv", seed=5):
        gs, gt = on.step_batch(slab, mask)
        rs, rt = off.step_batch(slab, mask)
        np.testing.assert_array_equal(gs, rs)
        np.testing.assert_array_equal(gt, rt)
    _assert_states_identical(on, off)
    np.testing.assert_array_equal(on.wake_rate, off.wake_rate)


@pytest.mark.skipif(
    N_DEV < 2,
    reason="needs a multi-device platform (conftest forces 8 emulated "
    "CPU devices unless XLA_FLAGS overrides it)",
)
def test_metrics_bit_identical_sharded(backend):
    """Metrics-on == metrics-off on the ("stream",) mesh: sharded
    dispatch, sharded score fetches, deferred handles."""
    pipe, params = backend
    ms = 2 * MESH_DEV
    on, off = _twin_servers(
        pipe, params, devices=MESH_DEV, max_streams=ms
    )
    ticks = _ticks(pipe, 4, "fv", seed=7, n_streams=ms)
    handles = [on.step_batch_async(s, m) for s, m in ticks]
    ref = [off.step_batch(s, m) for s, m in ticks]
    for h, (rs, rt) in zip(handles, ref):
        gs, gt = h.result()
        np.testing.assert_array_equal(gs, rs)
        np.testing.assert_array_equal(gt, rt)
    _assert_states_identical(on, off)


def test_metrics_bit_identical_pipelined_ingress(qat_backend):
    """The traced PipelinedIngress (span marks, queue gauges) retires
    the same bits as an uninstrumented one."""
    pipe, params = qat_backend
    on, off = _twin_servers(pipe, params)
    dim = pipe.config.fex.num_channels
    ing_on = PipelinedIngress(on, dim, depth=2)
    ing_off = PipelinedIngress(off, dim, depth=2)
    for s, m in _ticks(pipe, 6, "fv", seed=9):
        for ing in (ing_on, ing_off):
            slab, mask = ing.stage()
            slab[:] = s
            mask[:] = m
            ing.commit()
    for ha, hb in zip(ing_on.drain(), ing_off.drain()):
        np.testing.assert_array_equal(ha.scores, hb.scores)
        np.testing.assert_array_equal(ha.top, hb.top)
    _assert_states_identical(on, off)


# --------------------------------------------------------------------------
# Histogram / Counter / Gauge unit behavior
# --------------------------------------------------------------------------

def test_histogram_bucket_edges_le_inclusive():
    """Prometheus le semantics: v strictly below an edge and v exactly
    ON the edge both land in that edge's bucket; above the last edge is
    the implicit +Inf bucket."""
    h = Histogram(buckets=(1.0, 2.0, 4.0))
    for v, bucket in [
        (0.5, 0), (1.0, 0),   # on-edge -> that edge's bucket
        (1.5, 1), (2.0, 1),
        (4.0, 2),
        (4.0001, 3), (100.0, 3),  # past the last edge -> +Inf
    ]:
        before = list(h.counts)
        h.observe(v)
        assert h.counts[bucket] == before[bucket] + 1, (v, bucket)
    assert h.counts == [2, 2, 1, 2]
    assert h.count == 7
    assert h.last == 100.0
    np.testing.assert_allclose(h.sum, 0.5 + 1.0 + 1.5 + 2.0 + 4.0
                               + 4.0001 + 100.0)
    p = h.percentiles()
    assert p["max"] == 100.0 and p["p50"] == 2.0


def test_histogram_validation_and_sample_window():
    with pytest.raises(ValueError, match="ascending"):
        Histogram(buckets=(2.0, 1.0))
    with pytest.raises(ValueError, match="ascending"):
        Histogram(buckets=(1.0, 1.0))  # strictly ascending
    with pytest.raises(ValueError, match="ascending"):
        Histogram(buckets=())
    h = Histogram(buckets=(10.0,), keep_samples=4)
    assert h.last is None and h.percentiles() is None
    for v in [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]:
        h.observe(v)
    # bucket counts cover all 6; percentiles only the retained last 4
    assert h.count == 6
    assert list(h.samples) == [3.0, 4.0, 5.0, 6.0]
    assert h.percentiles()["max"] == 6.0


def test_counter_monotonic_and_gauge():
    c = Counter()
    c.inc()
    c.inc(3)
    assert c.value == 4
    with pytest.raises(ValueError, match=">= 0"):
        c.inc(-1)
    g = Gauge()
    g.set(7)
    assert g.value == 7.0 and isinstance(g.value, float)


def test_registry_get_or_create_and_kind_conflict():
    reg = MetricsRegistry()
    c1 = reg.counter("x_total", "help")
    assert reg.counter("x_total") is c1
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x_total")
    # label sets create distinct children under one family
    a = reg.counter("y_total", reason="full")
    b = reg.counter("y_total", reason="deadline")
    assert a is not b
    assert reg.counter("y_total", reason="full") is a


# --------------------------------------------------------------------------
# EventJournal: seq monotonic past trims; server event ordering
# --------------------------------------------------------------------------

def test_journal_seq_monotonic_across_trim():
    t = [0.0]
    journal = EventJournal(clock=lambda: t[0], capacity=4)
    for i in range(10):
        t[0] = float(i)
        journal.append("ev", i=i)
    assert len(journal) == 4
    snap = journal.snapshot()
    # oldest 6 dropped; seq keeps counting so the gap is detectable
    assert [e["seq"] for e in snap] == [6, 7, 8, 9]
    assert [e["i"] for e in snap] == [6, 7, 8, 9]
    assert all(e["kind"] == "ev" for e in snap)
    # snapshot returns copies, not live references
    snap[0]["i"] = 999
    assert journal.snapshot()[0]["i"] == 6


def test_journal_orders_resize_events(qat_backend):
    """resize() journals one "resize" event with before/after capacity;
    a resize back to a seen shape journals but does NOT retrace."""
    pipe, params = qat_backend
    srv = StreamingKWSServer(
        pipe, params, max_streams=MAX_STREAMS, metrics=True
    )
    srv.open_stream(0)
    dim = pipe.config.fex.num_channels
    srv.step_batch(np.zeros((MAX_STREAMS, dim), np.float32),
                   np.ones(MAX_STREAMS, bool))
    srv.resize(2 * MAX_STREAMS)
    srv.step_batch(np.zeros((2 * MAX_STREAMS, dim), np.float32),
                   np.ones(2 * MAX_STREAMS, bool))
    srv.resize(MAX_STREAMS)
    srv.step_batch(np.zeros((MAX_STREAMS, dim), np.float32),
                   np.ones(MAX_STREAMS, bool))
    ev = srv.metrics.journal.snapshot()
    kinds = [e["kind"] for e in ev]
    assert kinds == [
        "compile_programs",   # construction
        "retrace",            # first tick at 8
        "resize",             # 8 -> 16
        "retrace",            # first tick at 16
        "resize",             # 16 -> 8: back to a seen shape...
    ]                         # ...so NO trailing retrace event
    seqs = [e["seq"] for e in ev]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    grows = [e for e in ev if e["kind"] == "resize"]
    assert (grows[0]["from_streams"], grows[0]["to_streams"]) == (8, 16)
    assert (grows[1]["from_streams"], grows[1]["to_streams"]) == (16, 8)
    assert srv.retrace_count == 2


@pytest.mark.skipif(
    N_DEV < 2,
    reason="needs a multi-device platform (conftest forces 8 emulated "
    "CPU devices unless XLA_FLAGS overrides it)",
)
def test_journal_orders_shard_loss_events(qat_backend):
    """Shard loss journals the way recovery actually runs: the rebuild
    ("compile_programs") happens MID-recovery, so it lands before the
    "shard_loss" summary event; the first post-recovery tick retraces
    (the seen-shape set was cleared with the old programs)."""
    pipe, params = qat_backend
    ms = 2 * MESH_DEV
    srv = StreamingKWSServer(
        pipe, params, max_streams=ms, devices=MESH_DEV, metrics=True
    )
    for sid in range(ms):
        srv.open_stream(sid)
    dim = pipe.config.fex.num_channels
    srv.step_batch(np.zeros((ms, dim), np.float32), np.ones(ms, bool))
    r0 = srv.retrace_count
    info = srv.recover_shard_loss(0)
    new_ms = srv.max_streams
    srv.step_batch(np.zeros((new_ms, dim), np.float32),
                   np.ones(new_ms, bool))
    kinds = [e["kind"] for e in srv.metrics.journal.snapshot()]
    assert kinds == [
        "compile_programs",  # construction
        "retrace",           # first tick on the full mesh
        "compile_programs",  # rebuild on the survivor mesh...
        "shard_loss",        # ...then the recovery summary
        "retrace",           # first tick post-recovery counts again
    ]
    loss = [e for e in srv.metrics.journal.snapshot()
            if e["kind"] == "shard_loss"][0]
    assert loss["lost_shard"] == 0
    assert loss["from_devices"] == MESH_DEV
    assert loss["to_devices"] == srv.n_devices
    assert loss["from_streams"] == ms
    assert loss["to_streams"] == new_ms
    assert srv.retrace_count == r0 + 1
    assert srv.compile_count == 2
    assert info  # the recovery report itself is unchanged


# --------------------------------------------------------------------------
# Autoscaler decisions: last_decision + journal + counters
# --------------------------------------------------------------------------

def _auto(qat_backend, n_open, **policy):
    pipe, params = qat_backend
    srv = StreamingKWSServer(
        pipe, params, max_streams=policy.get("min_streams", 8),
        metrics=True,
    )
    for sid in range(n_open):
        srv.open_stream(sid)
    pol = AutoscalePolicy(**policy)
    return srv, Autoscaler(srv, pol, monitor=StragglerMonitor(warmup=0))


def test_autoscaler_grow_reasons_and_counter(qat_backend):
    srv, auto = _auto(
        qat_backend, n_open=8, min_streams=8, max_streams=32,
        hysteresis_ticks=2, cooldown_ticks=0,
    )
    assert auto.last_decision is None
    assert auto.observe() is None      # hysteresis tick 1
    assert auto.observe() == "grow"    # tick 2: watermark trip
    assert auto.last_decision["reason"] == "occupancy_watermark"
    assert auto.last_decision["action"] == "grow"
    assert (auto.last_decision["from"], auto.last_decision["to"]) == (
        8, 16
    )
    auto.note_rejection()
    assert auto.observe() == "grow"    # rejection: immediate
    assert auto.last_decision["reason"] == "rejection"
    assert srv.max_streams == 32
    counted = srv.metrics.counter(
        "kws_autoscale_decisions_total", action="grow"
    )
    assert counted.value == 2
    # the server's "resize" event precedes the "autoscale" decision
    # that caused it (the resize happens inside the decision)
    kinds = [e["kind"] for e in srv.metrics.journal.snapshot()]
    i_rs = kinds.index("resize")
    i_as = kinds.index("autoscale")
    assert i_rs < i_as


def test_autoscaler_slo_veto_recorded_once_per_trip(qat_backend):
    srv, auto = _auto(
        qat_backend, n_open=1, min_streams=4, max_streams=16,
        shrink_at=0.3, grow_at=0.9, hysteresis_ticks=2,
        cooldown_ticks=0,
    )
    srv.resize(16)  # occupancy 1/16 -> shrink territory
    auto.observe(0.001)  # seeds the straggler EMA
    # a 100x tick: SLO unhealthy while low occupancy trips hysteresis
    assert auto.observe(0.1) is None
    assert auto.last_decision == {
        "step": 2, "action": "hold", "from": 16, "to": 16,
        "reason": "slo_veto",
    }
    assert auto.observe(0.1) is None  # still vetoed, NOT re-recorded
    vetos = [e for e in srv.metrics.journal.snapshot()
             if e["kind"] == "autoscale"]
    assert len(vetos) == 1 and vetos[0]["reason"] == "slo_veto"
    assert srv.metrics.counter(
        "kws_autoscale_decisions_total", action="hold"
    ).value == 1
    # SLO recovers -> the held shrink applies, with its own reason
    assert auto.observe(0.001) == "shrink"
    assert auto.last_decision["action"] == "shrink"
    assert auto.last_decision["reason"] == "occupancy_watermark"
    assert srv.max_streams < 16


# --------------------------------------------------------------------------
# snapshot round-trip + Prometheus exposition
# --------------------------------------------------------------------------

def _exercised_server(qat_backend):
    pipe, params = qat_backend
    srv = StreamingKWSServer(
        pipe, params, max_streams=MAX_STREAMS, metrics=True
    )
    for sid in range(MAX_STREAMS):
        srv.open_stream(sid)
    ing = PipelinedIngress(srv, pipe.config.fex.num_channels, depth=2)
    for s, m in _ticks(pipe, 5, "fv", seed=17):
        slab, mask = ing.stage()
        slab[:] = s
        mask[:] = m
        ing.commit()
    ing.drain()
    return srv


def test_metrics_snapshot_json_round_trip(qat_backend):
    srv = _exercised_server(qat_backend)
    snap = srv.metrics_snapshot()
    assert set(snap) >= {
        "server", "counters", "gauges", "histograms", "journal", "spans"
    }
    sb = snap["server"]
    assert sb["open_streams"] == MAX_STREAMS and sb["occupancy"] == 1.0
    assert sb["retraces"] == srv.retrace_count >= 1
    assert json.loads(json.dumps(snap)) == snap
    # every pipelined tick carried the full span chain
    assert snap["spans"]["stage_to_commit"]["count"] == 5
    assert snap["spans"]["dispatch_to_retire"]["count"] == 5
    assert snap["spans"]["total"]["count"] == 5
    # metrics-off server: the server block alone, still JSON-able.
    # metrics=False (an argparse store_true default) means OFF too —
    # any falsy value must not be treated as a registry
    pipe, params = qat_backend
    off = StreamingKWSServer(
        pipe, params, max_streams=MAX_STREAMS, metrics=False
    )
    assert off.metrics is None
    snap_off = off.metrics_snapshot()
    assert set(snap_off) == {"server"}
    assert json.loads(json.dumps(snap_off)) == snap_off
    assert snap_off["server"]["sparsity_mean"] is None  # no open slots


_SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)"            # metric name
    r'(\{[a-zA-Z_][a-zA-Z0-9_]*="[^"]*"'      # first label
    r'(,[a-zA-Z_][a-zA-Z0-9_]*="[^"]*")*\})?' # more labels
    r" (-?[0-9.e+\-]+|NaN)$"                  # value
)


def test_prometheus_exposition_parses(qat_backend):
    srv = _exercised_server(qat_backend)
    text = srv.metrics.render_prometheus()
    assert text.endswith("\n")
    families = {}
    samples = {}
    for line in text.strip().split("\n"):
        if line.startswith("# TYPE "):
            _, _, name, kind = line.split(" ")
            families[name] = kind
            continue
        if line.startswith("# HELP "):
            continue
        m = _SAMPLE_RE.match(line)
        assert m, f"unparseable sample line: {line!r}"
        samples.setdefault(m.group(1), []).append(line)
    assert families["kws_serve_ticks_total"] == "counter"
    assert families["kws_serve_tick_dispatch_ms"] == "histogram"
    assert families["kws_serve_occupancy"] == "gauge"
    # histogram series: cumulative buckets, +Inf bucket == _count
    for name, kind in families.items():
        if kind != "histogram":
            continue
        buckets = [
            ln for ln in samples.get(name + "_bucket", [])
        ]
        counts = [float(ln.rsplit(" ", 1)[1]) for ln in buckets]
        assert counts == sorted(counts)  # cumulative, non-decreasing
        assert any('le="+Inf"' in ln for ln in buckets)
        total = float(samples[name + "_count"][0].rsplit(" ", 1)[1])
        inf = [ln for ln in buckets if 'le="+Inf"' in ln][0]
        assert float(inf.rsplit(" ", 1)[1]) == total


def test_prometheus_label_escaping():
    reg = MetricsRegistry()
    reg.counter("esc_total", "", path='a"b\\c\nd').inc()
    text = reg.render_prometheus()
    assert r'path="a\"b\\c\nd"' in text


# --------------------------------------------------------------------------
# trace spans + ingress gauges + coalescer flush reasons
# --------------------------------------------------------------------------

def test_ingress_trace_marks_ordered(qat_backend):
    srv = _exercised_server(qat_backend)
    traces = list(srv.metrics.traces)
    assert len(traces) == 5
    for tr in traces:
        assert list(tr.marks) == ["stage", "commit", "dispatch",
                                  "retire"]
        ts = list(tr.marks.values())
        assert ts == sorted(ts)  # marks advance monotonically
    assert srv.metrics.counter(
        "kws_ingress_dispatches_total"
    ).value == 5
    assert srv.metrics.gauge("kws_ingress_in_flight").value == 0.0


def test_span_percentiles_rollup():
    t = [0.0]
    reg = MetricsRegistry(clock=lambda: t[0])
    for k in range(3):
        tr = reg.trace(("tick", k))
        tr.mark("stage", t=0.0)
        tr.mark("commit", t=0.001 * (k + 1))   # 1, 2, 3 ms
        tr.mark("retire", t=0.010)
    spans = span_percentiles(reg.traces)
    assert spans["stage_to_commit"]["count"] == 3
    np.testing.assert_allclose(spans["stage_to_commit"]["mean_ms"], 2.0)
    np.testing.assert_allclose(spans["total"]["mean_ms"], 10.0)
    # traces with < 2 marks contribute nothing
    lone = TickTrace("x", lambda: 0.0)
    lone.mark("stage")
    assert span_percentiles([lone]) == {}


def test_coalescer_flush_reason_counters(qat_backend):
    pipe, params = qat_backend
    srv = StreamingKWSServer(
        pipe, params, max_streams=MAX_STREAMS, metrics=True
    )
    for sid in range(2):
        srv.open_stream(sid)
    clock = [100.0]
    co = TickCoalescer(srv, clock=lambda: clock[0], window_ms=16.0)
    f = np.ones(pipe.config.fex.num_channels, np.float32)

    def flushes(reason):
        return srv.metrics.counter(
            "kws_coalescer_flushes_total", reason=reason
        ).value

    co.add(0, f)
    co.add(1, f)          # every open stream submitted -> "full"
    assert flushes("full") == 1
    co.add(0, f)
    clock[0] += 0.017
    co.poll()             # past the window -> "deadline"
    assert flushes("deadline") == 1
    co.add(0, f)
    co.add(0, 2 * f)      # same stream again -> "second_frame"
    assert flushes("second_frame") == 1
    co.flush()            # the reopened window -> "manual"
    assert flushes("manual") == 1
    co.drain()


# --------------------------------------------------------------------------
# TickHandle.done_at regression: stamped on first ready() poll
# --------------------------------------------------------------------------

def test_tick_handle_done_at_stamped_on_first_ready_poll(qat_backend):
    """done_at marks COMPLETION, not fetch: the first ready() poll that
    observes the tick done stamps it, and a (possibly much later)
    result() must not move it."""
    pipe, params = qat_backend
    srv = StreamingKWSServer(
        pipe, params, max_streams=MAX_STREAMS, metrics=True
    )
    for sid in range(MAX_STREAMS):
        srv.open_stream(sid)
    slab, mask = _ticks(pipe, 1, "fv", seed=23)[0]
    h = srv.step_batch_async(slab, mask)
    while not h.ready():
        pass
    assert h.done_at is not None   # stamped by the poll itself...
    d0 = h.done_at
    h.result()                     # ...and a later fetch keeps it
    assert h.done_at == d0
    # a handle fetched without ever polling still gets a stamp
    h2 = srv.step_batch_async(slab, mask)
    h2.result()
    assert h2.done_at is not None
    # and the fetch itself was observed into the serve-side histogram
    assert srv.metrics.histogram("kws_serve_tick_fetch_ms").count >= 2
