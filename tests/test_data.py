"""Synthetic GSCD corpus: shapes, balance, determinism, separability."""

import numpy as np
import pytest

from repro.data.gscd import (
    CLASSES,
    GSCDSynthConfig,
    batch_iterator,
    make_dataset,
)


def test_classes_structure():
    assert len(CLASSES) == 12
    assert CLASSES[0] == "silence" and CLASSES[1] == "unknown"


def test_dataset_shapes_and_balance():
    d = make_dataset(5, seed=0)
    assert d["audio"].shape == (60, 16000)
    assert d["audio"].dtype == np.float32
    counts = np.bincount(d["label"], minlength=12)
    assert (counts == 5).all()


def test_determinism():
    a = make_dataset(3, seed=7)
    b = make_dataset(3, seed=7)
    np.testing.assert_array_equal(a["audio"], b["audio"])
    c = make_dataset(3, seed=8)
    assert not np.allclose(a["audio"], c["audio"])


def test_amplitude_matches_vtc_range():
    """~250 mVpp drive level: peaks near 0.125 of VTC full scale."""
    d = make_dataset(4, seed=1)
    speech = d["audio"][d["label"] >= 2]
    peaks = np.abs(speech).max(axis=1)
    assert peaks.max() < 0.5
    assert np.median(peaks) > 0.03


def test_silence_is_quiet():
    d = make_dataset(6, seed=2)
    sil = d["audio"][d["label"] == 0]
    speech = d["audio"][d["label"] >= 2]
    assert np.abs(sil).max() < np.median(np.abs(speech).max(axis=1))


def test_unknown_split_differs():
    tr = make_dataset(4, seed=3, unknown_split="train")
    te = make_dataset(4, seed=3, unknown_split="test")
    unk_tr = tr["audio"][tr["label"] == 1]
    unk_te = te["audio"][te["label"] == 1]
    assert not np.allclose(unk_tr, unk_te)


def test_batch_iterator():
    d = make_dataset(4, seed=0)
    batches = list(batch_iterator(d, 16, seed=0))
    assert len(batches) == 3  # 48 of 60 (drop remainder)
    assert batches[0]["audio"].shape == (16, 16000)


@pytest.mark.slow
def test_classes_spectrally_separable():
    """Mean spectra of two different keywords should differ clearly —
    the dataset must carry class information for the KWS task."""
    d = make_dataset(8, seed=0)

    def mean_spec(label):
        xs = d["audio"][d["label"] == label]
        return np.abs(np.fft.rfft(xs, axis=1)).mean(0)

    yes = mean_spec(CLASSES.index("yes"))
    go = mean_spec(CLASSES.index("go"))
    cos = (yes @ go) / (np.linalg.norm(yes) * np.linalg.norm(go))
    assert cos < 0.97
