"""Cascaded always-on wake serving: always-open bit-identity + gating
semantics + wake-rate telemetry (repro.serving.cascade).

The contract under test: the stage-1 detector produces nonnegative
scores, so an always-open gate (`CascadeConfig.always_on()`, i.e.
wake_threshold=0) makes the cascaded server BIT-identical
(assert_array_equal, never allclose) to the non-cascaded server for
EVERY registered classifier backend — fused tick (raw audio + FV
slabs, partial masks), slab ingress, and the lax.scan replay. (The
sharded multi-device twin of these identities lives in
tests/test_serve_sharded.py.) At wake_threshold > 0 the gate must
hold a gated stream's classifier state frozen (optionally decaying
its posterior), honor the hysteresis/hangover state machine, and keep
`srv.wake_rate` exact: reset with the slot, frozen while idle,
identical between live ticks and the scanned replay.

Like the integer/delta identity suites, these tests are fast and run
in the `-m "not slow"` CI selection (and as an explicit CI step).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.core.classifier import get_classifier
from repro.core.fex import fit_norm_stats
from repro.core.gru_delta import DeltaConfig
from repro.core.pipeline import KWSPipeline, KWSPipelineConfig
from repro.serving.cascade import (
    CascadeConfig,
    detector_scores,
    fit_linear_detector,
    gate_step,
    init_state,
    wake_rate,
)
from repro.serving.serve_loop import StreamingKWSServer

CLASSIFIERS = ("float", "qat", "integer", "delta", "delta-int")

# on the Q6.8 grid; energy score 0.0 (all channels below the corpus
# mean, the normalized shape of silence) vs 2.0 (speech-like)
SILENCE_FV = np.full((16,), -1.0, np.float32)
LOUD_FV = np.full((16,), 2.0, np.float32)


# --------------------------------------------------------------------------
# config + detector mechanics
# --------------------------------------------------------------------------

def test_cascade_config_validation():
    with pytest.raises(ValueError, match="detector"):
        CascadeConfig(detector="fft")
    with pytest.raises(ValueError, match="wake_threshold"):
        CascadeConfig(wake_threshold=-0.1)
    with pytest.raises(ValueError, match="release"):
        CascadeConfig(wake_threshold=0.1, release_threshold=0.2)
    with pytest.raises(ValueError, match="release"):
        CascadeConfig(wake_threshold=0.1, release_threshold=-0.05)
    with pytest.raises(ValueError, match="hangover"):
        CascadeConfig(hangover_frames=-1)
    with pytest.raises(ValueError, match="score_decay"):
        CascadeConfig(score_decay=1.5)
    with pytest.raises(ValueError, match="linear_w"):
        CascadeConfig(detector="linear", wake_threshold=0.5)


def test_always_on_is_always_open():
    assert CascadeConfig.always_on().always_open
    assert CascadeConfig().always_open  # default threshold is 0
    assert not CascadeConfig(wake_threshold=0.1).always_open
    # release defaults to wake (no hysteresis band)
    assert CascadeConfig(wake_threshold=0.3).release == 0.3
    assert (
        CascadeConfig(wake_threshold=0.3, release_threshold=0.1).release
        == 0.1
    )


def test_config_hashable_with_linear_weights():
    """linear_w normalizes to a float tuple so the config stays
    hashable (it is closed over statically by the fused tick's jit)."""
    cc = CascadeConfig(
        detector="linear",
        wake_threshold=0.5,
        linear_w=np.ones(16, np.float32),
    )
    assert isinstance(cc.linear_w, tuple)
    assert hash(cc) == hash(dataclasses.replace(cc))


def test_pipeline_binds_cascade_config():
    cc = CascadeConfig(wake_threshold=0.25)
    cfg = KWSPipelineConfig(classifier="qat", cascade=cc)
    assert cfg.cascade is cc
    assert KWSPipelineConfig().cascade is None
    # the cascade composes around the backend, it does not replace it
    assert (
        KWSPipeline(cfg).classifier is get_classifier("qat")
    )


def test_energy_detector_scores():
    fv = jnp.stack([jnp.asarray(SILENCE_FV), jnp.asarray(LOUD_FV)])
    sc = np.asarray(detector_scores(fv, CascadeConfig()))
    np.testing.assert_array_equal(sc, np.asarray([0.0, 2.0], np.float32))
    # mixed frame: mean of the positive channels only
    mixed = jnp.asarray([3.0] * 4 + [-5.0] * 12, jnp.float32)
    assert float(detector_scores(mixed, CascadeConfig())) == pytest.approx(
        12.0 / 16.0
    )


def test_detector_scores_nonnegative():
    """The structural guarantee `always_open` rests on: both detectors
    score >= 0 for any input."""
    fv = jax.random.normal(jax.random.PRNGKey(0), (64, 16)) * 10.0
    assert (np.asarray(detector_scores(fv, CascadeConfig())) >= 0).all()
    lc = CascadeConfig(
        detector="linear", linear_w=tuple(np.linspace(-2, 2, 16))
    )
    sc = np.asarray(detector_scores(fv, lc))
    assert (sc >= 0).all() and (sc <= 1).all()


def test_gate_step_hysteresis_and_hangover():
    """Score trajectory 0.6, 0.3, 0.1, 0.1, 0.1 at wake=0.5,
    release=0.2, hangover=1: the latch holds through 0.3 (inside the
    hysteresis band), drops at 0.1, and the hangover keeps the gate
    open one extra tick."""
    cc = CascadeConfig(
        wake_threshold=0.5, release_threshold=0.2, hangover_frames=1
    )
    st = init_state(1)
    gates, awakes = [], []
    for s in (0.6, 0.3, 0.1, 0.1, 0.1):
        st, gate = gate_step(st, jnp.asarray([s], jnp.float32), cc)
        gates.append(bool(gate[0]))
        awakes.append(bool(st["awake"][0]))
    assert awakes == [True, True, False, False, False]
    assert gates == [True, True, True, False, False]
    assert int(st["woken"][0]) == 3 and int(st["ticks"][0]) == 5
    assert float(wake_rate(st)[0]) == pytest.approx(0.6)


def test_wake_rate_unity_without_traffic():
    np.testing.assert_array_equal(
        np.asarray(wake_rate(init_state(3))), np.ones(3, np.float32)
    )


def test_fit_linear_detector_separates():
    rng = np.random.default_rng(0)
    speech = rng.normal(0.8, 0.4, (300, 16)).astype(np.float32)
    silence = rng.normal(-0.8, 0.4, (300, 16)).astype(np.float32)
    w, b = fit_linear_detector(speech, silence, steps=100)
    cc = CascadeConfig(detector="linear", linear_w=w, linear_b=b)
    s_speech = np.asarray(detector_scores(jnp.asarray(speech), cc))
    s_sil = np.asarray(detector_scores(jnp.asarray(silence), cc))
    assert s_speech.mean() > 0.9 and s_sil.mean() < 0.1


# --------------------------------------------------------------------------
# always-open bit-identity: the whole serving stack, every backend
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def norm_stats():
    rng = np.random.default_rng(0)
    audio = jnp.asarray(
        rng.standard_normal((4, 16000)).astype(np.float32) * 0.05
    )
    boot = KWSPipeline(KWSPipelineConfig(use_norm=False))
    _, raw = boot.features(audio)
    return fit_norm_stats(quant.log_compress_lut(raw, 12, 10))


@pytest.fixture(scope="module")
def shared_params():
    return KWSPipeline(KWSPipelineConfig()).init_params(
        jax.random.PRNGKey(7)
    )


def _server(norm_stats, params, classifier, cascade=None, theta=0.0,
            max_streams=4, tick_impl="auto"):
    pipe = KWSPipeline(
        KWSPipelineConfig(
            classifier=classifier,
            delta=DeltaConfig(theta_x=theta, theta_h=theta),
            cascade=cascade,
        ),
        norm_stats=norm_stats,
    )
    return StreamingKWSServer(
        pipe, params, max_streams=max_streams, tick_impl=tick_impl
    )


def _assert_gru_identical(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)
        ),
        list(a.state.gru),
        list(b.state.gru),
    )


@pytest.mark.parametrize("classifier", CLASSIFIERS)
def test_always_open_bit_identical(norm_stats, shared_params, classifier):
    """`CascadeConfig.always_on()` degenerates the wake mask to the
    submitted mask: every backend's cascaded server matches the plain
    one bit for bit across live ticks (raw audio, rotating partial
    masks), FV slab ingress, and the scan replay."""
    plain = _server(norm_stats, shared_params, classifier)
    casc = _server(
        norm_stats, shared_params, classifier,
        cascade=CascadeConfig.always_on(),
    )
    for s in (plain, casc):
        for sid in range(3):
            s.open_stream(sid)
    hop = plain.pipeline.chunk_samples
    rng = np.random.default_rng(8)
    for t in range(3):  # live raw-audio ticks, rotating partial masks
        slab = rng.standard_normal((4, hop)).astype(np.float32) * 0.05
        mask = np.zeros(4, bool)
        mask[:3] = True
        mask[t % 3] = False
        s_a, t_a = plain.step_batch(slab, mask)
        s_b, t_b = casc.step_batch(slab, mask)
        np.testing.assert_array_equal(s_a, s_b)
        np.testing.assert_array_equal(t_a, t_b)
    # FV_Norm tick on the Q6.8 grid (the documented input contract)
    fv = np.asarray(
        quant.fake_quant(
            jnp.asarray(rng.standard_normal((4, 16)).astype(np.float32)),
            quant.ACT_Q6_8,
        )
    )
    s_a, _ = plain.step_batch(fv, np.ones(4, bool))
    s_b, _ = casc.step_batch(fv, np.ones(4, bool))
    np.testing.assert_array_equal(s_a, s_b)
    # scan replay
    slab = rng.standard_normal((5, 4, hop)).astype(np.float32) * 0.05
    mask = rng.random((5, 4)) < 0.7
    seq_a, tops_a = plain.run_batch(slab, mask)
    seq_b, tops_b = casc.run_batch(slab, mask)
    np.testing.assert_array_equal(seq_a, seq_b)
    np.testing.assert_array_equal(tops_a, tops_b)
    # hidden state + scores identical; every submitted tick woke
    _assert_gru_identical(plain, casc)
    np.testing.assert_array_equal(plain.scores, casc.scores)
    np.testing.assert_array_equal(
        casc.wake_rate, np.ones(4, np.float32)
    )
    # and the plain server reports unity wake rate by definition
    np.testing.assert_array_equal(
        plain.wake_rate, np.ones(4, np.float32)
    )


def test_always_open_linear_detector_bit_identical(
    norm_stats, shared_params
):
    """The guarantee is detector-independent: a trained linear scorer
    at wake_threshold=0 (sigmoid >= 0) is also always-open."""
    rng = np.random.default_rng(13)
    cc = CascadeConfig.always_on(
        detector="linear",
        linear_w=tuple(rng.standard_normal(16)),
        linear_b=-0.3,
    )
    plain = _server(norm_stats, shared_params, "qat")
    casc = _server(norm_stats, shared_params, "qat", cascade=cc)
    for s in (plain, casc):
        s.open_stream(0)
    hop = plain.pipeline.chunk_samples
    for _ in range(4):
        f = rng.standard_normal(hop).astype(np.float32) * 0.05
        a = plain.step({0: f})
        b = casc.step({0: f})
        np.testing.assert_array_equal(a[0]["probs"], b[0]["probs"])
    np.testing.assert_array_equal(casc.wake_rate[casc.active[0]], 1.0)


# --------------------------------------------------------------------------
# gating semantics: frozen hold, decay, hangover, telemetry
# --------------------------------------------------------------------------

def test_silence_stream_never_wakes(norm_stats, shared_params):
    """A pure-silence stream's classifier state stays at its fresh
    zeros (the gate held it asleep from the first tick) and its wake
    rate reads 0."""
    srv = _server(
        norm_stats, shared_params, "qat",
        cascade=CascadeConfig(wake_threshold=0.1),
    )
    srv.open_stream(0)
    slot = srv.active[0]
    for _ in range(5):
        srv.step({0: SILENCE_FV})
    np.testing.assert_array_equal(
        srv.scores[slot], np.zeros(12, np.float32)
    )
    for h in srv.state.gru:
        np.testing.assert_array_equal(
            np.asarray(h)[slot], np.zeros_like(np.asarray(h)[slot])
        )
    assert srv.wake_rate[slot] == 0.0


def test_gate_wakes_holds_and_hangs_over(norm_stats, shared_params):
    """Loud frame wakes the classifier; the hangover keeps it running
    through trailing silence; past the hangover the hidden state holds
    frozen. woken/ticks counters are exact."""
    srv = _server(
        norm_stats, shared_params, "qat",
        cascade=CascadeConfig(wake_threshold=0.1, hangover_frames=2),
    )
    srv.open_stream(0)
    slot = srv.active[0]
    srv.step({0: LOUD_FV})
    assert srv.wake_rate[slot] == 1.0
    assert np.any(srv.scores[slot] != 0)
    for _ in range(4):
        srv.step({0: SILENCE_FV})
    # woken = 1 (loud) + 2 (hangover) of 5 submitted ticks
    det = srv.state.det
    assert int(np.asarray(det["woken"])[slot]) == 3
    assert int(np.asarray(det["ticks"])[slot]) == 5
    assert srv.wake_rate[slot] == pytest.approx(3 / 5)
    # fully gated now: further silence leaves the classifier state
    # bit-identical (frozen hold; default score_decay=1.0)
    h_before = [np.asarray(h)[slot].copy() for h in srv.state.gru]
    s_before = srv.scores[slot].copy()
    srv.step({0: SILENCE_FV})
    for h, hb in zip(srv.state.gru, h_before):
        np.testing.assert_array_equal(np.asarray(h)[slot], hb)
    np.testing.assert_array_equal(srv.scores[slot], s_before)


def test_score_decay_on_gated_ticks(norm_stats, shared_params):
    """score_decay < 1 forgets a stale detection while the classifier
    sleeps: each gated tick multiplies the held posterior exactly."""
    srv = _server(
        norm_stats, shared_params, "qat",
        cascade=CascadeConfig(wake_threshold=0.1, score_decay=0.5),
    )
    srv.open_stream(0)
    slot = srv.active[0]
    srv.step({0: LOUD_FV})
    s0 = srv.scores[slot].copy()
    srv.step({0: SILENCE_FV})  # gated: no hangover configured
    np.testing.assert_array_equal(srv.scores[slot], s0 * np.float32(0.5))
    srv.step({0: SILENCE_FV})
    np.testing.assert_array_equal(srv.scores[slot], s0 * np.float32(0.25))


def test_wake_telemetry_idle_freeze_and_slot_reset(
    norm_stats, shared_params
):
    """`srv.wake_rate` has the `srv.sparsity` telemetry contract:
    frozen while the stream idles (other streams' traffic is
    invisible), reset with the slot on open_stream."""
    srv = _server(
        norm_stats, shared_params, "qat",
        cascade=CascadeConfig(wake_threshold=0.1),
    )
    srv.open_stream(0)
    srv.open_stream(1)
    slot1 = srv.active[1]
    srv.step({0: LOUD_FV, 1: LOUD_FV})
    srv.step({0: SILENCE_FV, 1: SILENCE_FV})
    wr_before = srv.wake_rate[slot1]
    assert wr_before == pytest.approx(0.5)
    for fv in (LOUD_FV, SILENCE_FV, LOUD_FV):  # stream 1 idles
        srv.step({0: fv})
    assert srv.wake_rate[slot1] == wr_before
    # close + reopen: the reused slot's gate state starts fresh
    srv.close_stream(1)
    srv.open_stream(99)
    assert srv.active[99] == slot1
    det = srv.state.det
    for leaf in det.values():
        assert np.asarray(leaf)[slot1] == 0
    assert srv.wake_rate[slot1] == 1.0


def test_scan_replay_matches_live_ticks(norm_stats, shared_params):
    """The gate is exact under `lax.scan`: replaying a slab through
    run_batch leaves scores AND detector counters bit-identical to the
    same traffic through live step_batch ticks."""
    cc = CascadeConfig(wake_threshold=0.1, hangover_frames=1)
    live = _server(norm_stats, shared_params, "qat", cascade=cc)
    scan = _server(norm_stats, shared_params, "qat", cascade=cc)
    for s in (live, scan):
        for sid in range(3):
            s.open_stream(sid)
    rng = np.random.default_rng(21)
    slab = np.zeros((6, 4, 16), np.float32)
    for t in range(6):
        for n in range(4):
            slab[t, n] = LOUD_FV if rng.random() < 0.4 else SILENCE_FV
    mask = rng.random((6, 4)) < 0.7
    for t in range(6):
        live.step_batch(slab[t], mask[t])
    scan.run_batch(slab, mask)
    np.testing.assert_array_equal(live.scores, scan.scores)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)
        ),
        live.state.det,
        scan.state.det,
    )
    np.testing.assert_array_equal(live.wake_rate, scan.wake_rate)


def test_cascade_composes_with_delta(norm_stats, shared_params):
    """Cascade x ΔGRU: gated ticks freeze the delta MAC counters (the
    classifier never ran), so `srv.sparsity` measures sparsity WITHIN
    the woken ticks — the factor that multiplies with `srv.wake_rate`
    in the energy model."""
    srv = _server(
        norm_stats, shared_params, "delta", theta=0.25,
        cascade=CascadeConfig(wake_threshold=0.1),
    )
    srv.open_stream(0)
    slot = srv.active[0]
    srv.step({0: LOUD_FV})
    totals_after_wake = [
        int(np.asarray(st["total"])[slot]) for st in srv.state.gru
    ]
    assert all(t > 0 for t in totals_after_wake)
    sparsity_after_wake = srv.sparsity[slot]
    for _ in range(3):
        srv.step({0: SILENCE_FV})
    totals_after_gate = [
        int(np.asarray(st["total"])[slot]) for st in srv.state.gru
    ]
    assert totals_after_gate == totals_after_wake
    assert srv.sparsity[slot] == sparsity_after_wake
    assert srv.wake_rate[slot] == pytest.approx(1 / 4)


@pytest.mark.parametrize("classifier", ("qat", "delta"))
def test_cascaded_fused_tick_bit_identical(
    norm_stats, shared_params, classifier
):
    """The megakernel tick (interpret tier) reproduces the cascaded
    server bit for bit at a REAL wake threshold: frozen gated state,
    score decay, and the wake telemetry all survive block slicing."""
    casc = CascadeConfig(wake_threshold=0.1, score_decay=0.9)
    a = _server(norm_stats, shared_params, classifier, cascade=casc,
                theta=0.25, tick_impl="xla")
    b = _server(norm_stats, shared_params, classifier, cascade=casc,
                theta=0.25, tick_impl="fused-interpret")
    for s in (a, b):
        for sid in range(2):
            s.open_stream(sid)
    # alternate loud and silent frames so the gate actually closes
    for t in range(4):
        fv = LOUD_FV if t % 2 == 0 else SILENCE_FV
        o_a = a.step({0: fv, 1: SILENCE_FV})
        o_b = b.step({0: fv, 1: SILENCE_FV})
        for sid in (0, 1):
            np.testing.assert_array_equal(
                o_a[sid]["probs"], o_b[sid]["probs"]
            )
    _assert_gru_identical(a, b)
    np.testing.assert_array_equal(a.wake_rate, b.wake_rate)
    np.testing.assert_array_equal(a.sparsity, b.sparsity)
