"""Optimizer: AdamW math, int8 moments, schedules."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.training.optimizer import (
    AdamWConfig,
    ReduceLROnPlateau,
    adamw_update,
    cosine_schedule,
    init_opt_state,
)


def _quadratic_losses(cfg, steps=60, dim=512):
    """Minimize ||x - t||^2 from a fixed start; returns loss trajectory.

    dim=512 makes the (16, 512) weight big enough for the int8-moment
    path (>= 4096 elements)."""
    target = jnp.asarray(
        np.random.default_rng(0).standard_normal((16, dim)), jnp.float32
    )
    params = {"w": jnp.zeros((16, dim), jnp.float32)}
    state = init_opt_state(params, cfg)
    losses = []
    for _ in range(steps):
        loss, g = jax.value_and_grad(
            lambda p: jnp.mean((p["w"] - target) ** 2)
        )(params)
        params, state, _ = adamw_update(params, g, state, cfg)
        losses.append(float(loss))
    return losses


def test_adamw_first_step_matches_reference():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=1e9)
    params = {"w": jnp.asarray([1.0, -2.0])}
    grads = {"w": jnp.asarray([0.5, -0.5])}
    state = init_opt_state(params, cfg)
    new_p, state, metrics = adamw_update(params, grads, state, cfg)
    # bias-corrected first Adam step == -lr * sign-ish update
    m_hat = 0.1 * jnp.asarray([0.5, -0.5]) / 0.1
    v_hat = 0.001 * jnp.asarray([0.25, 0.25]) / 0.001
    ref = params["w"] - 0.1 * m_hat / (jnp.sqrt(v_hat) + cfg.eps)
    np.testing.assert_allclose(np.asarray(new_p["w"]), np.asarray(ref), rtol=1e-5)
    assert abs(float(metrics["grad_norm"]) - float(jnp.sqrt(0.5))) < 1e-5


def test_grad_clip_applies():
    cfg = AdamWConfig(lr=1.0, grad_clip=0.001, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    grads = {"w": jnp.full(4, 100.0)}
    state = init_opt_state(params, cfg)
    new_p, _, _ = adamw_update(params, grads, state, cfg)
    # direction preserved, magnitude bounded by lr (Adam normalizes) —
    # mostly checks no NaN/exploding behavior under clipping
    assert bool(jnp.isfinite(new_p["w"]).all())


def test_fp32_and_int8_states_converge_similarly():
    fp = _quadratic_losses(AdamWConfig(lr=0.05, weight_decay=0.0))
    q8 = _quadratic_losses(
        AdamWConfig(lr=0.05, weight_decay=0.0, state_dtype="int8")
    )
    assert fp[-1] < 0.3 * fp[0]
    assert q8[-1] < 0.3 * q8[0]
    assert abs(q8[-1] - fp[-1]) < 0.2 * fp[0]  # int8 tracks fp32


def test_int8_state_only_for_big_leaves():
    cfg = AdamWConfig(state_dtype="int8")
    params = {
        "big": jnp.zeros((128, 128)),
        "small": jnp.zeros((16,)),
    }
    st = init_opt_state(params, cfg)
    assert isinstance(st["m"]["big"], dict) and "q" in st["m"]["big"]
    assert st["m"]["big"]["q"].dtype == jnp.int8
    assert st["m"]["small"].dtype == jnp.float32


def test_bf16_params_updated_via_fp32_math():
    cfg = AdamWConfig(lr=0.01, weight_decay=0.0)
    params = {"w": jnp.ones(4, jnp.bfloat16)}
    grads = {"w": jnp.full(4, 0.1, jnp.bfloat16)}
    state = init_opt_state(params, cfg)
    new_p, _, _ = adamw_update(params, grads, state, cfg)
    assert new_p["w"].dtype == jnp.bfloat16
    assert float(new_p["w"][0]) < 1.0


def test_reduce_lr_on_plateau_matches_paper_recipe():
    s = ReduceLROnPlateau(lr=1e-3, factor=0.8, patience=3, min_lr=5e-4)
    # improving: lr stays
    for m in [1.0, 0.9, 0.8]:
        assert s.step(m) == 1e-3
    # plateau of patience+1 epochs drops lr by 0.8
    for m in [0.8, 0.8, 0.8]:
        s.step(0.8)
    lr = s.step(0.8)
    assert abs(lr - 8e-4) < 1e-12
    # floor at 5e-4
    for _ in range(40):
        lr = s.step(0.8)
    assert lr == 5e-4


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.int32(100))) < 1e-5
