"""Sharded multi-device serving, proven bit-identical on an emulated mesh.

tests/conftest.py forces an 8-device emulated CPU host platform (before
the first jax import, guarded against a user-set flag), so this suite
runs on any plain CPU runner. It proves the stream-parallel
`StreamingKWSServer` (slot axis sharded over a 1-D ``("stream",)``
mesh) is BIT-identical — `np.testing.assert_array_equal`, never
allclose — to the single-device server for every classifier backend
("float" / "qat" / "integer" / "delta" / "delta-int"), across live
ticks (`step` / `step_batch`), the scanned replay (`run_batch`),
idle-stream isolation, and slot-reuse hygiene across shard boundaries.
The ΔGRU backends additionally get a cross-backend check: a θ=0 delta
server sharded over the mesh must bit-match its dense base backend's
single-device server (the temporal-sparsity engine survives
partitioning), with the sparsity telemetry consistent across shards.
The cascade subsystem (`repro.serving.cascade`) gets the same
treatment: an always-open cascaded server sharded over the mesh must
bit-match the plain single-device server for every backend, and at a
real wake threshold the per-stream `srv.wake_rate` telemetry must be
placement-independent. A hypothesis property
test drives random open/close/submit schedules against a pure-Python
lifecycle oracle: a stream's scores depend only on its own submitted
frames, never on other streams' traffic or its device placement — a
cascaded variant additionally asserts `wake_rate` resets on
open_stream, freezes while idle, and is placement-independent. The
donation-hazard regression (step twice without fetching scores in
between) runs here for the sharded path and in
tests/test_pipeline_serving.py for the single-device path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.core.fex import fit_norm_stats
from repro.core.pipeline import KWSPipeline, KWSPipelineConfig
from repro.distributed.sharding import STREAM_AXIS, stream_mesh
from repro.serving.autoscale import StreamRouter, shard_of_slot
from repro.serving.cascade import CascadeConfig
from repro.serving.serve_loop import StreamingKWSServer

from _hypothesis_compat import given, settings, st

N_DEV = len(jax.devices())
pytestmark = pytest.mark.skipif(
    N_DEV < 2,
    reason="needs a multi-device platform (conftest forces 8 emulated "
    "CPU devices unless XLA_FLAGS overrides it)",
)

MAX_STREAMS = 16
# largest power-of-two mesh (<= 8 devices) the slot axis divides, so a
# user-forced odd device count (the conftest guard allows e.g. =6)
# degrades to a smaller mesh instead of erroring the whole suite
MESH_DEV = max(d for d in (2, 4, 8) if d <= min(8, N_DEV)) if N_DEV >= 2 else 1

CLASSIFIERS = ("float", "qat", "integer", "delta", "delta-int")


@pytest.fixture(scope="module")
def norm_stats():
    rng = np.random.default_rng(0)
    audio = jnp.asarray(
        rng.standard_normal((4, 16000)).astype(np.float32) * 0.05
    )
    boot = KWSPipeline(KWSPipelineConfig(use_norm=False))
    _, raw = boot.features(audio)
    return fit_norm_stats(quant.log_compress_lut(raw, 12, 10))


@pytest.fixture(scope="module", params=CLASSIFIERS)
def backend(request, norm_stats):
    """(pipeline, params) per classifier backend, built once."""
    pipe = KWSPipeline(
        KWSPipelineConfig(classifier=request.param), norm_stats=norm_stats
    )
    return pipe, pipe.init_params(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def server_pair(backend):
    """Matched (single-device, sharded) servers on the same params."""
    pipe, params = backend
    single = StreamingKWSServer(pipe, params, max_streams=MAX_STREAMS)
    sharded = StreamingKWSServer(
        pipe, params, max_streams=MAX_STREAMS, devices=MESH_DEV
    )
    return single, sharded


def _reset_pair(pair):
    """Close every open stream on both servers (fixtures are
    module-scoped; open_stream zeroes the reused slot, so close+open is
    a full per-example reset)."""
    for srv in pair:
        for sid in list(srv.active):
            srv.close_stream(sid)


def _state_leaves(srv):
    return [
        np.asarray(leaf).copy()
        for leaf in jax.tree_util.tree_leaves(srv.state)
    ]


def _assert_states_identical(a, b):
    for la, lb in zip(_state_leaves(a), _state_leaves(b)):
        np.testing.assert_array_equal(la, lb)


def _slot_slice(srv, sid):
    slot = srv.active[sid]
    return jax.tree_util.tree_map(
        lambda t: np.asarray(t[slot]).copy(), srv.state
    )


# --------------------------------------------------------------------------
# mesh construction + fallback
# --------------------------------------------------------------------------

def test_sharded_server_places_state_on_mesh(server_pair):
    _, sharded = server_pair
    assert sharded.n_devices == MESH_DEV
    assert sharded.mesh is not None
    assert sharded.mesh.axis_names == (STREAM_AXIS,)
    for leaf in jax.tree_util.tree_leaves(sharded.state):
        spec = leaf.sharding.spec
        assert spec and spec[0] == STREAM_AXIS, spec
        assert len(leaf.devices()) == MESH_DEV
    # params replicate: every leaf lives whole on every device
    for leaf in jax.tree_util.tree_leaves(sharded.params):
        assert leaf.sharding.is_fully_replicated


def test_single_visible_device_falls_back(backend):
    pipe, params = backend
    srv = StreamingKWSServer(pipe, params, max_streams=4, devices=1)
    assert srv.mesh is None and srv.n_devices == 1
    # a size-1 mesh also falls back to the plain single-device program
    srv1 = StreamingKWSServer(
        pipe, params, max_streams=4, mesh=stream_mesh(1)
    )
    assert srv1.mesh is None and srv1.n_devices == 1


def test_constructor_validation(backend):
    pipe, params = backend
    with pytest.raises(ValueError, match="divide over"):
        StreamingKWSServer(
            pipe, params, max_streams=9, devices=MESH_DEV
        )
    with pytest.raises(ValueError, match="not both"):
        StreamingKWSServer(
            pipe, params, max_streams=8, mesh=stream_mesh(2), devices=2
        )
    with pytest.raises(ValueError, match="visible"):
        StreamingKWSServer(
            pipe, params, max_streams=8, devices=N_DEV + 1
        )


# --------------------------------------------------------------------------
# bit-identity: sharded == single-device, all backends, all entry points
# --------------------------------------------------------------------------

def test_step_batch_bit_identical(server_pair):
    """Live fused ticks (raw-audio and FV_Norm slabs, partial masks):
    scores, argmax, and the full ServerState match bit for bit."""
    single, sharded = server_pair
    _reset_pair(server_pair)
    pipe = single.pipeline
    for srv in (single, sharded):
        for sid in range(MAX_STREAMS):
            srv.open_stream(sid)
    rng = np.random.default_rng(1)
    hop = pipe.chunk_samples
    for t in range(3):  # raw-audio ticks, rotating partial masks
        slab = rng.standard_normal((MAX_STREAMS, hop)).astype(np.float32)
        slab *= 0.05
        mask = np.ones(MAX_STREAMS, bool)
        mask[t::3] = False
        s_a, t_a = single.step_batch(slab, mask)
        s_b, t_b = sharded.step_batch(slab, mask)
        np.testing.assert_array_equal(s_a, s_b)
        np.testing.assert_array_equal(t_a, t_b)
    fv = rng.standard_normal((MAX_STREAMS, 16)).astype(np.float32)
    s_a, t_a = single.step_batch(fv, np.ones(MAX_STREAMS, bool))
    s_b, t_b = sharded.step_batch(fv, np.ones(MAX_STREAMS, bool))
    np.testing.assert_array_equal(s_a, s_b)
    np.testing.assert_array_equal(t_a, t_b)
    _assert_states_identical(single, sharded)


def test_run_batch_bit_identical(server_pair):
    """The lax.scan replay lowers to one SPMD program whose whole
    (n_ticks, N, K) trajectory matches the single-device scan."""
    single, sharded = server_pair
    _reset_pair(server_pair)
    pipe = single.pipeline
    for srv in (single, sharded):
        for sid in range(MAX_STREAMS):
            srv.open_stream(sid)
    rng = np.random.default_rng(2)
    hop = pipe.chunk_samples
    slab = rng.standard_normal((4, MAX_STREAMS, hop)).astype(np.float32)
    slab *= 0.05
    mask = rng.random((4, MAX_STREAMS)) < 0.7
    seq_a, tops_a = single.run_batch(slab, mask)
    seq_b, tops_b = sharded.run_batch(slab, mask)
    np.testing.assert_array_equal(seq_a, seq_b)
    np.testing.assert_array_equal(tops_a, tops_b)
    _assert_states_identical(single, sharded)


def test_fused_tick_sharded_bit_identical(backend):
    """tick_impl="fused-interpret" on the mesh: the megakernel runs
    once per shard-local slab under `shard_map` (GSPMD cannot partition
    a pallas_call), and the result still matches the single-device
    xla-tick server bit for bit — for every backend."""
    pipe, params = backend
    single = StreamingKWSServer(
        pipe, params, max_streams=8, tick_impl="xla"
    )
    sharded = StreamingKWSServer(
        pipe, params, max_streams=8, devices=MESH_DEV,
        tick_impl="fused-interpret",
    )
    assert sharded.tick_dispatch == "interpret"
    for srv in (single, sharded):
        for sid in range(8):
            srv.open_stream(sid)
    rng = np.random.default_rng(6)
    hop = pipe.chunk_samples
    for t in range(3):
        slab = rng.standard_normal((8, hop)).astype(np.float32) * 0.05
        mask = np.ones(8, bool)
        mask[t::3] = False
        s_a, t_a = single.step_batch(slab, mask)
        s_b, t_b = sharded.step_batch(slab, mask)
        np.testing.assert_array_equal(s_a, s_b)
        np.testing.assert_array_equal(t_a, t_b)
    _assert_states_identical(single, sharded)


def test_dict_step_bit_identical_across_placements(server_pair):
    """`step` with {sid: frame} dicts: the sharded router places the
    same stream ids on different slots/shards than the single-device
    free list, yet every stream's posteriors match bit for bit —
    placement independence."""
    single, sharded = server_pair
    _reset_pair(server_pair)
    for srv in (single, sharded):
        for sid in range(6):
            srv.open_stream(sid)
    # same ids, different slots (round-robin vs first-free)
    assert single.active != sharded.active
    rng = np.random.default_rng(3)
    for _ in range(3):
        frames = {
            sid: rng.standard_normal(16).astype(np.float32)
            for sid in range(6)
        }
        out_a = single.step(frames)
        out_b = sharded.step(frames)
        for sid in frames:
            np.testing.assert_array_equal(
                out_a[sid]["probs"], out_b[sid]["probs"]
            )
            assert out_a[sid]["top"] == out_b[sid]["top"]


# --------------------------------------------------------------------------
# isolation + slot hygiene across shard boundaries
# --------------------------------------------------------------------------

def test_idle_stream_isolation_across_shards(server_pair):
    """A stream idling on one shard is bit-identical across ticks that
    only touch streams on OTHER shards (the temporal-sparsity contract
    survives partitioning)."""
    _, sharded = server_pair
    _reset_pair(server_pair)
    # round-robin: sids 0..MESH_DEV-1 land one per shard
    for sid in range(MESH_DEV):
        sharded.open_stream(sid)
    shards = {
        sid: shard_of_slot(sharded.active[sid], MAX_STREAMS, MESH_DEV)
        for sid in range(MESH_DEV)
    }
    assert sorted(shards.values()) == list(range(MESH_DEV))
    rng = np.random.default_rng(4)
    fv = rng.standard_normal(16).astype(np.float32)
    sharded.step({sid: fv for sid in range(MESH_DEV)})
    idle_before = _slot_slice(sharded, 0)
    for _ in range(3):  # stream 0 (shard 0) idles; every other shard ticks
        sharded.step({
            sid: rng.standard_normal(16).astype(np.float32)
            for sid in range(1, MESH_DEV)
        })
    idle_after = _slot_slice(sharded, 0)
    jax.tree_util.tree_map(
        np.testing.assert_array_equal, idle_before, idle_after
    )


def test_slot_reuse_hygiene_across_shards(server_pair):
    """close -> reopen on a non-zero shard hands out a fully zeroed
    slot while every other slot (on every shard) is untouched."""
    _, sharded = server_pair
    _reset_pair(server_pair)
    for sid in range(MAX_STREAMS):
        sharded.open_stream(sid)
    rng = np.random.default_rng(5)
    fv = rng.standard_normal((MAX_STREAMS, 16)).astype(np.float32)
    sharded.step_batch(fv, np.ones(MAX_STREAMS, bool))
    victim = next(
        sid for sid in sharded.active
        if shard_of_slot(sharded.active[sid], MAX_STREAMS, MESH_DEV)
        == MESH_DEV - 1
    )
    victim_slot = sharded.active[victim]
    before = _state_leaves(sharded)
    sharded.close_stream(victim)
    sharded.open_stream(999)  # only free slot -> must reuse it
    assert sharded.active[999] == victim_slot
    reused = _slot_slice(sharded, 999)
    jax.tree_util.tree_map(
        lambda t: np.testing.assert_array_equal(t, np.zeros_like(t)),
        reused,
    )
    after = _state_leaves(sharded)
    for la, lb in zip(before, after):
        la[victim_slot] = 0  # the reused slot is the ONLY change
        np.testing.assert_array_equal(la, lb)


def test_router_round_robin_balance():
    """Slot allocation keeps shard loads within 1 at every point of an
    open/close sequence, and placement matches the block mapping."""
    r = StreamRouter(MAX_STREAMS, MESH_DEV)
    slots = []
    for _ in range(MAX_STREAMS):
        slot = r.acquire()
        slots.append(slot)
        loads = r.shard_loads()
        assert max(loads) - min(loads) <= 1, loads
        p = r.placement(slot)
        assert p.shard == shard_of_slot(slot, MAX_STREAMS, MESH_DEV)
        assert p.slot == slot
    assert sorted(slots) == list(range(MAX_STREAMS))
    with pytest.raises(RuntimeError, match="capacity"):
        r.acquire()
    # releases rebalance: freeing two slots on one shard makes it the
    # next two allocation targets
    shard0 = [s for s in slots if shard_of_slot(s, MAX_STREAMS, MESH_DEV) == 0]
    for s in shard0[:2]:
        r.release(s)
    got = [r.acquire(), r.acquire()]
    assert sorted(got) == sorted(shard0[:2])
    # single-shard router preserves the pre-sharding lowest-first order
    r1 = StreamRouter(4, 1)
    assert [r1.acquire() for _ in range(4)] == [0, 1, 2, 3]


# --------------------------------------------------------------------------
# donation hazard (sharded path; single-device twin lives in
# tests/test_pipeline_serving.py)
# --------------------------------------------------------------------------

def test_step_twice_keeps_first_scores_sharded(server_pair):
    """Two ticks back-to-back without fetching `scores` in between: the
    first tick's returned arrays must own their memory and stay intact
    (a zero-copy view would alias a buffer donated to tick 2)."""
    _, sharded = server_pair
    _reset_pair(server_pair)
    for sid in range(MAX_STREAMS):
        sharded.open_stream(sid)
    rng = np.random.default_rng(6)
    mask = np.ones(MAX_STREAMS, bool)
    fv1 = rng.standard_normal((MAX_STREAMS, 16)).astype(np.float32)
    fv2 = rng.standard_normal((MAX_STREAMS, 16)).astype(np.float32)
    s1, t1 = sharded.step_batch(fv1, mask)
    assert s1.flags["OWNDATA"] and t1.flags["OWNDATA"]
    snap_s, snap_t = s1.copy(), t1.copy()
    view = sharded.scores
    assert view.flags["OWNDATA"]
    sharded.step_batch(fv2, mask)
    sharded.step_batch(fv1, mask)
    np.testing.assert_array_equal(s1, snap_s)
    np.testing.assert_array_equal(t1, snap_t)
    np.testing.assert_array_equal(view, snap_s)


# --------------------------------------------------------------------------
# ΔGRU: θ=0 sharded delta server == single-device dense base server
# --------------------------------------------------------------------------

@pytest.mark.parametrize(
    "delta_key,base_key", [("delta", "qat"), ("delta-int", "integer")]
)
def test_sharded_delta_matches_dense_base(norm_stats, delta_key, base_key):
    """Cross-backend AND cross-placement: the θ=0 ΔGRU server sharded
    over the emulated mesh bit-matches the dense base backend's
    single-device server — scores, argmax, and the hidden-state
    trajectory — for live slab ticks and the scanned replay. The
    per-stream sparsity telemetry survives partitioning (counters are
    just more sharded state leaves)."""
    pipe_delta = KWSPipeline(
        KWSPipelineConfig(classifier=delta_key), norm_stats=norm_stats
    )
    pipe_base = KWSPipeline(
        KWSPipelineConfig(classifier=base_key), norm_stats=norm_stats
    )
    params = pipe_base.init_params(jax.random.PRNGKey(13))
    dense = StreamingKWSServer(pipe_base, params, max_streams=MAX_STREAMS)
    sharded = StreamingKWSServer(
        pipe_delta, params, max_streams=MAX_STREAMS, devices=MESH_DEV
    )
    for srv in (dense, sharded):
        for sid in range(MAX_STREAMS):
            srv.open_stream(sid)
    hop = pipe_base.chunk_samples
    rng = np.random.default_rng(14)
    for t in range(3):
        slab = rng.standard_normal((MAX_STREAMS, hop)).astype(np.float32)
        slab *= 0.05
        mask = np.ones(MAX_STREAMS, bool)
        mask[t::3] = False
        s_a, t_a = dense.step_batch(slab, mask)
        s_b, t_b = sharded.step_batch(slab, mask)
        np.testing.assert_array_equal(s_a, s_b)
        np.testing.assert_array_equal(t_a, t_b)
    slab = rng.standard_normal((4, MAX_STREAMS, hop)).astype(np.float32)
    slab *= 0.05
    mask = rng.random((4, MAX_STREAMS)) < 0.7
    seq_a, tops_a = dense.run_batch(slab, mask)
    seq_b, tops_b = sharded.run_batch(slab, mask)
    np.testing.assert_array_equal(seq_a, seq_b)
    np.testing.assert_array_equal(tops_a, tops_b)
    # the delta server's true hidden state tracks the dense server's
    for hb, std in zip(dense.state.gru, sharded.state.gru):
        np.testing.assert_array_equal(
            np.asarray(hb), np.asarray(std["h"])
        )
    # telemetry: dense base reports all-ones, the sharded delta server
    # a valid fraction per slot (θ=0 still skips exactly-repeated
    # components), gathered transparently from the sharded counters
    np.testing.assert_array_equal(
        dense.sparsity, np.ones(MAX_STREAMS, np.float32)
    )
    frac = sharded.sparsity
    assert frac.shape == (MAX_STREAMS,)
    assert ((frac >= 0.0) & (frac <= 1.0)).all()


def test_sharded_delta_sparsity_matches_single_device(norm_stats):
    """The measured per-stream effective-MAC fraction is placement-
    independent: a θ>0 sharded delta server reports bit-identical
    sparsity to its single-device twin on the same traffic."""
    from repro.core.gru_delta import DeltaConfig

    pipe = KWSPipeline(
        KWSPipelineConfig(
            classifier="delta",
            delta=DeltaConfig(theta_x=0.25, theta_h=0.25),
        ),
        norm_stats=norm_stats,
    )
    params = pipe.init_params(jax.random.PRNGKey(15))
    single = StreamingKWSServer(pipe, params, max_streams=MAX_STREAMS)
    sharded = StreamingKWSServer(
        pipe, params, max_streams=MAX_STREAMS, devices=MESH_DEV
    )
    for srv in (single, sharded):
        for sid in range(MAX_STREAMS):
            srv.open_stream(sid)
    hop = pipe.chunk_samples
    rng = np.random.default_rng(16)
    base = rng.standard_normal((MAX_STREAMS, hop)).astype(np.float32) * 0.05
    for t in range(4):
        slab = base + rng.standard_normal(
            (MAX_STREAMS, hop)
        ).astype(np.float32) * 0.002
        mask = np.ones(MAX_STREAMS, bool)
        single.step_batch(slab, mask)
        sharded.step_batch(slab, mask)
    np.testing.assert_array_equal(single.sparsity, sharded.sparsity)
    assert (sharded.sparsity < 1.0).all()  # near-static traffic skips


# --------------------------------------------------------------------------
# cascade: always-open sharded server == plain single-device server
# --------------------------------------------------------------------------

def test_sharded_cascade_always_open_matches_plain(backend):
    """Cross-config AND cross-placement: an always-open cascaded server
    sharded over the emulated mesh bit-matches the NON-cascaded
    single-device server — scores, argmax, hidden states — for live
    slab ticks and the scanned replay, for every backend. The gate
    mask degenerates to the submitted mask, so the extra detector
    leaves in `ServerState` change nothing downstream."""
    pipe, params = backend
    import dataclasses as _dc

    pipe_casc = KWSPipeline(
        _dc.replace(pipe.config, cascade=CascadeConfig.always_on()),
        norm_stats=pipe.norm_stats,
    )
    plain = StreamingKWSServer(pipe, params, max_streams=MAX_STREAMS)
    sharded = StreamingKWSServer(
        pipe_casc, params, max_streams=MAX_STREAMS, devices=MESH_DEV
    )
    for srv in (plain, sharded):
        for sid in range(MAX_STREAMS):
            srv.open_stream(sid)
    hop = pipe.chunk_samples
    rng = np.random.default_rng(17)
    for t in range(3):
        slab = rng.standard_normal((MAX_STREAMS, hop)).astype(np.float32)
        slab *= 0.05
        mask = np.ones(MAX_STREAMS, bool)
        mask[t::3] = False
        s_a, t_a = plain.step_batch(slab, mask)
        s_b, t_b = sharded.step_batch(slab, mask)
        np.testing.assert_array_equal(s_a, s_b)
        np.testing.assert_array_equal(t_a, t_b)
    slab = rng.standard_normal((4, MAX_STREAMS, hop)).astype(np.float32)
    slab *= 0.05
    mask = rng.random((4, MAX_STREAMS)) < 0.7
    seq_a, tops_a = plain.run_batch(slab, mask)
    seq_b, tops_b = sharded.run_batch(slab, mask)
    np.testing.assert_array_equal(seq_a, seq_b)
    np.testing.assert_array_equal(tops_a, tops_b)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)
        ),
        list(plain.state.gru),
        list(sharded.state.gru),
    )
    np.testing.assert_array_equal(plain.scores, sharded.scores)
    # every submitted tick woke the classifier, on every shard
    np.testing.assert_array_equal(
        sharded.wake_rate, np.ones(MAX_STREAMS, np.float32)
    )
    # detector leaves are sharded over the stream axis like the rest
    for leaf in jax.tree_util.tree_leaves(sharded.state.det):
        spec = leaf.sharding.spec
        assert spec and spec[0] == STREAM_AXIS, spec


def test_sharded_cascade_wake_rate_matches_single_device(norm_stats):
    """The measured per-stream wake rate is placement-independent: a
    gated sharded server reports bit-identical `wake_rate` (and
    scores) to its single-device twin on the same mixed loud/quiet
    traffic."""
    pipe = KWSPipeline(
        KWSPipelineConfig(
            classifier="qat",
            cascade=CascadeConfig(wake_threshold=0.3, hangover_frames=1),
        ),
        norm_stats=norm_stats,
    )
    params = pipe.init_params(jax.random.PRNGKey(18))
    single = StreamingKWSServer(pipe, params, max_streams=MAX_STREAMS)
    sharded = StreamingKWSServer(
        pipe, params, max_streams=MAX_STREAMS, devices=MESH_DEV
    )
    for srv in (single, sharded):
        for sid in range(MAX_STREAMS):
            srv.open_stream(sid)
    rng = np.random.default_rng(19)
    for _ in range(6):
        # half the slots get speech-loud frames, half near-silence
        scale = np.where(rng.random(MAX_STREAMS) < 0.5, 3.0, 0.02)
        fv = (
            rng.standard_normal((MAX_STREAMS, 16)) * scale[:, None]
        ).astype(np.float32)
        mask = rng.random(MAX_STREAMS) < 0.8
        s_a, _ = single.step_batch(fv, mask)
        s_b, _ = sharded.step_batch(fv, mask)
        np.testing.assert_array_equal(s_a, s_b)
    np.testing.assert_array_equal(single.wake_rate, sharded.wake_rate)
    wr = sharded.wake_rate
    assert (wr < 1.0).any() and (wr > 0.0).any()  # the gate really gated


# --------------------------------------------------------------------------
# property test: random lifecycles vs a pure-Python oracle
# --------------------------------------------------------------------------

class LifecycleOracle:
    """Pure-Python model of the sharded server's stream lifecycles.

    Tracks, with no device code: which streams are open, every frame
    each stream submitted since it was (re)opened, and the slot each
    stream must occupy (an independent reimplementation of the
    round-robin placement). The expected posteriors for a stream are
    then whatever the single-device engine produces for that stream's
    OWN frame sequence alone — by construction independent of every
    other stream's traffic and of device placement.
    """

    def __init__(self, max_streams, n_shards):
        self.max_streams = max_streams
        self.n_shards = n_shards
        self.per_shard = max_streams // n_shards
        self.free = [
            sorted(range(s * self.per_shard, (s + 1) * self.per_shard))
            for s in range(n_shards)
        ]
        self.slot_of = {}
        self.frames = {}

    def _place(self, sid):
        loads = [self.per_shard - len(f) for f in self.free]
        shard = min(
            (ld, s) for s, ld in enumerate(loads) if self.free[s]
        )[1]
        self.slot_of[sid] = self.free[shard].pop(0)

    def open(self, sid):
        self._place(sid)
        self.frames[sid] = []

    def resize(self, new_max):
        """Model of `StreamingKWSServer.resize`'s router remap: fresh
        free lists at the new capacity, survivors re-placed in
        ascending OLD-slot order through the same least-loaded rule.
        Frames are untouched — a resize must never change what a
        stream has seen."""
        order = sorted(self.slot_of, key=self.slot_of.get)
        self.max_streams = new_max
        self.per_shard = new_max // self.n_shards
        self.free = [
            sorted(range(s * self.per_shard, (s + 1) * self.per_shard))
            for s in range(self.n_shards)
        ]
        self.slot_of = {}
        for sid in order:
            self._place(sid)

    def close(self, sid):
        slot = self.slot_of.pop(sid)
        shard = slot // self.per_shard
        self.free[shard].append(slot)
        self.free[shard].sort()
        del self.frames[sid]

    def submit(self, sid, frame):
        self.frames[sid].append(frame)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    events=st.lists(
        st.tuples(
            st.booleans(),  # open a new stream before this tick?
            st.booleans(),  # close the oldest open stream first?
            st.integers(min_value=0, max_value=255),  # submit bitmask
        ),
        min_size=2,
        max_size=6,
    ),
)
def test_random_schedule_matches_lifecycle_oracle(
    oracle_servers, seed, events
):
    """Random open/close/submit schedules: each open stream's scores
    bit-match a single-device replay of its own recorded frames —
    independent of other streams' traffic and of shard placement."""
    sharded, reference = oracle_servers
    for srv in (sharded, reference):
        for sid in list(srv.active):
            srv.close_stream(sid)
    oracle = LifecycleOracle(sharded.max_streams, sharded.n_devices)
    rng = np.random.default_rng(seed)
    next_sid = 0

    def do_open():
        nonlocal next_sid
        sharded.open_stream(next_sid)
        oracle.open(next_sid)
        next_sid += 1

    do_open()
    for want_open, want_close, submit_bits in events:
        if want_close and len(oracle.slot_of) > 1:
            victim = min(oracle.slot_of)
            sharded.close_stream(victim)
            oracle.close(victim)
        if want_open and len(oracle.slot_of) < sharded.max_streams:
            do_open()
        open_sids = sorted(oracle.slot_of)
        frames = {}
        for i, sid in enumerate(open_sids):
            if submit_bits >> (i % 8) & 1:
                f = rng.standard_normal(16).astype(np.float32)
                frames[sid] = f
                oracle.submit(sid, f)
        out = sharded.step(frames)
        del out
        # placement must match the oracle's independent reimplementation
        assert {s: oracle.slot_of[s] for s in open_sids} == {
            s: sharded.active[s] for s in open_sids
        }
    # every open stream's scores == single-device replay of its own frames
    for sid in sorted(oracle.slot_of):
        reference.open_stream(sid)
        expected = np.zeros_like(
            np.asarray(reference.state.scores[0])
        )
        for f in oracle.frames[sid]:
            out = reference.step({sid: f})
            expected = out[sid]["probs"]
        got = sharded.scores[sharded.active[sid]]
        np.testing.assert_array_equal(got, expected)
        reference.close_stream(sid)


@pytest.fixture(scope="module")
def oracle_servers(norm_stats):
    """(sharded 8-slot server, single-device 1-slot reference) on shared
    qat params — module-scoped so hypothesis examples reuse the
    compiled tick programs."""
    pipe = KWSPipeline(
        KWSPipelineConfig(classifier="qat"), norm_stats=norm_stats
    )
    params = pipe.init_params(jax.random.PRNGKey(7))
    sharded = StreamingKWSServer(
        pipe, params, max_streams=8, devices=MESH_DEV
    )
    reference = StreamingKWSServer(pipe, params, max_streams=1)
    return sharded, reference


@pytest.fixture(scope="module")
def cascade_oracle_servers(norm_stats):
    """Cascaded twin of `oracle_servers`: a real wake threshold with
    hangover, so random schedules exercise gated AND woken ticks."""
    pipe = KWSPipeline(
        KWSPipelineConfig(
            classifier="qat",
            cascade=CascadeConfig(wake_threshold=0.3, hangover_frames=1),
        ),
        norm_stats=norm_stats,
    )
    params = pipe.init_params(jax.random.PRNGKey(7))
    sharded = StreamingKWSServer(
        pipe, params, max_streams=8, devices=MESH_DEV
    )
    reference = StreamingKWSServer(pipe, params, max_streams=1)
    return sharded, reference


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    events=st.lists(
        st.tuples(
            st.booleans(),  # open a new stream before this tick?
            st.booleans(),  # close the oldest open stream first?
            st.integers(min_value=0, max_value=255),  # submit bitmask
        ),
        min_size=2,
        max_size=6,
    ),
)
def test_random_schedule_cascade_wake_rate_oracle(
    cascade_oracle_servers, seed, events
):
    """Random open/close/submit schedules on a GATED cascaded server:
    each open stream's scores AND wake rate bit-match a single-device
    replay of its own recorded frames. This pins every telemetry
    clause at once: `wake_rate` resets on open_stream (the reference
    starts at 1.0 and the replay reproduces it from scratch), freezes
    while idle (ticks the stream skipped leave no trace), and is
    independent of shard placement and other streams' traffic."""
    sharded, reference = cascade_oracle_servers
    for srv in (sharded, reference):
        for sid in list(srv.active):
            srv.close_stream(sid)
    oracle = LifecycleOracle(sharded.max_streams, sharded.n_devices)
    rng = np.random.default_rng(seed)
    next_sid = 0

    def do_open():
        nonlocal next_sid
        sharded.open_stream(next_sid)
        oracle.open(next_sid)
        # a freshly opened slot reads unity wake rate (reset contract)
        assert sharded.wake_rate[sharded.active[next_sid]] == 1.0
        next_sid += 1

    do_open()
    for want_open, want_close, submit_bits in events:
        if want_close and len(oracle.slot_of) > 1:
            victim = min(oracle.slot_of)
            sharded.close_stream(victim)
            oracle.close(victim)
        if want_open and len(oracle.slot_of) < sharded.max_streams:
            do_open()
        open_sids = sorted(oracle.slot_of)
        frames = {}
        for i, sid in enumerate(open_sids):
            if submit_bits >> (i % 8) & 1:
                # mixed traffic: loud frames wake the gate, quiet ones
                # leave it (or its hangover) to gate the classifier
                scale = 3.0 if rng.random() < 0.5 else 0.02
                f = (rng.standard_normal(16) * scale).astype(np.float32)
                frames[sid] = f
                oracle.submit(sid, f)
        sharded.step(frames)
    # every open stream's scores and wake rate == single-device replay
    # of its own frames alone
    for sid in sorted(oracle.slot_of):
        reference.open_stream(sid)
        assert reference.wake_rate[0] == 1.0
        expected = np.zeros_like(np.asarray(reference.state.scores[0]))
        for f in oracle.frames[sid]:
            out = reference.step({sid: f})
            expected = out[sid]["probs"]
        slot = sharded.active[sid]
        np.testing.assert_array_equal(sharded.scores[slot], expected)
        np.testing.assert_array_equal(
            sharded.wake_rate[slot], reference.wake_rate[0]
        )
        reference.close_stream(sid)


# --------------------------------------------------------------------------
# elastic capacity: live resize (grow / shrink) & shard-loss recovery
# --------------------------------------------------------------------------

GROWN = MAX_STREAMS * 2


def test_resize_grow_shrink_bit_identical(server_pair):
    """Grow then shrink back, live, against an UN-resized single-device
    server: every surviving stream's posteriors and full per-slot state
    (GRU/delta/carry/scores leaves) stay bit-identical through both
    moves — for every classifier backend."""
    single, sharded = server_pair
    _reset_pair(server_pair)
    for srv in (single, sharded):
        for sid in range(10):
            srv.open_stream(sid)
    rng = np.random.default_rng(30)

    def tick(n):
        for _ in range(n):
            frames = {
                sid: rng.standard_normal(16).astype(np.float32)
                for sid in sorted(sharded.active)
            }
            out_a = single.step(frames)
            out_b = sharded.step(frames)
            for sid in frames:
                np.testing.assert_array_equal(
                    out_a[sid]["probs"], out_b[sid]["probs"]
                )
                assert out_a[sid]["top"] == out_b[sid]["top"]

    try:
        tick(2)
        sharded.resize(GROWN)
        assert sharded.max_streams == GROWN
        assert sharded.router.max_streams == GROWN
        tick(2)
        # grown capacity is genuinely usable: open past the old limit
        for sid in range(100, 100 + MAX_STREAMS):
            sharded.open_stream(sid)
        assert len(sharded.active) > MAX_STREAMS
        for sid in range(100, 100 + MAX_STREAMS):
            sharded.close_stream(sid)
        sharded.resize(MAX_STREAMS)
        tick(2)
        # full per-slot state, not just scores, survived both moves
        for sid in sorted(single.active):
            jax.tree_util.tree_map(
                np.testing.assert_array_equal,
                _slot_slice(single, sid),
                _slot_slice(sharded, sid),
            )
    finally:
        if sharded.max_streams != MAX_STREAMS:
            sharded.resize(MAX_STREAMS)  # module-scoped fixture


def test_resize_keeps_mesh_layout(server_pair):
    """After a grow every state leaf is still block-sharded over the
    SAME ("stream",) mesh at the new capacity, params stay replicated,
    and the router's placement stays balanced — no program rebuild, no
    layout drift."""
    _, sharded = server_pair
    _reset_pair(server_pair)
    for sid in range(MAX_STREAMS):
        sharded.open_stream(sid)
    mesh_before = sharded.mesh
    tick_before = sharded._tick_fv
    try:
        sharded.resize(GROWN)
        assert sharded.mesh is mesh_before
        # resize must NOT rebuild the jitted programs (shape-agnostic
        # NamedShardings; jax's own cache handles the retrace)
        assert sharded._tick_fv is tick_before
        for leaf in jax.tree_util.tree_leaves(sharded.state):
            assert leaf.shape[0] == GROWN
            spec = leaf.sharding.spec
            assert spec and spec[0] == STREAM_AXIS, spec
        for leaf in jax.tree_util.tree_leaves(sharded.params):
            assert leaf.sharding.is_fully_replicated
        loads = sharded.router.shard_loads()
        assert max(loads) - min(loads) <= 1
        assert sum(loads) == len(sharded.active)
    finally:
        sharded.resize(MAX_STREAMS)


def test_resize_validation(server_pair):
    _, sharded = server_pair
    _reset_pair(server_pair)
    for sid in range(10):
        sharded.open_stream(sid)
    with pytest.raises(ValueError, match="divide over"):
        sharded.resize(MAX_STREAMS + 1)
    with pytest.raises(ValueError, match=">= 1"):
        sharded.resize(0)
    with pytest.raises(RuntimeError, match="open"):
        sharded.resize(MESH_DEV)  # 10 open streams never fit
    # same capacity is a no-op: same state object, nothing re-laid
    state_before = sharded.state
    sharded.resize(MAX_STREAMS)
    assert sharded.state is state_before


def test_resize_with_async_handle_in_flight(backend):
    """A TickHandle dispatched BEFORE a resize stays valid after it:
    the handle owns device-side copies, so its scores bit-match the
    synchronous un-resized twin however late it is fetched. The twin
    is a second SHARDED server (identical router placement — slot-
    major `step_batch` comparisons are only meaningful between servers
    that place the same stream on the same slot)."""
    pipe, params = backend
    sharded = StreamingKWSServer(
        pipe, params, max_streams=MAX_STREAMS, devices=MESH_DEV
    )
    twin = StreamingKWSServer(
        pipe, params, max_streams=MAX_STREAMS, devices=MESH_DEV
    )
    for srv in (sharded, twin):
        for sid in range(MAX_STREAMS):
            srv.open_stream(sid)
    assert sharded.active == twin.active  # identical placement
    rng = np.random.default_rng(31)
    mask = np.ones(MAX_STREAMS, bool)
    fv1 = rng.standard_normal((MAX_STREAMS, 16)).astype(np.float32)
    fv2 = rng.standard_normal((MAX_STREAMS, 16)).astype(np.float32)
    s1, t1 = twin.step_batch(fv1, mask)
    handle = sharded.step_batch_async(fv1, mask)
    sharded.resize(GROWN)  # before the handle is fetched
    s_b, t_b = handle.result()
    np.testing.assert_array_equal(s1, s_b)
    np.testing.assert_array_equal(t1, t_b)
    # and the resized server keeps serving bit-identically, per sid
    out_a = twin.step({sid: fv2[sid] for sid in range(MAX_STREAMS)})
    out_b = sharded.step({sid: fv2[sid] for sid in range(MAX_STREAMS)})
    for sid in range(MAX_STREAMS):
        np.testing.assert_array_equal(
            out_a[sid]["probs"], out_b[sid]["probs"]
        )


def test_resize_cascaded_bit_identical(norm_stats):
    """A GATED cascaded server (real wake threshold + hangover) grown
    and shrunk mid-traffic: per-stream scores AND the wake-rate
    telemetry bit-match the un-resized single-device twin — detector
    state (awake latch, hangover countdown, woken/ticks counters) is
    carried bitwise like every other leaf."""
    pipe = KWSPipeline(
        KWSPipelineConfig(
            classifier="qat",
            cascade=CascadeConfig(wake_threshold=0.3, hangover_frames=1),
        ),
        norm_stats=norm_stats,
    )
    params = pipe.init_params(jax.random.PRNGKey(33))
    single = StreamingKWSServer(pipe, params, max_streams=MAX_STREAMS)
    sharded = StreamingKWSServer(
        pipe, params, max_streams=MAX_STREAMS, devices=MESH_DEV
    )
    for srv in (single, sharded):
        for sid in range(12):
            srv.open_stream(sid)
    rng = np.random.default_rng(34)

    def tick(n):
        for _ in range(n):
            frames = {}
            for sid in sorted(sharded.active):
                scale = 3.0 if rng.random() < 0.5 else 0.02
                frames[sid] = (
                    rng.standard_normal(16) * scale
                ).astype(np.float32)
            out_a = single.step(frames)
            out_b = sharded.step(frames)
            for sid in frames:
                np.testing.assert_array_equal(
                    out_a[sid]["probs"], out_b[sid]["probs"]
                )

    tick(3)
    sharded.resize(GROWN)
    tick(3)
    sharded.resize(MAX_STREAMS)
    tick(3)
    wr_a, wr_b = single.wake_rate, sharded.wake_rate
    for sid in sorted(single.active):
        np.testing.assert_array_equal(
            wr_a[single.active[sid]], wr_b[sharded.active[sid]]
        )
    assert (wr_b[: len(sharded.active)] < 1.0).any() or (
        wr_a < 1.0
    ).any()  # the gate really gated through the moves


def test_shard_loss_recovery(backend):
    """Simulated loss of one shard: recovery shrink-reshards onto the
    surviving devices, healthy streams' per-slot state is BIT-unchanged
    through the move, the lost shard's streams reopen (same ids) on
    fresh zeroed slots, and post-recovery serving bit-matches a
    single-device replay of each stream's surviving history — for
    every classifier backend.

    The reference is width-matched (same max_streams, one device):
    XLA vectorizes the float classifier differently at different batch
    widths, so bit-identity only holds at a fixed slot-axis width —
    which is exactly what recovery preserves (16 slots before and
    after losing a shard here), while the device count shrinks."""
    pipe, params = backend
    sharded = StreamingKWSServer(
        pipe, params, max_streams=MAX_STREAMS, devices=MESH_DEV
    )
    reference = StreamingKWSServer(pipe, params, max_streams=MAX_STREAMS)
    rng = np.random.default_rng(35)
    history = {sid: [] for sid in range(12)}
    for sid in range(12):
        sharded.open_stream(sid)

    def tick(n):
        for _ in range(n):
            frames = {
                sid: rng.standard_normal(16).astype(np.float32)
                for sid in sorted(sharded.active)
            }
            sharded.step(frames)
            for sid, f in frames.items():
                history[sid].append(f)

    tick(3)
    lost = 1
    pre = {sid: _slot_slice(sharded, sid) for sid in sharded.active}
    lost_sids = {
        sid for sid, slot in sharded.active.items()
        if shard_of_slot(slot, MAX_STREAMS, MESH_DEV) == lost
    }
    assert lost_sids  # 12 streams round-robin over <= 8 shards
    info = sharded.recover_shard_loss(lost)
    assert set(info["reopened"]) == lost_sids
    assert set(info["survivors"]) == set(range(12)) - lost_sids
    assert sharded.n_devices < MESH_DEV
    assert sharded.max_streams % sharded.n_devices == 0
    assert set(sharded.active) == set(range(12))  # same ids throughout
    # healthy shards' per-stream state: bit-unchanged through the move
    for sid in info["survivors"]:
        jax.tree_util.tree_map(
            np.testing.assert_array_equal,
            pre[sid],
            _slot_slice(sharded, sid),
        )
    # the lost shard's streams: fresh zeroed slots, history restarted
    for sid in info["reopened"]:
        jax.tree_util.tree_map(
            lambda t: np.testing.assert_array_equal(t, np.zeros_like(t)),
            _slot_slice(sharded, sid),
        )
        history[sid] = []
    # the state leaves live on the SMALLER mesh now
    if sharded.mesh is not None:
        for leaf in jax.tree_util.tree_leaves(sharded.state):
            assert len(leaf.devices()) == sharded.n_devices
    tick(2)
    # every stream bit-matches a single-device replay of the frames its
    # surviving state has seen
    for sid in sorted(sharded.active):
        reference.open_stream(sid)
        expected = np.zeros_like(np.asarray(reference.state.scores[0]))
        for f in history[sid]:
            out = reference.step({sid: f})
            expected = out[sid]["probs"]
        np.testing.assert_array_equal(
            sharded.scores[sharded.active[sid]], expected
        )
        reference.close_stream(sid)


def test_shard_loss_validation(server_pair, backend):
    _, sharded = server_pair
    with pytest.raises(ValueError, match="outside"):
        sharded.recover_shard_loss(sharded.n_devices)
    pipe, params = backend
    single = StreamingKWSServer(pipe, params, max_streams=4)
    with pytest.raises(ValueError, match="no shards"):
        single.recover_shard_loss(0)


# --------------------------------------------------------------------------
# router: churn at capacity boundaries + remap
# --------------------------------------------------------------------------

def test_shard_of_slot_validates_divisibility():
    """Regression: max_streams=10 over 4 shards used to silently
    truncate to 2-slot blocks, reporting slot 9 on 'shard 4' — an
    index past the mesh. Uneven geometry is now an error at the
    function itself, not only in StreamRouter.__init__."""
    with pytest.raises(ValueError, match="divide evenly"):
        shard_of_slot(9, 10, 4)
    with pytest.raises(ValueError, match="n_shards"):
        shard_of_slot(0, 8, 0)
    with pytest.raises(ValueError, match="outside"):
        shard_of_slot(8, 8, 4)
    assert shard_of_slot(5, 8, 4) == 2


def test_router_churn_at_capacity_boundaries():
    """Random release/acquire interleavings: every acquire targets a
    least-loaded shard (with free capacity), double-release raises,
    acquire-at-full raises, and one release reopens exactly one
    slot."""
    rng = np.random.default_rng(40)
    r = StreamRouter(MAX_STREAMS, MESH_DEV)
    held = []
    for _ in range(300):
        if held and (rng.random() < 0.45 or r.free_count == 0):
            s = held.pop(int(rng.integers(len(held))))
            r.release(s)
            with pytest.raises(ValueError, match="already free"):
                r.release(s)
        else:
            loads_before = r.shard_loads()
            slot = r.acquire()
            shard = shard_of_slot(slot, MAX_STREAMS, MESH_DEV)
            eligible = [
                ld for ld in loads_before if ld < r.slots_per_shard
            ]
            assert loads_before[shard] == min(eligible)
            held.append(slot)
    while r.free_count:
        held.append(r.acquire())
    with pytest.raises(RuntimeError, match="capacity"):
        r.acquire()
    r.release(held.pop())
    assert r.free_count == 1
    held.append(r.acquire())
    with pytest.raises(RuntimeError, match="capacity"):
        r.acquire()


def test_router_remap_survives_resize():
    """Placements survive a resize remap: deterministic mapping in
    ascending old-slot order, balanced on the new geometry, further
    acquires continue the round-robin fill, and impossible remaps
    (overflow, duplicates) are rejected before any state would move."""
    r = StreamRouter(MAX_STREAMS, MESH_DEV)
    slots = [r.acquire() for _ in range(MAX_STREAMS)]
    kept = [s for i, s in enumerate(slots) if i % 3]  # scattered subset
    # grow remap
    r2, mapping = StreamRouter.remap(kept, GROWN, MESH_DEV)
    assert sorted(mapping) == sorted(kept)
    assert len(set(mapping.values())) == len(kept)
    assert all(0 <= v < GROWN for v in mapping.values())
    loads = r2.shard_loads()
    assert max(loads) - min(loads) <= 1
    assert sum(loads) == len(kept)
    # deterministic: identical inputs -> identical mapping
    _, mapping2 = StreamRouter.remap(kept, GROWN, MESH_DEV)
    assert mapping2 == mapping
    # the remapped router keeps allocating balanced
    extra = r2.acquire()
    assert extra not in set(mapping.values())
    loads = r2.shard_loads()
    assert max(loads) - min(loads) <= 1
    # shrink remap down to the exact occupied count still fits
    n_kept = len(kept)
    target = -(-n_kept // MESH_DEV) * MESH_DEV
    r3, m3 = StreamRouter.remap(kept, target, MESH_DEV)
    assert sorted(m3.values()) == list(range(n_kept)) or len(
        set(m3.values())
    ) == n_kept
    assert r3.free_count == target - n_kept
    # rejected remaps
    with pytest.raises(ValueError, match="cannot remap"):
        StreamRouter.remap(list(range(MESH_DEV + 1)), MESH_DEV, MESH_DEV)
    with pytest.raises(ValueError, match="unique"):
        StreamRouter.remap([1, 1], MAX_STREAMS, MESH_DEV)


# --------------------------------------------------------------------------
# property test: random lifecycles WITH live resizes vs the oracle
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def resize_oracle_servers(norm_stats):
    """(elastic sharded server, single-device 1-slot reference) —
    capacity starts at 8 and toggles among {8, 16, 32} across
    examples, so jax's shape-keyed jit cache amortizes the retraces."""
    pipe = KWSPipeline(
        KWSPipelineConfig(classifier="qat"), norm_stats=norm_stats
    )
    params = pipe.init_params(jax.random.PRNGKey(9))
    sharded = StreamingKWSServer(
        pipe, params, max_streams=8, devices=MESH_DEV
    )
    reference = StreamingKWSServer(pipe, params, max_streams=1)
    return sharded, reference


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    events=st.lists(
        st.tuples(
            st.booleans(),  # open a new stream before this tick?
            st.booleans(),  # close the oldest open stream first?
            st.integers(min_value=0, max_value=255),  # submit bitmask
            st.sampled_from(("none", "grow", "shrink")),  # resize after
        ),
        min_size=2,
        max_size=6,
    ),
)
def test_random_schedule_with_resize_matches_oracle(
    resize_oracle_servers, seed, events
):
    """The lifecycle-oracle harness extended with live resizes: random
    open/close/submit/grow/shrink schedules, placements matching the
    oracle's independent remap model after every event, and each open
    stream's final scores bit-matching a single-device replay of its
    own frames — a resize is invisible to every surviving stream."""
    sharded, reference = resize_oracle_servers
    for sid in list(sharded.active):
        sharded.close_stream(sid)
    sharded.resize(8)
    oracle = LifecycleOracle(8, sharded.n_devices)
    rng = np.random.default_rng(seed)
    next_sid = 0

    def do_open():
        nonlocal next_sid
        sharded.open_stream(next_sid)
        oracle.open(next_sid)
        next_sid += 1

    do_open()
    for want_open, want_close, submit_bits, action in events:
        if want_close and len(oracle.slot_of) > 1:
            victim = min(oracle.slot_of)
            sharded.close_stream(victim)
            oracle.close(victim)
        if want_open and len(oracle.slot_of) < sharded.max_streams:
            do_open()
        open_sids = sorted(oracle.slot_of)
        frames = {}
        for i, sid in enumerate(open_sids):
            if submit_bits >> (i % 8) & 1:
                f = rng.standard_normal(16).astype(np.float32)
                frames[sid] = f
                oracle.submit(sid, f)
        sharded.step(frames)
        new_max = None
        if action == "grow" and sharded.max_streams < 32:
            new_max = sharded.max_streams * 2
        elif action == "shrink" and sharded.max_streams > 8:
            half = sharded.max_streams // 2
            if half >= len(sharded.active):
                new_max = half
        if new_max is not None:
            sharded.resize(new_max)
            oracle.resize(new_max)
        # placement matches the oracle's independent remap model
        assert oracle.slot_of == dict(sharded.active)
    # every open stream's scores == single-device replay of its frames
    for sid in sorted(oracle.slot_of):
        reference.open_stream(sid)
        expected = np.zeros_like(
            np.asarray(reference.state.scores[0])
        )
        for f in oracle.frames[sid]:
            out = reference.step({sid: f})
            expected = out[sid]["probs"]
        got = sharded.scores[sharded.active[sid]]
        np.testing.assert_array_equal(got, expected)
        reference.close_stream(sid)
