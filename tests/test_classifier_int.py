"""Classifier backend registry + integer/QAT bit-identity suite.

The contract under test (promised in repro.core.quant's docstring): the
bit-exact integer engine (`repro.core.gru_int` — int8 weight codes,
Q6.8 activation codes, saturating-int24 matmuls, LUT sigmoid/tanh) is
BIT-identical to the QAT fake-quant forward of `repro.core.gru` on the
same parameters, for the full forward, the streaming step, and the
whole serving stack (fused tick, slab ingress, lax.scan replay). These
tests are deliberately exact (assert_array_equal, never allclose) and
fast — they run in the `-m "not slow"` CI selection so any parity
regression fails on every PR.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import quant
from repro.core.classifier import (
    available_classifiers,
    get_classifier,
    resolve_classifier_key,
)
from repro.core.fex import fit_norm_stats
from repro.core.gru import (
    GRUConfig,
    gru_classifier_forward,
    gru_classifier_step,
    init_gru_classifier,
    init_states,
)
from repro.core.gru_int import (
    QuantizedClassifier,
    dequantize_acts,
    int_gru_classifier_forward,
    int_gru_classifier_step,
    int_init_states,
    quantize_acts,
)
from repro.core.pipeline import KWSPipeline, KWSPipelineConfig
from repro.serving.quantize import quantize_classifier
from repro.serving.serve_loop import StreamingKWSServer

CFG = GRUConfig(quantized=True)


def _params(seed=0):
    return init_gru_classifier(jax.random.PRNGKey(seed), CFG)


def _grid_fv(shape, seed=0, scale=4.0):
    """Random FV_Norm input snapped to the Q6.8 grid, as the pipeline's
    post-processing guarantees for real traffic."""
    x = jax.random.normal(jax.random.PRNGKey(seed), shape) * scale
    return quant.fake_quant(x, quant.ACT_Q6_8)


# --------------------------------------------------------------------------
# registry mechanics (mirrors the frontend registry contract)
# --------------------------------------------------------------------------

def test_registry_contents():
    assert available_classifiers() == (
        "delta", "delta-int", "float", "integer", "qat"
    )
    for name in available_classifiers():
        assert get_classifier(name).name == name


def test_unknown_classifier_rejected():
    with pytest.raises(KeyError, match="unknown classifier"):
        get_classifier("analog")
    with pytest.raises(KeyError, match="unknown classifier"):
        KWSPipeline(KWSPipelineConfig(classifier="analog"))


def test_default_resolution_follows_gru_quantized():
    assert resolve_classifier_key(None, GRUConfig(quantized=True)) == "qat"
    assert resolve_classifier_key(None, GRUConfig(quantized=False)) == "float"
    assert resolve_classifier_key("integer", CFG) == "integer"
    assert KWSPipeline(KWSPipelineConfig()).classifier.name == "qat"
    assert (
        KWSPipeline(
            KWSPipelineConfig(gru=GRUConfig(quantized=False))
        ).classifier.name
        == "float"
    )


def test_prepare_params_idempotent():
    pipe = KWSPipeline(KWSPipelineConfig(classifier="integer"))
    params = _params()
    q = pipe.prepare_params(params)
    assert isinstance(q, QuantizedClassifier)
    assert pipe.prepare_params(q) is q
    # float/qat backends pass float params through untouched
    pipe_qat = KWSPipeline(KWSPipelineConfig(classifier="qat"))
    assert pipe_qat.prepare_params(params) is params


def test_integer_backend_rejects_unprepared_params():
    backend = get_classifier("integer")
    with pytest.raises(TypeError, match="prepare_params"):
        backend.step(_params(), int_init_states(CFG, 1), jnp.zeros((1, 16)), CFG)


def test_quantize_classifier_checks_geometry():
    with pytest.raises(ValueError, match="layers"):
        quantize_classifier(
            _params(), GRUConfig(num_layers=3, quantized=True)
        )


# --------------------------------------------------------------------------
# bit-identity: integer engine vs QAT fake-quant
# --------------------------------------------------------------------------

def test_forward_bit_identical_to_qat():
    params = _params(0)
    q = quantize_classifier(params, CFG)
    fv = _grid_fv((3, 25, 16), seed=1)
    ref = gru_classifier_forward(params, fv, CFG)
    out = dequantize_acts(int_gru_classifier_forward(q, quantize_acts(fv), CFG))
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


def test_streaming_step_bit_identical_to_qat():
    params = _params(2)
    q = quantize_classifier(params, CFG)
    fv = _grid_fv((4, 15, 16), seed=3)
    states_f = init_states(CFG, 4)
    states_i = int_init_states(CFG, 4)
    for t in range(fv.shape[1]):
        states_f, lf = gru_classifier_step(params, states_f, fv[:, t], CFG)
        states_i, li = int_gru_classifier_step(
            q, states_i, quantize_acts(fv[:, t]), CFG
        )
        np.testing.assert_array_equal(
            np.asarray(lf), np.asarray(dequantize_acts(li))
        )
        # the hidden-state codes themselves track the QAT values exactly
        for hf, hi in zip(states_f, states_i):
            np.testing.assert_array_equal(
                np.asarray(hf), np.asarray(dequantize_acts(hi))
            )


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.floats(min_value=0.25, max_value=16.0),
    t=st.integers(min_value=1, max_value=8),
)
def test_forward_bit_identity_property(seed, scale, t):
    """Property sweep over input magnitude and sequence length: parity
    must hold for any on-grid input, not just one lucky draw (skipped
    when the hypothesis test extra is absent)."""
    params = _params(seed % 7)
    q = quantize_classifier(params, CFG)
    key = jax.random.PRNGKey(seed)
    fv = quant.fake_quant(
        jax.random.normal(key, (2, t, 16)) * scale, quant.ACT_Q6_8
    )
    ref = gru_classifier_forward(params, fv, CFG)
    out = dequantize_acts(
        int_gru_classifier_forward(q, quantize_acts(fv), CFG)
    )
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


def test_lut_nonlinearities_match_fake_quant():
    """The Q6.8 sigmoid/tanh ROMs agree with float-op-then-fake-quant on
    every representable summed preactivation."""
    codes = jnp.arange(2 * quant.ACT_Q6_8.qmin, 2 * quant.ACT_Q6_8.qmax + 1)
    x = codes.astype(jnp.float32) * quant.ACT_Q6_8.scale
    np.testing.assert_array_equal(
        np.asarray(quant.lut_sigmoid_q68(codes)),
        np.asarray(quant.quantize_int(jax.nn.sigmoid(x), quant.ACT_Q6_8)),
    )
    np.testing.assert_array_equal(
        np.asarray(quant.lut_tanh_q68(codes)),
        np.asarray(quant.quantize_int(jnp.tanh(x), quant.ACT_Q6_8)),
    )


@settings(max_examples=30, deadline=None)
@given(
    v=st.integers(min_value=-(2**23), max_value=2**23 - 1),
    shift=st.integers(min_value=1, max_value=16),
)
def test_round_shift_even_matches_jnp_round(v, shift):
    got = int(quant.round_shift_even(jnp.int32(v), shift))
    want = int(np.round(v / 2.0**shift))  # numpy double: exact + half-even
    assert got == want


# --------------------------------------------------------------------------
# pipeline + serving integration
# --------------------------------------------------------------------------

def _audio(batch=2, samples=8192, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.standard_normal((batch, samples)).astype(np.float32) * 0.05
    )


def _stats(audio):
    boot = KWSPipeline(KWSPipelineConfig(use_norm=False))
    _, raw = boot.features(audio)
    return fit_norm_stats(quant.log_compress_lut(raw, 12, 10))


def test_pipeline_logits_and_predict_parity():
    audio = _audio(batch=3, seed=20)
    stats = _stats(audio)
    pq = KWSPipeline(KWSPipelineConfig(classifier="qat"), norm_stats=stats)
    pi = KWSPipeline(
        KWSPipelineConfig(classifier="integer"), norm_stats=stats
    )
    params = pq.init_params(jax.random.PRNGKey(20))
    fv, _ = pq.features(audio)
    np.testing.assert_array_equal(
        np.asarray(pq.logits(params, fv)), np.asarray(pi.logits(params, fv))
    )
    np.testing.assert_array_equal(
        np.asarray(pq.logits_all_frames(params, fv)),
        np.asarray(pi.logits_all_frames(params, fv)),
    )
    np.testing.assert_array_equal(
        np.asarray(pq.predict(params, audio)),
        np.asarray(pi.predict(params, audio)),
    )


def test_pipeline_streaming_step_parity_and_state_dtype():
    audio = _audio(seed=21)
    stats = _stats(audio)
    pq = KWSPipeline(KWSPipelineConfig(classifier="qat"), norm_stats=stats)
    pi = KWSPipeline(
        KWSPipelineConfig(classifier="integer"), norm_stats=stats
    )
    params = pq.init_params(jax.random.PRNGKey(21))
    fv, _ = pq.features(audio)
    sq = pq.streaming_init(2)
    si = pi.streaming_init(2)
    assert si[0].dtype == jnp.int32 and sq[0].dtype == jnp.float32
    for t in range(6):
        sq, lq = pq.streaming_step(params, sq, fv[:, t])
        si, li = pi.streaming_step(params, si, fv[:, t])
        np.testing.assert_array_equal(np.asarray(lq), np.asarray(li))


def _server(classifier, params=None, max_streams=4, seed=22):
    audio = _audio(seed=seed)
    stats = _stats(audio)
    pipe = KWSPipeline(
        KWSPipelineConfig(classifier=classifier), norm_stats=stats
    )
    if params is None:
        params = pipe.init_params(jax.random.PRNGKey(seed))
    return pipe, StreamingKWSServer(pipe, params, max_streams=max_streams)


def test_server_fused_tick_parity_qat_vs_integer():
    """The whole fused serving tick (frontend + GRU + softmax +
    smoothing) produces bit-identical posteriors on both backends, for
    raw-audio and FV ticks."""
    params_src = KWSPipeline(KWSPipelineConfig()).init_params(
        jax.random.PRNGKey(22)
    )
    pipe, sq = _server("qat", params_src)
    _, si = _server("integer", params_src)
    assert isinstance(si.params, QuantizedClassifier)
    for s in (sq, si):
        s.open_stream(1)
        s.open_stream(2)
    hop = pipe.chunk_samples
    rng = np.random.default_rng(22)
    for _ in range(4):
        frames = {
            sid: rng.standard_normal(hop).astype(np.float32) * 0.05
            for sid in (1, 2)
        }
        oq = sq.step(dict(frames))
        oi = si.step(dict(frames))
        for sid in frames:
            np.testing.assert_array_equal(
                oq[sid]["probs"], oi[sid]["probs"]
            )
            assert oq[sid]["top"] == oi[sid]["top"]
    fv = np.ones(16, np.float32)
    oq = sq.step({1: fv})
    oi = si.step({1: fv})
    np.testing.assert_array_equal(oq[1]["probs"], oi[1]["probs"])


def test_server_integer_idle_stream_isolation():
    """The temporal-sparsity contract holds for int32 GRU state leaves:
    an idle stream's codes are bit-identical across others' ticks."""
    pipe, srv = _server("integer", seed=23)
    srv.open_stream(1)
    srv.open_stream(2)
    hop = pipe.chunk_samples
    rng = np.random.default_rng(23)
    hops = [rng.standard_normal(hop).astype(np.float32) * 0.05
            for _ in range(3)]
    srv.step({1: hops[0], 2: hops[0]})
    slot = srv.active[2]
    before = jax.tree_util.tree_map(
        lambda t: np.asarray(t[slot]).copy(), srv.state
    )
    for h in hops[1:]:
        srv.step({1: h})
    after = jax.tree_util.tree_map(
        lambda t: np.asarray(t[slot]).copy(), srv.state
    )
    jax.tree_util.tree_map(np.testing.assert_array_equal, before, after)


def test_server_integer_scan_replay_matches_live():
    """run (lax.scan over the fused tick) == live step ticks with the
    integer engine inside the scanned program."""
    params_src = KWSPipeline(KWSPipelineConfig()).init_params(
        jax.random.PRNGKey(24)
    )
    pipe, live = _server("integer", params_src, seed=24)
    _, scan = _server("integer", params_src, seed=24)
    hop = pipe.chunk_samples
    rng = np.random.default_rng(24)
    buf = rng.standard_normal(hop * 4).astype(np.float32) * 0.05
    for s in (live, scan):
        s.open_stream(9)
    outs = []
    for t in range(4):
        o = live.step({9: buf[t * hop:(t + 1) * hop]})
        outs.append(o[9]["probs"])
    rep = scan.run({9: buf})
    np.testing.assert_array_equal(np.stack(outs), rep[9]["probs"])
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)
        ),
        live.state, scan.state,
    )


def test_float_backend_is_unquantized():
    """classifier="float" must bypass fake-quant entirely (outputs off
    the Q6.8 grid), regardless of gru.quantized on the config."""
    params = _params(25)
    fv = _grid_fv((2, 10, 16), seed=25)
    backend = get_classifier("float")
    out = np.asarray(backend.forward(params, fv, CFG))
    codes = out * 256.0
    assert np.abs(codes - np.round(codes)).max() > 1e-3
