"""Checkpointing: roundtrip, atomicity, pruning, corruption detection."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training.checkpoint import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)),
                   "b": jnp.zeros(16, jnp.bfloat16)},
        "opt": {"m": [jnp.ones(3), jnp.arange(4.0)]},
        "step": jnp.int32(7),
    }


def test_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 100, tree)
    restored, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 100
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_latest_and_pruning(tmp_path):
    tree = _tree()
    for s in [10, 20, 30, 40]:
        save_checkpoint(str(tmp_path), s, tree, keep=2)
    assert latest_step(str(tmp_path)) == 40
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_000000030", "step_000000040"]


def test_async_save(tmp_path):
    t = save_checkpoint(str(tmp_path), 5, _tree(), async_save=True)
    t.join()
    _, step = restore_checkpoint(str(tmp_path), _tree())
    assert step == 5


def test_tmp_dirs_invisible(tmp_path):
    """A partially-written checkpoint (crash mid-save) is never
    restorable: only fully renamed step_ dirs count."""
    os.makedirs(tmp_path / "step_000000099.tmp")
    save_checkpoint(str(tmp_path), 10, _tree())
    assert latest_step(str(tmp_path)) == 10  # not 99


def test_corruption_detected(tmp_path):
    save_checkpoint(str(tmp_path), 3, _tree())
    d = tmp_path / "step_000000003"
    # flip bytes in one leaf
    target = d / "leaf_00000.npy"
    arr = np.load(target)
    arr = np.asarray(arr).copy()
    arr.flat[0] += 1
    np.save(target, arr)
    with pytest.raises(IOError, match="checksum"):
        restore_checkpoint(str(tmp_path), _tree())


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path / "nope"), _tree())
