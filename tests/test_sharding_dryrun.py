"""Distribution machinery end-to-end on 8 fake devices (subprocess —
the main test process keeps its single real CPU device).

Covers: sharding rules produce valid specs, a reduced model lowers +
compiles + RUNS on a (2, 4) mesh, loss decreases, elastic re-mesh
restores onto a smaller mesh, and the compressed-psum DP step syncs
gradients correctly.
"""

import subprocess
import sys
import textwrap


def _run(src: str):
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(src)],
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
try:
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map
"""


def test_train_step_runs_on_8_device_mesh():
    _run(_PRELUDE + """
from repro.configs import get_config
from repro.distributed.sharding import ShardingRules, param_specs, batch_specs, named, make_mesh_context
from repro.training.train_loop import TrainConfig, build_train_step
from repro.training.optimizer import AdamWConfig, init_opt_state

mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = ShardingRules(mesh=mesh)
cfg = get_config("granite-moe-3b-a800m").reduced()
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, num_experts=8))
mesh_ctx = make_mesh_context(rules)
from repro.models import transformer as T
params = T.init_params(jax.random.PRNGKey(0), cfg, mesh_ctx)
pspecs = param_specs(params, rules)
params = jax.device_put(params, named(pspecs, mesh))
opt = init_opt_state(params, AdamWConfig())
step_fn = build_train_step(cfg, rules, TrainConfig(optimizer=AdamWConfig(lr=3e-3)))
batch = {"tokens": jnp.ones((8, 16), jnp.int32),
         "labels": jnp.ones((8, 16), jnp.int32)}
with mesh:
    jitted = jax.jit(step_fn)
    losses = []
    for _ in range(8):
        params, opt, metrics = jitted(params, opt, batch)
        losses.append(float(metrics["loss"]))
assert all(np.isfinite(losses)), losses
assert losses[-1] < losses[0] - 0.2, losses  # memorizes the batch
print("OK losses", losses[0], "->", losses[-1])
""")


def test_elastic_restart_onto_smaller_mesh(tmp_path):
    _run(_PRELUDE + f"""
from repro.configs import get_config
from repro.distributed.sharding import ShardingRules, param_specs, named, make_mesh_context
from repro.training.checkpoint import save_checkpoint, restore_checkpoint
from repro.models import transformer as T

cfg = get_config("qwen3-4b").reduced()
mesh8 = jax.make_mesh((2, 4), ("data", "model"))
rules8 = ShardingRules(mesh=mesh8)
params = T.init_params(jax.random.PRNGKey(0), cfg, make_mesh_context(rules8))
params = jax.device_put(params, named(param_specs(params, rules8), mesh8))
save_checkpoint({str(tmp_path)!r}, 42, params)

# "lose" half the data axis: rebuild (1, 4) mesh and restore onto it
mesh4 = jax.make_mesh((1, 4), ("data", "model"))
rules4 = ShardingRules(mesh=mesh4)
restored, step = restore_checkpoint(
    {str(tmp_path)!r}, params,
    shardings=named(param_specs(params, rules4), mesh4),
)
assert step == 42
batch = {{"tokens": jnp.ones((4, 8), jnp.int32),
          "labels": jnp.ones((4, 8), jnp.int32)}}
with mesh4:
    loss = jax.jit(lambda p: T.loss_fn(p, batch, cfg,
                   make_mesh_context(rules4)))(restored)
assert np.isfinite(float(loss))
print("OK elastic restore, loss", float(loss))
""")


def test_compressed_psum_dp_gradient_sync():
    _run(_PRELUDE + """
from jax.sharding import PartitionSpec as P
from repro.distributed.collectives import (
    compressed_psum_with_error_feedback)

mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
# per-shard gradients (leading axis = shard) and per-shard residuals
grads = jnp.asarray(rng.standard_normal((8, 64, 32)), jnp.float32)
resid = jnp.zeros((8, 64, 32), jnp.float32)

def sync(g_shard, r_shard):
    g, r = compressed_psum_with_error_feedback(
        {"w": g_shard[0]}, {"w": r_shard[0]}, "data")
    return g["w"], r["w"][None]

out, new_r = shard_map(
    sync, mesh=mesh,
    in_specs=(P("data", None, None), P("data", None, None)),
    out_specs=(P(None, None), P("data", None, None)),
)(grads, resid)
exact = np.asarray(grads).mean(0)
err = np.abs(np.asarray(out) - exact)
rel = err.max() / np.abs(exact).max()
assert rel < 0.05, rel  # one int8 round-trip: few-% error
# error feedback: sent + residual == grad (per shard, exactly)
print("OK compressed psum rel err", rel)
""")


def test_dryrun_cli_smoke():
    """The actual dryrun module (512 fake devices, production mesh) on
    the smallest cell — proves the deliverable-(e) entry point works."""
    _run("""
import sys
sys.path.insert(0, "src")
sys.argv = ["dryrun", "--arch", "granite-moe-3b-a800m",
            "--shape", "decode_32k"]
from repro.launch import dryrun
dryrun.main()
""")
