"""Shared dispatch-tier selection for the Pallas kernel layer.

Every kernel in `repro.kernels` ships three equivalent implementations:

  * ``pallas``    — the compiled Mosaic kernel (TPU);
  * ``interpret`` — the same kernel body run by the Pallas interpreter
                    (validates kernel logic on CPU CI);
  * ``reference`` — a pure-jnp formulation, bit-identical by contract
                    (fastest off-TPU for shapes the interpreter crawls
                    on).

Before this module each kernel's ``ops.py`` carried its own copy of the
same three decisions; they are centralized here so `tdc` / `intgemm` /
`gru` / `fex_fused` / `tick_fused` resolve identically:

  1. `resolve_dispatch` — map ("auto" | explicit tier, legacy
     ``interpret=`` flag) to a concrete tier for this jax backend.
  2. `trace_aware_jit` — jit a kernel entry point at the top level but
     inline it under an outer trace, so a caller's jit (the fused
     serving tick, a training scan) compiles ONE program with no
     nested-jit call boundary.
  3. `force_dispatch` — a thread-local override consulted before
     everything else. The fused-tick megakernel body
     (`repro.kernels.tick_fused`) traces the whole serving tick —
     including classifier backends that themselves call `intgemm` —
     INSIDE a `pallas_call`; a `pallas_call` cannot nest, so the
     megakernel activates ``force_dispatch("reference")`` while tracing
     its body and every nested kernel entry point resolves to its
     pure-jnp reference (bit-identical by contract, so the megakernel's
     output is unchanged).
"""

from __future__ import annotations

import contextlib
import functools
import threading
from typing import Optional

import jax

__all__ = [
    "DISPATCH_TIERS",
    "dispatch_override",
    "force_dispatch",
    "resolve_dispatch",
    "trace_aware_jit",
]

DISPATCH_TIERS = ("pallas", "interpret", "reference")

_local = threading.local()


def dispatch_override() -> Optional[str]:
    """The tier forced by an enclosing `force_dispatch`, or None."""
    return getattr(_local, "tier", None)


@contextlib.contextmanager
def force_dispatch(tier: str):
    """Force every kernel dispatch in this thread to ``tier``.

    Overrides BOTH the ``dispatch=`` argument and the legacy
    ``interpret=`` flag of every kernel entry point resolved inside the
    context — this is the no-nested-`pallas_call` escape hatch for
    kernel bodies that trace other kernels' entry points (see module
    docstring). Thread-local and re-entrant.
    """
    if tier not in DISPATCH_TIERS:
        raise ValueError(
            f"unknown dispatch tier {tier!r}; expected one of "
            f"{DISPATCH_TIERS}"
        )
    prev = dispatch_override()
    _local.tier = tier
    try:
        yield
    finally:
        _local.tier = prev


def resolve_dispatch(
    dispatch: str = "auto",
    interpret: Optional[bool] = None,
    *,
    off_tpu: str = "reference",
    has_reference: bool = True,
) -> str:
    """Resolve ('auto' | tier, legacy flag) to a concrete dispatch tier.

    Precedence: an enclosing `force_dispatch` wins over everything;
    then the legacy ``interpret=`` flag (True -> "interpret", False ->
    "pallas"); then an explicit ``dispatch=`` tier; then "auto" picks
    "pallas" on TPU and ``off_tpu`` elsewhere (each kernel states its
    own off-TPU default: "reference" where the jnp formulation is the
    fast path, "interpret" where the interpreter is cheap enough to
    keep CI exercising the kernel body — `tdc` flips between the two
    on batch size).

    Kernels without a standalone reference tier (``has_reference=
    False``: `gru`, `fex_fused`) degrade a forced/explicit "reference"
    to "interpret" — the interpreter is their bit-identical non-Mosaic
    evaluation of the same body.
    """
    forced = dispatch_override()
    if forced is not None:
        return forced if has_reference or forced != "reference" else "interpret"
    if interpret is not None:  # legacy flag wins when given explicitly
        return "interpret" if interpret else "pallas"
    if dispatch != "auto":
        if dispatch not in DISPATCH_TIERS:
            raise ValueError(
                f"unknown dispatch {dispatch!r}; "
                "expected 'auto', 'pallas', 'interpret' or 'reference'"
            )
        if dispatch == "reference" and not has_reference:
            return "interpret"
        return dispatch
    if jax.default_backend() == "tpu":
        return "pallas"
    return off_tpu


def trace_aware_jit(fn, *, static_argnames=()):
    """Wrap a kernel entry point: jit at top level, inline under a trace.

    Batch shapes are static under tracing, so dispatch resolves the
    same way inside an outer jit (e.g. the fused serving tick of
    `repro.serving.serve_loop` or `KWSPipeline.features`) as at the
    top level — but when already inside a trace the wrapper calls
    ``fn`` directly instead of nesting another `jax.jit`, so the
    caller's program keeps a single jaxpr with no inner call boundary.
    """
    jitted = jax.jit(fn, static_argnames=static_argnames)

    @functools.wraps(fn)
    def call(*args, **kwargs):
        if jax.core.trace_state_clean():
            return jitted(*args, **kwargs)
        return fn(*args, **kwargs)

    return call
