"""Pallas TPU kernels for the compute hot spots (validated in
interpret mode on CPU; BlockSpec layouts target TPU VMEM/MXU).

  fex_fused — biquad filterbank + FWR + frame accumulation, fused
  gru       — weights-resident GRU sequence (the IC's WMEM insight)
  intgemm   — int16 x int8 -> saturating-int24 matmul (HPE datapath)
  tdc       — SRO DeltaSigma TDC + XOR diff + CIC decimation
  wkv6      — state-resident RWKV6 recurrence (the §Perf cell-C lever)
"""

from repro.kernels.fex_fused import fex_fused, fex_fused_ref
from repro.kernels.gru import gru_sequence, gru_sequence_ref
from repro.kernels.intgemm import intgemm, intgemm_ref
from repro.kernels.tdc import tdc_counts, tdc_counts_ref
from repro.kernels.wkv6 import wkv6, wkv6_ref

__all__ = [
    "fex_fused", "fex_fused_ref",
    "gru_sequence", "gru_sequence_ref",
    "intgemm", "intgemm_ref",
    "tdc_counts", "tdc_counts_ref",
    "wkv6", "wkv6_ref",
]
