"""Pallas TPU kernels for the compute hot spots (validated in
interpret mode on CPU; BlockSpec layouts target TPU VMEM/MXU).

  fex_fused  — biquad filterbank + FWR + frame accumulation, fused
  gru        — weights-resident GRU sequence (the IC's WMEM insight)
  intgemm    — int16 x int8 -> saturating-int24 matmul (HPE datapath)
  tdc        — SRO DeltaSigma TDC + XOR diff + CIC decimation
  tick_fused — the WHOLE 16 ms serving tick (frontend + cascade gate +
               ΔGRU/GRU layers + FC + softmax + smoothing) as one
               megakernel over stream blocks, with a gather-only ΔGRU
               column update so temporal sparsity becomes wall-clock
  wkv6       — state-resident RWKV6 recurrence (the §Perf cell-C lever)

Dispatch-tier selection (pallas on TPU / interpret / reference, the
legacy ``interpret=`` flag, the `force_dispatch` override, trace-aware
no-nested-jit calls) is shared across all kernels: `repro.kernels.
dispatch`.
"""

from jax.experimental.pallas import tpu as _pltpu

# --- version-compat shim -------------------------------------------------
# jax renamed the Mosaic compiler-params dataclass across releases:
# `pltpu.CompilerParams` (old) -> `pltpu.TPUCompilerParams` -> (newer
# releases again) `pltpu.CompilerParams`. Resolve whichever this jax
# provides once, here, so every kernel builds against one spelling.
_TPU_COMPILER_PARAMS_CLS = getattr(
    _pltpu, "TPUCompilerParams", None
) or getattr(_pltpu, "CompilerParams", None)


def tpu_compiler_params(**kwargs):
    """Build a pallas TPU compiler-params object on any supported jax.

    Unknown keyword arguments (fields removed in some jax versions) are
    dropped rather than raised, so kernels can always pass their full
    intent (e.g. ``dimension_semantics``).
    """
    if _TPU_COMPILER_PARAMS_CLS is None:  # pragma: no cover
        return None
    import dataclasses

    if dataclasses.is_dataclass(_TPU_COMPILER_PARAMS_CLS):
        fields = {f.name for f in dataclasses.fields(_TPU_COMPILER_PARAMS_CLS)}
        kwargs = {k: v for k, v in kwargs.items() if k in fields}
    return _TPU_COMPILER_PARAMS_CLS(**kwargs)


from repro.kernels.dispatch import (
    DISPATCH_TIERS,
    dispatch_override,
    force_dispatch,
    resolve_dispatch,
    trace_aware_jit,
)
from repro.kernels.fex_fused import fex_fused, fex_fused_ref
from repro.kernels.gru import gru_sequence, gru_sequence_ref
from repro.kernels.intgemm import intgemm, intgemm_ref
from repro.kernels.tdc import tdc_counts, tdc_counts_ref
from repro.kernels.wkv6 import wkv6, wkv6_ref

# tick_fused traces classifier backends (which call intgemm) inside its
# kernel body, so it must import LAST: everything it reroutes through
# `force_dispatch("reference")` is already bound above.
from repro.kernels.tick_fused import (
    tick_fused,
    tick_fused_pallas,
    tick_reference,
)

__all__ = [
    "tpu_compiler_params",
    "DISPATCH_TIERS", "dispatch_override", "force_dispatch",
    "resolve_dispatch", "trace_aware_jit",
    "fex_fused", "fex_fused_ref",
    "gru_sequence", "gru_sequence_ref",
    "intgemm", "intgemm_ref",
    "tdc_counts", "tdc_counts_ref",
    "tick_fused", "tick_fused_pallas", "tick_reference",
    "wkv6", "wkv6_ref",
]
