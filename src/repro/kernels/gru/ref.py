"""Oracle for the GRU sequence kernel — delegates to the core float GRU
(quantization off) so kernel and software model share one definition."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.gru import GRUConfig, gru_layer


def gru_sequence_ref(xs, w, u, b_i, b_h, h0):
    """(T, B, I) time-major in -> (T, B, H) time-major out."""
    cfg = GRUConfig(
        input_dim=xs.shape[-1],
        hidden_dim=u.shape[0],
        quantized=False,
    )
    layer = {"w_i": w, "w_h": u, "b_i": b_i, "b_h": b_h}
    hs, _ = gru_layer(layer, jnp.moveaxis(xs, 0, 1), cfg, h0=h0)
    return jnp.moveaxis(hs, 0, 1)
