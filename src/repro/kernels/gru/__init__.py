from repro.kernels.gru.ops import gru_sequence
from repro.kernels.gru.ref import gru_sequence_ref

__all__ = ["gru_sequence", "gru_sequence_ref"]
