"""jit'd wrapper: batch-major API, auto interpret off-TPU, batch padding."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.gru.kernel import gru_sequence_pallas


@functools.partial(jax.jit, static_argnames=("block_batch", "interpret"))
def _gru_seq_jit(xs, w, u, b_i, b_h, h0, block_batch, interpret):
    return gru_sequence_pallas(
        xs, w, u, b_i, b_h, h0,
        block_batch=block_batch, interpret=interpret,
    )


def gru_sequence(
    xs: jnp.ndarray,  # (B, T, I) batch-major
    w: jnp.ndarray,
    u: jnp.ndarray,
    b_i: jnp.ndarray,
    b_h: jnp.ndarray,
    h0: Optional[jnp.ndarray] = None,
    block_batch: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """(B, T, I) -> (B, T, H) with weights resident in VMEM."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if block_batch is None:
        block_batch = 8 if interpret else 128
    b = xs.shape[0]
    h = u.shape[0]
    if h0 is None:
        h0 = jnp.zeros((b, h), xs.dtype)
    pad = (-b) % block_batch
    if pad:
        xs = jnp.concatenate(
            [xs, jnp.zeros((pad,) + xs.shape[1:], xs.dtype)], axis=0
        )
        h0 = jnp.concatenate([h0, jnp.zeros((pad, h), h0.dtype)], axis=0)
    out = _gru_seq_jit(
        jnp.moveaxis(xs, 1, 0), w, u, b_i, b_h, h0, block_batch, interpret
    )
    return jnp.moveaxis(out, 0, 1)[:b]
