"""Public wrapper: batch-major API, shared dispatch tiers, batch padding.

Tier selection (pallas on TPU, interpreter off-TPU — this kernel has
no standalone jnp reference, the interpreter IS its non-Mosaic
evaluation) and the trace-aware jit discipline come from
`repro.kernels.dispatch`.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.dispatch import resolve_dispatch, trace_aware_jit
from repro.kernels.gru.kernel import gru_sequence_pallas

_gru_seq_call = trace_aware_jit(
    gru_sequence_pallas, static_argnames=("block_batch", "interpret")
)


def gru_sequence(
    xs: jnp.ndarray,  # (B, T, I) batch-major
    w: jnp.ndarray,
    u: jnp.ndarray,
    b_i: jnp.ndarray,
    b_h: jnp.ndarray,
    h0: Optional[jnp.ndarray] = None,
    block_batch: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """(B, T, I) -> (B, T, H) with weights resident in VMEM."""
    path = resolve_dispatch(
        interpret=interpret, off_tpu="interpret", has_reference=False
    )
    interpret = path != "pallas"
    if block_batch is None:
        block_batch = 8 if interpret else 128
    b = xs.shape[0]
    h = u.shape[0]
    if h0 is None:
        h0 = jnp.zeros((b, h), xs.dtype)
    pad = (-b) % block_batch
    if pad:
        xs = jnp.concatenate(
            [xs, jnp.zeros((pad,) + xs.shape[1:], xs.dtype)], axis=0
        )
        h0 = jnp.concatenate([h0, jnp.zeros((pad, h), h0.dtype)], axis=0)
    out = _gru_seq_call(
        jnp.moveaxis(xs, 1, 0), w, u, b_i, b_h, h0,
        block_batch=block_batch, interpret=interpret,
    )
    return jnp.moveaxis(out, 0, 1)[:b]
