"""Weights-resident GRU sequence kernel.

TPU transcription of the IC's accelerator (Section III-E): the whole
24 KB weight memory lives next to the MACs (WMEM SRAM) and never moves
during inference. Here the layer weights are pinned in VMEM across every
time step (constant-index BlockSpecs load them once), and the hidden
state h lives in VMEM scratch — nothing round-trips HBM except one frame
of input in and one frame of logits/hidden out per step.

Grid = (B/BB, T) with T sequential. Per step:
    gi = x_t @ W + b_i          (BB, 3H)
    gh = h   @ U + b_h          (BB, 3H)
    r = sigmoid(gi_r + gh_r); z = sigmoid(gi_z + gh_z)
    n = tanh(gi_n + r * gh_n)
    h' = (1 - z) * n + z * h    (PyTorch GRU convention, like the paper)

Matmul shapes (BB x I x 3H) = (128, 16..48, 144): one MXU pass each.
VMEM: W + U + b = (I+H)*3H*4 B < 56 KB — trivially resident, same
work-fits-in-SRAM property the IC exploits.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _gru_seq_kernel(
    x_ref,  # (1, BB, I) this step's input
    w_ref,  # (I, 3H)
    u_ref,  # (H, 3H)
    bi_ref,  # (1, 3H)
    bh_ref,  # (1, 3H)
    h0_ref,  # (BB, H) initial state for this batch tile
    out_ref,  # (1, BB, H)
    h_ref,  # scratch (BB, H)
):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _reset():
        h_ref[...] = h0_ref[...].astype(h_ref.dtype)

    h = h_ref[...]  # f32 scratch — state accumulates in f32
    x = x_ref[0, :, :].astype(jnp.float32)
    hdim = h.shape[-1]

    gi = (
        jnp.dot(x, w_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32)
        + bi_ref[0, :][None, :].astype(jnp.float32)
    )
    gh = (
        jnp.dot(h, u_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32)
        + bh_ref[0, :][None, :].astype(jnp.float32)
    )
    i_r, i_z, i_n = gi[:, :hdim], gi[:, hdim : 2 * hdim], gi[:, 2 * hdim :]
    h_r, h_z, h_n = gh[:, :hdim], gh[:, hdim : 2 * hdim], gh[:, 2 * hdim :]
    r = jax.nn.sigmoid(i_r + h_r)
    z = jax.nn.sigmoid(i_z + h_z)
    n = jnp.tanh(i_n + r * h_n)
    h_new = (1.0 - z) * n + z * h

    h_ref[...] = h_new.astype(h_ref.dtype)
    out_ref[0, :, :] = h_new.astype(out_ref.dtype)


def gru_sequence_pallas(
    xs: jnp.ndarray,  # (T, B, I) time-major
    w: jnp.ndarray,  # (I, 3H)
    u: jnp.ndarray,  # (H, 3H)
    b_i: jnp.ndarray,  # (3H,)
    b_h: jnp.ndarray,  # (3H,)
    h0: jnp.ndarray,  # (B, H)
    *,
    block_batch: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns all hidden states, time-major (T, B, H)."""
    t, b, i = xs.shape
    h = u.shape[0]
    if b % block_batch:
        raise ValueError(f"B={b} not a multiple of block_batch={block_batch}")
    return pl.pallas_call(
        _gru_seq_kernel,
        grid=(b // block_batch, t),
        in_specs=[
            pl.BlockSpec((1, block_batch, i), lambda ib, it: (it, ib, 0)),
            pl.BlockSpec((i, 3 * h), lambda ib, it: (0, 0)),
            pl.BlockSpec((h, 3 * h), lambda ib, it: (0, 0)),
            pl.BlockSpec((1, 3 * h), lambda ib, it: (0, 0)),
            pl.BlockSpec((1, 3 * h), lambda ib, it: (0, 0)),
            pl.BlockSpec((block_batch, h), lambda ib, it: (ib, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, block_batch, h), lambda ib, it: (it, ib, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((t, b, h), xs.dtype),
        scratch_shapes=[pltpu.VMEM((block_batch, h), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xs, w, u, b_i[None, :], b_h[None, :], h0)
