"""Oracle: exact int32 matmul with final 24-bit saturation."""

from __future__ import annotations

import jax.numpy as jnp

INT24_MAX = 2**23 - 1
INT24_MIN = -(2**23)


def intgemm_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    acc = jnp.dot(
        x.astype(jnp.int32),
        w.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    return jnp.clip(acc, INT24_MIN, INT24_MAX)
