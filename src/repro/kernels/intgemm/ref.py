"""Oracle: exact int32 matmul with final 24-bit saturation.

Exactness bound: products of 14-bit activation codes and 8-bit weight
codes are < 2^20, so the int32 accumulator is exact for K < 2^11 —
far above the classifier's K <= 96 — and the only nonlinearity is the
final saturation to the IC's 24-bit HPE accumulator range. This is the
off-TPU serving path of the integer classifier (`repro.core.gru_int`)
and the bit-identity reference the Pallas kernel is tested against.
"""

from __future__ import annotations

import jax.numpy as jnp

INT24_MAX = 2**23 - 1
INT24_MIN = -(2**23)


def intgemm_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    acc = jnp.dot(
        x.astype(jnp.int32),
        w.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    return jnp.clip(acc, INT24_MIN, INT24_MAX)
