"""Config-aware public entry point for the integer GEMM kernel.

`intgemm` picks one of three equivalent implementations per call:

  * ``pallas``    — the compiled Mosaic kernel (TPU), the MXU analogue
                    of the IC's 8-HPE int8 datapath;
  * ``interpret`` — the same kernel body run by the Pallas interpreter
                    (validates kernel logic on CPU CI);
  * ``reference`` — the exact jnp int32 matmul + final 24-bit saturation
                    (`intgemm_ref`; fastest off-TPU, bit-identical to
                    the kernel for all in-range inputs).

Dispatch is automatic (pallas on TPU, reference elsewhere) unless
forced via ``dispatch``; the legacy ``interpret=`` flag is honored.

`intgemm` is trace-aware: inside an outer trace (the fused serving tick
of `repro.serving.serve_loop`, the integer classifier's `lax.scan`
drivers) it inlines the chosen implementation instead of nesting
another `jax.jit`, so the caller's program keeps a single jaxpr.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.intgemm.kernel import intgemm_pallas
from repro.kernels.intgemm.ref import intgemm_ref


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def _intgemm_jit(x, w, block_m, block_n, block_k, interpret):
    return intgemm_pallas(
        x, w,
        block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret,
    )


def resolve_intgemm_dispatch(
    dispatch: str = "auto",
    interpret: Optional[bool] = None,
) -> str:
    """Resolve 'auto' to a concrete path for this backend."""
    if interpret is not None:  # legacy flag wins when given explicitly
        return "interpret" if interpret else "pallas"
    if dispatch != "auto":
        if dispatch not in ("pallas", "interpret", "reference"):
            raise ValueError(
                f"unknown dispatch {dispatch!r}; "
                "expected 'auto', 'pallas', 'interpret' or 'reference'"
            )
        return dispatch
    # Off-TPU the interpreter is per-element slow and the jnp reference
    # is bit-identical by contract (tests/test_kernels.py), so serving
    # hot paths (the integer classifier tick) auto-select the reference.
    return "pallas" if jax.default_backend() == "tpu" else "reference"


def intgemm(
    x: jnp.ndarray,  # (M, K) int (14-bit activation codes)
    w: jnp.ndarray,  # (K, N) int8 weight codes
    block_m: int = 8,
    block_n: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
    dispatch: str = "auto",
) -> jnp.ndarray:
    """Saturating-24-bit int matmul, any (M, K, N) via zero padding."""
    path = resolve_intgemm_dispatch(dispatch, interpret)
    if path == "reference":
        return intgemm_ref(x, w)
    run_interpret = path == "interpret"
    m, k = x.shape
    n = w.shape[1]
    pm, pk, pn = (-m) % block_m, (-k) % block_k, (-n) % block_n
    xp = jnp.pad(x.astype(jnp.int32), ((0, pm), (0, pk)))
    wp = jnp.pad(w.astype(jnp.int32), ((0, pk), (0, pn)))
    if jax.core.trace_state_clean():
        out = _intgemm_jit(
            xp, wp, block_m, block_n, block_k, run_interpret
        )
    else:
        # already under an outer trace: inline the kernel call so the
        # caller's jit compiles one program (no nested-jit boundary)
        out = intgemm_pallas(
            xp, wp,
            block_m=block_m, block_n=block_n, block_k=block_k,
            interpret=run_interpret,
        )
    return out[:m, :n]
