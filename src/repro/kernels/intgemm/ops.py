"""jit'd wrapper with shape padding and auto-interpret off TPU."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.intgemm.kernel import intgemm_pallas


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def _intgemm_jit(x, w, block_m, block_n, block_k, interpret):
    return intgemm_pallas(
        x, w,
        block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret,
    )


def intgemm(
    x: jnp.ndarray,  # (M, K) int (14-bit activation codes)
    w: jnp.ndarray,  # (K, N) int8 weight codes
    block_m: int = 8,
    block_n: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Saturating-24-bit int matmul, any (M, K, N) via zero padding."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, k = x.shape
    n = w.shape[1]
    pm, pk, pn = (-m) % block_m, (-k) % block_k, (-n) % block_n
    xp = jnp.pad(x.astype(jnp.int32), ((0, pm), (0, pk)))
    wp = jnp.pad(w.astype(jnp.int32), ((0, pk), (0, pn)))
    out = _intgemm_jit(xp, wp, block_m, block_n, block_k, interpret)
    return out[:m, :n]
