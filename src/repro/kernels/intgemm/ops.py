"""Config-aware public entry point for the integer GEMM kernel.

`intgemm` picks one of three equivalent implementations per call:

  * ``pallas``    — the compiled Mosaic kernel (TPU), the MXU analogue
                    of the IC's 8-HPE int8 datapath;
  * ``interpret`` — the same kernel body run by the Pallas interpreter
                    (validates kernel logic on CPU CI);
  * ``reference`` — the exact jnp int32 matmul + final 24-bit saturation
                    (`intgemm_ref`; fastest off-TPU, bit-identical to
                    the kernel for all in-range inputs).

Tier selection, the legacy ``interpret=`` flag, the `force_dispatch`
override, and the trace-aware no-nested-jit call discipline are the
shared `repro.kernels.dispatch` machinery. The override matters here:
`intgemm` is traced inside the fused-tick megakernel's body by the
integer/delta-int classifier backends, where `force_dispatch
("reference")` reroutes it to `intgemm_ref` (a `pallas_call` cannot
nest).
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.dispatch import resolve_dispatch, trace_aware_jit
from repro.kernels.intgemm.kernel import intgemm_pallas
from repro.kernels.intgemm.ref import intgemm_ref

_intgemm_call = trace_aware_jit(
    intgemm_pallas,
    static_argnames=("block_m", "block_n", "block_k", "interpret"),
)


def resolve_intgemm_dispatch(
    dispatch: str = "auto",
    interpret: Optional[bool] = None,
) -> str:
    """Resolve 'auto' to a concrete path for this backend.

    Off-TPU the interpreter is per-element slow and the jnp reference
    is bit-identical by contract (tests/test_kernels.py), so serving
    hot paths (the integer classifier tick) auto-select the reference.
    """
    return resolve_dispatch(dispatch, interpret, off_tpu="reference")


def intgemm(
    x: jnp.ndarray,  # (M, K) int (14-bit activation codes)
    w: jnp.ndarray,  # (K, N) int8 weight codes
    block_m: int = 8,
    block_n: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
    dispatch: str = "auto",
) -> jnp.ndarray:
    """Saturating-24-bit int matmul, any (M, K, N) via zero padding."""
    path = resolve_intgemm_dispatch(dispatch, interpret)
    if path == "reference":
        return intgemm_ref(x, w)
    run_interpret = path == "interpret"
    m, k = x.shape
    n = w.shape[1]
    pm, pk, pn = (-m) % block_m, (-k) % block_k, (-n) % block_n
    xp = jnp.pad(x.astype(jnp.int32), ((0, pm), (0, pk)))
    wp = jnp.pad(w.astype(jnp.int32), ((0, pk), (0, pn)))
    out = _intgemm_call(
        xp, wp,
        block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=run_interpret,
    )
    return out[:m, :n]
