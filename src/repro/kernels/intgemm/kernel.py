"""Integer GEMM kernel modeling the IC's HPE datapath (Section III-E).

HPE arithmetic: 14-bit activation x 8-bit weight multiplies into a 24-bit
saturating accumulator. On TPU the analogue is the MXU's native int8 path
with int32 accumulation; we saturate the final reduction to the 24-bit
range so results are bit-identical to the hardware (for the network sizes
involved, K <= 512, the exact int32 sum cannot overflow before the final
saturation: |x| < 2^13, |w| < 2^7 -> |x.w| < K * 2^20 < 2^30).

Grid = (M/BM, N/BN, K/BK), K sequential innermost; partial products
accumulate in an int32 VMEM scratch tile; the last K step saturates to
[-2^23, 2^23 - 1] and writes out.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

INT24_MAX = 2**23 - 1
INT24_MIN = -(2**23)


def _intgemm_kernel(x_ref, w_ref, out_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.int32),
        w_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == n_k - 1)
    def _write():
        out_ref[...] = jnp.clip(acc_ref[...], INT24_MIN, INT24_MAX)


def intgemm_pallas(
    x: jnp.ndarray,  # (M, K) int16 activation codes (14-bit range)
    w: jnp.ndarray,  # (K, N) int8 weight codes
    *,
    block_m: int = 8,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Saturating 24-bit integer matmul -> (M, N) int32 codes."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    if m % block_m or n % block_n or k % block_k:
        raise ValueError(
            f"shapes ({m},{k})x({k},{n}) not multiples of blocks "
            f"({block_m},{block_k},{block_n})"
        )
    n_k = k // block_k
    import functools

    return pl.pallas_call(
        functools.partial(_intgemm_kernel, n_k=n_k),
        grid=(m // block_m, n // block_n, n_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w)
