from repro.kernels.intgemm.ops import intgemm
from repro.kernels.intgemm.ref import intgemm_ref

__all__ = ["intgemm", "intgemm_ref"]
