"""jit'd wrapper for the TDC kernel, config-aware."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.tdfex import TDFExConfig, TDFExState
from repro.kernels.tdc.kernel import tdc_pallas


@functools.partial(
    jax.jit,
    static_argnames=(
        "samples_per_frame", "os", "f_tdc", "n_phases",
        "block_batch", "interpret",
    ),
)
def _tdc_jit(u, f0_eff, k_eff, samples_per_frame, os, f_tdc, n_phases,
             block_batch, interpret):
    return tdc_pallas(
        u, f0_eff, k_eff,
        samples_per_frame=samples_per_frame, os=os, f_tdc=f_tdc,
        n_phases=n_phases, block_batch=block_batch, interpret=interpret,
    )


def tdc_counts(
    u: jnp.ndarray,  # (B, T, C) rectified @ fs_internal
    cfg: TDFExConfig,
    chip: Optional[TDFExState] = None,
    block_batch: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Config-level entry point: (B, T, C) -> (B, F, C) counts."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if block_batch is None:
        block_batch = 8 if interpret else 128
    c = u.shape[-1]
    gain = jnp.ones((c,), jnp.float32)
    if chip is not None:
        gain = 1.0 + chip.gain_mismatch
    f0_eff = cfg.f_free_hz * gain
    k_eff = cfg.k_sro_hz * gain
    samples_per_frame = cfg.decimation // cfg.tdc_oversample
    b = u.shape[0]
    pad = (-b) % block_batch
    if pad:
        u = jnp.concatenate(
            [u, jnp.zeros((pad,) + u.shape[1:], u.dtype)], axis=0
        )
    out = _tdc_jit(
        u, f0_eff, k_eff, samples_per_frame, cfg.tdc_oversample,
        cfg.f_tdc, cfg.n_phases, block_batch, interpret,
    )
    return out[:b]
