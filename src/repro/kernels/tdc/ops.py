"""Config-aware public entry point for the TDC kernel.

`tdc_counts` picks one of three equivalent implementations per call:

  * ``pallas``    — the compiled Mosaic kernel (TPU);
  * ``interpret`` — the same kernel body run by the Pallas interpreter
                    (validates kernel logic on CPU CI);
  * ``reference`` — the pure-jnp cumsum/floor formulation of
                    `repro.core.tdfex.sro_tdc` (fastest off-TPU, and the
                    fallback for shapes the kernel does not tile well).

Tier selection, the legacy ``interpret=`` flag, the `force_dispatch`
override, and the trace-aware no-nested-jit call discipline are the
shared `repro.kernels.dispatch` machinery; this kernel's only local
policy is the off-TPU auto split — small batches run the interpreter
(cheap, keeps CI validating the kernel logic), large batches the
vectorized jnp reference (the interpreter is per-element slow).
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.tdfex import TDFExConfig, TDFExState, sro_tdc
from repro.kernels.dispatch import resolve_dispatch, trace_aware_jit
from repro.kernels.tdc.kernel import tdc_pallas

_tdc_call = trace_aware_jit(
    tdc_pallas,
    static_argnames=(
        "samples_per_frame", "os", "f_tdc", "n_phases",
        "block_batch", "interpret",
    ),
)


def resolve_tdc_dispatch(
    batch: int,
    dispatch: str = "auto",
    interpret: Optional[bool] = None,
) -> str:
    """Resolve 'auto' to a concrete path for this backend + batch shape."""
    return resolve_dispatch(
        dispatch, interpret,
        off_tpu="interpret" if batch <= 8 else "reference",
    )


def tdc_counts(
    u: jnp.ndarray,  # (B, T, C) rectified @ fs_internal
    cfg: TDFExConfig,
    chip: Optional[TDFExState] = None,
    block_batch: Optional[int] = None,
    interpret: Optional[bool] = None,
    dispatch: str = "auto",
) -> jnp.ndarray:
    """Config-level entry point: (B, T, C) -> (B, F, C) counts."""
    b = u.shape[0]
    path = resolve_tdc_dispatch(b, dispatch, interpret)
    if path == "reference":
        return sro_tdc(u, cfg, chip)
    run_interpret = path == "interpret"
    if block_batch is None:
        block_batch = 8 if run_interpret else 128
    c = u.shape[-1]
    gain = jnp.ones((c,), jnp.float32)
    if chip is not None:
        gain = 1.0 + chip.gain_mismatch
    f0_eff = cfg.f_free_hz * gain
    k_eff = cfg.k_sro_hz * gain
    samples_per_frame = cfg.decimation // cfg.tdc_oversample
    # trim to whole frames (the reference path does the same inside its
    # CIC decimation)
    t_use = (u.shape[1] // samples_per_frame) * samples_per_frame
    u = u[:, :t_use]
    pad = (-b) % block_batch
    if pad:
        u = jnp.concatenate(
            [u, jnp.zeros((pad,) + u.shape[1:], u.dtype)], axis=0
        )
    out = _tdc_call(
        u, f0_eff, k_eff,
        samples_per_frame=samples_per_frame,
        os=cfg.tdc_oversample, f_tdc=cfg.f_tdc,
        n_phases=cfg.n_phases, block_batch=block_batch,
        interpret=run_interpret,
    )
    return out[:b]
