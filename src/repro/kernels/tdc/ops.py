"""Config-aware public entry point for the TDC kernel.

`tdc_counts` picks one of three equivalent implementations per call:

  * ``pallas``    — the compiled Mosaic kernel (TPU);
  * ``interpret`` — the same kernel body run by the Pallas interpreter
                    (validates kernel logic on CPU CI);
  * ``reference`` — the pure-jnp cumsum/floor formulation of
                    `repro.core.tdfex.sro_tdc` (fastest off-TPU, and the
                    fallback for shapes the kernel does not tile well).

Dispatch is automatic (backend + batch shape) unless forced via the
``dispatch`` argument; the legacy ``interpret=`` flag is still honored.

`tdc_counts` is trace-aware: batch shapes are static under tracing, so
dispatch resolves the same way inside an outer jit (e.g. the fused
serving tick of `repro.serving.serve_loop` or `KWSPipeline.features`)
as at the top level — but when already inside a trace it inlines the
kernel call instead of nesting another `jax.jit`, so the caller's
program keeps a single jaxpr with no inner call boundary.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.tdfex import TDFExConfig, TDFExState, sro_tdc
from repro.kernels.tdc.kernel import tdc_pallas


@functools.partial(
    jax.jit,
    static_argnames=(
        "samples_per_frame", "os", "f_tdc", "n_phases",
        "block_batch", "interpret",
    ),
)
def _tdc_jit(u, f0_eff, k_eff, samples_per_frame, os, f_tdc, n_phases,
             block_batch, interpret):
    return tdc_pallas(
        u, f0_eff, k_eff,
        samples_per_frame=samples_per_frame, os=os, f_tdc=f_tdc,
        n_phases=n_phases, block_batch=block_batch, interpret=interpret,
    )


def resolve_tdc_dispatch(
    batch: int,
    dispatch: str = "auto",
    interpret: Optional[bool] = None,
) -> str:
    """Resolve 'auto' to a concrete path for this backend + batch shape."""
    if interpret is not None:  # legacy flag wins when given explicitly
        return "interpret" if interpret else "pallas"
    if dispatch != "auto":
        if dispatch not in ("pallas", "interpret", "reference"):
            raise ValueError(
                f"unknown dispatch {dispatch!r}; "
                "expected 'auto', 'pallas', 'interpret' or 'reference'"
            )
        return dispatch
    if jax.default_backend() == "tpu":
        return "pallas"
    # Off-TPU, small batches run the kernel body under the Pallas
    # interpreter (cheap, and it keeps CI validating the kernel logic);
    # the interpreter is per-element slow, so large batches switch to
    # the vectorized jnp reference for throughput.
    return "interpret" if batch <= 8 else "reference"


def tdc_counts(
    u: jnp.ndarray,  # (B, T, C) rectified @ fs_internal
    cfg: TDFExConfig,
    chip: Optional[TDFExState] = None,
    block_batch: Optional[int] = None,
    interpret: Optional[bool] = None,
    dispatch: str = "auto",
) -> jnp.ndarray:
    """Config-level entry point: (B, T, C) -> (B, F, C) counts."""
    b = u.shape[0]
    path = resolve_tdc_dispatch(b, dispatch, interpret)
    if path == "reference":
        return sro_tdc(u, cfg, chip)
    run_interpret = path == "interpret"
    if block_batch is None:
        block_batch = 8 if run_interpret else 128
    c = u.shape[-1]
    gain = jnp.ones((c,), jnp.float32)
    if chip is not None:
        gain = 1.0 + chip.gain_mismatch
    f0_eff = cfg.f_free_hz * gain
    k_eff = cfg.k_sro_hz * gain
    samples_per_frame = cfg.decimation // cfg.tdc_oversample
    # trim to whole frames (the reference path does the same inside its
    # CIC decimation)
    t_use = (u.shape[1] // samples_per_frame) * samples_per_frame
    u = u[:, :t_use]
    pad = (-b) % block_batch
    if pad:
        u = jnp.concatenate(
            [u, jnp.zeros((pad,) + u.shape[1:], u.dtype)], axis=0
        )
    if jax.core.trace_state_clean():
        out = _tdc_jit(
            u, f0_eff, k_eff, samples_per_frame, cfg.tdc_oversample,
            cfg.f_tdc, cfg.n_phases, block_batch, run_interpret,
        )
    else:
        # already under an outer trace: inline the kernel call so the
        # caller's jit compiles one program (no nested-jit boundary)
        out = tdc_pallas(
            u, f0_eff, k_eff,
            samples_per_frame=samples_per_frame,
            os=cfg.tdc_oversample, f_tdc=cfg.f_tdc,
            n_phases=cfg.n_phases, block_batch=block_batch,
            interpret=run_interpret,
        )
    return out[:b]
