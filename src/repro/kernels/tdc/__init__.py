from repro.kernels.tdc.ops import tdc_counts
from repro.kernels.tdc.ref import tdc_counts_ref

__all__ = ["tdc_counts", "tdc_counts_ref"]
