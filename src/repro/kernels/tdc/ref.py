"""Oracle for the TDC kernel: exact float64 fractional-carry simulation
(numpy), plus a helper that reproduces `repro.core.tdfex.sro_tdc`'s
cumsum/floor/diff formulation — the two agree exactly in float64."""

from __future__ import annotations

import numpy as np


def tdc_counts_ref(
    u: np.ndarray,  # (B, T, C) rectified input at the internal rate
    f0_eff: np.ndarray,  # (C,)
    k_eff: np.ndarray,  # (C,)
    samples_per_frame: int,
    os: int,
    f_tdc: float,
    n_phases: int = 15,
) -> np.ndarray:
    """Exact float64 reference: (B, F, C) counts."""
    u = np.asarray(u, np.float64)
    b, t, c = u.shape
    n_frames = t // samples_per_frame
    u = u[:, : n_frames * samples_per_frame, :]
    # ZOH to the TDC rate, then cumsum phase / floor / frame-diff.
    uu = np.repeat(u, os, axis=1)
    f = np.maximum(
        np.asarray(f0_eff, np.float64)[None, None, :]
        + np.asarray(k_eff, np.float64)[None, None, :] * uu,
        0.0,
    )
    phase = np.cumsum(f / f_tdc, axis=1)
    counts = np.floor(n_phases * phase)
    ticks_per_frame = samples_per_frame * os
    frame_edges = counts[:, ticks_per_frame - 1 :: ticks_per_frame, :]
    prev = np.concatenate(
        [np.zeros((b, 1, c)), frame_edges[:, :-1, :]], axis=1
    )
    return (frame_edges - prev).astype(np.float64)
