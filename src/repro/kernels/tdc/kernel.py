"""SRO DeltaSigma-TDC Pallas kernel (Sections III-B/D).

Simulates, per channel: SRO frequency f = f0_eff + k_eff * u; phase
integration; 15-phase floor quantization; XOR first-difference; 1st-order
CIC decimation. Uses the exact fractional-carry formulation

    r <- r + n_phases * f * dt ;  incr = floor(r) ;  r <- r - incr

whose per-frame sum telescopes to the quantized phase increment — the
same math as counter sampling + XOR diff + boxcar, but without an
unbounded phase accumulator (float32-safe for arbitrarily long streams,
like the real free-running counter which wraps modulo 2^width).

The 2x zero-order-hold from the 32 kHz audio-internal rate to the TDC
rate is fused (os ticks per input sample), so the 64 kHz stream is never
materialized: HBM traffic is one read of (B, T, C) and one write of
(B, F, C) — the same in-stream property as the silicon.

Grid = (B/BB, n_frames) with frames sequential (carry r); per-frame
fori_loop over samples, os ticks unrolled inside.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _tdc_kernel(
    u_ref,  # (BB, S, C) rectified input, one frame of samples
    f0_ref,  # (1, C) effective free-running frequency (incl. mismatch)
    k_ref,  # (1, C) effective gain (incl. mismatch)
    out_ref,  # (BB, 1, C) counts per frame
    r_ref,  # scratch (BB, C): fractional phase carry in [0, 1)
    *,
    samples_per_frame: int,
    os: int,
    dt: float,
    n_phases: int,
):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _reset():
        r_ref[...] = jnp.zeros_like(r_ref)

    f0 = f0_ref[0, :][None, :]
    kg = k_ref[0, :][None, :]
    scale = n_phases * dt

    def sample_step(i, carry):
        r, acc = carry
        u = u_ref[:, i, :]  # (BB, C)
        delta = scale * jnp.maximum(f0 + kg * u, 0.0)
        for _ in range(os):  # os static (ZOH ticks per sample)
            r = r + delta
            incr = jnp.floor(r)
            r = r - incr
            acc = acc + incr
        return (r, acc)

    r0 = r_ref[...]
    acc0 = jnp.zeros_like(r0)
    r, acc = jax.lax.fori_loop(
        0, samples_per_frame, sample_step, (r0, acc0)
    )
    r_ref[...] = r
    out_ref[:, 0, :] = acc


def tdc_pallas(
    u: jnp.ndarray,  # (B, T, C) rectified, at the 32 kHz internal rate
    f0_eff: jnp.ndarray,  # (C,)
    k_eff: jnp.ndarray,  # (C,)
    *,
    samples_per_frame: int,
    os: int,
    f_tdc: float,
    n_phases: int = 15,
    block_batch: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns per-frame counts (B, T // samples_per_frame, C)."""
    b, t, c = u.shape
    if t % samples_per_frame:
        raise ValueError(f"T={t} not multiple of frame {samples_per_frame}")
    if b % block_batch:
        raise ValueError(f"B={b} not multiple of block {block_batch}")
    n_frames = t // samples_per_frame
    kernel = functools.partial(
        _tdc_kernel,
        samples_per_frame=samples_per_frame,
        os=os,
        dt=1.0 / f_tdc,
        n_phases=n_phases,
    )
    return pl.pallas_call(
        kernel,
        grid=(b // block_batch, n_frames),
        in_specs=[
            pl.BlockSpec(
                (block_batch, samples_per_frame, c),
                lambda ib, it: (ib, it, 0),
            ),
            pl.BlockSpec((1, c), lambda ib, it: (0, 0)),
            pl.BlockSpec((1, c), lambda ib, it: (0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (block_batch, 1, c), lambda ib, it: (ib, it, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, n_frames, c), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_batch, c), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(u, f0_eff[None, :], k_eff[None, :])
