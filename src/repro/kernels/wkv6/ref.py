"""Oracle: the backbone's own sequential WKV6 (already validated against
the chunked training formulation in tests/test_ssm_kernels.py)."""

from repro.models.rwkv6 import wkv6_sequential


def wkv6_ref(r, k, v, logw, u):
    return wkv6_sequential(r, k, v, logw, u)
