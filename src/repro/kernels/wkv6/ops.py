"""jit'd wrapper: batch padding + auto interpret off TPU."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.wkv6.kernel import wkv6_pallas


@functools.partial(jax.jit, static_argnames=("block_batch", "interpret"))
def _wkv6_jit(r, k, v, logw, u, block_batch, interpret):
    return wkv6_pallas(
        r, k, v, logw, u, block_batch=block_batch, interpret=interpret
    )


def wkv6(
    r: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    logw: jnp.ndarray,
    u: jnp.ndarray,
    block_batch: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """(B, T, H, P) x4 + (H, P) -> (B, T, H, P), state-resident."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if block_batch is None:
        block_batch = 2 if interpret else 8
    b = r.shape[0]
    pad = (-b) % block_batch
    if pad:
        z = lambda x: jnp.concatenate(  # noqa: E731
            [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0
        )
        r, k, v, logw = z(r), z(k), z(v), z(logw)
    out = _wkv6_jit(r, k, v, logw, u, block_batch, interpret)
    return out[:b]
