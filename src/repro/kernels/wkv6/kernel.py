"""WKV6 recurrence Pallas kernel — the state-resident inner loop of the
RWKV6 backbone, and the direct scaled-up analogue of the paper's GRU
accelerator (state lives next to the compute; one frame in, one frame
out, nothing else moves).

Motivation measured in EXPERIMENTS.md §Perf cell C: the chunked XLA
formulation materializes (t, j, H, P) decay-ratio tensors in HBM —
~5 × 550 GB per training step at chunk 128. This kernel runs the exact
sequential recurrence

    y_t = r_t . (S + u ⊙ k_t v_t^T)
    S  <- diag(w_t) S + k_t v_t^T

with S (BB, P, P) pinned in VMEM scratch across the whole sequence: HBM
traffic is exactly one read of r/k/v/w and one write of y — zero
intermediate tensors. Grid = (B/BB, H, T) with T sequential (carry S).

Intended TPU layout: P=64 lanes x BB sublanes; the (BB, P, P) state is
BB*16 KB of VMEM (BB=8 -> 128 KB/core).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _wkv6_kernel(
    r_ref,  # (BB, 1, 1, P)
    k_ref,
    v_ref,
    w_ref,  # log-decay (<= 0)
    u_ref,  # (1, P) bonus for this head
    y_ref,  # (BB, 1, 1, P) output
    s_ref,  # scratch (BB, P, P): the resident state
):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _reset():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[:, 0, 0, :]  # (BB, P)
    k = k_ref[:, 0, 0, :]
    v = v_ref[:, 0, 0, :]
    w = jnp.exp(w_ref[:, 0, 0, :])  # decay in (0, 1]
    u = u_ref[0, :][None, :]  # (1, P)

    s = s_ref[...]  # (BB, P, P) keyed [key_dim, value_dim]
    kv = k[:, :, None] * v[:, None, :]  # (BB, P, P)
    y = jnp.sum(
        r[:, :, None] * (s + u[:, :, None] * kv), axis=1
    )  # (BB, P)
    s_ref[...] = s * w[:, :, None] + kv
    y_ref[:, 0, 0, :] = y.astype(y_ref.dtype)


def wkv6_pallas(
    r: jnp.ndarray,  # (B, T, H, P)
    k: jnp.ndarray,
    v: jnp.ndarray,
    logw: jnp.ndarray,  # (B, T, H, P), <= 0
    u: jnp.ndarray,  # (H, P)
    *,
    block_batch: int = 4,
    interpret: bool = False,
) -> jnp.ndarray:
    b, t, h, p = r.shape
    if b % block_batch:
        raise ValueError(f"B={b} not a multiple of {block_batch}")
    spec = pl.BlockSpec(
        (block_batch, 1, 1, p), lambda ib, ih, it: (ib, it, ih, 0)
    )
    return pl.pallas_call(
        _wkv6_kernel,
        grid=(b // block_batch, h, t),
        in_specs=[
            spec, spec, spec, spec,
            pl.BlockSpec((1, p), lambda ib, ih, it: (ih, 0)),
        ],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b, t, h, p), r.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_batch, p, p), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(r, k, v, logw, u)
