from repro.kernels.fex_fused.ops import fex_fused
from repro.kernels.fex_fused.ref import fex_fused_ref

__all__ = ["fex_fused", "fex_fused_ref"]
