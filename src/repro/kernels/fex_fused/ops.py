"""Public wrapper for the fused FEx kernel.

Tier selection (pallas on TPU, interpreter off-TPU — no standalone jnp
reference, the interpreter IS the non-Mosaic evaluation of the same
body) and the trace-aware jit discipline come from
`repro.kernels.dispatch`, so the same call site works in CI (CPU,
interpret validates the kernel body) and in production (TPU, compiled
Mosaic kernel).
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.filters import BiquadCoeffs
from repro.kernels.dispatch import resolve_dispatch, trace_aware_jit
from repro.kernels.fex_fused.kernel import fex_fused_pallas

_fex_fused_call = trace_aware_jit(
    fex_fused_pallas,
    static_argnames=("frame_len", "block_batch", "interpret"),
)


def fex_fused(
    x: jnp.ndarray,
    coeffs: BiquadCoeffs,
    frame_len: int,
    block_batch: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Fused biquad + FWR + frame average: (B, T) -> (B, F, C).

    Pads the batch up to the block size and trims T to a whole number of
    frames, so any (B, T) is accepted.
    """
    path = resolve_dispatch(
        interpret=interpret, off_tpu="interpret", has_reference=False
    )
    interpret = path != "pallas"
    if block_batch is None:
        block_batch = 8 if interpret else 256
    b, t = x.shape
    t_use = (t // frame_len) * frame_len
    x = x[:, :t_use]
    pad = (-b) % block_batch
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, t_use), x.dtype)], axis=0)
    # Coefficients stay f32 regardless of the IO dtype: the 100 Hz
    # channel's a1 ~ -1.9961 rounds to -1.9922 in bf16, pushing the pole
    # to the unit circle and blowing the filter up (the analog
    # equivalent: the FLL bias precision that sets each channel's f0).
    out = _fex_fused_call(
        x, coeffs.stacked(dtype=jnp.float32),
        frame_len=frame_len, block_batch=block_batch, interpret=interpret,
    )
    return out[:b]
