"""Pure-jnp oracle for the fused FEx kernel — composed from the core
software model so the kernel is checked against the *same* code the
paper-faithful pipeline uses."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.fex import biquad_filterbank, frame_average, full_wave_rectify
from repro.core.filters import BiquadCoeffs


def fex_fused_ref(
    x: jnp.ndarray, coeffs: BiquadCoeffs, frame_len: int
) -> jnp.ndarray:
    """(B, T) -> (B, T // frame_len, C), unfused reference chain."""
    y = biquad_filterbank(x, coeffs)
    return frame_average(full_wave_rectify(y), frame_len)
