"""Fused FEx Pallas kernel: biquad filterbank + FWR + frame accumulation.

The IC computes features *in-stream*: the per-channel band-passed waveform
never exists as a stored signal — only the rectified, decimated energy
leaves the analog front-end. This kernel is the TPU transcription of that
insight: the (B, T, C) filtered intermediate never touches HBM.

Memory-roofline napkin math (per 1 s clip, 32 kHz, C=16, f32):
  unfused:  write+read BPF output  2 * T*C*4 = 4.1 MB
            + read audio T*4      = 0.13 MB, write frames F*C*4 = 4 KB
  fused:    read audio 0.13 MB + write frames 4 KB      (~32x less traffic)

Layout: grid = (B/BB, T/FRAME); the frame axis is sequential ("arbitrary")
so the IIR state carried in VMEM scratch persists across frames; the batch
axis is parallel. Within a block the kernel scans FRAME time steps with a
fori_loop over (BB, C) vectors — batch in sublanes, channels in lanes
(C=16 zero-padded to the 128-lane register; BB defaults to 8 sublanes of
f32; on real TPUs BB=256 amortizes the scalar loop overhead and still uses
< 1 MB of VMEM).

State is transposed-direct-form-II per channel:
    y  = b0*x + s1
    s1 = b1*x - a1*y + s2
    s2 = b2*x - a2*y
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _fex_fused_kernel(
    x_ref,  # (BB, FRAME) audio block at the internal rate
    coef_ref,  # (5, C): b0, b1, b2, a1, a2
    out_ref,  # (BB, 1, C) frame output
    s1_ref,  # scratch (BB, C) IIR state
    s2_ref,  # scratch (BB, C)
    acc_ref,  # scratch (BB, C) rectified accumulator
    *,
    frame_len: int,
):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _reset():
        # New batch tile (frame index restarts): clear filter state.
        s1_ref[...] = jnp.zeros_like(s1_ref)
        s2_ref[...] = jnp.zeros_like(s2_ref)

    acc_ref[...] = jnp.zeros_like(acc_ref)

    b0 = coef_ref[0, :][None, :].astype(jnp.float32)  # (1, C)
    b1 = coef_ref[1, :][None, :].astype(jnp.float32)
    b2 = coef_ref[2, :][None, :].astype(jnp.float32)
    a1 = coef_ref[3, :][None, :].astype(jnp.float32)
    a2 = coef_ref[4, :][None, :].astype(jnp.float32)

    def step(i, carry):
        s1, s2, acc = carry
        x_t = x_ref[:, i][:, None].astype(jnp.float32)  # (BB, 1)
        y = b0 * x_t + s1
        s1 = b1 * x_t - a1 * y + s2
        s2 = b2 * x_t - a2 * y
        acc = acc + jnp.abs(y)
        return (s1, s2, acc)

    s1, s2, acc = jax.lax.fori_loop(
        0, frame_len, step, (s1_ref[...], s2_ref[...], acc_ref[...])
    )
    s1_ref[...] = s1
    s2_ref[...] = s2
    out_ref[:, 0, :] = (acc * (1.0 / frame_len)).astype(out_ref.dtype)


def fex_fused_pallas(
    x: jnp.ndarray,  # (B, T) audio at the internal (32 kHz) rate
    coeffs: jnp.ndarray,  # (5, C) stacked biquad coefficients
    *,
    frame_len: int,
    block_batch: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns rectified-average frames (B, T // frame_len, C)."""
    b, t = x.shape
    c = coeffs.shape[1]
    if t % frame_len:
        raise ValueError(f"T={t} not a multiple of frame_len={frame_len}")
    if b % block_batch:
        raise ValueError(f"B={b} not a multiple of block_batch={block_batch}")
    n_frames = t // frame_len

    kernel = functools.partial(_fex_fused_kernel, frame_len=frame_len)
    return pl.pallas_call(
        kernel,
        grid=(b // block_batch, n_frames),
        in_specs=[
            pl.BlockSpec((block_batch, frame_len), lambda ib, it: (ib, it)),
            pl.BlockSpec((5, c), lambda ib, it: (0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (block_batch, 1, c), lambda ib, it: (ib, it, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, n_frames, c), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_batch, c), jnp.float32),
            pltpu.VMEM((block_batch, c), jnp.float32),
            pltpu.VMEM((block_batch, c), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, coeffs)
