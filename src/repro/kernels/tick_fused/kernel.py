"""The fused serving-tick megakernel (pallas / interpret tiers).

One `pallas_call`, gridded over stream blocks, executes the ENTIRE
16 ms serving tick — frontend feature frame, stage-1 cascade wake
gate, every GRU layer, FC head, softmax, exponential smoothing, masked
state advance — as one device program per block. All per-stream state
(GRU hidden states, Δ reference memories, partial-sum accumulators,
frontend carry, detector latches, smoothed scores) is staged into VMEM
by the block specs and every intermediate (feature frame, gate
preactivations, logits, probabilities) lives and dies in registers/
VMEM inside the one kernel invocation: zero intermediate HBM
round-trips, which is the software inverse of the paper IC's
always-resident FEx→GRU→FC datapath.

The kernel body does not reimplement the tick: it re-runs the exact
`tick_reference` math (`repro.kernels.tick_fused.ref`) on one stream
block. Per-stream math has no cross-stream term anywhere in the tick
(the invariant the sharded==single suite already proves), so slicing
the stream axis into grid blocks is exact and the kernel is
bit-identical to the XLA tick by construction — the identity suites in
tests/test_tick_fused.py (+ the serve_sharded / gru_delta / cascade
extensions) pin it down to array equality.

Nested kernels: the classifier backends traced inside the body call
`intgemm` themselves, and a `pallas_call` cannot nest. The body traces
under `force_dispatch("reference")` (`repro.kernels.dispatch`), so
every nested kernel entry point resolves to its bit-identical pure-jnp
reference.

ΔGRU gather path: for the "delta"/"delta-int" backends the dense
``Δ @ W`` inside each cell is replaced (via the cells' ``matmul=``
hook) with a gather-only column update. The per-component fire mask is
already encoded in the thresholded Δ (zeros where not fired); the
block's union of firing columns is compacted with a cumsum prefix sum
into a dense index list and a `fori_loop` with a DYNAMIC trip count
issues one rank-1 ``Δ[:, i] · W[i]`` update per firing column. Work —
not just a counter — now scales with the fire count, so measured tick
latency drops toward the effective-MAC fraction (`srv.sparsity`)
instead of staying dense. Rows whose new state the tick's wake mask
will discard are zeroed out of the union first: an idle or gated
stream costs no columns. Bit-identity of the reordered accumulation
rests on the same fixed-point-grid argument as the θ=0 telescoping
guarantee (`repro.core.gru_delta`): every operand lives on a Q6.8 /
frac-15 grid whose in-range sums are exact in f32 and int32, so
summation order changes nothing; the integer domain additionally
applies `intgemm`'s final int24 saturation to the whole per-tick
contribution, exactly like `intgemm_ref`.
"""

from __future__ import annotations

import functools
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import tpu_compiler_params
from repro.kernels.dispatch import force_dispatch
from repro.kernels.intgemm.ref import INT24_MAX, INT24_MIN
from repro.kernels.tick_fused.ref import tick_reference

__all__ = [
    "gather_delta_matmul",
    "gather_delta_intgemm",
    "make_sparse_step",
    "tick_fused_pallas",
]


# --------------------------------------------------------------------------
# gather-only ΔGRU column update
# --------------------------------------------------------------------------

def _gather_contrib(d: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Σ over firing columns i of outer(d[:, i], w[i]), gather-compacted.

    d (B, I) is a thresholded delta block (zeros where not fired); w is
    (I, N). Columns that fired for NO row in the block are skipped
    entirely: the block-union fire mask is prefix-summed into a compact
    index list and a dynamically-bounded `fori_loop` touches only the
    ``n_fired`` entries — the loop lowers to a while_loop whose trip
    count is the fire count, so the work (and on CPU tiers the wall
    clock) scales with sparsity. Equal to ``d @ w`` wherever that
    product is exact on its fixed-point grid (the ΔGRU regime): columns
    with d ≡ 0 contribute exact zeros, and in-range grid sums are
    order-independent.
    """
    bsz, in_dim = d.shape
    col = jnp.any(d != 0, axis=0)  # (I,) block-union fire mask
    n_fired = jnp.sum(col.astype(jnp.int32))
    # compact[j] = index of the j-th firing column (prefix-sum scatter;
    # non-firing columns scatter to index I and are dropped)
    pos = jnp.cumsum(col.astype(jnp.int32)) - 1
    compact = (
        jnp.zeros((in_dim,), jnp.int32)
        .at[jnp.where(col, pos, in_dim)]
        .set(jnp.arange(in_dim, dtype=jnp.int32), mode="drop")
    )

    def body(j, acc):
        i = compact[j]
        d_col = jax.lax.dynamic_slice_in_dim(d, i, 1, axis=1)  # (B, 1)
        w_row = jax.lax.dynamic_slice_in_dim(w, i, 1, axis=0)  # (1, N)
        return acc + d_col * w_row

    acc0 = jnp.zeros((bsz, w.shape[1]), jnp.result_type(d, w))
    return jax.lax.fori_loop(0, n_fired, body, acc0)


def _mask_rows(d: jnp.ndarray, row_mask: Optional[jnp.ndarray]):
    """Zero the delta rows of streams whose new state the tick's wake
    mask discards anyway (`masked_select` keeps the old value), so an
    idle or gated stream contributes no columns to the block union.
    Changes only discarded values — never an output bit."""
    if row_mask is None:
        return d
    return jnp.where(row_mask[:, None], d, jnp.zeros((), d.dtype))


def gather_delta_matmul(
    d: jnp.ndarray, w: jnp.ndarray, row_mask: Optional[jnp.ndarray] = None
) -> jnp.ndarray:
    """Float-domain gather Δ·W: drop-in for ``d @ w`` in
    `gru_delta.delta_gru_cell` (bit-identical on the QAT grids)."""
    return _gather_contrib(_mask_rows(d, row_mask), w)


def gather_delta_intgemm(
    d: jnp.ndarray, w: jnp.ndarray, row_mask: Optional[jnp.ndarray] = None
) -> jnp.ndarray:
    """Code-domain gather Δ·W: drop-in for ``intgemm(d, w)`` in
    `gru_delta.int_delta_gru_cell`.

    The int24 saturation is applied to the WHOLE per-tick contribution
    after the gather sum, exactly where `intgemm_ref` clips its full
    matmul result — int32 accumulation of the partial products is exact
    (products < 2^21, ≤ 96 terms), so the gather sum equals the dense
    matmul on the nose and the clip sees the identical value.
    """
    contrib = _gather_contrib(
        _mask_rows(d, row_mask).astype(jnp.int32), w.astype(jnp.int32)
    )
    return jnp.clip(contrib, INT24_MIN, INT24_MAX)


def make_sparse_step(pipeline):
    """A `tick_reference` ``step_fn`` with gather-compacted Δ·W updates
    for the delta backends, or None (dense step) for the others.

    Reuses the very `gru_delta` classifier step the XLA tick runs —
    thresholds, counters, gate math and all — overriding ONLY the
    ``matmul=`` hook, so the gather path can never drift from the
    bit-identity target.
    """
    backend = pipeline.classifier
    name = getattr(backend, "name", None)
    if name not in ("delta", "delta-int"):
        return None
    # lazy: gru_delta/gru_int import repro.kernels.intgemm, which runs
    # the kernels package init that imports this module last
    from repro.core import gru_delta, gru_int

    cfg = pipeline.config.gru
    thetas = backend.delta.code_thresholds(cfg.num_layers)

    if name == "delta":
        def step(params, states, fv, wake):
            return gru_delta.delta_classifier_step(
                params, states, fv, cfg, thetas,
                matmul=functools.partial(gather_delta_matmul, row_mask=wake),
            )
        return step

    def step(params, states, fv, wake):
        states, codes = gru_delta.int_delta_classifier_step(
            params, states, gru_int.quantize_acts(fv), cfg, thetas,
            matmul=functools.partial(gather_delta_intgemm, row_mask=wake),
        )
        return states, gru_int.dequantize_acts(codes)
    return step


# --------------------------------------------------------------------------
# pytree <-> kernel-operand encoding
# --------------------------------------------------------------------------
#
# pallas operands want >= 2-D arrays of non-bool dtype; the tick's
# pytrees carry (N,) bool masks, () scalars and (C,) calibration
# vectors. Each leaf is encoded at the wrapper boundary (bool -> int32,
# (N,) -> (N, 1) stream leaves, () -> (1, 1) / (C,) -> (1, C)
# replicated leaves) and decoded back inside the kernel body — both
# directions are exact, so the encoding is invisible to the math.

def _enc_stream(x):
    x = jnp.asarray(x)
    meta = (x.ndim, x.dtype)
    if x.dtype == jnp.bool_:
        x = x.astype(jnp.int32)
    if x.ndim == 1:
        x = x[:, None]
    return x, meta


def _enc_rep(x):
    x = jnp.asarray(x)
    meta = (x.ndim, x.dtype)
    if x.dtype == jnp.bool_:
        x = x.astype(jnp.int32)
    if x.ndim == 0:
        x = x.reshape(1, 1)
    elif x.ndim == 1:
        x = x.reshape(1, -1)
    return x, meta


def _dec(x, meta):
    ndim, dtype = meta
    if ndim == 0:
        x = x.reshape(())
    elif ndim == 1:
        x = x.reshape(-1)
    if dtype == jnp.bool_:
        x = x.astype(jnp.bool_)
    return x


def _enc_out_val(x):
    if x.dtype == jnp.bool_:
        x = x.astype(jnp.int32)
    if x.ndim == 1:
        x = x[:, None]
    return x


def _stream_spec(shape2d, block):
    nd = len(shape2d)
    return pl.BlockSpec(
        (block,) + tuple(shape2d[1:]),
        lambda ib, _nd=nd: (ib,) + (0,) * (_nd - 1),
    )


def _rep_spec(shape2d):
    nd = len(shape2d)
    return pl.BlockSpec(
        tuple(shape2d), lambda ib, _nd=nd: (0,) * _nd
    )


# --------------------------------------------------------------------------
# the megakernel
# --------------------------------------------------------------------------

def tick_fused_pallas(
    pipeline,
    raw_audio: bool,
    params,
    state: Tuple[Any, Any, jnp.ndarray, Any],
    inp: jnp.ndarray,
    mask: jnp.ndarray,
    frontend_state,
    smoothing,
    *,
    block_streams: int = 8,
    interpret: bool = False,
):
    """One fused serving tick as a single `pallas_call` over stream blocks.

    Same contract as `tick_reference` (state is the ``(gru, carry,
    scores, det)`` 4-tuple): returns ``(new_state, scores, top)``,
    bit-identical for every classifier backend. The stream axis is
    zero-padded to a whole number of ``block_streams`` blocks (padded
    slots carry mask=False, so they are idle streams whose state
    provably does not advance) and sliced back afterwards.
    """
    state = (tuple(state[0]), state[1], state[2], state[3])
    n = mask.shape[0]
    sparse_step = make_sparse_step(pipeline)

    state_leaves, state_def = jax.tree_util.tree_flatten(state)
    s_leaves, s_def = jax.tree_util.tree_flatten((state, inp, mask))
    r_leaves, r_def = jax.tree_util.tree_flatten(
        (params, frontend_state, jnp.asarray(smoothing, jnp.float32))
    )
    enc_s = [_enc_stream(x) for x in s_leaves]
    enc_r = [_enc_rep(x) for x in r_leaves]
    s_arrs = [a for a, _ in enc_s]
    s_meta = [m for _, m in enc_s]
    r_arrs = [a for a, _ in enc_r]
    r_meta = [m for _, m in enc_r]

    def block_fn(s_vals, r_vals):
        (st, x_in, m_in) = jax.tree_util.tree_unflatten(s_def, s_vals)
        (pp, fs, sm) = jax.tree_util.tree_unflatten(r_def, r_vals)
        with force_dispatch("reference"):
            new_state, scores, top = tick_reference(
                pipeline, raw_audio, pp, st, x_in, m_in, fs, sm,
                step_fn=sparse_step,
            )
        return jax.tree_util.tree_leaves(new_state) + [scores, top]

    # Trace the tick once on one block. This both derives the output
    # geometry and LIFTS closed-over device arrays (filterbank
    # coefficients, LUTs — anything living on the pipeline object
    # rather than in params/frontend_state) out as jaxpr consts: a
    # pallas kernel body may not capture array constants, so they ride
    # along as extra replicated operands and the body replays the
    # jaxpr.
    s_structs = [
        jax.ShapeDtypeStruct((block_streams,) + tuple(x.shape[1:]), x.dtype)
        for x in s_leaves
    ]
    r_structs = [
        jax.ShapeDtypeStruct(tuple(x.shape), x.dtype) for x in r_leaves
    ]
    block_jaxpr = jax.make_jaxpr(block_fn)(s_structs, r_structs)
    consts = [jnp.asarray(c) for c in block_jaxpr.consts]
    out_structs = [
        jax.ShapeDtypeStruct(a.shape, a.dtype)
        for a in block_jaxpr.out_avals
    ]
    out_meta = [(len(o.shape), o.dtype) for o in out_structs]

    pad = (-n) % block_streams
    if pad:
        s_arrs = [
            jnp.concatenate(
                [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)], axis=0
            )
            for a in s_arrs
        ]
    n_pad = n + pad

    out_shapes_2d = []
    for o in out_structs:
        shape = (n_pad,) + tuple(o.shape[1:])
        if len(o.shape) == 1:
            shape = (n_pad, 1)
        dtype = jnp.int32 if o.dtype == jnp.bool_ else o.dtype
        out_shapes_2d.append(jax.ShapeDtypeStruct(shape, dtype))

    enc_c = [_enc_rep(c) for c in consts]
    c_arrs = [a for a, _ in enc_c]
    c_meta = [m for _, m in enc_c]
    n_s, n_r, n_c = len(s_arrs), len(r_arrs), len(c_arrs)

    def kernel(*refs):
        in_refs, out_refs = refs[: n_s + n_r + n_c], refs[n_s + n_r + n_c:]
        s_vals = [
            _dec(r[...], m) for r, m in zip(in_refs[:n_s], s_meta)
        ]
        r_vals = [
            _dec(r[...], m)
            for r, m in zip(in_refs[n_s:n_s + n_r], r_meta)
        ]
        c_vals = [
            _dec(r[...], m)
            for r, m in zip(in_refs[n_s + n_r:], c_meta)
        ]
        outs = jax.core.eval_jaxpr(
            block_jaxpr.jaxpr, c_vals, *s_vals, *r_vals
        )
        for ref, val in zip(out_refs, outs):
            ref[...] = _enc_out_val(val)

    outs = pl.pallas_call(
        kernel,
        grid=(n_pad // block_streams,),
        in_specs=(
            [_stream_spec(a.shape, block_streams) for a in s_arrs]
            + [_rep_spec(a.shape) for a in r_arrs]
            + [_rep_spec(a.shape) for a in c_arrs]
        ),
        out_specs=[
            _stream_spec(o.shape, block_streams) for o in out_shapes_2d
        ],
        out_shape=out_shapes_2d,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(*s_arrs, *r_arrs, *c_arrs)

    out_vals = [
        _dec(o[:n], m) for o, m in zip(outs, out_meta)
    ]
    n_state = len(state_leaves)
    new_state = jax.tree_util.tree_unflatten(state_def, out_vals[:n_state])
    scores, top = out_vals[n_state], out_vals[n_state + 1]
    return new_state, scores, top
