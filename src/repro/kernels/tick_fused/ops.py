"""Public entry point for the fused serving-tick megakernel.

`tick_fused` picks one of three equivalent implementations per call,
through the shared `repro.kernels.dispatch` machinery (same convention
as `tdc` / `intgemm`):

  * ``pallas``    — the compiled Mosaic megakernel (TPU): the whole
                    tick as ONE `pallas_call` over stream blocks;
  * ``interpret`` — the same kernel body under the Pallas interpreter
                    (validates the megakernel — block slicing, operand
                    encoding, the ΔGRU gather path — on CPU CI);
  * ``reference`` — `tick_reference` directly: the plain fused-XLA
                    tick, exactly the pre-kernel server program.

Sharding: the stream-block grid axis maps 1:1 onto shard-local slabs.
GSPMD cannot partition a `pallas_call`, so with a ``mesh=`` the kernel
call is wrapped in a `shard_map` over the ``("stream",)`` axis — each
device runs ONE kernel on its own slab (slots are computationally
independent; there is no collective anywhere in the tick), so the SPMD
program per device is still a single kernel.

The expected call site is inside the serving layer's outer jit
(`repro.serving.serve_loop._fused_tick` with ``tick_impl=
"fused-pallas"|"fused-interpret"``), where the kernel call inlines
into the tick's single jaxpr; top-level calls (the identity tests)
simply trace eagerly.
"""

from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax.numpy as jnp

from repro.kernels.dispatch import resolve_dispatch
from repro.kernels.tick_fused.kernel import tick_fused_pallas
from repro.kernels.tick_fused.ref import tick_reference


def resolve_tick_dispatch(
    dispatch: str = "auto",
    interpret: Optional[bool] = None,
) -> str:
    """Resolve 'auto' to a concrete tier for this backend.

    Off-TPU the interpreter re-traces the whole tick per stream block
    (correct but slow), so 'auto' picks the plain fused-XLA reference —
    the serving layer's ``tick_impl="auto"`` maps to the same choice.
    """
    return resolve_dispatch(dispatch, interpret, off_tpu="reference")


def tick_fused(
    pipeline,
    raw_audio: bool,
    params,
    state: Tuple[Any, Any, jnp.ndarray, Any],
    inp: jnp.ndarray,
    mask: jnp.ndarray,
    frontend_state,
    smoothing,
    *,
    dispatch: str = "auto",
    interpret: Optional[bool] = None,
    block_streams: Optional[int] = None,
    mesh=None,
) -> Tuple[Tuple[Any, Any, jnp.ndarray, Any], jnp.ndarray, jnp.ndarray]:
    """One fused serving tick; state is the ``(gru, carry, scores, det)``
    tuple of `tick_reference`. Returns ``(new_state, scores, top)``,
    bit-identical across all three tiers for every classifier backend.
    """
    state = (tuple(state[0]), state[1], state[2], state[3])
    path = resolve_tick_dispatch(dispatch, interpret)
    if path == "reference":
        return tick_reference(
            pipeline, raw_audio, params, state, inp, mask,
            frontend_state, smoothing,
        )
    run_interpret = path == "interpret"
    if block_streams is None:
        block_streams = 8 if run_interpret else 128
    call = functools.partial(
        tick_fused_pallas, pipeline, raw_audio,
        block_streams=block_streams, interpret=run_interpret,
    )
    if mesh is None:
        return call(params, state, inp, mask, frontend_state, smoothing)
    # stream axis sharded over the mesh: GSPMD cannot partition a
    # pallas_call, so run one kernel per shard-local slab
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import STREAM_AXIS

    slab = P(STREAM_AXIS)
    rep = P()
    fn = shard_map(
        lambda p, st, x, m, fs, sm: call(p, st, x, m, fs, sm),
        mesh=mesh,
        in_specs=(rep, slab, slab, slab, rep, rep),
        out_specs=(slab, slab, slab),
        check_rep=False,
    )
    return fn(
        params, state, inp, mask, frontend_state,
        jnp.asarray(smoothing, jnp.float32),
    )
