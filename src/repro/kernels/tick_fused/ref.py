"""Reference ("xla") tier of the fused serving tick.

`tick_reference` is the single definition of the 16 ms serving-tick
math — frontend feature frame, stage-1 cascade wake gate, all GRU
layers through the pipeline's classifier backend, FC head, softmax,
exponential score smoothing, masked state advance. It used to live
inline in `repro.serving.serve_loop._fused_tick`; it was moved here
(pure code motion) so every dispatch tier of the tick kernel evaluates
the SAME function:

  * the "xla" / "reference" tier calls it directly (one fused XLA
    program, exactly the pre-kernel server);
  * the "pallas" / "interpret" tiers re-run it INSIDE the megakernel
    body on one stream block at a time (`repro.kernels.tick_fused.
    kernel`) — per-stream math has no cross-stream term anywhere, so
    block slicing is exact and the kernel inherits the tick's whole
    bit-identity story.

The state crossing this boundary is a plain 4-tuple ``(gru, carry,
scores, det)`` rather than the serving layer's `ServerState`
dataclass, so the kernel layer stays importable without the serving
module (no import cycle: serving imports kernels, never the reverse).

``step_fn`` overrides the classifier step (default:
``pipeline.streaming_logits_apply``); the megakernel passes the
gather-compacted ΔGRU step for the delta backends. It receives the
resolved per-stream wake mask as a fourth argument so a sparse step
can suppress the Δ·W work of streams whose new state is about to be
discarded by `masked_select` anyway — legal because ONLY values the
mask keeps reach the returned state, so any per-row value may differ
on masked-out rows without changing a single output bit.
"""

from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.frontend import masked_select
from repro.serving import cascade as cascade_lib

# (gru states tuple, frontend carry, smoothed scores, detector state)
TickState = Tuple[Any, Any, jnp.ndarray, Any]


def tick_reference(
    pipeline,
    raw_audio: bool,
    params,
    state: TickState,
    inp: jnp.ndarray,
    mask: jnp.ndarray,
    frontend_state,
    smoothing,
    step_fn: Optional[Callable] = None,
) -> Tuple[TickState, jnp.ndarray, jnp.ndarray]:
    """One fully fused serving tick on explicit state leaves.

    inp is a raw-audio slab (N, chunk_samples) when ``raw_audio`` else
    an FV_Norm slab (N, C); mask (N,) bool marks slots that submitted
    this tick. Frontend carry, GRU states, and smoothed scores advance
    ONLY under the mask — an idle slot's slice of every buffer is
    returned bit-identical (jnp.where keeps the old value), so a
    stream skipping a tick resumes from its own contiguous state.

    With a cascade (`pipeline.config.cascade`, a static branch) the
    stage-1 detector scores the feature frame and its gate narrows the
    mask the classifier/scores advance under: a submitted-but-gated
    stream's GRU state holds frozen (and its posterior optionally
    decays toward silence), while the frontend carry and the detector
    state still advance under the plain submitted mask — the stage-1
    gate is always-on and consumes every frame, only the classifier
    sleeps. An always-open gate makes ``wake == mask`` elementwise, so
    the tick is bit-identical to the non-cascaded program.

    Returns ``((gru, carry, scores, det), scores, top)``.
    """
    gru_in, carry_in, scores_in, det_in = state
    if raw_audio:
        new_carry, fv = pipeline.streaming_features_apply(
            carry_in, inp, frontend_state
        )
        carry = masked_select(mask, new_carry, carry_in)
    else:
        carry = carry_in
        fv = inp
    casc = pipeline.config.cascade
    if casc is not None:
        score = cascade_lib.detector_scores(fv, casc)
        new_det, gate = cascade_lib.gate_step(det_in, score, casc)
        det = masked_select(mask, new_det, det_in)
        wake = jnp.logical_and(mask, gate)
    else:
        det = det_in
        wake = mask
    if step_fn is None:
        new_gru, logits = pipeline.streaming_logits_apply(
            params, list(gru_in), fv
        )
    else:
        new_gru, logits = step_fn(params, list(gru_in), fv, wake)
    gru = tuple(masked_select(wake, tuple(new_gru), tuple(gru_in)))
    probs = jax.nn.softmax(logits, axis=-1)
    smoothed = smoothing * scores_in + (1.0 - smoothing) * probs
    scores = masked_select(wake, smoothed, scores_in)
    if casc is not None and casc.score_decay != 1.0:
        # submitted but gated: decay the stale posterior toward zero
        # ("silence") while the classifier sleeps
        gated = jnp.logical_and(mask, jnp.logical_not(wake))
        scores = masked_select(gated, casc.score_decay * scores_in, scores)
    top = jnp.argmax(scores, axis=-1)
    return (gru, carry, scores, det), scores, top
