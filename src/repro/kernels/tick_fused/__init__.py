from repro.kernels.tick_fused.kernel import (
    gather_delta_intgemm,
    gather_delta_matmul,
    make_sparse_step,
    tick_fused_pallas,
)
from repro.kernels.tick_fused.ops import resolve_tick_dispatch, tick_fused
from repro.kernels.tick_fused.ref import tick_reference

__all__ = [
    "gather_delta_intgemm",
    "gather_delta_matmul",
    "make_sparse_step",
    "resolve_tick_dispatch",
    "tick_fused",
    "tick_fused_pallas",
    "tick_reference",
]
