from repro.data.gscd import CLASSES, KEYWORDS, GSCDSynthConfig, make_dataset

__all__ = ["CLASSES", "KEYWORDS", "GSCDSynthConfig", "make_dataset"]
