"""Synthetic GSCD-v2-like dataset (formant synthesis).

The real Google Speech Commands Dataset is not available offline, so we
synthesize a 12-class corpus with the same task structure:

  classes = ["silence", "unknown"] + 10 target keywords

Each keyword is a formant-trajectory template (sequence of voiced /
unvoiced segments with F1-F3 resonances); samples draw per-utterance
pitch, tempo, formant jitter, amplitude, and background noise, so classes
overlap realistically ("go"/"no" share vowels, "unknown" reuses held-out
templates the classifier never sees labeled).

All accuracy numbers in EXPERIMENTS.md are therefore *relative*
reproductions of the paper's claims (ablation gaps, SNR robustness, hw/sw
gap) — documented in DESIGN.md §3.

Synthesis is host-side numpy/scipy (it plays the role of the laptop +
sound card in Fig. 16); the device-side model consumes raw waveforms.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
from scipy import signal as sps

__all__ = [
    "CLASSES",
    "KEYWORDS",
    "GSCDSynthConfig",
    "synth_keyword",
    "make_dataset",
    "batch_iterator",
]

KEYWORDS = ["yes", "no", "up", "down", "left", "right", "on", "off", "stop", "go"]
CLASSES = ["silence", "unknown"] + KEYWORDS

# Formant templates: list of segments
#   (duration_weight, voiced, (F1_start, F1_end), (F2_start, F2_end),
#    (F3_start, F3_end), amplitude)
# Loosely modeled on American English phone formants; exact phonetics is
# irrelevant — distinct, overlapping spectro-temporal classes are the goal.
_Seg = Tuple[float, bool, Tuple[float, float], Tuple[float, float], Tuple[float, float], float]

_TEMPLATES: Dict[str, List[_Seg]] = {
    "yes": [
        (0.35, True, (280, 500), (2100, 1800), (2900, 2600), 0.9),  # /jE/
        (0.30, True, (550, 550), (1800, 1800), (2500, 2500), 1.0),  # /E/
        (0.35, False, (4500, 5000), (6000, 6500), (7500, 7500), 0.55),  # /s/
    ],
    "no": [
        (0.40, True, (400, 450), (1300, 900), (2500, 2300), 0.9),  # /n/->/o/
        (0.60, True, (450, 380), (900, 700), (2300, 2200), 1.0),  # /oU/
    ],
    "up": [
        (0.55, True, (640, 640), (1190, 1190), (2400, 2400), 1.0),  # /V/
        (0.20, False, (100, 100), (400, 400), (900, 900), 0.0),  # closure
        (0.25, False, (800, 1200), (1800, 2200), (3000, 3400), 0.45),  # /p/ burst
    ],
    "down": [
        (0.30, False, (300, 400), (2800, 2400), (3600, 3400), 0.5),  # /d/
        (0.40, True, (750, 400), (1300, 800), (2500, 2300), 1.0),  # /aU/
        (0.30, True, (400, 350), (1100, 1200), (2400, 2400), 0.7),  # /n/
    ],
    "left": [
        (0.30, True, (380, 530), (2200, 1850), (2800, 2500), 0.85),  # /lE/
        (0.25, True, (530, 530), (1850, 1850), (2500, 2500), 1.0),
        (0.20, False, (4000, 4500), (5500, 6000), (7000, 7000), 0.4),  # /f/
        (0.25, False, (500, 900), (1800, 2000), (3000, 3200), 0.45),  # /t/
    ],
    "right": [
        (0.35, True, (420, 750), (1300, 1100), (1600, 2300), 0.9),  # /raI/
        (0.35, True, (750, 450), (1100, 1900), (2300, 2600), 1.0),  # /aI/
        (0.30, False, (600, 1000), (1900, 2100), (3100, 3300), 0.45),  # /t/
    ],
    "on": [
        (0.55, True, (700, 600), (1100, 1000), (2500, 2400), 1.0),  # /A/
        (0.45, True, (400, 350), (1300, 1250), (2400, 2400), 0.75),  # /n/
    ],
    "off": [
        (0.50, True, (650, 600), (950, 900), (2500, 2400), 1.0),  # /O/
        (0.50, False, (4200, 4600), (5800, 6200), (7200, 7200), 0.5),  # /f/
    ],
    "stop": [
        (0.25, False, (4500, 4800), (6200, 6400), (7500, 7500), 0.5),  # /s/
        (0.15, False, (600, 900), (1800, 2000), (3000, 3100), 0.4),  # /t/
        (0.40, True, (650, 650), (1000, 1000), (2450, 2450), 1.0),  # /A/
        (0.20, False, (700, 1100), (1700, 2100), (2900, 3300), 0.4),  # /p/
    ],
    "go": [
        (0.30, False, (250, 400), (1800, 1400), (2600, 2400), 0.5),  # /g/
        (0.70, True, (480, 380), (1000, 720), (2350, 2250), 1.0),  # /oU/
    ],
}

# Held-out "unknown" words (Section III-F: 25 non-target words).
_UNKNOWN_TEMPLATES: List[List[_Seg]] = []


def _make_unknown_templates(n: int = 25, seed: int = 1234) -> List[List[_Seg]]:
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        n_seg = int(rng.integers(2, 5))
        segs: List[_Seg] = []
        for _ in range(n_seg):
            voiced = bool(rng.random() < 0.65)
            if voiced:
                f1 = float(rng.uniform(280, 800))
                f2 = float(rng.uniform(700, 2300))
                f3 = float(rng.uniform(2200, 3000))
                amp = float(rng.uniform(0.7, 1.0))
            else:
                f1 = float(rng.uniform(800, 4800))
                f2 = float(rng.uniform(1800, 6400))
                f3 = float(rng.uniform(3000, 7600))
                amp = float(rng.uniform(0.35, 0.6))
            drift = rng.uniform(0.8, 1.25)
            segs.append(
                (
                    float(rng.uniform(0.5, 1.5)),
                    voiced,
                    (f1, f1 * drift),
                    (f2, f2 * drift),
                    (f3, f3 * drift),
                    amp,
                )
            )
        out.append(segs)
    return out


@dataclasses.dataclass(frozen=True)
class GSCDSynthConfig:
    fs: int = 16000
    duration_s: float = 1.0
    # Nominal waveform amplitude: the paper drives ~250 mVpp into the VTC;
    # we use normalized units where 1.0 = VTC full scale, so speech peaks
    # sit near 0.25 (=0.125 amplitude) like the measurement setup.
    amplitude: float = 0.125
    background_noise: float = 0.004  # always-present noise floor
    silence_noise: float = 0.010  # "silence" class = background tracks
    pitch_lo: float = 95.0
    pitch_hi: float = 220.0
    tempo_jitter: float = 0.18
    formant_jitter: float = 0.06
    amp_jitter_db: float = 6.0
    n_unknown_templates: int = 25

    @property
    def n_samples(self) -> int:
        return int(self.fs * self.duration_s)


def _resonator_sos(f0: float, fs: float, bw: float = 120.0) -> np.ndarray:
    """2nd-order resonator (formant) as an sos section."""
    f0 = float(np.clip(f0, 60.0, fs / 2 * 0.95))
    r = np.exp(-np.pi * bw / fs)
    theta = 2 * np.pi * f0 / fs
    # poles at r e^{+-j theta}; unity gain at resonance (approx)
    b = np.array([1.0 - r, 0.0, 0.0])
    a = np.array([1.0, -2 * r * np.cos(theta), r * r])
    return np.concatenate([b, a])[None, :]


def _synth_segment(
    rng: np.random.Generator,
    cfg: GSCDSynthConfig,
    n: int,
    voiced: bool,
    f1: Tuple[float, float],
    f2: Tuple[float, float],
    f3: Tuple[float, float],
    amp: float,
    pitch: float,
) -> np.ndarray:
    if n <= 0:
        return np.zeros(0, np.float32)
    fs = cfg.fs
    if voiced:
        # glottal impulse train with slight jitter
        period = max(int(fs / pitch), 8)
        exc = np.zeros(n)
        idx = np.arange(0, n, period)
        idx = idx + rng.integers(-2, 3, size=idx.shape)
        idx = np.clip(idx, 0, n - 1)
        exc[idx] = 1.0
        exc = sps.lfilter([1.0], [1.0, -0.96], exc)  # glottal rolloff
    else:
        exc = rng.standard_normal(n) * 0.35
    # Two halves with interpolated formants (cheap trajectory model).
    halves = []
    for frac in (0.25, 0.75):
        h = n // 2 if frac < 0.5 else n - n // 2
        if h <= 0:
            continue
        seg_exc = exc[: h] if frac < 0.5 else exc[n - h :]
        y = seg_exc
        for (lo, hi), bw in ((f1, 110.0), (f2, 160.0), (f3, 220.0)):
            fc = lo + (hi - lo) * frac
            fc *= 1.0 + rng.normal(0, cfg.formant_jitter)
            y = sps.sosfilt(_resonator_sos(fc, fs, bw), y)
        halves.append(y)
    y = np.concatenate(halves)
    # amplitude envelope (attack/decay)
    env = np.ones(n)
    a = max(int(0.012 * fs), 1)
    env[:a] = np.linspace(0, 1, a)
    env[-a:] = np.linspace(1, 0, a)
    return (amp * env * y).astype(np.float32)


def synth_keyword(
    rng: np.random.Generator,
    template: Sequence[_Seg],
    cfg: GSCDSynthConfig,
) -> np.ndarray:
    """One utterance from a template, with speaker/tempo variability."""
    n_total = cfg.n_samples
    speech_frac = rng.uniform(0.55, 0.8)
    n_speech = int(n_total * speech_frac)
    pitch = rng.uniform(cfg.pitch_lo, cfg.pitch_hi)
    weights = np.array([s[0] for s in template], np.float64)
    weights = weights * rng.uniform(
        1 - cfg.tempo_jitter, 1 + cfg.tempo_jitter, size=weights.shape
    )
    weights /= weights.sum()
    lens = np.floor(weights * n_speech).astype(int)
    lens[-1] = n_speech - lens[:-1].sum()
    parts = [
        _synth_segment(rng, cfg, n, v, f1, f2, f3, a, pitch)
        for (_, v, f1, f2, f3, a), n in zip(template, lens)
    ]
    speech = np.concatenate(parts) if parts else np.zeros(0, np.float32)
    # random placement within the 1 s window
    start = int(rng.uniform(0.0, max(n_total - n_speech, 1)))
    out = np.zeros(n_total, np.float32)
    out[start : start + len(speech)] = speech
    # normalize to nominal amplitude with per-utterance gain jitter
    peak = np.abs(out).max() + 1e-9
    gain_db = rng.uniform(-cfg.amp_jitter_db, cfg.amp_jitter_db)
    out = out / peak * cfg.amplitude * (10.0 ** (gain_db / 20.0))
    out += rng.standard_normal(n_total).astype(np.float32) * cfg.background_noise
    return out.astype(np.float32)


def _synth_silence(rng: np.random.Generator, cfg: GSCDSynthConfig) -> np.ndarray:
    n = cfg.n_samples
    kind = rng.integers(0, 3)
    noise = rng.standard_normal(n)
    if kind == 1:  # pink-ish
        noise = sps.lfilter([0.05], [1.0, -0.95], noise)
    elif kind == 2:  # hum + noise
        t = np.arange(n) / cfg.fs
        noise = 0.6 * noise + 2.0 * np.sin(2 * np.pi * 120 * t + rng.uniform(0, 6.3))
    noise = noise / (np.abs(noise).max() + 1e-9)
    level = cfg.silence_noise * 10.0 ** (rng.uniform(-6, 6) / 20.0)
    return (level * noise).astype(np.float32)


def make_dataset(
    n_per_class: int,
    cfg: Optional[GSCDSynthConfig] = None,
    seed: int = 0,
    unknown_split: str = "train",
) -> Dict[str, np.ndarray]:
    """Generate a balanced synthetic dataset.

    unknown_split: "train" uses the first half of the unknown templates,
    "test" the second half — so the Unknown class at test time contains
    words never seen in training, like the real GSCD protocol (and like the
    paper, Unknown stays the hardest class).
    """
    cfg = cfg or GSCDSynthConfig()
    global _UNKNOWN_TEMPLATES
    if not _UNKNOWN_TEMPLATES:
        _UNKNOWN_TEMPLATES = _make_unknown_templates(cfg.n_unknown_templates)
    rng = np.random.default_rng(seed)
    xs, ys = [], []
    half = len(_UNKNOWN_TEMPLATES) // 2
    unk_pool = (
        _UNKNOWN_TEMPLATES[:half]
        if unknown_split == "train"
        else _UNKNOWN_TEMPLATES[half:]
    )
    for ci, cls in enumerate(CLASSES):
        for _ in range(n_per_class):
            if cls == "silence":
                x = _synth_silence(rng, cfg)
            elif cls == "unknown":
                tpl = unk_pool[rng.integers(0, len(unk_pool))]
                x = synth_keyword(rng, tpl, cfg)
            else:
                x = synth_keyword(rng, _TEMPLATES[cls], cfg)
            xs.append(x)
            ys.append(ci)
    order = rng.permutation(len(xs))
    return {
        "audio": np.stack(xs)[order],
        "label": np.asarray(ys, np.int32)[order],
    }


def batch_iterator(
    data: Dict[str, np.ndarray],
    batch_size: int,
    seed: int = 0,
    drop_remainder: bool = True,
):
    """Shuffled epoch iterator over host arrays."""
    n = len(data["label"])
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    end = n - n % batch_size if drop_remainder else n
    for i in range(0, end, batch_size):
        sl = idx[i : i + batch_size]
        yield {k: v[sl] for k, v in data.items()}
