"""Measurement stimuli for the benchmark suite (the function generator +
sound card of Fig. 16)."""

from __future__ import annotations

import numpy as np

__all__ = ["sine", "multitone", "white_noise", "silence"]


def sine(freq_hz: float, duration_s: float, fs: float = 16000.0,
         amplitude: float = 0.125, phase: float = 0.0) -> np.ndarray:
    t = np.arange(int(duration_s * fs)) / fs
    return (amplitude * np.sin(2 * np.pi * freq_hz * t + phase)).astype(np.float32)


def multitone(freqs_hz, duration_s: float, fs: float = 16000.0,
              amplitude: float = 0.125) -> np.ndarray:
    t = np.arange(int(duration_s * fs)) / fs
    out = np.zeros_like(t)
    for i, f in enumerate(freqs_hz):
        out += np.sin(2 * np.pi * f * t + 0.7 * i)
    out /= max(len(list(freqs_hz)), 1)
    return (amplitude * out).astype(np.float32)


def white_noise(duration_s: float, fs: float = 16000.0, rms: float = 0.02,
                seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return (rms * rng.standard_normal(int(duration_s * fs))).astype(np.float32)


def silence(duration_s: float, fs: float = 16000.0) -> np.ndarray:
    return np.zeros(int(duration_s * fs), np.float32)
