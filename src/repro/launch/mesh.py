"""Production mesh definitions.

Importing this module never touches jax device state; meshes are built
inside functions only (the dry-run sets XLA_FLAGS *before* any jax
import — see launch/dryrun.py).
"""

from __future__ import annotations

from typing import Optional, Tuple

__all__ = ["make_production_mesh", "make_rules", "mesh_device_count"]


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: 16x16 = 256 chips per pod; 2 pods = 512 chips."""
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_device_count(*, multi_pod: bool = False) -> int:
    return 512 if multi_pod else 256


def make_rules(mesh, *, fsdp: bool = True, fsdp_over_pod: bool = False):
    """ShardingRules for a production mesh (single- or multi-pod)."""
    from repro.distributed.sharding import ShardingRules

    multi_pod = "pod" in mesh.axis_names
    dp_axes = ("pod", "data") if multi_pod else ("data",)
    fsdp_axes = dp_axes if (multi_pod and fsdp_over_pod) else ("data",)
    return ShardingRules(
        mesh=mesh,
        dp_axes=dp_axes,
        model_axis="model",
        fsdp_axes=fsdp_axes,
        fsdp=fsdp,
    )
