import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and emit the roofline table.

The two lines above MUST stay the first statements in this module: jax
locks the device count at first init, and the dry-run needs 512
placeholder host devices for the 2x16x16 production mesh. (Tests and
benches import everything EXCEPT this module and see 1 device.)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod-check

Per cell it prints memory_analysis() + cost_analysis() (the spec's
required proof-of-fit) and writes a CellReport JSON with the three
roofline terms (launch/roofline.py). The multi-pod pass compiles the
same cell on the (2,16,16) mesh to prove the "pod" axis shards; roofline
terms are reported on the single-pod 16x16 mesh.
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax

from repro.configs import SHAPES, get_config, list_archs
from repro.launch.mesh import make_production_mesh, make_rules
from repro.launch.roofline import make_report
from repro.serving.serve_loop import lower_decode_step, lower_prefill
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainConfig, lower_train_step

import jax.numpy as jnp


def train_batch_shape(arch_cfg, shape_spec):
    b, s = shape_spec.global_batch, shape_spec.seq_len
    if arch_cfg.frontend == "embedding":
        return {
            "embeddings": jax.ShapeDtypeStruct(
                (b, s, arch_cfg.d_model), arch_cfg.activation_dtype
            ),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }


def arch_train_config(arch_cfg) -> TrainConfig:
    """The 1T MoE needs int8 optimizer moments to fit (DESIGN.md §6)."""
    state_dtype = "int8" if arch_cfg.param_count() > 100e9 else "float32"
    return TrainConfig(optimizer=AdamWConfig(state_dtype=state_dtype))


def run_cell(arch: str, shape: str, multi_pod: bool = False, note: str = "",
             overrides: dict | None = None):
    """Lower+compile one cell; returns (CellReport, compiled).

    Long-context prefill defaults to flash-style KV chunking (2048): the
    vanilla (S, S) score materialization transiently needs >16 GB/device
    at 32k and would not fit HBM — the unchunked variant is measured once
    in EXPERIMENTS.md §Perf for comparison. `overrides` replaces arbitrary
    ArchConfig fields (the §Perf iteration hook).
    """
    import dataclasses as _dc

    arch_cfg = get_config(arch)
    shape_spec = SHAPES[shape]
    if shape_spec.kind == "prefill" and shape_spec.seq_len >= 16384:
        arch_cfg = _dc.replace(arch_cfg, attn_chunk=2048)
    if overrides:
        arch_cfg = _dc.replace(arch_cfg, **overrides)
    if shape in arch_cfg.skip_shapes:
        raise SystemExit(
            f"{arch} skips {shape} (see DESIGN.md §Arch-applicability)"
        )
    mesh = make_production_mesh(multi_pod=multi_pod)
    big = arch_cfg.param_count() > 100e9
    rules = make_rules(mesh, fsdp_over_pod=big)
    chips = 512 if multi_pod else 256
    mesh_name = "2x16x16" if multi_pod else "16x16"

    t0 = time.time()
    if shape_spec.kind == "train":
        lowered, _, _ = lower_train_step(
            arch_cfg, rules, train_batch_shape(arch_cfg, shape_spec),
            arch_train_config(arch_cfg),
        )
    elif shape_spec.kind == "prefill":
        lowered, _ = lower_prefill(arch_cfg, rules, shape_spec)
    else:
        lowered, _, _ = lower_decode_step(arch_cfg, rules, shape_spec)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    report = make_report(
        arch_cfg, shape_spec, mesh_name, chips, compiled,
        shape_spec.kind, note=note,
    )
    from repro.launch.roofline import cost_analysis_dict, peak_memory_bytes

    ma = compiled.memory_analysis()
    ca = cost_analysis_dict(compiled)
    print(
        f"[{arch} x {shape} @ {mesh_name}] lower {t_lower:.1f}s "
        f"compile {t_compile:.1f}s | peak/dev "
        f"{peak_memory_bytes(ma) / 1e9:.2f} GB, args "
        f"{ma.argument_size_in_bytes / 1e9:.2f} GB | "
        f"cost_analysis flops={ca.get('flops', 0):.3e} (while bodies "
        f"counted once) | parsed flops/dev {report.hlo_flops:.3e}"
    )
    print(
        f"  roofline: compute {report.compute_s * 1e3:.2f} ms, memory "
        f"{report.memory_s * 1e3:.2f} ms, collective "
        f"{report.collective_s * 1e3:.2f} ms -> {report.dominant}-bound; "
        f"useful-ratio {report.useful_ratio:.2f}, roofline fraction "
        f"{report.roofline_fraction:.2%}"
    )
    return report, compiled


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="compile on the 2x16x16 mesh instead of 16x16")
    ap.add_argument("--out", default=None, help="directory for JSON reports")
    ap.add_argument("--note", default="")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in list_archs():
            cfg = get_config(arch)
            for shape in SHAPES:
                if shape not in cfg.skip_shapes:
                    cells.append((arch, shape))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape in cells:
        try:
            report, _ = run_cell(arch, shape, args.multi_pod, args.note)
            if args.out:
                os.makedirs(args.out, exist_ok=True)
                tag = "mp" if args.multi_pod else "sp"
                fn = os.path.join(
                    args.out, f"{arch}__{shape}__{tag}.json"
                )
                with open(fn, "w") as f:
                    json.dump(report.to_json(), f, indent=2)
        except SystemExit:
            raise
        except Exception as e:  # noqa: BLE001 — record and continue
            traceback.print_exc()
            failures.append((arch, shape, repr(e)))
    if failures:
        print("FAILURES:")
        for f in failures:
            print(" ", f)
        sys.exit(1)
    print(f"dry-run OK: {len(cells)} cells")


if __name__ == "__main__":
    main()
