import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: run the three chosen cells through their
hypothesis->change->measure iterations (DESIGN.md §9 / EXPERIMENTS.md
§Perf) and save one JSON per iteration under results/perf/.

  PYTHONPATH=src python -m repro.launch.hillclimb [--cell A|B|C|kimi_fit]
"""

import argparse
import dataclasses
import json

from repro.configs import get_config
from repro.launch.dryrun import run_cell


def save(report, name):
    os.makedirs("results/perf", exist_ok=True)
    with open(f"results/perf/{name}.json", "w") as f:
        json.dump(report.to_json(), f, indent=2)


def cell_a():
    """musicgen-medium x train_4k — worst roofline fraction (0.32%)."""
    print("#### CELL A: musicgen-medium x train_4k")
    steps = [
        ("A0_baseline", {}, "baseline (24 heads pad-replicated by GSPMD, "
         "vanilla attention, remat=full)"),
        ("A1_headpad", {"attn_head_pad": 32},
         "hypothesis: zero-padding heads 24->32 removes GSPMD involuntary "
         "replication -> memory term ~/2 or better"),
        ("A2_flash", {"attn_head_pad": 32, "attn_chunk": 1024},
         "hypothesis: flash-chunked attention removes (S,S) score "
         "materialization -> memory term drops by the score traffic"),
        ("A3_dots", {"attn_head_pad": 32, "attn_chunk": 1024,
                     "remat": "dots"},
         "hypothesis: with scores gone, saving dots removes fwd "
         "recompute -> compute term ~ -25%"),
    ]
    for name, ov, note in steps:
        r, _ = run_cell("musicgen-medium", "train_4k", note=note,
                        overrides=ov)
        save(r, name)


def cell_b():
    """kimi-k2 x decode_32k — most collective-bound (4.9 s wire)."""
    print("#### CELL B: kimi-k2-1t-a32b x decode_32k")
    cfg = get_config("kimi-k2-1t-a32b")
    steps = [
        ("B0_gather", {"moe": dataclasses.replace(
            cfg.moe, stationary_threshold=0)},
         "baseline: FSDP expert all-gather per layer per token step"),
        ("B1_stationary", {},
         "hypothesis: weights-stationary EP (tokens all-gather ~MBs, "
         "experts never move) -> collective term -99%"),
    ]
    for name, ov, note in steps:
        r, _ = run_cell("kimi-k2-1t-a32b", "decode_32k", note=note,
                        overrides=ov)
        save(r, name)


def cell_c():
    """rwkv6-7b x train_4k — paper-representative (weights-resident
    recurrence, the GRU accelerator's scaled-up cousin)."""
    print("#### CELL C: rwkv6-7b x train_4k")
    cfg = get_config("rwkv6-7b")
    steps = [
        ("C0_baseline", {}, "baseline (remat=full, wkv chunk 128)"),
        ("C1_dots", {"remat": "dots"},
         "hypothesis: remat=full re-runs every fwd TP all-reduce in the "
         "bwd pass; remat=dots keeps psum'd outputs -> collective -1/3, "
         "compute -25%"),
        ("C2_chunk256", {"remat": "dots", "ssm": dataclasses.replace(
            cfg.ssm, chunk=256)},
         "hypothesis: wkv chunk 128->256 halves inter-chunk scan steps; "
         "intra-chunk work doubles per step but is matmul-dense -> "
         "memory term down, compute slightly up"),
        ("C3_chunk64", {"remat": "dots", "ssm": dataclasses.replace(
            cfg.ssm, chunk=64)},
         "counter-hypothesis probe: chunk 64 lowers intra-chunk "
         "(Q,Q,P) traffic -> memory down if ratio tensors dominate"),
    ]
    for name, ov, note in steps:
        r, _ = run_cell("rwkv6-7b", "train_4k", note=note, overrides=ov)
        save(r, name)


def kimi_fit():
    """kimi-k2 train_4k peaks 17.56 GB (> 16 GB HBM) at baseline."""
    print("#### kimi-k2 train_4k HBM fit")
    steps = [
        ("K0_baseline", {}, "baseline: peak 17.56 GB > 16 GB"),
        ("K1_flash", {"attn_chunk": 1024},
         "hypothesis: chunked attention removes the (4096,4096) f32 "
         "score transients -> peak under 16 GB"),
    ]
    for name, ov, note in steps:
        r, _ = run_cell("kimi-k2-1t-a32b", "train_4k", note=note,
                        overrides=ov)
        save(r, name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=["A", "B", "C", "kimi_fit", "all"],
                    default="all")
    args = ap.parse_args()
    if args.cell in ("A", "all"):
        cell_a()
    if args.cell in ("B", "all"):
        cell_b()
    if args.cell in ("C", "all"):
        cell_c()
    if args.cell in ("kimi_fit", "all"):
        kimi_fit()


if __name__ == "__main__":
    main()
