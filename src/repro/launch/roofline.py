"""Roofline analysis from compiled (post-SPMD, per-device) HLO.

Why a custom HLO parser: `compiled.cost_analysis()` counts `while` bodies
ONCE (verified in tests/test_roofline.py), so a scanned-52-layer model
reports ~1/52 of its FLOPs. Post-optimization HLO text, however, carries
`backend_config={"known_trip_count":{"n":..}}` on every lax.scan-derived
while loop — so we walk the computation graph, scale every computation by
the product of its enclosing loops' trip counts, and derive:

  FLOPs      — MXU convention: 2 * out_numel * contracted for every
               `dot` (elementwise VPU flops excluded, as in MFU).
  HBM bytes  — per top-level instruction (fusions count their operands +
               outputs once — exactly the XLA fusion-boundary traffic
               model); parameters/constants/GTEs/bitcasts excluded.
  wire bytes — ring-model per device:
               all-reduce 2B(n-1)/n, all-gather/reduce-scatter/all-to-all
               B(n-1)/n, collective-permute B; n = replica group size.

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s/link ICI (single-link-serialized collectives — conservative).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

__all__ = [
    "HARDWARE", "HLOAnalysis", "analyze_hlo", "CellReport", "make_report",
    "peak_memory_bytes", "cost_analysis_dict",
]


def peak_memory_bytes(ma) -> float:
    """Per-device peak bytes from a CompiledMemoryStats, across jax
    versions: older jaxlibs drop `peak_memory_in_bytes`, in which case
    args + outputs + temps is the standard approximation."""
    pk = getattr(ma, "peak_memory_in_bytes", None)
    if pk:
        return float(pk)
    return float(
        ma.argument_size_in_bytes
        + ma.output_size_in_bytes
        + ma.temp_size_in_bytes
    )


def cost_analysis_dict(compiled) -> dict:
    """compiled.cost_analysis() as a dict (pre-0.5 jax returns [dict])."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        return ca[0] if ca else {}
    return dict(ca)


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12  # bf16 / chip
    hbm_bw: float = 819e9  # B/s
    ici_bw: float = 50e9  # B/s per link


HARDWARE = Hardware()

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "s2": 0.25, "u2": 0.25,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1, "f4e2m1fn": 0.5,
    "f8e8m0fnu": 1, "f8e4m3b11fnz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

# HBM-traffic model: count only ops that are memory-boundary ops on TPU
# (fusions, dots, data movement, reductions, collectives). Standalone
# elementwise/convert ops in the CPU-lowered HLO would be fused into
# neighbors by the TPU backend, so counting them would double-bill the
# same bytes (measured ~10x inflation on qwen3 train; see DESIGN.md §7).
_COUNT_BYTES_OPS = {
    "fusion", "dot", "convolution", "copy", "copy-start",
    "dynamic-slice", "dynamic-update-slice", "reduce", "reduce-window",
    "gather", "scatter", "concatenate", "sort", "select-and-scatter",
    "transpose", "pad", "slice", "fft", "triangular-solve", "cholesky",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start", "ragged-all-to-all",
}

_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start", "ragged-all-to-all",
}


@dataclasses.dataclass
class _Instr:
    name: str
    shape: str
    opcode: str
    operands: List[str]
    attrs: str
    raw_args: str = ""


@dataclasses.dataclass
class _Computation:
    name: str
    instrs: List[_Instr]


def _shape_numel_bytes(shape: str) -> Tuple[float, float]:
    """'bf16[8,64]{1,0}' or tuple '(s32[], bf16[8,64]{1,0})' ->
    (numel, bytes). Tuples sum components."""
    shape = shape.strip()
    if shape.startswith("("):
        total_n = total_b = 0.0
        for part in _split_top(shape[1:-1]):
            n, b = _shape_numel_bytes(part)
            total_n += n
            total_b += b
        return total_n, total_b
    m = re.match(r"([a-z0-9]+)\[([\d,]*)\]", shape)
    if not m:
        return 0.0, 0.0
    dtype, dims = m.group(1), m.group(2)
    numel = 1.0
    if dims:
        for d in dims.split(","):
            numel *= int(d)
    return numel, numel * _DTYPE_BYTES.get(dtype, 4)


def _shape_dims(shape: str) -> List[int]:
    m = re.match(r"[a-z0-9]+\[([\d,]*)\]", shape.strip())
    if not m or not m.group(1):
        return []
    return [int(d) for d in m.group(1).split(",")]


def _split_top(s: str) -> List[str]:
    """split on commas at paren/brace depth 0."""
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    return out


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")


def _parse_instr(name: str, rest: str) -> Optional[_Instr]:
    rest = rest.strip()
    # shape: tuple or simple
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                shape, rest2 = rest[: i + 1], rest[i + 1 :]
                break
        else:
            return None
    else:
        sp = rest.find(" ")
        shape, rest2 = rest[:sp], rest[sp:]
    rest2 = rest2.strip()
    m = re.match(r"([\w\-]+)\((.*)$", rest2)
    if not m:
        return None
    opcode = m.group(1)
    body = m.group(2)
    # split call args from trailing attrs at the matching close paren
    depth = 1
    for i, ch in enumerate(body):
        depth += ch == "("
        depth -= ch == ")"
        if depth == 0:
            args, attrs = body[:i], body[i + 1 :]
            break
    else:
        args, attrs = body, ""
    operands = [
        a.split()[-1].lstrip("%")
        for a in _split_top(args)
        if a.strip().startswith("%") or " %" in a
    ]
    return _Instr(name, shape, opcode, operands, attrs, args)


def _parse_computations(text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = _Computation(m.group(1), [])
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            inst = _parse_instr(m.group(1), m.group(2))
            if inst is not None:
                cur.instrs.append(inst)
    return comps


def _entry_name(text: str) -> Optional[str]:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.MULTILINE)
    return m.group(1) if m else None


def _group_size(attrs: str, default: int = 1) -> int:
    # replica_groups=[8,32]<=[256] -> group size 32 ; or explicit lists
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return default


def _fusion_traffic(ins: _Instr, out_bytes: float,
                    symbols: Dict[str, str],
                    comps: Dict[str, "_Computation"]) -> float:
    """Traffic of a fusion = boundary reads + writes, window-aware.

    Two scan idioms otherwise inflate traffic by n_layers per iteration:
      * stacked weights consumed by an in-fusion dynamic-slice — real
        read is the slice window, not the full stack;
      * the saved-activation stack written by a dynamic-update-slice
        rooted fusion — XLA aliases the base buffer in place, so real
        traffic is the update window (write) + window-sized read, not
        the full (n_layers, ...) output.
    """
    m = re.search(r"calls=%?([\w\.\-]+)", ins.attrs)
    body = comps.get(m.group(1)) if m else None
    if body is None:
        return out_bytes + sum(
            _shape_numel_bytes(symbols.get(o, ""))[1]
            for o in ins.operands
        )
    # map parameter index (from 'parameter(N)') -> body instruction
    params = [bi for bi in body.instrs if bi.opcode == "parameter"]

    def pidx(bi):
        try:
            return int(bi.raw_args.strip())
        except ValueError:
            return 0

    params_in_order = sorted(params, key=pidx)
    body_syms = {i.name: i.shape for i in body.instrs}
    # dataflow aliases: convert/bitcast/copy/reshape are pass-through (the
    # CPU backend wraps everything in bf16<->f32 converts)
    alias: Dict[str, str] = {}
    for bi in body.instrs:
        if bi.opcode in ("convert", "bitcast", "copy", "reshape") and bi.operands:
            alias[bi.name] = bi.operands[0]

    def resolve(name: str) -> str:
        seen = set()
        while name in alias and name not in seen:
            seen.add(name)
            name = alias[name]
        return name

    slice_out: Dict[str, float] = {}
    dus_base: Dict[str, float] = {}  # base source -> update window bytes
    full_needed: Dict[str, bool] = {}
    for bi in body.instrs:
        if bi.opcode in ("convert", "bitcast", "copy", "reshape"):
            continue  # alias, not a consumer
        if bi.opcode in ("dynamic-slice", "slice"):
            b = _shape_numel_bytes(bi.shape)[1]
            for o in bi.operands:
                src = resolve(o)
                slice_out[src] = max(slice_out.get(src, 0.0), b)
            continue
        if bi.opcode == "dynamic-update-slice" and len(bi.operands) >= 2:
            upd = _shape_numel_bytes(body_syms.get(bi.operands[1], ""))[1]
            base = resolve(bi.operands[0])
            dus_base[base] = max(dus_base.get(base, 0.0), upd)
            full_needed[resolve(bi.operands[1])] = True
            continue
        for o in bi.operands:
            full_needed[resolve(o)] = True
    total = 0.0
    for idx, pi in enumerate(params_in_order):
        if idx >= len(ins.operands):
            break
        full = _shape_numel_bytes(symbols.get(ins.operands[idx], ""))[1]
        if pi.name in full_needed:
            total += full
        elif pi.name in slice_out:
            total += min(slice_out[pi.name], full)
        elif pi.name in dus_base:
            total += min(dus_base[pi.name], full)
        else:
            total += full
    # output: if the root (through aliases) is a DUS — the in-place
    # saved-activation append — bill the window, not the stack
    root = body.instrs[-1] if body.instrs else None
    root_src = resolve(root.name) if root is not None else ""
    root_ins = next(
        (bi for bi in body.instrs if bi.name == root_src), None
    )
    if root_ins is not None and root_ins.opcode == "dynamic-update-slice":
        upd = (
            _shape_numel_bytes(body_syms.get(root_ins.operands[1], ""))[1]
            if len(root_ins.operands) >= 2
            else out_bytes
        )
        total += min(upd, out_bytes)
    else:
        total += out_bytes
    return total


@dataclasses.dataclass
class HLOAnalysis:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    wire_bytes: float = 0.0
    collective_breakdown: Dict[str, float] = dataclasses.field(
        default_factory=dict
    )
    dot_flops_by_meta: Dict[str, float] = dataclasses.field(
        default_factory=dict
    )
    unknown_trip_counts: int = 0


def analyze_hlo(text: str) -> HLOAnalysis:
    comps = _parse_computations(text)
    entry = _entry_name(text)
    res = HLOAnalysis()
    if entry is None or entry not in comps:
        return res

    # computations called as fusion bodies / reducers: excluded from the
    # per-instruction walk (their cost is attributed to the caller op)
    fused: set = set()
    for comp in comps.values():
        for ins in comp.instrs:
            for attr_key in ("calls=", "to_apply="):
                m = re.search(attr_key + r"%?([\w\.\-]+)", ins.attrs)
                if m:
                    fused.add(m.group(1))

    # walk: (computation, multiplier) — whiles multiply by trip count
    seen_stack: List[str] = []

    def walk(comp_name: str, mult: float):
        comp = comps.get(comp_name)
        if comp is None or comp_name in seen_stack:
            return
        seen_stack.append(comp_name)
        symbols = {i.name: i.shape for i in comp.instrs}
        for ins in comp.instrs:
            op = ins.opcode
            if op == "while":
                m = re.search(r'known_trip_count[":{\s]+n["\s:]+"?(\d+)', ins.attrs)
                trips = float(m.group(1)) if m else 1.0
                if m is None:
                    res.unknown_trip_counts += 1
                mb = re.search(r"body=%?([\w\.\-]+)", ins.attrs)
                if mb:
                    walk(mb.group(1), mult * trips)
                mc = re.search(r"condition=%?([\w\.\-]+)", ins.attrs)
                if mc:
                    walk(mc.group(1), mult * trips)
                continue
            if op in ("call", "conditional", "async-start"):
                for key in ("to_apply=", "called_computations=\\{", "branch_computations=\\{"):
                    for m in re.finditer(key + r"%?([\w\.\-]+)", ins.attrs):
                        walk(m.group(1), mult)
                continue

            # ---- FLOPs (dot ops) ----
            if op == "dot" and len(ins.operands) >= 2:
                lhs_shape = symbols.get(ins.operands[0], "")
                lhs_dims = _shape_dims(lhs_shape)
                mcd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
                contract = 1.0
                if mcd and mcd.group(1) and lhs_dims:
                    for d in mcd.group(1).split(","):
                        di = int(d)
                        if di < len(lhs_dims):
                            contract *= lhs_dims[di]
                out_numel, _ = _shape_numel_bytes(ins.shape)
                flops = 2.0 * out_numel * contract * mult
                res.flops += flops
                meta = re.search(r'op_name="([^"]*)"', ins.attrs)
                key = meta.group(1).split("/")[-1] if meta else "dot"
                res.dot_flops_by_meta[key] = (
                    res.dot_flops_by_meta.get(key, 0.0) + flops
                )

            # ---- collectives ----
            if op in _COLLECTIVES:
                n = _group_size(ins.attrs, default=2)
                op_bytes = sum(
                    _shape_numel_bytes(symbols.get(o, ""))[1]
                    for o in ins.operands
                )
                _, out_bytes = _shape_numel_bytes(ins.shape)
                base = op.replace("-start", "")
                if base == "all-reduce":
                    wire = 2.0 * op_bytes * (n - 1) / max(n, 1)
                elif base == "all-gather":
                    wire = out_bytes * (n - 1) / max(n, 1)
                elif base in ("reduce-scatter", "all-to-all",
                              "ragged-all-to-all"):
                    wire = op_bytes * (n - 1) / max(n, 1)
                else:  # collective-permute
                    wire = op_bytes
                res.wire_bytes += wire * mult
                res.collective_breakdown[base] = (
                    res.collective_breakdown.get(base, 0.0) + wire * mult
                )

            # ---- HBM traffic ----
            if op in _COUNT_BYTES_OPS:
                _, out_bytes = _shape_numel_bytes(ins.shape)
                if op in ("dynamic-slice", "slice", "gather"):
                    # reads only the sliced window, not the operand
                    traffic = 2.0 * out_bytes
                elif op == "dynamic-update-slice":
                    upd = (
                        _shape_numel_bytes(
                            symbols.get(ins.operands[1], "")
                        )[1]
                        if len(ins.operands) > 1
                        else out_bytes
                    )
                    traffic = 2.0 * upd
                elif op == "fusion":
                    traffic = _fusion_traffic(
                        ins, out_bytes, symbols, comps
                    )
                else:
                    in_bytes = sum(
                        _shape_numel_bytes(symbols.get(o, ""))[1]
                        for o in ins.operands
                    )
                    traffic = out_bytes + in_bytes
                res.hbm_bytes += traffic * mult
        seen_stack.pop()

    walk(entry, 1.0)
    return res


@dataclasses.dataclass
class CellReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    kind: str  # train | prefill | decode
    # per-device roofline terms (seconds)
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    # raw
    hlo_flops: float  # per device
    hlo_bytes: float
    wire_bytes: float
    model_flops: float  # analytic useful flops, global
    useful_ratio: float  # model_flops / (hlo_flops * chips)
    peak_bytes_per_device: float
    arg_bytes_per_device: float
    note: str = ""
    collective_breakdown: Dict[str, float] = dataclasses.field(
        default_factory=dict
    )

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / modeled step time: how close the step is
        to the pure-compute roofline of its useful flops."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        useful_t = self.model_flops / self.chips / HARDWARE.peak_flops
        return min(useful_t / t, 1.0)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["step_time_s"] = self.step_time_s
        d["roofline_fraction"] = self.roofline_fraction
        return d


def model_flops_for(arch_cfg, shape_spec) -> float:
    """Analytic 'useful' FLOPs per step, global across chips.

    train: 6*N*D (fwd+bwd), MoE counts active params only;
    prefill: 2*N*D; decode: 2*N*B per token (one step).
    Attention score/value flops are excluded (same convention as 6ND).
    """
    n = arch_cfg.active_param_count()
    if shape_spec.kind == "train":
        tokens = shape_spec.global_batch * shape_spec.seq_len
        return 6.0 * n * tokens
    if shape_spec.kind == "prefill":
        tokens = shape_spec.global_batch * shape_spec.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape_spec.global_batch  # decode: one token/stream


def make_report(
    arch_cfg,
    shape_spec,
    mesh_name: str,
    chips: int,
    compiled,
    kind: str,
    note: str = "",
) -> CellReport:
    analysis = analyze_hlo(compiled.as_text())
    ma = compiled.memory_analysis()
    mf = model_flops_for(arch_cfg, shape_spec)
    compute_s = analysis.flops / HARDWARE.peak_flops
    memory_s = analysis.hbm_bytes / HARDWARE.hbm_bw
    collective_s = analysis.wire_bytes / HARDWARE.ici_bw
    terms = {
        "compute": compute_s,
        "memory": memory_s,
        "collective": collective_s,
    }
    dominant = max(terms, key=terms.get)
    return CellReport(
        arch=arch_cfg.name,
        shape=shape_spec.name,
        mesh=mesh_name,
        chips=chips,
        kind=kind,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        hlo_flops=analysis.flops,
        hlo_bytes=analysis.hbm_bytes,
        wire_bytes=analysis.wire_bytes,
        model_flops=mf,
        useful_ratio=(
            mf / (analysis.flops * chips) if analysis.flops else 0.0
        ),
        peak_bytes_per_device=peak_memory_bytes(ma),
        arg_bytes_per_device=float(ma.argument_size_in_bytes),
        note=note,
        collective_breakdown=analysis.collective_breakdown,
    )
