"""Fault tolerance: checkpoint/restart, elastic re-meshing, straggler
mitigation (DESIGN.md §5).

On a real cluster the coordinator detects node loss (missed heartbeats /
collective timeout); here the same control flow is driven explicitly so
the logic is testable on host devices:

  * `CheckpointPolicy` + the manager wrap training/checkpoint.py with
    periodic + best-effort-final saves and resume-from-latest.
  * `ElasticMeshManager.shrink()` rebuilds a smaller data axis after a
    simulated node loss, re-lowers the train step for the new mesh, and
    restores the latest checkpoint with the new shardings — elastic
    scaling without restart-from-zero.
  * `StragglerMonitor` tracks per-step durations (EMA + deviation); steps
    slower than `threshold` x EMA are flagged, and after `budget`
    consecutive flags it recommends eviction/re-mesh (policy hook — the
    decision stays with the orchestrator).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Optional

import jax

from repro.training import checkpoint as ckpt

Pytree = Any


@dataclasses.dataclass
class CheckpointPolicy:
    directory: str
    every_steps: int = 100
    keep: int = 3
    async_save: bool = True


class CheckpointManager:
    def __init__(self, policy: CheckpointPolicy):
        self.policy = policy
        self._pending = None

    def maybe_save(self, step: int, tree: Pytree):
        # step 0 is the untrained init: `0 % every_steps == 0` used to
        # save it, burning a `keep` slot and making restore_latest's
        # answer after an early crash a checkpoint with zero training
        # in it. The first real save is at `every_steps`.
        if step == 0 or step % self.policy.every_steps:
            return
        self.wait()
        self._pending = ckpt.save_checkpoint(
            self.policy.directory, step, tree,
            keep=self.policy.keep, async_save=self.policy.async_save,
        )

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore_latest(self, template: Pytree, shardings=None):
        self.wait()
        return ckpt.restore_checkpoint(
            self.policy.directory, template, shardings=shardings
        )


class ElasticMeshManager:
    """Rebuilds the mesh with a smaller data axis on node loss.

    The model axis is preserved (model-parallel groups die together on a
    real pod slice); lost capacity comes out of data parallelism, and the
    global batch either shrinks or is re-split (caller's choice via
    `batch_resize`).
    """

    def __init__(self, make_mesh: Callable[[int], Any],
                 initial_data_size: int):
        self.make_mesh = make_mesh
        self.data_size = initial_data_size

    def shrink(self, lost_nodes: int = 1):
        new_size = self.data_size - lost_nodes
        # keep the data axis a divisor-friendly size (power of two here)
        while new_size > 1 and (new_size & (new_size - 1)):
            new_size -= 1
        if new_size < 1:
            raise RuntimeError("no capacity left after failures")
        self.data_size = new_size
        return self.make_mesh(new_size)


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    ema: float


class StragglerMonitor:
    """Per-step duration tracking with an EMA baseline.

    ``warmup`` steps (default 1) are discarded entirely before the EMA
    is seeded: step 0 of any jitted loop includes compilation, so
    seeding the baseline from it poisons the EMA ~100x high and real
    stragglers are never flagged (a 2x-slow step against a 100x-high
    baseline looks fast). The EMA seeds from the first post-warmup
    duration instead.
    """

    def __init__(self, threshold: float = 2.0, budget: int = 3,
                 ema_alpha: float = 0.1, warmup: int = 1):
        if warmup < 0:
            raise ValueError(f"warmup must be >= 0, got {warmup}")
        self.threshold = threshold
        self.budget = budget
        self.alpha = ema_alpha
        self.warmup = warmup
        self._seen = 0
        self.ema: Optional[float] = None
        self.consecutive = 0
        self.events: List[StragglerEvent] = []

    def record(self, step: int, duration: float) -> bool:
        """Returns True when the eviction/re-mesh budget is exhausted."""
        if self._seen < self.warmup:
            # compilation / cold-cache steps: not data, not baseline
            self._seen += 1
            return False
        if self.ema is None:
            self.ema = duration
            return False
        slow = duration > self.threshold * self.ema
        if slow:
            self.consecutive += 1
            self.events.append(StragglerEvent(step, duration, self.ema))
        else:
            self.consecutive = 0
            # only fold healthy steps into the EMA (stragglers would
            # poison the baseline)
            self.ema = (1 - self.alpha) * self.ema + self.alpha * duration
        return self.consecutive >= self.budget

    def timed(self, step: int) -> "_Timed":
        """with monitor.timed(step): ... — records duration on exit."""
        return _Timed(self, step)


class _Timed:
    def __init__(self, monitor: StragglerMonitor, step: int):
        self.monitor = monitor
        self.step = step

    def __enter__(self):
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *exc):
        self.monitor.record(self.step, time.monotonic() - self.t0)
        return False
