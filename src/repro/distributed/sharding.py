"""Parameter / activation sharding rules (DESIGN.md §5).

Conventions on the production mesh (("pod",) "data", "model"):
  * TP over "model": attention heads, FFN hidden, vocab, MoE experts.
  * FSDP over `fsdp_axes` (usually ("data",), plus "pod" for the 1T MoE):
    the remaining large dimension of each weight.
  * batch over dp_axes = ("pod", "data") when multi-pod.

Rules are name-based over the param tree; scanned stacks (leading
n_steps axis) get a None prepended automatically. Everything funnels
through `param_specs` / `batch_specs` so train/serve/dry-run agree.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.moe import MeshContext

__all__ = [
    "ShardingRules",
    "param_specs",
    "batch_specs",
    "cache_specs",
    "make_mesh_context",
    "named",
    "STREAM_AXIS",
    "stream_mesh",
    "stream_shardings",
    "replicated_shardings",
    "surviving_devices",
]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    dp_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    fsdp_axes: Tuple[str, ...] = ("data",)
    fsdp: bool = True

    @property
    def fsdp_spec(self):
        if not self.fsdp:
            return None
        return self.fsdp_axes if len(self.fsdp_axes) > 1 else self.fsdp_axes[0]

    @property
    def dp_spec(self):
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]


def make_mesh_context(rules: ShardingRules) -> MeshContext:
    return MeshContext(
        mesh=rules.mesh,
        dp_axes=rules.dp_axes,
        model_axis=rules.model_axis,
        fsdp_axes=rules.fsdp_axes if rules.fsdp else (),
    )


# expected trailing ndims for each named weight class
_RULES = {
    # name: (base_ndim, spec builder)
    "embed": (2, lambda r: P(r.model_axis, r.fsdp_spec)),
    "head": (2, lambda r: P(r.fsdp_spec, r.model_axis)),
    "wq": (3, lambda r: P(r.fsdp_spec, r.model_axis, None)),
    "wk": (3, lambda r: P(r.fsdp_spec, r.model_axis, None)),
    "wv": (3, lambda r: P(r.fsdp_spec, r.model_axis, None)),
    "wo": (3, lambda r: P(r.model_axis, None, r.fsdp_spec)),
    "w_up": (2, lambda r: P(r.fsdp_spec, r.model_axis)),
    "w_gate": (2, lambda r: P(r.fsdp_spec, r.model_axis)),
    "w_down": (2, lambda r: P(r.model_axis, r.fsdp_spec)),
    "router": (2, lambda r: P(None, None)),
    # mamba2 projections (column-parallel inner dim / heads over model)
    "in_proj": (2, lambda r: P(r.fsdp_spec, r.model_axis)),
    "out_proj": (2, lambda r: P(r.model_axis, r.fsdp_spec)),
    "w_z": (2, lambda r: P(r.fsdp_spec, r.model_axis)),
    "w_x": (2, lambda r: P(r.fsdp_spec, r.model_axis)),
    "w_dt": (2, lambda r: P(r.fsdp_spec, r.model_axis)),
    "conv_w": (2, lambda r: P(None, r.model_axis)),  # (K, d_inner)
    # rwkv6 time-mix (channels == heads x head_dim over model)
    "w_r": (2, lambda r: P(r.fsdp_spec, r.model_axis)),
    "w_k": (2, lambda r: P(r.fsdp_spec, r.model_axis)),
    "w_v": (2, lambda r: P(r.fsdp_spec, r.model_axis)),
    "w_g": (2, lambda r: P(r.fsdp_spec, r.model_axis)),
    "w_o": (2, lambda r: P(r.model_axis, r.fsdp_spec)),
    # rwkv6 channel-mix
    "cm_w_k": (2, lambda r: P(r.fsdp_spec, r.model_axis)),
    "cm_w_v": (2, lambda r: P(r.model_axis, r.fsdp_spec)),
    "cm_w_r": (2, lambda r: P(r.fsdp_spec, r.model_axis)),
}

# MoE expert banks: one extra leading expert axis sharded over model
_EXPERT_RULES = {
    "w_up": lambda r: P(r.model_axis, r.fsdp_spec, None),
    "w_gate": lambda r: P(r.model_axis, r.fsdp_spec, None),
    "w_down": lambda r: P(r.model_axis, None, r.fsdp_spec),
}


def _axes_size(entry, mesh: Mesh) -> int:
    if entry is None:
        return 1
    if isinstance(entry, str):
        return mesh.shape[entry]
    n = 1
    for ax in entry:
        n *= mesh.shape[ax]
    return n


def _fit(spec: P, shape, mesh: Mesh) -> P:
    """Drop spec entries that don't divide the dim size (explicit
    in_shardings require exact divisibility). The systematic case is GQA
    kv heads (8) on the 16-way model axis: KV projections replicate
    under wide TP (Megatron convention — attention then runs fully local
    per rank); the KV *cache* stays distributed by sharding its sequence
    axis instead (see cache_specs)."""
    dims = list(spec) + [None] * (len(shape) - len(spec))
    for i, entry in enumerate(dims):
        if entry is None:
            continue
        if shape[i] % _axes_size(entry, mesh) != 0:
            dims[i] = None
    return P(*dims)


def _leaf_spec(path, leaf, rules: ShardingRules) -> P:
    keys = [
        k.key if isinstance(k, jax.tree_util.DictKey) else None
        for k in path
    ]
    names = [k for k in keys if isinstance(k, str)]
    name = names[-1] if names else ""
    # int8 serving weights: {"q","s"} dicts under the weight's name —
    # q inherits the weight rule; s drops the (now size-1) last-dim entry
    is_q = is_s = False
    if name in ("q", "s") and len(names) >= 2:
        is_q, is_s = name == "q", name == "s"
        name = names[-2]
    in_moe = "moe" in names or "experts" in names
    ndim = leaf.ndim

    if in_moe and name in _EXPERT_RULES:
        base = 3
        spec = _EXPERT_RULES[name](rules)
    elif name in _RULES:
        base, builder = _RULES[name]
        spec = builder(rules)
    else:
        # norms, biases, small vectors: replicated
        base = ndim
        spec = P(*([None] * ndim))
    extra = ndim - base
    if extra < 0:
        return P(*([None] * ndim))
    dims = [None] * extra + list(spec)
    if is_s:
        dims = dims[:-1] + [None]
    del is_q
    return _fit(P(*dims), leaf.shape, rules.mesh)


def param_specs(params_shape: Any, rules: ShardingRules):
    """Pytree of PartitionSpec matching a params (shape) pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: _leaf_spec(p, l, rules), params_shape
    )


def named(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs)


def batch_specs(batch_shape: Any, rules: ShardingRules):
    """Input batch: leading dim is the global batch -> dp axes; if the
    batch doesn't divide the dp axes (long-context batch=1), replicate."""
    dp_total = 1
    for ax in rules.dp_axes:
        dp_total *= rules.mesh.shape[ax]

    def spec(leaf):
        if leaf.ndim == 0:
            return P()
        if leaf.shape[0] % dp_total == 0:
            return P(*([rules.dp_spec] + [None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree.map(spec, batch_shape)


# --------------------------------------------------------------------------
# Stream-parallel serving mesh (KWS)
# --------------------------------------------------------------------------
#
# The KWS server's unit of parallelism is the stream SLOT: every
# `ServerState` leaf, input slab, and submitted mask leads with the
# (max_streams,) slot axis, and slots are computationally independent
# (per-stream GRU state, filter carry, scores — no cross-slot reduction
# anywhere in the tick). That makes the slot axis embarrassingly
# shardable: a 1-D ("stream",) mesh splits it block-wise over devices
# while the classifier/frontend parameters replicate.

STREAM_AXIS = "stream"


def stream_mesh(
    devices: Union[int, Sequence[Any], None] = None
) -> Mesh:
    """A 1-D ``("stream",)`` mesh for stream-parallel serving.

    devices: an int (the first N visible devices), an explicit device
    sequence, or None for every visible device. An int larger than the
    visible device count is an error — serving capacity planning must
    not silently degrade.
    """
    if devices is None:
        devs = list(jax.devices())
    elif isinstance(devices, int):
        visible = list(jax.devices())
        if devices < 1 or devices > len(visible):
            raise ValueError(
                f"stream_mesh(devices={devices}) but only "
                f"{len(visible)} device(s) visible"
            )
        devs = visible[:devices]
    else:
        devs = list(devices)
    if hasattr(jax, "make_mesh") and devs == list(jax.devices()):
        return jax.make_mesh((len(devs),), (STREAM_AXIS,))
    return Mesh(np.asarray(devs), (STREAM_AXIS,))


def surviving_devices(mesh: Mesh, lost_index: int) -> list:
    """Devices of a 1-D stream mesh minus the lost shard's device, in
    mesh order — the pool a shard-loss recovery rebuilds its (smaller)
    mesh from (`StreamingKWSServer.recover_shard_loss` hands this to
    `ElasticMeshManager`, whose power-of-two shrink takes a prefix)."""
    devs = list(np.ravel(mesh.devices))
    if not 0 <= lost_index < len(devs):
        raise ValueError(
            f"lost_index {lost_index} outside mesh of {len(devs)} "
            "device(s)"
        )
    return [d for i, d in enumerate(devs) if i != lost_index]


def stream_shardings(tree: Any, mesh: Mesh):
    """NamedShardings sharding every leaf's LEADING axis over
    ``"stream"`` (scalars replicate) — the layout of `ServerState`
    leaves and per-tick slot-major slabs. Scanned replay slabs
    ``(n_ticks, max_streams, ...)`` shard their second axis instead;
    the serving loop spells those specs out at its jit boundary."""

    def spec(leaf):
        ndim = getattr(leaf, "ndim", 0)
        if ndim == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(STREAM_AXIS, *([None] * (ndim - 1))))

    return jax.tree.map(spec, tree)


def replicated_shardings(tree: Any, mesh: Mesh):
    """Fully replicated NamedShardings (classifier params, frontend
    calibration state, scalars)."""
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def cache_specs(cache_shape: Any, rules: ShardingRules, batch: int):
    """Serving-state sharding, keyed by leaf name.

    KV caches ("k"/"v", shape (..., B, S, KV, hd)): batch over dp when it
    divides; the SEQUENCE axis shards over "model" (plus "data" when the
    batch cannot shard — long-context batch=1). Decode attention then
    reduces its softmax over the sharded seq dim: flash-decoding, with
    GSPMD inserting the cross-shard max/sum. Recurrent states shard
    their head/channel axis over "model" to match the column-parallel
    projections that produce them."""
    dp_total = 1
    for ax in rules.dp_axes:
        dp_total *= rules.mesh.shape[ax]
    batch_ok = batch % dp_total == 0
    seq_axes = (
        rules.model_axis if batch_ok else ("data", rules.model_axis)
    )

    def spec(path, leaf):
        names = [
            k.key for k in path if isinstance(k, jax.tree_util.DictKey)
        ]
        name = names[-1] if names else ""
        dims = [None] * leaf.ndim
        bidx = None
        for i, d in enumerate(leaf.shape[:2]):
            if d == batch:
                bidx = i
                break
        if bidx is None:
            return P(*dims)
        if batch_ok:
            dims[bidx] = rules.dp_spec
        if name in ("k", "v") and leaf.ndim >= bidx + 4:
            dims[bidx + 1] = seq_axes  # sequence axis
        elif name in ("wkv", "ssd") and leaf.ndim >= bidx + 3:
            dims[bidx + 1] = rules.model_axis  # heads
        elif name == "conv":
            dims[-1] = rules.model_axis  # d_inner (column-parallel)
        return _fit(P(*dims), leaf.shape, rules.mesh)

    return jax.tree_util.tree_map_with_path(spec, cache_shape)
