"""Distributed-optimization collectives.

`compressed_psum_with_error_feedback`: int8-quantized gradient all-reduce
with residual error feedback (1-bit-Adam / PowerSGD family, here absmax
int8). Each shard quantizes (grad + residual), psums the int8 codes (as
int32 to avoid overflow) and fp32 scales, and keeps the quantization
error as the next step's residual — unbiased in the long run, 4x less
gradient traffic than fp32 / 2x less than bf16 on the wire.

Used by the shard_map data-parallel KWS train step (the paper's own
model trains pure-DP) and available as an opt-in for LM data-parallel
gradient sync; measured in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def compressed_psum_with_error_feedback(
    grads: Pytree,
    residual: Pytree,
    axis_name,
) -> Tuple[Pytree, Pytree]:
    """Inside shard_map/pmap: all-reduce-mean grads with int8 compression.

    Protocol per tensor: (1) pmax a single absmax scalar so every shard
    quantizes with the SAME scale (decode is then exact for what was
    sent — a per-shard scale cannot be error-fed-back); (2) psum the int8
    codes (as int32 on the wire accumulator); (3) keep the local
    quantization error as next step's residual. Wire cost: 1 byte/elem
    + one scalar — 4x less than fp32 gradient sync.

    Returns (synced grads, new residual); residual has grads' structure.
    """
    n = jax.lax.psum(1.0, axis_name)

    def leaf(g, r):
        g32 = g.astype(jnp.float32) + r
        scale = (
            jax.lax.pmax(jnp.max(jnp.abs(g32)), axis_name) / 127.0 + 1e-12
        )
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        sent = q.astype(jnp.float32) * scale
        new_r = g32 - sent  # error feedback: keep what we failed to send
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        mean = total.astype(jnp.float32) * scale / n
        return mean.astype(g.dtype), new_r

    flat_g, td = jax.tree_util.tree_flatten(grads)
    flat_r = td.flatten_up_to(residual)
    out = [leaf(g, r) for g, r in zip(flat_g, flat_r)]
    return (
        td.unflatten([o[0] for o in out]),
        td.unflatten([o[1] for o in out]),
    )


def init_residual(params: Pytree) -> Pytree:
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
