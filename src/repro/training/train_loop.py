"""Train-step builders: full update step (fwd + bwd + AdamW) as a single
pjit'd program — what the dry-run lowers and what a real run executes.

Features: global-norm clipping, gradient accumulation (microbatching via
lax.scan), donated params/opt-state buffers, schedule-driven lr, and the
sharding rules of distributed/sharding.py applied to params, moments,
and batch alike.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import (
    ShardingRules,
    batch_specs,
    make_mesh_context,
    named,
    param_specs,
)
from repro.models.registry import get_backbone
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state

Pytree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    microbatch: int = 1  # gradient-accumulation steps per update
    lr_schedule: Optional[Callable] = None  # step -> lr


def _opt_state_specs(opt_state_shape, pspecs):
    """Moments mirror param sharding exactly (int8 q keeps the param's
    shape; its row scale drops the last-dim sharding entry)."""
    from jax.sharding import PartitionSpec as P

    def mirror(spec_tree, state_tree):
        def leaf_map(spec, st):
            if isinstance(st, dict) and "q" in st:
                dims = list(spec) if spec else []
                sdims = dims[:-1] + [None] if dims else []
                return {"q": spec, "s": P(*sdims)}
            return spec

        return jax.tree.map(
            leaf_map, spec_tree, state_tree,
            is_leaf=lambda x: isinstance(x, dict) and "q" in x,
        )

    return {
        "step": P(),
        "m": mirror(pspecs, opt_state_shape["m"]),
        "v": mirror(pspecs, opt_state_shape["v"]),
    }


def build_train_step(
    arch_cfg,
    rules: ShardingRules,
    train_cfg: TrainConfig = TrainConfig(),
):
    """Returns (train_step, param_shardings_fn). train_step(params,
    opt_state, batch, step) -> (params, opt_state, metrics)."""
    backbone = get_backbone(arch_cfg)
    mesh_ctx = make_mesh_context(rules)

    def loss(params, batch):
        return backbone.loss_fn(params, batch, arch_cfg, mesh_ctx)

    def train_step(params, opt_state, batch):
        if train_cfg.microbatch > 1:
            mb = train_cfg.microbatch

            def micro(g_acc, mb_batch):
                l, g = jax.value_and_grad(loss)(params, mb_batch)
                return jax.tree.map(jnp.add, g_acc, g), l

            def split(leaf):
                b = leaf.shape[0]
                return leaf.reshape(mb, b // mb, *leaf.shape[1:])

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            g_sum, losses = jax.lax.scan(
                micro, g0, jax.tree.map(split, batch)
            )
            grads = jax.tree.map(lambda g: g / mb, g_sum)
            l = losses.mean()
        else:
            l, grads = jax.value_and_grad(loss)(params, batch)
        lr = None
        if train_cfg.lr_schedule is not None:
            lr = train_cfg.lr_schedule(opt_state["step"])
        params, opt_state, metrics = adamw_update(
            params, grads, opt_state, train_cfg.optimizer, lr
        )
        metrics["loss"] = l
        return params, opt_state, metrics

    return train_step


def lower_train_step(
    arch_cfg,
    rules: ShardingRules,
    batch_shape,
    train_cfg: TrainConfig = TrainConfig(),
):
    """Abstract lower+compile of the full update step (dry-run entry).

    Never allocates: params/opt-state come from eval_shape.
    """
    backbone = get_backbone(arch_cfg)
    mesh_ctx = make_mesh_context(rules)
    params_shape = jax.eval_shape(
        lambda k: backbone.init_params(k, arch_cfg, mesh_ctx),
        jax.random.PRNGKey(0),
    )
    opt_shape = jax.eval_shape(
        lambda p: init_opt_state(p, train_cfg.optimizer), params_shape
    )
    pspecs = param_specs(params_shape, rules)
    ospecs = _opt_state_specs(opt_shape, pspecs)
    bspecs = batch_specs(batch_shape, rules)
    step_fn = build_train_step(arch_cfg, rules, train_cfg)
    with rules.mesh:
        lowered = jax.jit(
            step_fn,
            in_shardings=(
                named(pspecs, rules.mesh),
                named(ospecs, rules.mesh),
                named(bspecs, rules.mesh),
            ),
            # outputs mirror inputs (donation reuses the buffers); metrics
            # replicate
            out_shardings=(
                named(pspecs, rules.mesh),
                named(ospecs, rules.mesh),
                None,
            ),
            donate_argnums=(0, 1),
        ).lower(params_shape, opt_shape, batch_shape)
    return lowered, params_shape, opt_shape
