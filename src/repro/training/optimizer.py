"""Optimizers, pure JAX (no optax): AdamW with optional int8-quantized
moments, plus the paper's training schedule pieces.

The paper trains its GRU with AdamW (lr 1e-3, wd 0.01) and
ReduceLROnPlateau (factor 0.8, patience 3, min lr 5e-4) — Section III-F.
Both are implemented here and used by the KWS examples; the LM train
steps use AdamW + cosine.

int8 moments (`state_dtype="int8"`): blockwise absmax quantization
(block 128 on the flattened tensor) — the distributed-optimization trick
that lets the 1T MoE's optimizer state fit v5e-512 (DESIGN.md §6), and
the framework-level echo of the paper's 8-bit weight memory.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any
_BLOCK = 128


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    state_dtype: str = "float32"  # float32 | int8


# ---------- int8 row-wise moment quantization ----------
# Quantized moments keep the PARAM's shape (int8) with one fp32 absmax
# scale per last-dim row, so they shard with exactly the param's
# PartitionSpec — no resharding traffic in the update step. Small leaves
# (norm scales, biases) stay fp32.
#
# The second moment v is quantized in SQRT space (unsigned): linear
# absmax int8 on v zeroes small coordinates and 1/sqrt(v_hat) then
# explodes (measured: diverges on a quadratic). sqrt-space bounds the
# *denominator* error by max(sqrt(v))/255 — small coordinates understep
# instead of exploding (same reason bitsandbytes uses a nonlinear map).

_INT8_MIN_SIZE = 4096


def _use_int8(p) -> bool:
    return p.ndim >= 2 and p.size >= _INT8_MIN_SIZE


def _quant_rowwise(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Signed absmax int8 per last-dim row (first moment)."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequant_rowwise(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def _quant_sqrt_rowwise(v: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Unsigned sqrt-space uint8-in-int8 for the second moment."""
    r = jnp.sqrt(jnp.maximum(v, 0.0))
    scale = jnp.max(r, axis=-1, keepdims=True) / 254.0 + 1e-20
    q = jnp.clip(jnp.round(r / scale) - 127, -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequant_sqrt_rowwise(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    r = (q.astype(jnp.float32) + 127.0) * scale
    return r * r


def init_opt_state(params: Pytree, cfg: AdamWConfig) -> Pytree:
    def zeros_like_moment(p):
        if cfg.state_dtype == "int8" and _use_int8(p):
            return {
                "q": jnp.zeros(p.shape, jnp.int8),
                "s": jnp.zeros(p.shape[:-1] + (1,), jnp.float32),
            }
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros_like_moment, params),
        "v": jax.tree.map(zeros_like_moment, params),
    }


def global_norm(tree: Pytree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(
            jnp.sum(jnp.square(l.astype(jnp.float32)))
            for l in jax.tree.leaves(tree)
        )
    )


def adamw_update(
    params: Pytree,
    grads: Pytree,
    state: Pytree,
    cfg: AdamWConfig,
    lr: Optional[jnp.ndarray] = None,
) -> Tuple[Pytree, Pytree, dict]:
    """One AdamW step. Params may be bf16 (updated in fp32, cast back);
    moments fp32 or int8-blockwise. Returns (params, state, metrics)."""
    lr = cfg.lr if lr is None else lr
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def update_leaf(p, g, m, v):
        g32 = g.astype(jnp.float32) * clip
        quantized = isinstance(m, dict)
        if quantized:
            m32 = _dequant_rowwise(m["q"], m["s"])
            v32 = _dequant_sqrt_rowwise(v["q"], v["s"])
        else:
            m32, v32 = m, v
        m32 = cfg.b1 * m32 + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v32 + (1 - cfg.b2) * g32 * g32
        mhat = m32 / bc1
        vhat = v32 / bc2
        p32 = p.astype(jnp.float32)
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p32
        p_new = (p32 - lr * upd).astype(p.dtype)
        if quantized:
            mq, ms = _quant_rowwise(m32)
            vq, vs = _quant_sqrt_rowwise(v32)
            return p_new, {"q": mq, "s": ms}, {"q": vq, "s": vs}
        return p_new, m32, v32

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [update_leaf(*args) for args in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_state = {"step": step, "m": new_m, "v": new_v}
    return new_p, new_state, {"grad_norm": gnorm}


# ---------- schedules ----------

def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return lr


class ReduceLROnPlateau:
    """Host-side scheduler matching the paper's training recipe
    (factor 0.8, patience 3 epochs, floor 5e-4)."""

    def __init__(self, lr: float = 1e-3, factor: float = 0.8,
                 patience: int = 3, min_lr: float = 5e-4):
        self.lr = lr
        self.factor = factor
        self.patience = patience
        self.min_lr = min_lr
        self.best = float("inf")
        self.bad_epochs = 0

    def step(self, metric: float) -> float:
        if metric < self.best - 1e-6:
            self.best = metric
            self.bad_epochs = 0
        else:
            self.bad_epochs += 1
            if self.bad_epochs > self.patience:
                self.lr = max(self.lr * self.factor, self.min_lr)
                self.bad_epochs = 0
        return self.lr
