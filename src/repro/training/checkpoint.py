"""Versioned, atomic, async-capable checkpointing.

Layout: <dir>/step_<N>/ with one .npy per flattened leaf plus a
manifest.json (step, leaf index, shapes/dtypes, tree structure, fletcher
checksums). Writes go to step_<N>.tmp and are renamed only after fsync —
a partially-written checkpoint is never visible, so a node failure
mid-save cannot corrupt the restore path (fault-tolerance requirement,
DESIGN.md §5).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

Pytree = Any


def _dtype_from_str(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _flatten_with_names(tree: Pytree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        out.append((name, leaf))
    return out


def save_checkpoint(
    directory: str,
    step: int,
    tree: Pytree,
    keep: int = 3,
    async_save: bool = False,
) -> threading.Thread | None:
    """Atomically write `tree` at `step`; prune to the newest `keep`."""
    host_tree = jax.tree.map(np.asarray, tree)

    def _write():
        final = os.path.join(directory, f"step_{step:09d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        manifest: Dict[str, Any] = {"step": step, "leaves": []}
        for i, (name, leaf) in enumerate(_flatten_with_names(host_tree)):
            fn = f"leaf_{i:05d}.npy"
            # store raw bytes: np.save can't represent ml_dtypes (bf16,
            # fp8); dtype travels in the manifest instead
            raw = np.frombuffer(
                np.ascontiguousarray(leaf).tobytes(), np.uint8
            )
            np.save(os.path.join(tmp, fn), raw)
            manifest["leaves"].append(
                {
                    "name": name,
                    "file": fn,
                    "shape": list(leaf.shape),
                    "dtype": str(leaf.dtype),
                    "crc": zlib.crc32(raw.tobytes()) & 0xFFFFFFFF,
                }
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _prune(directory, keep)

    if async_save:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _prune(directory: str, keep: int):
    steps = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str,
    template: Pytree,
    step: Optional[int] = None,
    shardings: Optional[Pytree] = None,
    verify: bool = True,
) -> Tuple[Pytree, int]:
    """Load into the template's structure; optionally device_put with the
    given shardings (resume onto a different mesh = elastic restart)."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = []
    for entry in manifest["leaves"]:
        raw = np.load(os.path.join(path, entry["file"]))
        if verify:
            crc = zlib.crc32(raw.tobytes()) & 0xFFFFFFFF
            if crc != entry["crc"]:
                raise IOError(
                    f"checksum mismatch in {entry['name']} at step {step}"
                )
        arr = raw.view(_dtype_from_str(entry["dtype"])).reshape(
            entry["shape"]
        )
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(template)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings
        )
    return tree, step
