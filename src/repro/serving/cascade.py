"""Cascaded always-on wake serving: a stage-1 detector gating the GRU.

The Sub-mW MCU KWS cascade (Cerutti et al., PAPERS.md): at serving
scale most always-on streams are silence, so a tiny first-stage
detector runs on every 16 ms feature frame and *wakes* the expensive
GRU classifier only on candidate speech. This module is the stage-1
subsystem the fused serving tick (`repro.serving.serve_loop`)
composes with ANY registered classifier backend (float / qat /
integer / delta / delta-int):

  * `CascadeConfig` — detector kind, wake/release thresholds
    (hysteresis), hangover frames, gated-tick score decay. Bound to a
    pipeline via `KWSPipelineConfig.cascade`.
  * `detector_scores` — per-frame nonnegative wake scores from the
    16-channel FV_Norm frame: an energy/VAD gate (`"energy"`) or a
    tiny trainable linear scorer (`"linear"`, a BNN-style single
    neuron fit by `fit_linear_detector`).
  * `init_state` / `gate_step` / `wake_rate` — the per-stream detector
    state machine (awake latch, hangover countdown, woken/ticks
    counters) that rides `ServerState` through donation, the jitted
    slot reset, and the ``("stream",)`` mesh like every other leaf.

Hard contract (tests/test_cascade.py, tests/test_serve_sharded.py):
both detectors produce scores >= 0, so ``wake_threshold=0``
(`CascadeConfig.always_on()`) opens the gate on every submitted tick
and the cascaded server is BIT-identical to the non-cascaded one for
every backend — the gate mask degenerates to the submitted mask and
the classifier arithmetic is untouched.

Like the ΔGRU engine, the gate is *modeled* sparsity on SPMD hardware:
the masked-out classifier work still executes under `jnp.where`, and
the energy story lives in `AcceleratorModel.duty_cycle`
(`repro.core.energy`), which composes the measured `srv.wake_rate`
with the ΔGRU `effective_mac_fraction` to predict IC µW
(benchmarks/fig_cascade_roc.py).

This module is deliberately free of serving/pipeline imports (jax
only) so `repro.core.pipeline` can host the config without a cycle.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "CascadeConfig",
    "DETECTORS",
    "detector_scores",
    "init_state",
    "gate_step",
    "wake_rate",
    "fit_linear_detector",
]

DETECTORS = ("energy", "linear")


@dataclasses.dataclass(frozen=True)
class CascadeConfig:
    """Stage-1 wake-gate configuration (hashable; closed over in jit).

    detector          "energy": mean over channels of relu(FV_Norm) —
                      silence-normalized features sit below the corpus
                      mean, so silence scores ~0 and speech positive.
                      "linear": sigmoid(fv @ linear_w + linear_b), a
                      trainable single-neuron scorer in [0, 1] (fit
                      with `fit_linear_detector`).
    wake_threshold    score >= wake_threshold turns the awake latch on.
                      Both detectors are nonnegative by construction,
                      so 0.0 means the gate is structurally always
                      open (`always_open`) — the bit-identity anchor.
    release_threshold score < release_threshold turns the latch off
                      (hysteresis band; must satisfy
                      0 <= release <= wake). None -> wake_threshold,
                      i.e. no band.
    hangover_frames   extra ticks the classifier keeps running after
                      the latch drops (VAD hangover: bridges short
                      intra-utterance pauses and lets the smoothed
                      posterior settle).
    score_decay       per-gated-tick multiplier on the smoothed
                      posterior of a stream the gate held asleep
                      (in [0, 1]; 1.0 = frozen hold). Decaying toward
                      zero ("silence") forgets a stale detection while
                      the classifier is not running.
    """

    detector: str = "energy"
    wake_threshold: float = 0.0
    release_threshold: Optional[float] = None
    hangover_frames: int = 0
    score_decay: float = 1.0
    # "linear" detector parameters — a weight per feature channel plus
    # a bias. Stored as a tuple of floats so the config stays hashable
    # (it is closed over statically by the fused tick's jit).
    linear_w: Optional[Tuple[float, ...]] = None
    linear_b: float = 0.0

    def __post_init__(self):
        if self.detector not in DETECTORS:
            raise ValueError(
                f"unknown cascade detector {self.detector!r}; "
                f"registered: {DETECTORS}"
            )
        if self.wake_threshold < 0.0:
            raise ValueError(
                "wake_threshold must be >= 0 (detector scores are "
                f"nonnegative); got {self.wake_threshold}"
            )
        if self.release_threshold is not None and not (
            0.0 <= self.release_threshold <= self.wake_threshold
        ):
            raise ValueError(
                "release_threshold must satisfy 0 <= release <= wake "
                f"({self.wake_threshold}); got {self.release_threshold}"
            )
        if self.hangover_frames < 0:
            raise ValueError(
                f"hangover_frames must be >= 0; got {self.hangover_frames}"
            )
        if not 0.0 <= self.score_decay <= 1.0:
            raise ValueError(
                f"score_decay must be in [0, 1]; got {self.score_decay}"
            )
        if self.detector == "linear":
            if self.linear_w is None:
                raise ValueError(
                    "detector='linear' needs linear_w (and linear_b); "
                    "fit them with cascade.fit_linear_detector"
                )
            object.__setattr__(
                self, "linear_w", tuple(float(w) for w in self.linear_w)
            )

    @classmethod
    def always_on(cls, **kwargs) -> "CascadeConfig":
        """A gate that is structurally always open (wake_threshold=0):
        the cascaded server is bit-identical to the plain one."""
        return cls(wake_threshold=0.0, **kwargs)

    @property
    def always_open(self) -> bool:
        """True when every submitted tick wakes the classifier: both
        detectors score >= 0, so threshold 0 never gates."""
        return self.wake_threshold <= 0.0

    @property
    def release(self) -> float:
        return (
            self.wake_threshold
            if self.release_threshold is None
            else self.release_threshold
        )


def detector_scores(fv: jnp.ndarray, config: CascadeConfig) -> jnp.ndarray:
    """Stage-1 wake scores for FV_Norm frames, shape (..., C) -> (...).

    Nonnegative for every input (the `always_open` contract):
      * "energy": mean(relu(fv)) over channels. FV_Norm is
        (x - mu) / sigma per channel, so silence — the bottom of the
        corpus log-energy range — is strongly negative and scores ~0,
        while speech lifts channels above the corpus mean.
      * "linear": sigmoid(fv @ w + b) in [0, 1].
    """
    if config.detector == "energy":
        return jnp.mean(jax.nn.relu(fv), axis=-1)
    w = jnp.asarray(config.linear_w, jnp.float32)
    b = jnp.float32(config.linear_b)
    return jax.nn.sigmoid(fv @ w + b)


def init_state(batch: int, device=None) -> Dict[str, jnp.ndarray]:
    """Fresh per-stream detector state, all (batch,) leaves.

    All-zeros is the valid fresh state (asleep, no hangover, zero
    counters) — the invariant the jitted slot reset relies on
    (`_reset_slot` writes plain zeros into the reused slot's slice).

    awake  — the hysteresis latch (score crossed wake and has not yet
             dropped below release).
    hang   — remaining hangover ticks after the latch dropped.
    woken  — ticks the gate let the classifier advance (int32).
    ticks  — submitted ticks seen (int32; wraps after ~397 days of
             16 ms ticks, like the ΔGRU column counters).
    """
    z = dict(device=device) if device is not None else {}
    return {
        "awake": jnp.zeros((batch,), bool, **z),
        "hang": jnp.zeros((batch,), jnp.int32, **z),
        "woken": jnp.zeros((batch,), jnp.int32, **z),
        "ticks": jnp.zeros((batch,), jnp.int32, **z),
    }


def gate_step(
    state: Dict[str, jnp.ndarray],
    score: jnp.ndarray,
    config: CascadeConfig,
):
    """Advance the detector state machine one tick; return (state, gate).

    gate (bool, per stream) is True where the classifier runs this
    tick: the awake latch is on, or the hangover countdown is still
    draining. The caller applies its submitted mask on top (an idle
    stream's detector state must not advance — `masked_select`).
    """
    above = score >= config.wake_threshold
    below = score < config.release
    # hysteresis latch: set on wake crossing, hold until release
    # crossing (release == wake degenerates to awake = above)
    awake = jnp.logical_or(
        above, jnp.logical_and(state["awake"], jnp.logical_not(below))
    )
    gate = jnp.logical_or(awake, state["hang"] > 0)
    hang = jnp.where(
        awake,
        jnp.int32(config.hangover_frames),
        jnp.maximum(state["hang"] - 1, 0),
    )
    new_state = {
        "awake": awake,
        "hang": hang,
        "woken": state["woken"] + gate.astype(jnp.int32),
        "ticks": state["ticks"] + jnp.int32(1),
    }
    return new_state, gate


def wake_rate(state: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Fraction of submitted ticks the gate woke the classifier,
    per stream; 1.0 for slots that have seen no traffic (mirroring
    `srv.sparsity`: "no evidence" reads as dense)."""
    ticks = state["ticks"].astype(jnp.float32)
    woken = state["woken"].astype(jnp.float32)
    return jnp.where(state["ticks"] > 0, woken / jnp.maximum(ticks, 1.0), 1.0)


def fit_linear_detector(
    speech_fv,
    silence_fv,
    steps: int = 200,
    lr: float = 0.5,
) -> Tuple[Tuple[float, ...], float]:
    """Fit the "linear" detector: logistic regression speech-vs-silence
    on FV_Norm frames.

    speech_fv / silence_fv: (..., C) frame stacks (any leading shape);
    returns (linear_w tuple, linear_b) ready for `CascadeConfig`.
    Full-batch gradient descent — the model is C+1 scalars, so this is
    a few thousand FLOPs per step.
    """
    speech = jnp.asarray(speech_fv, jnp.float32)
    silence = jnp.asarray(silence_fv, jnp.float32)
    n_ch = speech.shape[-1]
    if silence.shape[-1] != n_ch:
        raise ValueError(
            f"channel mismatch: speech C={n_ch}, silence C={silence.shape[-1]}"
        )
    xs = jnp.concatenate(
        [speech.reshape(-1, n_ch), silence.reshape(-1, n_ch)]
    )
    ys = jnp.concatenate(
        [
            jnp.ones((speech.reshape(-1, n_ch).shape[0],), jnp.float32),
            jnp.zeros((silence.reshape(-1, n_ch).shape[0],), jnp.float32),
        ]
    )

    def loss(wb):
        w, b = wb
        z = xs @ w + b
        # binary cross-entropy on logits: softplus(z) - y*z
        return jnp.mean(jax.nn.softplus(z) - ys * z)

    grad = jax.jit(jax.grad(loss))
    w = jnp.zeros((n_ch,), jnp.float32)
    b = jnp.float32(0.0)
    for _ in range(steps):
        gw, gb = grad((w, b))
        w = w - lr * gw
        b = b - lr * gb
    return tuple(float(v) for v in np.asarray(w)), float(b)
