"""Serving observability: metrics registry, trace spans, event journal.

The serving stack's telemetry used to be fragmented — `srv.sparsity` /
`srv.wake_rate` were ad-hoc properties, per-tick latency existed only
inside the load generators' private `perf_counter` lists, and
autoscaler / resize / shard-loss decisions left no record at all. This
module is the one process-local home for all of it:

  * `MetricsRegistry` — get-or-create families of monotonic `Counter`s,
    `Gauge`s, and fixed-bucket `Histogram`s (default bucket edges keyed
    on the paper's 16 ms tick budget, `DEFAULT_MS_BUCKETS`), each
    optionally labeled. `snapshot()` returns one JSON-able dict;
    `render_prometheus()` emits the Prometheus text exposition format.
  * `EventJournal` — an append-only structured event log (`append(kind,
    **fields)` stamps a monotonically increasing ``seq`` and the
    registry clock). Bounded drop-oldest capacity; ``seq`` keeps
    counting even after old events are trimmed, so consumers can detect
    the gap. `StreamingKWSServer` journals compiles / retraces /
    resizes / shard losses here, the `Autoscaler` every capacity
    decision with its reason.
  * `TickTrace` — per-tick span timestamps: named marks ("stage",
    "commit", "dispatch", "retire") recorded by the async ingress as a
    tick moves through the pipeline. Completed traces live in a bounded
    ring (`registry.traces`); `span_percentiles` rolls consecutive-mark
    durations into p50/p99 summaries (the numbers
    `benchmarks/serve_load.py` records per pipelined row).

Everything is host-side Python: no device code, no forced syncs, no
change to any tick's operands or dispatch order — which is what makes a
metrics-enabled `StreamingKWSServer` BIT-identical to a metrics-off one
(tests/test_metrics.py proves it for every classifier backend,
cascaded, async, and on the emulated 8-device mesh). The registry is
single-process and not thread-safe, matching the single-threaded
serving loop it instruments.
"""

from __future__ import annotations

import bisect
import collections
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

__all__ = [
    "TICK_BUDGET_MS",
    "DEFAULT_MS_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "EventJournal",
    "TickTrace",
    "MetricsRegistry",
    "span_percentiles",
]

# the paper's frame shift: one serving tick every 16 ms — the latency
# budget every histogram is read against
TICK_BUDGET_MS = 16.0

# default histogram bucket upper edges (milliseconds), keyed on the
# tick budget: sub-budget edges resolve where inside the 16 ms window a
# tick lands, the 16.0 edge IS the budget (SLO breaches are everything
# above it), and the coarse tail catches compile spikes
DEFAULT_MS_BUCKETS = (
    0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 12.0, 16.0,
    24.0, 32.0, 64.0, 128.0, 256.0, 1024.0,
)


class Counter:
    """Monotonic counter. `inc` rejects negative increments — a counter
    that can go down is a gauge wearing the wrong name."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter increment must be >= 0, got {n}")
        self.value += n


class Gauge:
    """Point-in-time value (occupancy, queue depth, capacity)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-bucket histogram with exact-percentile sample retention.

    ``buckets`` are ascending upper edges with Prometheus ``le``
    semantics: an observation lands in the first bucket whose edge is
    >= the value (an observation exactly ON an edge belongs to that
    edge's bucket), and everything above the last edge lands in the
    implicit +Inf bucket. `counts` holds per-bucket (NOT cumulative)
    counts, len(buckets) + 1 long.

    Besides the buckets, the last ``keep_samples`` raw observations are
    retained (drop-oldest ring) so `percentiles()` is exact over the
    retained window — the serving benchmarks read their p50/p99 from
    here instead of keeping private latency lists.
    """

    __slots__ = ("buckets", "counts", "sum", "count", "samples")

    def __init__(self, buckets: Iterable[float] = DEFAULT_MS_BUCKETS,
                 keep_samples: int = 8192):
        edges = tuple(float(b) for b in buckets)
        if not edges or any(
            b >= a for a, b in zip(edges[1:], edges[:-1])
        ):
            raise ValueError(
                f"buckets must be non-empty and strictly ascending; "
                f"got {edges}"
            )
        self.buckets = edges
        self.counts = [0] * (len(edges) + 1)
        self.sum = 0.0
        self.count = 0
        self.samples: collections.deque = collections.deque(
            maxlen=keep_samples
        )

    def observe(self, v: float) -> None:
        v = float(v)
        # bisect_left: v == edge -> that edge's bucket (le includes ==)
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.sum += v
        self.count += 1
        self.samples.append(v)

    @property
    def last(self) -> Optional[float]:
        """Most recent observation (None before the first)."""
        return self.samples[-1] if self.samples else None

    def percentiles(self) -> Optional[Dict[str, float]]:
        """Exact p50/p99/mean/max over the retained samples (None when
        empty). Exactness holds for the retained window; past
        ``keep_samples`` observations the window slides."""
        if not self.samples:
            return None
        s = sorted(self.samples)
        n = len(s)

        def q(p):
            return s[min(n - 1, int(round(p * (n - 1))))]

        return {
            "p50": float(q(0.50)),
            "p99": float(q(0.99)),
            "mean": float(self.sum / self.count) if self.count == n
            else float(sum(s) / n),
            "max": float(s[-1]),
        }


class EventJournal:
    """Append-only structured event log.

    Every event gets a monotonically increasing ``seq`` and the
    registry clock's timestamp, then the caller's fields verbatim (keep
    them JSON-able — ints, floats, strings, lists). Capacity is a
    drop-oldest bound; ``seq`` keeps increasing across trims, so a
    reader that sees seq jump knows events were dropped, never
    reordered.
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 capacity: int = 4096):
        self.clock = clock
        self.events: collections.deque = collections.deque(
            maxlen=capacity
        )
        self._seq = 0

    def append(self, kind: str, **fields: Any) -> Dict[str, Any]:
        ev = {"seq": self._seq, "t": self.clock(), "kind": kind,
              **fields}
        self._seq += 1
        self.events.append(ev)
        return ev

    def __len__(self) -> int:
        return len(self.events)

    def snapshot(self) -> List[Dict[str, Any]]:
        return [dict(ev) for ev in self.events]


class TickTrace:
    """Named span timestamps of one tick's trip through the pipeline.

    Marks record in insertion order (the order the pipeline reaches
    them: stage -> commit -> dispatch -> retire); consecutive marks
    define the spans `span_percentiles` aggregates.
    """

    __slots__ = ("id", "marks", "_clock")

    def __init__(self, trace_id: Any, clock: Callable[[], float]):
        self.id = trace_id
        self.marks: Dict[str, float] = {}
        self._clock = clock

    def mark(self, name: str, t: Optional[float] = None) -> None:
        self.marks[name] = self._clock() if t is None else float(t)


def span_percentiles(traces: Iterable[TickTrace]
                     ) -> Dict[str, Dict[str, float]]:
    """Roll per-tick traces into per-span duration percentiles.

    For each trace, consecutive marks (insertion order) become spans
    named ``"<a>_to_<b>"``, plus ``"total"`` (first mark to last); the
    result maps span name -> {count, p50_ms, p99_ms, mean_ms} over
    every trace that carried that span. Durations are milliseconds.
    """
    durs: Dict[str, List[float]] = {}
    for tr in traces:
        items = list(tr.marks.items())
        if len(items) < 2:
            continue
        for (a, ta), (b, tb) in zip(items, items[1:]):
            durs.setdefault(f"{a}_to_{b}", []).append((tb - ta) * 1e3)
        durs.setdefault("total", []).append(
            (items[-1][1] - items[0][1]) * 1e3
        )
    out = {}
    for name, vals in durs.items():
        s = sorted(vals)
        n = len(s)

        def q(p, s=s, n=n):
            return s[min(n - 1, int(round(p * (n - 1))))]

        out[name] = {
            "count": n,
            "p50_ms": float(q(0.50)),
            "p99_ms": float(q(0.99)),
            "mean_ms": float(sum(s) / n),
        }
    return out


class _Family:
    """One metric name: its kind, help text, and labeled children."""

    __slots__ = ("name", "kind", "help", "children", "buckets",
                 "keep_samples")

    def __init__(self, name, kind, help_text, buckets=None,
                 keep_samples=None):
        self.name = name
        self.kind = kind
        self.help = help_text
        self.buckets = buckets
        self.keep_samples = keep_samples
        self.children: Dict[Tuple[Tuple[str, str], ...], Any] = {}

    def child(self, labels: Dict[str, Any]):
        key = tuple(sorted((str(k), str(v)) for k, v in labels.items()))
        got = self.children.get(key)
        if got is None:
            if self.kind == "counter":
                got = Counter()
            elif self.kind == "gauge":
                got = Gauge()
            else:
                got = Histogram(self.buckets, self.keep_samples)
            self.children[key] = got
        return got


def _escape_label(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r"\"").replace(
        "\n", r"\n"
    )


def _labels_str(key: Tuple[Tuple[str, str], ...]) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{_escape_label(v)}"' for k, v in key)
    return "{" + inner + "}"


class MetricsRegistry:
    """Process-local metric families + journal + trace ring.

    `counter` / `gauge` / `histogram` get-or-create: the first call for
    a name fixes its kind (and, for histograms, its buckets); a later
    call with the same name returns the existing family (extra label
    sets create new children) and a kind conflict raises. ``clock`` is
    injectable for deterministic tests and stamps the journal, traces,
    and nothing else — metric values are whatever callers observe.
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 journal_capacity: int = 4096,
                 trace_capacity: int = 4096,
                 keep_samples: int = 8192):
        self.clock = clock
        self.keep_samples = keep_samples
        self.journal = EventJournal(clock=clock,
                                    capacity=journal_capacity)
        self.traces: collections.deque = collections.deque(
            maxlen=trace_capacity
        )
        self._families: Dict[str, _Family] = {}

    # ---- metric families ----

    def _family(self, name, kind, help_text, buckets=None) -> _Family:
        fam = self._families.get(name)
        if fam is None:
            fam = _Family(
                name, kind, help_text, buckets=buckets,
                keep_samples=self.keep_samples,
            )
            self._families[name] = fam
        elif fam.kind != kind:
            raise ValueError(
                f"metric {name!r} already registered as {fam.kind}, "
                f"requested {kind}"
            )
        return fam

    def counter(self, name: str, help_text: str = "",
                **labels: Any) -> Counter:
        return self._family(name, "counter", help_text).child(labels)

    def gauge(self, name: str, help_text: str = "",
              **labels: Any) -> Gauge:
        return self._family(name, "gauge", help_text).child(labels)

    def histogram(self, name: str, help_text: str = "",
                  buckets: Iterable[float] = DEFAULT_MS_BUCKETS,
                  **labels: Any) -> Histogram:
        return self._family(
            name, "histogram", help_text, buckets=tuple(buckets)
        ).child(labels)

    # ---- traces ----

    def trace(self, trace_id: Any = None) -> TickTrace:
        """New per-tick trace, appended to the bounded ring."""
        tr = TickTrace(trace_id, self.clock)
        self.traces.append(tr)
        return tr

    # ---- export ----

    def snapshot(self) -> Dict[str, Any]:
        """One JSON-able dict of everything: metric values (histograms
        with per-bucket counts AND exact percentiles over the retained
        samples; raw samples stay out — they are bounded but big),
        journal events, and per-span duration rollups of the trace
        ring. `json.loads(json.dumps(snapshot()))` round-trips equal.
        """
        counters, gauges, hists = [], [], []
        for fam in self._families.values():
            for key, child in fam.children.items():
                entry = {
                    "name": fam.name,
                    "help": fam.help,
                    "labels": {k: v for k, v in key},
                }
                if fam.kind == "counter":
                    counters.append({**entry, "value": child.value})
                elif fam.kind == "gauge":
                    gauges.append({**entry, "value": child.value})
                else:
                    hists.append({
                        **entry,
                        "buckets": [float(b) for b in child.buckets],
                        "counts": list(child.counts),
                        "sum": float(child.sum),
                        "count": int(child.count),
                        "percentiles": child.percentiles(),
                    })
        return {
            "counters": counters,
            "gauges": gauges,
            "histograms": hists,
            "journal": self.journal.snapshot(),
            "spans": span_percentiles(self.traces),
        }

    def render_prometheus(self) -> str:
        """Prometheus text exposition (v0.0.4) of every metric family.

        Histograms render cumulative ``_bucket{le=...}`` series (the
        +Inf bucket equals ``_count``) plus ``_sum`` / ``_count``;
        journal events and traces are not metrics and do not render.
        """
        lines: List[str] = []
        for fam in self._families.values():
            if fam.help:
                lines.append(f"# HELP {fam.name} {fam.help}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            for key, child in fam.children.items():
                ls = _labels_str(key)
                if fam.kind in ("counter", "gauge"):
                    lines.append(f"{fam.name}{ls} {child.value}")
                    continue
                cum = 0
                for edge, c in zip(child.buckets, child.counts):
                    cum += c
                    le = _labels_str(key + (("le", repr(float(edge))),))
                    lines.append(f"{fam.name}_bucket{le} {cum}")
                inf = _labels_str(key + (("le", "+Inf"),))
                lines.append(f"{fam.name}_bucket{inf} {child.count}")
                lines.append(f"{fam.name}_sum{ls} {child.sum}")
                lines.append(f"{fam.name}_count{ls} {child.count}")
        return "\n".join(lines) + "\n"
