"""Serving-side parameter quantization.

Two independent consumers share this module:

  1. **KWS classifier (the paper's datapath, primary).**
     `quantize_classifier` converts the float/QAT GRU-FC parameters of
     `repro.core.gru` into a `repro.core.gru_int.QuantizedClassifier`:
     int8 weight codes, frac-15 accumulator-resident bias codes — the
     ~24 KB WMEM image the IC actually stores (Sections II, III-E).
     The integer engine evaluated on these codes is bit-identical to
     the QAT fake-quant forward (tests/test_classifier_int.py); the
     conversion uses the same round-to-nearest-even the QAT fake-quant
     applies, so quantize -> dequantize lands exactly on the values the
     QAT forward already sees.

  2. **LM expert banks (legacy, from the framework-scale LM side).**
     `quantize_expert_params` / `quantize_expert_shapes` store MoE
     expert FFN banks as int8 codes + one fp32 absmax scale per
     last-dim row, dequantized on the fly inside the expert matmuls to
     halve decode-step HBM traffic. Used by the pjit'd LM serving
     programs of `repro.serving.serve_loop` (`serve_quant`).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.gru import GRUConfig
from repro.core.gru_int import QuantizedClassifier

__all__ = [
    "quantize_classifier",
    "dequant_weight",
    "quantize_expert_params",
    "quantize_expert_shapes",
]


# --------------------------------------------------------------------------
# KWS classifier -> integer codes (the paper's WMEM image)
# --------------------------------------------------------------------------

def _w_codes(w: jnp.ndarray) -> jnp.ndarray:
    """Float weights -> int8 codes on the paper's fixed frac-7 grid.

    Identical rounding to `quant.fake_quant(w, WEIGHT_INT8)`, so the
    integer engine consumes exactly the weights the QAT forward sees.
    """
    return quant.quantize_int(w, quant.WEIGHT_INT8, jnp.int8)


def _b_codes(b: jnp.ndarray) -> jnp.ndarray:
    """Float biases -> int32 codes at the accumulator scale (frac 15)."""
    return quant.quantize_int(b, quant.BIAS_Q8_15, jnp.int32)


def quantize_classifier(params: Any, config: GRUConfig) -> QuantizedClassifier:
    """Float/QAT GRU-FC params -> `QuantizedClassifier` integer codes.

    ``params`` is the `repro.core.gru.init_gru_classifier` dict (or any
    trained instance of it); ``config`` is the `GRUConfig` the params
    were built for (checked against the param geometry — a mismatch
    would otherwise surface as silently wrong codes). The result is a
    pytree of int8/int32 buffers only — safe to donate through the
    fused serving tick and to keep device-resident.
    """
    if len(params["gru"]) != config.num_layers:
        raise ValueError(
            f"params have {len(params['gru'])} GRU layers, config says "
            f"{config.num_layers}"
        )
    if params["gru"][0]["w_h"].shape[0] != config.hidden_dim:
        raise ValueError(
            f"params hidden_dim {params['gru'][0]['w_h'].shape[0]} != "
            f"config.hidden_dim {config.hidden_dim}"
        )
    gru = tuple(
        {
            "w_i": _w_codes(layer["w_i"]),
            "w_h": _w_codes(layer["w_h"]),
            "b_i": _b_codes(layer["b_i"]),
            "b_h": _b_codes(layer["b_h"]),
        }
        for layer in params["gru"]
    )
    return QuantizedClassifier(
        gru=gru,
        fc_w=_w_codes(params["fc"]["w"]),
        fc_b=_b_codes(params["fc"]["b"]),
    )


# --------------------------------------------------------------------------
# LM MoE expert banks -> int8 + absmax row scales (legacy LM serving)
# --------------------------------------------------------------------------

_QUANT_NAMES = ("w_up", "w_gate", "w_down")


def _quant_leaf(x: jnp.ndarray):
    x32 = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x32), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "s": scale.astype(jnp.float32)}


def dequant_weight(w, dtype):
    """Transparent accessor used by the expert matmuls."""
    if isinstance(w, dict) and "q" in w:
        return (w["q"].astype(jnp.float32) * w["s"]).astype(dtype)
    return w.astype(dtype)


def quantize_expert_params(params: Any) -> Any:
    """Quantize MoE expert banks in a param tree (serving only)."""

    def walk(node, under_moe=False):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if under_moe and k in _QUANT_NAMES and not isinstance(v, dict):
                    out[k] = _quant_leaf(v)
                else:
                    out[k] = walk(
                        v, (under_moe or k == "moe") and k != "shared"
                    )
            return out
        if isinstance(node, list):
            return [walk(v, under_moe) for v in node]
        return node

    return walk(params)


def quantize_expert_shapes(params_shape: Any) -> Any:
    """Abstract (ShapeDtypeStruct) version for dry-run lowering."""

    def walk(node, under_moe=False):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if under_moe and k in _QUANT_NAMES and not isinstance(v, dict):
                    out[k] = {
                        "q": jax.ShapeDtypeStruct(v.shape, jnp.int8),
                        "s": jax.ShapeDtypeStruct(
                            v.shape[:-1] + (1,), jnp.float32
                        ),
                    }
                else:
                    out[k] = walk(
                        v, (under_moe or k == "moe") and k != "shared"
                    )
            return out
        if isinstance(node, list):
            return [walk(v, under_moe) for v in node]
        return node

    return walk(params_shape)
