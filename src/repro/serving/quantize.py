"""Serving-side KWS classifier quantization (the paper's WMEM image).

`quantize_classifier` converts the float/QAT GRU-FC parameters of
`repro.core.gru` into a `repro.core.gru_int.QuantizedClassifier`:
int8 weight codes, frac-15 accumulator-resident bias codes — the
~24 KB WMEM image the IC actually stores (Sections II, III-E).
The integer engine evaluated on these codes is bit-identical to
the QAT fake-quant forward (tests/test_classifier_int.py); the
conversion uses the same round-to-nearest-even the QAT fake-quant
applies, so quantize -> dequantize lands exactly on the values the
QAT forward already sees. The ΔGRU code-domain backend ("delta-int",
`repro.core.gru_delta`) consumes the same codes.

(The LM-side MoE expert-bank quantizer that used to share this module
now lives with its consumer: `repro.models.moe_quant`.)
"""

from __future__ import annotations

from typing import Any

import jax.numpy as jnp

from repro.core import quant
from repro.core.gru import GRUConfig
from repro.core.gru_int import QuantizedClassifier

__all__ = [
    "quantize_classifier",
]


def _w_codes(w: jnp.ndarray) -> jnp.ndarray:
    """Float weights -> int8 codes on the paper's fixed frac-7 grid.

    Identical rounding to `quant.fake_quant(w, WEIGHT_INT8)`, so the
    integer engine consumes exactly the weights the QAT forward sees.
    """
    return quant.quantize_int(w, quant.WEIGHT_INT8, jnp.int8)


def _b_codes(b: jnp.ndarray) -> jnp.ndarray:
    """Float biases -> int32 codes at the accumulator scale (frac 15)."""
    return quant.quantize_int(b, quant.BIAS_Q8_15, jnp.int32)


def quantize_classifier(params: Any, config: GRUConfig) -> QuantizedClassifier:
    """Float/QAT GRU-FC params -> `QuantizedClassifier` integer codes.

    ``params`` is the `repro.core.gru.init_gru_classifier` dict (or any
    trained instance of it); ``config`` is the `GRUConfig` the params
    were built for (checked against the param geometry — a mismatch
    would otherwise surface as silently wrong codes). The result is a
    pytree of int8/int32 buffers only — safe to donate through the
    fused serving tick and to keep device-resident.
    """
    if len(params["gru"]) != config.num_layers:
        raise ValueError(
            f"params have {len(params['gru'])} GRU layers, config says "
            f"{config.num_layers}"
        )
    if params["gru"][0]["w_h"].shape[0] != config.hidden_dim:
        raise ValueError(
            f"params hidden_dim {params['gru'][0]['w_h'].shape[0]} != "
            f"config.hidden_dim {config.hidden_dim}"
        )
    gru = tuple(
        {
            "w_i": _w_codes(layer["w_i"]),
            "w_h": _w_codes(layer["w_h"]),
            "b_i": _b_codes(layer["b_i"]),
            "b_h": _b_codes(layer["b_h"]),
        }
        for layer in params["gru"]
    )
    return QuantizedClassifier(
        gru=gru,
        fc_w=_w_codes(params["fc"]["w"]),
        fc_b=_b_codes(params["fc"]["b"]),
    )
