"""Serving paths.

LM side: `lower_prefill` / `lower_decode_step` build the pjit'd serving
programs the dry-run compiles (batch of requests, KV cache / recurrent
state sharded per distributed/sharding.py).

KWS side: `StreamingKWSServer` — the deployment shape of the paper's
chip: N concurrent audio streams, one tick per 16 ms frame, a batched
weights-resident GRU step, per-stream argmax + exponential score
smoothing. Each tick accepts, per stream, EITHER a precomputed FV_Norm
frame (C,) OR a raw 16 ms audio hop (`pipeline.chunk_samples` samples at
fs_audio); raw audio is pushed through the pipeline's registered
`FeatureFrontend` (software / hardware-sim / Pallas TDC) with per-stream
filter + SRO-phase carry, so the server is end-to-end audio-in,
posteriors-out. The GRU step itself runs through the pipeline's
registered `ClassifierBackend` (float / qat / integer / delta /
delta-int): with ``classifier="integer"`` the tick consumes int8
weight codes and int32 Q6.8 hidden-state codes — the IC's
WMEM-resident arithmetic, bit-identical to the QAT path; with the
ΔGRU backends ("delta"/"delta-int", `repro.core.gru_delta`) each
slot's state additionally carries last-transmitted memories, partial-
sum accumulators, and skipped/total MAC counters, and the server
exposes the measured temporal sparsity as `srv.sparsity` (per-stream
effective-MAC fraction). Orthogonally, a cascaded pipeline
(`KWSPipelineConfig.cascade`, `repro.serving.cascade`) puts a stage-1
always-on wake detector inside the same tick: an energy/linear gate
on the feature frame wakes the full classifier only on candidate
speech (frozen-state hold + optional score decay while gated), with
the measured duty cycle exposed as `srv.wake_rate`; an always-open
gate (`CascadeConfig.always_on()`) is bit-identical to no cascade for
every backend. This is the serve-side example driver
(examples/serve_streaming.py).

The whole per-tick device program is ONE fused jit (`_fused_tick`):
frontend feature extraction, the batched GRU step, softmax, and
exponential score smoothing run back-to-back on-device over donated
state buffers, under a per-stream submitted mask. State — GRU hidden
states, frontend carry, smoothed scores — lives in a single
`ServerState` pytree; an idle stream's slice of every buffer is
bit-identical across a tick it did not submit to (temporal sparsity,
the DeltaKWS deployment contract). `open_stream`/`close_stream` recycle
slots from a free list, zeroing only the reused slot, and
`StreamingKWSServer.run` replays buffered audio through a `lax.scan`
over the same tick body for offline-throughput serving.

Stream-parallel sharding: slots are computationally independent (no
cross-slot reduction anywhere in the tick), so the slot axis shards
block-wise over a 1-D ``("stream",)`` device mesh
(`repro.distributed.sharding.stream_mesh`). With ``devices=N`` (or an
explicit ``mesh=``) every `ServerState` leaf, input slab, and submitted
mask carries a `NamedSharding` over its slot axis while classifier
params and frontend calibration replicate; the fused tick, the scanned
replay, and the jitted slot reset each lower to one SPMD program with
the sharded state donated across calls. Slot assignment doubles as
device placement, handled by `repro.serving.autoscale.StreamRouter`
(round-robin fill keeps shards balanced). Per-slot math is unchanged by
the partition, so sharded serving is BIT-identical to the single-device
server (tests/test_serve_sharded.py proves it on an emulated CPU mesh).
With one visible device the server falls back to exactly the
pre-sharding single-device program.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.kernels.tick_fused import tick_fused, tick_reference
from repro.serving import cascade as cascade_lib

from repro.distributed.sharding import (
    STREAM_AXIS,
    ShardingRules,
    batch_specs,
    cache_specs,
    make_mesh_context,
    named,
    param_specs,
    replicated_shardings,
    stream_mesh,
    stream_shardings,
    surviving_devices,
)
from repro.models.registry import get_backbone
from repro.serving.autoscale import StreamRouter
from repro.serving.ingress import TickHandle
from repro.serving.metrics import MetricsRegistry

Pytree = Any


def serve_batch_shape(arch_cfg, shape_spec):
    """ShapeDtypeStructs for one serve step of the given input shape."""
    b = shape_spec.global_batch
    if arch_cfg.frontend == "embedding":
        return {
            "embeddings": jax.ShapeDtypeStruct(
                (b, 1, arch_cfg.d_model), arch_cfg.activation_dtype
            )
        }
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def prefill_batch_shape(arch_cfg, shape_spec):
    b, s = shape_spec.global_batch, shape_spec.seq_len
    if arch_cfg.frontend == "embedding":
        return {
            "embeddings": jax.ShapeDtypeStruct(
                (b, s, arch_cfg.d_model), arch_cfg.activation_dtype
            )
        }
    return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}


def lower_decode_step(arch_cfg, rules: ShardingRules, shape_spec):
    """Abstract lower of one decode step at (batch, cache_len) scale."""
    backbone = get_backbone(arch_cfg)
    mesh_ctx = make_mesh_context(rules)
    b, s = shape_spec.global_batch, shape_spec.seq_len
    params_shape = jax.eval_shape(
        lambda k: backbone.init_params(k, arch_cfg, mesh_ctx),
        jax.random.PRNGKey(0),
    )
    if getattr(arch_cfg, "serve_quant", False):
        from repro.models.moe_quant import quantize_expert_shapes

        params_shape = quantize_expert_shapes(params_shape)
    cache_shape = jax.eval_shape(
        lambda: backbone.init_cache(arch_cfg, b, s, mesh_ctx)
    )
    batch_shape = serve_batch_shape(arch_cfg, shape_spec)
    pspecs = param_specs(params_shape, rules)
    cspecs = cache_specs(cache_shape, rules, b)
    bspecs = batch_specs(batch_shape, rules)

    def step(params, cache, cache_len, batch):
        return backbone.decode_step(
            params, cache, cache_len, batch, arch_cfg, mesh_ctx
        )

    # the updated cache keeps the input cache's sharding (donated buffers)
    out_cache_shape = jax.eval_shape(
        step,
        params_shape,
        cache_shape,
        jax.ShapeDtypeStruct((), jnp.int32),
        batch_shape,
    )[1]
    out_cspecs = cache_specs(out_cache_shape, rules, b)
    with rules.mesh:
        lowered = jax.jit(
            step,
            in_shardings=(
                named(pspecs, rules.mesh),
                named(cspecs, rules.mesh),
                None,
                named(bspecs, rules.mesh),
            ),
            out_shardings=(None, named(out_cspecs, rules.mesh)),
            donate_argnums=(1,),
        ).lower(
            params_shape,
            cache_shape,
            jax.ShapeDtypeStruct((), jnp.int32),
            batch_shape,
        )
    return lowered, params_shape, cache_shape


def lower_prefill(arch_cfg, rules: ShardingRules, shape_spec):
    backbone = get_backbone(arch_cfg)
    mesh_ctx = make_mesh_context(rules)
    b, s = shape_spec.global_batch, shape_spec.seq_len
    params_shape = jax.eval_shape(
        lambda k: backbone.init_params(k, arch_cfg, mesh_ctx),
        jax.random.PRNGKey(0),
    )
    batch_shape = prefill_batch_shape(arch_cfg, shape_spec)
    pspecs = param_specs(params_shape, rules)
    bspecs = batch_specs(batch_shape, rules)

    def step(params, batch):
        return backbone.prefill(params, batch, arch_cfg, mesh_ctx)

    out_cache_shape = jax.eval_shape(step, params_shape, batch_shape)[1]
    out_cspecs = cache_specs(out_cache_shape, rules, b)
    with rules.mesh:
        lowered = jax.jit(
            step,
            in_shardings=(
                named(pspecs, rules.mesh),
                named(bspecs, rules.mesh),
            ),
            out_shardings=(None, named(out_cspecs, rules.mesh)),
        ).lower(params_shape, batch_shape)
    return lowered, params_shape


# --------------------------------------------------------------------------
# Streaming KWS serving (the paper's own deployment shape)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServerState:
    """All per-slot device state of a `StreamingKWSServer`, as one pytree.

    gru    — per-layer classifier state, owned by the backend: a
             (max_streams, H) float32 hidden state per layer for
             float/qat, int32 Q6.8 codes for "integer", and for the
             ΔGRU backends a per-layer dict {h, x_ref, h_ref, acc_x,
             acc_h, skipped, total} of (max_streams, ...) leaves
             (masking, donation, slot resets, and the stream mesh are
             structure- and dtype-agnostic; all-zeros is every
             backend's valid fresh state).
    carry  — frontend streaming carry (filter / SRO-phase state), a dict
             of (max_streams, ...) arrays from `streaming_features_init`.
    scores — exponentially smoothed posteriors, (max_streams, K).
    det    — stage-1 wake-gate state for a cascaded pipeline
             (`repro.serving.cascade.init_state`: per-stream awake
             latch, hangover countdown, woken/ticks counters, all
             (max_streams,) leaves; all-zeros is the valid fresh
             state). None when `pipeline.config.cascade` is None —
             a None leaf vanishes from the pytree, so a non-cascaded
             server keeps the exact pre-cascade state structure and
             device programs.

    The pytree crosses jit as a single donated argument: every tick
    consumes the old state buffers and writes the new ones in place
    (donation), so steady-state serving allocates nothing per tick.
    """

    gru: Tuple[jnp.ndarray, ...]
    carry: Any
    scores: jnp.ndarray
    det: Any = None


try:
    jax.tree_util.register_dataclass(
        ServerState,
        data_fields=["gru", "carry", "scores", "det"],
        meta_fields=[],
    )
except (AttributeError, TypeError):  # very old jax — manual fallback
    jax.tree_util.register_pytree_node(
        ServerState,
        lambda s: ((s.gru, s.carry, s.scores, s.det), None),
        lambda _, xs: ServerState(*xs),
    )


# kept importable for API compatibility with the pre-fused server
@dataclasses.dataclass
class StreamState:
    stream_id: int
    scores: Optional[np.ndarray] = None  # smoothed class scores


# tick_impl -> the kernel layer's dispatch tier (ISSUE: the serving API
# speaks deployment names, the kernel layer speaks tiers)
_TICK_IMPLS = ("auto", "xla", "fused-pallas", "fused-interpret")
_TICK_DISPATCH = {
    "xla": "xla", "fused-pallas": "pallas", "fused-interpret": "interpret",
}


def _fused_tick(pipeline, raw_audio, params, state: ServerState, inp,
                mask, frontend_state, smoothing, *, tick_impl="xla",
                mesh=None):
    """One fully fused serving tick, traced as a single device program.

    The tick MATH — frontend feature frame, stage-1 cascade wake gate,
    classifier step, softmax, smoothing, masked state advance — lives
    in `repro.kernels.tick_fused.tick_reference` (moved there verbatim
    so the megakernel can re-run it per stream block); this wrapper
    owns only the `ServerState` packing and the implementation choice:

      tick_impl="xla"             one fused XLA program (the default
                                  off-TPU; exactly the pre-kernel tick)
      tick_impl="fused-pallas"    the whole tick as ONE `pallas_call`
                                  over stream blocks with the ΔGRU
                                  gather path (TPU)
      tick_impl="fused-interpret" the same megakernel body under the
                                  Pallas interpreter (CPU-testable)

    All three are bit-identical for every classifier backend (tests/
    test_tick_fused.py). ``mesh`` threads the stream mesh to the kernel
    tiers, whose `pallas_call` GSPMD cannot partition — the kernel
    wraps itself in a `shard_map` so each device still runs one kernel
    on its shard-local slab.
    """
    state4 = (state.gru, state.carry, state.scores, state.det)
    if tick_impl == "xla":
        (gru, carry, scores, det), out_scores, top = tick_reference(
            pipeline, raw_audio, params, state4, inp, mask,
            frontend_state, smoothing,
        )
    else:
        (gru, carry, scores, det), out_scores, top = tick_fused(
            pipeline, raw_audio, params, state4, inp, mask,
            frontend_state, smoothing,
            dispatch=_TICK_DISPATCH[tick_impl], mesh=mesh,
        )
    return (
        ServerState(gru=gru, carry=carry, scores=scores, det=det),
        out_scores,
        top,
    )


def _reset_slot(state: ServerState, slot) -> ServerState:
    """Zero one slot's slice of every state buffer (slot is traced, so
    open/close never recompiles). The zero is written in each leaf's
    own dtype — the cascade's awake latch is a bool leaf, and scatter
    of a literal int into bool is deprecated."""
    return jax.tree_util.tree_map(
        lambda t: t.at[slot].set(jnp.zeros((), t.dtype)), state
    )


class StreamingKWSServer:
    """Batched frame-synchronous KWS over N concurrent audio streams.

    Each frame tick: callers push, per active stream, either one FV_Norm
    (C,) or one raw 16 ms audio hop (`pipeline.chunk_samples` samples at
    fs_audio) — the kinds may not be mixed within one tick. The whole
    tick is one jit-compiled program over donated `ServerState` buffers:
    frontend (for raw audio, with per-stream filter/SRO carry), ONE
    batched GRU step for all slots (the accelerator's Fig. 4 timing,
    vectorized across streams), softmax, and exponential score smoothing
    — no per-stream Python loop, no host-side numpy math. Streams that
    did not submit a frame this tick are masked out of every state
    update (frontend carry, GRU hidden state, scores).

    Slot lifecycle: `open_stream` takes a slot from the router's free
    list and zeroes only that slot's slices; `close_stream` returns it.
    `step` drives one live tick from a {stream_id: frame} dict; `run`
    replays pre-buffered audio through a `lax.scan` over the same tick
    body.

    Live ingress comes in two cadences: `step_batch` (synchronous —
    dispatch, then block on the score fetch) and `step_batch_async`
    (non-blocking — returns a `TickHandle` whose scores materialize
    later, so tick N-1's results are fetched while tick N runs). The
    double-buffered staging and micro-batch coalescing around the async
    path live in `repro.serving.ingress`; both cadences drive the same
    device program and are bit-identical.

    Sharding: ``devices=N`` (first N visible devices) or an explicit
    ``mesh=`` (a 1-D `stream_mesh`) shards the slot axis of every state
    buffer, slab, and mask over the mesh and replicates the params —
    one SPMD program per tick, bit-identical to the single-device
    server. ``devices=None`` with a single visible device (and a
    size-1 mesh) falls back to the pre-sharding single-device path.

    Tick implementation: ``tick_impl=`` selects how the per-tick device
    program is built — ``"xla"`` (one fused XLA program, the historical
    tick), ``"fused-pallas"`` (the whole tick as ONE Pallas megakernel
    over stream blocks with the ΔGRU gather path — temporal sparsity
    becomes wall-clock speed), ``"fused-interpret"`` (the megakernel
    under the Pallas interpreter, for CPU CI), or ``"auto"`` (default:
    fused-pallas on TPU, xla elsewhere). All choices are bit-identical
    for every backend; the resolved choice and its kernel dispatch tier
    are exposed as `srv.tick_impl` / `srv.tick_dispatch`.

    Observability: ``metrics=`` takes a
    `repro.serving.metrics.MetricsRegistry` (or ``True`` for a fresh
    default one, exposed as `srv.metrics`) and instruments the server:
    tick dispatch / fetch latency histograms keyed on the 16 ms budget,
    tick / retrace / compile counters, occupancy gauges, and a
    structured journal event for every compile, shape-keyed retrace,
    resize, and shard-loss recovery. Everything is host-side clock
    reads around the existing calls — the device operands, programs,
    and dispatch order are untouched, so a metrics-enabled server is
    BIT-identical to a metrics-off one (tests/test_metrics.py).
    `srv.metrics_snapshot()` rolls the registry plus the server-level
    telemetry (`sparsity` / `wake_rate` means over open slots — host
    reads of existing counters, taken at snapshot time, never on the
    tick path) into one JSON-able dict. Retrace/compile counts are
    tracked even with metrics off (`srv.retrace_count` /
    `srv.compile_count`): a "retrace" is the first dispatch of a
    (program, operand-shape) pair since the programs were last rebuilt
    — exactly the ticks that pay jax's trace+compile cost, e.g. the
    first tick after a `resize` to a not-yet-seen capacity (resizing
    BACK to a seen capacity hits jax's cache and counts nothing).
    """

    def __init__(self, pipeline, params, max_streams: int = 256,
                 smoothing: float = 0.7, state=None, mesh=None,
                 devices: Optional[int] = None, tick_impl: str = "auto",
                 metrics=None):
        if mesh is not None and devices is not None:
            raise ValueError("pass mesh= or devices=, not both")
        if tick_impl not in _TICK_IMPLS:
            raise ValueError(
                f"tick_impl must be one of {_TICK_IMPLS}; got "
                f"{tick_impl!r}"
            )
        if tick_impl == "auto":
            # the megakernel is only a wall-clock win compiled on TPU;
            # off-TPU the fused-XLA tick is both fastest and the
            # bit-identity reference
            tick_impl = (
                "fused-pallas" if jax.default_backend() == "tpu" else "xla"
            )
        self.tick_impl = tick_impl
        # the kernel dispatch tier the ticks will actually run
        # ("xla" = no pallas_call at all) — benchmarks record this
        self.tick_dispatch = _TICK_DISPATCH[tick_impl]
        if mesh is None and devices is not None:
            # stream_mesh is the single count-vs-visible validator; the
            # size-1 fallback below then strips a one-device mesh
            mesh = stream_mesh(devices)
        if mesh is not None and mesh.devices.size == 1:
            mesh = None  # single-device fallback: no SPMD plumbing
        if mesh is not None and mesh.axis_names != (STREAM_AXIS,):
            raise ValueError(
                f"server mesh must be 1-D with axis named "
                f"{STREAM_AXIS!r} (see stream_mesh); got "
                f"{mesh.axis_names}"
            )
        self.mesh = mesh
        self.n_devices = int(mesh.devices.size) if mesh is not None else 1
        if max_streams % self.n_devices != 0:
            raise ValueError(
                f"max_streams={max_streams} must divide over "
                f"{self.n_devices} devices"
            )
        # `_is_raw` dispatches on the trailing dim alone, so a geometry
        # where a raw hop and an FV_Norm frame have the SAME width would
        # silently route every tick down the raw-audio path. The paper's
        # geometry (256-sample hops, 16 channels) never collides; any
        # config that does is rejected here, at construction, instead of
        # misclassifying ticks at serve time.
        if pipeline.chunk_samples == pipeline.config.fex.num_channels:
            raise ValueError(
                "ambiguous serving geometry: chunk_samples == "
                f"fex.num_channels == {pipeline.chunk_samples}, so raw "
                "audio hops and FV_Norm frames are indistinguishable by "
                "width; change fex.fs_audio / frame_shift_ms / "
                "num_channels so the two differ"
            )
        self.pipeline = pipeline
        # Backend-shape the params once (e.g. classifier="integer"
        # quantizes to the int8/int32 `QuantizedClassifier` here, so
        # every tick runs on weight codes); float/qat pass through.
        # On a mesh the codes are placed replicated across every device.
        self.params = pipeline.prepare_params(params, mesh=mesh)
        self.max_streams = max_streams
        self.smoothing = smoothing
        # frontend state (norm stats / calibration); default = the
        # pipeline's bound state. Replicated on the mesh.
        self.frontend_state = (
            pipeline.state if state is None else state
        )
        if mesh is not None:
            self.frontend_state = jax.device_put(
                self.frontend_state,
                replicated_shardings(self.frontend_state, mesh),
            )
        scores_sharding = (
            None if mesh is None
            else NamedSharding(mesh, P(STREAM_AXIS, None))
        )
        # stage-1 detector state only when the pipeline carries a
        # cascade — None keeps the pre-cascade pytree structure (and
        # device programs) for plain servers
        det = None
        if pipeline.config.cascade is not None:
            det = cascade_lib.init_state(
                max_streams,
                device=(
                    None if mesh is None
                    else NamedSharding(mesh, P(STREAM_AXIS))
                ),
            )
        self.state = ServerState(
            gru=tuple(pipeline.streaming_init(max_streams, mesh=mesh)),
            carry=pipeline.streaming_features_init(max_streams, mesh=mesh),
            scores=jnp.zeros(
                (max_streams, pipeline.config.gru.num_classes),
                jnp.float32,
                device=scores_sharding,
            ),
            det=det,
        )
        self.active: Dict[int, int] = {}  # stream_id -> slot
        # slot allocation = device placement on a mesh; the router's
        # round-robin fill keeps per-shard load balanced (and reduces
        # to the lowest-free-slot order of the pre-sharding free list
        # when n_shards == 1)
        self.router = StreamRouter(max_streams, self.n_devices)
        # retrace/compile accounting is always on (it is two ints and a
        # set — the benchmarks' exact compile-tick exclusion needs it
        # with metrics off too); the registry mirrors are optional
        self._retraces = 0
        self._compiles = 0
        self._tick_shapes: set = set()
        # metrics: True -> fresh default registry, an existing
        # MetricsRegistry -> shared, any falsy value (None/False) -> off
        if metrics is True:
            metrics = MetricsRegistry()
        elif not metrics:
            metrics = None
        self.metrics: Optional[MetricsRegistry] = metrics
        if metrics is not None:
            self._m_ticks = metrics.counter(
                "kws_serve_ticks_total",
                "fused serving ticks dispatched (scanned windows count "
                "each scanned tick)",
            )
            self._m_retraces = metrics.counter(
                "kws_serve_retraces_total",
                "dispatches that traced+compiled a new (program, "
                "operand shape) — the ticks that pay jit cost",
            )
            self._m_compiles = metrics.counter(
                "kws_serve_compile_programs_total",
                "full program rebuilds (construction and mesh changes)",
            )
            self._m_dispatch = metrics.histogram(
                "kws_serve_tick_dispatch_ms",
                "host time to dispatch one tick (or one coalesced "
                "window) — slab handoff to handle return, fetch "
                "excluded",
            )
            self._m_fetch = metrics.histogram(
                "kws_serve_tick_fetch_ms",
                "host time blocked in TickHandle.result() fetching "
                "scores to host",
            )
            self._m_tick = metrics.histogram(
                "kws_serve_tick_ms",
                "synchronous step_batch wall time (dispatch + fetch)",
            )
            self._m_open = metrics.gauge(
                "kws_serve_open_streams", "streams currently open"
            )
            self._m_cap = metrics.gauge(
                "kws_serve_capacity", "stream-slot capacity"
            )
            self._m_occ = metrics.gauge(
                "kws_serve_occupancy", "open streams / capacity"
            )
        self._update_occupancy_gauges()
        self._compile_programs()

    def _compile_programs(self):
        """(Re)build the jitted device programs for the current mesh.

        One compiled program per input kind; pipeline is closed over
        (static), state buffers are donated. On a mesh every jit gets
        explicit in/out shardings so each lowers to one SPMD program
        over the ("stream",) axis with the state donated in place.

        Called at construction and again only when the MESH changes
        (`recover_shard_loss`): the in/out NamedShardings name the mesh
        object, so a new mesh needs new jit wrappers. A capacity
        `resize` on an unchanged mesh deliberately does NOT come here —
        NamedShardings are shape-agnostic and `ServerState`'s pytree
        structure is capacity-independent, so the existing wrappers
        simply retrace at the new slot-axis shape (jax's own shape-
        keyed cache) and toggling between capacities reuses already-
        compiled programs instead of rebuilding them every resize.
        """
        # new wrappers mean every previously seen operand shape will
        # trace+compile again — reset the retrace tracking to match
        self._tick_shapes.clear()
        self._compiles += 1
        if self.metrics is not None:
            self._m_compiles.inc()
            self.metrics.journal.append(
                "compile_programs",
                n_devices=self.n_devices,
                max_streams=self.max_streams,
                tick_impl=self.tick_impl,
            )
        mesh, pipeline = self.mesh, self.pipeline
        if mesh is None:
            jit_kw = dict(donate_argnums=(1,))
            tick_kw = run_kw = jit_kw
            reset_kw = dict(donate_argnums=(0,))
        else:
            st_sh = stream_shardings(self.state, mesh)
            rep = lambda t: replicated_shardings(t, mesh)  # noqa: E731
            row = NamedSharding(mesh, P(STREAM_AXIS, None))
            vec = NamedSharding(mesh, P(STREAM_AXIS))
            seq_row = NamedSharding(mesh, P(None, STREAM_AXIS, None))
            seq_vec = NamedSharding(mesh, P(None, STREAM_AXIS))
            scalar = NamedSharding(mesh, P())
            tick_kw = dict(
                donate_argnums=(1,),
                in_shardings=(
                    rep(self.params), st_sh, row, vec,
                    rep(self.frontend_state), scalar,
                ),
                out_shardings=(st_sh, row, vec),
            )
            run_kw = dict(
                donate_argnums=(1,),
                in_shardings=(
                    rep(self.params), st_sh, seq_row, seq_vec,
                    rep(self.frontend_state), scalar,
                ),
                out_shardings=(st_sh, seq_row, seq_vec),
            )
            reset_kw = dict(
                donate_argnums=(0,),
                in_shardings=(st_sh, scalar),
                out_shardings=st_sh,
            )
        impl_kw = dict(tick_impl=self.tick_impl, mesh=mesh)
        self._tick_audio = jax.jit(
            functools.partial(_fused_tick, pipeline, True, **impl_kw),
            **tick_kw,
        )
        self._tick_fv = jax.jit(
            functools.partial(_fused_tick, pipeline, False, **impl_kw),
            **tick_kw,
        )
        self._reset = jax.jit(_reset_slot, **reset_kw)
        self._run_audio = jax.jit(
            functools.partial(_run_scan, pipeline, True, **impl_kw),
            **run_kw,
        )
        self._run_fv = jax.jit(
            functools.partial(_run_scan, pipeline, False, **impl_kw),
            **run_kw,
        )
        # Device-side ownership copy for the async path: the fused
        # tick's (scores, top) outputs can alias the new ServerState's
        # buffers, which the NEXT tick donates — a deferred host fetch
        # of the raw outputs would read garbage. jnp.copy under jit
        # (no donation) always produces fresh buffers, dispatched
        # asynchronously right behind the tick, so a TickHandle stays
        # valid however late it is fetched. Shardings are inherited
        # from the inputs, so the same program serves the mesh path.
        self._own = jax.jit(lambda s, t: (jnp.copy(s), jnp.copy(t)))

    # ---- observability ----

    @property
    def retrace_count(self) -> int:
        """Dispatches so far that traced+compiled a new (program,
        operand shape) pair — i.e. the ticks that paid jit cost. The
        first tick after construction counts (it compiles), as does
        the first tick after a `resize` to a capacity this program set
        has not served yet; a resize back to a seen capacity hits
        jax's shape-keyed cache and does not. Rebuilt programs
        (`_compile_programs`) reset the seen-shape tracking, so the
        first post-recovery tick counts again. Tracked with metrics
        off too — `benchmarks/churn_load.py` keys its exact
        compile-tick exclusion on this."""
        return self._retraces

    @property
    def compile_count(self) -> int:
        """Full program rebuilds so far (1 after construction; +1 per
        mesh change, i.e. `recover_shard_loss`)."""
        return self._compiles

    def _note_dispatch(self, program: str, shape) -> None:
        """Record one dispatch of `program` at `shape`: the first
        (program, shape) since the last `_compile_programs` is a
        retrace (jax traces+compiles under this very call)."""
        key = (program, tuple(int(d) for d in shape))
        if key in self._tick_shapes:
            return
        self._tick_shapes.add(key)
        self._retraces += 1
        if self.metrics is not None:
            self._m_retraces.inc()
            self.metrics.journal.append(
                "retrace", program=program, shape=list(key[1]),
                max_streams=self.max_streams,
            )

    def _update_occupancy_gauges(self) -> None:
        if self.metrics is None:
            return
        n = len(self.active)
        self._m_open.set(n)
        self._m_cap.set(self.max_streams)
        self._m_occ.set(n / self.max_streams if self.max_streams else 0.0)

    def metrics_snapshot(self) -> Dict[str, Any]:
        """One JSON-able dict of everything observable about the server.

        ``server`` block: identity (tick_impl / dispatch tier / mesh
        size), capacity and occupancy, retrace/compile counts, and the
        per-backend telemetry rollups — mean `sparsity` (ΔGRU
        effective-MAC fraction) and `wake_rate` (cascade duty cycle)
        over the OPEN slots (None with no streams open). Those two
        read device state (a host sync), which is fine here: snapshots
        happen off the tick path. With `metrics=` enabled the registry
        snapshot (counters / gauges / histograms with percentiles,
        journal, trace span rollups) is merged in; with metrics off
        only the server block is returned.

        `json.dumps(srv.metrics_snapshot())` always succeeds and
        round-trips equal (tests/test_metrics.py).
        """
        slots = sorted(self.active.values())
        server: Dict[str, Any] = {
            "tick_impl": self.tick_impl,
            "tick_dispatch": self.tick_dispatch,
            "n_devices": self.n_devices,
            "max_streams": self.max_streams,
            "open_streams": len(self.active),
            "occupancy": (
                len(self.active) / self.max_streams
                if self.max_streams else 0.0
            ),
            "retraces": self._retraces,
            "compiles": self._compiles,
            "sparsity_mean": (
                float(np.mean(self.sparsity[slots])) if slots else None
            ),
            "wake_rate_mean": (
                float(np.mean(self.wake_rate[slots])) if slots else None
            ),
        }
        snap: Dict[str, Any] = {"server": server}
        if self.metrics is not None:
            snap.update(self.metrics.snapshot())
        return snap

    # ---- compatibility views of the fused state ----

    @property
    def states(self) -> List[jnp.ndarray]:
        """Per-layer GRU hidden states (pre-fused API name)."""
        return list(self.state.gru)

    @property
    def feat_carry(self):
        """Frontend streaming carry (pre-fused API name)."""
        return self.state.carry

    @property
    def scores(self) -> np.ndarray:
        """Smoothed per-slot posteriors as a host array.

        An owned copy, not a view: `np.asarray` of a CPU device buffer
        can be zero-copy, and the buffer it would alias is donated to
        the next tick — a view could silently mutate under the caller
        (see `step_batch`). The authoritative copy lives in
        `self.state.scores`."""
        return np.array(self.state.scores)

    @property
    def sparsity(self) -> np.ndarray:
        """Per-slot effective-MAC fraction, (max_streams,) float32.

        For the ΔGRU backends ("delta"/"delta-int") this reads the
        skipped/total MAC counters the tick accumulates per stream
        (executed / offered over the whole classifier, always-dense FC
        included — see `repro.core.gru_delta.effective_mac_fraction`):
        1.0 means fully dense, 0.1 means the stream's traffic let the
        engine skip 90 % of the eligible work. Counters reset with the
        slot on `open_stream`, advance only under the submitted mask
        (an idle tick changes nothing), and ride `ServerState` through
        donation and the stream mesh like every other leaf, so the
        telemetry is exact for live ticks, slab ingress, and the
        scanned replay alike. Dense backends report all-ones — the
        fraction is an invariant 1.0 there, so callers can sweep
        backends without special-casing.

        An owned host copy, like `scores` (never a view of a
        donation-bound buffer).
        """
        from repro.core.gru_delta import (
            effective_mac_fraction,
            is_delta_states,
        )

        if is_delta_states(self.state.gru):
            return np.array(
                effective_mac_fraction(
                    list(self.state.gru), self.pipeline.config.gru
                ),
                dtype=np.float32,
            )
        return np.ones((self.max_streams,), np.float32)

    @property
    def wake_rate(self) -> np.ndarray:
        """Per-slot stage-1 wake rate, (max_streams,) float32.

        For a cascaded pipeline (`pipeline.config.cascade`) this reads
        the detector's woken/ticks counters the tick accumulates per
        stream: the fraction of a stream's submitted ticks on which
        the gate let the classifier advance (1.0 = always woken, 0.0 =
        the stream never crossed the wake threshold). The mean over
        active slots is the classifier duty cycle — it plugs straight
        into `AcceleratorModel(duty_cycle=...)` to predict gated IC
        µW, composing with the ΔGRU `srv.sparsity` (which, for a
        cascaded delta server, measures sparsity *within* the woken
        ticks — the two factors multiply).

        Same telemetry contract as `sparsity`: counters reset with the
        slot on `open_stream`, advance only under the submitted mask,
        freeze while the stream idles, ride donation and the stream
        mesh, and are placement-independent. Slots with no traffic —
        and every slot of a non-cascaded server — report 1.0, so
        callers can sweep configurations without special-casing.

        An owned host copy, like `scores` (never a view of a
        donation-bound buffer).
        """
        if self.state.det is None:
            return np.ones((self.max_streams,), np.float32)
        return np.array(
            cascade_lib.wake_rate(self.state.det), dtype=np.float32
        )

    # ---- slot lifecycle ----

    def open_stream(self, stream_id: int):
        if stream_id in self.active:
            raise ValueError(f"stream {stream_id} already open")
        slot = self.router.acquire()  # raises RuntimeError at capacity
        self.active[stream_id] = slot
        # zero only the reused slot — concurrent streams' slices and the
        # free slots' garbage are untouched (they are masked anyway).
        # The slot index is traced (and replicated on a mesh), so
        # open/close never recompiles and works across shard boundaries.
        self.state = self._reset(self.state, jnp.int32(slot))
        self._update_occupancy_gauges()

    def close_stream(self, stream_id: int):
        # validate before touching the router: a raw KeyError from
        # active.pop leaked bookkeeping internals for double-closes and
        # never-opened ids
        if stream_id not in self.active:
            raise ValueError(f"stream {stream_id} not open")
        slot = self.active.pop(stream_id)
        self.router.release(slot)
        self._update_occupancy_gauges()

    # ---- elastic capacity: live resize & shard-loss recovery ----

    def _host_state(self) -> ServerState:
        """Owned host copies of every state leaf. `np.array` both
        forces the copy (a zero-copy view would alias buffers the next
        tick donates) and blocks until any in-flight tick that writes
        them has executed — a resize never tears a tick."""
        return jax.tree.map(lambda t: np.array(t), self.state)

    def _relay_state(self, host_state: ServerState, new_max: int,
                     src, dst) -> ServerState:
        """Re-lay host state onto a new capacity: per-leaf zeros at
        `new_max` slots with old rows `src` copied BITWISE to new rows
        `dst` (numpy fancy indexing — no arithmetic touches the data,
        which is what makes survivors array-equal, not just close, in
        every dtype: float32 scores, int32 Q6.8 codes, bool latches,
        ΔGRU accumulators)."""
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)

        def relay(leaf):
            out = np.zeros((new_max,) + leaf.shape[1:], leaf.dtype)
            out[dst] = leaf[src]
            return out

        return jax.tree.map(relay, host_state)

    def _place_state(self, host_state: ServerState) -> ServerState:
        """Put a host-side state onto the device(s) in the server's
        canonical layout (slot axis block-sharded over the mesh)."""
        if self.mesh is None:
            return jax.device_put(host_state)
        return jax.device_put(
            host_state, stream_shardings(host_state, self.mesh)
        )

    def resize(self, new_max_streams: int) -> None:
        """Grow or shrink the stream-slot capacity live.

        Every `ServerState` leaf is re-laid onto the new capacity:
        open streams' per-slot slices are copied bitwise (host-side
        fancy indexing, then `device_put` back onto the ``("stream",)``
        block layout), stream ids keep serving through the move, and
        the `StreamRouter` re-places the survivors in ascending
        old-slot order (`StreamRouter.remap` — deterministic, so the
        new placement is balanced and oracle-predictable). Surviving
        streams are BIT-identical to an un-resized server afterwards —
        all five classifier backends, cascaded detector state, ΔGRU
        counters, async handles in flight (handles own their copies)
        — proven in tests/test_serve_sharded.py.

        The mesh is unchanged, so no device program is rebuilt; the
        existing jits retrace at the new slot-axis shape and previously
        compiled capacities are reused from jax's cache (grow then
        shrink back costs zero new compiles).

        The new capacity must divide over the mesh (whole per-shard
        blocks) and hold every open stream; shrinking below the open
        count raises before any state moves. Callers holding a
        `PipelinedIngress` must `drain()` it around a resize — its
        staged slabs are capacity-shaped (it reallocates on next
        `stage()`; see `repro.serving.ingress`).
        """
        if new_max_streams < 1:
            raise ValueError(
                f"new_max_streams must be >= 1, got {new_max_streams}"
            )
        if new_max_streams % self.n_devices != 0:
            raise ValueError(
                f"new_max_streams={new_max_streams} must divide over "
                f"{self.n_devices} devices"
            )
        if len(self.active) > new_max_streams:
            raise RuntimeError(
                f"cannot shrink to {new_max_streams} slots with "
                f"{len(self.active)} stream(s) open"
            )
        if new_max_streams == self.max_streams:
            return
        occupied = sorted(self.active.values())
        router, mapping = StreamRouter.remap(
            occupied, new_max_streams, self.n_devices
        )
        host = self._host_state()
        new_host = self._relay_state(
            host, new_max_streams, occupied,
            [mapping[s] for s in occupied],
        )
        self.state = self._place_state(new_host)
        self.active = {
            sid: mapping[slot] for sid, slot in self.active.items()
        }
        self.router = router
        old_max, self.max_streams = self.max_streams, new_max_streams
        if self.metrics is not None:
            self.metrics.journal.append(
                "resize", from_streams=old_max,
                to_streams=new_max_streams,
                open_streams=len(self.active),
                n_devices=self.n_devices,
            )
        self._update_occupancy_gauges()

    def recover_shard_loss(self, lost_shard: int) -> Dict[str, Any]:
        """Shrink-reshard after losing one shard's device.

        The recovery control flow of `repro.distributed.fault_tolerance`
        wired into serving: the lost device's slot block is gone, so

          1. every OTHER shard's per-slot state is gathered to host
             (bitwise — healthy streams must come out unchanged),
          2. `ElasticMeshManager` rebuilds a smaller ``("stream",)``
             mesh from the surviving devices (power-of-two shrink, as
             for the training mesh; one survivor -> the single-device
             fallback, no mesh),
          3. capacity is rounded UP to whole per-shard blocks of the
             new mesh (survivors never stop fitting),
          4. survivors are remapped (ascending old-slot order) and
             their state re-laid bitwise onto the new layout,
          5. params / frontend calibration are re-replicated and the
             jitted programs REBUILT — unlike `resize`, the mesh
             changed, and the programs' NamedShardings name it,
          6. the lost shard's streams are reopened under their own
             stream ids on fresh zeroed slots (their state died with
             the device; the caller replays or resumes their audio).

        Returns a summary dict: ``lost_shard``, ``n_devices`` /
        ``max_streams`` (after), ``reopened`` (stream ids that lost
        state), ``survivors`` (stream ids bit-preserved).
        """
        if self.mesh is None:
            raise ValueError(
                "single-device server has no shards to lose"
            )
        if not 0 <= lost_shard < self.n_devices:
            raise ValueError(
                f"lost_shard {lost_shard} outside "
                f"[0, {self.n_devices})"
            )
        from repro.distributed.fault_tolerance import ElasticMeshManager
        from repro.serving.autoscale import shard_of_slot

        # gather BEFORE the mesh shrinks: in this simulation the host
        # can still read every shard; only the lost block's rows are
        # treated as gone (never copied into the new layout)
        host = self._host_state()
        healthy = surviving_devices(self.mesh, lost_shard)
        manager = ElasticMeshManager(
            make_mesh=lambda n: stream_mesh(healthy[:n]),
            initial_data_size=self.n_devices,
        )
        new_mesh = manager.shrink(1)
        new_n = manager.data_size
        if new_n == 1:
            new_mesh = None  # single-device fallback, like __init__
        new_max = -(-self.max_streams // new_n) * new_n
        survivors = {
            sid: slot for sid, slot in self.active.items()
            if shard_of_slot(slot, self.max_streams, self.n_devices)
            != lost_shard
        }
        affected = sorted(
            (slot, sid) for sid, slot in self.active.items()
            if sid not in survivors
        )
        occupied = sorted(survivors.values())
        router, mapping = StreamRouter.remap(occupied, new_max, new_n)
        new_host = self._relay_state(
            host, new_max, occupied, [mapping[s] for s in occupied]
        )
        old_devices, old_max = self.n_devices, self.max_streams
        self.mesh = new_mesh
        self.n_devices = new_n
        self.max_streams = new_max
        # replicated operands follow the mesh; state takes the new
        # block layout; programs rebuild against the new shardings
        if new_mesh is not None:
            self.params = jax.device_put(
                self.params, replicated_shardings(self.params, new_mesh)
            )
            self.frontend_state = jax.device_put(
                self.frontend_state,
                replicated_shardings(self.frontend_state, new_mesh),
            )
        else:
            to_default = lambda t: jax.device_put(np.asarray(t))  # noqa: E731
            self.params = jax.tree.map(to_default, self.params)
            self.frontend_state = jax.tree.map(
                to_default, self.frontend_state
            )
        self.state = self._place_state(new_host)
        self.active = {
            sid: mapping[slot] for sid, slot in survivors.items()
        }
        self.router = router
        self._compile_programs()
        # reopen the lost streams: same ids, fresh zeroed slots (old
        # slot order keeps the reopening deterministic for the oracle)
        reopened = []
        for _old_slot, sid in affected:
            slot = self.router.acquire()
            self.active[sid] = slot
            self.state = self._reset(self.state, jnp.int32(slot))
            reopened.append(sid)
        if self.metrics is not None:
            self.metrics.journal.append(
                "shard_loss",
                lost_shard=lost_shard,
                from_devices=old_devices, to_devices=new_n,
                from_streams=old_max, to_streams=new_max,
                reopened=list(reopened),
                survivors=sorted(survivors),
            )
        self._update_occupancy_gauges()
        return {
            "lost_shard": lost_shard,
            "n_devices": new_n,
            "max_streams": new_max,
            "reopened": reopened,
            "survivors": sorted(survivors),
        }

    # ---- serving ----

    def _require_open(self, stream_ids) -> None:
        """Reject ticks naming unopened streams BEFORE any slab or
        state mutation — a bad tick must leave the server bit-unchanged
        (the pre-validation code KeyError'd out of `_slab` mid-build)."""
        unknown = [sid for sid in stream_ids if sid not in self.active]
        if unknown:
            raise ValueError(
                f"stream(s) {sorted(unknown)} not open"
            )

    def _is_raw(self, dim: int) -> bool:
        """The single kind-dispatch site: True for raw audio hops, False
        for FV_Norm frames, canonical error otherwise. (The two widths
        never collide for the paper's geometry.)"""
        if dim == self.pipeline.chunk_samples:
            return True
        if dim == self.pipeline.config.fex.num_channels:
            return False
        raise ValueError(
            "per-stream input must be an FV_Norm frame "
            f"({self.pipeline.config.fex.num_channels},) or a raw audio "
            f"hop ({self.pipeline.chunk_samples},); got trailing dim {dim}"
        )

    def _slab(self, frames: Dict[int, np.ndarray]):
        """{sid: frame} -> (dense slab, mask) host-side; kind validation
        happens downstream in `step_batch`."""
        self._require_open(frames)
        dims = {int(np.shape(f)[-1]) for f in frames.values()}
        if len(dims) > 1:
            raise ValueError(
                "all frames in one tick must be the same kind; got "
                f"trailing dims {sorted(dims)}"
            )
        dim = dims.pop()
        slab = np.zeros((self.max_streams, dim), np.float32)
        mask = np.zeros((self.max_streams,), bool)
        for sid, frame in frames.items():
            slot = self.active[sid]
            slab[slot] = frame
            mask[slot] = True
        return slab, mask

    def step_batch(self, slab, mask):
        """Pre-batched tick: the high-throughput ingress path.

        slab: (max_streams, S) raw audio hops or (max_streams, C) FV_Norm
        frames, slot-major (rows for unsubmitted slots are ignored);
        mask: (max_streams,) bool, True where the slot submitted. Callers
        that already maintain slot-major buffers (a socket ingress, the
        load generator) skip `step`'s per-stream dict assembly entirely —
        the tick is one device dispatch plus one result fetch.

        Returns (scores (max_streams, K), top (max_streams,)) as host
        arrays; rows of unsubmitted slots hold their previous values.
        The arrays are OWNED copies (never views of donation-bound
        buffers): this is `step_batch_async` fetched immediately.
        """
        m = self.metrics
        if m is None:
            return self.step_batch_async(slab, mask).result()
        t0 = m.clock()
        out = self.step_batch_async(slab, mask).result()
        self._m_tick.observe((m.clock() - t0) * 1e3)
        return out

    def step_batch_async(self, slab, mask) -> TickHandle:
        """Non-blocking tick: dispatch and return a deferred handle.

        Same operands and same device program as `step_batch`, but the
        host is NOT blocked on the device-to-host score fetch — the
        returned `TickHandle` materializes (scores, top) on its first
        `result()` call. Dispatching tick N+1 before fetching tick N's
        handle overlaps host slab staging with device execution (the
        async ingress path: `repro.serving.ingress.PipelinedIngress`
        does the buffer discipline, `TickCoalescer` the sub-window
        arrival merging), which is what closes the live-vs-scan
        throughput gap.

        The handle owns device-side copies of the tick's outputs
        (dispatched right behind the tick, still non-blocking), so it
        survives any number of later ticks donating the `ServerState`
        buffers the raw outputs alias — fetch it as late as you like.
        The state trajectory is bit-identical to the synchronous
        `step_batch` sequence: async moves only WHEN the host reads the
        results, never what the device computes.

        Host buffers go straight into the jit call — an explicit
        `jnp.asarray` staging hop here measured ~0.35 ms/tick extra on
        a single-core host, most of the live-vs-scan dispatch gap.
        """
        raw = self._is_raw(int(np.shape(slab)[-1]))
        tick = self._tick_audio if raw else self._tick_fv
        self._note_dispatch(
            "tick_audio" if raw else "tick_fv", np.shape(slab)
        )
        m = self.metrics
        if m is None:
            self.state, scores, top = tick(
                self.params, self.state, slab, mask,
                self.frontend_state, self.smoothing,
            )
            return TickHandle(*self._own(scores, top))
        t0 = m.clock()
        self.state, scores, top = tick(
            self.params, self.state, slab, mask,
            self.frontend_state, self.smoothing,
        )
        handle = TickHandle(
            *self._own(scores, top), fetch_hist=self._m_fetch,
            clock=m.clock,
        )
        self._m_ticks.inc()
        self._m_dispatch.observe((m.clock() - t0) * 1e3)
        return handle

    def step(self, frames: Dict[int, np.ndarray]) -> Dict[int, dict]:
        """frames: stream_id -> FV_Norm (C,) or raw audio hop (S,).

        One 16 ms tick. Inputs are raw audio when their trailing dim is
        `pipeline.chunk_samples` (e.g. 256 @ 16 kHz), FV_Norm when it is
        `fex.num_channels` (e.g. 16) — the two never collide for the
        paper's geometry. An empty dict is a no-op tick: no device call,
        no state change."""
        if not frames:
            return {}
        slab, mask = self._slab(frames)
        scores, top = self.step_batch(slab, mask)
        out = {}
        for sid in frames:
            slot = self.active[sid]
            out[sid] = {"probs": scores[slot], "top": int(top[slot])}
        return out

    def run_batch(self, slab, mask):
        """Offline replay of pre-batched tick slabs, as one device program.

        slab: (n_ticks, max_streams, S) raw audio hops or
        (n_ticks, max_streams, C) FV_Norm frames; mask: (n_ticks,
        max_streams) bool, True where the slot submitted that tick. The
        whole replay is a `lax.scan` over the fused tick body with the
        `ServerState` donated across ticks — the pre-refactor path could
        not be scanned at all, since its per-tick numpy smoothing forced
        a host round-trip every 16 ms. Compiles once per (n_ticks, kind).

        Returns (scores_seq (n_ticks, N, K), tops (n_ticks, N)) as host
        arrays and advances the server state by n_ticks. The arrays are
        owned copies, never views of donation-bound buffers: this is
        `run_batch_async` fetched immediately.
        """
        return self.run_batch_async(slab, mask).result()

    def run_batch_async(self, slab, mask) -> TickHandle:
        """Non-blocking window dispatch: `run_batch` returning a handle.

        Scan-replays a (window, max_streams, S|C) slab of consecutive
        ticks as ONE device program (state donated across ticks inside
        the scan) and returns immediately; the handle's `result()` is
        (scores_seq (window, N, K), tops (window, N)). Because the scan
        body is the very `_fused_tick` the live path jits, the state
        trajectory and every per-tick score row are bit-identical to
        `window` sequential `step_batch` calls — which is what lets the
        async ingress amortize the per-dispatch host cost over a whole
        window (`PipelinedIngress(window=K)`) without touching the
        correctness story. Same owned-copy fetch discipline as
        `step_batch_async`.
        """
        raw = self._is_raw(int(np.shape(slab)[-1]))
        run = self._run_audio if raw else self._run_fv
        self._note_dispatch(
            "run_audio" if raw else "run_fv", np.shape(slab)
        )
        m = self.metrics
        if m is None:
            self.state, scores_seq, tops = run(
                self.params, self.state, slab, mask,
                self.frontend_state, self.smoothing,
            )
            return TickHandle(*self._own(scores_seq, tops))
        t0 = m.clock()
        self.state, scores_seq, tops = run(
            self.params, self.state, slab, mask,
            self.frontend_state, self.smoothing,
        )
        handle = TickHandle(
            *self._own(scores_seq, tops), fetch_hist=self._m_fetch,
            clock=m.clock,
        )
        self._m_ticks.inc(int(np.shape(slab)[0]))
        self._m_dispatch.observe((m.clock() - t0) * 1e3)
        return handle

    def run(self, buffers: Dict[int, np.ndarray]) -> Dict[int, dict]:
        """Offline replay: buffered audio -> per-tick posteriors, scanned.

        buffers: stream_id -> raw audio (n_samples,) for streams that are
        already open; each is split into consecutive
        `pipeline.chunk_samples` hops (trailing remainder dropped).
        Streams may have different lengths — a stream is masked out of
        every tick past its own end, exactly as if it had stopped
        submitting to `step`.

        The whole replay is ONE device program: `lax.scan` over the fused
        tick body, state donated across ticks. Compiles once per
        (n_ticks, kind) shape. Returns, per stream,
        ``{"probs": (n_ticks_sid, K) smoothed posteriors trajectory,
        "top": final argmax}``, and advances the server state by the
        replayed ticks.
        """
        if not buffers:
            return {}
        self._require_open(buffers)
        hop = self.pipeline.chunk_samples
        ticks = {sid: len(np.asarray(b)) // hop for sid, b in buffers.items()}
        n_ticks = max(ticks.values())
        if n_ticks == 0:
            return {}
        slab = np.zeros((n_ticks, self.max_streams, hop), np.float32)
        mask = np.zeros((n_ticks, self.max_streams), bool)
        for sid, buf in buffers.items():
            slot = self.active[sid]
            t = ticks[sid]
            buf = np.asarray(buf, np.float32)[: t * hop]
            slab[:t, slot] = buf.reshape(t, hop)
            mask[:t, slot] = True
        scores_seq, tops = self.run_batch(slab, mask)  # (T, N, K), (T, N)
        out = {}
        for sid in buffers:
            slot = self.active[sid]
            t = ticks[sid]
            out[sid] = {
                "probs": scores_seq[:t, slot],
                "top": int(tops[t - 1, slot]) if t else None,
            }
        return out


def _run_scan(pipeline, raw_audio, params, state: ServerState, slab, mask,
              frontend_state, smoothing, *, tick_impl="xla", mesh=None):
    """lax.scan of the fused tick over (n_ticks, N, S|C) buffered input.

    The scan body is the very `_fused_tick` the live path jits — same
    tick_impl, so a fused-pallas server replays its megakernel inside
    the scan too (one kernel launch per scanned tick)."""

    def body(st, xs):
        x_t, m_t = xs
        st, scores, top = _fused_tick(
            pipeline, raw_audio, params, st, x_t, m_t, frontend_state,
            smoothing, tick_impl=tick_impl, mesh=mesh,
        )
        return st, (scores, top)

    state, (scores_seq, tops) = jax.lax.scan(body, state, (slab, mask))
    return state, scores_seq, tops
