"""Serving paths.

LM side: `lower_prefill` / `lower_decode_step` build the pjit'd serving
programs the dry-run compiles (batch of requests, KV cache / recurrent
state sharded per distributed/sharding.py).

KWS side: `StreamingKWSServer` — the deployment shape of the paper's
chip: N concurrent audio streams, one 16 ms FV per stream per frame, a
batched weights-resident GRU step, per-stream argmax + exponential score
smoothing. This is the serve-side example driver (examples/
serve_streaming.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import (
    ShardingRules,
    batch_specs,
    cache_specs,
    make_mesh_context,
    named,
    param_specs,
)
from repro.models.registry import get_backbone

Pytree = Any


def serve_batch_shape(arch_cfg, shape_spec):
    """ShapeDtypeStructs for one serve step of the given input shape."""
    b = shape_spec.global_batch
    if arch_cfg.frontend == "embedding":
        return {
            "embeddings": jax.ShapeDtypeStruct(
                (b, 1, arch_cfg.d_model), arch_cfg.activation_dtype
            )
        }
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def prefill_batch_shape(arch_cfg, shape_spec):
    b, s = shape_spec.global_batch, shape_spec.seq_len
    if arch_cfg.frontend == "embedding":
        return {
            "embeddings": jax.ShapeDtypeStruct(
                (b, s, arch_cfg.d_model), arch_cfg.activation_dtype
            )
        }
    return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}


def lower_decode_step(arch_cfg, rules: ShardingRules, shape_spec):
    """Abstract lower of one decode step at (batch, cache_len) scale."""
    backbone = get_backbone(arch_cfg)
    mesh_ctx = make_mesh_context(rules)
    b, s = shape_spec.global_batch, shape_spec.seq_len
    params_shape = jax.eval_shape(
        lambda k: backbone.init_params(k, arch_cfg, mesh_ctx),
        jax.random.PRNGKey(0),
    )
    if getattr(arch_cfg, "serve_quant", False):
        from repro.serving.quantize import quantize_expert_shapes

        params_shape = quantize_expert_shapes(params_shape)
    cache_shape = jax.eval_shape(
        lambda: backbone.init_cache(arch_cfg, b, s, mesh_ctx)
    )
    batch_shape = serve_batch_shape(arch_cfg, shape_spec)
    pspecs = param_specs(params_shape, rules)
    cspecs = cache_specs(cache_shape, rules, b)
    bspecs = batch_specs(batch_shape, rules)

    def step(params, cache, cache_len, batch):
        return backbone.decode_step(
            params, cache, cache_len, batch, arch_cfg, mesh_ctx
        )

    # the updated cache keeps the input cache's sharding (donated buffers)
    out_cache_shape = jax.eval_shape(
        step,
        params_shape,
        cache_shape,
        jax.ShapeDtypeStruct((), jnp.int32),
        batch_shape,
    )[1]
    out_cspecs = cache_specs(out_cache_shape, rules, b)
    with rules.mesh:
        lowered = jax.jit(
            step,
            in_shardings=(
                named(pspecs, rules.mesh),
                named(cspecs, rules.mesh),
                None,
                named(bspecs, rules.mesh),
            ),
            out_shardings=(None, named(out_cspecs, rules.mesh)),
            donate_argnums=(1,),
        ).lower(
            params_shape,
            cache_shape,
            jax.ShapeDtypeStruct((), jnp.int32),
            batch_shape,
        )
    return lowered, params_shape, cache_shape


def lower_prefill(arch_cfg, rules: ShardingRules, shape_spec):
    backbone = get_backbone(arch_cfg)
    mesh_ctx = make_mesh_context(rules)
    b, s = shape_spec.global_batch, shape_spec.seq_len
    params_shape = jax.eval_shape(
        lambda k: backbone.init_params(k, arch_cfg, mesh_ctx),
        jax.random.PRNGKey(0),
    )
    batch_shape = prefill_batch_shape(arch_cfg, shape_spec)
    pspecs = param_specs(params_shape, rules)
    bspecs = batch_specs(batch_shape, rules)

    def step(params, batch):
        return backbone.prefill(params, batch, arch_cfg, mesh_ctx)

    out_cache_shape = jax.eval_shape(step, params_shape, batch_shape)[1]
    out_cspecs = cache_specs(out_cache_shape, rules, b)
    with rules.mesh:
        lowered = jax.jit(
            step,
            in_shardings=(
                named(pspecs, rules.mesh),
                named(bspecs, rules.mesh),
            ),
            out_shardings=(None, named(out_cspecs, rules.mesh)),
        ).lower(params_shape, batch_shape)
    return lowered, params_shape


# --------------------------------------------------------------------------
# Streaming KWS serving (the paper's own deployment shape)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class StreamState:
    stream_id: int
    scores: Optional[np.ndarray] = None  # smoothed class scores


class StreamingKWSServer:
    """Batched frame-synchronous KWS over N concurrent audio streams.

    Each frame tick: callers push one FV_Norm (C,) per active stream; the
    server runs ONE batched GRU step for all of them (the accelerator's
    Fig. 4 timing, vectorized across streams) and returns per-stream
    smoothed posteriors + argmax.
    """

    def __init__(self, pipeline, params, max_streams: int = 256,
                 smoothing: float = 0.7):
        self.pipeline = pipeline
        self.params = params
        self.max_streams = max_streams
        self.smoothing = smoothing
        self.states = pipeline.streaming_init(max_streams)
        self.active: Dict[int, int] = {}  # stream_id -> slot
        self.scores = np.zeros(
            (max_streams, pipeline.config.gru.num_classes), np.float32
        )
        self._free = list(range(max_streams))[::-1]

    def open_stream(self, stream_id: int):
        if not self._free:
            raise RuntimeError("server at capacity")
        slot = self._free.pop()
        self.active[stream_id] = slot
        for i, h in enumerate(self.states):
            self.states[i] = h.at[slot].set(0.0)
        self.scores[slot] = 0.0

    def close_stream(self, stream_id: int):
        slot = self.active.pop(stream_id)
        self._free.append(slot)

    def step(self, frames: Dict[int, np.ndarray]) -> Dict[int, dict]:
        """frames: stream_id -> FV_Norm (C,). One 16 ms tick."""
        c = self.pipeline.config.fex.num_channels
        fv = np.zeros((self.max_streams, c), np.float32)
        for sid, frame in frames.items():
            fv[self.active[sid]] = frame
        self.states, logits = self.pipeline.streaming_step(
            self.params, self.states, jnp.asarray(fv)
        )
        logits = np.asarray(logits)
        out = {}
        for sid in frames:
            slot = self.active[sid]
            p = np.exp(logits[slot] - logits[slot].max())
            p /= p.sum()
            self.scores[slot] = (
                self.smoothing * self.scores[slot]
                + (1 - self.smoothing) * p
            )
            out[sid] = {
                "probs": self.scores[slot].copy(),
                "top": int(self.scores[slot].argmax()),
            }
        return out
