"""Serving paths.

LM side: `lower_prefill` / `lower_decode_step` build the pjit'd serving
programs the dry-run compiles (batch of requests, KV cache / recurrent
state sharded per distributed/sharding.py).

KWS side: `StreamingKWSServer` — the deployment shape of the paper's
chip: N concurrent audio streams, one tick per 16 ms frame, a batched
weights-resident GRU step, per-stream argmax + exponential score
smoothing. Each tick accepts, per stream, EITHER a precomputed FV_Norm
frame (C,) OR a raw 16 ms audio hop (`pipeline.chunk_samples` samples at
fs_audio); raw audio is pushed through the pipeline's registered
`FeatureFrontend` (software / hardware-sim / Pallas TDC) with per-stream
filter + SRO-phase carry, so the server is end-to-end audio-in,
posteriors-out. This is the serve-side example driver
(examples/serve_streaming.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import (
    ShardingRules,
    batch_specs,
    cache_specs,
    make_mesh_context,
    named,
    param_specs,
)
from repro.models.registry import get_backbone

Pytree = Any


def serve_batch_shape(arch_cfg, shape_spec):
    """ShapeDtypeStructs for one serve step of the given input shape."""
    b = shape_spec.global_batch
    if arch_cfg.frontend == "embedding":
        return {
            "embeddings": jax.ShapeDtypeStruct(
                (b, 1, arch_cfg.d_model), arch_cfg.activation_dtype
            )
        }
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def prefill_batch_shape(arch_cfg, shape_spec):
    b, s = shape_spec.global_batch, shape_spec.seq_len
    if arch_cfg.frontend == "embedding":
        return {
            "embeddings": jax.ShapeDtypeStruct(
                (b, s, arch_cfg.d_model), arch_cfg.activation_dtype
            )
        }
    return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}


def lower_decode_step(arch_cfg, rules: ShardingRules, shape_spec):
    """Abstract lower of one decode step at (batch, cache_len) scale."""
    backbone = get_backbone(arch_cfg)
    mesh_ctx = make_mesh_context(rules)
    b, s = shape_spec.global_batch, shape_spec.seq_len
    params_shape = jax.eval_shape(
        lambda k: backbone.init_params(k, arch_cfg, mesh_ctx),
        jax.random.PRNGKey(0),
    )
    if getattr(arch_cfg, "serve_quant", False):
        from repro.serving.quantize import quantize_expert_shapes

        params_shape = quantize_expert_shapes(params_shape)
    cache_shape = jax.eval_shape(
        lambda: backbone.init_cache(arch_cfg, b, s, mesh_ctx)
    )
    batch_shape = serve_batch_shape(arch_cfg, shape_spec)
    pspecs = param_specs(params_shape, rules)
    cspecs = cache_specs(cache_shape, rules, b)
    bspecs = batch_specs(batch_shape, rules)

    def step(params, cache, cache_len, batch):
        return backbone.decode_step(
            params, cache, cache_len, batch, arch_cfg, mesh_ctx
        )

    # the updated cache keeps the input cache's sharding (donated buffers)
    out_cache_shape = jax.eval_shape(
        step,
        params_shape,
        cache_shape,
        jax.ShapeDtypeStruct((), jnp.int32),
        batch_shape,
    )[1]
    out_cspecs = cache_specs(out_cache_shape, rules, b)
    with rules.mesh:
        lowered = jax.jit(
            step,
            in_shardings=(
                named(pspecs, rules.mesh),
                named(cspecs, rules.mesh),
                None,
                named(bspecs, rules.mesh),
            ),
            out_shardings=(None, named(out_cspecs, rules.mesh)),
            donate_argnums=(1,),
        ).lower(
            params_shape,
            cache_shape,
            jax.ShapeDtypeStruct((), jnp.int32),
            batch_shape,
        )
    return lowered, params_shape, cache_shape


def lower_prefill(arch_cfg, rules: ShardingRules, shape_spec):
    backbone = get_backbone(arch_cfg)
    mesh_ctx = make_mesh_context(rules)
    b, s = shape_spec.global_batch, shape_spec.seq_len
    params_shape = jax.eval_shape(
        lambda k: backbone.init_params(k, arch_cfg, mesh_ctx),
        jax.random.PRNGKey(0),
    )
    batch_shape = prefill_batch_shape(arch_cfg, shape_spec)
    pspecs = param_specs(params_shape, rules)
    bspecs = batch_specs(batch_shape, rules)

    def step(params, batch):
        return backbone.prefill(params, batch, arch_cfg, mesh_ctx)

    out_cache_shape = jax.eval_shape(step, params_shape, batch_shape)[1]
    out_cspecs = cache_specs(out_cache_shape, rules, b)
    with rules.mesh:
        lowered = jax.jit(
            step,
            in_shardings=(
                named(pspecs, rules.mesh),
                named(bspecs, rules.mesh),
            ),
            out_shardings=(None, named(out_cspecs, rules.mesh)),
        ).lower(params_shape, batch_shape)
    return lowered, params_shape


# --------------------------------------------------------------------------
# Streaming KWS serving (the paper's own deployment shape)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class StreamState:
    stream_id: int
    scores: Optional[np.ndarray] = None  # smoothed class scores


class StreamingKWSServer:
    """Batched frame-synchronous KWS over N concurrent audio streams.

    Each frame tick: callers push, per active stream, either one FV_Norm
    (C,) or one raw 16 ms audio hop (`pipeline.chunk_samples` samples at
    fs_audio) — the kinds may not be mixed within one tick. Raw audio is
    converted by the pipeline's registered frontend with per-stream
    filter/SRO carry; then the server runs ONE batched GRU step for all
    streams (the accelerator's Fig. 4 timing, vectorized across streams)
    and returns per-stream smoothed posteriors + argmax.
    """

    def __init__(self, pipeline, params, max_streams: int = 256,
                 smoothing: float = 0.7, state=None):
        self.pipeline = pipeline
        self.params = params
        self.max_streams = max_streams
        self.smoothing = smoothing
        # frontend state (norm stats / calibration); default = the
        # pipeline's bound state
        self.frontend_state = (
            pipeline.state if state is None else state
        )
        self.states = pipeline.streaming_init(max_streams)
        self.feat_carry = pipeline.streaming_features_init(max_streams)
        self.active: Dict[int, int] = {}  # stream_id -> slot
        self.scores = np.zeros(
            (max_streams, pipeline.config.gru.num_classes), np.float32
        )
        self._free = list(range(max_streams))[::-1]

    def open_stream(self, stream_id: int):
        if not self._free:
            raise RuntimeError("server at capacity")
        slot = self._free.pop()
        self.active[stream_id] = slot
        for i, h in enumerate(self.states):
            self.states[i] = h.at[slot].set(0.0)
        self.feat_carry = jax.tree_util.tree_map(
            lambda t: t.at[slot].set(0.0), self.feat_carry
        )
        self.scores[slot] = 0.0

    def close_stream(self, stream_id: int):
        slot = self.active.pop(stream_id)
        self._free.append(slot)

    def _features_tick(self, chunks: Dict[int, np.ndarray]) -> np.ndarray:
        """Raw audio hops -> FV_Norm frames via the frontend (batched).

        The per-stream filter/SRO carry advances only for streams that
        submitted audio this tick — a stream skipping a tick resumes
        from its own contiguous state, not from a fabricated silent hop.
        """
        s = self.pipeline.chunk_samples
        audio = np.zeros((self.max_streams, s), np.float32)
        mask = np.zeros((self.max_streams,), bool)
        for sid, chunk in chunks.items():
            audio[self.active[sid]] = chunk
            mask[self.active[sid]] = True
        new_carry, fv = self.pipeline.streaming_features_step(
            self.feat_carry, jnp.asarray(audio), self.frontend_state
        )
        m = jnp.asarray(mask)[:, None]
        self.feat_carry = jax.tree_util.tree_map(
            lambda new, old: jnp.where(m, new, old),
            new_carry, self.feat_carry,
        )
        return np.asarray(fv)

    def step(self, frames: Dict[int, np.ndarray]) -> Dict[int, dict]:
        """frames: stream_id -> FV_Norm (C,) or raw audio hop (S,).

        One 16 ms tick. Inputs are raw audio when their trailing dim is
        `pipeline.chunk_samples` (e.g. 256 @ 16 kHz), FV_Norm when it is
        `fex.num_channels` (e.g. 16) — the two never collide for the
        paper's geometry."""
        c = self.pipeline.config.fex.num_channels
        hop = self.pipeline.chunk_samples
        dim = next(iter(frames.values())).shape[-1] if frames else c
        if dim == hop:
            fv_all = self._features_tick(frames)
            fv = np.zeros((self.max_streams, c), np.float32)
            for sid in frames:
                fv[self.active[sid]] = fv_all[self.active[sid]]
        elif dim == c:
            fv = np.zeros((self.max_streams, c), np.float32)
            for sid, frame in frames.items():
                fv[self.active[sid]] = frame
        else:
            raise ValueError(
                f"per-stream input must be an FV_Norm frame ({c},) or a "
                f"raw audio hop ({hop},); got trailing dim {dim}"
            )
        self.states, logits = self.pipeline.streaming_step(
            self.params, self.states, jnp.asarray(fv)
        )
        logits = np.asarray(logits)
        out = {}
        for sid in frames:
            slot = self.active[sid]
            p = np.exp(logits[slot] - logits[slot].max())
            p /= p.sum()
            self.scores[slot] = (
                self.smoothing * self.scores[slot]
                + (1 - self.smoothing) * p
            )
            out[sid] = {
                "probs": self.scores[slot].copy(),
                "top": int(self.scores[slot].argmax()),
            }
        return out
