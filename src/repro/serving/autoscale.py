"""Slab routing for sharded stream serving: stream_id -> (shard, slot).

A sharded `StreamingKWSServer` splits its slot axis block-wise over a
1-D ``("stream",)`` device mesh (`repro.distributed.sharding.
stream_mesh`): global slots ``[k * slots_per_shard, (k + 1) *
slots_per_shard)`` live on shard ``k``. Slot assignment therefore IS
device placement, and a naive first-free allocation would pile every
early stream onto shard 0 while the other devices idle.

`StreamRouter` owns that assignment: `acquire` hands out the lowest
free local slot on the least-loaded shard (ties to the lowest shard
id), so concurrent streams spread round-robin across the mesh and the
per-device batch stays balanced at any occupancy. With ``n_shards=1``
it degrades to exactly the pre-sharding free list (lowest slot first)
— the single-device server's slot order is unchanged.

The router is pure host-side bookkeeping — deterministic, no device
code — so a pure-Python lifecycle oracle can replay any open/close
schedule and predict placement exactly (tests/test_serve_sharded.py).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import List

__all__ = [
    "SlotPlacement",
    "StreamRouter",
    "shard_of_slot",
]


def shard_of_slot(slot: int, max_streams: int, n_shards: int) -> int:
    """Shard owning a global slot under block-wise ("stream",) sharding."""
    if not 0 <= slot < max_streams:
        raise ValueError(f"slot {slot} outside [0, {max_streams})")
    return slot // (max_streams // n_shards)


@dataclasses.dataclass(frozen=True)
class SlotPlacement:
    """Where a global slot lives on the mesh."""

    shard: int
    local_slot: int
    slot: int  # global: shard * slots_per_shard + local_slot


class StreamRouter:
    """Balanced slot allocator over ``n_shards`` equal shard blocks."""

    def __init__(self, max_streams: int, n_shards: int = 1):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if max_streams % n_shards != 0:
            raise ValueError(
                f"max_streams={max_streams} must divide evenly over "
                f"{n_shards} shard(s)"
            )
        self.max_streams = max_streams
        self.n_shards = n_shards
        self.slots_per_shard = max_streams // n_shards
        self._free: List[List[int]] = [
            list(range(self.slots_per_shard)) for _ in range(n_shards)
        ]
        for f in self._free:
            heapq.heapify(f)

    @property
    def free_count(self) -> int:
        return sum(len(f) for f in self._free)

    def shard_loads(self) -> List[int]:
        """Open slots per shard (the balance the round-robin fill keeps)."""
        return [self.slots_per_shard - len(f) for f in self._free]

    def placement(self, slot: int) -> SlotPlacement:
        shard = shard_of_slot(slot, self.max_streams, self.n_shards)
        return SlotPlacement(
            shard=shard,
            local_slot=slot - shard * self.slots_per_shard,
            slot=slot,
        )

    def acquire(self) -> int:
        """Lowest free local slot on the least-loaded shard (ties to the
        lowest shard id). Raises RuntimeError at capacity."""
        best = None
        for shard, free in enumerate(self._free):
            if not free:
                continue
            load = self.slots_per_shard - len(free)
            if best is None or load < best[0]:
                best = (load, shard)
        if best is None:
            raise RuntimeError("server at capacity")
        shard = best[1]
        local = heapq.heappop(self._free[shard])
        return shard * self.slots_per_shard + local

    def release(self, slot: int) -> None:
        p = self.placement(slot)
        if p.local_slot in self._free[p.shard]:
            raise ValueError(f"slot {slot} already free")
        heapq.heappush(self._free[p.shard], p.local_slot)
