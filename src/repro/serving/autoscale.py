"""Slab routing for sharded stream serving: stream_id -> (shard, slot).

A sharded `StreamingKWSServer` splits its slot axis block-wise over a
1-D ``("stream",)`` device mesh (`repro.distributed.sharding.
stream_mesh`): global slots ``[k * slots_per_shard, (k + 1) *
slots_per_shard)`` live on shard ``k``. Slot assignment therefore IS
device placement, and a naive first-free allocation would pile every
early stream onto shard 0 while the other devices idle.

`StreamRouter` owns that assignment: `acquire` hands out the lowest
free local slot on the least-loaded shard (ties to the lowest shard
id), so concurrent streams spread round-robin across the mesh and the
per-device batch stays balanced at any occupancy. With ``n_shards=1``
it degrades to exactly the pre-sharding free list (lowest slot first)
— the single-device server's slot order is unchanged.

The router is pure host-side bookkeeping — deterministic, no device
code — so a pure-Python lifecycle oracle can replay any open/close
schedule and predict placement exactly (tests/test_serve_sharded.py).

`Autoscaler` closes the loop from telemetry to capacity: it watches
occupancy (open slots / capacity) and per-tick latency (through a
`repro.distributed.fault_tolerance.StragglerMonitor`) and calls
`StreamingKWSServer.resize` under hysteresis — grow when occupancy
holds above the high watermark (or an open is rejected at capacity),
shrink when it holds below the low watermark AND the latency SLO is
healthy (shrinking packs more streams per device, so a breached SLO
vetoes it). Every decision is deterministic host-side policy; the
resize itself is the server's bitwise-exact reshard.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional

__all__ = [
    "SlotPlacement",
    "StreamRouter",
    "shard_of_slot",
    "AutoscalePolicy",
    "Autoscaler",
]


def shard_of_slot(slot: int, max_streams: int, n_shards: int) -> int:
    """Shard owning a global slot under block-wise ("stream",) sharding.

    Validates the geometry itself: `StreamRouter.__init__` guards the
    divisibility, but direct callers used to get silently-truncated
    `max_streams // n_shards` blocks (and therefore wrong shards) when
    the division wasn't even.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if max_streams % n_shards != 0:
        raise ValueError(
            f"max_streams={max_streams} must divide evenly over "
            f"{n_shards} shard(s)"
        )
    if not 0 <= slot < max_streams:
        raise ValueError(f"slot {slot} outside [0, {max_streams})")
    return slot // (max_streams // n_shards)


@dataclasses.dataclass(frozen=True)
class SlotPlacement:
    """Where a global slot lives on the mesh."""

    shard: int
    local_slot: int
    slot: int  # global: shard * slots_per_shard + local_slot


class StreamRouter:
    """Balanced slot allocator over ``n_shards`` equal shard blocks."""

    def __init__(self, max_streams: int, n_shards: int = 1):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if max_streams % n_shards != 0:
            raise ValueError(
                f"max_streams={max_streams} must divide evenly over "
                f"{n_shards} shard(s)"
            )
        self.max_streams = max_streams
        self.n_shards = n_shards
        self.slots_per_shard = max_streams // n_shards
        self._free: List[List[int]] = [
            list(range(self.slots_per_shard)) for _ in range(n_shards)
        ]
        for f in self._free:
            heapq.heapify(f)

    @property
    def free_count(self) -> int:
        return sum(len(f) for f in self._free)

    def shard_loads(self) -> List[int]:
        """Open slots per shard (the balance the round-robin fill keeps)."""
        return [self.slots_per_shard - len(f) for f in self._free]

    def placement(self, slot: int) -> SlotPlacement:
        shard = shard_of_slot(slot, self.max_streams, self.n_shards)
        return SlotPlacement(
            shard=shard,
            local_slot=slot - shard * self.slots_per_shard,
            slot=slot,
        )

    def acquire(self) -> int:
        """Lowest free local slot on the least-loaded shard (ties to the
        lowest shard id). Raises RuntimeError at capacity."""
        best = None
        for shard, free in enumerate(self._free):
            if not free:
                continue
            load = self.slots_per_shard - len(free)
            if best is None or load < best[0]:
                best = (load, shard)
        if best is None:
            raise RuntimeError("server at capacity")
        shard = best[1]
        local = heapq.heappop(self._free[shard])
        return shard * self.slots_per_shard + local

    def release(self, slot: int) -> None:
        p = self.placement(slot)
        if p.local_slot in self._free[p.shard]:
            raise ValueError(f"slot {slot} already free")
        heapq.heappush(self._free[p.shard], p.local_slot)

    @classmethod
    def remap(
        cls,
        occupied: List[int],
        new_max_streams: int,
        n_shards: int = 1,
    ) -> "tuple[StreamRouter, Dict[int, int]]":
        """Re-place occupied slots onto a fresh router geometry.

        The resize/reshard primitive: given the occupied slots of the
        OLD layout, build a new router at ``new_max_streams`` over
        ``n_shards`` and acquire one slot per occupied old slot, in
        ascending old-slot order (deterministic — the lifecycle oracle
        reimplements exactly this). Returns ``(router, {old_slot:
        new_slot})``; the router is left with every mapped slot
        acquired, so subsequent `acquire` calls continue the balanced
        round-robin fill. Raises ValueError when the occupied slots
        outnumber the new capacity (a shrink below the live stream
        count must be rejected before any state moves).
        """
        if len(occupied) > new_max_streams:
            raise ValueError(
                f"cannot remap {len(occupied)} occupied slot(s) into "
                f"capacity {new_max_streams}"
            )
        if len(set(occupied)) != len(occupied):
            raise ValueError("occupied slots must be unique")
        router = cls(new_max_streams, n_shards)
        mapping = {old: router.acquire() for old in sorted(occupied)}
        return router, mapping


# --------------------------------------------------------------------------
# Occupancy/SLO-driven autoscaling
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AutoscalePolicy:
    """When to grow/shrink a server's slot capacity.

    grow_at / shrink_at   occupancy watermarks (open / capacity). The
                          band between them is the hysteresis dead
                          zone — a fleet oscillating around one
                          threshold never resizes.
    hysteresis_ticks      consecutive observations beyond a watermark
                          before acting (transient spikes don't flap
                          capacity).
    cooldown_ticks        observations to ignore after any resize
                          (resharding has a real pause cost; back-to-
                          back actions are never warranted).
    factor                grow multiplies capacity by it, shrink
                          divides (the slot axis doubles/halves, so
                          the mesh block layout stays even).
    min_streams /
    max_streams           hard capacity bounds (both must divide over
                          the server's shard count).
    """

    min_streams: int = 8
    max_streams: int = 1024
    grow_at: float = 0.85
    shrink_at: float = 0.30
    hysteresis_ticks: int = 4
    cooldown_ticks: int = 16
    factor: int = 2

    def __post_init__(self):
        if not 0.0 < self.shrink_at < self.grow_at <= 1.0:
            raise ValueError(
                f"need 0 < shrink_at < grow_at <= 1; got "
                f"shrink_at={self.shrink_at}, grow_at={self.grow_at}"
            )
        if self.min_streams < 1 or self.max_streams < self.min_streams:
            raise ValueError(
                f"need 1 <= min_streams <= max_streams; got "
                f"{self.min_streams}, {self.max_streams}"
            )
        if self.factor < 2:
            raise ValueError(f"factor must be >= 2, got {self.factor}")
        if self.hysteresis_ticks < 1 or self.cooldown_ticks < 0:
            raise ValueError("hysteresis_ticks >= 1, cooldown_ticks >= 0")


class Autoscaler:
    """Occupancy/SLO-driven capacity control for a `StreamingKWSServer`.

    Call `observe(tick_seconds)` once per serving tick (tick_seconds
    optional — without it only occupancy drives decisions) and
    `note_rejection()` whenever `open_stream` raised at capacity.
    `observe` returns ``"grow"`` / ``"shrink"`` when it resized the
    server this call, else None.

    Policy:
      * grow  — occupancy >= grow_at for hysteresis_ticks consecutive
                observations, OR any rejected open since the last
                observation (a rejection is a hard signal; it still
                respects the cooldown and the max_streams cap).
      * shrink — occupancy <= shrink_at for hysteresis_ticks AND the
                latency SLO is healthy: the `StragglerMonitor` (see
                `repro.distributed.fault_tolerance`; jit-warmup steps
                excluded via its ``warmup``) has no active straggler
                streak. Shrinking packs more streams per device, so a
                breached SLO vetoes it. The shrink target is clamped
                so open streams always fit.
      * both  — only in multiples of the server's device count, never
                within cooldown_ticks of the previous action.

    History: every applied resize appends to `events` ({step, action,
    from, to, reason}) and becomes `last_decision` — reason is
    ``"rejection"`` or ``"occupancy_watermark"`` for grows,
    ``"occupancy_watermark"`` for shrinks. A shrink the SLO vetoed is
    recorded as `last_decision` (and journaled) with action
    ``"hold"`` / reason ``"slo_veto"`` once per hysteresis trip, so
    "why didn't it shrink?" is answerable. When the server carries a
    `metrics=` registry, every decision (applied or vetoed) is also
    journaled as an ``"autoscale"`` event with before/after capacity
    and counted in ``kws_autoscale_decisions_total{action=...}``.
    """

    def __init__(self, server, policy: Optional[AutoscalePolicy] = None,
                 monitor=None):
        if monitor is None:
            from repro.distributed.fault_tolerance import StragglerMonitor

            monitor = StragglerMonitor()
        self.server = server
        self.policy = policy or AutoscalePolicy()
        self.monitor = monitor
        self.metrics = getattr(server, "metrics", None)
        self._step = 0
        self._above = 0
        self._below = 0
        self._cooldown = 0
        self._rejections = 0
        self.events: List[dict] = []  # {step, action, from, to, reason}
        self.last_decision: Optional[dict] = None

    @property
    def occupancy(self) -> float:
        return len(self.server.active) / self.server.max_streams

    def note_rejection(self) -> None:
        """An `open_stream` was refused at capacity — the strongest
        grow signal there is."""
        self._rejections += 1

    def _record(self, action: str, reason: str, frm: int,
                to: int) -> dict:
        decision = {
            "step": self._step, "action": action, "from": frm,
            "to": to, "reason": reason,
        }
        self.last_decision = decision
        if self.metrics is not None:
            self.metrics.journal.append(
                "autoscale", step=self._step, action=action,
                reason=reason, from_streams=frm, to_streams=to,
                open_streams=len(self.server.active),
            )
            self.metrics.counter(
                "kws_autoscale_decisions_total",
                "autoscaler decisions by outcome",
                action=action,
            ).inc()
        return decision

    def _resize(self, action: str, target: int,
                reason: str) -> Optional[str]:
        if target == self.server.max_streams:
            return None
        frm = self.server.max_streams
        self.server.resize(target)
        self.events.append(self._record(action, reason, frm, target))
        self._above = self._below = 0
        self._rejections = 0
        self._cooldown = self.policy.cooldown_ticks
        return action

    def observe(self, tick_seconds: Optional[float] = None
                ) -> Optional[str]:
        pol = self.policy
        slo_breach = False
        if tick_seconds is not None:
            slo_breach = self.monitor.record(self._step, tick_seconds)
        self._step += 1
        occ = self.occupancy
        if occ >= pol.grow_at:
            self._above += 1
            self._below = 0
        elif occ <= pol.shrink_at:
            self._below += 1
            self._above = 0
        else:
            self._above = self._below = 0
        if self._cooldown > 0:
            self._cooldown -= 1
            return None
        n_dev = self.server.n_devices
        cap = self.server.max_streams
        if self._rejections or self._above >= pol.hysteresis_ticks:
            reason = (
                "rejection" if self._rejections
                else "occupancy_watermark"
            )
            target = min(cap * pol.factor, pol.max_streams)
            target -= target % n_dev
            if target > cap:
                return self._resize("grow", target, reason)
            self._rejections = 0  # at the cap: nothing to do, stop
            return None           # re-firing every observation
        slo_unhealthy = slo_breach or self.monitor.consecutive > 0
        if self._below >= pol.hysteresis_ticks:
            if slo_unhealthy:
                # record the veto once per hysteresis trip (the
                # condition re-fires every low-occupancy tick; the
                # FIRST qualifying one is the decision point)
                if self._below == pol.hysteresis_ticks:
                    self._record("hold", "slo_veto", cap, cap)
                return None
            target = max(cap // pol.factor, pol.min_streams)
            # open streams must fit, in whole per-shard blocks
            floor = -(-len(self.server.active) // n_dev) * n_dev
            target = max(target, floor, n_dev)
            target -= target % n_dev
            if 0 < target < cap:
                return self._resize("shrink", target,
                                    "occupancy_watermark")
        return None
