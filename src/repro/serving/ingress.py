"""Async double-buffered ingress for the streaming KWS server.

The fused tick (`repro.serving.serve_loop._fused_tick`) is one device
program, but the live `step_batch` path around it is synchronous: build
the slab, dispatch, then BLOCK on the device-to-host score fetch before
the next tick may even be assembled. On an async-dispatch backend the
device is idle while the host stages the next slab and the host is idle
while the device computes — which is exactly the live-vs-scan
throughput gap `BENCH_serve.json` measures (the `lax.scan` replay never
returns to the host between ticks).

This module closes that gap without touching the tick itself:

  * `TickHandle` — the deferred result of one dispatched tick. The
    server hands it back immediately after (non-blocking) dispatch; the
    scores materialize on first `result()`. The handle owns device-side
    copies of the tick's outputs, so it stays valid however many later
    ticks donate the `ServerState` buffers the raw outputs alias — a
    handle fetched two ticks late reads exactly what a synchronous
    fetch would have.
  * `PipelinedIngress` — preallocated ping-pong host staging. `stage()`
    hands out a (slab, mask) buffer pair to assemble the next tick into
    while the previous tick is still in flight; `commit()` dispatches
    it via `StreamingKWSServer.step_batch_async`. A buffer is reused
    only after the tick that consumed it has been forced to completion
    (the `depth`-deep FIFO), so host writes can never race the device's
    read of a staged slab. `window=K` coalesces K committed ticks into
    one `run_batch_async` scan dispatch — the fixed per-dispatch host
    cost amortizes K-fold at (K-1) ticks of added latency.
  * `TickCoalescer` — micro-batched arrival merging: per-stream frames
    arriving within one 16 ms window coalesce into a single staged
    tick, flushed when every open stream has submitted, when the window
    deadline passes (`poll`), or when a stream submits a second frame
    (which by definition belongs to the next tick).

The pipelined path is BIT-identical to the synchronous `step_batch`
sequence: it dispatches the same jitted program on the same operands in
the same order — only the host-side fetch moves later in time
(tests/test_serve_async.py proves it for every classifier backend,
cascaded and sharded included).
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "TickHandle",
    "PipelinedIngress",
    "TickCoalescer",
    "CoalescedTick",
]


class TickHandle:
    """Deferred result of one asynchronously dispatched serving tick.

    Holds device-side OWNED copies of the tick's (scores, top) outputs
    — never the raw tick outputs, which can alias `ServerState` buffers
    that the NEXT tick donates. `result()` blocks until the tick (and
    the copy chained behind it) has executed, materializes owned host
    arrays, and caches them; the device arrays are dropped at that
    point so steady-state serving holds at most `depth` tick outputs.

    `meta` is caller-owned freight (e.g. a submit timestamp or the
    {stream_id: slot} map of a coalesced tick); `done_at` records the
    host clock at the EARLIEST moment the tick was observed complete —
    the first `ready() == True` poll, or the end of the first
    `result()` when nobody polled — for SLO-style latency accounting.
    (It used to be stamped only inside `result()`, so a consumer that
    polled `ready()` and fetched later recorded the fetch time, not
    the completion time, inflating its submit-to-scores latency.)

    `fetch_hist`, when given, is a `repro.serving.metrics.Histogram`
    that receives the milliseconds the first `result()` spent blocked
    materializing host arrays (the server wires its
    ``kws_serve_tick_fetch_ms`` here when metrics are enabled).
    """

    __slots__ = ("_scores", "_top", "_host", "meta", "done_at",
                 "_fetch_hist", "_clock")

    def __init__(self, scores, top, meta: Any = None, fetch_hist=None,
                 clock: Callable[[], float] = time.perf_counter):
        self._scores = scores
        self._top = top
        self._host: Optional[Tuple[np.ndarray, np.ndarray]] = None
        self.meta = meta
        self.done_at: Optional[float] = None
        self._fetch_hist = fetch_hist
        self._clock = clock

    def ready(self) -> bool:
        """True when the tick has finished executing (non-blocking).
        The first True poll stamps `done_at`."""
        if self._host is not None:
            return True
        try:
            ok = bool(self._scores.is_ready() and self._top.is_ready())
        except AttributeError:  # non-jax array stand-ins
            ok = True
        if ok and self.done_at is None:
            self.done_at = self._clock()
        return ok

    def result(self) -> Tuple[np.ndarray, np.ndarray]:
        """(scores (N, K), top (N,)) as owned host arrays; blocks until
        the tick has executed. Idempotent — later calls return the
        cached copy, so fetching a handle after further ticks (or slot
        resets) ran is always safe."""
        if self._host is None:
            t0 = self._clock()
            self._host = (np.array(self._scores), np.array(self._top))
            self._scores = self._top = None
            t1 = self._clock()
            if self.done_at is None:
                self.done_at = t1
            if self._fetch_hist is not None:
                self._fetch_hist.observe((t1 - t0) * 1e3)
        return self._host

    @property
    def scores(self) -> np.ndarray:
        return self.result()[0]

    @property
    def top(self) -> np.ndarray:
        return self.result()[1]


class PipelinedIngress:
    """Double-buffered slab staging over the server's async dispatch.

    `depth` preallocated (slab, mask) host buffer pairs cycle
    round-robin; at most `depth` dispatches are in flight. `stage()`
    returns the next pair for the caller to assemble a tick into —
    forcing the dispatch that consumed this buffer `depth` cycles ago
    to completion first, which both bounds the pipeline and guarantees
    the buffer being handed out is no longer being read by the device.
    `commit()` dispatches without blocking. Completed handles
    accumulate in FIFO order; collect them with `retired()` or force
    everything with `drain()`.

    depth=1 degrades to the synchronous cadence (every dispatch
    completes before the next is staged); depth=2 is classic double
    buffering — host staging of tick N+1 overlaps device execution of
    tick N.

    `window` is the throughput/latency knob: with window=1 (default)
    every `commit()` dispatches one fused tick via `step_batch_async`
    and `handle.meta` is that tick's meta. With window=K, K
    consecutively committed ticks coalesce into ONE device dispatch
    (`run_batch_async`: a length-K scan of the same fused tick body,
    bit-identical to K sequential ticks) — amortizing the fixed
    per-dispatch host cost K-fold, which is what closes the
    live-vs-scan throughput gap on a dispatch-bound host. The window's
    handle materializes (K, N, C) scores / (K, N) tops, `handle.meta`
    is the list of the K per-tick metas in commit order, and a tick's
    scores arrive only when its window flushes — at a 16 ms tick
    cadence that bounds added latency at (K-1) ticks, so keep K small
    (2-8) for live serving. `commit()` returns the handle on the
    window-filling commit and None otherwise; `flush()` force-
    dispatches a partial window (scan length = ticks staged so far).
    """

    def __init__(self, server, dim: int, depth: int = 2,
                 window: int = 1):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        server._is_raw(int(dim))  # canonical kind validation, up front
        self.server = server
        self.dim = int(dim)
        self.depth = depth
        self.window = window
        n = server.max_streams
        self._slabs = [
            np.zeros((window, n, self.dim), np.float32)
            for _ in range(depth)
        ]
        self._masks = [
            np.zeros((window, n), bool) for _ in range(depth)
        ]
        # (buffer index, handle, traces) in dispatch order; len <= depth
        self._fifo: collections.deque = collections.deque()
        self._retired: List[TickHandle] = []
        self._cursor = 0
        self._fill = 0  # ticks staged+committed into the cursor buffer
        self._metas: List[Any] = []
        self._staged = False
        # observability rides the server's registry: one TickTrace per
        # STAGED tick (stage -> commit -> dispatch -> retire marks; a
        # window of K ticks shares the dispatch/retire timestamps of
        # its one device call), plus in-flight / pending-window gauges.
        # All host clock reads around the existing calls — operands and
        # dispatch order are untouched, so the pipelined path stays
        # bit-identical with metrics on.
        self.metrics = getattr(server, "metrics", None)
        self._seq = 0
        self._cur_trace = None
        self._traces: List[Any] = []  # committed, awaiting dispatch
        if self.metrics is not None:
            self._m_in_flight = self.metrics.gauge(
                "kws_ingress_in_flight",
                "device dispatches in flight (<= depth)",
            )
            self._m_pending = self.metrics.gauge(
                "kws_ingress_pending_ticks",
                "ticks committed into the current window, undispatched",
            )
            self._m_dispatches = self.metrics.counter(
                "kws_ingress_dispatches_total",
                "device dispatches issued by the pipelined ingress",
            )

    @property
    def in_flight(self) -> int:
        return len(self._fifo)

    @property
    def pending_ticks(self) -> int:
        """Ticks committed into the current window but not dispatched."""
        return self._fill

    def stage(self) -> Tuple[np.ndarray, np.ndarray]:
        """Next (slab, mask) staging pair, mask cleared. Blocks only
        when the pipeline is full (forces the oldest in-flight
        dispatch)."""
        if self._staged:
            raise RuntimeError("stage() called again before commit()")
        n = self.server.max_streams
        if n != self._slabs[0].shape[1]:
            # The server was resized (autoscaler / shard-loss
            # recovery): the preallocated buffers are the wrong
            # capacity. Reallocating is only safe with the pipeline
            # empty — in-flight dispatches and half-filled windows
            # still hold old-capacity slabs — so callers drain()
            # around a resize and the next stage() picks up the new
            # capacity here.
            if self._fifo or self._fill:
                raise RuntimeError(
                    "server capacity changed mid-pipeline: drain() "
                    "the ingress before staging into the resized "
                    "server"
                )
            self._slabs = [
                np.zeros((self.window, n, self.dim), np.float32)
                for _ in range(self.depth)
            ]
            self._masks = [
                np.zeros((self.window, n), bool)
                for _ in range(self.depth)
            ]
        i = self._cursor
        if self._fill == 0:
            # about to write row 0 of buffer i: the dispatch that
            # consumed it (if any) is the FIFO front — buffers cycle
            # round-robin and retire in dispatch order
            while self._fifo and self._fifo[0][0] == i:
                self._retire(*self._fifo.popleft()[1:])
        self._staged = True
        if self.metrics is not None:
            tr = self.metrics.trace(("tick", self._seq))
            self._seq += 1
            tr.mark("stage")
            self._cur_trace = tr
        mask = self._masks[i][self._fill]
        mask[:] = False
        return self._slabs[i][self._fill], mask

    def commit(self, meta: Any = None) -> Optional[TickHandle]:
        """Commit the staged tick; dispatches (non-blocking) when the
        window is full. Returns the window's handle on the dispatching
        commit, None while the window is still filling."""
        if not self._staged:
            raise RuntimeError("commit() without a prior stage()")
        self._staged = False
        self._metas.append(meta)
        if self._cur_trace is not None:
            self._cur_trace.mark("commit")
            self._traces.append(self._cur_trace)
            self._cur_trace = None
            self._m_pending.set(self._fill + 1)
        self._fill += 1
        if self._fill == self.window:
            return self._dispatch()
        return None

    def flush(self) -> Optional[TickHandle]:
        """Dispatch the partially filled window now (no-op when empty).
        A partial window scans only the ticks actually staged — never
        padded no-op ticks — so the state trajectory stays identical."""
        if self._staged:
            raise RuntimeError("flush() with a stage() pending commit()")
        if self._fill == 0:
            return None
        return self._dispatch()

    def _dispatch(self) -> TickHandle:
        i, k = self._cursor, self._fill
        if self.window == 1:
            handle = self.server.step_batch_async(
                self._slabs[i][0], self._masks[i][0]
            )
            handle.meta = self._metas[0]
        else:
            handle = self.server.run_batch_async(
                self._slabs[i][:k], self._masks[i][:k]
            )
            handle.meta = list(self._metas)
        traces, self._traces = self._traces, []
        if traces:
            # one device call serves the whole window: its ticks share
            # the dispatch timestamp (and, at retire, done_at)
            t = self.metrics.clock()
            for tr in traces:
                tr.mark("dispatch", t)
        if self.metrics is not None:
            self._m_dispatches.inc()
            self._m_in_flight.set(len(self._fifo) + 1)
            self._m_pending.set(0)
        self._fifo.append((i, handle, traces))
        self._cursor = (i + 1) % self.depth
        self._fill = 0
        self._metas = []
        return handle

    def _retire(self, h: TickHandle, traces) -> None:
        """Force one in-flight dispatch to completion and collect it."""
        h.result()
        if traces:
            for tr in traces:
                tr.mark("retire", h.done_at)
        if self.metrics is not None:
            self._m_in_flight.set(len(self._fifo))
        self._retired.append(h)

    def retired(self) -> List[TickHandle]:
        """Handles forced to completion so far, in dispatch order
        (clears the internal list)."""
        out, self._retired = self._retired, []
        return out

    def drain(self) -> List[TickHandle]:
        """Flush the pending window, force every in-flight dispatch,
        and return ALL completed handles (previously retired +
        just-drained), in dispatch order."""
        self.flush()
        while self._fifo:
            self._retire(*self._fifo.popleft()[1:])
        return self.retired()


@dataclasses.dataclass
class CoalescedTick:
    """Meta freight of one coalesced tick's handle: which streams
    submitted (and the slot each occupied AT DISPATCH TIME — the
    mapping to index the handle's score rows with, immune to later
    close/reopen), plus the window's host timestamps."""

    sids: Dict[int, int]
    staged_at: float
    flushed_at: Optional[float] = None


class TickCoalescer:
    """Merge sub-window per-stream arrivals into single dispatched ticks.

    Live traffic rarely arrives slab-shaped: each stream's 16 ms hop
    lands on its own schedule. Dispatching a full-slab tick per arrival
    wastes the batch; waiting for stragglers forever stalls it. The
    coalescer stages arrivals into one pending tick and flushes it when

      * every open stream has submitted (the tick is full),
      * the window deadline (`window_ms` after the first arrival)
        passes — checked by `poll()`, or
      * a stream submits a SECOND frame (which belongs to the next
        tick: the pending one flushes first, then the new frame opens
        the next window).

    Flushing dispatches through a per-kind `PipelinedIngress`, so
    coalescing composes with double buffering: the flushed tick's
    handle materializes while the next window fills. Completed handles
    (meta = `CoalescedTick`) are collected via `retired()` / `drain()`.

    `clock` is injectable for deterministic tests; `now` may also be
    passed explicitly to `add`/`poll`/`flush`.
    """

    def __init__(self, server, window_ms: float = 16.0, depth: int = 2,
                 clock: Callable[[], float] = time.monotonic):
        if window_ms <= 0:
            raise ValueError(f"window_ms must be > 0, got {window_ms}")
        self.server = server
        self.window_s = window_ms * 1e-3
        self.depth = depth
        self.clock = clock
        self._ingress: Dict[int, PipelinedIngress] = {}
        self._pending = None  # (ingress, slab, mask, CoalescedTick, deadline)
        self._retired: List[TickHandle] = []
        # per-reason flush counters on the server's registry: "full"
        # (every open stream submitted), "deadline" (window_ms passed),
        # "second_frame" (a stream's next-tick frame forced the flush),
        # "manual" (caller flush()/drain())
        self.metrics = getattr(server, "metrics", None)

    @property
    def pending_streams(self) -> int:
        """Streams staged in the currently open window (0 = no window)."""
        return 0 if self._pending is None else len(self._pending[3].sids)

    def add(self, stream_id: int, frame, now: Optional[float] = None
            ) -> List[TickHandle]:
        """Stage one stream's frame; returns any handles this call
        retired (a second-frame or tick-full flush may complete older
        ticks)."""
        now = self.clock() if now is None else now
        if stream_id not in self.server.active:
            raise ValueError(f"stream {stream_id} not open")
        frame = np.asarray(frame, np.float32)
        dim = int(frame.shape[-1])
        self.server._is_raw(dim)  # canonical kind/width validation
        if self._pending is not None and self._pending[0].dim != dim:
            raise ValueError(
                "all frames in one tick must be the same kind; pending "
                f"window holds dim {self._pending[0].dim}, got {dim} "
                "(flush() the window before switching kinds)"
            )
        if self._pending is not None and stream_id in self._pending[3].sids:
            # a stream's second frame belongs to the NEXT tick
            self._flush("second_frame", now)
        if self._pending is None:
            ing = self._ingress.get(dim)
            if ing is None:
                ing = PipelinedIngress(self.server, dim, depth=self.depth)
                self._ingress[dim] = ing
            slab, mask = ing.stage()
            meta = CoalescedTick(sids={}, staged_at=now)
            self._pending = (ing, slab, mask, meta, now + self.window_s)
        ing, slab, mask, meta, _deadline = self._pending
        slot = self.server.active[stream_id]
        slab[slot] = frame
        mask[slot] = True
        meta.sids[stream_id] = slot
        if len(meta.sids) >= len(self.server.active):
            self._flush("full", now)
        return self.retired()

    def poll(self, now: Optional[float] = None) -> List[TickHandle]:
        """Flush the pending window iff its deadline has passed; returns
        handles retired so far either way."""
        now = self.clock() if now is None else now
        if self._pending is not None and now >= self._pending[4]:
            self._flush("deadline", now)
        return self.retired()

    def flush(self, now: Optional[float] = None) -> Optional[TickHandle]:
        """Dispatch the pending window as one tick (no-op when empty)."""
        return self._flush("manual", now)

    def _flush(self, reason: str, now: Optional[float] = None
               ) -> Optional[TickHandle]:
        if self._pending is None:
            return None
        now = self.clock() if now is None else now
        ing, _slab, _mask, meta, _deadline = self._pending
        self._pending = None
        meta.flushed_at = now
        handle = ing.commit(meta=meta)
        if self.metrics is not None:
            self.metrics.counter(
                "kws_coalescer_flushes_total",
                "coalesced-tick flushes by trigger",
                reason=reason,
            ).inc()
        self._retired.extend(ing.retired())
        return handle

    def retired(self) -> List[TickHandle]:
        """Completed handles collected so far (clears the list)."""
        for ing in self._ingress.values():
            self._retired.extend(ing.retired())
        out, self._retired = self._retired, []
        return out

    def drain(self) -> List[TickHandle]:
        """Flush the pending window and force every in-flight tick."""
        self.flush()
        for ing in self._ingress.values():
            self._retired.extend(ing.drain())
        return self.retired()
