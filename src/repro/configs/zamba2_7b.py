"""zamba2-7b [hybrid] — 81L d_model=3584, Mamba2 backbone with shared
attention blocks, ssm_state=64 [arXiv:2411.15242; unverified].

81 Mamba2 layers; a shared transformer block (32H MHA kv=32, d_ff=14336)
is applied every 6 mamba layers, alternating between 2 shared parameter
sets (Zamba2's shared-block scheme), fed concat(hidden, embedding).
Hybrid constant-state backbone -> long_500k decode runs.
"""

from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    backbone="zamba2",
    source="arXiv:2411.15242; unverified",
    n_layers=81,
    d_model=3584,
    d_ff=14336,
    vocab=32000,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    mlp_act="swiglu",
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=128),
    shared_attn_every=6,
    n_shared_blocks=2,
)
