"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) expert
d_ff=512 vocab=49155, 40 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

Experts are padded 40 -> 48 on the 16-way model axis (padded experts
routed -inf; see models/moe.py). Full attention -> long_500k skipped.
"""

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    backbone="transformer",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
    n_layers=32,
    d_model=1536,
    d_ff=512,
    vocab=49155,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    mlp_act="swiglu",
    tie_embeddings=True,
    moe=MoEConfig(
        num_experts=40,
        top_k=8,
        d_expert=512,
        capacity_factor=1.25,
    ),
    layer_pattern=("moe",),
    skip_shapes=("long_500k",),
    # 24 heads don't divide the 16-way model axis; zero-padding to 32
    # inside attention (semantics-preserving) + a head-sharding
    # constraint cuts the train memory term 7x (EXPERIMENTS.md §Perf A4)
    attn_head_pad=32,
)
