"""llava-next-mistral-7b [vlm] — Mistral-7B backbone: 32L d_model=4096
32H (GQA kv=8) d_ff=14336 vocab=32000
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

The anyres vision tower + projector is a stub per the assignment:
input_specs() provides precomputed patch embeddings interleaved with
text positions; the backbone is what we lower. Full attention ->
long_500k skipped.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    backbone="transformer",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
    n_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab=32000,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    rope_theta=1e6,
    mlp_act="swiglu",
    frontend="embedding",
    skip_shapes=("long_500k",),
)
