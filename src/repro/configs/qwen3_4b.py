"""qwen3-4b [dense] — 36L d_model=2560 32H (GQA kv=8) d_ff=9728
vocab=151936, qk_norm, head_dim 128, tied embeddings
[hf:Qwen/Qwen3-8B; hf]. Pure full attention -> long_500k skipped.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b",
    family="dense",
    backbone="transformer",
    source="hf:Qwen/Qwen3-8B; hf",
    n_layers=36,
    d_model=2560,
    d_ff=9728,
    vocab=151936,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    qk_norm=True,
    rope_theta=1e6,
    mlp_act="swiglu",
    tie_embeddings=True,
    skip_shapes=("long_500k",),
)
