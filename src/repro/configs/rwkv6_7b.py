"""rwkv6-7b (Finch) [ssm] — 32L d_model=4096 attn-free d_ff=14336
vocab=65536, data-dependent decay linear attention
[arXiv:2404.05892; hf]. Constant-state recurrence -> long_500k runs.
head size 64 (RWKV-6 standard).
"""

from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    backbone="rwkv6",
    source="arXiv:2404.05892; hf",
    n_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab=65536,
    n_heads=64,  # d_model / head size 64
    n_kv_heads=64,
    head_dim=64,
    ssm=SSMConfig(d_state=64, head_dim=64, chunk=128),
)
