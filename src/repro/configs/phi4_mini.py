"""phi4-mini-3.8b [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064, RoPE + SwiGLU + GQA, tied embeddings
[arXiv:2412.08905; hf]. Pure full attention -> long_500k skipped.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi4-mini-3.8b",
    family="dense",
    backbone="transformer",
    source="arXiv:2412.08905; hf",
    n_layers=32,
    d_model=3072,
    d_ff=8192,
    vocab=200064,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    mlp_act="swiglu",
    tie_embeddings=True,
    skip_shapes=("long_500k",),
    # 24 heads don't divide the 16-way model axis; zero-padding to 32
    # inside attention (semantics-preserving) + a head-sharding
    # constraint cuts the train memory term 7x (EXPERIMENTS.md §Perf A4)
    attn_head_pad=32,
)
