"""musicgen-medium [audio] — decoder-only over EnCodec tokens.

48L d_model=1536 24H (GQA kv=24 == MHA) d_ff=6144 vocab=2048
[arXiv:2306.05284; hf]. The EnCodec frontend is a stub: input_specs()
provides precomputed frame embeddings (assignment spec); the LM head
predicts the 2048-entry codebook. Pure full attention -> long_500k
skipped (DESIGN.md §4).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    backbone="transformer",
    source="arXiv:2306.05284; hf",
    n_layers=48,
    d_model=1536,
    d_ff=6144,
    vocab=2048,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    mlp_act="gelu",
    frontend="embedding",
    skip_shapes=("long_500k",),
    # 24 heads don't divide the 16-way model axis; zero-padding to 32
    # inside attention (semantics-preserving) + a head-sharding
    # constraint cuts the train memory term 7x (EXPERIMENTS.md §Perf A4)
    attn_head_pad=32,
)
