"""gemma2-27b [dense] — 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000 [arXiv:2408.00118; hf].

Local+global alternating attention (4096 window on local layers), attn
logit softcap 50, final logit softcap 30, GeGLU, sandwich norms, scaled
tied embeddings. The alternating 4k window makes half the stack
sub-quadratic, so long_500k decode IS exercised (the hybrid-window case
of DESIGN.md §4) — global layers use a data-axis-sharded 500k cache.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    backbone="transformer",
    source="arXiv:2408.00118; hf",
    n_layers=46,
    d_model=4608,
    d_ff=36864,
    vocab=256000,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    layer_pattern=("local", "global"),
    sliding_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    mlp_act="geglu",
    post_norms=True,
    scale_embeddings=True,
    tie_embeddings=True,
)
