"""Config registry: `--arch <id>` resolution for launcher/dry-run/tests."""

from __future__ import annotations

from typing import Dict, List

from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, SHAPES, ShapeSpec
from repro.configs.musicgen_medium import CONFIG as musicgen_medium
from repro.configs.qwen3_4b import CONFIG as qwen3_4b
from repro.configs.gemma2_27b import CONFIG as gemma2_27b
from repro.configs.codeqwen15_7b import CONFIG as codeqwen15_7b
from repro.configs.phi4_mini import CONFIG as phi4_mini
from repro.configs.zamba2_7b import CONFIG as zamba2_7b
from repro.configs.llava_next_mistral_7b import CONFIG as llava_next_mistral_7b
from repro.configs.rwkv6_7b import CONFIG as rwkv6_7b
from repro.configs.kimi_k2 import CONFIG as kimi_k2
from repro.configs.granite_moe_3b import CONFIG as granite_moe_3b

_REGISTRY: Dict[str, ArchConfig] = {
    c.name: c
    for c in [
        musicgen_medium,
        qwen3_4b,
        gemma2_27b,
        codeqwen15_7b,
        phi4_mini,
        zamba2_7b,
        llava_next_mistral_7b,
        rwkv6_7b,
        kimi_k2,
        granite_moe_3b,
    ]
}


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> List[str]:
    return sorted(_REGISTRY)


__all__ = [
    "ArchConfig", "MoEConfig", "SSMConfig", "SHAPES", "ShapeSpec",
    "get_config", "list_archs",
]
