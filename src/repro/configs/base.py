"""Config system: architecture configs + input-shape registry.

Every assigned architecture is a frozen `ArchConfig`; the dry-run /
launcher selects them by `--arch <id>` through `repro.configs.get_config`.
`reduced()` returns the same family at smoke-test scale (runs a forward +
train step on one CPU device in seconds).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp

__all__ = ["MoEConfig", "SSMConfig", "ArchConfig", "ShapeSpec", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int  # per-expert FFN hidden size
    num_shared_experts: int = 0
    first_k_dense: int = 0  # leading dense layers (DeepSeek/Kimi style)
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    # tokens-per-shard * top_k below which EP switches to the
    # weights-stationary path (tokens move, experts stay; see
    # models/moe.py). 0 disables.
    stationary_threshold: int = 4096


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD block parameters."""

    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128  # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    # identity
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    backbone: str  # transformer | mamba2 | rwkv6 | zamba2
    source: str  # citation string from the assignment table
    # core dims
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    n_heads: int = 0  # 0 for attention-free backbones
    n_kv_heads: int = 0
    head_dim: Optional[int] = None
    # transformer details
    rope_theta: float = 10000.0
    qk_norm: bool = False
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    sliding_window: Optional[int] = None  # window for local layers
    layer_pattern: Tuple[str, ...] = ("global",)  # scan-step pattern,
    # e.g. gemma2: ("local", "global"); entries: local|global|moe|mamba
    mlp_act: str = "swiglu"  # swiglu | geglu | gelu
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    post_norms: bool = False  # Gemma-2 sandwich norms
    scale_embeddings: bool = False  # Gemma: embeddings * sqrt(d_model)
    # modality frontend: "token" consumes int tokens; "embedding" consumes
    # precomputed frame/patch embeddings (audio/vlm stub per assignment)
    frontend: str = "token"
    # mixtures / ssm
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # zamba2: shared attention block applied every k mamba layers
    shared_attn_every: int = 0
    n_shared_blocks: int = 2
    # execution knobs (perf levers — defaults are the faithful baseline)
    dtype: str = "bfloat16"
    remat: str = "full"  # none | dots | full — "full" is the safe default
    # at 27B-1T scale; "dots" is a §Perf lever where memory allows
    attn_chunk: Optional[int] = None  # flash-style KV chunking if set
    attn_head_pad: Optional[int] = None  # zero-pad heads for clean TP
    serve_quant: bool = False  # int8 expert weights at serve time
    # shapes this arch skips (with the reason recorded in DESIGN.md)
    skip_shapes: Tuple[str, ...] = ()

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding/head
        shard cleanly on any mesh axis (MaxText-style padding; labels
        never index the pad rows)."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def activation_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.resolved_head_dim
        n_mlp_mats = 3 if self.mlp_act in ("swiglu", "geglu") else 2
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        if self.backbone == "rwkv6":
            # time-mix r/k/v/g/o (5 d^2) + channel-mix k/v (2 d f) + r
            # (d^2) + ddlerp/decay LoRAs (~448 d)
            per = 6 * d * d + 2 * d * f + 448 * d
            total += self.n_layers * per
        elif self.backbone in ("mamba2", "zamba2"):
            ssm = self.ssm
            d_in = ssm.expand * d
            per = d * (2 * d_in + 2 * ssm.d_state) + d_in * d  # in/out proj
            total += self.n_layers * per
            if self.shared_attn_every:
                attn = 2 * d * (self.n_heads + self.n_kv_heads) * hd + 2 * d * d
                mlp = n_mlp_mats * d * f
                total += self.n_shared_blocks * (attn + mlp)
        else:
            attn = d * (self.n_heads + 2 * self.n_kv_heads) * hd
            attn += self.n_heads * hd * d
            moe_layers = 0
            if self.moe is not None:
                moe_layers = self.n_layers - self.moe.first_k_dense
                dense_layers = self.moe.first_k_dense
            else:
                dense_layers = self.n_layers
            total += self.n_layers * attn
            total += dense_layers * n_mlp_mats * d * f
            if self.moe is not None:
                per_exp = n_mlp_mats * d * self.moe.d_expert
                total += moe_layers * (
                    self.moe.num_experts * per_exp
                    + self.moe.num_shared_experts * per_exp
                    + d * self.moe.num_experts  # router
                )
        return total

    def active_param_count(self) -> int:
        """Params touched per token (for MoE MODEL_FLOPS = 6*N_active*D)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        n_mlp_mats = 3 if self.mlp_act in ("swiglu", "geglu") else 2
        per_exp = n_mlp_mats * self.d_model * self.moe.d_expert
        moe_layers = self.n_layers - self.moe.first_k_dense
        inactive = moe_layers * per_exp * (
            self.moe.num_experts - self.moe.top_k
        )
        return full - inactive

    def reduced(self) -> "ArchConfig":
        """Smoke-test scale: same family/topology, tiny dims."""
        kw = dict(
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4 if self.shared_attn_every else 2),
            d_model=64,
            d_ff=128,
            vocab=128,
            head_dim=16,
            sliding_window=32 if self.sliding_window else None,
        )
        if self.n_heads:
            kw["n_heads"] = 4
            kw["n_kv_heads"] = max(1, min(self.n_kv_heads, 2))
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=8, top_k=2, d_expert=32
            )
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk=16
            )
        if self.shared_attn_every:
            kw["shared_attn_every"] = 2
            kw["n_layers"] = 5
        return dataclasses.replace(self, **kw)

    def shapes(self):
        return [s for n, s in SHAPES.items() if n not in self.skip_shapes]
