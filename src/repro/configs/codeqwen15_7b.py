"""codeqwen1.5-7b [dense] — 32L d_model=4096 32H (GQA kv=32 == MHA)
d_ff=13440 vocab=92416 [hf:Qwen/CodeQwen1.5-7B; hf]. 64k-context code
model (rope theta 1e6). Pure full attention -> long_500k skipped.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    backbone="transformer",
    source="hf:Qwen/CodeQwen1.5-7B; hf",
    n_layers=32,
    d_model=4096,
    d_ff=13440,
    vocab=92416,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    rope_theta=1e6,
    mlp_act="swiglu",
    skip_shapes=("long_500k",),
)
