"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) expert
d_ff=2048 vocab=163840, 384 routed experts top-8 + 1 shared expert,
first layer dense [arXiv:2501.kimi2; unverified] (paper-table entry).

~1.03T parameters, ~32B active. Assumption recorded in DESIGN.md: the
assignment table specifies GQA kv=8 (not MLA), head_dim = d_model /
n_heads = 112, and we set the single dense layer's FFN to 16384
(~ top_k * d_expert compute parity, DeepSeek-V3 style). Training this on
v5e-512 requires bf16 params + int8 optimizer state (DESIGN.md §6).
Full attention -> long_500k skipped.
"""

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    backbone="transformer",
    source="arXiv:2501.kimi2; unverified",
    n_layers=61,
    d_model=7168,
    d_ff=16384,  # dense-prefix layer FFN (assumption, see module docstring)
    vocab=163840,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,
    mlp_act="swiglu",
    moe=MoEConfig(
        num_experts=384,
        top_k=8,
        d_expert=2048,
        num_shared_experts=1,
        first_k_dense=1,
        capacity_factor=1.25,
    ),
    layer_pattern=("moe",),
    skip_shapes=("long_500k",),
)
