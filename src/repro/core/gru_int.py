"""Bit-exact integer GRU-FC engine (the IC's digital classifier on codes).

This is the inference twin of `repro.core.gru`: the same 16 -> GRU(48)
-> GRU(48) -> FC(12) network, evaluated entirely on integer codes the
way the chip's 8 HPEs do (Sections II, III-E):

  * weights as int8 codes (frac 7, `quant.WEIGHT_INT8`),
  * activations / hidden state as Q6.8 codes (`quant.ACT_Q6_8`),
  * biases pre-loaded in the 24-bit accumulator at the product scale
    (frac 15, `quant.BIAS_Q8_15`),
  * matmuls through `repro.kernels.intgemm` (24-bit saturating
    accumulator; Pallas on TPU, exact jnp reference elsewhere),
  * sigmoid/tanh as Q6.8 ROM lookups (`quant.lut_sigmoid_q68` /
    `quant.lut_tanh_q68`) over the 15-bit summed-preactivation domain,
  * every rescale a single round-to-nearest-even shift
    (`quant.round_shift_even`) plus Q6.8 saturation.

Bit-identity contract: for parameters produced by
`repro.serving.quantize.quantize_classifier` and inputs on the Q6.8
grid (which `KWSPipeline._postprocess` guarantees), the dequantized
outputs of `int_gru_classifier_forward` / `int_gru_classifier_step`
equal the QAT fake-quant path of `repro.core.gru` bit for bit — the
contract promised in `repro.core.quant`'s docstring and regression-
tested in tests/test_classifier_int.py. The one documented edge: the
integer path saturates the matmul accumulator at 24 bits before the
bias add, which the float path (clipping only at Q6.8) cannot see; it
binds only for |x . w| >= 256, far outside the network's Q6.8 range.

Everything here is pure jnp on integer arrays, so the engine scans,
vmaps, and fuses into the serving tick exactly like the float path.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.gru import GRUConfig
from repro.kernels.intgemm import intgemm

__all__ = [
    "QuantizedClassifier",
    "int_gru_cell",
    "int_gru_layer",
    "int_gru_classifier_forward",
    "int_gru_classifier_step",
    "int_init_states",
    "quantize_acts",
    "dequantize_acts",
]

# Rescale shifts fixed by the paper's formats: an act (frac 8) x weight
# (frac 7) accumulator carries frac 15 -> Q6.8 needs >> 7; an act x act
# product carries frac 16 -> Q6.8 needs >> 8. 1.0 in Q6.8 is 1 << 8.
_ACC_SHIFT = quant.WEIGHT_INT8.frac_bits
_ACT_SHIFT = quant.ACT_Q6_8.frac_bits
_ONE_Q68 = 1 << quant.ACT_Q6_8.frac_bits


@dataclasses.dataclass(frozen=True)
class QuantizedClassifier:
    """All classifier parameters as integer codes, as one pytree.

    gru  — per-layer dicts {w_i (I, 3H) int8, w_h (H, 3H) int8,
           b_i (3H,) int32 frac-15, b_h (3H,) int32 frac-15}.
    fc_w — (H, K) int8 weight codes.
    fc_b — (K,) int32 bias codes, frac-15.

    The scales are the paper's fixed per-tensor formats (weights 2^-7,
    biases 2^-15, activations 2^-8) and travel as class-level structure
    rather than leaves, so the pytree crosses jit/donation boundaries
    as plain integer buffers. Built by
    `repro.serving.quantize.quantize_classifier`.
    """

    gru: Tuple[Dict[str, jnp.ndarray], ...]
    fc_w: jnp.ndarray
    fc_b: jnp.ndarray


try:
    jax.tree_util.register_dataclass(
        QuantizedClassifier,
        data_fields=["gru", "fc_w", "fc_b"],
        meta_fields=[],
    )
except (AttributeError, TypeError):  # very old jax — manual fallback
    jax.tree_util.register_pytree_node(
        QuantizedClassifier,
        lambda s: ((s.gru, s.fc_w, s.fc_b), None),
        lambda _, xs: QuantizedClassifier(*xs),
    )


def quantize_acts(x: jnp.ndarray) -> jnp.ndarray:
    """Float activations -> Q6.8 codes (exact for on-grid inputs)."""
    return quant.quantize_int(x, quant.ACT_Q6_8)


def dequantize_acts(codes: jnp.ndarray) -> jnp.ndarray:
    """Q6.8 codes -> float32 (exact: code * 2^-8)."""
    return quant.dequantize_int(codes, quant.ACT_Q6_8)


def _accum(x_codes: jnp.ndarray, w_codes: jnp.ndarray,
           b_codes: jnp.ndarray) -> jnp.ndarray:
    """x (B, K) Q6.8 @ w (K, N) int8 + bias (frac 15) -> Q6.8 codes."""
    acc = intgemm(x_codes, w_codes) + b_codes
    return quant.clip_act_codes(quant.round_shift_even(acc, _ACC_SHIFT))


def int_gru_cell(
    layer: Dict[str, jnp.ndarray],
    h: jnp.ndarray,
    x: jnp.ndarray,
    config: GRUConfig,
) -> jnp.ndarray:
    """One GRU step on codes: x (B, I), h (B, H) -> h' (B, H), int32."""
    del config  # geometry is carried by the code arrays themselves
    gi = _accum(x, layer["w_i"], layer["b_i"])  # (B, 3H)
    gh = _accum(h, layer["w_h"], layer["b_h"])
    i_r, i_z, i_n = jnp.split(gi, 3, axis=-1)
    h_r, h_z, h_n = jnp.split(gh, 3, axis=-1)
    r = quant.lut_sigmoid_q68(i_r + h_r)
    z = quant.lut_sigmoid_q68(i_z + h_z)
    rn = quant.clip_act_codes(quant.round_shift_even(r * h_n, _ACT_SHIFT))
    n = quant.lut_tanh_q68(i_n + rn)
    h_new = quant.round_shift_even((_ONE_Q68 - z) * n + z * h, _ACT_SHIFT)
    return quant.clip_act_codes(h_new)


def int_gru_layer(
    layer: Dict[str, jnp.ndarray],
    xs: jnp.ndarray,
    config: GRUConfig,
    h0=None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """xs (B, T, I) codes -> (hs (B, T, H), h_T (B, H)) codes."""
    bsz = xs.shape[0]
    h = (
        jnp.zeros((bsz, config.hidden_dim), jnp.int32) if h0 is None else h0
    )

    def step(h, x_t):
        h_new = int_gru_cell(layer, h, x_t, config)
        return h_new, h_new

    h_t, hs = jax.lax.scan(step, h, jnp.moveaxis(xs, 1, 0))
    return jnp.moveaxis(hs, 0, 1), h_t


def int_gru_classifier_forward(
    qparams: QuantizedClassifier, fv_codes: jnp.ndarray, config: GRUConfig
) -> jnp.ndarray:
    """fv codes (B, T, C) -> per-frame logit codes (B, T, K), int32."""
    xs = fv_codes
    for layer in qparams.gru:
        xs, _ = int_gru_layer(layer, xs, config)
    b, t, h = xs.shape
    logits = _accum(
        xs.reshape(b * t, h), qparams.fc_w, qparams.fc_b
    )
    return logits.reshape(b, t, -1)


def int_gru_classifier_step(
    qparams: QuantizedClassifier,
    states: List[jnp.ndarray],
    fv_t: jnp.ndarray,
    config: GRUConfig,
) -> Tuple[List[jnp.ndarray], jnp.ndarray]:
    """Streaming step on codes: one frame (B, C) -> (states, (B, K))."""
    new_states = []
    x = fv_t
    for layer, h in zip(qparams.gru, states):
        h_new = int_gru_cell(layer, h, x, config)
        new_states.append(h_new)
        x = h_new
    logits = _accum(x, qparams.fc_w, qparams.fc_b)
    return new_states, logits


def int_init_states(
    config: GRUConfig, batch: int, device=None
) -> List[jnp.ndarray]:
    """Per-layer int32 Q6.8 hidden-state codes; ``device`` as in
    `repro.core.gru.init_states`."""
    return [
        jnp.zeros((batch, config.hidden_dim), jnp.int32, device=device)
        for _ in range(config.num_layers)
    ]
