"""GRU-FC classifier (paper Sections II, III-E).

Network: 16-in -> GRU(48) -> GRU(48) -> FC(12).  PyTorch gate convention
(the paper trains in PyTorch 1.8):

    r = sigmoid(W_ir x + b_ir + W_hr h + b_hr)
    z = sigmoid(W_iz x + b_iz + W_hz h + b_hz)
    n = tanh   (W_in x + b_in + r * (W_hn h + b_hn))
    h' = (1 - z) * n + z * h

Weight memory at 8 bits = ~24 KB, matching the IC's WMEM; QAT applies
8-bit weights / 14-bit (Q6.8) activations via `repro.core.quant`.

Three execution paths (selected via `KWSPipelineConfig.classifier` —
see `repro.core.classifier`):
  * float / QAT (this file) — training and the software-model numbers;
  * bit-exact integer engine (`repro.core.gru_int`) — int8 weight codes
    and Q6.8 activation codes through the saturating-int24 `intgemm`
    kernel, bit-identical to the QAT fake-quant forward;
  * weights-resident Pallas kernel (`repro.kernels.gru`) — the TPU
    analogue of the IC's 8-HPE accelerator, validated against this file.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant

__all__ = [
    "GRUConfig",
    "init_gru_classifier",
    "gru_cell",
    "gru_layer",
    "fc_logits",
    "gru_classifier_forward",
    "gru_classifier_step",
    "classifier_macs",
    "classifier_param_bytes",
]


@dataclasses.dataclass(frozen=True)
class GRUConfig:
    input_dim: int = 16
    hidden_dim: int = 48
    num_layers: int = 2
    num_classes: int = 12
    quantized: bool = True  # QAT fake-quant on weights + activations

    @property
    def weight_spec(self) -> quant.QuantSpec:
        return quant.WEIGHT_INT8

    @property
    def act_spec(self) -> quant.QuantSpec:
        return quant.ACT_Q6_8


Params = Dict[str, Any]


def init_gru_classifier(key: jax.Array, config: GRUConfig) -> Params:
    """Uniform(-1/sqrt(H)) init, PyTorch-style."""
    h = config.hidden_dim
    params: Params = {"gru": [], "fc": {}}
    k = 1.0 / np.sqrt(h)
    for layer in range(config.num_layers):
        in_dim = config.input_dim if layer == 0 else h
        key, k1, k2, k3, k4 = jax.random.split(key, 5)
        params["gru"].append(
            {
                "w_i": jax.random.uniform(k1, (in_dim, 3 * h), jnp.float32, -k, k),
                "w_h": jax.random.uniform(k2, (h, 3 * h), jnp.float32, -k, k),
                "b_i": jax.random.uniform(k3, (3 * h,), jnp.float32, -k, k),
                "b_h": jax.random.uniform(k4, (3 * h,), jnp.float32, -k, k),
            }
        )
    key, k1, k2 = jax.random.split(key, 3)
    params["fc"] = {
        "w": jax.random.uniform(
            k1, (h, config.num_classes), jnp.float32, -k, k
        ),
        "b": jax.random.uniform(k2, (config.num_classes,), jnp.float32, -k, k),
    }
    return params


def _maybe_q(x: jnp.ndarray, spec: Optional[quant.QuantSpec]) -> jnp.ndarray:
    return quant.fake_quant(x, spec) if spec is not None else x


def _layer_weights(layer: Params, wspec) -> Tuple[jnp.ndarray, ...]:
    # Biases are pre-loaded into the 24-bit HPE accumulator, which works
    # at the Q6.8 x int8 product scale (frac 15) — quantize them to that
    # grid whenever weights are quantized, so the QAT forward is exactly
    # replayable on integer codes (repro.core.gru_int).
    bspec = None if wspec is None else quant.BIAS_Q8_15
    return (
        _maybe_q(layer["w_i"], wspec),
        _maybe_q(layer["w_h"], wspec),
        _maybe_q(layer["b_i"], bspec),
        _maybe_q(layer["b_h"], bspec),
    )


def gru_cell(
    layer: Params,
    h: jnp.ndarray,
    x: jnp.ndarray,
    config: GRUConfig,
) -> jnp.ndarray:
    """One GRU step: x (B, I), h (B, H) -> h' (B, H)."""
    aspec = config.act_spec if config.quantized else None
    wspec = config.weight_spec if config.quantized else None
    w_i, w_h, b_i, b_h = _layer_weights(layer, wspec)
    hdim = h.shape[-1]

    gi = _maybe_q(x @ w_i + b_i, aspec)  # (B, 3H)
    gh = _maybe_q(h @ w_h + b_h, aspec)
    i_r, i_z, i_n = jnp.split(gi, 3, axis=-1)
    h_r, h_z, h_n = jnp.split(gh, 3, axis=-1)
    # Gate outputs are quantized BEFORE use: on the IC sigmoid/tanh are
    # Q6.8 ROM lookups, so every downstream consumer (the r * h_n
    # product and the convex h update) sees register values, never the
    # float intermediate. This keeps the QAT forward bit-replayable on
    # integer codes (repro.core.gru_int).
    r = _maybe_q(jax.nn.sigmoid(i_r + h_r), aspec)
    z = _maybe_q(jax.nn.sigmoid(i_z + h_z), aspec)
    n = _maybe_q(jnp.tanh(i_n + _maybe_q(r * h_n, aspec)), aspec)
    h_new = (1.0 - z) * n + z * h
    return _maybe_q(h_new, aspec)


def gru_layer(
    layer: Params, xs: jnp.ndarray, config: GRUConfig, h0=None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """xs (B, T, I) -> (hs (B, T, H), h_T (B, H))."""
    bsz = xs.shape[0]
    h = (
        jnp.zeros((bsz, config.hidden_dim), xs.dtype) if h0 is None else h0
    )

    def step(h, x_t):
        h_new = gru_cell(layer, h, x_t, config)
        return h_new, h_new

    h_t, hs = jax.lax.scan(step, h, jnp.moveaxis(xs, 1, 0))
    return jnp.moveaxis(hs, 0, 1), h_t


def fc_logits(params: Params, x: jnp.ndarray, config: GRUConfig):
    """The dense FC head on the last axis: (..., H) -> (..., K).

    The single definition shared by the batch forward, the streaming
    step, and the ΔGRU float engine (`repro.core.gru_delta`) — the
    θ=0 bit-identity target lives in exactly one place, mirroring how
    the code domain shares `gru_int._accum`.
    """
    wspec = config.weight_spec if config.quantized else None
    aspec = config.act_spec if config.quantized else None
    bspec = None if wspec is None else quant.BIAS_Q8_15
    w = _maybe_q(params["fc"]["w"], wspec)
    return _maybe_q(x @ w + _maybe_q(params["fc"]["b"], bspec), aspec)


def gru_classifier_forward(
    params: Params, fv: jnp.ndarray, config: GRUConfig
) -> jnp.ndarray:
    """fv (B, T, C) -> logits (B, T, num_classes) — per-frame scores.

    The IC streams an FV every 16 ms and the detected class is the most
    active output at the end of the sample (Section IV); callers take
    logits[:, -1] for classification.
    """
    xs = fv
    for layer in params["gru"]:
        xs, _ = gru_layer(layer, xs, config)
    return fc_logits(params, xs, config)


def gru_classifier_step(
    params: Params,
    states: List[jnp.ndarray],
    fv_t: jnp.ndarray,
    config: GRUConfig,
) -> Tuple[List[jnp.ndarray], jnp.ndarray]:
    """Streaming step: one frame fv_t (B, C) -> (new states, logits (B, K)).

    This is the serving path — state-resident, one FV per 16 ms frame,
    mirroring the accelerator's operation in Fig. 4.
    """
    new_states = []
    x = fv_t
    for layer, h in zip(params["gru"], states):
        h_new = gru_cell(layer, h, x, config)
        new_states.append(h_new)
        x = h_new
    return new_states, fc_logits(params, x, config)


def init_states(
    config: GRUConfig, batch: int, device=None
) -> List[jnp.ndarray]:
    """Per-layer hidden states; ``device`` (Device or Sharding) places
    each buffer at creation — sharded servers pass a stream-axis
    NamedSharding so no oversized single-device zeros is ever built."""
    return [
        jnp.zeros((batch, config.hidden_dim), jnp.float32, device=device)
        for _ in range(config.num_layers)
    ]


def classifier_macs(config: GRUConfig) -> int:
    """MAC count per frame — drives the latency model (Section III-E).

    Paper check: 2x48 GRU + FC over 16 inputs = 24,204 weights; at 8 HPEs
    and 250 kHz this yields the reported 12.4 ms latency (see energy.py).
    """
    macs = 0
    h = config.hidden_dim
    for layer in range(config.num_layers):
        in_dim = config.input_dim if layer == 0 else h
        macs += 3 * h * (in_dim + h) + 2 * 3 * h  # matmuls + two bias adds
    macs += config.num_classes * h + config.num_classes
    return macs


def classifier_param_bytes(config: GRUConfig, bits: int = 8) -> int:
    h = config.hidden_dim
    n = 0
    for layer in range(config.num_layers):
        in_dim = config.input_dim if layer == 0 else h
        n += 3 * h * (in_dim + h) + 2 * 3 * h
    n += config.num_classes * h + config.num_classes
    return n * bits // 8
