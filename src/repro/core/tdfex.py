"""Behavioral simulation of the time-domain analog FEx (paper Section III).

Signal chain (per Fig. 3):

  VTC      voltage -> multi-phase PWM duty; FLL-linearized, single-ended.
           Modeled as a linear pass-through + optional HD2/HD3 distortion
           (-70 dB per Fig. 7) + input-referred noise (248 uV_RMS, Fig. 17c).
  Rec-BPF  SRO Tow-Thomas biquad, eq. (5): a 2nd-order band-pass in the
           phase domain, with inherent PFD full-wave rectification.
           Modeled as the bilinear-discretized biquad + |.|, with
           per-channel bias mismatch (the shared-V_VAR systematic error of
           Fig. 17a) scaling both center frequency and gain.
  SRO PFM + DeltaSigma TDC
           SRO frequency f = (f_free + k_sro * u) * (1 + mismatch);
           phase integrates f; 15-phase counters sample floor(15*phi) at
           f_over; XOR differentiators emit first differences (<=1 LSB,
           noise-shaped); a 1st-order CIC decimates by R. Telescoping makes
           the CIC output exactly floor-quantized phase increments per
           frame — this is what gives the 20 dB/dec shaped spectrum of
           Fig. 17c.
  post     beta offset subtract (free-running counts), alpha per-channel
           gain calibration, log LUT, (x-mu)/sigma normalizer — shared with
           the software model in `repro.core.fex` / `repro.core.quant`.

Rates: the chip runs the TDC at 62.5 kHz and decimates by 2^10 (61 Hz,
16.384 ms frames). We simulate the TDC at 64 kHz (integer 2x of the 32 kHz
audio-internal rate) with R=1024 so frames are exactly 16 ms — the same
frame shift as the software model; this changes in-band noise by <0.2 dB
and is noted in DESIGN.md.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fex import FExConfig, biquad_filterbank, oversample2x
from repro.core.filters import design_filterbank

__all__ = [
    "TDFExConfig",
    "TDFExState",
    "vtc",
    "design_mismatched_filterbank",
    "rec_bpf",
    "sro_tdc",
    "tdfex_raw_counts",
    "tdfex_forward",
]


@dataclasses.dataclass(frozen=True)
class TDFExConfig:
    fex: FExConfig = dataclasses.field(default_factory=FExConfig)
    # --- VTC (Section III-A) ---
    vtc_hd2_db: float = -70.0  # 2nd-harmonic distortion (post-layout, Fig. 7)
    vtc_hd3_db: float = -70.0
    input_noise_rms: float = 248e-6 / 0.125  # 248 uV_RMS input-referred at
    # ~250 mVpp (=0.125 amplitude) full scale -> normalized units
    # --- SRO PFM encoder / TDC (Sections III-B/D) ---
    tdc_oversample: int = 2  # TDC rate = 2 x 32 kHz = 64 kHz
    decimation: int = 1024  # R; 64 kHz / 1024 -> 62.5 Hz (16 ms frames)
    n_phases: int = 15  # ring oscillator taps
    f_free_hz: float = 4000.0  # SRO free-running frequency (offset beta)
    k_sro_hz: float = 120000.0  # Hz per unit rectified input (gain)
    # --- mismatch (Fig. 17a) ---
    gain_mismatch_sigma: float = 0.15  # shared-bias systematic + random
    cf_mismatch_sigma: float = 0.03  # center-frequency spread
    phase_noise_rms: float = 0.0  # optional per-step phase jitter (cycles)

    @property
    def f_tdc(self) -> float:
        return self.fex.fs_internal * self.tdc_oversample

    @property
    def beta_nominal(self) -> float:
        """Free-running counts per frame: f_free * n_phases * R / f_tdc."""
        return (
            self.f_free_hz
            * self.n_phases
            * self.decimation
            / self.f_tdc
        )

    def counts_per_frame(self, u: float) -> float:
        """Ideal (unquantized) counts for constant rectified input u."""
        return (
            (self.f_free_hz + self.k_sro_hz * u)
            * self.n_phases
            * self.decimation
            / self.f_tdc
        )


@dataclasses.dataclass(frozen=True)
class TDFExState:
    """Per-chip mismatch realization (drawn once per simulated die)."""

    gain_mismatch: jnp.ndarray  # (C,) multiplicative, ~N(0, sigma)
    cf_mismatch: jnp.ndarray  # (C,) multiplicative on f0


def draw_chip(key: jax.Array, cfg: TDFExConfig) -> TDFExState:
    k1, k2 = jax.random.split(key)
    c = cfg.fex.num_channels
    return TDFExState(
        gain_mismatch=cfg.gain_mismatch_sigma
        * jax.random.normal(k1, (c,), jnp.float32),
        cf_mismatch=cfg.cf_mismatch_sigma
        * jax.random.normal(k2, (c,), jnp.float32),
    )


def vtc(
    audio: jnp.ndarray,
    cfg: TDFExConfig,
    key: Optional[jax.Array] = None,
    audio_rate: bool = True,
) -> jnp.ndarray:
    """VTC: audio at fs_audio -> PWM duty at fs_internal (32 kHz).

    The FLL linearization makes f_FLL = V_IN / (15 R C V_REF) — linear — so
    the behavioral duty equals the input, plus small even/odd distortion
    from residual single-ended asymmetry and input-referred noise.

    audio_rate=False means the stimulus is already at fs_internal — used by
    the calibration/measurement path, where an *analog* function generator
    drives V_IN,VTC directly (Fig. 16) and is not band-limited to 8 kHz by
    the dataset's sample rate.
    """
    x = (
        oversample2x(audio)
        if (audio_rate and cfg.fex.oversample == 2)
        else audio
    )
    hd2 = 10.0 ** (cfg.vtc_hd2_db / 20.0)
    hd3 = 10.0 ** (cfg.vtc_hd3_db / 20.0)
    y = x + hd2 * x * x + hd3 * x * x * x
    if key is not None and cfg.input_noise_rms > 0:
        y = y + cfg.input_noise_rms * jax.random.normal(
            key, y.shape, y.dtype
        )
    return y


def design_mismatched_filterbank(cfg: TDFExConfig, chip: Optional[TDFExState] = None):
    """The (possibly mismatched) Rec-BPF filterbank for one simulated die.

    Center-frequency mismatch is applied by redesigning the per-channel
    biquad at f0*(1+eps) — the FLL bias error moves omega_0 per eq. (6).
    Requires concrete (non-traced) mismatch values: the chip's filterbank
    is fixed hardware, so design it once (e.g. at `FrontendState` build
    time), not per forward pass.
    """
    fexc = cfg.fex
    if chip is None:
        return fexc.filterbank()
    from repro.core.filters import design_bandpass_biquad

    f0 = np.asarray(
        design_filterbank(
            fexc.num_channels, fexc.fs_internal, fexc.f_lo, fexc.f_hi, fexc.q
        ).f0
    )
    f0 = f0 * (1.0 + np.asarray(chip.cf_mismatch))
    f0 = np.clip(f0, 10.0, fexc.fs_internal / 2 * 0.95)
    return design_bandpass_biquad(f0, fs=fexc.fs_internal, q=fexc.q)


def rec_bpf(
    duty: jnp.ndarray, cfg: TDFExConfig, chip: Optional[TDFExState] = None
) -> jnp.ndarray:
    """16-channel rectifying BPF: duty (B, T) -> rectified (B, T, C)."""
    y = biquad_filterbank(duty, design_mismatched_filterbank(cfg, chip))
    # PFD-based FWR (Section III-C): UP + DN = |delta phi|.
    return jnp.abs(y)


def sro_tdc(
    rectified: jnp.ndarray,
    cfg: TDFExConfig,
    chip: Optional[TDFExState] = None,
    key: Optional[jax.Array] = None,
    return_diff_stream: bool = False,
):
    """SRO PFM encoder + 1st-order DeltaSigma TDC + XOR diff + CIC decimate.

    rectified: (B, T, C) at fs_internal. Returns integer counts per frame
    (B, F, C); optionally also the pre-decimation differentiator stream
    (B, T*tdc_oversample, C) for spectrum analysis (Fig. 17c).
    """
    b, t, c = rectified.shape
    os = cfg.tdc_oversample
    # zero-order hold to the TDC rate (64 kHz)
    u = jnp.repeat(rectified, os, axis=1)  # (B, T*os, C)
    gain = 1.0
    if chip is not None:
        gain = 1.0 + chip.gain_mismatch  # (C,)
    f_inst = (cfg.f_free_hz + cfg.k_sro_hz * u) * gain  # Hz, >= 0 region
    f_inst = jnp.maximum(f_inst, 0.0)
    dt = 1.0 / cfg.f_tdc
    phase = jnp.cumsum(f_inst * dt, axis=1)  # cycles (lossless integrator)
    if key is not None and cfg.phase_noise_rms > 0:
        jitter = cfg.phase_noise_rms * jax.random.normal(
            key, phase.shape, phase.dtype
        )
        phase = phase + jitter
    counts = jnp.floor(cfg.n_phases * phase)  # 15-phase counter samples
    # XOR differentiator: first difference of the counter (metastability-free)
    prev = jnp.concatenate([jnp.zeros_like(counts[:, :1]), counts[:, :-1]], 1)
    diff = counts - prev
    # 1st-order CIC with decimation R: boxcar sum of R diffs == telescoped
    # count increments per frame.
    r = cfg.decimation
    n_frames = diff.shape[1] // r
    d = diff[:, : n_frames * r, :].reshape(b, n_frames, r, c)
    fv_counts = d.sum(axis=2)
    if return_diff_stream:
        return fv_counts, diff
    return fv_counts


def tdfex_raw_counts(
    audio: jnp.ndarray,
    cfg: TDFExConfig,
    chip: Optional[TDFExState] = None,
    key: Optional[jax.Array] = None,
    audio_rate: bool = True,
) -> jnp.ndarray:
    """audio (B, T) -> TDC counts (B, F, C) — the chip's FV before post-proc."""
    if key is not None:
        k_vtc, k_tdc = jax.random.split(key)
    else:
        k_vtc = k_tdc = None
    duty = vtc(audio, cfg, k_vtc, audio_rate=audio_rate)
    rect = rec_bpf(duty, cfg, chip)
    return sro_tdc(rect, cfg, chip, k_tdc)


def counts_to_fv_raw(
    counts: jnp.ndarray,
    cfg: TDFExConfig,
    beta: jnp.ndarray,
    alpha: jnp.ndarray,
) -> jnp.ndarray:
    """Apply offset/gain calibration and scale into the 12-bit quantizer
    code domain used by the software model.

    counts_signal = alpha * (counts - beta); the count-domain full scale
    corresponds to k_sro * quant_full_scale worth of rectified input.
    """
    sig = alpha * (counts - beta)
    full_scale_counts = (
        cfg.k_sro_hz
        * cfg.fex.quant_full_scale
        * cfg.n_phases
        * cfg.decimation
        / cfg.f_tdc
    )
    codes = sig / full_scale_counts * (2.0**cfg.fex.quant_bits - 1.0)
    return jnp.clip(jnp.round(codes), 0.0, 2.0**cfg.fex.quant_bits - 1.0)


def tdfex_forward(
    audio: jnp.ndarray,
    cfg: TDFExConfig,
    beta: jnp.ndarray,
    alpha: jnp.ndarray,
    chip: Optional[TDFExState] = None,
    key: Optional[jax.Array] = None,
) -> jnp.ndarray:
    """Full hardware-sim FEx to FV_Raw codes (B, F, C)."""
    counts = tdfex_raw_counts(audio, cfg, chip, key)
    return counts_to_fv_raw(counts, cfg, beta, alpha)
