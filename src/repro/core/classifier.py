"""Pluggable classifier backends for the KWS pipeline.

The paper's classifier is an integer machine — 8-bit weight memory,
Q6.8 activations, 24-bit accumulators — trained in float with QAT.
This module makes that execution axis a first-class API, exactly
mirroring `repro.core.frontend`: every way of evaluating the GRU-FC
network is a `ClassifierBackend` registered under a string key,
selected via `KWSPipelineConfig.classifier`:

  "float"   — plain float32 forward (no fake-quant); the ablation /
              debugging path.
  "qat"     — the quantization-aware fake-quant forward of
              `repro.core.gru` (8-bit weights, Q6.8 activations via
              straight-through estimators); training and the default
              inference path.
  "integer" — the bit-exact integer engine of `repro.core.gru_int`:
              parameters as int8/int32 codes
              (`repro.serving.quantize.quantize_classifier`), matmuls
              through the saturating-int24 `intgemm` kernel,
              sigmoid/tanh as Q6.8 LUTs. Bit-identical to "qat" on the
              same parameters (tests/test_classifier_int.py) while
              keeping weights WMEM-resident — the serving path.
  "delta"   — the temporal-sparsity ΔGRU engine of
              `repro.core.gru_delta` in the QAT float domain: per-layer
              last-transmitted input/state memories + partial-sum
              accumulators, thresholded deltas (θ per layer via
              `KWSPipelineConfig.delta`, a `gru_delta.DeltaConfig`),
              per-stream skipped/total MAC counters. θ=0 is
              BIT-identical to "qat" (tests/test_gru_delta.py).
  "delta-int" — the same ΔGRU engine layered on the "integer" codes
              (int8 weights through `intgemm`, int32 Q6.8 state and
              frac-15 accumulators). θ=0 is BIT-identical to "integer".

The backend boundary speaks float FV_Norm frames in and float logits
out for every backend, so softmax / smoothing / argmax downstream are
backend-agnostic; the integer backend converts at the boundary (exact
in both directions: inputs arrive on the Q6.8 grid from the pipeline's
post-processing, and logit codes dequantize to exact float32).

Hidden state is backend-owned: `init_states` returns float32 leaves
for "float"/"qat" and int32 code leaves for "integer", and the fused
serving tick (`repro.serving.serve_loop.ServerState`) carries whichever
it is through donation without caring.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.core.gru import (
    GRUConfig,
    gru_classifier_forward,
    gru_classifier_step,
    init_states,
)

__all__ = [
    "ClassifierBackend",
    "register_classifier",
    "get_classifier",
    "available_classifiers",
    "resolve_classifier_key",
    "FloatClassifier",
    "QATClassifier",
    "IntegerClassifier",
    "DeltaClassifier",
    "DeltaIntClassifier",
]


class ClassifierBackend:
    """One execution path of the GRU-FC classifier.

    Implementations are stateless singletons (all run-time state lives
    in the params pytree and the per-stream hidden states), safe to
    close over in jit'd functions. Subclasses implement:

      prepare(params, cfg)        float training params -> the pytree
                                  this backend consumes (idempotent:
                                  already-prepared params pass through)
      init_states(cfg, batch, device=None)
                                  per-layer hidden state leaves;
                                  ``device`` (a Device or Sharding)
                                  places them at creation — sharded
                                  servers pass a stream-axis
                                  NamedSharding
      forward(params, fv, cfg)    (B, T, C) float FV_Norm ->
                                  (B, T, K) float logits
      step(params, states, fv_t, cfg)
                                  one frame (B, C) ->
                                  (new states, (B, K) float logits)

    ``cfg`` is the `GRUConfig`. The quantization mode is the backend's
    identity, so each backend forces its own ``cfg.quantized`` and the
    flag a caller set on the config is ignored here (the pipeline
    resolves the default backend FROM that flag instead).
    """

    name: str = "?"
    #: True when forward is differentiable (training-capable).
    differentiable: bool = False

    def prepare(self, params: Any, cfg: GRUConfig) -> Any:
        return params

    def with_config(self, pipeline_config: Any) -> "ClassifierBackend":
        """Hook for backends parameterized by pipeline-level config
        beyond the `GRUConfig` (the ΔGRU thresholds live on
        `KWSPipelineConfig.delta`). The registry hands out stateless
        singletons; a backend that needs per-pipeline configuration
        returns a configured copy here. Default: the singleton itself.
        """
        return self

    def init_states(
        self, cfg: GRUConfig, batch: int, device: Any = None
    ) -> List[jnp.ndarray]:
        raise NotImplementedError

    def forward(self, params, fv: jnp.ndarray, cfg: GRUConfig):
        raise NotImplementedError

    def step(self, params, states, fv_t: jnp.ndarray, cfg: GRUConfig):
        raise NotImplementedError


_REGISTRY: Dict[str, ClassifierBackend] = {}


def register_classifier(name: str):
    """Class decorator: instantiate + register under ``name``."""

    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls()
        return cls

    return deco


def get_classifier(name: str) -> ClassifierBackend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown classifier {name!r}; registered classifiers: "
            f"{sorted(_REGISTRY)}"
        ) from None


def available_classifiers() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_classifier_key(
    classifier: Optional[str], gru: GRUConfig
) -> str:
    """None -> the backend the pre-registry pipeline implied: "qat"
    when ``gru.quantized`` else "float". Explicit keys win."""
    if classifier is not None:
        return classifier
    return "qat" if gru.quantized else "float"


# --------------------------------------------------------------------------
# float / qat — the repro.core.gru paths
# --------------------------------------------------------------------------

class _FloatBase(ClassifierBackend):
    """Shared float-forward plumbing; `_cfg` pins the fake-quant mode."""

    differentiable = True
    _quantized: bool = True

    def _cfg(self, cfg: GRUConfig) -> GRUConfig:
        if cfg.quantized == self._quantized:
            return cfg
        return dataclasses.replace(cfg, quantized=self._quantized)

    def init_states(self, cfg, batch, device=None):
        return init_states(cfg, batch, device=device)

    def forward(self, params, fv, cfg):
        return gru_classifier_forward(params, fv, self._cfg(cfg))

    def step(self, params, states, fv_t, cfg):
        return gru_classifier_step(params, states, fv_t, self._cfg(cfg))


@register_classifier("float")
class FloatClassifier(_FloatBase):
    """Plain float32 forward — no fake-quant anywhere."""

    _quantized = False


@register_classifier("qat")
class QATClassifier(_FloatBase):
    """QAT fake-quant forward (8-bit weights, Q6.8 activations, STE)."""

    _quantized = True


# --------------------------------------------------------------------------
# integer — the bit-exact code engine
# --------------------------------------------------------------------------

@register_classifier("integer")
class IntegerClassifier(ClassifierBackend):
    """Bit-exact integer engine over `QuantizedClassifier` codes.

    `prepare` quantizes float params once (idempotent); `forward`/
    `step` quantize the float FV_Norm input to Q6.8 codes at entry
    (exact for pipeline-produced frames, which are already on the
    grid) and dequantize logit codes to float at exit (always exact).
    Hidden states are int32 Q6.8 code buffers.
    """

    differentiable = False

    def prepare(self, params, cfg):
        from repro.core.gru_int import QuantizedClassifier

        if isinstance(params, QuantizedClassifier):
            return params
        from repro.serving.quantize import quantize_classifier

        return quantize_classifier(params, cfg)

    def init_states(self, cfg, batch, device=None):
        from repro.core.gru_int import int_init_states

        return int_init_states(cfg, batch, device=device)

    def forward(self, params, fv, cfg):
        from repro.core import gru_int

        self._check_prepared(params)
        codes = gru_int.int_gru_classifier_forward(
            params, gru_int.quantize_acts(fv), cfg
        )
        return gru_int.dequantize_acts(codes)

    def step(self, params, states, fv_t, cfg):
        from repro.core import gru_int

        self._check_prepared(params)
        states, codes = gru_int.int_gru_classifier_step(
            params, states, gru_int.quantize_acts(fv_t), cfg
        )
        return states, gru_int.dequantize_acts(codes)

    @staticmethod
    def _check_prepared(params):
        from repro.core.gru_int import QuantizedClassifier

        if not isinstance(params, QuantizedClassifier):
            raise TypeError(
                "integer classifier needs QuantizedClassifier params; "
                "call pipeline.prepare_params(params) (or "
                "repro.serving.quantize.quantize_classifier) first"
            )


# --------------------------------------------------------------------------
# delta / delta-int — the temporal-sparsity ΔGRU engine
# --------------------------------------------------------------------------

class _DeltaBase(ClassifierBackend):
    """Shared ΔGRU plumbing; subclasses pick the arithmetic domain.

    Instances carry their `gru_delta.DeltaConfig` (the registry
    singleton holds the θ=0 default); `with_config` returns a copy
    bound to `KWSPipelineConfig.delta`. The per-layer state dicts
    (memories, accumulators, skipped/total MAC counters) thread through
    `init_states`, `ServerState` donation, `masked_select`, the jitted
    slot reset, and the stream mesh exactly like the dense backends'
    hidden-state leaves — the serving tick never special-cases them.
    """

    differentiable = False

    def __init__(self, delta=None):
        from repro.core.gru_delta import DeltaConfig

        self.delta = DeltaConfig() if delta is None else delta

    def with_config(self, pipeline_config):
        delta = getattr(pipeline_config, "delta", None)
        if delta is None or delta == self.delta:
            return self
        return type(self)(delta)

    def _thetas(self, cfg: GRUConfig):
        return self.delta.code_thresholds(cfg.num_layers)


@register_classifier("delta")
class DeltaClassifier(_DeltaBase):
    """ΔGRU in the QAT fake-quant float domain (θ=0 ≡ "qat" bit for
    bit). Params stay float (like "qat"); state leaves are float32
    grid values plus int32 MAC counters."""

    def init_states(self, cfg, batch, device=None):
        from repro.core.gru_delta import delta_init_states

        return delta_init_states(cfg, batch, device=device)

    def forward(self, params, fv, cfg):
        from repro.core.gru_delta import delta_classifier_forward

        return delta_classifier_forward(params, fv, cfg, self._thetas(cfg))

    def step(self, params, states, fv_t, cfg):
        from repro.core.gru_delta import delta_classifier_step

        return delta_classifier_step(
            params, states, fv_t, cfg, self._thetas(cfg)
        )


@register_classifier("delta-int")
class DeltaIntClassifier(_DeltaBase):
    """ΔGRU on the "integer" backend's codes (θ=0 ≡ "integer" bit for
    bit): int8 weight codes through `intgemm`, int32 Q6.8 state and
    frac-15 accumulator codes, float FV_Norm/logits at the boundary
    exactly like `IntegerClassifier`."""

    def prepare(self, params, cfg):
        return IntegerClassifier.prepare(self, params, cfg)

    def init_states(self, cfg, batch, device=None):
        from repro.core.gru_delta import int_delta_init_states

        return int_delta_init_states(cfg, batch, device=device)

    def forward(self, params, fv, cfg):
        from repro.core import gru_int
        from repro.core.gru_delta import int_delta_classifier_forward

        IntegerClassifier._check_prepared(params)
        codes = int_delta_classifier_forward(
            params, gru_int.quantize_acts(fv), cfg, self._thetas(cfg)
        )
        return gru_int.dequantize_acts(codes)

    def step(self, params, states, fv_t, cfg):
        from repro.core import gru_int
        from repro.core.gru_delta import int_delta_classifier_step

        IntegerClassifier._check_prepared(params)
        states, codes = int_delta_classifier_step(
            params, states, gru_int.quantize_acts(fv_t), cfg,
            self._thetas(cfg),
        )
        return states, gru_int.dequantize_acts(codes)
