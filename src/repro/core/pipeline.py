"""End-to-end KWS pipeline assembly (Fig. 3): FEx -> classifier.

Both stages are pluggable, string-keyed backends:

  * `KWSPipelineConfig.frontend` names a registered
    `repro.core.frontend.FeatureFrontend` ("software", "hardware",
    "hardware-pallas" — see that module);
  * `KWSPipelineConfig.classifier` names a registered
    `repro.core.classifier.ClassifierBackend` ("float", "qat",
    "integer", "delta", "delta-int") — None resolves from
    ``gru.quantized``. The "integer" backend runs the bit-exact
    int8/Q6.8 engine of `repro.core.gru_int`; `prepare_params`
    converts float training params to its code pytree, and every
    classifier entry point below accepts either form. The ΔGRU
    backends ("delta"/"delta-int", `repro.core.gru_delta`) take their
    thresholds from `KWSPipelineConfig.delta` (bound to the backend at
    pipeline construction via `ClassifierBackend.with_config`).

Every feature entry point routes through the frontend:

  features(audio, state)                batch audio -> (FV_Norm, FV_Raw)
  record_features(audio, state)         batched numpy recording of
                                        FV_Raw (the Section III-F flow:
                                        the paper records features from
                                        the chip once, then trains)
  predict(params, audio, state)         features + GRU + argmax
  streaming_features_step(carry, chunk) one 16 ms raw-audio hop ->
                                        one FV_Norm frame per stream
  streaming_step(params, states, fv_t)  one GRU step per 16 ms frame

All frontend-side parameters (norm stats, chip mismatch, beta/alpha
calibration, filterbank coefficients) live in one `FrontendState`
pytree, built by `init_frontend_state` / `repro.core.calibration` and
passed to the calls above (or bound at construction time); loose
``beta``/``alpha``/``norm_stats`` positional arguments are gone.

The FV_Raw -> FV_Norm post-processing (log LUT, (x-mu)/sigma, Q6.8) is
the chip's digital back-end and is shared by every frontend
(`features_from_raw`). The classifier is always trained on features
*recorded from the chosen frontend*, exactly as the paper records FV_Raw
from the chip for its training set.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.classifier import (
    ClassifierBackend,
    get_classifier,
    resolve_classifier_key,
)
from repro.core.fex import FExConfig, FExNormStats
from repro.core.frontend import (
    FeatureFrontend,
    FrontendState,
    get_frontend,
)
from repro.core.gru import GRUConfig, init_gru_classifier
from repro.core.gru_delta import DeltaConfig
from repro.core.tdfex import TDFExConfig, TDFExState
from repro.serving.cascade import CascadeConfig

__all__ = [
    "KWSPipelineConfig",
    "KWSPipeline",
    "record_features_hardware",
]


@dataclasses.dataclass(frozen=True)
class KWSPipelineConfig:
    frontend: str = "software"  # registered FeatureFrontend key
    fex: FExConfig = dataclasses.field(default_factory=FExConfig)
    gru: GRUConfig = dataclasses.field(default_factory=GRUConfig)
    # Hardware-sim parameters for the "hardware*" frontends. None ->
    # TDFExConfig built around `fex` (the paper's nominal chip).
    tdfex: Optional[TDFExConfig] = None
    use_log: bool = True
    use_norm: bool = True
    # Registered ClassifierBackend key ("float" / "qat" / "integer" /
    # "delta" / "delta-int"); None resolves from gru.quantized ("qat"
    # when True else "float"), preserving the pre-registry behavior.
    classifier: Optional[str] = None
    # ΔGRU thresholds for the "delta"/"delta-int" backends
    # (`repro.core.gru_delta.DeltaConfig`; θ per layer). None -> θ=0,
    # which is bit-identical to the dense base backend. Ignored by the
    # dense backends.
    delta: Optional["DeltaConfig"] = None
    # Stage-1 wake cascade for the serving tick
    # (`repro.serving.cascade.CascadeConfig`): an always-on detector on
    # the feature frame gates the classifier per stream. None -> no
    # gate (the always-dense tick); `CascadeConfig.always_on()` is
    # bit-identical to None for every backend. Consumed only by the
    # serving layer (`StreamingKWSServer`) — batch `features`/`logits`
    # calls ignore it.
    cascade: Optional["CascadeConfig"] = None

    def __post_init__(self):
        # The pipeline post-processes (and shapes chunks) with `fex`
        # while the hardware frontends generate features with
        # `tdfex.fex`; a disagreement would surface as silently wrong
        # FV_Norm far from the misconfiguration.
        if self.tdfex is not None and self.tdfex.fex != self.fex:
            raise ValueError(
                "KWSPipelineConfig.fex and KWSPipelineConfig.tdfex.fex "
                "disagree; pass tdfex=TDFExConfig(fex=your_fex, ...)"
            )

    @property
    def tdfex_config(self) -> TDFExConfig:
        if self.tdfex is not None:
            return self.tdfex
        return TDFExConfig(fex=self.fex)

    @property
    def classifier_key(self) -> str:
        return resolve_classifier_key(self.classifier, self.gru)


class KWSPipeline:
    """Stateless-functional pipeline with convenience wrappers.

    A `FrontendState` may be bound at construction (used as the default
    for every call) or passed per call; methods never mutate it.
    """

    def __init__(
        self,
        config: KWSPipelineConfig,
        state: Optional[FrontendState] = None,
        norm_stats: Optional[FExNormStats] = None,
    ):
        self.config = config
        self.frontend: FeatureFrontend = get_frontend(config.frontend)
        # with_config binds pipeline-level backend parameters (the ΔGRU
        # thresholds of config.delta); dense backends return the
        # registry singleton unchanged.
        self.classifier: ClassifierBackend = get_classifier(
            config.classifier_key
        ).with_config(config)
        if state is None:
            state = FrontendState()
        if norm_stats is not None:
            state = state.with_norm_stats(norm_stats)
        self.state = state
        # memo for prepare_params: (params object, mesh, prepared
        # pytree). The strong reference to the keys object keeps its
        # id() from being recycled while the entry is alive.
        self._prepared: Optional[Tuple[Any, Any, Any]] = None

    @property
    def norm_stats(self) -> Optional[FExNormStats]:
        return self.state.norm_stats

    def _resolve(self, state: Optional[FrontendState]) -> FrontendState:
        return self.state if state is None else state

    # ---------- frontend state ----------

    def init_frontend_state(
        self, key: Optional[jax.Array] = None, **kwargs
    ) -> FrontendState:
        """Build this frontend's state (chip draw + beta/alpha calibration
        for the hardware paths; a no-op shell for "software"). Any bound
        norm_stats are carried over unless overridden via kwargs."""
        kwargs.setdefault("norm_stats", self.state.norm_stats)
        return self.frontend.init_state(self.config, key=key, **kwargs)

    def with_state(self, state: FrontendState) -> "KWSPipeline":
        """A copy of this pipeline with ``state`` bound as the default."""
        return KWSPipeline(self.config, state=state)

    # ---------- feature extraction ----------

    @functools.partial(jax.jit, static_argnums=(0,))
    def _features_jit(self, audio, state, key):
        fv_raw = self.frontend.raw_codes(audio, self.config, state, key=key)
        return self._postprocess(fv_raw, state), fv_raw

    def features(
        self,
        audio: jnp.ndarray,
        state: Optional[FrontendState] = None,
        key: Optional[jax.Array] = None,
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """audio (B, T) -> (fv_norm (B, F, C), fv_raw codes), via the
        configured frontend. One call site for all registered paths."""
        return self._features_jit(audio, self._resolve(state), key)

    def features_software(self, audio: jnp.ndarray):
        """Deprecated alias kept for the pre-registry API; equivalent to
        `features` on a ``frontend="software"`` pipeline."""
        warnings.warn(
            "KWSPipeline.features_software is deprecated; use "
            "KWSPipeline.features (works for any cfg.frontend) — see "
            "the migration table in CHANGES.md",
            DeprecationWarning,
            stacklevel=2,
        )
        if self.config.frontend != "software":
            raise ValueError(
                "features_software on a "
                f"frontend={self.config.frontend!r} pipeline; "
                "use features()"
            )
        return self.features(audio)

    def _postprocess(self, fv_raw, state: FrontendState) -> jnp.ndarray:
        """FV_Raw codes -> FV_Norm: the chip's digital back-end (log LUT,
        normalizer, Q6.8 saturation), shared by every frontend."""
        x = fv_raw
        if self.config.use_log:
            x = quant.log_compress_lut(
                x, self.config.fex.quant_bits, self.config.fex.log_bits
            )
        if self.config.use_norm:
            if state.norm_stats is None:
                raise ValueError("use_norm requires fitted norm_stats")
            x = (x - state.norm_stats.mu) / state.norm_stats.sigma
        else:
            in_bits = (
                self.config.fex.log_bits
                if self.config.use_log
                else self.config.fex.quant_bits
            )
            x = x * 2.0 ** -(in_bits - 5)
        return quant.fake_quant(x, quant.ACT_Q6_8)

    def features_from_raw(
        self, fv_raw: jnp.ndarray, state: Optional[FrontendState] = None
    ) -> jnp.ndarray:
        """Post-processing only: recorded FV_Raw codes -> FV_Norm."""
        return self._postprocess(fv_raw, self._resolve(state))

    def record_features(
        self,
        audio: np.ndarray,
        state: Optional[FrontendState] = None,
        key: Optional[jax.Array] = None,
        batch_size: int = 64,
    ) -> np.ndarray:
        """Record FV_Raw codes in host-memory batches (Section III-F).

        Works for any frontend; the hardware paths consume ``key`` for
        their per-record noise draw (VTC noise / SRO jitter)."""
        state = self._resolve(state)
        fn = jax.jit(
            lambda a, k: self.frontend.raw_codes(
                a, self.config, state, key=k
            )
        )
        outs = []
        n = audio.shape[0]
        for i in range(0, n, batch_size):
            chunk = jnp.asarray(audio[i : i + batch_size])
            k = None
            if key is not None:
                key, k = jax.random.split(key)
            outs.append(np.asarray(fn(chunk, k)))
        return np.concatenate(outs, axis=0)

    # ---------- classifier ----------

    def init_params(self, key: jax.Array) -> Dict[str, Any]:
        """Float training params (QAT trains in float; the configured
        backend converts via `prepare_params` at inference time)."""
        return init_gru_classifier(key, self.config.gru)

    def prepare_params(self, params, mesh=None):
        """Float training params -> whatever the configured backend
        consumes (e.g. `QuantizedClassifier` integer codes for
        ``classifier="integer"``). Idempotent: already-prepared params
        pass through, so every public entry point below can call it.
        The last conversion is memoized by parameter identity, so
        per-frame callers (`streaming_step`) don't re-quantize the
        whole parameter pytree every 16 ms tick.

        ``mesh`` (a serving `stream_mesh`) places the prepared pytree
        replicated across every mesh device — weights are resident on
        each shard of a stream-parallel server, never re-transferred
        per tick."""
        if (
            self._prepared is not None
            and self._prepared[0] is params
            and self._prepared[1] is mesh
        ):
            return self._prepared[2]
        prepared = self.classifier.prepare(params, self.config.gru)
        if mesh is not None:
            from repro.distributed.sharding import replicated_shardings

            prepared = jax.device_put(
                prepared, replicated_shardings(prepared, mesh)
            )
        self._prepared = (params, mesh, prepared)
        return prepared

    @functools.partial(jax.jit, static_argnums=(0,))
    def _logits_jit(self, params, fv_norm: jnp.ndarray) -> jnp.ndarray:
        all_logits = self.classifier.forward(
            params, fv_norm, self.config.gru
        )
        return all_logits[:, -1, :]

    def logits(self, params, fv_norm: jnp.ndarray) -> jnp.ndarray:
        """(B, F, C) -> final-frame logits (B, K), via the configured
        classifier backend."""
        return self._logits_jit(self.prepare_params(params), fv_norm)

    @functools.partial(jax.jit, static_argnums=(0,))
    def _logits_all_jit(self, params, fv_norm: jnp.ndarray) -> jnp.ndarray:
        return self.classifier.forward(params, fv_norm, self.config.gru)

    def logits_all_frames(self, params, fv_norm: jnp.ndarray) -> jnp.ndarray:
        return self._logits_all_jit(self.prepare_params(params), fv_norm)

    def predict(
        self,
        params,
        audio: jnp.ndarray,
        state: Optional[FrontendState] = None,
        key: Optional[jax.Array] = None,
    ) -> jnp.ndarray:
        fv_norm, _ = self.features(audio, state, key)
        return jnp.argmax(self.logits(params, fv_norm), axis=-1)

    # ---------- streaming serving ----------

    @property
    def chunk_samples(self) -> int:
        """Raw-audio samples per 16 ms streaming hop (at fs_audio)."""
        fexc = self.config.fex
        return int(round(fexc.fs_audio * fexc.frame_shift_ms / 1000.0))

    def streaming_init(self, batch: int, mesh=None):
        """Classifier (GRU) state for a batch of streams — float32 for
        the float/qat backends, int32 Q6.8 codes for "integer".

        ``mesh`` (a serving `stream_mesh`) creates the state buffers
        already sharded over their leading stream axis — no oversized
        single-device allocation, no post-hoc reshard."""
        return self.classifier.init_states(
            self.config.gru, batch, device=self._stream_sharding(mesh)
        )

    @functools.partial(jax.jit, static_argnums=(0,))
    def _streaming_step_jit(self, params, states, fv_t: jnp.ndarray):
        return self.classifier.step(params, states, fv_t, self.config.gru)

    def streaming_step(self, params, states, fv_t: jnp.ndarray):
        """One 16 ms frame for a batch of streams -> (states, logits)."""
        return self._streaming_step_jit(
            self.prepare_params(params), states, fv_t
        )

    @staticmethod
    def _stream_sharding(mesh):
        """mesh -> NamedSharding over the leading stream axis of a
        (batch, channels) state buffer; None stays None (default
        single-device placement)."""
        if mesh is None:
            return None
        from jax.sharding import NamedSharding, PartitionSpec

        from repro.distributed.sharding import STREAM_AXIS

        return NamedSharding(mesh, PartitionSpec(STREAM_AXIS, None))

    def streaming_features_init(self, batch: int, mesh=None):
        """Frontend carry (filter / SRO phase state) for batch streams;
        ``mesh`` shards the carry over its stream axis (see
        `streaming_init`)."""
        return self.frontend.streaming_init(
            self.config, batch, device=self._stream_sharding(mesh)
        )

    def streaming_features_apply(
        self,
        carry,
        chunk: jnp.ndarray,
        state: FrontendState,
        key: Optional[jax.Array] = None,
    ):
        """Pure (unjitted) body of `streaming_features_step`: one raw
        hop (B, chunk_samples) -> (carry, fv_norm (B, C)). Safe to call
        from inside a larger jit — the fused serving tick
        (`repro.serving.serve_loop`) inlines it so frontend + classifier
        + smoothing compile as one program."""
        carry, fv_raw = self.frontend.streaming_step(
            chunk, self.config, state, carry, key=key
        )
        fv_norm = self._postprocess(fv_raw[:, None, :], state)[:, 0, :]
        return carry, fv_norm

    def streaming_logits_apply(self, params, states, fv_t: jnp.ndarray):
        """Pure (unjitted) body of `streaming_step`, for fusing callers.

        ``params`` must already be backend-shaped (`prepare_params`);
        the fused serving tick prepares once at server construction."""
        return self.classifier.step(params, states, fv_t, self.config.gru)

    @functools.partial(jax.jit, static_argnums=(0,))
    def _sfeatures_jit(self, carry, chunk, state, key):
        carry, fv_raw = self.frontend.streaming_step(
            chunk, self.config, state, carry, key=key
        )
        fv_norm = self._postprocess(fv_raw[:, None, :], state)[:, 0, :]
        return carry, fv_norm, fv_raw

    def streaming_features_step(
        self,
        carry,
        chunk: jnp.ndarray,
        state: Optional[FrontendState] = None,
        key: Optional[jax.Array] = None,
    ):
        """One raw-audio hop (B, chunk_samples) -> (carry, fv_norm (B, C)).

        Feed consecutive 16 ms hops; the carry holds per-stream filter
        and SRO-phase state so the concatenated stream matches the batch
        `features` path (up to the documented chunk-edge approximation
        of the 2x oversampler)."""
        carry, fv_norm, _ = self._sfeatures_jit(
            carry, chunk, self._resolve(state), key
        )
        return carry, fv_norm


def record_features_hardware(
    audio: np.ndarray,
    tdcfg: TDFExConfig,
    chip: Optional[TDFExState],
    beta: jnp.ndarray,
    alpha: jnp.ndarray,
    key: Optional[jax.Array] = None,
    batch_size: int = 64,
) -> np.ndarray:
    """Deprecated shim for the pre-registry API: record FV_Raw from the
    hardware sim. Use ``KWSPipeline(KWSPipelineConfig(frontend="hardware",
    ...)).record_features(audio, state)`` instead."""
    warnings.warn(
        "record_features_hardware is deprecated; use "
        'KWSPipeline(KWSPipelineConfig(frontend="hardware", tdfex=...), '
        "state=hardware_state(...)).record_features(audio, key=...) — "
        "see the migration table in CHANGES.md",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.core.frontend import hardware_state

    cfg = KWSPipelineConfig(
        frontend="hardware", fex=tdcfg.fex, tdfex=tdcfg
    )
    state = hardware_state(tdcfg, chip, beta=beta, alpha=alpha)
    return KWSPipeline(cfg).record_features(
        audio, state, key=key, batch_size=batch_size
    )
