"""End-to-end KWS pipeline assembly (Fig. 3): FEx -> classifier.

Two feature paths share one classifier:
  * "software"  — the Section II model (`repro.core.fex`), differentiable,
                  used for QAT training and the Fig. 2 ablation;
  * "hardware"  — the Section III time-domain simulation
                  (`repro.core.tdfex`) with mismatch + calibration, used to
                  reproduce the measured-vs-software accuracy gap.

The classifier is always trained on features *recorded from the chosen
path* (the paper records FV_Raw from the chip for its training set —
Section III-F); `record_features` is that recording step.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.fex import (
    FExConfig,
    FExNormStats,
    fex_forward,
    fex_frames,
)
from repro.core.gru import (
    GRUConfig,
    gru_classifier_forward,
    gru_classifier_step,
    init_gru_classifier,
    init_states,
)
from repro.core.tdfex import TDFExConfig, TDFExState, tdfex_raw_counts, counts_to_fv_raw

__all__ = ["KWSPipelineConfig", "KWSPipeline"]


@dataclasses.dataclass(frozen=True)
class KWSPipelineConfig:
    fex: FExConfig = dataclasses.field(default_factory=FExConfig)
    gru: GRUConfig = dataclasses.field(default_factory=GRUConfig)
    use_log: bool = True
    use_norm: bool = True


class KWSPipeline:
    """Stateless-functional pipeline with convenience wrappers."""

    def __init__(
        self,
        config: KWSPipelineConfig,
        norm_stats: Optional[FExNormStats] = None,
    ):
        self.config = config
        self.norm_stats = norm_stats

    # ---------- feature extraction ----------

    @functools.partial(jax.jit, static_argnums=(0,))
    def features_software(self, audio: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """audio (B, T) -> (fv_norm (B, F, C), fv_raw codes)."""
        return fex_forward(
            audio,
            self.config.fex,
            norm_stats=self.norm_stats,
            use_log=self.config.use_log,
            use_norm=self.config.use_norm,
        )

    def features_from_raw(self, fv_raw: jnp.ndarray) -> jnp.ndarray:
        """Post-processing only: recorded FV_Raw codes -> FV_Norm.

        This is what the chip's digital back-end does after the decimation
        filter, and what training consumes (features recorded once).
        """
        x = fv_raw
        if self.config.use_log:
            x = quant.log_compress_lut(
                x, self.config.fex.quant_bits, self.config.fex.log_bits
            )
        if self.config.use_norm:
            if self.norm_stats is None:
                raise ValueError("use_norm requires fitted norm_stats")
            x = (x - self.norm_stats.mu) / self.norm_stats.sigma
        else:
            in_bits = (
                self.config.fex.log_bits
                if self.config.use_log
                else self.config.fex.quant_bits
            )
            x = x * 2.0 ** -(in_bits - 5)
        return quant.fake_quant(x, quant.ACT_Q6_8)

    # ---------- classifier ----------

    def init_params(self, key: jax.Array) -> Dict[str, Any]:
        return init_gru_classifier(key, self.config.gru)

    @functools.partial(jax.jit, static_argnums=(0,))
    def logits(self, params, fv_norm: jnp.ndarray) -> jnp.ndarray:
        """(B, F, C) -> final-frame logits (B, K)."""
        all_logits = gru_classifier_forward(params, fv_norm, self.config.gru)
        return all_logits[:, -1, :]

    @functools.partial(jax.jit, static_argnums=(0,))
    def logits_all_frames(self, params, fv_norm: jnp.ndarray) -> jnp.ndarray:
        return gru_classifier_forward(params, fv_norm, self.config.gru)

    def predict(self, params, audio: jnp.ndarray) -> jnp.ndarray:
        fv_norm, _ = self.features_software(audio)
        return jnp.argmax(self.logits(params, fv_norm), axis=-1)

    # ---------- streaming serving ----------

    def streaming_init(self, batch: int):
        return init_states(self.config.gru, batch)

    @functools.partial(jax.jit, static_argnums=(0,))
    def streaming_step(self, params, states, fv_t: jnp.ndarray):
        """One 16 ms frame for a batch of streams -> (states, logits)."""
        return gru_classifier_step(params, states, fv_t, self.config.gru)


def record_features_hardware(
    audio: np.ndarray,
    tdcfg: TDFExConfig,
    chip: Optional[TDFExState],
    beta: jnp.ndarray,
    alpha: jnp.ndarray,
    key: Optional[jax.Array] = None,
    batch_size: int = 64,
) -> np.ndarray:
    """Record FV_Raw codes from the hardware sim in batches (Section III-F)."""
    outs = []
    fn = jax.jit(
        lambda a, k: counts_to_fv_raw(
            tdfex_raw_counts(a, tdcfg, chip, k), tdcfg, beta, alpha
        )
    )
    n = audio.shape[0]
    for i in range(0, n, batch_size):
        chunk = jnp.asarray(audio[i : i + batch_size])
        k = None
        if key is not None:
            key, k = jax.random.split(key)
        outs.append(np.asarray(fn(chunk, k)))
    return np.concatenate(outs, axis=0)
