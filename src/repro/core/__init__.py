"""Core library: the paper's contribution (time-domain FEx + GRU-FC KWS)."""

from repro.core.classifier import (
    ClassifierBackend,
    available_classifiers,
    get_classifier,
    register_classifier,
)
from repro.core.fex import FExConfig, FExNormStats, fex_forward, fex_frames
from repro.core.filters import (
    BiquadCoeffs,
    design_filterbank,
    mel_center_frequencies,
)
from repro.core.frontend import (
    FeatureFrontend,
    FrontendState,
    available_frontends,
    get_frontend,
    register_frontend,
)
from repro.core.gru import GRUConfig, gru_classifier_forward, init_gru_classifier
from repro.core.gru_delta import DeltaConfig
from repro.core.gru_int import QuantizedClassifier
from repro.core.pipeline import KWSPipeline, KWSPipelineConfig
from repro.core.tdfex import TDFExConfig, TDFExState, tdfex_forward

__all__ = [
    "FExConfig",
    "FExNormStats",
    "fex_forward",
    "fex_frames",
    "BiquadCoeffs",
    "design_filterbank",
    "mel_center_frequencies",
    "FeatureFrontend",
    "FrontendState",
    "available_frontends",
    "get_frontend",
    "register_frontend",
    "ClassifierBackend",
    "available_classifiers",
    "get_classifier",
    "register_classifier",
    "GRUConfig",
    "gru_classifier_forward",
    "init_gru_classifier",
    "DeltaConfig",
    "QuantizedClassifier",
    "KWSPipeline",
    "KWSPipelineConfig",
    "TDFExConfig",
    "TDFExState",
    "tdfex_forward",
]
