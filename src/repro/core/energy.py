"""Cycle/energy model of the KWS IC — reproduces Table II and Fig. 21.

Grounded in the paper's disclosed numbers:
  * accelerator: 8 HPEs, 250 kHz, 0.75 V; 24 KB WMEM; 9.96 uW while
    streaming 16 ms frames; 75 % dynamic / 25 % leakage; leakage 78 % SRAM;
    dynamic split ~44 % logic / 56 % SRAM.
  * analog FEx: 9.3 uW at 0.5 V (16 channels, VTC + Rec-BPF + PFM).
  * total KWS core: 23 uW; latency 12.4 ms (Fig. 4 / Table II).

The latency model is *predictive*: ceil(MACs / n_hpe) + per-layer FSM
overhead cycles at f_clk. With the paper's network (24,204 MACs) this
gives 12.4 ms, matching Table II — validated in tests/test_energy.py.

Energy constants are calibrated once from the published power split and
then reused to predict power for *other* network sizes (e.g. the 499 KB
Cortex-M7 network of [36] discussed in Section IV) — and, via
`AcceleratorModel.effective_mac_fraction`, for *other MAC loads*: the
ΔGRU serving backend's measured temporal sparsity (`srv.sparsity`,
`repro.core.gru_delta`) plugs in to predict DeltaKWS-style µW/latency
at a given skip rate (benchmarks/fig_delta_tradeoff.py), and via
`AcceleratorModel.duty_cycle`, for gated workloads: the cascaded wake
gate's measured `srv.wake_rate` (`repro.serving.cascade`) composes
multiplicatively with the ΔGRU fraction to predict the µW of a
classifier that sleeps through non-speech frames entirely
(benchmarks/fig_cascade_roc.py).
"""

from __future__ import annotations

import dataclasses

from repro.core.gru import GRUConfig, classifier_macs, classifier_param_bytes

__all__ = [
    "AcceleratorModel",
    "ICPowerModel",
    "paper_accelerator",
    "paper_power_model",
]


@dataclasses.dataclass(frozen=True)
class AcceleratorModel:
    """The GRU-FC accelerator of Section III-E."""

    n_hpe: int = 8
    f_clk_hz: float = 250e3
    # FSM overhead per matrix/vector op (pipeline fill, state transitions).
    # Calibrated so the paper network lands on its measured 12.4 ms:
    # 12.4 ms * 250 kHz = 3100 cycles; MAC cycles = ceil(24204/8) = 3026;
    # 74 remaining cycles over ~10 sequenced ops ~= 7 cycles each.
    overhead_cycles_per_op: int = 7
    n_sequenced_ops: int = 10
    # Fraction of the per-frame MACs actually executed (1.0 = dense).
    # The ΔGRU serving backend (`repro.core.gru_delta`) measures this
    # per stream as `srv.sparsity`; plugging the measured fraction in
    # here predicts DeltaKWS-style gains: MAC cycles (and the dynamic
    # MAC energy in `ICPowerModel`) scale linearly with the executed
    # work, while the FSM overhead and the SRAM/logic leakage do not —
    # exactly the split the DeltaKWS IC reports.
    effective_mac_fraction: float = 1.0
    # Fraction of frames the classifier runs at all (1.0 = always-on).
    # The cascaded wake gate (`repro.serving.cascade`) measures this
    # per stream as `srv.wake_rate`; a gated frame costs the
    # accelerator nothing dynamic, so the time-averaged dynamic MAC
    # power in `ICPowerModel` scales by the duty cycle while leakage
    # (weights stay SRAM-resident) and the per-WOKEN-frame
    # latency/cycles do not — the gate skips frames, it does not speed
    # them up. Composes multiplicatively with effective_mac_fraction
    # (duty cycle x within-wake ΔGRU sparsity).
    duty_cycle: float = 1.0

    def __post_init__(self):
        if not 0.0 <= self.effective_mac_fraction <= 1.0:
            raise ValueError(
                "effective_mac_fraction must be in [0, 1]; got "
                f"{self.effective_mac_fraction}"
            )
        if not 0.0 <= self.duty_cycle <= 1.0:
            raise ValueError(
                f"duty_cycle must be in [0, 1]; got {self.duty_cycle}"
            )

    def effective_macs(self, config: GRUConfig) -> int:
        """Executed MACs per frame under the configured sparsity."""
        return int(round(classifier_macs(config) * self.effective_mac_fraction))

    def cycles_per_frame(self, config: GRUConfig) -> int:
        mac_cycles = -(-self.effective_macs(config) // self.n_hpe)  # ceil
        return mac_cycles + self.overhead_cycles_per_op * self.n_sequenced_ops

    def latency_s(self, config: GRUConfig) -> float:
        """Classifier latency after the last FV arrives (Fig. 4)."""
        return self.cycles_per_frame(config) / self.f_clk_hz

    def utilization(self, config: GRUConfig, frame_shift_s: float = 16e-3):
        """Fraction of the frame period the accelerator is busy."""
        return self.latency_s(config) / frame_shift_s


@dataclasses.dataclass(frozen=True)
class ICPowerModel:
    """Power model calibrated against Fig. 21 / Table I / Table II."""

    accel: AcceleratorModel = dataclasses.field(default_factory=AcceleratorModel)
    # Analog FEx power: per-channel BPF+PFM plus the shared VTC. Table I
    # gives 9.3 uW for 16 channels; the VTC is a single shared block that we
    # attribute ~1.5 uW (two VCOs + FLL at 0.5 V), the rest split per channel.
    fex_vtc_w: float = 1.5e-6
    fex_per_channel_w: float = (9.3e-6 - 1.5e-6) / 16.0
    # Digital front-end (TDC counters, CIC, post-processing @61 Hz): the
    # 23 uW total minus 9.3 (FEx) minus 9.96 (accel) = 3.74 uW.
    digital_frontend_w: float = 23e-6 - 9.3e-6 - 9.96e-6
    # Accelerator energy constants, calibrated from the 9.96 uW / 75-25
    # dynamic-leakage split at 1.513 MMAC/s (24204 MACs / 16 ms):
    #   dynamic 7.47 uW -> 4.94 pJ/MAC (incl. SRAM read, 0.75 V, 65 nm LP)
    #   leakage 2.49 uW at 24+1.3 KB SRAM + logic -> per-KB and fixed parts.
    e_mac_j: float = 7.47e-6 / (24204.0 / 16e-3)
    leak_sram_w_per_kb: float = (2.49e-6 * 0.78) / 25.3
    leak_logic_w: float = 2.49e-6 * 0.22

    def accelerator_power_w(
        self, config: GRUConfig, frame_shift_s: float = 16e-3
    ) -> float:
        # dynamic energy scales with the MACs actually executed (the
        # accelerator's effective_mac_fraction; 1.0 = dense) and with
        # the fraction of frames the cascade gate wakes the classifier
        # at all (duty_cycle; 1.0 = always-on); leakage is
        # state-independent — the weights stay SRAM-resident whether or
        # not a ΔGRU skips their columns (or the gate skips the frame)
        dyn = (
            self.e_mac_j
            * self.accel.effective_macs(config)
            * self.accel.duty_cycle
            / frame_shift_s
        )
        sram_kb = (classifier_param_bytes(config) + 1.3 * 1024) / 1024.0
        leak = self.leak_sram_w_per_kb * sram_kb + self.leak_logic_w
        return dyn + leak

    def fex_power_w(self, num_channels: int = 16) -> float:
        return self.fex_vtc_w + self.fex_per_channel_w * num_channels

    def total_power_w(
        self,
        config: GRUConfig,
        num_channels: int = 16,
        frame_shift_s: float = 16e-3,
    ) -> float:
        return (
            self.fex_power_w(num_channels)
            + self.digital_frontend_w
            + self.accelerator_power_w(config, frame_shift_s)
        )


def paper_accelerator() -> AcceleratorModel:
    return AcceleratorModel()


def paper_power_model() -> ICPowerModel:
    return ICPowerModel()
