"""Per-chip calibration, mirroring the measurement flow of Section III-F.

The chip requires:
  beta  — per-channel offset = free-running SRO counts/frame, measured with
          a zero input (Fig. 13's programmable offset subtractor);
  alpha — per-channel gain correction, measured by applying a reference
          sine at each channel's center frequency and equalizing the
          response (Fig. 17a -> 17b);
  mu/sigma — mean/std of FV_Log over the *training set*, used by the input
          normalizer (Section III-F applies the same mu/sigma at test time).

`calibrate_state` packages the whole bench flow into the `FrontendState`
pytree the pipeline's "hardware"/"hardware-pallas" frontends consume.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.fex import FExNormStats
from repro.core.filters import design_filterbank
from repro.core.frontend import FrontendState
from repro.core.tdfex import (
    TDFExConfig,
    TDFExState,
    tdfex_raw_counts,
)

__all__ = [
    "measure_beta",
    "measure_alpha",
    "calibrate_chip",
    "calibrate_state",
    "fit_norm_stats_from_counts",
]


def measure_beta(
    cfg: TDFExConfig,
    chip: Optional[TDFExState] = None,
    n_frames: int = 16,
    key: Optional[jax.Array] = None,
) -> jnp.ndarray:
    """Zero-input measurement of the free-running offset (counts/frame)."""
    t = int(cfg.fex.fs_audio * n_frames * cfg.fex.frame_shift_ms / 1000.0)
    silence = jnp.zeros((1, t), jnp.float32)
    counts = tdfex_raw_counts(silence, cfg, chip, key)  # (1, F, C)
    # Drop the first frames (filter settling) and average.
    return counts[0, 2:, :].mean(axis=0)


def measure_alpha(
    cfg: TDFExConfig,
    beta: jnp.ndarray,
    chip: Optional[TDFExState] = None,
    amplitude: float = 0.25,
    n_frames: int = 24,
    key: Optional[jax.Array] = None,
) -> jnp.ndarray:
    """Reference-tone gain equalization.

    For each channel, drive a sine at that channel's design center
    frequency and set alpha so all channels report the same signal counts.
    alpha is normalized to mean 1 across channels (pure equalization, no
    overall gain change), as on the chip where alpha is a programmable
    per-channel multiplier.
    """
    fexc = cfg.fex
    f0 = np.asarray(
        design_filterbank(
            fexc.num_channels, fexc.fs_internal, fexc.f_lo, fexc.f_hi, fexc.q
        ).f0
    )
    # Analog tones at the *internal* rate (the function generator of
    # Fig. 16 is not band-limited by the dataset's 16 kHz sampling).
    t = int(fexc.fs_internal * n_frames * fexc.frame_shift_ms / 1000.0)
    ts = np.arange(t) / fexc.fs_internal
    tones = jnp.asarray(
        amplitude * np.sin(2 * np.pi * f0[:, None] * ts[None, :]),
        jnp.float32,
    )  # (C, T) — one tone per channel
    counts = tdfex_raw_counts(tones, cfg, chip, key, audio_rate=False)
    # Response of channel c to its own tone, settling frames dropped:
    settled = counts[:, 4:, :].mean(axis=1)  # (C, C)
    own = jnp.diagonal(settled) - beta  # (C,)
    own = jnp.maximum(own, 1e-6)
    alpha = own.mean() / own
    return alpha / alpha.mean()


def calibrate_chip(
    cfg: TDFExConfig,
    chip: Optional[TDFExState] = None,
    key: Optional[jax.Array] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full per-chip calibration -> (beta, alpha)."""
    if key is not None:
        kb, ka = jax.random.split(key)
    else:
        kb = ka = None
    beta = measure_beta(cfg, chip, key=kb)
    alpha = measure_alpha(cfg, beta, chip, key=ka)
    return beta, alpha


def calibrate_state(
    cfg: TDFExConfig,
    chip: Optional[TDFExState] = None,
    key: Optional[jax.Array] = None,
    norm_stats: Optional[FExNormStats] = None,
) -> FrontendState:
    """Full bench calibration -> the `FrontendState` the hardware
    frontends consume: beta/alpha measurements plus the (possibly
    mismatched) Rec-BPF coefficients designed once for this die.

    ``norm_stats`` (fit from recorded training features, see
    `fit_norm_stats_from_counts`) can be attached now or later via
    `FrontendState.with_norm_stats`.
    """
    from repro.core.frontend import hardware_state

    beta, alpha = calibrate_chip(cfg, chip, key)
    return hardware_state(
        cfg, chip, beta=beta, alpha=alpha, norm_stats=norm_stats
    )


def fit_norm_stats_from_counts(
    fv_raw: jnp.ndarray, cfg: TDFExConfig, eps: float = 1e-3
) -> FExNormStats:
    """mu/sigma of FV_Log over recorded training-set features (B, F, C)."""
    fv_log = quant.log_compress_lut(
        fv_raw, cfg.fex.quant_bits, cfg.fex.log_bits
    )
    flat = fv_log.reshape(-1, fv_log.shape[-1])
    return FExNormStats(mu=flat.mean(0), sigma=flat.std(0) + eps)
