"""Biquad band-pass filter design for the 16-channel FEx.

The paper (Section II) models each analog channel as a *second-order
Butterworth band-pass filter* with Q = 2, center frequencies Mel-spaced
from 100 Hz to 8 kHz, running at a 32 kHz internal rate (the 16 kHz GSCD
audio is 2x oversampled so the top channel does not collide with Nyquist).

A second-order (one-pole-pair) Butterworth band-pass is exactly the
constant-Q biquad

    H(s) = (w0/Q) s / (s^2 + (w0/Q) s + w0^2)

discretized here with the bilinear transform + frequency pre-warping
(identical to the RBJ audio-EQ-cookbook "constant skirt gain" BPF up to
the peak-gain normalization; we use the unity-peak-gain variant so each
channel has 0 dB gain at its center frequency, matching Fig. 17b after
calibration).

Everything is pure numpy/jnp — scipy is used only as a test oracle.
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

__all__ = [
    "BiquadCoeffs",
    "mel_to_hz",
    "hz_to_mel",
    "mel_center_frequencies",
    "design_bandpass_biquad",
    "design_filterbank",
    "biquad_frequency_response",
]


@dataclasses.dataclass(frozen=True)
class BiquadCoeffs:
    """Normalized (a0 == 1) biquad coefficients for C channels.

    y[n] = b0 x[n] + b1 x[n-1] + b2 x[n-2] - a1 y[n-1] - a2 y[n-2]

    Arrays all have shape (C,). For the band-pass design b1 == 0.
    """

    b0: np.ndarray
    b1: np.ndarray
    b2: np.ndarray
    a1: np.ndarray
    a2: np.ndarray
    fs: float
    f0: np.ndarray  # center frequencies (Hz), for reference
    q: float

    @property
    def num_channels(self) -> int:
        return int(self.b0.shape[0])

    def as_arrays(self, dtype=jnp.float32):
        """(b0, b1, b2, a1, a2) stacked as jnp arrays of shape (C,)."""
        return tuple(
            jnp.asarray(v, dtype=dtype)
            for v in (self.b0, self.b1, self.b2, self.a1, self.a2)
        )

    def stacked(self, dtype=jnp.float32) -> jnp.ndarray:
        """Shape (5, C): rows are b0, b1, b2, a1, a2."""
        return jnp.stack(self.as_arrays(dtype), axis=0)


def hz_to_mel(f_hz):
    """HTK-style Mel scale, as used for Mel-spaced analog filterbanks."""
    return 2595.0 * np.log10(1.0 + np.asarray(f_hz, dtype=np.float64) / 700.0)


def mel_to_hz(m):
    return 700.0 * (10.0 ** (np.asarray(m, dtype=np.float64) / 2595.0) - 1.0)


def mel_center_frequencies(
    num_channels: int = 16, f_lo: float = 100.0, f_hi: float = 8000.0
) -> np.ndarray:
    """Center frequencies Mel-spaced from f_lo to f_hi inclusive.

    The paper distributes 16 BPF center frequencies on the Mel scale from
    100 Hz to 8 kHz (Section II); the fabricated chip measured 111 Hz to
    10.4 kHz (Section IV) due to analog spread — the *design* targets are
    what the software model uses.
    """
    mels = np.linspace(hz_to_mel(f_lo), hz_to_mel(f_hi), num_channels)
    return mel_to_hz(mels)


def design_bandpass_biquad(f0_hz, fs: float, q: float = 2.0) -> BiquadCoeffs:
    """Bilinear-transform design of the unity-peak-gain band-pass biquad.

    RBJ cookbook "BPF (constant 0 dB peak gain)":
        w0 = 2*pi*f0/fs ; alpha = sin(w0) / (2*Q)
        b = [alpha, 0, -alpha] / a0 ; a = [1+alpha, -2 cos w0, 1-alpha] / a0
    This is the bilinear transform of H(s) above with the standard
    tan(w0/2) pre-warp baked into the trigonometric form.
    """
    f0 = np.atleast_1d(np.asarray(f0_hz, dtype=np.float64))
    if np.any(f0 <= 0) or np.any(f0 >= fs / 2):
        raise ValueError(
            f"center frequencies must lie in (0, fs/2); got {f0} at fs={fs}"
        )
    w0 = 2.0 * math.pi * f0 / fs
    alpha = np.sin(w0) / (2.0 * q)
    a0 = 1.0 + alpha
    b0 = alpha / a0
    b1 = np.zeros_like(b0)
    b2 = -alpha / a0
    a1 = (-2.0 * np.cos(w0)) / a0
    a2 = (1.0 - alpha) / a0
    return BiquadCoeffs(b0=b0, b1=b1, b2=b2, a1=a1, a2=a2, fs=fs, f0=f0, q=q)


def design_filterbank(
    num_channels: int = 16,
    fs: float = 32000.0,
    f_lo: float = 100.0,
    f_hi: float = 8000.0,
    q: float = 2.0,
) -> BiquadCoeffs:
    """The paper's 16-channel Mel filterbank at the 32 kHz internal rate."""
    return design_bandpass_biquad(
        mel_center_frequencies(num_channels, f_lo, f_hi), fs=fs, q=q
    )


def biquad_frequency_response(coeffs: BiquadCoeffs, freqs_hz) -> np.ndarray:
    """|H(e^{jw})| evaluated at freqs_hz. Shape (C, F). Pure numpy oracle."""
    f = np.asarray(freqs_hz, dtype=np.float64)
    z = np.exp(-1j * 2.0 * math.pi * f / coeffs.fs)  # z^-1, shape (F,)
    z = z[None, :]
    num = (
        coeffs.b0[:, None]
        + coeffs.b1[:, None] * z
        + coeffs.b2[:, None] * z**2
    )
    den = 1.0 + coeffs.a1[:, None] * z + coeffs.a2[:, None] * z**2
    return np.abs(num / den)
