"""Software model of the analog feature extractor (paper Section II, Fig. 2).

Chain:  audio 16 kHz --2x oversample--> 32 kHz
        -> 16-ch band-pass biquad bank (Butterworth 2nd order, Q=2, Mel)
        -> full-wave rectifier |x|
        -> averaging (low-pass) + subsampler  == 16 ms frame shift
        -> 12-bit unsigned quantizer                  (FV_Raw)
        -> logarithmic compressor (12b -> 10b LUT)    (FV_Log)
        -> input normalizer (x - mu) / sigma, Q6.8    (FV_Norm)

This is the *faithful baseline*: a pure-jnp reference of every stage.
The Pallas kernel `repro.kernels.fex_fused` computes stages BPF..average
in a single fused pass and is tested against `biquad_filterbank` +
`frame_average` here.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.filters import BiquadCoeffs, design_filterbank

__all__ = [
    "FExConfig",
    "FExNormStats",
    "oversample2x",
    "biquad_filterbank",
    "biquad_filterbank_streaming",
    "biquad_filterbank_frame_mean",
    "full_wave_rectify",
    "frame_average",
    "fex_frames",
    "fex_forward",
]


@dataclasses.dataclass(frozen=True)
class FExConfig:
    num_channels: int = 16
    fs_audio: float = 16000.0  # GSCD sampling rate
    oversample: int = 2  # paper: 2x to keep 8 kHz channel off Nyquist
    frame_shift_ms: float = 16.0
    f_lo: float = 100.0
    f_hi: float = 8000.0
    q: float = 2.0
    quant_bits: int = 12  # FV_Raw quantizer
    log_bits: int = 10  # FV_Log LUT output
    # Full-scale of the 12-bit quantizer, in rectified-average units of a
    # full-scale (+-1) input. A full-scale sine at a channel center rectifies
    # to mean 2/pi ~ 0.64; 0.7 leaves ~1 dB headroom like the chip's TDC range.
    quant_full_scale: float = 0.7

    @property
    def fs_internal(self) -> float:
        return self.fs_audio * self.oversample

    @property
    def frame_len(self) -> int:
        """Samples per frame at the internal rate (512 for the paper values)."""
        n = self.fs_internal * self.frame_shift_ms / 1000.0
        if abs(n - round(n)) > 1e-9:
            raise ValueError(f"frame shift {self.frame_shift_ms} ms not integral")
        return int(round(n))

    def filterbank(self) -> BiquadCoeffs:
        return design_filterbank(
            self.num_channels, self.fs_internal, self.f_lo, self.f_hi, self.q
        )


@dataclasses.dataclass(frozen=True)
class FExNormStats:
    """mu / sigma of FV_Log over the training set (Section III-F)."""

    mu: jnp.ndarray  # (C,)
    sigma: jnp.ndarray  # (C,)


def oversample2x(audio: jnp.ndarray) -> jnp.ndarray:
    """Linear-interpolation 2x upsampling along the last axis.

    Models the paper's 16 kHz -> 32 kHz oversampling. (B, T) -> (B, 2T).
    """
    nxt = jnp.concatenate([audio[..., 1:], audio[..., -1:]], axis=-1)
    mid = 0.5 * (audio + nxt)
    out = jnp.stack([audio, mid], axis=-1)
    return out.reshape(*audio.shape[:-1], audio.shape[-1] * 2)


def _coeff_rows(coeffs, dtype):
    """Accept either a BiquadCoeffs or a stacked (5, C) array (the form a
    `FrontendState` carries through jit) -> (b0, b1, b2, a1, a2) arrays."""
    if isinstance(coeffs, BiquadCoeffs):
        return coeffs.as_arrays(dtype=dtype)
    arr = jnp.asarray(coeffs, dtype=dtype)
    return arr[0], arr[1], arr[2], arr[3], arr[4]


def biquad_filterbank_streaming(
    x: jnp.ndarray,
    coeffs,
    state: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Stateful filterbank step for chunked/streaming input.

    x: (B, T_chunk); coeffs: BiquadCoeffs or stacked (5, C) array;
    state: transposed-DF-II carry (s1, s2), each (B, C), or None for a
    zero (quiescent) filter. Returns (y (B, T_chunk, C), new_state) so a
    caller can feed consecutive chunks and obtain the same output as one
    batch pass over the concatenated signal.
    """
    b0, b1, b2, a1, a2 = _coeff_rows(coeffs, x.dtype)
    bsz, t = x.shape
    c = b0.shape[-1]

    def step(carry, x_t):
        s1, s2 = carry  # each (B, C)
        xc = x_t[:, None]  # (B, 1)
        y = b0 * xc + s1
        s1_new = b1 * xc - a1 * y + s2
        s2_new = b2 * xc - a2 * y
        return (s1_new, s2_new), y

    if state is None:
        state = (
            jnp.zeros((bsz, c), dtype=x.dtype),
            jnp.zeros((bsz, c), dtype=x.dtype),
        )
    state, ys = jax.lax.scan(step, state, jnp.moveaxis(x, -1, 0))  # (T, B, C)
    return jnp.moveaxis(ys, 0, -2), state


def biquad_filterbank_frame_mean(
    x: jnp.ndarray,
    coeffs,
    state: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """`biquad_filterbank_streaming` + |.| + frame mean, fused in-scan.

    x is ONE frame of internal-rate samples (B, frame_len). The rectified
    mean is accumulated inside the filter scan instead of materializing
    the (B, T, C) filter output and reducing it afterwards — the serving
    tick's hot path, where per-tick HBM traffic and scan-output stacking
    dominate. Returns (mean_abs (B, C), new_state). Matches
    ``abs(streaming output).mean(-2)`` up to float summation order.
    """
    b0, b1, b2, a1, a2 = _coeff_rows(coeffs, x.dtype)
    bsz, t = x.shape
    c = b0.shape[-1]
    if state is None:
        state = (
            jnp.zeros((bsz, c), dtype=x.dtype),
            jnp.zeros((bsz, c), dtype=x.dtype),
        )

    def step(carry, x_t):
        s1, s2, acc = carry
        xc = x_t[:, None]  # (B, 1)
        y = b0 * xc + s1
        s1_new = b1 * xc - a1 * y + s2
        s2_new = b2 * xc - a2 * y
        return (s1_new, s2_new, acc + jnp.abs(y)), None

    acc0 = jnp.zeros((bsz, c), dtype=x.dtype)
    (s1, s2, acc), _ = jax.lax.scan(
        step, (state[0], state[1], acc0), jnp.moveaxis(x, -1, 0)
    )
    return acc / t, (s1, s2)


def biquad_filterbank(x: jnp.ndarray, coeffs) -> jnp.ndarray:
    """Apply C biquads to x: (..., T) -> (..., T, C).

    Transposed direct-form II, scanned over time; this is the jnp oracle
    for the fused Pallas kernel.
    """
    batch_shape = x.shape[:-1]
    t = x.shape[-1]
    xf = x.reshape((-1, t))  # (B, T)
    ys, _ = biquad_filterbank_streaming(xf, coeffs)
    c = ys.shape[-1]
    return ys.reshape(*batch_shape, t, c)


def full_wave_rectify(y: jnp.ndarray) -> jnp.ndarray:
    """The FWR stage |x|. On silicon this is the PFD-based time-domain
    rectifier of Section III-C; behaviorally it is abs()."""
    return jnp.abs(y)


def frame_average(y: jnp.ndarray, frame_len: int) -> jnp.ndarray:
    """Averaging LPF + subsampler: (..., T, C) -> (..., T//frame_len, C).

    The hardware realizes this as a first-order CIC decimator (boxcar sum
    then decimate); averaging over non-overlapping windows is the same
    operation up to the 1/frame_len gain which we fold in here.
    """
    t = y.shape[-2]
    n_frames = t // frame_len
    y = y[..., : n_frames * frame_len, :]
    shape = y.shape[:-2] + (n_frames, frame_len, y.shape[-1])
    return y.reshape(shape).mean(axis=-2)


def fex_frames(audio: jnp.ndarray, config: FExConfig) -> jnp.ndarray:
    """audio (B, T @ fs_audio) -> rectified-average frames (B, F, C), float."""
    x = oversample2x(audio) if config.oversample == 2 else audio
    coeffs = config.filterbank()
    y = biquad_filterbank(x, coeffs)
    r = full_wave_rectify(y)
    return frame_average(r, config.frame_len)


def fex_forward(
    audio: jnp.ndarray,
    config: FExConfig,
    norm_stats: Optional[FExNormStats] = None,
    use_log: bool = True,
    use_norm: bool = True,
    frames: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full FEx: audio -> (fv_norm, fv_raw).

    fv_raw : integer codes of the 12-bit quantizer, shape (B, F, C).
    fv_norm: the classifier input. With use_log/use_norm toggles this
      reproduces the Fig. 2 ablation:
        baseline      : use_log=False, use_norm=False — FV_Raw scaled by the
                        activation LSB, then saturated to Q6.8 (the paper notes
                        the 14-bit activation format cannot cover the 12-bit
                        raw range, which is why the baseline is weak).
        +log          : use_log=True,  use_norm=False
        +log +norm    : use_log=True,  use_norm=True  (the paper's pipeline)
    `frames` short-circuits the filterbank when precomputed (e.g. by the
    fused Pallas kernel or recorded from the tdfex hardware sim).
    """
    if frames is None:
        frames = fex_frames(audio, config)
    fv_raw = quant.quantize_unsigned(
        frames, config.quant_bits, config.quant_full_scale
    )

    x = fv_raw
    if use_log:
        x = quant.log_compress_lut(x, config.quant_bits, config.log_bits)
    if use_norm:
        if norm_stats is None:
            raise ValueError("use_norm=True requires norm_stats (mu/sigma)")
        x = (x - norm_stats.mu) / norm_stats.sigma
    else:
        # Fixed static scaling into the activation format: map the full code
        # range into Q6.8's [0, 32) span (a power-of-two shift, as a
        # fixed-point datapath would): 10-bit log codes >> 5, 12-bit raw
        # codes >> 7. Without the log stage the linear-domain features
        # still condition the GRU poorly — the Fig. 2 baseline gap.
        in_bits = config.log_bits if use_log else config.quant_bits
        x = x * 2.0 ** -(in_bits - 5)
    fv_norm = quant.fake_quant(x, quant.ACT_Q6_8)
    return fv_norm, fv_raw


def fit_norm_stats(fv_log: jnp.ndarray, eps: float = 1e-3) -> FExNormStats:
    """mu/sigma over all frames of the training set (per channel)."""
    mu = fv_log.reshape(-1, fv_log.shape[-1]).mean(axis=0)
    sigma = fv_log.reshape(-1, fv_log.shape[-1]).std(axis=0) + eps
    return FExNormStats(mu=mu, sigma=sigma)
