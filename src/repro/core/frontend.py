"""Pluggable feature-extraction frontends for the KWS pipeline.

The paper's core contribution is a *swappable* analog front-end: the
time-domain FEx (Section III) feeds the same GRU classifier that a
conventional voltage-domain FEx would. This module makes that axis a
first-class API: every way of turning raw audio into FV_Raw quantizer
codes is a `FeatureFrontend` registered under a string key (mirroring
`repro.models.registry`):

  "software"        — the differentiable Section II model
                      (`repro.core.fex`); used for QAT training and the
                      Fig. 2 ablation.
  "hardware"        — the Section III behavioral time-domain simulation
                      (`repro.core.tdfex`): VTC distortion/noise,
                      mismatched Rec-BPF, SRO DeltaSigma TDC, and the
                      beta/alpha calibration of Section III-F.
  "hardware-pallas" — the same signal chain with the TDC stage served by
                      the fused Pallas kernel (`repro.kernels.tdc`),
                      auto-dispatching pallas / interpret / reference
                      per backend and batch shape.

All per-frontend parameters travel in one `FrontendState` pytree (norm
stats, chip mismatch draw, beta/alpha calibration, filterbank coeffs) so
`KWSPipeline.features(audio, state)` is one call site for every path and
the state can cross `jax.jit` boundaries as a regular traced argument.

Streaming: each frontend also exposes a chunked step that consumes one
16 ms raw-audio hop per call and carries filter / phase state across
calls, so `StreamingKWSServer` can accept raw audio instead of
precomputed FV_Norm frames. The only deviation from the batch path is at
chunk edges: the 2x linear-interpolation oversampler needs one sample of
lookahead, which streaming replaces with edge replication (one internal
sample per 512-sample frame; well below one FV_Raw LSB for band-limited
audio).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.fex import (
    FExNormStats,
    biquad_filterbank_frame_mean,
    biquad_filterbank_streaming,
    fex_frames,
    frame_average,
    oversample2x,
)
from repro.core.tdfex import (
    TDFExConfig,
    TDFExState,
    counts_to_fv_raw,
    design_mismatched_filterbank,
    draw_chip,
    sro_tdc,
    vtc,
)

__all__ = [
    "FrontendState",
    "FeatureFrontend",
    "register_frontend",
    "get_frontend",
    "available_frontends",
    "hardware_state",
    "masked_select",
    "SoftwareFrontend",
    "HardwareFrontend",
    "HardwarePallasFrontend",
]


def masked_select(mask: jnp.ndarray, new_tree: Any, old_tree: Any) -> Any:
    """Per-stream pytree select: leaves lead with the stream axis, and
    stream ``i`` takes ``new`` where ``mask[i]`` else keeps ``old``.

    This is how a batched streaming carry (or GRU state / score buffer)
    advances only for streams that submitted a frame this tick — the
    temporal-sparsity contract of frame-synchronous serving: an idle
    stream's state must be bit-identical before and after the tick.

    Sharding-transparent: when the leaves (and the mask) are sharded
    over their leading stream axis, the select is purely elementwise
    per slot, so SPMD partitioning inserts no collectives and the
    contract holds per shard — the broadcast below only ever expands
    replicated (non-stream) trailing dims.
    """
    mask = jnp.asarray(mask)

    def sel(new, old):
        m = jnp.expand_dims(mask, tuple(range(mask.ndim, new.ndim)))
        return jnp.where(m, new, old)

    return jax.tree_util.tree_map(sel, new_tree, old_tree)


# --------------------------------------------------------------------------
# State pytrees
# --------------------------------------------------------------------------

def _register_dataclass_pytree(cls, data_fields):
    """Make a frozen dataclass a jax pytree (all listed fields are leaves)."""
    try:
        jax.tree_util.register_dataclass(
            cls, data_fields=list(data_fields), meta_fields=[]
        )
    except (AttributeError, TypeError):  # very old jax — manual fallback
        jax.tree_util.register_pytree_node(
            cls,
            lambda s: (tuple(getattr(s, f) for f in data_fields), None),
            lambda _, xs: cls(**dict(zip(data_fields, xs))),
        )


# FExNormStats / TDFExState predate this module; register them here so a
# FrontendState holding them is itself a valid traced argument.
_register_dataclass_pytree(FExNormStats, ("mu", "sigma"))
_register_dataclass_pytree(TDFExState, ("gain_mismatch", "cf_mismatch"))


@dataclasses.dataclass(frozen=True)
class FrontendState:
    """Everything a frontend needs beyond the static config, as one pytree.

    norm_stats — mu/sigma of FV_Log over the training set (Section III-F);
                 required whenever the pipeline's ``use_norm`` is on.
    chip       — per-die mismatch realization (hardware frontends only).
    beta       — per-channel offset calibration: free-running SRO
                 counts/frame (Fig. 13's programmable subtractor).
    alpha      — per-channel gain calibration (Fig. 17a -> 17b).
    coeffs     — stacked (5, C) Rec-BPF biquad coefficients. Designed
                 once (including any cf mismatch) when the state is
                 built, because filter design is numpy-only and must not
                 run under a jit trace. None -> the nominal filterbank.

    Fields irrelevant to a given frontend stay None; None sub-trees are
    valid (empty) pytree nodes, so any FrontendState crosses jit.
    """

    norm_stats: Optional[FExNormStats] = None
    chip: Optional[TDFExState] = None
    beta: Optional[jnp.ndarray] = None
    alpha: Optional[jnp.ndarray] = None
    coeffs: Optional[jnp.ndarray] = None

    def with_norm_stats(self, norm_stats: Optional[FExNormStats]):
        return dataclasses.replace(self, norm_stats=norm_stats)


_register_dataclass_pytree(
    FrontendState, ("norm_stats", "chip", "beta", "alpha", "coeffs")
)


# --------------------------------------------------------------------------
# Protocol + registry
# --------------------------------------------------------------------------

class FeatureFrontend:
    """One feature path: raw audio -> FV_Raw quantizer codes.

    Implementations are stateless singletons (all run-time state lives in
    `FrontendState` / the streaming carry), so they are safe to close
    over in jit'd functions. Subclasses implement:

      init_state(cfg, key)            -> FrontendState (calibration etc.)
      raw_codes(audio, cfg, state, key) -> (B, F, C) FV_Raw codes
      streaming_init(cfg, batch, device=None)
                                      -> carry pytree (dict of arrays);
                                      ``device`` (Device or Sharding)
                                      places the buffers at creation —
                                      sharded servers pass a stream-axis
                                      NamedSharding
      streaming_step(chunk, cfg, state, carry, key)
                                      -> (carry, (B, C) FV_Raw frame)

    ``cfg`` is the `KWSPipelineConfig`; frontends read ``cfg.fex`` and
    ``cfg.tdfex_config`` from it. The shared FV_Raw -> FV_Norm
    post-processing (log LUT, normalizer, Q6.8) stays in the pipeline —
    it is the chip's digital back-end and identical for every frontend.
    """

    name: str = "?"
    #: True when raw_codes is differentiable end-to-end (QAT training).
    differentiable: bool = False

    def init_state(
        self,
        cfg,
        key: Optional[jax.Array] = None,
        norm_stats: Optional[FExNormStats] = None,
        **kwargs,
    ) -> FrontendState:
        raise NotImplementedError

    def raw_codes(
        self,
        audio: jnp.ndarray,
        cfg,
        state: FrontendState,
        key: Optional[jax.Array] = None,
    ) -> jnp.ndarray:
        raise NotImplementedError

    def streaming_init(
        self, cfg, batch: int, device: Any = None
    ) -> Dict[str, jnp.ndarray]:
        raise NotImplementedError

    def streaming_step(
        self,
        chunk: jnp.ndarray,
        cfg,
        state: FrontendState,
        carry: Dict[str, jnp.ndarray],
        key: Optional[jax.Array] = None,
    ) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray]:
        raise NotImplementedError


_REGISTRY: Dict[str, FeatureFrontend] = {}


def register_frontend(name: str):
    """Class decorator: instantiate + register under ``name``."""

    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls()
        return cls

    return deco


def get_frontend(name: str) -> FeatureFrontend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown frontend {name!r}; registered frontends: "
            f"{sorted(_REGISTRY)}"
        ) from None


def available_frontends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# --------------------------------------------------------------------------
# Shared streaming helpers
# --------------------------------------------------------------------------

def _chunk_to_internal(chunk: jnp.ndarray, fexc) -> jnp.ndarray:
    """One raw-audio hop (B, S @ fs_audio) -> internal rate (B, frame_len).

    Edge-replicated 2x oversampling (see module docstring for the
    one-sample boundary approximation vs the batch path).
    """
    if fexc.oversample == 2:
        chunk = oversample2x(chunk)
    return chunk


def _nominal_coeffs(cfg, state: FrontendState) -> jnp.ndarray:
    if state is not None and state.coeffs is not None:
        return state.coeffs
    if state is not None and state.chip is not None:
        # The chip's cf mismatch lives in the filterbank design, which is
        # numpy-only and cannot run under a jit trace — refusing here
        # beats silently simulating a mismatch-free filterbank.
        raise ValueError(
            "FrontendState has a chip (cf mismatch) but no designed "
            "coeffs; build the state via init_frontend_state / "
            "calibrate_state / hardware_state instead of by hand"
        )
    return cfg.fex.filterbank().stacked(dtype=jnp.float32)


def hardware_state(
    tdcfg: TDFExConfig,
    chip: Optional[TDFExState] = None,
    beta: Optional[jnp.ndarray] = None,
    alpha: Optional[jnp.ndarray] = None,
    norm_stats: Optional[FExNormStats] = None,
) -> FrontendState:
    """Assemble a hardware-frontend state, designing the (possibly
    mismatched) Rec-BPF coefficients once. beta/alpha default to the
    nominal offset / unity gain (an uncalibrated die)."""
    c = tdcfg.fex.num_channels
    if beta is None:
        beta = jnp.full((c,), tdcfg.beta_nominal, jnp.float32)
    if alpha is None:
        alpha = jnp.ones((c,), jnp.float32)
    return FrontendState(
        norm_stats=norm_stats,
        chip=chip,
        beta=jnp.asarray(beta),
        alpha=jnp.asarray(alpha),
        coeffs=design_mismatched_filterbank(tdcfg, chip).stacked(
            dtype=jnp.float32
        ),
    )


# --------------------------------------------------------------------------
# software — differentiable Section II model
# --------------------------------------------------------------------------

@register_frontend("software")
class SoftwareFrontend(FeatureFrontend):
    """Pure-jnp voltage-domain model: BPF -> |.| -> frame mean -> 12-bit
    quantizer (straight-through estimator), end-to-end differentiable."""

    differentiable = True

    def init_state(self, cfg, key=None, norm_stats=None, **kwargs):
        del key, kwargs  # nothing to calibrate in the ideal model
        return FrontendState(norm_stats=norm_stats)

    def raw_codes(self, audio, cfg, state, key=None):
        del key  # the software model is noiseless
        fexc = cfg.fex
        if state is not None and state.coeffs is not None:
            x = oversample2x(audio) if fexc.oversample == 2 else audio
            y, _ = biquad_filterbank_streaming(x, state.coeffs)
            frames = frame_average(jnp.abs(y), fexc.frame_len)
        else:
            frames = fex_frames(audio, fexc)
        return quant.quantize_unsigned(
            frames, fexc.quant_bits, fexc.quant_full_scale
        )

    def streaming_init(self, cfg, batch, device=None):
        c = cfg.fex.num_channels
        # distinct buffers per leaf: the serving tick donates the whole
        # carry, and a shared zeros buffer cannot be donated twice
        z = lambda: jnp.zeros(  # noqa: E731
            (batch, c), jnp.float32, device=device
        )
        return {"s1": z(), "s2": z()}

    def streaming_step(self, chunk, cfg, state, carry, key=None):
        del key
        fexc = cfg.fex
        x = _chunk_to_internal(chunk, fexc)
        # in-scan rectified mean: the serving tick's hot path never
        # materializes the (B, frame_len, C) filter output
        frame, (s1, s2) = biquad_filterbank_frame_mean(
            x, _nominal_coeffs(cfg, state), (carry["s1"], carry["s2"])
        )
        codes = quant.quantize_unsigned(
            frame, fexc.quant_bits, fexc.quant_full_scale
        )
        return {"s1": s1, "s2": s2}, codes


# --------------------------------------------------------------------------
# hardware — behavioral time-domain simulation (Section III)
# --------------------------------------------------------------------------

class _HardwareBase(FeatureFrontend):
    """Shared VTC -> Rec-BPF -> (TDC) -> beta/alpha signal chain; the TDC
    stage itself is provided by `_counts`."""

    def init_state(
        self,
        cfg,
        key=None,
        norm_stats=None,
        mismatch: bool = True,
        calibrate: bool = True,
        **kwargs,
    ):
        """Build a calibrated per-die state (Section III-F flow).

        key + mismatch=True draws a fresh chip (gain/cf mismatch);
        calibrate=True measures beta (zero input) and alpha (reference
        tones) exactly like the bench flow in `repro.core.calibration`.
        """
        del kwargs
        tdcfg = cfg.tdfex_config
        chip = None
        if key is not None and mismatch:
            key, k_chip = jax.random.split(key)
            chip = draw_chip(k_chip, tdcfg)
        beta = alpha = None  # hardware_state defaults: uncalibrated die
        if calibrate:
            from repro.core.calibration import calibrate_chip

            beta, alpha = calibrate_chip(tdcfg, chip, key=key)
        return hardware_state(
            tdcfg, chip, beta=beta, alpha=alpha, norm_stats=norm_stats
        )

    # --- TDC stage, overridden by the Pallas variant ---
    def _counts(self, rect, tdcfg, chip, key):
        return sro_tdc(rect, tdcfg, chip, key)

    def _calibration(self, tdcfg, state: FrontendState):
        if state is None or state.beta is None:
            beta = jnp.float32(tdcfg.beta_nominal)
        else:
            beta = state.beta
        alpha = (
            jnp.float32(1.0)
            if state is None or state.alpha is None
            else state.alpha
        )
        return beta, alpha

    def raw_codes(self, audio, cfg, state, key=None):
        tdcfg = cfg.tdfex_config
        if key is not None:
            k_vtc, k_tdc = jax.random.split(key)
        else:
            k_vtc = k_tdc = None
        duty = vtc(audio, tdcfg, k_vtc)
        y, _ = biquad_filterbank_streaming(
            duty, _nominal_coeffs(cfg, state)
        )
        rect = jnp.abs(y)
        chip = state.chip if state is not None else None
        counts = self._counts(rect, tdcfg, chip, k_tdc)
        beta, alpha = self._calibration(tdcfg, state)
        return counts_to_fv_raw(counts, tdcfg, beta, alpha)

    def streaming_init(self, cfg, batch, device=None):
        c = cfg.fex.num_channels
        # distinct buffers per leaf (donation-safe, see SoftwareFrontend)
        z = lambda: jnp.zeros(  # noqa: E731
            (batch, c), jnp.float32, device=device
        )
        # r: fractional phase carry of the 15-phase counter (counts);
        # j: the previous frame-edge phase jitter (counts), so keyed
        # streaming reproduces the batch path's SRO phase noise.
        return {"s1": z(), "s2": z(), "r": z(), "j": z()}

    def streaming_step(self, chunk, cfg, state, carry, key=None):
        tdcfg = cfg.tdfex_config
        if key is not None:
            k_vtc, k_jit = jax.random.split(key)
        else:
            k_vtc = k_jit = None
        duty = vtc(chunk, tdcfg, k_vtc)
        y, (s1, s2) = biquad_filterbank_streaming(
            duty, _nominal_coeffs(cfg, state), (carry["s1"], carry["s2"])
        )
        rect = jnp.abs(y)  # (B, frame_len, C)
        gain = 1.0
        if state is not None and state.chip is not None:
            gain = 1.0 + state.chip.gain_mismatch
        f_inst = jnp.maximum(
            (tdcfg.f_free_hz + tdcfg.k_sro_hz * rect) * gain, 0.0
        )
        # The per-tick floor increments telescope within a frame, so one
        # hop needs only the summed phase and the fractional carry r:
        # counts = floor(r + sum(P f dt)); r' = frac(...). ZOH over the
        # os TDC ticks per sample contributes a factor of os.
        delta = (
            tdcfg.n_phases
            * tdcfg.tdc_oversample
            / tdcfg.f_tdc
            * f_inst.sum(axis=-2)
        )  # (B, C)
        # SRO phase jitter: in the batch path only the jitter at the two
        # frame-edge ticks survives the telescoping, so one draw per
        # frame (scaled to counts) reproduces its per-frame statistics.
        j = carry["j"]
        if k_jit is not None and tdcfg.phase_noise_rms > 0:
            j = tdcfg.n_phases * tdcfg.phase_noise_rms * jax.random.normal(
                k_jit, delta.shape, delta.dtype
            )
        tot = carry["r"] + delta + (j - carry["j"])
        counts = jnp.floor(tot)
        r = tot - counts
        beta, alpha = self._calibration(tdcfg, state)
        codes = counts_to_fv_raw(
            counts[:, None, :], tdcfg, beta, alpha
        )[:, 0, :]
        return {"s1": s1, "s2": s2, "r": r, "j": j}, codes


@register_frontend("hardware")
class HardwareFrontend(_HardwareBase):
    """Behavioral chip simulation with the jnp cumsum/floor TDC."""


@register_frontend("hardware-pallas")
class HardwarePallasFrontend(_HardwareBase):
    """Same signal chain, TDC served by the fused Pallas kernel
    (`repro.kernels.tdc`), auto-dispatching pallas / interpret /
    reference per backend and batch shape. SRO phase jitter
    (``phase_noise_rms``) is not modeled inside the kernel."""

    def _counts(self, rect, tdcfg, chip, key):
        del key  # kernel path is deterministic
        from repro.kernels.tdc import tdc_counts

        return tdc_counts(rect, tdcfg, chip)
