"""Fixed-point / quantization substrate matching the paper's datapath.

The IC uses (Section II / III-E):
  * 12-bit unsigned quantizer on the decimated FEx output (FV_Raw),
  * 10-bit logarithmic LUT output (FV_Log),
  * 14-bit signed activations in Q6.8 (6 integer + 8 fractional bits)
    for FV_Norm and all GRU activations,
  * 8-bit signed weights,
  * 24-bit accumulators in the 8 HPEs.

Training uses quantization-aware training (QAT) with straight-through
estimators; inference can run a bit-exact integer path (see intgemm
kernel) whose results the QAT fake-quant path matches by construction.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "QuantSpec",
    "ACT_Q6_8",
    "WEIGHT_INT8",
    "ACC_INT24",
    "BIAS_Q8_15",
    "ste_round",
    "fake_quant",
    "quantize_int",
    "dequantize_int",
    "quantize_unsigned",
    "log_compress_lut",
    "make_log_lut",
    "round_shift_even",
    "clip_act_codes",
    "sigmoid_lut_q68",
    "tanh_lut_q68",
    "lut_sigmoid_q68",
    "lut_tanh_q68",
]


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """A fixed-point format: `bits` total, `frac_bits` fractional, signed."""

    bits: int
    frac_bits: int
    signed: bool = True

    @property
    def scale(self) -> float:
        """LSB weight: value = code * 2**-frac_bits."""
        return 2.0 ** (-self.frac_bits)

    @property
    def qmin(self) -> int:
        return -(2 ** (self.bits - 1)) if self.signed else 0

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1 if self.signed else 2**self.bits - 1

    @property
    def max_value(self) -> float:
        return self.qmax * self.scale

    @property
    def min_value(self) -> float:
        return self.qmin * self.scale


# The paper's formats.
ACT_Q6_8 = QuantSpec(bits=14, frac_bits=8, signed=True)  # activations / FV_Norm
WEIGHT_INT8 = QuantSpec(bits=8, frac_bits=7, signed=True)  # weights in [-1, 1)
ACC_INT24 = QuantSpec(bits=24, frac_bits=16, signed=True)  # HPE accumulator
FV_RAW_U12 = QuantSpec(bits=12, frac_bits=0, signed=False)  # quantizer output
FV_LOG_U10 = QuantSpec(bits=10, frac_bits=0, signed=False)  # log LUT output
# Biases live pre-loaded in the HPE accumulator, at the accumulation
# scale of a Q6.8 activation x int8 weight product (frac = 8 + 7 = 15).
BIAS_Q8_15 = QuantSpec(bits=24, frac_bits=15, signed=True)


@jax.custom_jvp
def ste_round(x: jnp.ndarray) -> jnp.ndarray:
    """round-to-nearest-even with a straight-through gradient."""
    return jnp.round(x)


@ste_round.defjvp
def _ste_round_jvp(primals, tangents):
    (x,), (t,) = primals, tangents
    return jnp.round(x), t


def fake_quant(x: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    """Quantize-dequantize to `spec` on the float path (QAT forward).

    Saturates at the format bounds (the HPE accumulator and activation
    registers saturate rather than wrap) and uses STE for gradients.
    """
    inv = 2.0**spec.frac_bits
    q = ste_round(x * inv)
    q = jnp.clip(q, spec.qmin, spec.qmax)
    return q * spec.scale


def quantize_int(x: jnp.ndarray, spec: QuantSpec, dtype=jnp.int32) -> jnp.ndarray:
    """Float -> integer codes (saturating). Bit-exact integer path entry."""
    q = jnp.round(x * 2.0**spec.frac_bits)
    return jnp.clip(q, spec.qmin, spec.qmax).astype(dtype)


def dequantize_int(codes: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    return codes.astype(jnp.float32) * spec.scale


def quantize_unsigned(x: jnp.ndarray, bits: int, x_max: float) -> jnp.ndarray:
    """The FEx 12-bit unsigned quantizer: [0, x_max] -> integer codes.

    Mirrors the DeltaSigma-TDC + decimation output register width. Values
    are clipped (the TDC count register saturates).
    """
    levels = 2**bits - 1
    q = ste_round(jnp.clip(x, 0.0, x_max) / x_max * levels)
    return q  # float codes in [0, levels]; STE-differentiable


def make_log_lut(in_bits: int = 12, out_bits: int = 10) -> jnp.ndarray:
    """The 12-bit -> 10-bit logarithmic compression LUT (Section II).

    out = round((2^out_bits - 1) * log2(1 + v) / log2(2^in_bits)) — a
    monotone logarithmic companding curve covering the full input range,
    exactly representable as a 4096-entry ROM on the IC.
    """
    v = jnp.arange(2**in_bits, dtype=jnp.float32)
    out = jnp.round(
        (2.0**out_bits - 1.0) * jnp.log2(1.0 + v) / (in_bits * 1.0)
    )
    return out.astype(jnp.float32)


def log_compress_lut(codes: jnp.ndarray, in_bits: int = 12, out_bits: int = 10):
    """Differentiable (STE) logarithmic compression of integer codes.

    On hardware this is a ROM lookup; here we evaluate the closed form and
    round with STE so QAT can backprop through the FEx chain.
    """
    x = jnp.clip(codes, 0.0, 2.0**in_bits - 1.0)
    out = (2.0**out_bits - 1.0) * jnp.log2(1.0 + x) / (in_bits * 1.0)
    return ste_round(out)


# --------------------------------------------------------------------------
# Bit-exact integer inference substrate (the IC's datapath on codes).
#
# The contract with the QAT fake-quant path: every float op the QAT
# forward performs on grid values is exactly representable in float32
# for the network's magnitudes, so replaying it on integer codes with
# the same round-to-nearest-even rule is bit-identical (regression-
# tested in tests/test_classifier_int.py). Rescaling a frac-a x frac-b
# product (or a bias-augmented accumulator) back to Q6.8 is a single
# `round_shift_even`; sigmoid/tanh are ROM lookups over the 15-bit sum
# of two saturated Q6.8 addends, exactly as the IC's LUTs.
# --------------------------------------------------------------------------

def round_shift_even(codes: jnp.ndarray, shift: int) -> jnp.ndarray:
    """``round(codes / 2**shift)`` with ties-to-even, pure integer ops.

    Matches `jnp.round` (round-half-even) on the same rational values,
    which is what makes the integer path reproduce `fake_quant` bit for
    bit. `codes` must be a signed integer array; the arithmetic right
    shift floors for negatives, and the remainder test rounds the tie
    toward the even quotient.
    """
    if shift == 0:
        return codes
    half = 1 << (shift - 1)
    q = codes >> shift  # arithmetic shift: floor division
    r = codes - (q << shift)  # remainder in [0, 2**shift)
    round_up = (r > half) | ((r == half) & ((q & 1) == 1))
    return q + round_up.astype(q.dtype)


def clip_act_codes(codes: jnp.ndarray) -> jnp.ndarray:
    """Saturate integer codes to the Q6.8 activation register range."""
    return jnp.clip(codes, ACT_Q6_8.qmin, ACT_Q6_8.qmax)


# Domain of the sigmoid/tanh LUTs: the sum of two saturated Q6.8 codes
# (gate preactivations are i_gate + h_gate with both addends already
# clipped to the activation register), i.e. [2*qmin, 2*qmax].
_LUT_MIN = 2 * ACT_Q6_8.qmin
_LUT_MAX = 2 * ACT_Q6_8.qmax


@functools.lru_cache(maxsize=None)
def sigmoid_lut_q68() -> jnp.ndarray:
    """Q6.8 sigmoid ROM over the summed-preactivation code domain.

    Entry ``i`` holds ``quantize_int(sigmoid((i + _LUT_MIN) * 2^-8))`` —
    the same float evaluation + round-half-even the QAT path performs,
    so lookup and fake-quant agree exactly on every representable input.
    Built eagerly even when first requested under a trace (the cached
    array must be a constant, not a tracer of the enclosing scan/jit).
    """
    with jax.ensure_compile_time_eval():
        codes = jnp.arange(_LUT_MIN, _LUT_MAX + 1, dtype=jnp.int32)
        vals = jax.nn.sigmoid(codes.astype(jnp.float32) * ACT_Q6_8.scale)
        return quantize_int(vals, ACT_Q6_8)


@functools.lru_cache(maxsize=None)
def tanh_lut_q68() -> jnp.ndarray:
    """Q6.8 tanh ROM over the summed-preactivation code domain."""
    with jax.ensure_compile_time_eval():
        codes = jnp.arange(_LUT_MIN, _LUT_MAX + 1, dtype=jnp.int32)
        vals = jnp.tanh(codes.astype(jnp.float32) * ACT_Q6_8.scale)
        return quantize_int(vals, ACT_Q6_8)


def lut_sigmoid_q68(codes: jnp.ndarray) -> jnp.ndarray:
    """Integer sigmoid: summed Q6.8 preactivation codes -> Q6.8 codes."""
    idx = jnp.clip(codes, _LUT_MIN, _LUT_MAX) - _LUT_MIN
    return jnp.take(sigmoid_lut_q68(), idx)


def lut_tanh_q68(codes: jnp.ndarray) -> jnp.ndarray:
    """Integer tanh: summed Q6.8 preactivation codes -> Q6.8 codes."""
    idx = jnp.clip(codes, _LUT_MIN, _LUT_MAX) - _LUT_MIN
    return jnp.take(tanh_lut_q68(), idx)
