"""Temporal-sparsity delta-GRU inference engine (DeltaKWS-style ΔGRU).

Speech features are temporally redundant: consecutive 16 ms FV_Norm
frames (and the GRU hidden states they drive) change little, so most of
the accelerator's dense MAC work recomputes products it already knows.
DeltaKWS ("DeltaKWS: A 65nm 36nJ/Decision Bio-inspired Temporal-
Sparsity-Aware Digital Keyword Spotting IC", PAPERS.md) exploits this
with a ΔGRU: each layer remembers the last-TRANSMITTED input/state
vectors and the running matmul partial sums, and per timestep only the
components whose change exceeds a threshold θ fire a (delta · weight
column) update — everything else is skipped, cutting effective MACs
several-fold at near-iso accuracy.

This module is that engine for the paper's 16 -> GRU(48) -> GRU(48) ->
FC(12) classifier, in both arithmetic domains of the classifier
registry (`repro.core.classifier`):

  * the QAT fake-quant float domain (`delta_*` functions) — the delta
    sibling of `repro.core.gru`, registered as backend ``"delta"``;
  * the bit-exact integer code domain (`int_delta_*` functions, int8
    weights through the saturating-int24 `intgemm` kernel, Q6.8 ROM
    LUT nonlinearities) — the delta sibling of `repro.core.gru_int`,
    registered as backend ``"delta-int"``.

Per layer, the delta state carries:

  h        the true GRU hidden state (identical to the dense backends),
  x_ref    last-transmitted input memory  (what the columns of W_i saw),
  h_ref    last-transmitted state memory  (what the columns of W_h saw),
  acc_x    running partial sum Σ Δx · W_i   (bias NOT folded in, so a
  acc_h    running partial sum Σ Δh · W_h    zeroed slot is a valid
                                             fresh stream — the serving
                                             slot reset just zeroes),
  skipped  per-stream int32 count of delta-eligible weight COLUMNS
           skipped so far (a layer's column = 3H MACs; column units
           keep the counter ~4 days from int32 overflow at 16 ms
           ticks, and `effective_mac_fraction` converts exactly),
  total    per-stream int32 count of delta-eligible columns offered.

Per step, with θ in Q6.8 code units (`DeltaConfig`):

  Δx = x - x_ref;  fire = |Δx| > θ_x;  Δx[~fire] = 0
  x_ref += Δx;     acc_x += Δx · W_i          (only fired columns cost)
  gi = quantize(acc_x + b_i)                  (the dense path's
                                               quantize(x·W_i + b_i))
  ... and the same for Δh against h_ref / acc_h / W_h; the gates, the
  r·h_n product and the convex h update are EXACTLY the dense cell's.

Bit-identity contract (regression-tested in tests/test_gru_delta.py):
at θ = 0 only exactly-unchanged components are skipped, so the partial
sums telescope — acc_x ≡ x · W_i and acc_h ≡ h · W_h on the nose — and
the engine is BIT-identical to its dense base backend ("qat" for the
float domain, "integer" for the code domain) for the full forward, the
streaming step, the fused serving tick, the lax.scan replay, and the
sharded multi-device server. The arithmetic argument is the same one
the QAT/integer identity already rests on (`repro.core.quant`): every
value lives on a fixed-point grid (Q6.8 inputs/states, frac-15 partial
sums) whose in-range sums and products are exact in both int32 and
float32, so adding increments in a different order changes nothing.

The skipped/total counters count DELTA-ELIGIBLE work only — the GRU
matmul lanes a ΔGRU can skip (each skipped input component saves a
3H-wide weight column). Bias adds and the dense FC head are excluded
from the counters; `effective_mac_fraction` converts columns to MACs
per layer and folds the always-dense FC back in, so the reported
fraction covers the whole classifier.

Everything is pure jnp on fixed-size arrays, so the engine scans,
vmaps, shards over the ("stream",) serving mesh, and fuses into the
serving tick exactly like the dense backends.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.gru import GRUConfig, _layer_weights, _maybe_q, fc_logits
from repro.core.gru_int import (
    _ACC_SHIFT,
    _ACT_SHIFT,
    _ONE_Q68,
    _accum,
    QuantizedClassifier,
)
from repro.kernels.intgemm import intgemm

__all__ = [
    "DeltaConfig",
    "delta_init_states",
    "delta_gru_cell",
    "delta_classifier_step",
    "delta_classifier_forward",
    "int_delta_init_states",
    "int_delta_gru_cell",
    "int_delta_classifier_step",
    "int_delta_classifier_forward",
    "delta_eligible_macs_per_frame",
    "dense_fc_macs_per_frame",
    "effective_mac_fraction",
    "is_delta_states",
]


@dataclasses.dataclass(frozen=True)
class DeltaConfig:
    """ΔGRU thresholds, in the FV_Norm/state value domain (Q6.8 units).

    ``theta_x`` / ``theta_h`` apply to every layer's input / hidden
    deltas; ``per_layer`` overrides both per layer as a tuple of
    (theta_x, theta_h) pairs (length must equal ``gru.num_layers``).
    θ = 0 (the default) skips only exactly-unchanged components and is
    bit-identical to the dense base backend.

    Thresholds are snapped to the Q6.8 grid (`code_thresholds`) so the
    float- and code-domain engines fire identically: a delta fires when
    ``|Δ| > θ`` with both sides on the grid.
    """

    theta_x: float = 0.0
    theta_h: float = 0.0
    per_layer: Optional[Tuple[Tuple[float, float], ...]] = None

    def __post_init__(self):
        thetas = [self.theta_x, self.theta_h]
        if self.per_layer is not None:
            # normalize to nested tuples so the config stays hashable
            object.__setattr__(
                self,
                "per_layer",
                tuple((float(tx), float(th)) for tx, th in self.per_layer),
            )
            thetas += [t for pair in self.per_layer for t in pair]
        if any(t < 0 for t in thetas):
            raise ValueError(f"delta thresholds must be >= 0; got {self}")

    def code_thresholds(self, num_layers: int) -> Tuple[Tuple[int, int], ...]:
        """Per-layer (θ_x, θ_h) in integer Q6.8 code units."""
        if self.per_layer is not None:
            if len(self.per_layer) != num_layers:
                raise ValueError(
                    f"DeltaConfig.per_layer has {len(self.per_layer)} "
                    f"entries for {num_layers} GRU layers"
                )
            pairs = self.per_layer
        else:
            pairs = ((self.theta_x, self.theta_h),) * num_layers
        scale = 2.0 ** quant.ACT_Q6_8.frac_bits
        return tuple(
            (int(round(tx * scale)), int(round(th * scale)))
            for tx, th in pairs
        )


def _layer_dims(config: GRUConfig) -> List[Tuple[int, int]]:
    h = config.hidden_dim
    return [
        (config.input_dim if layer == 0 else h, h)
        for layer in range(config.num_layers)
    ]


def delta_eligible_macs_per_frame(config: GRUConfig) -> int:
    """MACs per frame a ΔGRU can skip: the GRU matmul lanes (each input/
    state component drives a 3H-wide weight column)."""
    return sum(3 * h * (i + h) for i, h in _layer_dims(config))


def dense_fc_macs_per_frame(config: GRUConfig) -> int:
    """The always-dense FC head's MACs per frame (never delta-skipped)."""
    return config.num_classes * config.hidden_dim


def _zeros_state(config, batch, dtype, device) -> List[Dict[str, jnp.ndarray]]:
    states = []
    for in_dim, h in _layer_dims(config):
        z = lambda shape, dt: jnp.zeros(shape, dt, device=device)  # noqa: E731
        states.append(
            {
                "h": z((batch, h), dtype),
                "x_ref": z((batch, in_dim), dtype),
                "h_ref": z((batch, h), dtype),
                "acc_x": z((batch, 3 * h), dtype),
                "acc_h": z((batch, 3 * h), dtype),
                "skipped": z((batch,), jnp.int32),
                "total": z((batch,), jnp.int32),
            }
        )
    return states


def delta_init_states(
    config: GRUConfig, batch: int, device=None
) -> List[Dict[str, jnp.ndarray]]:
    """Float-domain per-layer delta state (all-zeros IS the fresh state:
    empty memories, empty partial sums, zero counters — which is why the
    serving slot reset can just zero a slot's slices)."""
    return _zeros_state(config, batch, jnp.float32, device)


def int_delta_init_states(
    config: GRUConfig, batch: int, device=None
) -> List[Dict[str, jnp.ndarray]]:
    """Code-domain per-layer delta state (int32 Q6.8 / frac-15 codes)."""
    return _zeros_state(config, batch, jnp.int32, device)


def is_delta_states(states: Any) -> bool:
    """True when ``states`` is a delta-backend state list/tuple (the
    serving layer uses this to expose sparsity telemetry)."""
    return (
        isinstance(states, (list, tuple))
        and len(states) > 0
        and isinstance(states[0], dict)
        and "skipped" in states[0]
    )


def _count_macs(st, fire_x, fire_h):
    """Update the per-stream skipped/total counters for one step.

    Every input/state component offers one 3H-wide weight column; a
    non-fired component skips it entirely. The counters tick in COLUMN
    units (each column = 3H MACs — `effective_mac_fraction` converts,
    and the 3H factor cancels inside a layer anyway): a layer offers
    I+H <= 96 columns per frame, so an int32 counter lasts ~2^31/96
    frames ~= 4 days of continuous 16 ms ticks before overflow, vs
    under an hour if it ticked in raw MACs. Counters reset with the
    slot (`open_stream`).
    """
    in_dim = fire_x.shape[-1]
    h = fire_h.shape[-1]
    fired = fire_x.sum(-1, dtype=jnp.int32) + fire_h.sum(-1, dtype=jnp.int32)
    skipped = st["skipped"] + (in_dim + h - fired)
    total = st["total"] + jnp.int32(in_dim + h)
    return skipped, total


# --------------------------------------------------------------------------
# float (QAT fake-quant) domain — the delta sibling of repro.core.gru
# --------------------------------------------------------------------------

def delta_gru_cell(
    layer: Dict[str, jnp.ndarray],
    st: Dict[str, jnp.ndarray],
    x: jnp.ndarray,
    config: GRUConfig,
    thetas: Tuple[int, int],
    matmul=None,
) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray]:
    """One ΔGRU step, QAT float domain: x (B, I) -> (new state, h' (B, H)).

    The gate math after the partial sums is verbatim `gru.gru_cell`
    (quantized gate outputs, ROM-faithful ordering); only the way the
    two matmul preactivations are produced differs — incrementally from
    the thresholded deltas instead of densely from x and h.

    ``matmul`` overrides how a Δ·W contribution is evaluated (default:
    dense ``dx @ w``). The fused-tick megakernel passes its gather-
    compacted sparse product here (`repro.kernels.tick_fused`), which
    multiplies only the firing columns — bit-identical by the grid
    argument above, but with work proportional to the fire count.
    """
    aspec = config.act_spec
    w_i, w_h, b_i, b_h = _layer_weights(layer, config.weight_spec)
    tx, th = thetas
    scale = quant.ACT_Q6_8.scale
    mm = (lambda d, w: d @ w) if matmul is None else matmul

    dx = x - st["x_ref"]
    fire_x = jnp.abs(dx) > tx * scale
    dx = jnp.where(fire_x, dx, 0.0)
    x_ref = st["x_ref"] + dx
    acc_x = st["acc_x"] + mm(dx, w_i)

    dh = st["h"] - st["h_ref"]
    fire_h = jnp.abs(dh) > th * scale
    dh = jnp.where(fire_h, dh, 0.0)
    h_ref = st["h_ref"] + dh
    acc_h = st["acc_h"] + mm(dh, w_h)

    gi = _maybe_q(acc_x + b_i, aspec)  # (B, 3H)
    gh = _maybe_q(acc_h + b_h, aspec)
    i_r, i_z, i_n = jnp.split(gi, 3, axis=-1)
    h_r, h_z, h_n = jnp.split(gh, 3, axis=-1)
    r = _maybe_q(jax.nn.sigmoid(i_r + h_r), aspec)
    z = _maybe_q(jax.nn.sigmoid(i_z + h_z), aspec)
    n = _maybe_q(jnp.tanh(i_n + _maybe_q(r * h_n, aspec)), aspec)
    h_new = _maybe_q((1.0 - z) * n + z * st["h"], aspec)

    skipped, total = _count_macs(st, fire_x, fire_h)
    new_st = {
        "h": h_new, "x_ref": x_ref, "h_ref": h_ref,
        "acc_x": acc_x, "acc_h": acc_h,
        "skipped": skipped, "total": total,
    }
    return new_st, h_new


def _fc_logits(params, x, config):
    """The dense FC head — delegated to `gru.fc_logits` (the θ=0
    bit-identity target lives in one place), with the quantized specs
    forced on: the delta engine is always quantized, like the gate
    math in `delta_gru_cell` which ignores ``config.quantized``.
    """
    if not config.quantized:
        config = dataclasses.replace(config, quantized=True)
    return fc_logits(params, x, config)


def delta_classifier_step(
    params: Dict[str, Any],
    states: List[Dict[str, jnp.ndarray]],
    fv_t: jnp.ndarray,
    config: GRUConfig,
    thetas: Tuple[Tuple[int, int], ...],
    matmul=None,
) -> Tuple[List[Dict[str, jnp.ndarray]], jnp.ndarray]:
    """Streaming ΔGRU step: one frame (B, C) -> (new states, (B, K)).

    The input is snapped to the Q6.8 grid first (a no-op for pipeline-
    produced frames, which already live on it): the delta memories MUST
    stay on the grid or the partial sums stop telescoping exactly —
    and it keeps "delta" and "delta-int" in bit-agreement for any
    input, mirroring the integer backend's entry quantization.
    ``matmul`` threads through to every `delta_gru_cell`.
    """
    new_states = []
    x = quant.fake_quant(fv_t, config.act_spec)
    for layer, st, t in zip(params["gru"], states, thetas):
        st, x = delta_gru_cell(layer, st, x, config, t, matmul=matmul)
        new_states.append(st)
    return new_states, _fc_logits(params, x, config)


def delta_classifier_forward(
    params: Dict[str, Any],
    fv: jnp.ndarray,
    config: GRUConfig,
    thetas: Tuple[Tuple[int, int], ...],
    return_states: bool = False,
):
    """fv (B, T, C) -> per-frame logits (B, T, K), scanned over frames.

    ``return_states`` additionally returns the final per-layer delta
    states (whose counters give the sweep's effective-MAC fraction via
    `effective_mac_fraction`).
    """
    states = delta_init_states(config, fv.shape[0])

    def step(states, x_t):
        states, logits = delta_classifier_step(
            params, states, x_t, config, thetas
        )
        return states, logits

    states, logits = jax.lax.scan(step, states, jnp.moveaxis(fv, 1, 0))
    logits = jnp.moveaxis(logits, 0, 1)
    return (logits, states) if return_states else logits


# --------------------------------------------------------------------------
# integer code domain — the delta sibling of repro.core.gru_int
# --------------------------------------------------------------------------

def int_delta_gru_cell(
    layer: Dict[str, jnp.ndarray],
    st: Dict[str, jnp.ndarray],
    x: jnp.ndarray,
    config: GRUConfig,
    thetas: Tuple[int, int],
    matmul=None,
) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray]:
    """One ΔGRU step on codes: x (B, I) int32 Q6.8 -> (state, h' codes).

    Gate math after the partial sums is verbatim `gru_int.int_gru_cell`;
    the frac-15 partial sums live in the persistent int32 accumulators
    (the DeltaKWS per-neuron partial-sum memory) instead of being
    recomputed densely.

    ``matmul`` overrides how a Δ·W contribution is evaluated (default:
    the saturating-int24 `intgemm` kernel). The fused-tick megakernel
    passes its gather-compacted sparse product (`repro.kernels.
    tick_fused`), which multiplies only the firing columns and applies
    the same final int24 saturation — identical int32 codes, work
    proportional to the fire count.
    """
    del config  # geometry is carried by the code arrays themselves
    tx, th = thetas
    mm = intgemm if matmul is None else matmul

    dx = x - st["x_ref"]
    fire_x = jnp.abs(dx) > tx
    dx = jnp.where(fire_x, dx, 0)
    x_ref = st["x_ref"] + dx
    acc_x = st["acc_x"] + mm(dx, layer["w_i"])

    dh = st["h"] - st["h_ref"]
    fire_h = jnp.abs(dh) > th
    dh = jnp.where(fire_h, dh, 0)
    h_ref = st["h_ref"] + dh
    acc_h = st["acc_h"] + mm(dh, layer["w_h"])

    gi = quant.clip_act_codes(
        quant.round_shift_even(acc_x + layer["b_i"], _ACC_SHIFT)
    )
    gh = quant.clip_act_codes(
        quant.round_shift_even(acc_h + layer["b_h"], _ACC_SHIFT)
    )
    i_r, i_z, i_n = jnp.split(gi, 3, axis=-1)
    h_r, h_z, h_n = jnp.split(gh, 3, axis=-1)
    r = quant.lut_sigmoid_q68(i_r + h_r)
    z = quant.lut_sigmoid_q68(i_z + h_z)
    rn = quant.clip_act_codes(quant.round_shift_even(r * h_n, _ACT_SHIFT))
    n = quant.lut_tanh_q68(i_n + rn)
    h_new = quant.clip_act_codes(
        quant.round_shift_even((_ONE_Q68 - z) * n + z * st["h"], _ACT_SHIFT)
    )

    skipped, total = _count_macs(st, fire_x, fire_h)
    new_st = {
        "h": h_new, "x_ref": x_ref, "h_ref": h_ref,
        "acc_x": acc_x, "acc_h": acc_h,
        "skipped": skipped, "total": total,
    }
    return new_st, h_new


def _int_fc_logits(qparams: QuantizedClassifier, x: jnp.ndarray):
    # the dense FC head, verbatim the integer engine's accumulate path
    # (shared so the bit-identity target can never drift from here)
    return _accum(x, qparams.fc_w, qparams.fc_b)


def int_delta_classifier_step(
    qparams: QuantizedClassifier,
    states: List[Dict[str, jnp.ndarray]],
    fv_t: jnp.ndarray,
    config: GRUConfig,
    thetas: Tuple[Tuple[int, int], ...],
    matmul=None,
) -> Tuple[List[Dict[str, jnp.ndarray]], jnp.ndarray]:
    """Streaming ΔGRU step on codes: one frame (B, C) -> (states, (B, K)).
    ``matmul`` threads through to every `int_delta_gru_cell`."""
    new_states = []
    x = fv_t
    for layer, st, t in zip(qparams.gru, states, thetas):
        st, x = int_delta_gru_cell(layer, st, x, config, t, matmul=matmul)
        new_states.append(st)
    return new_states, _int_fc_logits(qparams, x)


def int_delta_classifier_forward(
    qparams: QuantizedClassifier,
    fv_codes: jnp.ndarray,
    config: GRUConfig,
    thetas: Tuple[Tuple[int, int], ...],
    return_states: bool = False,
):
    """fv codes (B, T, C) -> per-frame logit codes (B, T, K), scanned."""
    states = int_delta_init_states(config, fv_codes.shape[0])

    def step(states, x_t):
        states, logits = int_delta_classifier_step(
            qparams, states, x_t, config, thetas
        )
        return states, logits

    states, logits = jax.lax.scan(
        step, states, jnp.moveaxis(fv_codes, 1, 0)
    )
    logits = jnp.moveaxis(logits, 0, 1)
    return (logits, states) if return_states else logits


# --------------------------------------------------------------------------
# sparsity telemetry
# --------------------------------------------------------------------------

def effective_mac_fraction(
    states: List[Dict[str, jnp.ndarray]], config: GRUConfig
) -> jnp.ndarray:
    """Per-stream effective-MAC fraction in [0, 1] from the counters.

    executed / offered over the WHOLE classifier: the delta-eligible GRU
    counters (column units, converted to MACs per layer — a layer's
    column is 3H multiplies) plus the always-dense FC head (its
    per-frame cost is folded back in from the frame count the totals
    imply). Streams with no traffic yet report 1.0 (dense — nothing has
    been skipped).

    Feeds `repro.core.energy.AcceleratorModel(effective_mac_fraction=…)`
    to turn measured sparsity into DeltaKWS-style µW/latency predictions.
    """
    dims = _layer_dims(config)
    skipped = sum(
        st["skipped"].astype(jnp.float32) * (3 * h)
        for st, (_, h) in zip(states, dims)
    )
    total = sum(
        st["total"].astype(jnp.float32) * (3 * h)
        for st, (_, h) in zip(states, dims)
    )
    per_frame = float(delta_eligible_macs_per_frame(config))
    fc = float(dense_fc_macs_per_frame(config))
    n_frames = total / per_frame
    executed = total - skipped + n_frames * fc
    offered = total + n_frames * fc
    return jnp.where(total > 0, executed / jnp.maximum(offered, 1.0), 1.0)
