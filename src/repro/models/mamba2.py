"""Mamba2 (SSD) blocks — the Zamba2 backbone's workhorse.

Selective state-space recurrence per head h (state N x P):
    S_t = a_t * S_{t-1} + dt_t * B_t (x) x_t        a_t = exp(dt_t * A_h)
    y_t = C_t . S_t + D_h * x_t
with x gated by silu(z) and a gated RMSNorm before out_proj (Mamba2
arXiv:2405.21060).

Training uses the chunked SSD algorithm (chunk Q): intra-chunk work is a
masked (Q, Q) matmul per head, inter-chunk state flows through a
lax.scan — O(L*Q) instead of O(L^2), exact (not an approximation), and
every decay factor appears as exp(difference <= 0), so nothing
overflows. A sequential-scan oracle (`ssd_sequential`) backs the tests.

TP: heads shard over "model" (w_z/w_x column-parallel, out_proj
row-parallel); B/C projections are per-group (G=1) and replicated.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm

Params = Dict[str, Any]


def mamba2_block_init(key, cfg) -> Params:
    ssm = cfg.ssm
    d = cfg.d_model
    d_in = ssm.expand * d
    n_heads = d_in // ssm.head_dim
    ks = jax.random.split(key, 8)
    return {
        "ln": jnp.zeros((d,), jnp.float32),
        "w_z": dense_init(ks[0], (d, d_in)),
        "w_x": dense_init(ks[1], (d, d_in)),
        "w_B": dense_init(ks[2], (d, ssm.d_state)),
        "w_C": dense_init(ks[3], (d, ssm.d_state)),
        "w_dt": dense_init(ks[4], (d, n_heads)),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "A_log": jnp.zeros((n_heads,), jnp.float32),  # A = -exp(A_log)
        "D": jnp.ones((n_heads,), jnp.float32),
        "conv_w": dense_init(ks[5], (ssm.d_conv, d_in), fan_in=ssm.d_conv),
        "conv_b": jnp.zeros((d_in,), jnp.float32),
        "gn": jnp.zeros((d_in,), jnp.float32),  # gated RMSNorm scale
        "out_proj": dense_init(ks[6], (d_in, d), fan_in=d_in),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv along time. x (B, L, C), w (K, C).

    state (B, K-1, C) carries the last K-1 inputs for streaming decode;
    returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, L+K-1, C)
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
        for i in range(k)
    )
    y = y + b.astype(x.dtype)
    new_state = xp[:, -(k - 1) :, :] if k > 1 else None
    return jax.nn.silu(y), new_state


def _ssd_chunked(xh, a_log, bmat, cmat, chunk):
    """Chunked SSD scan.

    xh   : (B, L, H, P)   dt-premultiplied inputs (dt folded into x)
    a_log: (B, L, H)      per-step log decay (= dt * A <= 0)
    bmat : (B, L, N)      input projections (shared across heads, G=1)
    cmat : (B, L, N)      output projections
    returns y (B, L, H, P), final state (B, H, N, P)
    """
    b, l, h, p = xh.shape
    n = bmat.shape[-1]
    q = min(chunk, l)
    pad = (-l) % q
    if pad:
        # zero-pad (x=0 adds nothing, a_log=0 preserves state — exact)
        xh = jnp.concatenate(
            [xh, jnp.zeros((b, pad, h, p), xh.dtype)], axis=1
        )
        a_log = jnp.concatenate(
            [a_log, jnp.zeros((b, pad, h), a_log.dtype)], axis=1
        )
        bmat = jnp.concatenate(
            [bmat, jnp.zeros((b, pad, n), bmat.dtype)], axis=1
        )
        cmat = jnp.concatenate(
            [cmat, jnp.zeros((b, pad, n), cmat.dtype)], axis=1
        )
    nc = (l + pad) // q
    xh = xh.reshape(b, nc, q, h, p)
    a_log = a_log.reshape(b, nc, q, h).astype(jnp.float32)
    bmat = bmat.reshape(b, nc, q, n)
    cmat = cmat.reshape(b, nc, q, n)

    il = jnp.cumsum(a_log, axis=2)  # inclusive log-decay (b, nc, q, h)
    total = il[:, :, -1, :]  # (b, nc, h)

    # intra-chunk: y_t reads S_t AFTER the step-t update, so input j
    # contributes to output t >= j with decay prod_{s=j+1..t} a_s
    # = exp(il_t - il_j); t == j gives decay 1 (the diagonal).
    cb = jnp.einsum("bcin,bcjn->bcij", cmat, bmat)  # (b, nc, q, q)
    ratio = jnp.exp(
        jnp.clip(il[:, :, :, None, :] - il[:, :, None, :, :], -60.0, 0.0)
    )  # (b, nc, t, j, h); <= 1 wherever t >= j
    tri = jnp.tril(jnp.ones((q, q), bool))  # t >= j, diagonal included
    scores = cb[..., None] * jnp.where(
        tri[None, None, :, :, None], ratio, 0.0
    ).astype(cb.dtype)
    y_intra = jnp.einsum(
        "bcijh,bcjhp->bcihp", scores.astype(xh.dtype), xh
    )

    # chunk-local end states: S_c = sum_j exp(total - il_j) B_j (x) x_j
    decay_to_end = jnp.exp(
        jnp.clip(total[:, :, None, :] - il, -60.0, 0.0)
    )  # (b, nc, q, h)
    s_local = jnp.einsum(
        "bcjn,bcjh,bcjhp->bchnp",
        bmat,
        decay_to_end.astype(xh.dtype),
        xh,
    )  # (b, nc, h, n, p)

    # inter-chunk scan over nc
    def step(s_prev, inputs):
        s_loc, tot = inputs  # (b,h,n,p), (b,h)
        s_new = s_prev * jnp.exp(tot)[:, :, None, None].astype(s_prev.dtype) + s_loc
        return s_new, s_prev

    s0 = jnp.zeros((b, h, n, p), xh.dtype)
    s_final, s_prevs = jax.lax.scan(
        step,
        s0,
        (jnp.moveaxis(s_local, 1, 0), jnp.moveaxis(total, 1, 0)),
    )
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)  # (b, nc, h, n, p) state at chunk start

    # inter-chunk contribution: the carried state decays through step t
    # inclusive: y_t += C_t . (exp(il_t) * S_chunk_start)
    y_inter = jnp.einsum(
        "bcin,bcih,bchnp->bcihp",
        cmat,
        jnp.exp(il).astype(xh.dtype),
        s_prevs,
    )
    y = (y_intra + y_inter).reshape(b, l + pad, h, p)[:, :l]
    return y, s_final


def ssd_sequential(xh, a_log, bmat, cmat):
    """Oracle: direct per-step recurrence (tests only)."""
    b, l, h, p = xh.shape
    n = bmat.shape[-1]

    def step(s, inputs):
        x_t, a_t, b_t, c_t = inputs
        s = s * jnp.exp(a_t)[:, :, None, None] + jnp.einsum(
            "bn,bhp->bhnp", b_t, x_t
        )
        y = jnp.einsum("bn,bhnp->bhp", c_t, s)
        return s, y

    s0 = jnp.zeros((b, h, n, p), xh.dtype)
    _, ys = jax.lax.scan(
        step,
        s0,
        (
            jnp.moveaxis(xh, 1, 0),
            jnp.moveaxis(a_log.astype(xh.dtype), 1, 0),
            jnp.moveaxis(bmat, 1, 0),
            jnp.moveaxis(cmat, 1, 0),
        ),
    )
    return jnp.moveaxis(ys, 0, 1)


def _block_pre(p, x, cfg, conv_state=None):
    """Shared pre-SSD computation: projections + conv + dt."""
    ssm = cfg.ssm
    dt_ = x.dtype
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    z = h @ p["w_z"].astype(dt_)
    xc = h @ p["w_x"].astype(dt_)
    xc, new_conv = _causal_conv(xc, p["conv_w"], p["conv_b"], conv_state)
    bmat = h @ p["w_B"].astype(dt_)
    cmat = h @ p["w_C"].astype(dt_)
    dt = jax.nn.softplus(
        (h @ p["w_dt"].astype(dt_)).astype(jnp.float32)
        + p["dt_bias"][None, None, :]
    )  # (B, L, H)
    a_log = -jnp.exp(p["A_log"])[None, None, :] * dt  # <= 0
    n_heads = xc.shape[-1] // ssm.head_dim
    xh = xc.reshape(*xc.shape[:-1], n_heads, ssm.head_dim)
    xh = xh * dt[..., None].astype(dt_)  # fold dt into input
    return z, xh, a_log, bmat, cmat, new_conv


def mamba2_block_apply(p, x, cfg):
    """Training/prefill path. x (B, L, d) -> (y, (conv_state, ssd_state))."""
    ssm = cfg.ssm
    z, xh, a_log, bmat, cmat, conv_state = _block_pre(p, x, cfg)
    y, s_final = _ssd_chunked(xh, a_log, bmat, cmat, ssm.chunk)
    n_heads = xh.shape[-2]
    d_x = xh.reshape(*x.shape[:2], -1)
    y = y.reshape(*x.shape[:2], -1) + (
        jnp.repeat(p["D"], ssm.head_dim)[None, None, :].astype(x.dtype) * d_x
    )
    y = rms_norm(y * jax.nn.silu(z), p["gn"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    return x + out, (conv_state, s_final)


def mamba2_block_decode(p, x, cfg, conv_state, ssd_state):
    """One-token decode. x (B, 1, d); states carried explicitly."""
    ssm = cfg.ssm
    z, xh, a_log, bmat, cmat, new_conv = _block_pre(p, x, cfg, conv_state)
    # single-step recurrence
    a = jnp.exp(a_log[:, 0, :]).astype(x.dtype)  # (B, H)
    s_new = ssd_state * a[:, :, None, None] + jnp.einsum(
        "bn,bhp->bhnp", bmat[:, 0], xh[:, 0]
    )
    y = jnp.einsum("bn,bhnp->bhp", cmat[:, 0], s_new)[:, None]  # (B,1,H,P)
    d_x = xh.reshape(*x.shape[:2], -1)
    y = y.reshape(*x.shape[:2], -1) + (
        jnp.repeat(p["D"], ssm.head_dim)[None, None, :].astype(x.dtype) * d_x
    )
    y = rms_norm(y * jax.nn.silu(z), p["gn"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    return x + out, (new_conv, s_new)


def init_conv_state(cfg, batch: int):
    ssm = cfg.ssm
    d_in = ssm.expand * cfg.d_model
    return jnp.zeros((batch, ssm.d_conv - 1, d_in), cfg.activation_dtype)


def init_ssd_state(cfg, batch: int):
    ssm = cfg.ssm
    d_in = ssm.expand * cfg.d_model
    n_heads = d_in // ssm.head_dim
    return jnp.zeros(
        (batch, n_heads, ssm.d_state, ssm.head_dim), cfg.activation_dtype
    )
