"""Decoder-only transformer backbone covering the dense + MoE assigned
architectures (musicgen, qwen3, gemma2, codeqwen, phi4, llava, kimi-k2,
granite-moe).

Layers are scanned: `cfg.layer_pattern` defines the per-scan-step block
sequence (("global",) for uniform stacks, ("local","global") for Gemma-2,
("moe",) for MoE stacks); parameters carry a leading (n_steps,) axis.
MoE stacks may put `first_k_dense` unscanned dense layers in front
(Kimi-K2 style).

API (shared by every backbone module via models.registry):
    init_params(key, cfg, mesh_ctx)        -> params pytree
    forward(params, batch, cfg, mesh_ctx)  -> (logits, aux_loss)
    loss_fn(params, batch, cfg, mesh_ctx)  -> scalar loss
    init_cache(cfg, batch, max_len, ...)   -> cache pytree
    prefill(params, batch, cfg, mesh_ctx)  -> (logits, cache)
    decode_step(params, cache, cache_len, batch, cfg, mesh_ctx)
                                           -> (logits, cache)
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import attn_apply, attn_init, decode_attn_apply
from repro.models.layers import (
    cross_entropy_loss,
    dense_init,
    mlp_apply,
    mlp_init,
    rms_norm,
    softcap,
)
from repro.models.moe import MeshContext, moe_apply, moe_init

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _block_init(key, cfg, kind: str, mesh_ctx) -> Params:
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    p: Params = {
        "ln1": jnp.zeros((d,), jnp.float32),
        "attn": attn_init(
            ks[0], d, cfg.n_heads, cfg.n_kv_heads,
            cfg.resolved_head_dim, cfg.qk_norm,
        ),
        "ln2": jnp.zeros((d,), jnp.float32),
    }
    if cfg.post_norms:
        p["ln1_post"] = jnp.zeros((d,), jnp.float32)
        p["ln2_post"] = jnp.zeros((d,), jnp.float32)
    if kind == "moe":
        p["moe"] = moe_init(ks[1], cfg, mesh_ctx)
    else:
        p["mlp"] = mlp_init(ks[1], d, cfg.d_ff, cfg.mlp_act)
    return p


def _pattern_slots(cfg):
    return [(f"slot{i}_{k}", k) for i, k in enumerate(cfg.layer_pattern)]


def _n_steps(cfg) -> int:
    first_dense = cfg.moe.first_k_dense if cfg.moe else 0
    n = cfg.n_layers - first_dense
    if n % len(cfg.layer_pattern):
        raise ValueError(
            f"{cfg.name}: {n} layers not divisible by pattern "
            f"{cfg.layer_pattern}"
        )
    return n // len(cfg.layer_pattern)


def init_params(key, cfg, mesh_ctx: Optional[MeshContext] = None) -> Params:
    keys = jax.random.split(key, 8)
    d, v = cfg.d_model, cfg.vocab_padded
    params: Params = {
        "embed": dense_init(keys[0], (v, d), fan_in=d),
        "final_norm": jnp.zeros((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(keys[1], (d, v))
    first_dense = cfg.moe.first_k_dense if cfg.moe else 0
    if first_dense:
        dk = jax.random.split(keys[2], first_dense)
        params["dense_prefix"] = [
            _block_init(dk[i], cfg, "global", mesh_ctx)
            for i in range(first_dense)
        ]
    n_steps = _n_steps(cfg)
    layers: Params = {}
    for i, (slot_name, kind) in enumerate(_pattern_slots(cfg)):
        slot_keys = jax.random.split(jax.random.fold_in(keys[3], i), n_steps)
        layers[slot_name] = jax.vmap(
            lambda k: _block_init(k, cfg, kind, mesh_ctx)
        )(slot_keys)
    params["layers"] = layers
    # Model params live in the activation dtype (bf16); optimizer masters
    # are separate (training/optimizer.py), per DESIGN.md §6.
    return jax.tree.map(lambda l: l.astype(cfg.activation_dtype), params)


# --------------------------------------------------------------------------
# forward / loss
# --------------------------------------------------------------------------

def _block_apply(p, x, cfg, kind, mesh_ctx):
    window = cfg.sliding_window if kind == "local" else None
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    attn_out, kv = attn_apply(
        p["attn"], h, cfg, window=window, mesh_ctx=mesh_ctx
    )
    if cfg.post_norms:
        attn_out = rms_norm(attn_out, p["ln1_post"], cfg.norm_eps)
    x = x + attn_out
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind == "moe":
        ffn_out, aux = moe_apply(p["moe"], h, cfg, mesh_ctx)
    else:
        ffn_out = mlp_apply(p["mlp"], h, cfg.mlp_act)
        aux = jnp.zeros((), jnp.float32)
    if cfg.post_norms:
        ffn_out = rms_norm(ffn_out, p["ln2_post"], cfg.norm_eps)
    x = x + ffn_out
    return x, aux, kv


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        )
    return jax.checkpoint(fn)  # "full": save nothing


def _embed_in(params, batch, cfg, mesh_ctx=None):
    if cfg.frontend == "embedding":
        x = batch["embeddings"].astype(cfg.activation_dtype)
    else:
        x = params["embed"].astype(cfg.activation_dtype)[batch["tokens"]]
    if cfg.scale_embeddings:
        x = x * jnp.asarray(
            jnp.sqrt(cfg.d_model * 1.0), cfg.activation_dtype
        )
    if mesh_ctx is not None:
        x = mesh_ctx.constrain_hidden(x)
    return x


def _head_out(params, x, cfg):
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = (
        params["embed"].T if cfg.tie_embeddings else params["head"]
    ).astype(h.dtype)
    return h @ w


def forward(
    params, batch, cfg, mesh_ctx: Optional[MeshContext] = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x = _embed_in(params, batch, cfg, mesh_ctx)
    aux_total = jnp.zeros((), jnp.float32)
    for p in params.get("dense_prefix", []):
        x, aux, _ = _block_apply(p, x, cfg, "global", mesh_ctx)
        aux_total += aux
    slots = _pattern_slots(cfg)

    def body(carry, step_params):
        x, aux_acc = carry
        if mesh_ctx is not None:
            x = mesh_ctx.constrain_hidden(x)
        for slot_name, kind in slots:
            x, aux, _ = _block_apply(
                step_params[slot_name], x, cfg, kind, mesh_ctx
            )
            aux_acc = aux_acc + aux
        return (x, aux_acc), None

    (x, aux_total), _ = jax.lax.scan(
        _remat(body, cfg), (x, aux_total), params["layers"]
    )
    return _head_out(params, x, cfg), aux_total


def loss_fn(params, batch, cfg, mesh_ctx=None, aux_weight: float = 0.01):
    logits, aux = forward(params, batch, cfg, mesh_ctx)
    ce = cross_entropy_loss(logits, batch["labels"], cfg.final_softcap)
    return ce + aux_weight * aux


# --------------------------------------------------------------------------
# serving: cache init / prefill / decode
# --------------------------------------------------------------------------

def _slot_cache_len(cfg, kind: str, max_len: int) -> int:
    if kind == "local" and cfg.sliding_window:
        return min(cfg.sliding_window, max_len)
    return max_len


def init_cache(cfg, batch: int, max_len: int,
               mesh_ctx: Optional[MeshContext] = None) -> Params:
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dt = cfg.activation_dtype
    n_steps = _n_steps(cfg)
    cache: Params = {"layers": {}}
    for slot_name, kind in _pattern_slots(cfg):
        s = _slot_cache_len(cfg, kind, max_len)
        cache["layers"][slot_name] = {
            "k": jnp.zeros((n_steps, batch, s, kv, hd), dt),
            "v": jnp.zeros((n_steps, batch, s, kv, hd), dt),
        }
    first_dense = cfg.moe.first_k_dense if cfg.moe else 0
    if first_dense:
        cache["dense_prefix"] = [
            {
                "k": jnp.zeros((batch, max_len, kv, hd), dt),
                "v": jnp.zeros((batch, max_len, kv, hd), dt),
            }
            for _ in range(first_dense)
        ]
    return cache


def _compress_kv(k, v, cfg, kind, max_len):
    """Full-sequence (k, v) -> slot cache layout (ring for local slots)."""
    s_slot = _slot_cache_len(cfg, kind, max_len)
    s = k.shape[1]
    if s_slot >= s:
        pad = s_slot - s
        if pad:
            zk = jnp.zeros((k.shape[0], pad) + k.shape[2:], k.dtype)
            k = jnp.concatenate([k, zk], axis=1)
            v = jnp.concatenate([v, zk], axis=1)
        return k, v
    k = jnp.roll(k[:, s - s_slot :], s % s_slot, axis=1)
    v = jnp.roll(v[:, s - s_slot :], s % s_slot, axis=1)
    return k, v


def prefill(params, batch, cfg, mesh_ctx=None, max_len: Optional[int] = None):
    """Run the prompt, return (last-token logits, cache)."""
    x = _embed_in(params, batch, cfg, mesh_ctx)
    s = x.shape[1]
    max_len = max_len or s
    cache: Params = {"layers": {}}
    dense_kvs = []
    for p in params.get("dense_prefix", []):
        x, _, kv = _block_apply(p, x, cfg, "global", mesh_ctx)
        k, v = _compress_kv(kv[0], kv[1], cfg, "global", max_len)
        dense_kvs.append({"k": k, "v": v})
    if dense_kvs:
        cache["dense_prefix"] = dense_kvs
    slots = _pattern_slots(cfg)

    def body(x, step_params):
        if mesh_ctx is not None:
            x = mesh_ctx.constrain_hidden(x)
        kvs = {}
        for slot_name, kind in slots:
            x, _, kv = _block_apply(
                step_params[slot_name], x, cfg, kind, mesh_ctx
            )
            k, v = _compress_kv(kv[0], kv[1], cfg, kind, max_len)
            kvs[slot_name] = {"k": k, "v": v}
        return x, kvs

    x, layer_kvs = jax.lax.scan(body, x, params["layers"])
    cache["layers"] = layer_kvs
    logits = _head_out(params, x[:, -1:, :], cfg)
    return softcap(logits[:, 0, :], cfg.final_softcap), cache


def decode_step(params, cache, cache_len, batch, cfg, mesh_ctx=None):
    """One token for the whole batch. batch: {"tokens": (B, 1)} or
    {"embeddings": (B, 1, d)}. Returns (logits (B, V), new cache)."""
    x = _embed_in(params, batch, cfg, mesh_ctx)

    def apply_one(p, c, x, kind):
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        attn_out, k_c, v_c = decode_attn_apply(
            p["attn"], h, cfg, c["k"], c["v"], cache_len,
            ring=(kind == "local" and cfg.sliding_window is not None),
        )
        if cfg.post_norms:
            attn_out = rms_norm(attn_out, p["ln1_post"], cfg.norm_eps)
        x = x + attn_out
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if kind == "moe":
            ffn_out, _ = moe_apply(p["moe"], h, cfg, mesh_ctx)
        else:
            ffn_out = mlp_apply(p["mlp"], h, cfg.mlp_act)
        if cfg.post_norms:
            ffn_out = rms_norm(ffn_out, p["ln2_post"], cfg.norm_eps)
        return x + ffn_out, {"k": k_c, "v": v_c}

    new_cache: Params = {"layers": {}}
    if "dense_prefix" in cache:
        new_dense = []
        for p, c in zip(params["dense_prefix"], cache["dense_prefix"]):
            x, c_new = apply_one(p, c, x, "global")
            new_dense.append(c_new)
        new_cache["dense_prefix"] = new_dense
    slots = _pattern_slots(cfg)

    def body(x, inputs):
        step_params, step_cache = inputs
        if mesh_ctx is not None:
            x = mesh_ctx.constrain_hidden(x)
        kvs = {}
        for slot_name, kind in slots:
            x, c_new = apply_one(
                step_params[slot_name], step_cache[slot_name], x, kind
            )
            kvs[slot_name] = c_new
        return x, kvs

    x, layer_kvs = jax.lax.scan(
        body, x, (params["layers"], cache["layers"])
    )
    new_cache["layers"] = layer_kvs
    logits = _head_out(params, x, cfg)
    return softcap(logits[:, 0, :], cfg.final_softcap), new_cache
