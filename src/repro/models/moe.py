"""Mixture-of-Experts FFN with expert parallelism.

Dispatch strategy (DESIGN.md §5): experts are sharded over the "model"
mesh axis. Inside a shard_map, every model-shard sees the *same* local
tokens (activations are sharded over "data" only), routes them, gathers
the ones destined for its local expert slice into a fixed-capacity
buffer, runs the expert FFNs as one batched einsum, scatters results
back, and a single psum over "model" combines expert contributions.
Communication per MoE layer = one psum of the (tokens, d_model) output —
no all_to_all, no (T, E, C) GShard dispatch tensor.

Fixed capacity C = ceil(T_local * top_k / E * capacity_factor); overflow
tokens are dropped (standard dropping MoE; the router aux loss keeps load
balanced). Experts are zero-padded to a multiple of the model-axis size
when E doesn't divide (granite: 40 -> 48); padded experts get -inf router
logits so they never receive tokens.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.5 exposes shard_map at the top level
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map

from repro.models.layers import dense_init, mlp_apply, mlp_init

__all__ = ["MeshContext", "moe_init", "moe_apply", "padded_num_experts"]


@dataclasses.dataclass(frozen=True)
class MeshContext:
    """Mesh + axis-name conventions threaded through model apply fns."""

    mesh: object  # jax.sharding.Mesh
    dp_axes: Tuple[str, ...] = ("data",)  # batch axes ("pod","data") multi-pod
    model_axis: Optional[str] = "model"
    # FSDP axes the expert weights are sharded over (empty = no FSDP).
    # Expert all-gathers happen *inside* the shard_map body, one layer at
    # a time — declaring them gathered in in_specs makes GSPMD hoist the
    # all-gather of the whole stacked scan bank out of the loop (measured:
    # 127 GB/device peak on kimi-k2; see EXPERIMENTS.md §Dry-run).
    fsdp_axes: Tuple[str, ...] = ()

    @property
    def model_size(self) -> int:
        if self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]

    def constrain_heads(self, t):
        """Pin (B, S, H, D) attention activations to batch-over-dp +
        heads-over-model. Needed when the head axis only becomes
        divisible after zero-padding inside _sdpa — GSPMD won't re-shard
        a dim it already decided to replicate (measured: musicgen's 24
        unsharded heads cost 16x score traffic; EXPERIMENTS.md §Perf)."""
        if self.mesh is None or self.model_axis is None:
            return t
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        dp_total = 1
        for ax in self.dp_axes:
            dp_total *= self.mesh.shape[ax]
        dims = [None] * t.ndim
        if t.shape[0] % dp_total == 0:
            dims[0] = tuple(self.dp_axes)
        if t.shape[2] % self.model_size == 0:
            dims[2] = self.model_axis
        return jax.lax.with_sharding_constraint(
            t, NamedSharding(self.mesh, P(*dims))
        )

    def constrain_hidden(self, x):
        """Pin activation sharding to (batch over dp, rest replicated).

        Without this, GSPMD happily propagates weight shardings into the
        residual stream (measured: the embedding table's d-over-data
        spec turned the whole attention stack data-replicated — 16x
        compute; EXPERIMENTS.md §Dry-run). Applied at the embedding
        output and at each scan-step entry."""
        if self.mesh is None:
            return x
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        dp_total = 1
        for ax in self.dp_axes:
            dp_total *= self.mesh.shape[ax]
        if x.shape[0] % dp_total != 0:
            return x
        dims = [tuple(self.dp_axes)] + [None] * (x.ndim - 1)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*dims))
        )


def padded_num_experts(num_experts: int, mesh_ctx: Optional[MeshContext]):
    m = mesh_ctx.model_size if mesh_ctx is not None else 1
    return ((num_experts + m - 1) // m) * m


def moe_init(key, cfg, mesh_ctx: Optional[MeshContext] = None):
    """One MoE FFN layer: router + padded expert bank (+ shared experts)."""
    m = cfg.moe
    e_pad = padded_num_experts(m.num_experts, mesh_ctx)
    ks = jax.random.split(key, 5)
    d, f = cfg.d_model, m.d_expert
    p = {
        "router": dense_init(ks[0], (d, e_pad)),
        "w_up": dense_init(ks[1], (e_pad, d, f)),
        "w_gate": dense_init(ks[2], (e_pad, d, f)),
        "w_down": dense_init(ks[3], (e_pad, f, d), fan_in=f),
    }
    if m.num_shared_experts:
        p["shared"] = mlp_init(
            ks[4], d, f * m.num_shared_experts, cfg.mlp_act
        )
    return p


def _expert_ffn(p_loc, xb: jnp.ndarray, act: str) -> jnp.ndarray:
    """xb (E_loc, C, d) -> (E_loc, C, d), batched over local experts.
    Weights may be int8-quantized {"q","s"} dicts (serving)."""
    from repro.models.moe_quant import dequant_weight

    dt = xb.dtype
    up = jnp.einsum("ecd,edf->ecf", xb, dequant_weight(p_loc["w_up"], dt))
    gate = jnp.einsum(
        "ecd,edf->ecf", xb, dequant_weight(p_loc["w_gate"], dt)
    )
    h = jax.nn.silu(gate) * up
    return jnp.einsum(
        "ecf,efd->ecd", h, dequant_weight(p_loc["w_down"], dt)
    )


def _route_and_compute(
    x: jnp.ndarray,  # (T, d) local tokens
    p_loc,  # expert slice params: w_up (E_loc, d, f), router (d, E_pad) full
    e_start: jnp.ndarray,  # scalar: first expert id of this shard
    *,
    num_experts: int,  # real (unpadded) expert count
    e_pad: int,
    top_k: int,
    capacity: int,
    act: str,
    ffn_fn=None,  # override expert FFN (weights-stationary path)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (T, d): this shard's expert contributions, aux loss)."""
    t, d = x.shape
    _wu = (
        p_loc["w_up"]["q"] if isinstance(p_loc["w_up"], dict)
        else p_loc["w_up"]
    )
    e_loc = _wu.shape[0]
    logits = (x.astype(jnp.float32) @ p_loc["router"].astype(jnp.float32))
    # mask padded experts out of routing
    pad_mask = jnp.arange(e_pad) < num_experts
    logits = jnp.where(pad_mask[None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E_pad)
    gates, idx = jax.lax.top_k(probs, top_k)  # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch-style): E * sum(load * importance)
    importance = probs.mean(axis=0)  # (E_pad,)
    load = jnp.zeros((e_pad,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (
        t * top_k
    )
    aux = num_experts * jnp.sum(importance * load)

    # ---- dispatch to this shard's local experts ----
    # All bookkeeping stays in (T*k,) index space; activations only ever
    # materialize at (T, d) and (E_loc*C, d) — never (T*k, d) — so the 1T
    # MoE's dispatch fits HBM (DESIGN.md §6).
    flat_e = idx.reshape(-1)  # (T*k,)
    flat_g = gates.reshape(-1).astype(x.dtype)
    flat_tok = jnp.repeat(jnp.arange(t), top_k)
    local = (flat_e >= e_start) & (flat_e < e_start + e_loc)
    e_rel = jnp.where(local, flat_e - e_start, e_loc)  # e_loc = trash row
    onehot = jax.nn.one_hot(e_rel, e_loc, dtype=jnp.int32)  # (T*k, E_loc)
    pos = jnp.cumsum(onehot, axis=0) - onehot  # exclusive rank per expert
    pos_in_e = jnp.sum(pos * onehot, axis=-1)  # (T*k,)
    keep = local & (pos_in_e < capacity)
    n_slots = e_loc * capacity
    slot = jnp.where(keep, e_rel * capacity + pos_in_e, n_slots)
    # invert slot -> token (each real slot receives at most one token)
    tok_for_slot = jnp.zeros((n_slots + 1,), jnp.int32).at[slot].max(
        flat_tok.astype(jnp.int32)
    )[:-1]
    gate_for_slot = jnp.zeros((n_slots + 1,), x.dtype).at[slot].max(
        jnp.where(keep, flat_g, 0)
    )[:-1]
    valid_slot = (
        jnp.zeros((n_slots + 1,), jnp.int32).at[slot].max(keep.astype(jnp.int32))
    )[:-1]
    buf = x[tok_for_slot] * valid_slot[:, None].astype(x.dtype)
    if ffn_fn is None:
        h = _expert_ffn(p_loc, buf.reshape(e_loc, capacity, d), act)
    else:
        h = ffn_fn(buf.reshape(e_loc, capacity, d))
    h_flat = h.reshape(n_slots, d)
    contrib = h_flat * (gate_for_slot * valid_slot.astype(x.dtype))[:, None]
    y = jnp.zeros((t, d), x.dtype).at[tok_for_slot].add(contrib)
    return y, aux


def moe_apply(
    p,
    x: jnp.ndarray,  # (B, S, d)
    cfg,
    mesh_ctx: Optional[MeshContext] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """MoE FFN layer. Returns (y, aux_loss)."""
    m = cfg.moe
    b, s, d = x.shape
    _wu = p["w_up"]["q"] if isinstance(p["w_up"], dict) else p["w_up"]
    e_pad = _wu.shape[0]

    if mesh_ctx is None or mesh_ctx.model_axis is None:
        t = b * s
        capacity = max(int(t * m.top_k / m.num_experts * m.capacity_factor), 4)
        y, aux = _route_and_compute(
            x.reshape(t, d),
            p,
            jnp.int32(0),
            num_experts=m.num_experts,
            e_pad=e_pad,
            top_k=m.top_k,
            capacity=capacity,
            act=cfg.mlp_act,
        )
        y = y.reshape(b, s, d)
    else:
        mc = mesh_ctx
        n_model = mc.model_size
        e_loc = e_pad // n_model
        dp_total = 1
        for ax in mc.dp_axes:
            dp_total *= mc.mesh.shape[ax]
        t_loc = max(b // dp_total, 1) * s
        capacity = max(
            int(t_loc * m.top_k / m.num_experts * m.capacity_factor), 4
        )

        fsdp = tuple(mc.fsdp_axes)
        # weights-stationary EP for small token counts (decode): moving
        # 2 TB of gathered expert weights to 1-token batches is what made
        # kimi decode collective-bound (4.9 s/step wire time, §Perf);
        # instead the TOKENS move (all-gather, ~MBs) and the expert
        # weights never leave their shards.
        stationary = bool(fsdp) and (
            t_loc * m.top_k <= getattr(m, "stationary_threshold", 4096)
        )

        def shard_fn(x_loc, router, w_up, w_gate, w_down):
            e_start = jax.lax.axis_index(mc.model_axis) * e_loc
            bb, ss, dd = x_loc.shape

            if not stationary:
                def gather_w(w, axis):
                    # int8 dicts: gather q along the sharded axis; the
                    # per-row scale only travels when its axis is sharded
                    if isinstance(w, dict):
                        out = {"q": jax.lax.all_gather(
                            w["q"], fsdp, axis=axis, tiled=True)}
                        out["s"] = (
                            jax.lax.all_gather(
                                w["s"], fsdp, axis=axis, tiled=True)
                            if axis != w["q"].ndim - 1 else w["s"]
                        )
                        return out
                    return jax.lax.all_gather(w, fsdp, axis=axis, tiled=True)

                if fsdp:
                    # gather this layer's expert shards over the FSDP
                    # axes (bwd becomes a reduce-scatter of expert grads)
                    w_up = gather_w(w_up, 1)
                    w_gate = gather_w(w_gate, 1)
                    w_down = gather_w(w_down, 2)
                p_loc = {
                    "router": router, "w_up": w_up,
                    "w_gate": w_gate, "w_down": w_down,
                }
                y, aux = _route_and_compute(
                    x_loc.reshape(bb * ss, dd),
                    p_loc,
                    e_start,
                    num_experts=m.num_experts,
                    e_pad=e_pad,
                    top_k=m.top_k,
                    capacity=capacity,
                    act=cfg.mlp_act,
                )
                y = jax.lax.psum(y, mc.model_axis)
                # router logits are identical across the model axis, so
                # aux is too; average over data (different tokens/shard)
                aux = jax.lax.pmean(aux, mc.dp_axes)
                return y.reshape(bb, ss, dd), aux

            # ---- stationary path ----
            dp = tuple(mc.dp_axes)
            dp_n = 1
            for ax in dp:
                dp_n *= mc.mesh.shape[ax]
            x_all = jax.lax.all_gather(
                x_loc.reshape(bb * ss, dd), dp, axis=0, tiled=True
            )  # (T_all, d)
            t_all = x_all.shape[0]
            cap_all = max(
                int(t_all * m.top_k / m.num_experts * m.capacity_factor), 4
            )
            d_shard = (
                w_up["q"].shape[1] if isinstance(w_up, dict)
                else w_up.shape[1]
            )
            fsdp_idx = jnp.int32(0)
            for ax in fsdp:
                fsdp_idx = fsdp_idx * mc.mesh.shape[ax] + jax.lax.axis_index(ax)

            def ffn_stationary(buf):  # (E_loc, C, d) full-d dispatch buffer
                from repro.models.moe_quant import dequant_weight

                buf_sl = jax.lax.dynamic_slice_in_dim(
                    buf, fsdp_idx * d_shard, d_shard, axis=2
                )
                up = jnp.einsum(
                    "ecd,edf->ecf", buf_sl, dequant_weight(w_up, buf.dtype)
                )
                gate = jnp.einsum(
                    "ecd,edf->ecf", buf_sl,
                    dequant_weight(w_gate, buf.dtype),
                )
                up = jax.lax.psum(up, fsdp)
                gate = jax.lax.psum(gate, fsdp)
                h = jax.nn.silu(gate) * up
                y_sl = jnp.einsum(
                    "ecf,efd->ecd", h, dequant_weight(w_down, buf.dtype)
                )  # (E_loc, C, d/F)
                return jax.lax.all_gather(y_sl, fsdp, axis=2, tiled=True)

            y_all, aux = _route_and_compute(
                x_all,
                {"router": router, "w_up": w_up, "w_gate": w_gate,
                 "w_down": w_down},
                e_start,
                num_experts=m.num_experts,
                e_pad=e_pad,
                top_k=m.top_k,
                capacity=cap_all,
                act=cfg.mlp_act,
                ffn_fn=ffn_stationary,
            )
            y_all = jax.lax.psum(y_all, mc.model_axis)
            # aux is numerically identical across data shards (computed
            # from the gathered token set); pmean proves replication to
            # shard_map's checker
            aux = jax.lax.pmean(aux, dp)
            dp_idx = jnp.int32(0)
            for ax in dp:
                dp_idx = dp_idx * mc.mesh.shape[ax] + jax.lax.axis_index(ax)
            y = jax.lax.dynamic_slice_in_dim(
                y_all, dp_idx * bb * ss, bb * ss, axis=0
            )
            return y.reshape(bb, ss, dd), aux

        bspec = tuple(mc.dp_axes)
        fspec = (fsdp if len(fsdp) > 1 else fsdp[0]) if fsdp else None

        def wspec(w, base):
            """in_spec for a weight that may be an int8 {"q","s"} dict:
            q inherits the base spec; the per-row scale (last dim 1)
            drops the last entry."""
            if isinstance(w, dict):
                return {"q": base, "s": P(*(list(base)[:-1] + [None]))}
            return base

        y, aux = shard_map(
            shard_fn,
            mesh=mc.mesh,
            in_specs=(
                P(bspec, None, None),
                P(None, None),  # router replicated
                wspec(p["w_up"], P(mc.model_axis, fspec, None)),
                wspec(p["w_gate"], P(mc.model_axis, fspec, None)),
                wspec(p["w_down"], P(mc.model_axis, None, fspec)),
            ),
            out_specs=(P(bspec, None, None), P()),
        )(x, p["router"], p["w_up"], p["w_gate"], p["w_down"])

    if "shared" in p:
        y = y + mlp_apply(p["shared"], x, cfg.mlp_act)
    return y, aux
