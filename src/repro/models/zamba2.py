"""Zamba2 hybrid backbone (arXiv:2411.15242): a deep Mamba2 stack with a
small number of *shared* transformer blocks applied periodically.

Layout here (assumptions recorded in DESIGN.md §Arch-applicability):
  * cfg.n_layers Mamba2 layers (81 for zamba2-7b);
  * before mamba layer i where i % shared_attn_every == 0, one of
    n_shared_blocks (=2) shared attention+MLP blocks runs, alternating;
  * the shared block consumes concat(hidden, initial embedding) (2d) —
    Zamba's re-injection of the prompt embedding — projects attention
    output back to d, then a standard d->d_ff MLP.
Each *application* of a shared block has its own KV cache (distinct
positions), even though parameters are shared.

Scan structure: mamba layers are stacked (n_layers, ...) and consumed in
per-group lax.scans (shared_attn_every layers per group) between shared-
block applications, so HLO stays compact at 81 layers.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import attn_init, decode_attn_apply
from repro.models.layers import (
    cross_entropy_loss,
    dense_init,
    mlp_apply,
    mlp_init,
    rms_norm,
)
from repro.models import mamba2 as M2
from repro.models.attention import attn_apply

Params = Dict[str, Any]


def _groups(cfg) -> List[Tuple[int, int]]:
    """[(start, length)] mamba-layer groups between shared applications."""
    k = cfg.shared_attn_every
    out = []
    i = 0
    while i < cfg.n_layers:
        out.append((i, min(k, cfg.n_layers - i)))
        i += k
    return out


def n_shared_applications(cfg) -> int:
    return len(_groups(cfg))


def _shared_block_init(key, cfg) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    # attention consumes the 2d concat input via a fused input projection
    return {
        "ln1": jnp.zeros((2 * d,), jnp.float32),
        "in_proj": dense_init(ks[0], (2 * d, d)),
        "attn": attn_init(
            ks[1], d, cfg.n_heads, cfg.n_kv_heads,
            cfg.resolved_head_dim, cfg.qk_norm,
        ),
        "ln2": jnp.zeros((d,), jnp.float32),
        "mlp": mlp_init(ks[2], d, cfg.d_ff, cfg.mlp_act),
    }


def init_params(key, cfg, mesh_ctx=None) -> Params:
    keys = jax.random.split(key, 5)
    d, v = cfg.d_model, cfg.vocab_padded
    mamba_keys = jax.random.split(keys[1], cfg.n_layers)
    shared_keys = jax.random.split(keys[2], cfg.n_shared_blocks)
    params = {
        "embed": dense_init(keys[0], (v, d), fan_in=d),
        "head": dense_init(keys[3], (d, v)),
        "final_norm": jnp.zeros((d,), jnp.float32),
        "mamba": jax.vmap(lambda k: M2.mamba2_block_init(k, cfg))(
            mamba_keys
        ),
        "shared": [
            _shared_block_init(k, cfg) for k in shared_keys
        ],
    }
    return jax.tree.map(lambda l: l.astype(cfg.activation_dtype), params)


def _shared_apply(p, x, emb, cfg, cache=None, cache_len=None):
    """One shared-block application. cache None -> training (returns kv);
    else decode step against the provided cache."""
    xin = jnp.concatenate([x, emb], axis=-1)
    h = rms_norm(xin, p["ln1"], cfg.norm_eps) @ p["in_proj"].astype(x.dtype)
    if cache is None:
        attn_out, kv = attn_apply(p["attn"], h, cfg)
        x = x + attn_out
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp_apply(p["mlp"], h2, cfg.mlp_act)
        return x, kv
    attn_out, k_c, v_c = decode_attn_apply(
        p["attn"], h, cfg, cache["k"], cache["v"], cache_len
    )
    x = x + attn_out
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + mlp_apply(p["mlp"], h2, cfg.mlp_act)
    return x, {"k": k_c, "v": v_c}


def _slice_group(tree, start: int, length: int):
    return jax.tree.map(lambda a: a[start : start + length], tree)


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        )
    return jax.checkpoint(fn)


def forward(params, batch, cfg, mesh_ctx=None):
    x = params["embed"].astype(cfg.activation_dtype)[batch["tokens"]]
    if mesh_ctx is not None:
        x = mesh_ctx.constrain_hidden(x)
    emb = x

    def mamba_body(x, p):
        if mesh_ctx is not None:
            x = mesh_ctx.constrain_hidden(x)
        x, _ = M2.mamba2_block_apply(p, x, cfg)
        return x, None

    body = _remat(mamba_body, cfg)
    for gi, (start, length) in enumerate(_groups(cfg)):
        p_shared = params["shared"][gi % cfg.n_shared_blocks]
        x, _ = _shared_apply(p_shared, x, emb, cfg)
        x, _ = jax.lax.scan(
            body, x, _slice_group(params["mamba"], start, length)
        )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["head"].astype(x.dtype)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params, batch, cfg, mesh_ctx=None):
    logits, _ = forward(params, batch, cfg, mesh_ctx)
    return cross_entropy_loss(logits, batch["labels"], cfg.final_softcap)


def init_cache(cfg, batch: int, max_len: int, mesh_ctx=None):
    n_apps = n_shared_applications(cfg)
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dt = cfg.activation_dtype
    return {
        "shared_kv": {
            "k": jnp.zeros((n_apps, batch, max_len, kv, hd), dt),
            "v": jnp.zeros((n_apps, batch, max_len, kv, hd), dt),
        },
        "conv": jnp.zeros(
            (cfg.n_layers, batch, cfg.ssm.d_conv - 1,
             cfg.ssm.expand * cfg.d_model), dt
        ),
        "ssd": jnp.zeros(
            (cfg.n_layers, batch,
             cfg.ssm.expand * cfg.d_model // cfg.ssm.head_dim,
             cfg.ssm.d_state, cfg.ssm.head_dim), dt
        ),
        # the prompt's first-token embedding is re-injected at every
        # shared block; for decode we carry the *current* token embedding
        # (training concatenates per-position embeddings)
    }


def prefill(params, batch, cfg, mesh_ctx=None, max_len=None):
    x = params["embed"].astype(cfg.activation_dtype)[batch["tokens"]]
    if mesh_ctx is not None:
        x = mesh_ctx.constrain_hidden(x)
    emb = x
    s = x.shape[1]
    max_len = max_len or s
    b = x.shape[0]
    cache = init_cache(cfg, b, max_len, mesh_ctx)
    shared_k, shared_v = [], []

    def mamba_body(x, p):
        x, (conv_s, ssd_s) = M2.mamba2_block_apply(p, x, cfg)
        return x, (conv_s, ssd_s)

    convs, ssds = [], []
    for gi, (start, length) in enumerate(_groups(cfg)):
        p_shared = params["shared"][gi % cfg.n_shared_blocks]
        x, (k, v) = _shared_apply(p_shared, x, emb, cfg)
        pad = max_len - k.shape[1]
        if pad > 0:
            zk = jnp.zeros((b, pad) + k.shape[2:], k.dtype)
            k = jnp.concatenate([k, zk], 1)
            v = jnp.concatenate([v, zk], 1)
        shared_k.append(k)
        shared_v.append(v)
        x, (conv_s, ssd_s) = jax.lax.scan(
            mamba_body, x, _slice_group(params["mamba"], start, length)
        )
        convs.append(conv_s)
        ssds.append(ssd_s)
    cache["shared_kv"]["k"] = jnp.stack(shared_k)
    cache["shared_kv"]["v"] = jnp.stack(shared_v)
    cache["conv"] = jnp.concatenate(convs, axis=0)
    cache["ssd"] = jnp.concatenate(ssds, axis=0)
    h = rms_norm(x[:, -1:, :], params["final_norm"], cfg.norm_eps)
    logits = (h @ params["head"].astype(h.dtype))[:, 0, :]
    return logits, cache


def decode_step(params, cache, cache_len, batch, cfg, mesh_ctx=None):
    x = params["embed"].astype(cfg.activation_dtype)[batch["tokens"]]
    if mesh_ctx is not None:
        x = mesh_ctx.constrain_hidden(x)
    emb = x

    def mamba_body(x, inputs):
        p, conv_s, ssd_s = inputs
        x, (conv_new, ssd_new) = M2.mamba2_block_decode(
            p, x, cfg, conv_s, ssd_s
        )
        return x, (conv_new, ssd_new)

    new_sk, new_sv, new_conv, new_ssd = [], [], [], []
    for gi, (start, length) in enumerate(_groups(cfg)):
        p_shared = params["shared"][gi % cfg.n_shared_blocks]
        c = {
            "k": cache["shared_kv"]["k"][gi],
            "v": cache["shared_kv"]["v"][gi],
        }
        x, c_new = _shared_apply(p_shared, x, emb, cfg, c, cache_len)
        new_sk.append(c_new["k"])
        new_sv.append(c_new["v"])
        x, (conv_new, ssd_new) = jax.lax.scan(
            mamba_body,
            x,
            (
                _slice_group(params["mamba"], start, length),
                cache["conv"][start : start + length],
                cache["ssd"][start : start + length],
            ),
        )
        new_conv.append(conv_new)
        new_ssd.append(ssd_new)
    new_cache = {
        "shared_kv": {"k": jnp.stack(new_sk), "v": jnp.stack(new_sv)},
        "conv": jnp.concatenate(new_conv, axis=0),
        "ssd": jnp.concatenate(new_ssd, axis=0),
    }
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (h @ params["head"].astype(h.dtype))[:, 0, :]
    return logits, new_cache
