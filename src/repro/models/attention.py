"""GQA attention: train (full-sequence causal), prefill, and decode-step
paths, with the variant knobs the assigned archs need — qk-norm (Qwen3),
attention logit soft-capping (Gemma-2), sliding windows (Gemma-2 local
layers / Mistral), and optional flash-style KV chunking (perf lever).

Shapes: q (B, S, H, D); k, v (B, Skv, KV, D) with H % KV == 0.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, rms_norm, rope, softcap

__all__ = ["attn_init", "attention_scores_apply", "attn_apply", "decode_attn_apply"]

NEG_INF = -2.0**30  # large-negative fill that survives bf16 softmax


def attn_init(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
              qk_norm: bool):
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (d_model, n_heads, head_dim)),
        "wk": dense_init(ks[1], (d_model, n_kv, head_dim)),
        "wv": dense_init(ks[2], (d_model, n_kv, head_dim)),
        "wo": dense_init(
            ks[3], (n_heads, head_dim, d_model), fan_in=n_heads * head_dim
        ),
    }
    if qk_norm:
        p["q_norm"] = jnp.zeros((head_dim,), jnp.float32)
        p["k_norm"] = jnp.zeros((head_dim,), jnp.float32)
    return p


def _mask(
    q_pos: jnp.ndarray,  # (S,) or (B, S)
    kv_pos: jnp.ndarray,  # (Skv,)
    causal: bool,
    window: Optional[int],
    kv_len: Optional[jnp.ndarray],  # scalar: valid cache length
):
    """Boolean (…, S, Skv) mask of allowed attention edges."""
    qp = q_pos[..., :, None]
    kp = kv_pos[None, :]
    m = jnp.ones(jnp.broadcast_shapes(qp.shape, (kv_pos.shape[0],)), bool)
    if causal:
        m = m & (kp <= qp)
    if window is not None:
        m = m & (kp > qp - window)
    if kv_len is not None:
        m = m & (kp < kv_len)
    return m


def _sdpa(q, k, v, mask, scale, cap, chunk, head_pad=None, mesh_ctx=None):
    """q (B,S,H,D), k/v (B,Skv,KV,D), mask (S,Skv) or (B,S,Skv).

    GQA is materialized by repeating KV heads to H before the einsums:
    every contraction then carries the intact head axis, which shards
    cleanly over the "model" mesh axis. (Splitting H into (KV, G) inside
    the einsum makes the head sharding inexpressible to GSPMD — measured
    16x replicated attention compute on the 16-way axis; see
    EXPERIMENTS.md §Dry-run.)

    head_pad: zero-pad the head axis to this count before the einsums
    and slice the pad off after — pure layout, zero semantic change.
    24-head stacks on a 16-way model axis otherwise hit GSPMD's
    "involuntary full rematerialization" (measured 6-10x memory term on
    musicgen/phi4/granite; EXPERIMENTS.md §Perf).
    """
    b, s, h, d = q.shape
    skv, kv = k.shape[1], k.shape[2]
    g = h // kv  # query groups per kv head
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    h_real = h
    if head_pad is not None and head_pad > h:
        def pad_heads(t):
            z = jnp.zeros(
                t.shape[:2] + (head_pad - h,) + t.shape[3:], t.dtype
            )
            return jnp.concatenate([t, z], axis=2)

        q, k, v = pad_heads(q), pad_heads(k), pad_heads(v)
        h = head_pad
        if mesh_ctx is not None:
            q = mesh_ctx.constrain_heads(q)
            k = mesh_ctx.constrain_heads(k)
            v = mesh_ctx.constrain_heads(v)
    if mask.ndim == 2:
        mask = mask[None]
    mask_b = mask[:, None, :, :]  # (B,1,S,Skv)

    def block_scores(k_blk, mask_blk):
        sc = jnp.einsum("bshd,bthd->bhst", q, k_blk) * scale
        sc = softcap(sc, cap)
        return jnp.where(mask_blk, sc, NEG_INF)

    if chunk is None or skv <= chunk:
        sc = block_scores(k, mask_b)
        w = jax.nn.softmax(sc.astype(jnp.float32), axis=-1).astype(q.dtype)
        return jnp.einsum("bhst,bthd->bshd", w, v)[:, :, :h_real]

    # flash-style streaming softmax over KV chunks (perf lever;
    # numerically identical up to fp accumulation order)
    n_blk = skv // chunk
    kb = k.reshape(b, n_blk, chunk, h, d)
    vb = v.reshape(b, n_blk, chunk, h, d)

    def body(carry, inputs):
        m_run, l_run, acc = carry
        k_blk, v_blk, idx = inputs
        mask_blk = jax.lax.dynamic_slice_in_dim(
            mask_b, idx * chunk, chunk, axis=-1
        )
        sc = block_scores(k_blk, mask_blk).astype(jnp.float32)
        m_new = jnp.maximum(m_run, sc.max(axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(sc - m_new[..., None])
        l_new = l_run * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhst,bthd->bhsd", p.astype(q.dtype), v_blk
        ).astype(jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, h, s), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, s), jnp.float32)
    a0 = jnp.zeros((b, h, s, d), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(
        body,
        (m0, l0, a0),
        (
            jnp.moveaxis(kb, 1, 0),
            jnp.moveaxis(vb, 1, 0),
            jnp.arange(n_blk),
        ),
    )
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return jnp.moveaxis(out, 2, 1).astype(q.dtype)[:, :, :h_real]


def _sdpa_grouped(q, k, v, mask, scale, cap):
    """Decode-step attention (S_q == 1): grouped-query einsums read each
    KV head exactly once (no repeat — the KV cache read IS the decode
    roofline). q is tiny and replicated over "model"; the cache shards on
    its sequence axis, so the softmax reduces over a sharded dim —
    flash-decoding realized by GSPMD collectives."""
    b, s, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, d)
    sc = jnp.einsum("bskgd,btkd->bkgst", qg, k) * scale
    sc = softcap(sc, cap)
    sc = jnp.where(mask[:, None, None, :, :], sc, NEG_INF)
    w = jax.nn.softmax(sc.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(b, s, h, d)


def _project_qkv(p, x, cfg, positions):
    dt = x.dtype
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"].astype(dt))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = rope(positions, cfg.resolved_head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def attn_apply(
    p,
    x: jnp.ndarray,  # (B, S, d)
    cfg,
    window: Optional[int] = None,
    cache: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    mesh_ctx=None,
):
    """Training / prefill attention. Returns (out, (k, v)) — the kv pair
    becomes the prefill cache."""
    b, s, _ = x.shape
    positions = jnp.arange(s)
    q, k, v = _project_qkv(p, x, cfg, positions)
    mask = _mask(positions, positions, True, window, None)
    scale = 1.0 / math.sqrt(cfg.resolved_head_dim)
    out = _sdpa(
        q, k, v, mask, scale, cfg.attn_softcap, cfg.attn_chunk,
        head_pad=cfg.attn_head_pad, mesh_ctx=mesh_ctx,
    )
    out = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(x.dtype))
    return out, (k, v)


def decode_attn_apply(
    p,
    x: jnp.ndarray,  # (B, 1, d)
    cfg,
    k_cache: jnp.ndarray,  # (B, Smax, KV, D)
    v_cache: jnp.ndarray,
    cache_len: jnp.ndarray,  # scalar int32: tokens already in cache
    ring: bool = False,
):
    """Single decode step. ring=False: append at cache_len, attend the
    causal prefix (global layers). ring=True: the cache is a sliding-
    window ring buffer of size Smax == window; insert at cache_len % Smax
    and attend every valid slot (keys carry absolute RoPE, so slot order
    is irrelevant). Returns (out, k_cache, v_cache)."""
    s_max = k_cache.shape[1]
    positions = cache_len[None]  # (1,) absolute position for RoPE
    q, k_new, v_new = _project_qkv(p, x, cfg, positions)
    ins = cache_len % s_max if ring else cache_len
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), ins, axis=1
    )
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), ins, axis=1
    )
    kv_pos = jnp.arange(s_max)
    if ring:
        mask = (kv_pos < jnp.minimum(cache_len + 1, s_max))[None, None, :]
    else:
        mask = _mask(positions, kv_pos, True, None, cache_len + 1)[None]
    scale = 1.0 / math.sqrt(cfg.resolved_head_dim)
    out = _sdpa_grouped(
        q,
        k_cache.astype(q.dtype),
        v_cache.astype(q.dtype),
        jnp.broadcast_to(mask, (x.shape[0], 1, s_max)),
        scale,
        cfg.attn_softcap,
    )
    out = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(x.dtype))
    return out, k_cache, v_cache
