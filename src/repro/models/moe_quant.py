"""Serving-time int8 quantization of MoE expert FFN banks (LM side).

Expert banks are stored as int8 codes + one fp32 absmax scale per
last-dim row and dequantized on the fly inside the expert matmuls
(`repro.models.moe._expert_ffn`), halving decode-step HBM traffic. Used
by the pjit'd LM serving programs of `repro.serving.serve_loop` when
``arch_cfg.serve_quant`` is set.

This lived in `repro.serving.quantize` until PR 5; that module is now
the KWS classifier's quantizer only (`quantize_classifier` — the
paper's WMEM image), and the MoE walker moved here next to its one
consumer.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "dequant_weight",
    "quantize_expert_params",
    "quantize_expert_shapes",
]

_QUANT_NAMES = ("w_up", "w_gate", "w_down")


def _quant_leaf(x: jnp.ndarray):
    x32 = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x32), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "s": scale.astype(jnp.float32)}


def dequant_weight(w, dtype):
    """Transparent accessor used by the expert matmuls."""
    if isinstance(w, dict) and "q" in w:
        return (w["q"].astype(jnp.float32) * w["s"]).astype(dtype)
    return w.astype(dtype)


def quantize_expert_params(params: Any) -> Any:
    """Quantize MoE expert banks in a param tree (serving only)."""

    def walk(node, under_moe=False):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if under_moe and k in _QUANT_NAMES and not isinstance(v, dict):
                    out[k] = _quant_leaf(v)
                else:
                    out[k] = walk(
                        v, (under_moe or k == "moe") and k != "shared"
                    )
            return out
        if isinstance(node, list):
            return [walk(v, under_moe) for v in node]
        return node

    return walk(params)


def quantize_expert_shapes(params_shape: Any) -> Any:
    """Abstract (ShapeDtypeStruct) version for dry-run lowering."""

    def walk(node, under_moe=False):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if under_moe and k in _QUANT_NAMES and not isinstance(v, dict):
                    out[k] = {
                        "q": jax.ShapeDtypeStruct(v.shape, jnp.int8),
                        "s": jax.ShapeDtypeStruct(
                            v.shape[:-1] + (1,), jnp.float32
                        ),
                    }
                else:
                    out[k] = walk(
                        v, (under_moe or k == "moe") and k != "shared"
                    )
            return out
        if isinstance(node, list):
            return [walk(v, under_moe) for v in node]
        return node

    return walk(params_shape)
