"""Backbone registry: cfg.backbone -> module implementing the model API
(init_params / forward / loss_fn / init_cache / prefill / decode_step)."""

from __future__ import annotations

from repro.models import transformer


def get_backbone(cfg):
    if cfg.backbone == "transformer":
        return transformer
    if cfg.backbone == "mamba2":
        from repro.models import mamba2

        return mamba2
    if cfg.backbone == "zamba2":
        from repro.models import zamba2

        return zamba2
    if cfg.backbone == "rwkv6":
        from repro.models import rwkv6

        return rwkv6
    raise KeyError(f"unknown backbone {cfg.backbone!r}")
