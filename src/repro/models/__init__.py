from repro.models.registry import get_backbone

__all__ = ["get_backbone"]
